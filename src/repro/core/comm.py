"""Communicator-centric collective API (PID-Comm §IV, Table II, §IX-A).

This module is the single choke point through which every collective in the
repo is planned, dispatched and observed:

  ``cube.comm(dims)``
      binds a :class:`~repro.core.hypercube.Hypercube` and a resolved dim
      selection into a :class:`Communicator` handle, caching the group size,
      the fast/slow (ICI/DCN) split and the instance count once, and exposes
      the eight PID-Comm primitives as methods.

  algorithm registry
      every executable flow is a registered algorithm --
      ``@register_algorithm("all_to_all", "im")``.  The paper's Table II
      ablation stages (``naive``/``pr``/``im``/``cm``) are registered per
      primitive, and the applicability table is *derived from the registry*
      rather than maintained by hand.  First-class non-stage algorithms ride
      the same rails: the §IX-A ``hierarchical`` split, the §V-C int8
      ``compressed`` DCN flow, and the Fig. 23(a) ``ring`` / ``tree``
      topology comparators.

  plan-driven dispatch
      ``algorithm="auto"`` (the default) consults the analytic planner at
      trace time -- payload shapes are static under jit -- so the executed
      flow (direct vs hierarchical vs naive) is the cost model's pick.  This
      unifies :mod:`repro.core.planner` with the runtime: what the planner
      estimates is what the communicator lowers.

  instrumentation
      every dispatch appends a :class:`CommEvent` (primitive, bitmap, chosen
      flow/stage, estimated ICI/DCN bytes and seconds) to any active
      :class:`CommTrace` context.  ``launch/dryrun.py`` and the benchmark
      harness consume the trace for their ``derived`` columns.

The legacy :class:`repro.core.collectives.Collectives` class survives as a
thin deprecated shim delegating here, so the conformance matrix runs
bit-identically through either surface.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import planner
from repro.core.hypercube import Hypercube
from repro.telemetry import metrics as _telemetry

Array = jax.Array

# Canonical Table II stage ladder, weakest to strongest.
STAGE_ORDER = ("naive", "pr", "im", "cm")

PRIMITIVES = ("all_to_all", "reduce_scatter", "all_reduce", "all_gather",
              "scatter", "gather", "reduce", "broadcast")

_REDUCERS = {
    "add": (lax.psum, jnp.sum, jnp.add),
    "max": (lax.pmax, jnp.max, jnp.maximum),
    "min": (lax.pmin, jnp.min, jnp.minimum),
}


# ============================================================ the registry
@dataclasses.dataclass(frozen=True)
class AlgorithmSpec:
    """One registered collective flow."""
    primitive: str
    name: str            # registry key ("im", "hierarchical", "ring", ...)
    stage: str           # the Table II stage this flow maps onto
    table_ii: bool       # counts toward the derived applicability table
    fn: Callable         # body: fn(comm, x, **kwargs) -> Array


_REGISTRY: dict[str, dict[str, AlgorithmSpec]] = {p: {} for p in PRIMITIVES}
_APPLICABILITY_CACHE: dict[str, tuple[str, ...]] | None = None


def register_algorithm(primitive: str, name: str, *, stage: str | None = None,
                       table_ii: bool | None = None):
    """Decorator registering a collective algorithm body.

    ``stage`` defaults to ``name`` when the name is a Table II stage;
    ``table_ii`` defaults to True exactly for stage names, so extras
    (``hierarchical``, ``compressed``, ``ring``, ``tree``) do not widen the
    paper's applicability table.
    """
    if primitive not in _REGISTRY:
        raise ValueError(f"unknown primitive {primitive!r}")
    is_stage = name in STAGE_ORDER
    if stage is None:
        if not is_stage:
            raise ValueError(f"algorithm {name!r} needs an explicit stage=")
        stage = name
    if table_ii is None:
        table_ii = is_stage

    def deco(fn):
        global _APPLICABILITY_CACHE
        if name in _REGISTRY[primitive]:
            raise ValueError(
                f"algorithm {name!r} already registered for {primitive!r}")
        _REGISTRY[primitive][name] = AlgorithmSpec(
            primitive=primitive, name=name, stage=stage,
            table_ii=table_ii, fn=fn)
        _APPLICABILITY_CACHE = None
        return fn

    return deco


def get_algorithm(primitive: str, name: str) -> AlgorithmSpec:
    try:
        return _REGISTRY[primitive][name]
    except KeyError:
        raise ValueError(
            f"no algorithm {name!r} registered for {primitive!r}; have "
            f"{sorted(_REGISTRY.get(primitive, ()))}") from None


def registered_algorithms(primitive: str) -> tuple[str, ...]:
    return tuple(_REGISTRY[primitive])


def applicability() -> dict[str, tuple[str, ...]]:
    """Paper Table II, derived from the registry: the ordered tuple of
    optimization stages registered (as ``table_ii``) per primitive.  Cached
    until the next registration (resolve_stage consults it per dispatch)."""
    global _APPLICABILITY_CACHE
    if _APPLICABILITY_CACHE is None:
        out = {}
        for prim, algs in _REGISTRY.items():
            stages = {a.name for a in algs.values() if a.table_ii}
            out[prim] = tuple(s for s in STAGE_ORDER if s in stages)
        _APPLICABILITY_CACHE = out
    return _APPLICABILITY_CACHE


def resolve_stage(primitive: str, algorithm: str) -> str:
    """Resolve an algorithm request against Table II: ``pidcomm`` means the
    strongest applicable stage; an inapplicable request falls back to the
    strongest applicable stage at or below it."""
    stages = applicability()[primitive]
    if algorithm == "pidcomm":
        return stages[-1]
    if algorithm not in STAGE_ORDER:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    req = STAGE_ORDER.index(algorithm)
    best = stages[0]
    for s in stages:
        if STAGE_ORDER.index(s) <= req:
            best = s
    return best


# ppermute ladders get HLO-quadratic beyond this group size; the dispatcher
# falls through to the fused native collective there (the schedules coincide
# anyway).  Tunable: monkeypatch ``comm._LADDER_MAX`` (the legacy shim
# re-exposes it read-only as ``collectives._LADDER_MAX``).
_LADDER_MAX = 32


# ======================================================== instrumentation
@dataclasses.dataclass(frozen=True)
class CommEvent:
    """One dispatched collective, recorded at trace time."""
    primitive: str
    bitmap: str                  # dim selection in paper bitmap form
    dims: tuple[str, ...]
    algorithm: str               # what the caller requested ("auto", ...)
    flow: str                    # the registry algorithm actually executed
    stage: str                   # Table II stage of that flow
    group_size: int
    num_instances: int
    payload_bytes: int           # per-device payload
    ici_bytes: float             # planner estimate, per device
    dcn_bytes: float
    seconds: float
    # deferred-program provenance (repro.core.program): the CommProgram this
    # dispatch executed under, and the recorded op ids a fused/coalesced op
    # was rewritten from.  Empty for eager dispatches.
    program_id: str | None = None
    fused_from: tuple[int, ...] = ()
    # estimate provenance: "analytic" (hardcoded v5e constants) or
    # "measured" (an installed repro.tuning CommProfile priced this flow).
    est_source: str = "analytic"


_TRACES: list["CommTrace"] = []


class CommTrace:
    """Context manager collecting :class:`CommEvent` s from every dispatch.

    Dispatch happens at trace time (shapes are static under jit), so one
    traced program records each textual collective call site once -- the
    trace is the *planned schedule*, not an execution count.
    """

    def __init__(self):
        self.events: list[CommEvent] = []

    def __enter__(self) -> "CommTrace":
        _TRACES.append(self)
        return self

    def __exit__(self, *exc):
        _TRACES.remove(self)
        return False

    def record(self, event: CommEvent) -> None:
        self.events.append(event)

    def total_bytes(self) -> tuple[float, float]:
        return (sum(e.ici_bytes for e in self.events),
                sum(e.dcn_bytes for e in self.events))

    def summary(self) -> dict:
        """JSON-serializable per-(primitive, flow) aggregate."""
        by: dict[str, dict] = {}
        for e in self.events:
            d = by.setdefault(f"{e.primitive}/{e.flow}", {
                "count": 0, "stage": e.stage, "payload_bytes": 0,
                "ici_bytes": 0.0, "dcn_bytes": 0.0, "est_seconds": 0.0,
                "est_source": e.est_source})
            d["count"] += 1
            d["payload_bytes"] += e.payload_bytes
            d["ici_bytes"] += e.ici_bytes
            d["dcn_bytes"] += e.dcn_bytes
            d["est_seconds"] += e.seconds
            if d["est_source"] != e.est_source:
                d["est_source"] = "mixed"
        ici, dcn = self.total_bytes()
        fused = [e for e in self.events if e.fused_from]
        sources: dict[str, int] = {}
        for e in self.events:
            sources[e.est_source] = sources.get(e.est_source, 0) + 1
        return {"events": len(self.events), "ici_bytes": ici,
                "dcn_bytes": dcn, "by_flow": by,
                "est_sources": sources,
                "fused_events": len(fused),
                "fused_from_ops": sum(len(e.fused_from) for e in fused),
                "programs": sorted({e.program_id for e in self.events
                                    if e.program_id})}


def _emit(event: CommEvent) -> None:
    for t in _TRACES:
        t.record(event)


# ========================================================== communicator
def _payload_bytes(x) -> int:
    """Per-device payload bytes of ``x`` -- static at trace time."""
    size = int(getattr(x, "size", 1))
    dtype = getattr(x, "dtype", None)
    return size * (dtype.itemsize if dtype is not None else 4)


# planner algorithm each executed flow corresponds to, for the estimates
# attached to CommEvents.
_FLOW_TO_PLANNER = {
    "naive": "naive",
    "hierarchical": "pidcomm",
    "compressed": "compressed",
    "ring_fused": "ring_fused",
    "ag_prologue": "ag_prologue",
    "rs_epilogue": "rs_epilogue",
}

# compute-fused flows (repro.kernels.collective) the auto planner may pick
# when a measured profile prices them cheaper than the unfused stages
_FUSED_FLOWS = frozenset(("ring_fused", "ag_prologue", "rs_epilogue"))


def program_mod():
    """Deferred import of :mod:`repro.core.program` (cycle: program records
    through Communicator dispatch)."""
    from repro.core import program
    return program


class Communicator:
    """The eight PID-Comm primitives bound to one (cube, dim selection).

    Built via :meth:`repro.core.hypercube.Hypercube.comm`.  PE<->PE
    primitives (all_to_all / reduce_scatter / all_reduce / all_gather) are
    per-shard functions usable only inside ``shard_map`` over ``cube.mesh``;
    rooted primitives (scatter / gather / reduce / broadcast) operate at the
    jit boundary with the host as root (paper §IV-B3).

    ``algorithm`` per call (or ``default_algorithm`` at construction) is one
    of ``"auto"`` (planner-driven), ``"pidcomm"``, a Table II stage name, or
    a first-class registered algorithm (``"hierarchical"``, ``"compressed"``,
    ``"ring"``, ``"tree"``).
    """

    def __init__(self, cube: Hypercube, dims, *,
                 default_algorithm: str = "auto"):
        self.cube = cube
        self.dims: tuple[str, ...] = cube.resolve_dims(dims)
        self.bitmap = "".join(
            "1" if d in self.dims else "0" for d in cube.dim_names)
        self.group_size: int = cube.group_size(self.dims)
        self.num_instances: int = cube.num_instances(self.dims)
        self.fast_dims, self.slow_dims = cube.split_fast_slow(self.dims)
        self.crosses_dcn: bool = bool(self.slow_dims)
        self.default_algorithm = default_algorithm

    # ------------------------------------------------------------- helpers
    @property
    def ax(self) -> tuple[str, ...]:
        """The lax axis-name tuple of this group."""
        return self.dims

    def axis_index(self):
        """Linearized index of this shard within its group (shard_map)."""
        return lax.axis_index(self.dims)

    def describe(self) -> str:
        return (f"Communicator[{self.cube.describe()} dims={self.bitmap} "
                f"g={self.group_size} inst={self.num_instances} "
                f"slow={self.slow_dims or '()'}]")

    def program(self, *, name: str = ""):
        """Open a deferred :class:`repro.core.program.CommProgram` recording
        scope over this communicator's cube (any communicator of the same
        cube may record into it -- multi-communicator mixes included)."""
        return program_mod().CommProgram(self.cube, name=name)

    # ------------------------------------------------------------ dispatch
    def _resolve_flow(self, primitive: str, algorithm: str,
                      payload_bytes: int, op: str = "add"):
        """Map an algorithm request onto a registry flow name.  Returns
        (flow_name, planner_estimate_or_None)."""
        if algorithm == "auto":
            est = planner.plan(self.cube, primitive, self.dims, payload_bytes)
            if est.algorithm == "naive":
                return "naive", est
            if (est.algorithm == "hierarchical" and primitive == "all_reduce"
                    and op == "add"):
                return "hierarchical", est
            if (est.algorithm in _FUSED_FLOWS
                    and est.algorithm in _REGISTRY[primitive]):
                # a measured profile priced a compute-fused ring flow
                # cheaper than the unfused stages; run it as-is (without a
                # consumer/tile_fn the bodies are plain ring collectives)
                return est.algorithm, est
            if est.algorithm != "direct":
                # the planner's pick is not executable here (e.g. a
                # hierarchical split for a non-additive op); drop its
                # estimate so the trace reflects the flow actually run
                est = None
            return self._escalate(primitive,
                                  resolve_stage(primitive, "pidcomm"),
                                  op), est
        if algorithm == "pidcomm" or algorithm in STAGE_ORDER:
            return self._escalate(primitive,
                                  resolve_stage(primitive, algorithm),
                                  op), None
        if algorithm in _REGISTRY[primitive]:
            return algorithm, None
        raise ValueError(
            f"unknown algorithm {algorithm!r} for {primitive!r}; expected "
            f"'auto', 'pidcomm', a stage {STAGE_ORDER}, or one of "
            f"{sorted(_REGISTRY[primitive])}")

    def _escalate(self, primitive: str, stage: str, op: str) -> str:
        """Stage-level escalations that depend on the bound group:
        * all_to_all ``im`` ladders get HLO-quadratic beyond ``_LADDER_MAX``
          (or on multi-dim groups) and fall through to the fused ``cm``;
        * a DCN-crossing additive ``im`` all_reduce takes the §IX-A
          hierarchical split."""
        if (primitive == "all_to_all" and stage == "im"
                and (self.group_size > _LADDER_MAX or len(self.dims) > 1)):
            return "cm"
        if (primitive == "all_reduce" and stage == "im" and op == "add"
                and self.fast_dims and self.slow_dims):
            return "hierarchical"
        return stage

    def _dispatch(self, primitive: str, x, *, algorithm: str | None,
                  op: str = "add", _meta: tuple | None = None, **kwargs):
        alg = self.default_algorithm if algorithm is None else algorithm
        rec = program_mod().active_program()
        if rec is not None:
            # deferred mode: append a CommOp to the recording program
            # instead of dispatching; execution re-enters here with
            # recording suspended and ``_meta`` carrying provenance.
            return rec.record_op(self, primitive, x, algorithm=alg, op=op,
                                 kwargs=kwargs)
        payload = _payload_bytes(x)
        flow, est = self._resolve_flow(primitive, alg, payload, op)
        spec = get_algorithm(primitive, flow)
        if _TRACES or _telemetry.enabled():
            if est is None:
                est = planner.estimate(
                    self.cube, primitive, self.dims, payload,
                    algorithm=_FLOW_TO_PLANNER.get(flow, "direct"))
            _telemetry.inc("comm.dispatches")
            _telemetry.inc(f"comm.est_source.{est.est_source}")
        if _TRACES:
            program_id, fused_from = _meta if _meta else (None, ())
            _emit(CommEvent(
                primitive=primitive, bitmap=self.bitmap, dims=self.dims,
                algorithm=alg, flow=flow, stage=spec.stage,
                group_size=self.group_size,
                num_instances=self.num_instances, payload_bytes=payload,
                ici_bytes=est.ici_bytes, dcn_bytes=est.dcn_bytes,
                seconds=est.seconds, program_id=program_id,
                fused_from=tuple(fused_from), est_source=est.est_source))
        return spec.fn(self, x, op=op, **kwargs) \
            if primitive in ("all_reduce", "reduce_scatter", "reduce") \
            else spec.fn(self, x, **kwargs)

    # ---------------------------------------------------- PE<->PE primitives
    def all_to_all(self, x: Array, *, split_axis: int, concat_axis: int,
                   algorithm: str | None = None) -> Array:
        if self.group_size == 1:
            return x
        return self._dispatch("all_to_all", x, algorithm=algorithm,
                              split_axis=split_axis, concat_axis=concat_axis)

    def reduce_scatter(self, x: Array, *, axis: int, op: str = "add",
                       algorithm: str | None = None) -> Array:
        if self.group_size == 1:
            return x
        return self._dispatch("reduce_scatter", x, algorithm=algorithm,
                              op=op, axis=axis)

    def all_gather(self, x: Array, *, axis: int,
                   algorithm: str | None = None) -> Array:
        if self.group_size == 1:
            return x
        return self._dispatch("all_gather", x, algorithm=algorithm, axis=axis)

    def all_reduce(self, x: Array, *, op: str = "add",
                   algorithm: str | None = None) -> Array:
        if self.group_size == 1:
            return x
        return self._dispatch("all_reduce", x, algorithm=algorithm, op=op)

    def all_reduce_with_error(self, x: Array, *, error: Array | None = None,
                              block: int = 256) -> tuple[Array, Array]:
        """§V-C compressed (int8 DCN hop) additive all-reduce that also
        returns the local quantization error, for callers that persist an
        error-feedback buffer across steps (``runtime.trainer``).

        ``error`` is the previous step's returned error (replicated within
        the fast/ICI group, per-pod values).  It is folded in scaled by
        1/|ICI|: the fast-domain reduce inside the flow sums the |ICI|
        replicas back to exactly one correction per pod.

        Always dispatches eagerly (even inside a program recording scope:
        the two-output flow has no registry body) and records a
        ``compressed`` CommEvent like the single-output registry algorithm.
        """
        from repro.core import compress
        if not self.slow_dims:
            raise ValueError(
                "all_reduce_with_error needs a DCN-crossing group; "
                f"{self.dims} is entirely intra-pod")
        if error is not None:
            gf = self.cube.group_size(self.fast_dims) if self.fast_dims \
                else 1
            x = x + error / gf
        payload = _payload_bytes(x)
        if _TRACES or _telemetry.enabled():
            est = planner.estimate(self.cube, "all_reduce", self.dims,
                                   payload, algorithm="compressed",
                                   block=block)
            _telemetry.inc("comm.dispatches")
            _telemetry.inc(f"comm.est_source.{est.est_source}")
        if _TRACES:
            _emit(CommEvent(
                primitive="all_reduce", bitmap=self.bitmap, dims=self.dims,
                algorithm="compressed", flow="compressed", stage="cm",
                group_size=self.group_size,
                num_instances=self.num_instances, payload_bytes=payload,
                ici_bytes=est.ici_bytes, dcn_bytes=est.dcn_bytes,
                seconds=est.seconds, est_source=est.est_source))
        return compress.compressed_pod_all_reduce(
            x, self.cube, self.fast_dims, self.slow_dims, block=block)

    # ------------------------------------------------- rooted (host) four
    def scatter(self, host_value, *, axis: int | None = None,
                spec: tuple | None = None,
                algorithm: str | None = None):
        """Host -> PEs: partition ``host_value`` along ``axis`` over the
        bound dims, or — when ``spec`` is given instead — place it under a
        full PartitionSpec-shaped tuple (entries ``None`` / dim name / tuple
        of dim names per array axis).  The ``spec`` form is what elastic
        checkpoint restore records: one rooted scatter per leaf carrying the
        leaf's complete target sharding."""
        if (axis is None) == (spec is None):
            raise ValueError("scatter takes exactly one of axis= or spec=")
        if spec is not None:
            return self._dispatch("scatter", host_value, algorithm=algorithm,
                                  spec=tuple(spec))
        return self._dispatch("scatter", host_value, algorithm=algorithm,
                              axis=axis)

    def broadcast(self, host_value, *, algorithm: str | None = None):
        """Host -> PEs: replicate to every node of the cube."""
        return self._dispatch("broadcast", host_value, algorithm=algorithm)

    def gather(self, x, *, algorithm: str | None = None):
        """PEs -> host: materialize the global array in host memory."""
        return self._dispatch("gather", x, algorithm=algorithm)

    def reduce(self, x, *, op: str = "add", axis: int = 0,
               algorithm: str | None = None):
        """PEs -> host: reduction over the sharded axis, result on host."""
        return self._dispatch("reduce", x, algorithm=algorithm, op=op,
                              axis=axis)


# ===================================================== algorithm bodies
# Block-layout helpers shared by the bodies.
def _split_axis_to_front(x: Array, axis: int, groups: int) -> Array:
    """(..., G*b, ...) -> (G, ..., b, ...)."""
    shape = x.shape
    if shape[axis] % groups:
        raise ValueError(f"axis {axis} of {shape} not divisible by {groups}")
    b = shape[axis] // groups
    new = shape[:axis] + (groups, b) + shape[axis + 1:]
    return jnp.moveaxis(x.reshape(new), axis, 0)


def _merge_front_blocks(x: Array, axis: int) -> Array:
    """Inverse of `_split_axis_to_front`: (G, ..., b, ...) -> (..., G*b, ...)."""
    x = jnp.moveaxis(x, 0, axis)
    shape = x.shape
    return x.reshape(shape[:axis] + (shape[axis] * shape[axis + 1],)
                     + shape[axis + 2:])


# ----------------------------------------------------------- all_to_all
@register_algorithm("all_to_all", "naive")
def _aa_naive(comm, x, *, split_axis, concat_axis):
    # replicated intermediate over the group ("host buffer"), then per-word
    # modulation -- data-dependent gather over the flattened buffer (the
    # host rearranging word by word).
    g, ax = comm.group_size, comm.ax
    blocks = _split_axis_to_front(x, split_axis, g)            # (G, ..., b, ..)
    gathered = compat.all_gather(blocks, ax, axis=0, tiled=False)  # (G, G, ..)
    me = lax.axis_index(ax)
    idx = jnp.arange(g) * g + me
    flat = gathered.reshape((g * g,) + gathered.shape[2:])
    mine = jnp.take(flat, idx, axis=0)
    return _merge_front_blocks(mine, concat_axis)


@register_algorithm("all_to_all", "pr")
def _aa_pr(comm, x, *, split_axis, concat_axis):
    # PE-assisted reordering: sources pre-arranged their blocks so the
    # mediator extracts one column with a single dynamic slice.
    g, ax = comm.group_size, comm.ax
    blocks = _split_axis_to_front(x, split_axis, g)
    gathered = compat.all_gather(blocks, ax, axis=0, tiled=False)
    me = lax.axis_index(ax)
    mine = lax.dynamic_index_in_dim(
        jnp.swapaxes(gathered, 0, 1), me, axis=0, keepdims=False)
    return _merge_front_blocks(mine, concat_axis)


@register_algorithm("all_to_all", "im")
def _aa_ladder(comm, x, *, split_axis, concat_axis):
    """(G-1)-step ppermute ladder: one destination block per step, no
    replicated intermediate (in-register modulation analogue)."""
    g, ax = comm.group_size, comm.ax
    blocks = _split_axis_to_front(x, split_axis, g)
    me = lax.axis_index(ax)
    received = [lax.dynamic_index_in_dim(blocks, me, axis=0)]  # own block
    for step in range(1, g):
        # i sends its block destined for (i - step); it lands on (i - step)
        perm = [(i, (i - step) % g) for i in range(g)]
        send = lax.dynamic_index_in_dim(blocks, (me - step) % g, axis=0)
        received.append(lax.ppermute(send, ax, perm))
    stacked = jnp.concatenate(received, axis=0)  # slot s <- source (me+s)%g
    idx = (jnp.arange(g) - me) % g               # out[j] = slot (j-me)%g
    mine = jnp.take(stacked, idx, axis=0)
    return _merge_front_blocks(mine, concat_axis)


@register_algorithm("all_to_all", "cm")
def _aa_fused(comm, x, *, split_axis, concat_axis):
    # single fused native collective: the layout change happens inside the
    # transfer (cross-domain modulation).
    return lax.all_to_all(x, comm.ax, split_axis, concat_axis, tiled=True)


# ------------------------------------------------------- reduce_scatter
@register_algorithm("reduce_scatter", "naive")
def _rs_naive(comm, x, *, axis, op):
    g, ax = comm.group_size, comm.ax
    blocks = _split_axis_to_front(x, axis, g)                  # (G, ..., b, ..)
    gathered = compat.all_gather(blocks, ax, axis=0, tiled=False)
    me = lax.axis_index(ax)
    col = lax.dynamic_index_in_dim(gathered, me, axis=1, keepdims=False)
    # naive: horizontal, source-by-source sequential reduction.
    comb = _REDUCERS[op][2]
    acc = col[0]
    for s in range(1, g):
        acc = comb(acc, col[s])
    return acc


@register_algorithm("reduce_scatter", "pr")
def _rs_pr(comm, x, *, axis, op):
    g, ax = comm.group_size, comm.ax
    blocks = _split_axis_to_front(x, axis, g)
    gathered = compat.all_gather(blocks, ax, axis=0, tiled=False)
    me = lax.axis_index(ax)
    col = lax.dynamic_index_in_dim(gathered, me, axis=1, keepdims=False)
    # vertical (vectorized) reduction over the stacked source axis -- the
    # paper's one-SIMD-op-per-register argument.
    return _REDUCERS[op][1](col, axis=0)


@register_algorithm("reduce_scatter", "im")
def _rs_stream(comm, x, *, axis, op):
    g, ax = comm.group_size, comm.ax
    if op == "add":
        return compat.psum_scatter(x, ax, scatter_dimension=axis)
    red = _REDUCERS[op][0](x, ax)
    blocks = _split_axis_to_front(red, axis, g)
    me = lax.axis_index(ax)
    return lax.dynamic_index_in_dim(blocks, me, axis=0, keepdims=False)


# ----------------------------------------------------------- all_gather
@register_algorithm("all_gather", "naive")
def _ag_naive(comm, x, *, axis):
    # naive: root collects then broadcasts full copies -- emulated by a
    # masked psum carrying G full-size buffers over the bus.
    g, ax = comm.group_size, comm.ax
    me = lax.axis_index(ax)
    stacked = jnp.zeros((g,) + x.shape, x.dtype)
    stacked = lax.dynamic_update_index_in_dim(stacked, x, me, axis=0)
    full = lax.psum(stacked, ax)
    return _merge_front_blocks(full, axis)


@register_algorithm("all_gather", "pr")
def _ag_pr(comm, x, *, axis):
    gathered = compat.all_gather(x, comm.ax, axis=0, tiled=False)
    return _merge_front_blocks(gathered, axis)


@register_algorithm("all_gather", "im")
def _ag_stream(comm, x, *, axis):
    # direct tiled gather; with CM the consumer additionally reads the
    # gathered layout in place (no post-reorder op survives fusion), so the
    # same body serves both stages.
    return compat.all_gather(x, comm.ax, axis=axis)


register_algorithm("all_gather", "cm")(_ag_stream)


# ----------------------------------------------------------- all_reduce
@register_algorithm("all_reduce", "naive")
def _ar_naive(comm, x, *, op):
    g, ax = comm.group_size, comm.ax
    gathered = compat.all_gather(x, ax, axis=0, tiled=False)
    comb = _REDUCERS[op][2]
    acc = gathered[0]
    for s in range(1, g):
        acc = comb(acc, gathered[s])
    return acc


@register_algorithm("all_reduce", "pr")
def _ar_pr(comm, x, *, op):
    gathered = compat.all_gather(x, comm.ax, axis=0, tiled=False)
    return _REDUCERS[op][1](gathered, axis=0)


@register_algorithm("all_reduce", "im")
def _ar_direct(comm, x, *, op):
    # the runtime's fused native collective (data streams through the
    # reduction); DCN-crossing additive groups are escalated to
    # "hierarchical" by the dispatcher before reaching this body.
    return _REDUCERS[op][0](x, comm.ax)


@register_algorithm("all_reduce", "hierarchical", stage="im", table_ii=False)
def _ar_hierarchical(comm, x, *, op):
    """§IX-A: ICI reduce-scatter, DCN all-reduce of the 1/|ICI| shard, ICI
    all-gather.  DCN bytes drop |ICI|x.  Falls back to the direct flow when
    the group does not span both domains or the op is not additive."""
    fast, slow = comm.fast_dims, comm.slow_dims
    if not (fast and slow) or op != "add":
        return _REDUCERS[op][0](x, comm.ax)
    gf = comm.cube.group_size(fast)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % gf
    if pad:
        flat = jnp.pad(flat, (0, pad))
    shard = compat.psum_scatter(flat, fast, scatter_dimension=0)
    shard = lax.psum(shard, slow)
    full = compat.all_gather(shard, fast, axis=0)
    if pad:
        full = full[:-pad]
    return full.reshape(x.shape)


@register_algorithm("all_reduce", "compressed", stage="cm", table_ii=False)
def _ar_compressed(comm, x, *, op):
    """§V-C: hierarchical all-reduce whose DCN hop carries blockwise-absmax
    int8 payloads (8-bit cross-domain modulation), under a custom_vjp so the
    flow is usable inside differentiated code (straight-through quantizer)."""
    from repro.core import compress
    if op != "add":
        raise ValueError("compressed all_reduce supports op='add' only")
    if not comm.slow_dims:
        raise ValueError(
            "compressed all_reduce needs a DCN-crossing group; "
            f"{comm.dims} is entirely intra-pod")
    return compress.compressed_all_reduce(x, comm.cube, comm.dims)


@register_algorithm("all_reduce", "ring", stage="im", table_ii=False)
def _ar_ring(comm, x, *, op):
    """Bandwidth-optimal ring (Fig. 23a comparator): (G-1) reduce-scatter
    steps + (G-1) all-gather steps of 1/G-size chunks, via ppermute."""
    if op != "add":
        raise ValueError("ring all_reduce supports op='add' only")
    if len(comm.dims) != 1:
        raise ValueError("ring all_reduce runs on a single dim")
    g, ax = comm.group_size, comm.ax
    me = lax.axis_index(ax)
    orig_len = x.shape[0]
    pad = (-orig_len) % g
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else x
    chunks = jnp.stack(jnp.split(xp, g, axis=0), axis=0)   # (G, n/G, ...)
    fwd = [(i, (i + 1) % g) for i in range(g)]
    # reduce-scatter phase: after g-1 hops, i holds reduced chunk (i+1)%g.
    cur = lax.dynamic_index_in_dim(chunks, me, axis=0, keepdims=False)
    for step in range(g - 1):
        got = lax.ppermute(cur, ax, fwd)
        idx = (me - 1 - step) % g
        cur = got + lax.dynamic_index_in_dim(chunks, idx, axis=0,
                                             keepdims=False)
    red_idx = (me + 1) % g
    # all-gather phase: h_s = (me + 1 - s) % g after s hops.
    out = jnp.zeros_like(chunks)
    out = lax.dynamic_update_index_in_dim(out, cur, red_idx, axis=0)
    for s in range(1, g):
        cur = lax.ppermute(cur, ax, fwd)
        out = lax.dynamic_update_index_in_dim(out, cur, (me + 1 - s) % g,
                                              axis=0)
    full = out.reshape((-1,) + x.shape[1:])
    return full[:orig_len] if pad else full


@register_algorithm("all_reduce", "tree", stage="im", table_ii=False)
def _ar_tree(comm, x, *, op):
    """Recursive-doubling (hypercube-exchange) all-reduce: log2(G) steps of
    full-payload XOR-partner exchanges -- latency-optimal, bandwidth-
    suboptimal; stands in for the two-tree comparison of Fig 23(a)."""
    if op != "add":
        raise ValueError("tree all_reduce supports op='add' only")
    g, ax = comm.group_size, comm.ax
    if g & (g - 1):
        raise ValueError("tree_all_reduce needs a power-of-two group")
    acc = x
    level = 1
    while level < g:
        perm = [(i, i ^ level) for i in range(g)]
        got = lax.ppermute(acc, ax, perm)
        acc = acc + got
        level <<= 1
    return acc


# --------------------------------------------------- rooted (host) four
# The host is always the root (paper §IV-B3).  These run at the jit boundary
# on global arrays; one buffer per cube slice, like the paper's per-group
# host buffers.  The device path is stage-invariant: at the jit boundary the
# runtime's native host<->device transfer *is* the in-register path, so
# naive/pr only differ in the emulated host flow the paper ablates, not in
# bytes placed on devices -- one body serves every registered stage.
def _rooted_scatter(comm, host_value, *, axis=None, spec=None):
    if spec is None:
        ax = comm.dims
        spec = [None] * host_value.ndim
        spec[axis] = ax if len(ax) > 1 else ax[0]
    return jax.device_put(host_value, comm.cube.sharding(P(*spec)))


def _rooted_broadcast(comm, host_value):
    return jax.device_put(host_value, comm.cube.sharding(P()))


def _rooted_gather(comm, x):
    return jax.device_get(x)


def _rooted_reduce(comm, x, *, op, axis):
    reducer = {"add": jnp.sum, "max": jnp.max, "min": jnp.min}[op]
    return jax.device_get(reducer(x, axis=axis))


for _stage_name in ("naive", "im"):
    register_algorithm("scatter", _stage_name)(_rooted_scatter)
    register_algorithm("gather", _stage_name)(_rooted_gather)
for _stage_name in ("naive", "pr", "im"):
    register_algorithm("reduce", _stage_name)(_rooted_reduce)
register_algorithm("broadcast", "naive")(_rooted_broadcast)
del _stage_name


__all__ = [
    "AlgorithmSpec", "CommEvent", "CommTrace", "Communicator",
    "PRIMITIVES", "STAGE_ORDER", "applicability", "get_algorithm",
    "register_algorithm", "registered_algorithms", "resolve_stage",
]

# registration side effect: the compute-fused ring flows
# (ring_fused / ag_prologue / rs_epilogue) live with their kernels in
# repro.kernels.collective but must exist in the registry whenever comm is
# importable -- auto dispatch, microbench sweeps, and conformance
# accounting all resolve them by name.  Importing at the bottom keeps the
# cycle safe: every name the kernel module pulls from here is defined by
# now.
import repro.kernels.collective  # noqa: E402,F401  (registers fused flows)
