# PID-Comm core: virtual hypercube + eight multi-instance collective
# primitives + planner + 8-bit DCN compression.
from repro.core.hypercube import Hypercube
from repro.core.collectives import (
    Collectives, APPLICABILITY, ring_all_reduce, tree_all_reduce)
from repro.core.planner import CommEstimate, estimate, plan
from repro.core.compress import (
    quantize_int8, dequantize_int8, compressed_pod_all_reduce)

__all__ = [
    "Hypercube", "Collectives", "APPLICABILITY",
    "ring_all_reduce", "tree_all_reduce",
    "CommEstimate", "estimate", "plan",
    "quantize_int8", "dequantize_int8", "compressed_pod_all_reduce",
]
