# PID-Comm core: virtual hypercube + communicator-centric collective API
# (algorithm registry, plan-driven dispatch, trace instrumentation) +
# planner + 8-bit DCN compression. `Collectives` is the deprecated per-call
# shim over the same registry.
from repro.core.hypercube import Hypercube
from repro.core.comm import (
    AlgorithmSpec, CommEvent, CommTrace, Communicator, applicability,
    get_algorithm, register_algorithm, registered_algorithms, resolve_stage)
from repro.core.collectives import (
    Collectives, APPLICABILITY, ring_all_reduce, tree_all_reduce)
from repro.core.planner import (
    CommEstimate, ProgramOpSpec, ProgramPlan, active_profile, estimate,
    install_profile, plan, plan_program)
from repro.core.program import (
    CommFuture, CommOp, CommProgram, LoweredProgram, ProgramExecution,
    ProgramValue)
from repro.core.compress import (
    quantize_int8, dequantize_int8, compressed_pod_all_reduce,
    compressed_all_reduce)

__all__ = [
    "Hypercube",
    "AlgorithmSpec", "CommEvent", "CommTrace", "Communicator",
    "applicability", "get_algorithm", "register_algorithm",
    "registered_algorithms", "resolve_stage",
    "Collectives", "APPLICABILITY",
    "ring_all_reduce", "tree_all_reduce",
    "CommEstimate", "ProgramOpSpec", "ProgramPlan",
    "active_profile", "estimate", "install_profile", "plan", "plan_program",
    "CommFuture", "CommOp", "CommProgram", "LoweredProgram",
    "ProgramExecution", "ProgramValue",
    "quantize_int8", "dequantize_int8", "compressed_pod_all_reduce",
    "compressed_all_reduce",
]
