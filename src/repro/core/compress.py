"""8-bit cross-domain modulation for the slow (DCN) hop (paper §V-C, §VIII-F).

The paper observes that 8-bit payloads skip the domain-transfer step even for
arithmetic primitives, yielding an extra 1.64x on GNNs. The TPU analogue:
quantizing the gradient payload to int8 before it crosses the pod (DCN)
boundary both shrinks the slow-domain bytes 2-4x and removes the bf16<->fp32
conversion from the wire path. Error feedback keeps the optimizer contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.hypercube import Hypercube

Array = jax.Array


def quantize_int8(x: Array, block: int = 256) -> tuple[Array, Array]:
    """Blockwise absmax int8 quantization. Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: Array, scale: Array, shape, size: int) -> Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[:size].reshape(shape)


def compressed_pod_all_reduce(x: Array, cube: Hypercube, fast_dims, slow_dims,
                              *, block: int = 256) -> tuple[Array, Array]:
    """Hierarchical all-reduce with an int8 DCN hop + error feedback.

    ICI: full-precision reduce-scatter (fast, cheap). DCN: int8 all-gather of
    the 1/|ICI| shard + local dequant-sum. ICI: all-gather back.

    Returns (all_reduced, local_quantization_error) -- callers add the error
    into the next step's gradient (error feedback), preserving convergence.
    """
    fast = cube.resolve_dims(fast_dims) if fast_dims else ()
    slow = cube.resolve_dims(slow_dims)
    gf = cube.group_size(fast) if fast else 1
    return _compressed_hops(x, fast, slow, gf, block)


# ------------------------------------------------- differentiable boundary
def compressed_all_reduce(x: Array, cube: Hypercube, dims, *,
                          block: int = 256) -> Array:
    """§V-C compressed all-reduce under a ``custom_vjp`` boundary.

    Forward: hierarchical all-reduce over ``dims`` with the DCN hop carried
    as blockwise-absmax int8 (the local quantization error is *dropped* --
    callers that need error feedback thread :func:`compressed_pod_all_reduce`
    explicitly).  Backward: the cotangent takes the same compressed
    all-reduce, i.e. a straight-through quantizer around the psum transpose
    convention of pre-vma jax -- so the flow is registrable as a first-class
    collective algorithm inside differentiated model code.
    """
    fast, slow = cube.split_fast_slow(dims)
    if not slow:
        raise ValueError(f"{dims} never crosses DCN; use a plain all-reduce")
    gf = cube.group_size(fast) if fast else 1
    return _compressed_core(x, fast, slow, gf, block)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def _compressed_core(x, fast, slow, gf, block):
    full, _ = _compressed_hops(x, fast, slow, gf, block)
    return full


def _compressed_hops(x, fast, slow, gf, block):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % (gf * block)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    shard = lax.psum_scatter(flat, fast, scatter_dimension=0, tiled=True) \
        if fast else flat
    q, scale = quantize_int8(shard, block)
    deq_local = dequantize_int8(q, scale, shard.shape, shard.size)
    err_shard = shard - deq_local
    q_all = lax.all_gather(q, slow, axis=0, tiled=False)
    s_all = lax.all_gather(scale, slow, axis=0, tiled=False)
    summed = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)
    summed = summed.reshape(-1)[:shard.size].reshape(shard.shape)
    if fast:
        full = lax.all_gather(summed, fast, axis=0, tiled=True)
        err = lax.all_gather(err_shard, fast, axis=0, tiled=True)
    else:
        full, err = summed, err_shard
    if pad:
        full = full[:-pad]
        err = err[:-pad]
    return full.reshape(x.shape).astype(x.dtype), err.reshape(x.shape)


def _compressed_core_fwd(x, fast, slow, gf, block):
    return _compressed_core(x, fast, slow, gf, block), None


def _compressed_core_bwd(fast, slow, gf, block, _, ct):
    # pre-vma psum convention: the transpose of an all-reduce is an
    # all-reduce of the cotangent; keep it on the compressed path so the
    # backward DCN hop is 8-bit too (straight-through quantizer).
    return (_compressed_core(ct, fast, slow, gf, block),)


_compressed_core.defvjp(_compressed_core_fwd, _compressed_core_bwd)
