"""8-bit cross-domain modulation for the slow (DCN) hop (paper §V-C, §VIII-F).

The paper observes that 8-bit payloads skip the domain-transfer step even for
arithmetic primitives, yielding an extra 1.64x on GNNs. The TPU analogue:
quantizing the gradient payload to int8 before it crosses the pod (DCN)
boundary both shrinks the slow-domain bytes 2-4x and removes the bf16<->fp32
conversion from the wire path. Error feedback keeps the optimizer contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.hypercube import Hypercube

Array = jax.Array


def quantize_int8(x: Array, block: int = 256) -> tuple[Array, Array]:
    """Blockwise absmax int8 quantization. Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: Array, scale: Array, shape, size: int) -> Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[:size].reshape(shape)


def compressed_pod_all_reduce(x: Array, cube: Hypercube, fast_dims, slow_dims,
                              *, block: int = 256) -> tuple[Array, Array]:
    """Hierarchical all-reduce with an int8 DCN hop + error feedback.

    ICI: full-precision reduce-scatter (fast, cheap). DCN: int8 all-gather of
    the 1/|ICI| shard + local dequant-sum. ICI: all-gather back.

    Returns (all_reduced, local_quantization_error) -- callers add the error
    into the next step's gradient (error feedback), preserving convergence.
    """
    fast = cube.resolve_dims(fast_dims)
    slow = cube.resolve_dims(slow_dims)
    gf = cube.group_size(fast)

    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % (gf * block)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    shard = lax.psum_scatter(flat, fast, scatter_dimension=0, tiled=True)

    q, scale = quantize_int8(shard, block)
    deq_local = dequantize_int8(q, scale, shard.shape, shard.size)
    err_shard = shard - deq_local  # local error, fed back by the caller

    q_all = lax.all_gather(q, slow, axis=0, tiled=False)
    s_all = lax.all_gather(scale, slow, axis=0, tiled=False)
    summed = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)
    summed = summed.reshape(-1)[:shard.size].reshape(shard.shape)

    full = lax.all_gather(summed, fast, axis=0, tiled=True)
    err = lax.all_gather(err_shard, fast, axis=0, tiled=True)
    if pad:
        full = full[:-pad]
        err = err[:-pad]
    return full.reshape(x.shape), err.reshape(x.shape)
