"""Virtual hypercube abstraction (PID-Comm §IV) mapped onto a physical jax.Mesh.

The paper abstracts PIM PEs as a user-defined N-dimensional hypercube whose
nodes are transparently mapped to physical PEs following the DRAM hierarchy
(chip -> bank -> rank -> channel), never splitting an *entangled group*
(banks that must be driven together to saturate the external bus).

On TPU the physical hierarchy is (core ->) chip -> ICI axis -> pod (DCN).
``Hypercube`` re-views the devices of a physical mesh as a finer logical mesh
in hierarchy-preserving order, and enforces the TPU analogue of the
entangled-group rule: a logical dimension may never straddle the pod (DCN)
boundary partially -- the pod boundary must coincide with a logical-dimension
boundary, so every intra-pod collective group stays on ICI.

Dimension sizes must be powers of two except the outermost (the paper allows
one non-power-of-two dimension and requires it to sit at the slowest level of
the hierarchy -- the channel count there, the pod count here).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Physical axes that cross the data-center network (slow domain). Everything
# else is assumed ICI (fast domain). Mirrors PIM-domain vs host-domain.
DCN_AXES = ("pod",)


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


@dataclasses.dataclass(frozen=True)
class Hypercube:
    """A logical hypercube over the devices of a physical mesh.

    Attributes:
      mesh: logical ``jax.sharding.Mesh`` (axes ordered outermost->innermost).
      dim_names: logical dimension names, outermost first.
      dim_sizes: logical dimension sizes, outermost first.
      physical_axes: the physical mesh axis names this was derived from.
      dcn_dims: logical dims that live (partly) in the DCN domain.
    """

    mesh: Mesh
    dim_names: tuple[str, ...]
    dim_sizes: tuple[int, ...]
    physical_axes: tuple[str, ...]
    dcn_dims: tuple[str, ...]

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(physical_mesh: Mesh, dims: Mapping[str, int]) -> "Hypercube":
        """Re-view ``physical_mesh`` as the logical hypercube ``dims``.

        ``dims`` is ordered outermost -> innermost. The flattened device order
        of the physical mesh (major -> minor) is preserved, which is exactly
        the paper's hierarchy-order mapping (channel -> rank -> bank -> chip
        there; pod -> ici-axis -> chip here).
        """
        names = tuple(dims.keys())
        sizes = tuple(int(s) for s in dims.values())
        ndev = int(np.prod(physical_mesh.devices.shape))
        if int(np.prod(sizes)) != ndev:
            raise ValueError(
                f"hypercube {dict(dims)} has {int(np.prod(sizes))} nodes, "
                f"physical mesh has {ndev} devices")
        for name, size in zip(names[1:], sizes[1:]):
            if not _is_pow2(size):
                raise ValueError(
                    f"dim {name!r}={size} must be a power of two (only the "
                    "outermost dimension may be non-power-of-two)")
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate dim names in {names}")

        # Entangled-group rule: the DCN (pod) boundary must coincide with a
        # logical dim boundary. devices_per_pod must equal the product of a
        # suffix of the logical dims.
        phys_names = tuple(physical_mesh.axis_names)
        phys_sizes = physical_mesh.devices.shape
        dcn_extent = 1
        for pname, psize in zip(phys_names, phys_sizes):
            if pname in DCN_AXES:
                dcn_extent *= psize
        devices_per_pod = ndev // dcn_extent
        suffix = 1
        suffixes = {1}
        for s in reversed(sizes):
            suffix *= s
            suffixes.add(suffix)
        if devices_per_pod not in suffixes:
            raise ValueError(
                f"hypercube {dict(dims)} splits the pod boundary "
                f"({devices_per_pod} devices/pod is not a suffix product of "
                f"{sizes}); intra-pod groups would straddle DCN")

        # Which logical dims touch the DCN domain: those whose inner extent
        # (product of strictly-inner dims) is >= devices_per_pod.
        dcn_dims = []
        inner = 1
        for name, size in zip(reversed(names), reversed(sizes)):
            if inner >= devices_per_pod and size > 1:
                dcn_dims.append(name)
            inner *= size
        dcn_dims = tuple(reversed(dcn_dims))

        devs = physical_mesh.devices.reshape(sizes)
        logical = Mesh(devs, names)
        return Hypercube(
            mesh=logical,
            dim_names=names,
            dim_sizes=sizes,
            physical_axes=phys_names,
            dcn_dims=dcn_dims,
        )

    # ------------------------------------------------------------- selections
    def dims_from_bitmap(self, bitmap: str) -> tuple[str, ...]:
        """PID-Comm dim selection, e.g. "010" -> the middle dimension.

        The bitmap is ordered like ``dim_names`` (outermost first), matching
        the paper's ``comm_dimensions`` argument.
        """
        if len(bitmap) != len(self.dim_names) or set(bitmap) - {"0", "1"}:
            raise ValueError(
                f"bitmap {bitmap!r} invalid for dims {self.dim_names}")
        sel = tuple(n for n, b in zip(self.dim_names, bitmap) if b == "1")
        if not sel:
            raise ValueError("empty dim selection")
        return sel

    def resolve_dims(self, dims) -> tuple[str, ...]:
        """Accept a bitmap string, a single name, or a sequence of names."""
        if isinstance(dims, str):
            if set(dims) <= {"0", "1"} and len(dims) == len(self.dim_names):
                return self.dims_from_bitmap(dims)
            if dims in self.dim_names:
                return (dims,)
            raise ValueError(f"unknown dim selection {dims!r}")
        sel = tuple(dims)
        for d in sel:
            if d not in self.dim_names:
                raise ValueError(f"unknown dim {d!r}; have {self.dim_names}")
        # preserve hypercube (major->minor) order regardless of input order
        return tuple(d for d in self.dim_names if d in sel)

    def group_size(self, dims) -> int:
        sel = self.resolve_dims(dims)
        return int(np.prod([self.size(d) for d in sel]))

    def num_instances(self, dims) -> int:
        """Number of independent communication groups (cube slices)."""
        return int(np.prod(self.dim_sizes)) // self.group_size(dims)

    def size(self, name: str) -> int:
        return self.dim_sizes[self.dim_names.index(name)]

    def crosses_dcn(self, dims) -> bool:
        sel = self.resolve_dims(dims)
        return any(d in self.dcn_dims for d in sel)

    def split_fast_slow(self, dims) -> tuple[tuple[str, ...], tuple[str, ...]]:
        """Partition selected dims into (ICI dims, DCN dims)."""
        sel = self.resolve_dims(dims)
        fast = tuple(d for d in sel if d not in self.dcn_dims)
        slow = tuple(d for d in sel if d in self.dcn_dims)
        return fast, slow

    # ---------------------------------------------------------- communicator
    def comm(self, dims, *, algorithm: str = "auto"):
        """Bind a :class:`repro.core.comm.Communicator` to a dim selection.

        The communicator resolves ``dims`` once (bitmap / name / sequence),
        caches the group size, fast/slow split and instance count, and
        exposes the eight PID-Comm primitives as methods.  ``algorithm`` is
        the handle's default dispatch mode: ``"auto"`` consults the planner
        at trace time; stage names and registered first-class algorithms
        are accepted per call.
        """
        from repro.core.comm import Communicator  # deferred: avoid cycle
        return Communicator(self, dims, default_algorithm=algorithm)

    def program(self, *, name: str = ""):
        """Open a deferred :class:`repro.core.program.CommProgram` recording
        scope: inside ``with cube.program() as prog``, every communicator
        primitive on this cube appends a CommOp instead of dispatching;
        ``prog.lower()`` fuses/coalesces/plans and ``prog.execute(*inputs)``
        runs the optimized schedule through the algorithm registry."""
        from repro.core.program import CommProgram  # deferred: avoid cycle
        return CommProgram(self, name=name)

    # ------------------------------------------------------------- shardings
    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def axis_index(self, dims) -> jax.Array:
        """Linearized index of this shard within its communication group
        (valid inside shard_map over ``self.mesh``)."""
        return jax.lax.axis_index(self.resolve_dims(dims))

    @property
    def ndev(self) -> int:
        return int(np.prod(self.dim_sizes))

    def describe(self) -> str:
        parts = [f"{n}={s}" for n, s in zip(self.dim_names, self.dim_sizes)]
        tag = ",".join(parts)
        return f"Hypercube[{tag}; dcn={self.dcn_dims or '()'}]"
