"""Analytic cost model + algorithm planner (PID-Comm's "guide the user"
role, §III-B/§IV-A, automated).

Given a hypercube, a dim selection and a payload size, estimates per-device
communication time for each applicable algorithm and picks the fastest. The
same terms feed the roofline analysis (EXPERIMENTS.md) and the benchmark
harness's ``derived`` column.

Hardware constants are TPU v5e (the deployment target):
  peak bf16 compute  197 TFLOP/s / chip
  HBM bandwidth      819 GB/s / chip
  ICI link bandwidth  50 GB/s / link (per mesh-axis neighbour hop)
  DCN bandwidth       3.125 GB/s / chip effective (25 Gb/s; pods cross DCN)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

import numpy as np

from repro.core.hypercube import Hypercube

PEAK_BF16_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 3.125e9


@dataclasses.dataclass(frozen=True)
class CommEstimate:
    primitive: str
    algorithm: str                     # naive | hierarchical | direct
    schedule: tuple[str, ...]          # human-readable hop list
    ici_bytes: float                   # per-device bytes over ICI
    dcn_bytes: float                   # per-device bytes over DCN
    seconds: float
    stage: str = ""                    # the Table II stage this flow maps to

    def dominant(self) -> str:
        return "dcn" if self.dcn_bytes / DCN_BW > self.ici_bytes / ICI_BW \
            else "ici"


def _bw_time(ici_bytes: float, dcn_bytes: float) -> float:
    return ici_bytes / ICI_BW + dcn_bytes / DCN_BW


def _group_bytes(primitive: str, payload: float, g: int) -> float:
    """Per-device bytes moved by the *direct* algorithm on one flat group."""
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    return {
        "all_to_all": payload * frac,
        "reduce_scatter": payload * frac,
        "all_gather": payload * (g - 1),   # payload = per-device shard bytes
        "all_reduce": 2 * payload * frac,
        "broadcast": payload,
        "scatter": payload,
        "gather": payload,
        "reduce": payload,
    }[primitive]


def _table_ii_stage(primitive: str, algorithm: str) -> str:
    """Map a planner flow onto the Table II stage it corresponds to."""
    from repro.core.comm import resolve_stage
    if algorithm == "naive":
        return "naive"
    if algorithm == "compressed":
        return "cm"  # §V-C: 8-bit payloads make CM applicable to arithmetic
    # hierarchical / direct both run the runtime's best native flow
    return resolve_stage(primitive, "pidcomm")


def estimate(cube: Hypercube, primitive: str, dims, payload_bytes: float,
             algorithm: str = "pidcomm", *, dtype_bytes: int = 4,
             block: int = 256) -> CommEstimate:
    """Estimate one collective. ``payload_bytes`` is the per-device payload
    (for all_gather: the local shard; for others: the local buffer).

    ``algorithm``: ``naive`` (replicated-intermediate host flow),
    ``direct`` (one flat native collective over the whole group, even when
    it crosses DCN), ``compressed`` (the §V-C hierarchical split with a
    blockwise-int8 DCN hop; ``dtype_bytes``/``block`` size the compression
    ratio), or ``pidcomm``/``hierarchical`` (the §IX-A split whenever the
    primitive is an all-reduce spanning both domains; like the runtime, the
    request *falls back to direct* otherwise -- check the returned
    ``algorithm`` field when the distinction matters).
    """
    if algorithm not in ("pidcomm", "naive", "direct", "hierarchical",
                         "compressed"):
        raise ValueError(f"unknown planner algorithm {algorithm!r}")
    sel = cube.resolve_dims(dims)
    fast, slow = cube.split_fast_slow(sel)
    gf = int(np.prod([cube.size(d) for d in fast])) if fast else 1
    gs = int(np.prod([cube.size(d) for d in slow])) if slow else 1
    g = gf * gs

    if algorithm == "compressed":
        # §V-C int8 DCN hop: full-precision ICI reduce-scatter, int8
        # all-gather of the 1/|ICI| shard (+ one fp32 scale per block)
        # across pods, ICI all-gather back.
        ici = 2 * payload_bytes * (gf - 1) / gf if gf > 1 else 0.0
        shard = payload_bytes / gf
        dcn = (gs - 1) * (shard / dtype_bytes) * (1.0 + 4.0 / block) \
            if gs > 1 else 0.0
        sched = ((f"reduce_scatter[{'x'.join(fast)}]",) if fast else ()) + \
            ((f"all_gather-int8[{'x'.join(slow)}]",) if slow else ()) + \
            ((f"all_gather[{'x'.join(fast)}]",) if fast else ())
        return CommEstimate(primitive, "compressed", sched, ici, dcn,
                            _bw_time(ici, dcn), "cm")

    if algorithm == "naive":
        # replicated-intermediate flow: every device ships its full payload to
        # everyone and receives (g-1) full payloads.
        ici = payload_bytes * (gf - 1) if gf > 1 else 0.0
        dcn = payload_bytes * (g - 1) - ici if gs > 1 else 0.0
        sched = (f"allgather-full[{'x'.join(sel)}]", "local-modulate",
                 "local-slice")
        return CommEstimate(primitive, "naive", sched, ici, dcn,
                            _bw_time(ici, dcn), "naive")

    if (algorithm != "direct" and primitive == "all_reduce"
            and gs > 1 and gf > 1):
        # hierarchical §IX-A
        ici = 2 * payload_bytes * (gf - 1) / gf
        dcn = 2 * (payload_bytes / gf) * (gs - 1) / gs
        sched = (f"reduce_scatter[{'x'.join(fast)}]",
                 f"all_reduce[{'x'.join(slow)}]",
                 f"all_gather[{'x'.join(fast)}]")
        return CommEstimate(primitive, "hierarchical", sched, ici, dcn,
                            _bw_time(ici, dcn),
                            _table_ii_stage(primitive, "hierarchical"))

    ici = _group_bytes(primitive, payload_bytes, gf) if gf > 1 else 0.0
    # direct over a pod-crossing group: the (gs-1)/gs fraction crosses DCN
    dcn = 0.0
    if gs > 1:
        total = _group_bytes(primitive, payload_bytes * (gf if primitive == "all_gather" else 1), gs)
        dcn = total
    sched = (f"{primitive}[{'x'.join(sel)}]",)
    return CommEstimate(primitive, "direct", sched, ici, dcn,
                        _bw_time(ici, dcn),
                        _table_ii_stage(primitive, "direct"))


def plan(cube: Hypercube, primitive: str, dims, payload_bytes: float, *,
         allow_compressed: bool = False) -> CommEstimate:
    """Pick the fastest flow for this primitive/group among the naive host
    flow, the flat direct collective, and (when the group spans both
    domains) the hierarchical split.  This is what ``algorithm="auto"``
    dispatch on a :class:`repro.core.comm.Communicator` executes.

    ``allow_compressed`` adds the §V-C int8-DCN candidate for pod-crossing
    additive all-reduces; it is opt-in because the caller (e.g. the trainer)
    owns the accuracy contract that lossy compression bends.
    """
    algs = ["naive", "direct", "pidcomm"]
    if allow_compressed and primitive == "all_reduce" \
            and cube.crosses_dcn(dims):
        algs.append("compressed")
    cands = [estimate(cube, primitive, dims, payload_bytes, a) for a in algs]
    # Tie-break away from naive: when the byte model can't separate the host
    # flow from the native collective, the runtime still executes the native
    # one, and the reported stage must reflect that.
    return min(cands, key=lambda e: (e.seconds, e.algorithm == "naive"))


def matmul_time(m: int, n: int, k: int, dtype_bytes: int = 2) -> float:
    """Roofline time of one matmul on one chip: max(compute, memory)."""
    flops = 2 * m * n * k
    bytes_ = dtype_bytes * (m * k + k * n + m * n)
    return max(flops / PEAK_BF16_FLOPS, bytes_ / HBM_BW)
