"""Analytic cost model + algorithm planner (PID-Comm's "guide the user"
role, §III-B/§IV-A, automated).

Given a hypercube, a dim selection and a payload size, estimates per-device
communication time for each applicable algorithm and picks the fastest. The
same terms feed the roofline analysis (EXPERIMENTS.md) and the benchmark
harness's ``derived`` column.

Hardware constants are TPU v5e (the deployment target):
  peak bf16 compute  197 TFLOP/s / chip
  HBM bandwidth      819 GB/s / chip
  ICI link bandwidth  50 GB/s / link (per mesh-axis neighbour hop)
  DCN bandwidth       3.125 GB/s / chip effective (25 Gb/s; pods cross DCN)
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import math
from typing import Mapping

import numpy as np

from repro.core.hypercube import Hypercube

PEAK_BF16_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 3.125e9


@dataclasses.dataclass(frozen=True)
class CommEstimate:
    primitive: str
    algorithm: str                     # naive | hierarchical | direct
    schedule: tuple[str, ...]          # human-readable hop list
    ici_bytes: float                   # per-device bytes over ICI
    dcn_bytes: float                   # per-device bytes over DCN
    seconds: float
    stage: str = ""                    # the Table II stage this flow maps to
    est_source: str = "analytic"       # "analytic" | "measured" provenance

    def dominant(self) -> str:
        return "dcn" if self.dcn_bytes / DCN_BW > self.ici_bytes / ICI_BW \
            else "ici"


def _bw_time(ici_bytes: float, dcn_bytes: float) -> float:
    return ici_bytes / ICI_BW + dcn_bytes / DCN_BW


# ------------------------------------------------------- measured profiles
# Stack of installed CommProfiles (repro.tuning.profile); the innermost one
# prices every estimate whose (flow, stage, domains) its fitted models
# cover, replacing the hardcoded v5e constants with measured alpha-beta
# terms.  The planner only needs the duck-typed ``seconds_for`` interface,
# so there is no import cycle with the tuning package.
_PROFILES: list = []


def active_profile():
    """The innermost installed profile, or None (analytic constants)."""
    return _PROFILES[-1] if _PROFILES else None


@contextlib.contextmanager
def install_profile(profile):
    """Context manager pricing every ``plan``/``estimate``/``plan_program``
    call (and therefore every ``algorithm="auto"`` dispatch) under it from
    ``profile``'s measured models.  Nests; the innermost profile wins."""
    _PROFILES.append(profile)
    try:
        yield profile
    finally:
        _PROFILES.remove(profile)


def _finish(primitive: str, algorithm: str, sched: tuple[str, ...],
            ici: float, dcn: float, stage: str, profile) -> CommEstimate:
    """Price one candidate: measured model when the active/passed profile
    covers this (flow, stage, domains), analytic constants otherwise."""
    prof = profile if profile is not None else active_profile()
    if prof is not None:
        t = prof.seconds_for(algorithm, stage, ici, dcn)
        if t is not None:
            return CommEstimate(primitive, algorithm, sched, ici, dcn, t,
                                stage, "measured")
    return CommEstimate(primitive, algorithm, sched, ici, dcn,
                        _bw_time(ici, dcn), stage)


def _group_bytes(primitive: str, payload: float, g: int) -> float:
    """Per-device bytes moved by the *direct* algorithm on one flat group."""
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    return {
        "all_to_all": payload * frac,
        "reduce_scatter": payload * frac,
        "all_gather": payload * (g - 1),   # payload = per-device shard bytes
        "all_reduce": 2 * payload * frac,
        "broadcast": payload,
        "scatter": payload,
        "gather": payload,
        "reduce": payload,
    }[primitive]


def _table_ii_stage(primitive: str, algorithm: str) -> str:
    """Map a planner flow onto the Table II stage it corresponds to."""
    from repro.core.comm import resolve_stage
    if algorithm == "naive":
        return "naive"
    if algorithm == "compressed":
        return "cm"  # §V-C: 8-bit payloads make CM applicable to arithmetic
    # hierarchical / direct both run the runtime's best native flow
    return resolve_stage(primitive, "pidcomm")


def estimate(cube: Hypercube, primitive: str, dims, payload_bytes: float,
             algorithm: str = "pidcomm", *, dtype_bytes: int = 4,
             block: int = 256, profile=None) -> CommEstimate:
    """Estimate one collective. ``payload_bytes`` is the per-device payload
    (for all_gather: the local shard; for others: the local buffer).

    ``algorithm``: ``naive`` (replicated-intermediate host flow),
    ``direct`` (one flat native collective over the whole group, even when
    it crosses DCN), ``compressed`` (the §V-C hierarchical split with a
    blockwise-int8 DCN hop; ``dtype_bytes``/``block`` size the compression
    ratio), or ``pidcomm``/``hierarchical`` (the §IX-A split whenever the
    primitive is an all-reduce spanning both domains; like the runtime, the
    request *falls back to direct* otherwise -- check the returned
    ``algorithm`` field when the distinction matters).

    ``profile`` (or an :func:`install_profile` context) switches the
    *time* term to the profile's measured alpha-beta models when they cover
    the flow -- the returned estimate then carries
    ``est_source="measured"``.  The byte terms stay analytic either way:
    they are structural properties of the flow.
    """
    if algorithm not in ("pidcomm", "naive", "direct", "hierarchical",
                         "compressed"):
        raise ValueError(f"unknown planner algorithm {algorithm!r}")
    sel = cube.resolve_dims(dims)
    fast, slow = cube.split_fast_slow(sel)
    gf = int(np.prod([cube.size(d) for d in fast])) if fast else 1
    gs = int(np.prod([cube.size(d) for d in slow])) if slow else 1
    g = gf * gs

    if algorithm == "compressed":
        # §V-C int8 DCN hop: full-precision ICI reduce-scatter, int8
        # all-gather of the 1/|ICI| shard (+ one fp32 scale per block)
        # across pods, ICI all-gather back.
        ici = 2 * payload_bytes * (gf - 1) / gf if gf > 1 else 0.0
        shard = payload_bytes / gf
        dcn = (gs - 1) * (shard / dtype_bytes) * (1.0 + 4.0 / block) \
            if gs > 1 else 0.0
        sched = ((f"reduce_scatter[{'x'.join(fast)}]",) if fast else ()) + \
            ((f"all_gather-int8[{'x'.join(slow)}]",) if slow else ()) + \
            ((f"all_gather[{'x'.join(fast)}]",) if fast else ())
        return _finish(primitive, "compressed", sched, ici, dcn, "cm",
                       profile)

    if algorithm == "naive":
        # replicated-intermediate flow: every device ships its full payload to
        # everyone and receives (g-1) full payloads.
        ici = payload_bytes * (gf - 1) if gf > 1 else 0.0
        dcn = payload_bytes * (g - 1) - ici if gs > 1 else 0.0
        sched = (f"allgather-full[{'x'.join(sel)}]", "local-modulate",
                 "local-slice")
        return _finish(primitive, "naive", sched, ici, dcn, "naive", profile)

    if (algorithm != "direct" and primitive == "all_reduce"
            and gs > 1 and gf > 1):
        # hierarchical §IX-A
        ici = 2 * payload_bytes * (gf - 1) / gf
        dcn = 2 * (payload_bytes / gf) * (gs - 1) / gs
        sched = (f"reduce_scatter[{'x'.join(fast)}]",
                 f"all_reduce[{'x'.join(slow)}]",
                 f"all_gather[{'x'.join(fast)}]")
        return _finish(primitive, "hierarchical", sched, ici, dcn,
                       _table_ii_stage(primitive, "hierarchical"), profile)

    ici = _group_bytes(primitive, payload_bytes, gf) if gf > 1 else 0.0
    # direct over a pod-crossing group: the (gs-1)/gs fraction crosses DCN
    dcn = 0.0
    if gs > 1:
        total = _group_bytes(primitive, payload_bytes * (gf if primitive == "all_gather" else 1), gs)
        dcn = total
    sched = (f"{primitive}[{'x'.join(sel)}]",)
    return _finish(primitive, "direct", sched, ici, dcn,
                   _table_ii_stage(primitive, "direct"), profile)


# -------------------------------------------------------- program planning
@dataclasses.dataclass(frozen=True)
class ProgramOpSpec:
    """One CommProgram op as the planner sees it (shapes only)."""
    op_id: int
    primitive: str
    dims: tuple[str, ...]
    payload_bytes: float
    deps: tuple[int, ...] = ()
    algorithm: str = "auto"
    op: str = "add"                    # reducer, for escalation parity
    allow_compressed: bool = False


@dataclasses.dataclass(frozen=True)
class ProgramPlan:
    """Joint plan for a whole program: per-op estimates under one shared
    ICI/DCN budget, an explicit interleaving order for independent ops, and
    the overlapped vs serial time bounds."""
    estimates: Mapping[int, CommEstimate]
    order: tuple[int, ...]             # dependency-safe dispatch order
    levels: tuple[tuple[int, ...], ...]  # independent-op waves
    ici_bytes: float
    dcn_bytes: float
    seconds: float                     # per-level max(ICI budget, DCN budget)
    serial_seconds: float              # sum of per-op estimates


# planner algorithm to estimate for an explicitly requested dispatch
# algorithm; anything unlisted (Table II stages, ring/tree, "pidcomm") runs
# the runtime's native flow, whose byte model is "direct".
_REQUEST_TO_PLANNER = {
    "naive": "naive",
    "hierarchical": "pidcomm",
    "compressed": "compressed",
}


def plan_program(cube: Hypercube, ops, *, profile=None) -> ProgramPlan:
    """One planning pass over a whole CommProgram.

    Per op: ``algorithm="auto"`` gets the full :func:`plan` candidate race;
    explicit requests get the matching :func:`estimate`.  Ops are then
    levelled by data dependency; within a level (independent ops) the
    dispatch order interleaves ICI-dominant and DCN-dominant ops so both
    domains stream concurrently, and the level's time is the larger of the
    two domain budgets (plus any op that exceeds both alone).

    ``profile`` (or an :func:`install_profile` context) prices every op
    from measured models where covered, like :func:`plan`.
    """
    est: dict[int, CommEstimate] = {}
    for o in ops:
        if o.algorithm in ("auto", "pidcomm"):
            est[o.op_id] = plan(cube, o.primitive, o.dims, o.payload_bytes,
                                allow_compressed=o.allow_compressed,
                                profile=profile)
        else:
            alg = _REQUEST_TO_PLANNER.get(o.algorithm)
            if alg is None:
                # Table II stage / ring / tree: native flow, "direct" byte
                # model -- except an additive im-resolving all_reduce, which
                # the dispatcher escalates to the hierarchical split when
                # the group spans both domains (estimate("pidcomm") applies
                # exactly that condition, falling back to direct otherwise).
                alg = "direct"
                if (o.primitive == "all_reduce" and o.op == "add"
                        and o.algorithm not in ("ring", "tree")):
                    from repro.core.comm import resolve_stage
                    try:
                        stage = resolve_stage("all_reduce", o.algorithm)
                    except ValueError:
                        stage = None
                    if stage == "im":
                        alg = "pidcomm"
            est[o.op_id] = estimate(
                cube, o.primitive, o.dims, o.payload_bytes, alg,
                profile=profile)

    # dependency levels (wave l = ops whose deps all sit in waves < l)
    level_of: dict[int, int] = {}
    remaining = {o.op_id: o for o in ops}
    levels: list[tuple[int, ...]] = []
    while remaining:
        wave = [oid for oid, o in remaining.items()
                if all(d in level_of or d not in est for d in o.deps)]
        if not wave:
            raise ValueError("cyclic dependencies in program ops")
        # explicit interleaving: alternate DCN-dominant and ICI-dominant ops
        # (longest first within each domain) so neither link sits idle
        dcn = sorted((oid for oid in wave if est[oid].dominant() == "dcn"),
                     key=lambda i: -est[i].seconds)
        ici = sorted((oid for oid in wave if est[oid].dominant() == "ici"),
                     key=lambda i: -est[i].seconds)
        inter = []
        for pair in itertools.zip_longest(dcn, ici):
            inter += [i for i in pair if i is not None]
        levels.append(tuple(inter))
        for oid in inter:
            level_of[oid] = len(levels) - 1
            del remaining[oid]

    seconds = 0.0
    for wave in levels:
        ici_t = sum(est[i].ici_bytes / ICI_BW for i in wave)
        dcn_t = sum(est[i].dcn_bytes / DCN_BW for i in wave)
        slowest = max(est[i].seconds for i in wave)
        seconds += max(ici_t, dcn_t, slowest)
    return ProgramPlan(
        estimates=est,
        order=tuple(oid for wave in levels for oid in wave),
        levels=tuple(levels),
        ici_bytes=sum(e.ici_bytes for e in est.values()),
        dcn_bytes=sum(e.dcn_bytes for e in est.values()),
        seconds=seconds,
        serial_seconds=sum(e.seconds for e in est.values()))


def plan(cube: Hypercube, primitive: str, dims, payload_bytes: float, *,
         allow_compressed: bool = False, profile=None) -> CommEstimate:
    """Pick the fastest flow for this primitive/group among the naive host
    flow, the flat direct collective, and (when the group spans both
    domains) the hierarchical split.  This is what ``algorithm="auto"``
    dispatch on a :class:`repro.core.comm.Communicator` executes.

    ``allow_compressed`` adds the §V-C int8-DCN candidate for pod-crossing
    additive all-reduces; it is opt-in because the caller (e.g. the trainer)
    owns the accuracy contract that lossy compression bends.

    Under an installed (or passed) measured profile the race is priced from
    the fitted alpha-beta models wherever they cover a candidate, so
    ``algorithm="auto"`` dispatches on measured data -- the picked
    estimate's ``est_source`` says which model priced it.  Measured and
    analytic seconds are not commensurable (CPU wall time vs v5e
    constants), so when *any* candidate is measured the race is restricted
    to the measured ones: an uncovered candidate must not win on
    incomparably-cheap analytic numbers.
    """
    algs = ["naive", "direct", "pidcomm"]
    if allow_compressed and primitive == "all_reduce" \
            and cube.crosses_dcn(dims):
        algs.append("compressed")
    cands = [estimate(cube, primitive, dims, payload_bytes, a,
                      profile=profile) for a in algs]
    measured = [e for e in cands if e.est_source == "measured"]
    if measured:
        cands = measured
    # Tie-break away from naive: when the byte model can't separate the host
    # flow from the native collective, the runtime still executes the native
    # one, and the reported stage must reflect that.
    return min(cands, key=lambda e: (e.seconds, e.algorithm == "naive"))


def matmul_time(m: int, n: int, k: int, dtype_bytes: int = 2) -> float:
    """Roofline time of one matmul on one chip: max(compute, memory)."""
    flops = 2 * m * n * k
    bytes_ = dtype_bytes * (m * k + k * n + m * n)
    return max(flops / PEAK_BF16_FLOPS, bytes_ / HBM_BW)
