"""Analytic cost model + algorithm planner (PID-Comm's "guide the user"
role, §III-B/§IV-A, automated).

Given a hypercube, a dim selection and a payload size, estimates per-device
communication time for each applicable algorithm and picks the fastest. The
same terms feed the roofline analysis (EXPERIMENTS.md) and the benchmark
harness's ``derived`` column.

Hardware constants are TPU v5e (the deployment target):
  peak bf16 compute  197 TFLOP/s / chip
  HBM bandwidth      819 GB/s / chip
  ICI link bandwidth  50 GB/s / link (per mesh-axis neighbour hop)
  DCN bandwidth       3.125 GB/s / chip effective (25 Gb/s; pods cross DCN)
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import math
from typing import Mapping

import numpy as np

from repro.core.hypercube import Hypercube
from repro.telemetry import metrics as _telemetry

PEAK_BF16_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 3.125e9


@dataclasses.dataclass(frozen=True)
class CommEstimate:
    primitive: str
    algorithm: str                     # naive | hierarchical | direct
    schedule: tuple[str, ...]          # human-readable hop list
    ici_bytes: float                   # per-device bytes over ICI
    dcn_bytes: float                   # per-device bytes over DCN
    seconds: float
    stage: str = ""                    # the Table II stage this flow maps to
    est_source: str = "analytic"       # "analytic" | "measured" provenance

    def dominant(self) -> str:
        return "dcn" if self.dcn_bytes / DCN_BW > self.ici_bytes / ICI_BW \
            else "ici"


def _bw_time(ici_bytes: float, dcn_bytes: float) -> float:
    return ici_bytes / ICI_BW + dcn_bytes / DCN_BW


# ------------------------------------------------------- measured profiles
# Stack of installed CommProfiles (repro.tuning.profile); the innermost one
# prices every estimate whose (flow, stage, domains) its fitted models
# cover, replacing the hardcoded v5e constants with measured alpha-beta
# terms.  The planner only needs the duck-typed ``seconds_for`` interface,
# so there is no import cycle with the tuning package.
_PROFILES: list = []


def active_profile():
    """The innermost installed profile, or None (analytic constants)."""
    return _PROFILES[-1] if _PROFILES else None


@contextlib.contextmanager
def install_profile(profile):
    """Context manager pricing every ``plan``/``estimate``/``plan_program``
    call (and therefore every ``algorithm="auto"`` dispatch) under it from
    ``profile``'s measured models.  Nests; the innermost profile wins."""
    _PROFILES.append(profile)
    try:
        yield profile
    finally:
        _PROFILES.remove(profile)


def _finish(primitive: str, algorithm: str, sched: tuple[str, ...],
            ici: float, dcn: float, stage: str, profile) -> CommEstimate:
    """Price one candidate: measured model when the active/passed profile
    covers this (flow, stage, domains), analytic constants otherwise."""
    prof = profile if profile is not None else active_profile()
    if prof is not None:
        t = prof.seconds_for(algorithm, stage, ici, dcn)
        if t is not None:
            return CommEstimate(primitive, algorithm, sched, ici, dcn, t,
                                stage, "measured")
    return CommEstimate(primitive, algorithm, sched, ici, dcn,
                        _bw_time(ici, dcn), stage)


def _group_bytes(primitive: str, payload: float, g: int) -> float:
    """Per-device bytes moved by the *direct* algorithm on one flat group."""
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    return {
        "all_to_all": payload * frac,
        "reduce_scatter": payload * frac,
        "all_gather": payload * (g - 1),   # payload = per-device shard bytes
        "all_reduce": 2 * payload * frac,
        "broadcast": payload,
        "scatter": payload,
        "gather": payload,
        "reduce": payload,
    }[primitive]


# compute-fused ring flows (repro.kernels.collective) and the primitive
# each one is registered under; the planner races them for that primitive
# and validates explicit estimate requests against it
_FUSED_PRIMITIVE = {
    "ring_fused": "all_gather",
    "ag_prologue": "all_gather",
    "rs_epilogue": "reduce_scatter",
}

# ppermute ladders go HLO-quadratic past this group size (same bound as
# comm._LADDER_MAX), so the fused ring candidates drop out of the race on
# larger groups
_FUSED_GROUP_MAX = 32


def _table_ii_stage(primitive: str, algorithm: str) -> str:
    """Map a planner flow onto the stage label its estimates report.

    Non-Table-II registry entries (hierarchical, compressed, the
    compute-fused ring flows) carry their own stage label -- reuse it, so
    estimate provenance never reports a bogus Table II stage for a flow
    that is not a Table II row.  ``direct`` has no registry entry: it runs
    the runtime's best native flow, whose Table II stage is the resolved
    pidcomm stage."""
    from repro.core.comm import get_algorithm, resolve_stage
    if algorithm == "naive":
        return "naive"
    try:
        spec = get_algorithm(primitive, algorithm)
    except ValueError:
        spec = None
    if spec is not None and not spec.table_ii:
        return spec.stage
    return resolve_stage(primitive, "pidcomm")


def estimate(cube: Hypercube, primitive: str, dims, payload_bytes: float,
             algorithm: str = "pidcomm", *, dtype_bytes: int = 4,
             block: int = 256, profile=None) -> CommEstimate:
    """Estimate one collective. ``payload_bytes`` is the per-device payload
    (for all_gather: the local shard; for others: the local buffer).

    ``algorithm``: ``naive`` (replicated-intermediate host flow),
    ``direct`` (one flat native collective over the whole group, even when
    it crosses DCN), ``compressed`` (the §V-C hierarchical split with a
    blockwise-int8 DCN hop; ``dtype_bytes``/``block`` size the compression
    ratio), or ``pidcomm``/``hierarchical`` (the §IX-A split whenever the
    primitive is an all-reduce spanning both domains; like the runtime, the
    request *falls back to direct* otherwise -- check the returned
    ``algorithm`` field when the distinction matters).

    ``profile`` (or an :func:`install_profile` context) switches the
    *time* term to the profile's measured alpha-beta models when they cover
    the flow -- the returned estimate then carries
    ``est_source="measured"``.  The byte terms stay analytic either way:
    they are structural properties of the flow.
    """
    if algorithm in _FUSED_PRIMITIVE:
        want = _FUSED_PRIMITIVE[algorithm]
        if primitive != want:
            raise ValueError(
                f"fused algorithm {algorithm!r} is an {want!r} flow, not "
                f"{primitive!r}")
    elif algorithm not in ("pidcomm", "naive", "direct", "hierarchical",
                           "compressed"):
        raise ValueError(f"unknown planner algorithm {algorithm!r}")
    sel = cube.resolve_dims(dims)
    fast, slow = cube.split_fast_slow(sel)
    gf = int(np.prod([cube.size(d) for d in fast])) if fast else 1
    gs = int(np.prod([cube.size(d) for d in slow])) if slow else 1
    g = gf * gs

    if algorithm == "compressed":
        # §V-C int8 DCN hop: full-precision ICI reduce-scatter, int8
        # all-gather of the 1/|ICI| shard (+ one fp32 scale per block)
        # across pods, ICI all-gather back.
        ici = 2 * payload_bytes * (gf - 1) / gf if gf > 1 else 0.0
        shard = payload_bytes / gf
        dcn = (gs - 1) * (shard / dtype_bytes) * (1.0 + 4.0 / block) \
            if gs > 1 else 0.0
        sched = ((f"reduce_scatter[{'x'.join(fast)}]",) if fast else ()) + \
            ((f"all_gather-int8[{'x'.join(slow)}]",) if slow else ()) + \
            ((f"all_gather[{'x'.join(fast)}]",) if fast else ())
        return _finish(primitive, "compressed", sched, ici, dcn, "cm",
                       profile)

    if algorithm == "naive":
        # replicated-intermediate flow: every device ships its full payload to
        # everyone and receives (g-1) full payloads.
        ici = payload_bytes * (gf - 1) if gf > 1 else 0.0
        dcn = payload_bytes * (g - 1) - ici if gs > 1 else 0.0
        sched = (f"allgather-full[{'x'.join(sel)}]", "local-modulate",
                 "local-slice")
        return _finish(primitive, "naive", sched, ici, dcn, "naive", profile)

    if algorithm in _FUSED_PRIMITIVE:
        # compute-fused ring flows (repro.kernels.collective): per-device
        # bytes match the direct flow exactly -- the ring moves the same
        # blocks, just interleaved with compute -- so the byte terms reuse
        # the direct model and only the (measured) time term can separate
        # fused from unfused.  Stage comes from the registry entry
        # (non-Table-II), never from the Table II resolution.
        ici = _group_bytes(primitive, payload_bytes, gf) if gf > 1 else 0.0
        dcn = 0.0
        if gs > 1:
            dcn = _group_bytes(
                primitive,
                payload_bytes * (gf if primitive == "all_gather" else 1), gs)
        hops = g - 1
        sched = (f"ppermute-ring[{'x'.join(sel)}]x{hops}·fused-compute",)
        return _finish(primitive, algorithm, sched, ici, dcn,
                       _table_ii_stage(primitive, algorithm), profile)

    if (algorithm != "direct" and primitive == "all_reduce"
            and gs > 1 and gf > 1):
        # hierarchical §IX-A
        ici = 2 * payload_bytes * (gf - 1) / gf
        dcn = 2 * (payload_bytes / gf) * (gs - 1) / gs
        sched = (f"reduce_scatter[{'x'.join(fast)}]",
                 f"all_reduce[{'x'.join(slow)}]",
                 f"all_gather[{'x'.join(fast)}]")
        return _finish(primitive, "hierarchical", sched, ici, dcn,
                       _table_ii_stage(primitive, "hierarchical"), profile)

    ici = _group_bytes(primitive, payload_bytes, gf) if gf > 1 else 0.0
    # direct over a pod-crossing group: the (gs-1)/gs fraction crosses DCN
    dcn = 0.0
    if gs > 1:
        total = _group_bytes(primitive, payload_bytes * (gf if primitive == "all_gather" else 1), gs)
        dcn = total
    sched = (f"{primitive}[{'x'.join(sel)}]",)
    return _finish(primitive, "direct", sched, ici, dcn,
                   _table_ii_stage(primitive, "direct"), profile)


# -------------------------------------------------------- program planning
@dataclasses.dataclass(frozen=True)
class ProgramOpSpec:
    """One CommProgram op as the planner sees it (shapes only)."""
    op_id: int
    primitive: str
    dims: tuple[str, ...]
    payload_bytes: float
    deps: tuple[int, ...] = ()
    algorithm: str = "auto"
    op: str = "add"                    # reducer, for escalation parity
    allow_compressed: bool = False


@dataclasses.dataclass(frozen=True)
class ProgramPlan:
    """Joint plan for a whole program: per-op estimates under one shared
    ICI/DCN budget, an explicit interleaving order for independent ops, and
    the overlapped vs serial time bounds.

    ``est_source`` is the plan-level provenance: ``"measured"`` when every
    per-op estimate came from a profile's fitted models AND the
    interleaving budget was priced from its measured overlap factors (a
    single-op wave has no interleaving to price, so all-singleton programs
    only need the op models); ``"mixed"`` when measurement covered part of
    the pricing -- including the previously-unclosable case of measured
    per-op seconds under the *analytic* interleaving model; ``"analytic"``
    otherwise.  Inter-wave boundary pairs (the previous wave's tail op
    against the next wave's head op, when the head does not consume the
    tail's output) count toward the same pair coverage: an unmeasured
    overlappable boundary demotes the plan to ``"mixed"``."""
    estimates: Mapping[int, CommEstimate]
    order: tuple[int, ...]             # dependency-safe dispatch order
    levels: tuple[tuple[int, ...], ...]  # independent-op waves
    ici_bytes: float
    dcn_bytes: float
    seconds: float                     # overlap-aware whole-program time
    serial_seconds: float              # sum of per-op estimates
    est_source: str = "analytic"       # "analytic" | "mixed" | "measured"


# planner algorithm to estimate for an explicitly requested dispatch
# algorithm; anything unlisted (Table II stages, ring/tree, "pidcomm") runs
# the runtime's native flow, whose byte model is "direct".
_REQUEST_TO_PLANNER = {
    "naive": "naive",
    "hierarchical": "pidcomm",
    "compressed": "compressed",
    "ring_fused": "ring_fused",
    "ag_prologue": "ag_prologue",
    "rs_epilogue": "rs_epilogue",
}


def _wave_order_state(order, est: Mapping[int, CommEstimate], factor_of
                      ) -> tuple[float, int, int, dict[int, float]]:
    """Like :func:`_wave_order_seconds` but also returns the per-op
    remaining-hideable-time map (``left``), which inter-wave boundary
    pricing consumes so an op hidden within its wave cannot be hidden
    again across the wave boundary."""
    total = sum(est[i].seconds for i in order)
    measured = 0
    left = {i: est[i].seconds for i in order}
    for a, b in zip(order, order[1:]):
        da, db = est[a].dominant(), est[b].dominant()
        f = factor_of(da, db)
        if f is None:
            f = 0.0 if da != db else 1.0
        else:
            measured += 1
        small = a if est[a].seconds <= est[b].seconds else b
        credit = min((1.0 - f) * min(est[a].seconds, est[b].seconds),
                     left[small])
        left[small] -= credit
        total -= credit
    return (max(total, max(est[i].seconds for i in order)),
            measured, len(order) - 1, left)


def _wave_order_seconds(order, est: Mapping[int, CommEstimate],
                        factor_of) -> tuple[float, int, int]:
    """Price one candidate dispatch order of independent ops under the
    adjacent-pair overlap model: ops issue in sequence, and each adjacent
    pair (a, b) hides ``(1 - f(dom_a, dom_b)) * min(sec_a, sec_b)`` of the
    smaller op's time, where f is the measured serialization factor of the
    *ordered* domain pair.  Unmeasured pairs fall back to the analytic
    assumption (cross-domain links stream concurrently, f=0; same-domain
    dispatches serialize on the link, f=1).  An op's time can only be
    hidden once: the credit attributed to the smaller member of each pair
    is capped by what that op has left to hide, so a short op flanked by
    two long neighbours is not subtracted twice.  Returns
    (seconds, measured_pairs, total_pairs) for this order."""
    seconds, measured, pairs, _ = _wave_order_state(order, est, factor_of)
    return seconds, measured, pairs


def _boundary_credit(tail: int | None, head: int,
                     est: Mapping[int, CommEstimate], factor_of,
                     left_prev, left_new, deps_of
                     ) -> tuple[float, int, int, int | None]:
    """Inter-wave extension of the adjacent-pair model: the boundary pair
    (last op of wave i's chosen order, first op of wave i+1's) overlaps
    across the dependency-wave boundary exactly like an intra-wave pair --
    but only when the dependence structure allows it (the head op must not
    consume the tail op's output) and only under a *measured* factor (the
    analytic budget formula knows nothing about wave boundaries and must
    stay bit-identical without a profile).  Credits are capped by both
    ops' remaining hideable time, so time hidden inside a wave is never
    hidden again at the boundary.  Returns
    (credit, measured_pairs, total_pairs, capped_op): the op whose
    ``left`` the caller must decrement when the credit lands."""
    if tail is None:
        return 0.0, 0, 0, None
    if tail in deps_of.get(head, ()):
        return 0.0, 0, 0, None          # structurally serialized: no pair
    f = factor_of(est[tail].dominant(), est[head].dominant())
    if f is None:
        return 0.0, 0, 1, None          # unmeasured boundary -> "mixed"
    small = tail if est[tail].seconds <= est[head].seconds else head
    cap = left_prev[tail] if small == tail else left_new[head]
    credit = min((1.0 - f) * min(est[tail].seconds, est[head].seconds), cap)
    return credit, 1, 1, small


def _alternate(first, second):
    out = []
    for pair in itertools.zip_longest(first, second):
        out += [i for i in pair if i is not None]
    return out


def plan_program(cube: Hypercube, ops, *, profile=None) -> ProgramPlan:
    """One planning pass over a whole CommProgram.

    Per op: ``algorithm="auto"`` gets the full :func:`plan` candidate race;
    explicit requests get the matching :func:`estimate`.  Ops are then
    levelled by data dependency; within a level (independent ops) the
    dispatch order interleaves ICI-dominant and DCN-dominant ops so both
    domains stream concurrently, and the level's time is the larger of the
    two domain budgets (plus any op that exceeds both alone).

    ``profile`` (or an :func:`install_profile` context) prices every op
    from measured models where covered, like :func:`plan`.  A profile with
    an ``overlap`` section additionally replaces the analytic interleaving
    model: candidate dispatch orders for each wave (domain-alternating both
    ways, domain-grouped both ways, longest-first) race under the measured
    ordered-pair serialization factors (:func:`_wave_order_seconds`), so
    both the chosen order and the ``seconds``-vs-``serial_seconds`` budget
    are priced from data -- the plan's ``est_source`` says how much of the
    pricing was measured.

    The measured factors also discount **across dependency-wave
    boundaries** (:func:`_boundary_credit`): when the head op of wave i+1
    does not consume the tail op of wave i's output, the boundary pair
    overlaps exactly like an intra-wave adjacent pair -- the candidate
    race for each wave includes the boundary credit, hideable time is
    shared with the intra-wave pricing (an op is never hidden twice), and
    waves stop being a hard serialization fence.  Without measured
    factors the analytic budget (waves strictly sum) is unchanged,
    bit-for-bit.
    """
    est: dict[int, CommEstimate] = {}
    for o in ops:
        if o.algorithm in ("auto", "pidcomm"):
            est[o.op_id] = plan(cube, o.primitive, o.dims, o.payload_bytes,
                                allow_compressed=o.allow_compressed,
                                profile=profile)
        else:
            alg = _REQUEST_TO_PLANNER.get(o.algorithm)
            if alg is None:
                # Table II stage / ring / tree: native flow, "direct" byte
                # model -- except an additive im-resolving all_reduce, which
                # the dispatcher escalates to the hierarchical split when
                # the group spans both domains (estimate("pidcomm") applies
                # exactly that condition, falling back to direct otherwise).
                alg = "direct"
                if (o.primitive == "all_reduce" and o.op == "add"
                        and o.algorithm not in ("ring", "tree")):
                    from repro.core.comm import resolve_stage
                    try:
                        stage = resolve_stage("all_reduce", o.algorithm)
                    except ValueError:
                        stage = None
                    if stage == "im":
                        alg = "pidcomm"
            est[o.op_id] = estimate(
                cube, o.primitive, o.dims, o.payload_bytes, alg,
                profile=profile)

    prof = profile if profile is not None else active_profile()
    factor_of = getattr(prof, "overlap_factor", None) \
        if prof is not None and getattr(prof, "has_overlap", False) else None

    # dependency levels (wave l = ops whose deps all sit in waves < l)
    level_of: dict[int, int] = {}
    remaining = {o.op_id: o for o in ops}
    deps_of = {o.op_id: frozenset(o.deps) for o in ops}
    levels: list[tuple[int, ...]] = []
    seconds = 0.0
    pairs_measured = pairs_total = 0
    # inter-wave boundary state: the tail op of the previous wave's chosen
    # order and its remaining-hideable-time map, carried only while the
    # previous wave was priced by the measured pairwise model (an analytic
    # wave breaks the chain -- the analytic formula knows no boundaries)
    prev_tail: int | None = None
    prev_left: dict[int, float] = {}
    while remaining:
        wave = [oid for oid, o in remaining.items()
                if all(d in level_of or d not in est for d in o.deps)]
        if not wave:
            raise ValueError("cyclic dependencies in program ops")
        # analytic interleaving: alternate DCN-dominant and ICI-dominant
        # ops (longest first within each domain) so neither link sits idle
        dcn = sorted((oid for oid in wave if est[oid].dominant() == "dcn"),
                     key=lambda i: -est[i].seconds)
        ici = sorted((oid for oid in wave if est[oid].dominant() == "ici"),
                     key=lambda i: -est[i].seconds)
        inter = _alternate(dcn, ici)
        priced = None
        if factor_of is not None:
            # measured interleaving: race candidate orders under the
            # profile's ordered-pair factors; first candidate wins ties so
            # the analytic alternation stays the default shape.  The race
            # is boundary-aware: each candidate's score includes the
            # credit its head op can earn across the previous wave's
            # boundary, so a head that pipelines with the previous tail
            # can win the wave.
            cands, seen = [], set()
            for c in (inter, _alternate(ici, dcn), dcn + ici, ici + dcn,
                      sorted(wave, key=lambda i: -est[i].seconds)):
                t = tuple(c)
                if t not in seen:
                    seen.add(t)
                    cands.append(t)
            priced = []
            for c in cands:
                s, m, p, left = _wave_order_state(c, est, factor_of)
                bc, bm, bp, bsmall = _boundary_credit(
                    prev_tail, c[0], est, factor_of, prev_left, left,
                    deps_of)
                priced.append((s - bc, m + bm, p + bp, left, bc, bsmall))
            # when the winning order owes nothing to a measured factor
            # (within the wave or across its boundary), keep the legacy
            # analytic budget below: est_source="analytic" must always
            # denote the same seconds formula (the pairwise fallback model
            # is only a vehicle for measured factors, never a
            # reformulation of the analytic one)
            if priced[min(range(len(priced)),
                          key=lambda k: priced[k][0])][1] == 0:
                priced = None
        if priced is None:
            # analytic budget: both links stream concurrently; any single
            # op slower than either link budget bounds the wave
            ici_t = sum(est[i].ici_bytes / ICI_BW for i in wave)
            dcn_t = sum(est[i].dcn_bytes / DCN_BW for i in wave)
            slowest = max(est[i].seconds for i in wave)
            wave_s = max(ici_t, dcn_t, slowest)
            chosen = inter
            pairs_total += len(wave) - 1
            prev_tail, prev_left = None, {}
        else:
            best = min(range(len(priced)), key=lambda k: priced[k][0])
            wave_s, n_meas, n_pairs, left, credit, small = priced[best]
            chosen = cands[best]
            pairs_measured += n_meas
            pairs_total += n_pairs
            if small is not None and credit > 0.0:
                # the boundary credit consumes hideable time like any
                # intra-wave pair: never hide the same op twice
                (prev_left if small == prev_tail else left)[small] -= credit
            prev_tail, prev_left = chosen[-1], left
        seconds += wave_s
        levels.append(tuple(chosen))
        for oid in chosen:
            level_of[oid] = len(levels) - 1
            del remaining[oid]

    n_measured = sum(e.est_source == "measured" for e in est.values())
    # "measured" demands every adjacent pair of every wave's chosen order
    # was priced from a measured factor (vacuously true for all-singleton
    # programs, where there is no interleaving to price); partial pair
    # coverage -- or the analytic interleaving model -- is "mixed"
    overlap_full = pairs_measured == pairs_total
    if n_measured == 0 and pairs_measured == 0:
        src = "analytic"
    elif n_measured == len(est) and overlap_full:
        src = "measured"
    else:
        src = "mixed"
    serial = sum(e.seconds for e in est.values())
    if _telemetry.enabled():
        _telemetry.inc("planner.plan_program_calls")
        _telemetry.inc(f"planner.est_source.{src}")
        _telemetry.observe("planner.plan_seconds_us", seconds * 1e6)
        _telemetry.observe("planner.serial_seconds_us", serial * 1e6)
    return ProgramPlan(
        estimates=est,
        order=tuple(oid for wave in levels for oid in wave),
        levels=tuple(levels),
        ici_bytes=sum(e.ici_bytes for e in est.values()),
        dcn_bytes=sum(e.dcn_bytes for e in est.values()),
        seconds=seconds,
        serial_seconds=serial,
        est_source=src)


def plan(cube: Hypercube, primitive: str, dims, payload_bytes: float, *,
         allow_compressed: bool = False, profile=None) -> CommEstimate:
    """Pick the fastest flow for this primitive/group among the naive host
    flow, the flat direct collective, and (when the group spans both
    domains) the hierarchical split.  This is what ``algorithm="auto"``
    dispatch on a :class:`repro.core.comm.Communicator` executes.

    ``allow_compressed`` adds the §V-C int8-DCN candidate for pod-crossing
    additive all-reduces; it is opt-in because the caller (e.g. the trainer)
    owns the accuracy contract that lossy compression bends.

    Under an installed (or passed) measured profile the race is priced from
    the fitted alpha-beta models wherever they cover a candidate, so
    ``algorithm="auto"`` dispatches on measured data -- the picked
    estimate's ``est_source`` says which model priced it.  Measured and
    analytic seconds are not commensurable (CPU wall time vs v5e
    constants), so when *any* candidate is measured the race is restricted
    to the measured ones: an uncovered candidate must not win on
    incomparably-cheap analytic numbers.
    """
    algs = ["naive", "direct", "pidcomm"]
    if allow_compressed and primitive == "all_reduce" \
            and cube.crosses_dcn(dims):
        algs.append("compressed")
    if cube.group_size(cube.resolve_dims(dims)) <= _FUSED_GROUP_MAX:
        algs += [a for a, p in _FUSED_PRIMITIVE.items() if p == primitive]
    cands = [estimate(cube, primitive, dims, payload_bytes, a,
                      profile=profile) for a in algs]
    measured = [e for e in cands if e.est_source == "measured"]
    if measured:
        cands = measured
    # Tie-break away from naive (when the byte model can't separate the host
    # flow from the native collective, the runtime still executes the native
    # one, and the reported stage must reflect that) and away from the fused
    # ring flows (their byte model ties direct exactly, so analytically they
    # never win -- only a measured profile can price them cheaper).
    return min(cands, key=lambda e: (e.seconds, e.algorithm == "naive",
                                     e.algorithm in _FUSED_PRIMITIVE))


def matmul_time(m: int, n: int, k: int, dtype_bytes: int = 2) -> float:
    """Roofline time of one matmul on one chip: max(compute, memory)."""
    flops = 2 * m * n * k
    bytes_ = dtype_bytes * (m * k + k * n + m * n)
    return max(flops / PEAK_BF16_FLOPS, bytes_ / HBM_BW)
