"""Deferred CommProgram IR: record -> optimize -> execute collective programs.

PID-Comm's headline gains come from *composed* communication -- applications
chain reduce_scatter / all_gather / all_to_all across hypercube dims, and the
framework wins by scheduling the whole pattern rather than one primitive at a
time (paper SVII apps, SIX-A hierarchy).  The eager ``Communicator`` plans
each call in isolation; this module adds the whole-program surface:

  recording
      ``cube.program()`` / ``comm.program()`` / ``topo.program()`` open a
      scope in which every ``Communicator`` primitive appends a
      :class:`CommOp` (abstract shape/dtype, group bitmap, data deps)
      instead of dispatching, and returns a symbolic :class:`ProgramValue`.
      Concrete arrays (including jax tracers) passed into a primitive are
      captured as program *constants*; ``prog.input(aval)`` declares
      placeholders bound positionally at ``execute(*inputs)``.

  ``program.lower()``
      runs the optimization pipeline:
        * peephole fusion -- a ``reduce_scatter`` whose only consumer is an
          ``all_gather`` on the same axis/group becomes one ``all_reduce``
          (and the reverse split when the cost model strictly prefers it);
        * same-group coalescing -- independent small all-reduces on the same
          (group, op, dtype, algorithm) flatten/concat into one bucketed
          dispatch (the trainer's ``sync_replicated_grads`` is the client);
        * joint planning -- one :func:`repro.core.planner.plan_program` pass
          estimating every op under a shared ICI/DCN budget and choosing an
          explicit interleaving order for independent ops.

  execution
      ``program.execute(*inputs)`` runs the optimized schedule through the
      existing algorithm registry (each op dispatches via
      ``Communicator._dispatch``, so stage resolution, planner estimates and
      CommTrace instrumentation are identical to the eager path); every
      emitted :class:`~repro.core.comm.CommEvent` carries this program's
      ``program_id`` and the ``fused_from`` provenance of rewritten ops.
      ``execute_async()`` returns per-op :class:`CommFuture` s backed by
      dependency-ordered dispatch.

Eager single-op calls remain supported -- a one-op program executes the
identical registry body, so the conformance matrix is bit-identical through
both paths (tests/test_program.py).

Repeated recordings with identical op structure (the trainer's per-step
gradient sync, any re-traced ``comm.program()`` scope) reuse one cached
lowered schedule -- rewrite passes, coalescing buckets and the joint plan
run once per structural fingerprint, not once per program instance (see
``_LOWER_CACHE`` / ``LOWER_STATS``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import threading
import weakref
from typing import Any, Sequence

import jax
import numpy as np

from repro.core import planner
from repro.telemetry import metrics as _telemetry
from repro.telemetry import spans as _spans

# Coalescing folds all-reduces at or below this per-device payload into one
# bucketed dispatch (gradient-leaf scale; large tensors keep their own op).
DEFAULT_COALESCE_BYTES = 1 << 20

_PROGRAM_IDS = itertools.count()

# -------------------------------------------------- cross-program reuse
# Two programs with the same *structure* (op graph, avals, input/output
# wiring) lower to the same optimized schedule, so re-lowering every
# instance -- the trainer records a fresh grad-sync program each traced
# step -- redoes identical rewrite passes, bucket construction and joint
# planning.  ``lower()`` therefore consults a cache keyed by the program's
# structural fingerprint plus everything else that shapes the result: the
# lowering knobs and the installed profile's content token (a plan priced
# under one profile must not serve another).  A hit rebinds the cached
# schedule to the new program, so its constants (e.g. the fresh step's
# gradient tracers) are picked up at execution while the ops, coalescing
# buckets and ProgramPlan are reused verbatim.
#
# Lifetime: cached entries hold *program-less* LoweredPrograms (retaining
# the recording program would pin its captured constants -- per-trace
# gradient tracers, arbitrary arrays -- indefinitely), and the cache dict
# itself lives ON the cube object rather than in a module global: the
# cached ops reference the cube through their communicators anyway, so a
# module-level cache would pin every cube ever lowered against; attached
# to the cube, a discarded cube and its schedules form an internal cycle
# the garbage collector reclaims together.
_LOWER_CACHE_MAX = 256
_CACHED_CUBES: weakref.WeakSet = weakref.WeakSet()

# observability: how many schedules were actually built vs reused (dryrun
# records the per-cell delta; tests assert reuse strictly reduces work)
LOWER_STATS = {"lowered": 0, "cache_hits": 0}


def _cube_lower_cache(cube) -> dict:
    cache = getattr(cube, "_lower_cache", None)
    if cache is None:
        cache = {}
        # Hypercube is a frozen dataclass; attach the mutable cache the
        # same way frozen __init__ does
        object.__setattr__(cube, "_lower_cache", cache)
        _CACHED_CUBES.add(cube)
    return cache


def clear_lower_cache() -> None:
    for cube in list(_CACHED_CUBES):
        getattr(cube, "_lower_cache", {}).clear()


def _profile_token() -> str | None:
    """Cache-key component for the installed profile; None disables
    caching entirely -- a duck-typed profile without a content ``token()``
    has no alias-safe identity (``id()`` can be recycled after GC and
    would silently serve a plan priced under a dead profile)."""
    prof = planner.active_profile()
    if prof is None:
        return "analytic"
    tok = getattr(prof, "token", None)
    return tok() if callable(tok) else None

# Stack of CommPrograms currently recording.  ``Communicator._dispatch``
# consults :func:`active_program` on every call; execution temporarily
# suspends recording so a program can be executed from inside another scope.
# Both the stack and the suspension counter are thread-local: a background
# executor running a lowered program (e.g. the checkpoint gather offload)
# must not suppress — or record into — a program being built concurrently
# on the main thread.
_TLS = threading.local()


def _tls_state() -> "threading.local":
    if not hasattr(_TLS, "recording"):
        _TLS.recording = []  # list[CommProgram]
        _TLS.suspended = 0
    return _TLS


def active_program() -> "CommProgram | None":
    """The innermost recording scope on this thread, or None (also None
    mid-execution)."""
    tls = _tls_state()
    if tls.suspended or not tls.recording:
        return None
    return tls.recording[-1]


class _suspend_recording:
    def __enter__(self):
        _tls_state().suspended += 1

    def __exit__(self, *exc):
        _tls_state().suspended -= 1
        return False


# ------------------------------------------------------------------- values
class ProgramValue:
    """Symbolic SSA value inside a :class:`CommProgram` (abstract aval only).

    Mimics enough of the array protocol (shape/dtype/size/ndim) that shape
    arithmetic and payload accounting treat it like the array it stands for.
    """

    __slots__ = ("program", "vid")

    def __init__(self, program: "CommProgram", vid: int):
        self.program = program
        self.vid = vid

    @property
    def aval(self):
        return self.program._avals[self.vid]

    @property
    def shape(self):
        return self.aval.shape

    @property
    def dtype(self):
        return self.aval.dtype

    @property
    def ndim(self) -> int:
        return len(self.aval.shape)

    @property
    def size(self) -> int:
        return int(np.prod(self.aval.shape)) if self.aval.shape else 1

    def __repr__(self):
        return (f"ProgramValue(v{self.vid}: "
                f"{self.dtype}{list(self.shape)} of {self.program.program_id})")


def _aval_of(x) -> jax.ShapeDtypeStruct:
    shape = tuple(getattr(x, "shape", ()))
    dtype = getattr(x, "dtype", None)
    return jax.ShapeDtypeStruct(shape, np.dtype(dtype if dtype is not None
                                                else np.float32))


def _result_aval(comm, primitive: str, aval, kwargs) -> jax.ShapeDtypeStruct:
    """Abstract per-shard output shape of one primitive (shape inference)."""
    shape = list(aval.shape)
    g = comm.group_size

    def ax(name):
        a = kwargs[name]
        return a % len(shape) if shape else 0

    if primitive in ("all_reduce", "scatter", "broadcast", "gather"):
        pass
    elif primitive == "reduce_scatter":
        a = ax("axis")
        if shape[a] % g:
            raise ValueError(
                f"reduce_scatter axis {a} of {tuple(shape)} not divisible by "
                f"group size {g}")
        shape[a] //= g
    elif primitive == "all_gather":
        shape[ax("axis")] *= g
    elif primitive == "all_to_all":
        s, c = ax("split_axis"), ax("concat_axis")
        if shape[s] % g:
            raise ValueError(
                f"all_to_all split axis {s} of {tuple(shape)} not divisible "
                f"by group size {g}")
        shape[s] //= g
        shape[c] *= g
    elif primitive == "reduce":
        del shape[ax("axis")]
    else:
        raise ValueError(f"unknown primitive {primitive!r}")
    return jax.ShapeDtypeStruct(tuple(shape), aval.dtype)


# ---------------------------------------------------------------------- ops
@dataclasses.dataclass
class CommOp:
    """One recorded (or rewritten) collective in the program IR."""
    op_id: int
    primitive: str
    comm: Any                      # repro.core.comm.Communicator
    algorithm: str                 # requested ("auto", stage, registered)
    op: str                        # reducer name for reduction primitives
    kwargs: dict                   # axis / split_axis / concat_axis
    in_vids: tuple[int, ...]
    out_vids: tuple[int, ...]
    fused_from: tuple[int, ...] = ()   # provenance: recorded op ids
    coalesced: bool = False
    # multi-dim all_to_all chain (§VII DLRM pattern): per-stage
    # (communicator, kwargs, algorithm) triples.  A chained op is ONE IR op
    # -- jointly planned over the union of its dims -- whose execution
    # dispatches the stages in order, because the sequential per-dim chain
    # is what the recorded program computed (a single joint multi-dim
    # all_to_all permutes blocks differently and is NOT bit-identical).
    chain: tuple = ()

    @property
    def bitmap(self) -> str:
        return self.comm.bitmap

    def describe(self, program: "CommProgram") -> str:
        ins = ",".join(f"v{v}" for v in self.in_vids)
        outs = ",".join(f"v{v}" for v in self.out_vids)
        tag = ""
        if self.fused_from:
            kind = "coalesced" if self.coalesced else (
                "chained" if self.chain else "fused")
            tag = f" [{kind} from {list(self.fused_from)}]"
        return (f"op{self.op_id}: {outs} = {self.primitive}"
                f"[{self.bitmap}/{self.algorithm}]({ins}){tag}")


# ------------------------------------------------------------------ program
class CommProgram:
    """A recorded collective program over one hypercube.

    Use as a context manager; inside the scope every bound
    :class:`~repro.core.comm.Communicator` of the same cube appends ops here
    instead of dispatching.  ``lower()`` optimizes + plans, ``execute()``
    runs the optimized schedule (lowering on first use).
    """

    def __init__(self, cube, *, name: str = ""):
        self.cube = cube
        self.program_id = name or f"prog{next(_PROGRAM_IDS)}"
        self._avals: list[jax.ShapeDtypeStruct] = []
        self._consts: dict[int, Any] = {}
        self._input_vids: list[int] = []
        self._output_vids: list[int] = []
        self._ops: list[CommOp] = []
        self._open = False
        self._closed = False
        self._lowered: "LoweredProgram | None" = None

    # ------------------------------------------------------------ recording
    def __enter__(self) -> "CommProgram":
        if self._closed:
            raise RuntimeError(f"{self.program_id} already recorded")
        _tls_state().recording.append(self)
        self._open = True
        return self

    def __exit__(self, *exc):
        _tls_state().recording.remove(self)
        self._open = False
        self._closed = True
        return False

    def _new_value(self, aval) -> ProgramValue:
        self._avals.append(aval)
        return ProgramValue(self, len(self._avals) - 1)

    def input(self, x) -> ProgramValue:
        """Declare a positional input placeholder.  ``x`` is an abstract
        value (``jax.ShapeDtypeStruct``), an array to take shape/dtype from,
        or a ``(shape, dtype)`` pair."""
        if isinstance(x, tuple) and len(x) == 2 and not hasattr(x, "dtype"):
            aval = jax.ShapeDtypeStruct(tuple(x[0]), np.dtype(x[1]))
        else:
            aval = _aval_of(x)
        v = self._new_value(aval)
        self._input_vids.append(v.vid)
        return v

    def output(self, *values: ProgramValue) -> None:
        """Declare program outputs (in ``execute`` return order).  Without
        any declaration, every op result not consumed by another op is an
        output, in creation order."""
        for v in values:
            if not isinstance(v, ProgramValue) or v.program is not self:
                raise ValueError(f"{v!r} is not a value of this program")
            self._output_vids.append(v.vid)

    def record_op(self, comm, primitive: str, x, *, algorithm: str,
                  op: str = "add", kwargs: dict | None = None
                  ) -> ProgramValue:
        """Append one op (called by ``Communicator._dispatch`` while this
        scope is active).  Non-ProgramValue payloads are captured as
        constants, bound at record time."""
        if not self._open:
            raise RuntimeError(f"{self.program_id} is not recording")
        if comm.cube is not self.cube:
            raise ValueError(
                f"communicator {comm.describe()} is bound to a different "
                f"cube than program {self.program_id}")
        kwargs = dict(kwargs or {})
        if isinstance(x, ProgramValue):
            if x.program is not self:
                raise ValueError(
                    f"value of {x.program.program_id} used inside "
                    f"{self.program_id}")
            vin = x.vid
        else:
            v = self._new_value(_aval_of(x))
            self._consts[v.vid] = x
            vin = v.vid
        out = self._new_value(
            _result_aval(comm, primitive, self._avals[vin], kwargs))
        self._ops.append(CommOp(
            op_id=len(self._ops), primitive=primitive, comm=comm,
            algorithm=algorithm, op=op, kwargs=kwargs,
            in_vids=(vin,), out_vids=(out.vid,)))
        return out

    # ------------------------------------------------------------- lowering
    def _default_outputs(self) -> tuple[int, ...]:
        if self._output_vids:
            return tuple(self._output_vids)
        consumed = {v for o in self._ops for v in o.in_vids}
        return tuple(v for o in self._ops for v in o.out_vids
                     if v not in consumed)

    def structural_fingerprint(self) -> str:
        """Stable hash of everything the lowering pipeline reads from this
        program *except* constant values: the op graph (primitive, dims,
        algorithm, reducer, kwargs, SSA wiring), every value's aval, and
        the input/output declarations.  Two programs with equal
        fingerprints lower to interchangeable schedules, which is what
        keys cross-program reuse (the trainer's per-step grad-sync records
        fresh tracers as constants, but the structure never changes)."""
        blob = json.dumps({
            "avals": [(list(a.shape), np.dtype(a.dtype).str)
                      for a in self._avals],
            "consts": sorted(self._consts),
            "inputs": self._input_vids,
            "outputs": list(self._default_outputs()),
            "ops": [(o.primitive, list(o.comm.dims), o.algorithm, o.op,
                     sorted(o.kwargs.items()), list(o.in_vids),
                     list(o.out_vids))
                    for o in self._ops],
        }, sort_keys=True).encode()
        return hashlib.sha1(blob).hexdigest()

    def lower(self, *, fuse: bool = True, coalesce: bool = True,
              coalesce_bytes: int = DEFAULT_COALESCE_BYTES,
              split_all_reduce: str | bool = "cost",
              merge_a2a: bool = True, reuse: bool = True
              ) -> "LoweredProgram":
        """Optimize + jointly plan the recorded ops.

        ``split_all_reduce``: ``False`` never rewrites, ``True`` always
        splits an all_reduce into rs+ag (when the leading axis divides), and
        ``"cost"`` (default) splits only when the planner's estimate is
        strictly faster -- on this cost model the flat split ties the fused
        collective, so "cost" effectively keeps the fused form.

        ``merge_a2a``: merge consecutive all_to_all ops over disjoint
        hypercube dims into one jointly-planned multi-dim chain op (§VII
        DLRM pattern); execution stays the bit-identical sequential chain.

        ``reuse``: consult the cross-program lower cache -- a structurally
        identical program lowered earlier (same cube, same knobs, same
        installed profile) hands back its schedule rebound to this
        program's constants instead of re-running the passes.
        """
        if self._open:
            raise RuntimeError(
                f"{self.program_id} is still recording; lower() after the "
                "with-block closes")
        key = cache = None
        token = _profile_token() if reuse else None
        if reuse and token is not None:
            cache = _cube_lower_cache(self.cube)
            key = (self.structural_fingerprint(), fuse, coalesce,
                   coalesce_bytes, str(split_all_reduce), merge_a2a, token)
            hit = cache.get(key)
            if hit is not None:
                LOWER_STATS["cache_hits"] += 1
                _telemetry.inc("program.lower_cache_hits")
                _spans.maybe_instant("lower-cache-hit",
                                     program_id=self.program_id)
                return dataclasses.replace(hit, program=self)
        LOWER_STATS["lowered"] += 1
        _telemetry.inc("program.lowered")
        with _spans.maybe_span(f"lower:{self.program_id}", cat="trace",
                               program_id=self.program_id,
                               ops=len(self._ops)):
            ops = [dataclasses.replace(o) for o in self._ops]
            out_vids = self._default_outputs()
            if fuse:
                ops = _fuse_rs_ag(self, ops, out_vids)
            if split_all_reduce:
                ops = _split_all_reduce(self, ops, mode=split_all_reduce)
            if merge_a2a:
                ops = _merge_all_to_all(self, ops, out_vids)
            if coalesce:
                ops = _coalesce(self, ops, max_bytes=coalesce_bytes)
            if _telemetry.enabled():
                for o in ops:
                    if not o.fused_from:
                        continue
                    if o.coalesced:
                        _telemetry.inc("program.coalesced_ops")
                    elif o.chain:
                        _telemetry.inc("program.chained_ops")
                    else:
                        _telemetry.inc("program.fused_ops")
            produced = (set(self._consts) | set(self._input_vids)
                        | {v for o in ops for v in o.out_vids})
            lost = [v for v in out_vids if v not in produced]
            if lost:
                raise RuntimeError(
                    f"lowering {self.program_id} lost output values {lost} "
                    "(optimization-pass bug)")
            plan = planner.plan_program(self.cube, [
                planner.ProgramOpSpec(
                    op_id=o.op_id, primitive=o.primitive, dims=o.comm.dims,
                    payload_bytes=_op_payload_bytes(self, o),
                    deps=_dep_ids(o, ops), algorithm=o.algorithm, op=o.op)
                for o in ops])
        order = {oid: i for i, oid in enumerate(plan.order)}
        ops = sorted(ops, key=lambda o: order[o.op_id])
        lowered = LoweredProgram(program=self, ops=tuple(ops), plan=plan,
                                 out_vids=out_vids)
        if key is not None:
            if len(cache) >= _LOWER_CACHE_MAX:
                cache.pop(next(iter(cache)))
            cache[key] = dataclasses.replace(lowered, program=None)
        return lowered

    # ------------------------------------------------------------ execution
    def _lowered_default(self) -> "LoweredProgram":
        if self._lowered is None:
            self._lowered = self.lower()
        return self._lowered

    def execute(self, *inputs):
        """Lower (with default pipeline) and run; returns the tuple of
        program outputs (a single value is returned bare)."""
        return self._lowered_default().execute(*inputs)

    def execute_async(self, *inputs) -> "ProgramExecution":
        return self._lowered_default().execute_async(*inputs)

    def describe(self) -> str:
        lines = [f"CommProgram[{self.program_id} on {self.cube.describe()} "
                 f"ops={len(self._ops)} inputs={len(self._input_vids)}]"]
        lines += ["  " + o.describe(self) for o in self._ops]
        return "\n".join(lines)


def _op_payload_bytes(program: CommProgram, op: CommOp) -> int:
    total = 0
    for v in op.in_vids:
        aval = program._avals[v]
        size = int(np.prod(aval.shape)) if aval.shape else 1
        total += size * np.dtype(aval.dtype).itemsize
    return total


def _dep_ids(op: CommOp, ops: Sequence[CommOp]) -> tuple[int, ...]:
    producers = {v: o.op_id for o in ops for v in o.out_vids}
    return tuple(sorted({producers[v] for v in op.in_vids if v in producers}))


# ------------------------------------------------------- optimization passes
def _consumers(ops: Sequence[CommOp]) -> dict[int, list[CommOp]]:
    by_vid: dict[int, list[CommOp]] = {}
    for o in ops:
        for v in o.in_vids:
            by_vid.setdefault(v, []).append(o)
    return by_vid

def _next_op_id(ops: Sequence[CommOp], program: CommProgram) -> int:
    return max([o.op_id for o in ops] + [len(program._ops) - 1]) + 1


def _origin_ids(op: CommOp) -> tuple[int, ...]:
    """The *recorded* op ids behind ``op`` -- the fused_from contract always
    points at program._ops indices, so a rewrite of a rewrite chains its
    members' origins rather than the intermediate synthetic id."""
    return op.fused_from if op.fused_from else (op.op_id,)


def _fuse_rs_ag(program: CommProgram, ops: list[CommOp],
                out_vids: tuple[int, ...]) -> list[CommOp]:
    """Peephole: reduce_scatter -> all_gather on the same axis and group is
    one all_reduce (paper Table I algebra: AG(RS(x)) = AR(x))."""
    changed = True
    while changed:
        changed = False
        cons = _consumers(ops)
        for a in ops:
            if a.primitive != "reduce_scatter" or a.coalesced:
                continue
            v = a.out_vids[0]
            if v in out_vids:               # the shard itself is a result
                continue
            users = cons.get(v, [])
            if len(users) != 1:
                continue
            b = users[0]
            if (b.primitive != "all_gather" or b.comm.cube is not a.comm.cube
                    or b.comm.dims != a.comm.dims
                    or b.kwargs.get("axis") != a.kwargs.get("axis")):
                continue
            alg = a.algorithm if a.algorithm == b.algorithm else "auto"
            fused = CommOp(
                op_id=_next_op_id(ops, program), primitive="all_reduce",
                comm=a.comm, algorithm=alg, op=a.op, kwargs={},
                in_vids=a.in_vids, out_vids=b.out_vids,
                fused_from=_origin_ids(a) + _origin_ids(b))
            i = ops.index(a)
            ops = [o for o in ops if o is not a and o is not b]
            ops.insert(i, fused)
            changed = True
            break
    return ops


def _merge_all_to_all(program: CommProgram, ops: list[CommOp],
                      out_vids: tuple[int, ...]) -> list[CommOp]:
    """Peephole (§VII DLRM): consecutive all_to_all ops whose dim
    selections are *disjoint* -- the embedding-exchange chains that walk one
    hypercube dim group after another -- merge into one multi-dim chain op,
    planned jointly over the union of the dims.

    The merged op keeps sequential per-stage execution (see
    :class:`CommOp.chain`): a single joint all_to_all over the combined
    dims orders blocks differently, so chaining is the only rewrite that
    stays bit-identical to the unfused program.
    """
    changed = True
    while changed:
        changed = False
        cons = _consumers(ops)
        for a in ops:
            if a.primitive != "all_to_all" or a.coalesced:
                continue
            v = a.out_vids[0]
            if v in out_vids:           # the intermediate is a result
                continue
            users = cons.get(v, [])
            if len(users) != 1:
                continue
            b = users[0]
            if (b.primitive != "all_to_all" or b.coalesced
                    or b.comm.cube is not a.comm.cube
                    or set(a.comm.dims) & set(b.comm.dims)):
                continue
            chain = (a.chain or ((a.comm, a.kwargs, a.algorithm),)) \
                + (b.chain or ((b.comm, b.kwargs, b.algorithm),))
            union = tuple(d for d in a.comm.cube.dim_names
                          if d in a.comm.dims + b.comm.dims)
            merged = CommOp(
                op_id=_next_op_id(ops, program), primitive="all_to_all",
                comm=a.comm.cube.comm(union),
                algorithm=a.algorithm if a.algorithm == b.algorithm
                else "auto",
                op=a.op, kwargs={},     # per-stage kwargs live in the chain
                in_vids=a.in_vids, out_vids=b.out_vids,
                fused_from=_origin_ids(a) + _origin_ids(b), chain=chain)
            i = ops.index(a)
            ops = [o for o in ops if o is not a and o is not b]
            ops.insert(i, merged)
            changed = True
            break
    return ops


def _split_all_reduce(program: CommProgram, ops: list[CommOp],
                      *, mode) -> list[CommOp]:
    """Reverse rewrite: all_reduce -> reduce_scatter + all_gather over the
    first group-divisible axis, taken when the planner strictly prefers the
    split (or always, under ``mode=True``).  Ops created by fusion are left
    alone."""
    out = []
    for o in ops:
        aval = program._avals[o.in_vids[0]]
        g = o.comm.group_size
        axis = next((i for i, n in enumerate(aval.shape)
                     if n >= g and n % g == 0), None)
        eligible = (o.primitive == "all_reduce" and not o.fused_from
                    and not o.coalesced and axis is not None)
        if eligible and mode == "cost":
            payload = _op_payload_bytes(program, o)
            ar = planner.estimate(program.cube, "all_reduce", o.comm.dims,
                                  payload)
            rs = planner.estimate(program.cube, "reduce_scatter",
                                  o.comm.dims, payload)
            ag = planner.estimate(program.cube, "all_gather", o.comm.dims,
                                  payload / g)
            eligible = rs.seconds + ag.seconds < ar.seconds
        if not eligible:
            out.append(o)
            continue
        shard = program._new_value(_result_aval(
            o.comm, "reduce_scatter", aval, {"axis": axis}))
        nid = _next_op_id(ops + out, program)
        out.append(CommOp(
            op_id=nid, primitive="reduce_scatter", comm=o.comm,
            algorithm=o.algorithm, op=o.op, kwargs={"axis": axis},
            in_vids=o.in_vids, out_vids=(shard.vid,),
            fused_from=_origin_ids(o)))
        out.append(CommOp(
            op_id=nid + 1, primitive="all_gather", comm=o.comm,
            algorithm=o.algorithm, op="add", kwargs={"axis": axis},
            in_vids=(shard.vid,), out_vids=o.out_vids,
            fused_from=_origin_ids(o)))
    return out


def _reachable(frm: CommOp, to: CommOp, producers, by_id) -> bool:
    """True when ``to`` transitively consumes a value produced by ``frm``."""
    stack, seen = [to], set()
    while stack:
        cur = stack.pop()
        if cur.op_id == frm.op_id:
            return True
        if cur.op_id in seen:
            continue
        seen.add(cur.op_id)
        for v in cur.in_vids:
            p = producers.get(v)
            if p is not None:
                stack.append(by_id[p])
    return False


def _coalesce(program: CommProgram, ops: list[CommOp],
              *, max_bytes: int) -> list[CommOp]:
    """Flatten independent small same-group all-reduces into one bucketed
    dispatch per (dims, reducer, dtype, requested algorithm)."""
    producers = {v: o.op_id for o in ops for v in o.out_vids}
    by_id = {o.op_id: o for o in ops}
    buckets: dict[tuple, list[CommOp]] = {}
    for o in ops:
        if (o.primitive != "all_reduce" or o.kwargs or o.coalesced
                or len(o.in_vids) != 1
                or _op_payload_bytes(program, o) > max_bytes):
            continue
        key = (o.comm.dims, o.op, o.algorithm,
               np.dtype(program._avals[o.in_vids[0]].dtype).str)
        group = buckets.setdefault(key, [])
        # only mutually independent ops share a bucket
        if all(not _reachable(m, o, producers, by_id)
               and not _reachable(o, m, producers, by_id) for m in group):
            group.append(o)
    replaced: dict[int, CommOp] = {}
    next_id = _next_op_id(ops, program)
    for group in buckets.values():
        if len(group) < 2:
            continue
        lead = group[0]
        fused = CommOp(
            op_id=next_id, primitive="all_reduce",
            comm=lead.comm, algorithm=lead.algorithm, op=lead.op, kwargs={},
            in_vids=tuple(v for m in group for v in m.in_vids),
            out_vids=tuple(v for m in group for v in m.out_vids),
            fused_from=tuple(i for m in group for i in _origin_ids(m)),
            coalesced=True)
        next_id += 1
        replaced.update({m.op_id: fused for m in group})
    out, emitted = [], set()
    for o in ops:
        r = replaced.get(o.op_id)
        if r is None:
            out.append(o)
        elif r.op_id not in emitted:
            emitted.add(r.op_id)
            out.append(r)
    return out


# ------------------------------------------------------------------ execute
@dataclasses.dataclass
class LoweredProgram:
    """Optimized ops in jointly-planned schedule order, plus the plan."""
    program: CommProgram
    ops: tuple[CommOp, ...]
    plan: "planner.ProgramPlan"
    out_vids: tuple[int, ...]

    def describe(self) -> str:
        lines = [f"LoweredProgram[{self.program.program_id} "
                 f"ops={len(self.ops)} est={self.plan.seconds * 1e6:.2f}us "
                 f"(serial {self.plan.serial_seconds * 1e6:.2f}us, "
                 f"est_source={self.plan.est_source})]"]
        lines += ["  " + o.describe(self.program) for o in self.ops]
        return "\n".join(lines)

    def _env(self, inputs) -> dict[int, Any]:
        prog = self.program
        if len(inputs) != len(prog._input_vids):
            raise ValueError(
                f"{prog.program_id} takes {len(prog._input_vids)} inputs, "
                f"got {len(inputs)}")
        env = dict(prog._consts)
        env.update(zip(prog._input_vids, inputs))
        return env

    def _run_op(self, op: CommOp, env: dict[int, Any],
                staged: dict[int, Any] | None = None) -> None:
        import jax.numpy as jnp
        meta = (self.program.program_id, op.fused_from)
        with _suspend_recording():
            if op.chain:
                # merged all_to_all chain: dispatch the recorded stages in
                # order, all carrying the merged op's provenance
                val = env[op.in_vids[0]]
                for c_comm, c_kwargs, c_alg in op.chain:
                    val = c_comm._dispatch(
                        "all_to_all", val, algorithm=c_alg, op=op.op,
                        _meta=meta, **c_kwargs)
                env[op.out_vids[0]] = val
            elif op.coalesced:
                vals = [env[v] for v in op.in_vids]
                flat = staged.pop(op.op_id, None) if staged else None
                if flat is None:
                    flat = jnp.concatenate([jnp.ravel(v) for v in vals])
                red = op.comm._dispatch("all_reduce", flat,
                                        algorithm=op.algorithm, op=op.op,
                                        _meta=meta)
                offset = 0
                for v, vid in zip(vals, op.out_vids):
                    n = v.size
                    env[vid] = red[offset:offset + n].reshape(v.shape)
                    offset += n
            else:
                kwargs = dict(op.kwargs)
                env[op.out_vids[0]] = op.comm._dispatch(
                    op.primitive, env[op.in_vids[0]],
                    algorithm=op.algorithm, op=op.op, _meta=meta, **kwargs)

    def execute(self, *inputs):
        """Run the optimized schedule; returns the program outputs as a
        tuple (bare when there is exactly one)."""
        env = self._env(inputs)
        for op in self.ops:
            self._run_op(op, env)
        outs = tuple(env[v] for v in self.out_vids)
        return outs[0] if len(outs) == 1 else outs

    def execute_async(self, *inputs) -> "ProgramExecution":
        """Per-op futures backed by dependency-ordered dispatch: forcing a
        future runs (and memoizes) exactly its dependency cone, in planned
        order."""
        return ProgramExecution(self, self._env(inputs))


class CommFuture:
    """Handle on one scheduled op's result(s).

    ``out_vids`` restricts ``result()`` to a subset of the op's outputs --
    :meth:`ProgramExecution.future_for` uses it so a future resolved
    through coalescing provenance returns just the recorded op's own
    value, not the whole bucket.
    """

    def __init__(self, execution: "ProgramExecution", op: CommOp,
                 out_vids: tuple[int, ...] | None = None):
        self._execution = execution
        self.op = op
        self._out_vids = out_vids

    def done(self) -> bool:
        return self.op.op_id in self._execution._done

    def result(self):
        """Force this op (dispatching its unfinished dependencies first);
        returns the op's output value (tuple for coalesced ops)."""
        env = self._execution.force(self.op)
        outs = tuple(env[v] for v in (self._out_vids or self.op.out_vids))
        return outs[0] if len(outs) == 1 else outs


class ProgramExecution:
    """Dependency-ordered lazy run of a lowered program."""

    def __init__(self, lowered: LoweredProgram, env: dict[int, Any]):
        self.lowered = lowered
        self._env = env
        self._done: set[int] = set()
        self._staged: dict[int, Any] = {}
        self._producer = {v: o for o in lowered.ops for v in o.out_vids}
        self.futures = [CommFuture(self, o) for o in lowered.ops]

    def force(self, op: CommOp) -> dict[int, Any]:
        if op.op_id in self._done:
            return self._env
        for v in op.in_vids:
            dep = self._producer.get(v)
            if dep is not None and dep.op_id not in self._done:
                self.force(dep)
        self.lowered._run_op(op, self._env, self._staged)
        self._done.add(op.op_id)
        return self._env

    def stage(self) -> "ProgramExecution":
        """Pre-build the flattened/concatenated payload of every coalesced
        op whose inputs are already available -- the memory-side half of a
        bucketed dispatch -- without issuing any collective.  The
        double-buffered grad-sync pipeline
        (:mod:`repro.runtime.overlap`) stages bucket k+1 here while bucket
        k's wire op is still in flight; ``force`` then consumes the staged
        payload instead of re-concatenating."""
        import jax.numpy as jnp
        for op in self.lowered.ops:
            if (not op.coalesced or op.op_id in self._done
                    or op.op_id in self._staged
                    or any(v not in self._env for v in op.in_vids)):
                continue
            self._staged[op.op_id] = jnp.concatenate(
                [jnp.ravel(self._env[v]) for v in op.in_vids])
        return self

    def future_for(self, handle) -> CommFuture:
        """Future for a *recorded* op -- by the :class:`ProgramValue` its
        primitive returned at record time, or by recorded op id --
        resolving through rewrite provenance: a recorded op consumed by
        fusion/coalescing maps (via ``fused_from``) to the lowered op that
        carries it.  When the rewrite preserved the recorded op's output
        value (coalescing does), the future returns exactly that value;
        when it did not (the reduce_scatter of a fused rs+ag pair has no
        shard anymore), the future resolves to the rewritten op's result.
        """
        prog = self.lowered.program
        if isinstance(handle, ProgramValue):
            if handle.program is not prog:
                raise ValueError(
                    f"{handle!r} belongs to {handle.program.program_id}, "
                    f"not {prog.program_id}")
            rec = next((o for o in prog._ops if handle.vid in o.out_vids),
                       None)
            if rec is None:
                raise KeyError(
                    f"v{handle.vid} is not produced by any recorded op of "
                    f"{prog.program_id}")
        else:
            rid = int(handle)
            if not 0 <= rid < len(prog._ops):
                raise KeyError(
                    f"{prog.program_id} has no recorded op {rid}")
            rec = prog._ops[rid]
        target = next((o for o in self.lowered.ops
                       if rec.op_id in _origin_ids(o)), None)
        if target is None:
            raise KeyError(
                f"recorded op {rec.op_id} of {prog.program_id} has no "
                "lowered counterpart (rewrite provenance lost)")
        keep = tuple(v for v in rec.out_vids if v in target.out_vids)
        return CommFuture(self, target, out_vids=keep or None)

    def outputs(self):
        """Force every op and return the program outputs."""
        for f in self.futures:
            f.result()
        outs = tuple(self._env[v] for v in self.lowered.out_vids)
        return outs[0] if len(outs) == 1 else outs


__all__ = [
    "CommFuture", "CommOp", "CommProgram", "LoweredProgram",
    "LOWER_STATS", "ProgramExecution", "ProgramValue",
    "DEFAULT_COALESCE_BYTES", "active_program", "clear_lower_cache",
]
