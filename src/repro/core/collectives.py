"""Deprecated per-call collective surface, now a thin shim over
:mod:`repro.core.comm` (the communicator-centric API).

Historically this module *implemented* PID-Comm's eight primitives with the
paper's Table II algorithm stages (naive -> pr -> im -> cm) as per-call
``dims``/``algorithm`` arguments.  The bodies now live in the algorithm
registry of :mod:`repro.core.comm`; :class:`Collectives` survives unchanged
in signature, delegating every call to a cached, topology-bound
:class:`~repro.core.comm.Communicator`, so the conformance matrix runs
bit-identically through either surface.

New code should bind a communicator once instead::

    ar = cube.comm("010")          # resolves dims, caches group metadata
    y = ar.all_reduce(x)           # algorithm="auto": the planner's pick

``APPLICABILITY`` (paper Table II) is derived from the registry; the
``pidcomm`` algorithm alias still means "strongest applicable stage, plus
the hierarchical ICI/DCN split of §IX-A when the group crosses pods".
"""
from __future__ import annotations

import warnings

import jax

from repro.core import comm as _comm
from repro.core.comm import resolve_stage  # re-export (legacy import site)
from repro.core.hypercube import Hypercube

Array = jax.Array


def __getattr__(name):
    # Live views over the registry, so late register_algorithm() calls are
    # visible through the legacy surface too (PEP 562):
    #   APPLICABILITY -- paper Table II, derived from the algorithm registry
    #   _LADDER_MAX   -- the ppermute-ladder threshold; the canonical
    #                    (writable) knob is ``repro.core.comm._LADDER_MAX``
    if name == "APPLICABILITY":
        return _comm.applicability()
    if name == "_LADDER_MAX":
        return _comm._LADDER_MAX
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class Collectives:
    """The eight PID-Comm primitives, bound to a :class:`Hypercube`.

    .. deprecated:: use ``cube.comm(dims)`` -- this shim resolves ``dims``
       per call and delegates to the communicator's registry dispatch.

    PE<->PE primitives (all_to_all / reduce_scatter / all_gather /
    all_reduce) are per-shard functions usable only inside ``shard_map``
    over ``cube.mesh``.  Rooted primitives (scatter / gather / reduce /
    broadcast) operate at the jit boundary with the host as root (§IV-B3).
    """

    def __init__(self, cube: Hypercube):
        warnings.warn(
            "repro.core.collectives.Collectives is deprecated: bind a "
            "communicator with cube.comm(dims) (or topo.comm(axes)), and "
            "record composed patterns with cube.program()",
            DeprecationWarning, stacklevel=2)
        self.cube = cube
        self._comms: dict[tuple[str, ...], _comm.Communicator] = {}

    def _comm(self, dims) -> _comm.Communicator:
        key = self.cube.resolve_dims(dims)
        got = self._comms.get(key)
        if got is None:
            got = self._comms[key] = _comm.Communicator(
                self.cube, key, default_algorithm="pidcomm")
        return got

    # ----------------------------------------------------------- PE <-> PE
    def all_to_all(self, x: Array, dims, *, split_axis: int, concat_axis: int,
                   algorithm: str = "pidcomm") -> Array:
        return self._comm(dims).all_to_all(
            x, split_axis=split_axis, concat_axis=concat_axis,
            algorithm=algorithm)

    def reduce_scatter(self, x: Array, dims, *, axis: int, op: str = "add",
                       algorithm: str = "pidcomm") -> Array:
        return self._comm(dims).reduce_scatter(
            x, axis=axis, op=op, algorithm=algorithm)

    def all_gather(self, x: Array, dims, *, axis: int,
                   algorithm: str = "pidcomm") -> Array:
        return self._comm(dims).all_gather(x, axis=axis, algorithm=algorithm)

    def all_reduce(self, x: Array, dims, *, op: str = "add",
                   algorithm: str = "pidcomm") -> Array:
        return self._comm(dims).all_reduce(x, op=op, algorithm=algorithm)

    # --------------------------------------------------- rooted (host) four
    def scatter(self, host_value, dims, *, axis: int,
                algorithm: str = "pidcomm"):
        """Host -> PEs: partition ``host_value`` along ``axis`` over ``dims``."""
        return self._comm(dims).scatter(host_value, axis=axis,
                                        algorithm=algorithm)

    def broadcast(self, host_value, *, algorithm: str = "pidcomm"):
        """Host -> PEs: replicate to every node."""
        return self._comm(self.cube.dim_names).broadcast(
            host_value, algorithm=algorithm)

    def gather(self, x, *, algorithm: str = "pidcomm"):
        """PEs -> host: materialize the global array in host memory."""
        return self._comm(self.cube.dim_names).gather(x, algorithm=algorithm)

    def reduce(self, x, *, op: str = "add", axis: int = 0,
               algorithm: str = "pidcomm"):
        """PEs -> host: reduction over the sharded axis, result on host."""
        return self._comm(self.cube.dim_names).reduce(
            x, op=op, axis=axis, algorithm=algorithm)


# ------------------------------------------------------------------ topology
# Fig 23(a) comparison topologies over one dim (per-shard, inside shard_map).
# Now registered first-class all_reduce algorithms ("ring" / "tree"); these
# wrappers keep the original free-function signatures alive.
def ring_all_reduce(x: Array, cube: Hypercube, dim: str) -> Array:
    """Bandwidth-optimal ring all-reduce (see registry algorithm ``ring``)."""
    if cube.size(dim) == 1:
        return x
    return cube.comm((dim,)).all_reduce(x, algorithm="ring")


def tree_all_reduce(x: Array, cube: Hypercube, dim: str) -> Array:
    """Recursive-doubling all-reduce (see registry algorithm ``tree``)."""
    if cube.size(dim) == 1:
        return x
    return cube.comm((dim,)).all_reduce(x, algorithm="tree")
