"""PID-Comm's eight collective primitives for TPU meshes (paper §V).

Every primitive is *multi-instance*: invoked inside ``shard_map`` over the
hypercube's logical mesh, a call over a dim subset runs one independent
instance per cube slice (paper §IV-B3), which is exactly the semantics of a
``jax.lax`` collective over a tuple of axis names.

Each primitive carries a family of algorithms that reproduces the paper's
ablation stages (Fig. 16, Table II):

  naive   conventional host-mediated flow: materialize a fully-replicated
          intermediate ("send to host"), modulate it word-by-word with a
          data-dependent gather / sequential reduction ("host loops"), then
          slice the local part ("send back"). Maximal external-bus bytes and
          maximal mediator compute.
  pr      + PE-assisted reordering: local pre/post reordering makes the
          mediator's modulation a static slice / one vectorized (vertical)
          reduction instead of a per-word gather / horizontal loop.
  im      + in-register modulation: the replicated intermediate is never
          materialized -- data streams through the collective
          (psum_scatter/all_gather pairs, ppermute ladders).
  cm      + cross-domain modulation: the remaining layout conversion is fused
          into a single native collective (lax.all_to_all / tiled all_gather);
          for arithmetic primitives CM applies only to 8-bit payloads (paper
          §V-C), exposed via core.compress.
  pidcomm alias for the best applicable stage per Table II, plus the
          hierarchical ICI/DCN split of §IX-A when the group crosses pods.

Applicability (paper Table II) is enforced: requesting an inapplicable stage
falls back to the strongest applicable one at or below the request.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.hypercube import Hypercube

Array = jax.Array

# paper Table II: which optimization stages exist per primitive.
APPLICABILITY = {
    "all_to_all": ("naive", "pr", "im", "cm"),
    "reduce_scatter": ("naive", "pr", "im"),
    "all_reduce": ("naive", "pr", "im"),
    "all_gather": ("naive", "pr", "im", "cm"),
    "scatter": ("naive", "im"),
    "gather": ("naive", "im"),
    "reduce": ("naive", "pr", "im"),
    "broadcast": ("naive",),  # already at peak in the native runtime (Fig 14)
}

_REDUCERS = {
    "add": (lax.psum, jnp.sum, jnp.add),
    "max": (lax.pmax, jnp.max, jnp.maximum),
    "min": (lax.pmin, jnp.min, jnp.minimum),
}

# ppermute ladders get HLO-quadratic beyond this group size; fall through to
# the fused native collective (the schedules coincide there anyway).
_LADDER_MAX = 32


def resolve_stage(primitive: str, algorithm: str) -> str:
    """Resolve an algorithm request against Table II: ``pidcomm`` means the
    strongest applicable stage; an inapplicable request falls back to the
    strongest applicable stage at or below it."""
    stages = APPLICABILITY[primitive]
    if algorithm == "pidcomm":
        return stages[-1]
    order = ("naive", "pr", "im", "cm")
    if algorithm not in order:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    req = order.index(algorithm)
    best = stages[0]
    for s in stages:
        if order.index(s) <= req:
            best = s
    return best


_stage = resolve_stage  # internal alias kept for brevity at call sites


def _split_axis_to_front(x: Array, axis: int, groups: int) -> Array:
    """(..., G*b, ...) -> (G, ..., b, ...)."""
    shape = x.shape
    if shape[axis] % groups:
        raise ValueError(f"axis {axis} of {shape} not divisible by {groups}")
    b = shape[axis] // groups
    new = shape[:axis] + (groups, b) + shape[axis + 1:]
    return jnp.moveaxis(x.reshape(new), axis, 0)


def _merge_front_blocks(x: Array, axis: int) -> Array:
    """Inverse of `_split_axis_to_front`: (G, ..., b, ...) -> (..., G*b, ...)."""
    x = jnp.moveaxis(x, 0, axis)
    shape = x.shape
    return x.reshape(shape[:axis] + (shape[axis] * shape[axis + 1],) + shape[axis + 2:])


class Collectives:
    """The eight PID-Comm primitives, bound to a :class:`Hypercube`.

    PE<->PE primitives (all_to_all / reduce_scatter / all_gather / all_reduce)
    are per-shard functions usable only inside ``shard_map`` over
    ``cube.mesh``. Rooted primitives (scatter / gather / reduce / broadcast)
    operate at the jit boundary with the host as root (paper §IV-B3).
    """

    def __init__(self, cube: Hypercube):
        self.cube = cube

    # ----------------------------------------------------------- all_to_all
    def all_to_all(self, x: Array, dims, *, split_axis: int, concat_axis: int,
                   algorithm: str = "pidcomm") -> Array:
        ax = self.cube.resolve_dims(dims)
        g = self.cube.group_size(ax)
        if g == 1:
            return x
        stage = _stage("all_to_all", algorithm)
        if stage == "im" and (g > _LADDER_MAX or len(ax) > 1):
            stage = "cm"
        if stage == "cm":
            # single fused native collective: the layout change happens inside
            # the transfer (cross-domain modulation).
            return lax.all_to_all(x, ax, split_axis, concat_axis, tiled=True)
        if stage == "im":
            return self._aa_ladder(x, ax, g, split_axis, concat_axis)
        # naive / pr: replicated intermediate over the group ("host buffer").
        blocks = _split_axis_to_front(x, split_axis, g)       # (G, ..., b, ..)
        gathered = compat.all_gather(blocks, ax, axis=0, tiled=False)  # (G, G, ..)
        me = lax.axis_index(ax)
        if stage == "pr":
            # PE-assisted reordering: sources pre-arranged their blocks so the
            # mediator extracts one column with a single dynamic slice.
            mine = lax.dynamic_index_in_dim(
                jnp.swapaxes(gathered, 0, 1), me, axis=0, keepdims=False)
        else:
            # naive: per-word modulation -- data-dependent gather over the
            # flattened buffer (the host rearranging word by word).
            idx = jnp.arange(g) * g + me
            flat = gathered.reshape((g * g,) + gathered.shape[2:])
            mine = jnp.take(flat, idx, axis=0)
        return _merge_front_blocks(mine, concat_axis)

    def _aa_ladder(self, x: Array, ax, g: int, split_axis: int,
                   concat_axis: int) -> Array:
        """(G-1)-step ppermute ladder: one destination block per step, no
        replicated intermediate (in-register modulation analogue)."""
        blocks = _split_axis_to_front(x, split_axis, g)
        me = lax.axis_index(ax)
        received = [lax.dynamic_index_in_dim(blocks, me, axis=0)]  # own block
        for step in range(1, g):
            # i sends its block destined for (i - step); it lands on (i - step)
            perm = [(i, (i - step) % g) for i in range(g)]
            send = lax.dynamic_index_in_dim(blocks, (me - step) % g, axis=0)
            received.append(lax.ppermute(send, ax, perm))
        stacked = jnp.concatenate(received, axis=0)  # slot s <- source (me+s)%g
        idx = (jnp.arange(g) - me) % g               # out[j] = slot (j-me)%g
        mine = jnp.take(stacked, idx, axis=0)
        return _merge_front_blocks(mine, concat_axis)

    # ------------------------------------------------------- reduce_scatter
    def reduce_scatter(self, x: Array, dims, *, axis: int, op: str = "add",
                       algorithm: str = "pidcomm") -> Array:
        ax = self.cube.resolve_dims(dims)
        g = self.cube.group_size(ax)
        if g == 1:
            return x
        stage = _stage("reduce_scatter", algorithm)
        if stage == "im":
            if op == "add":
                return compat.psum_scatter(x, ax, scatter_dimension=axis)
            red = _REDUCERS[op][0](x, ax)
            blocks = _split_axis_to_front(red, axis, g)
            me = lax.axis_index(ax)
            return lax.dynamic_index_in_dim(blocks, me, axis=0, keepdims=False)
        blocks = _split_axis_to_front(x, axis, g)              # (G, ..., b, ..)
        gathered = compat.all_gather(blocks, ax, axis=0, tiled=False)  # (Gsrc, Gblk, ...)
        me = lax.axis_index(ax)
        col = lax.dynamic_index_in_dim(gathered, me, axis=1, keepdims=False)
        if stage == "pr":
            # vertical (vectorized) reduction over the stacked source axis --
            # the paper's one-SIMD-op-per-register argument.
            return _REDUCERS[op][1](col, axis=0)
        # naive: horizontal, source-by-source sequential reduction.
        comb = _REDUCERS[op][2]
        acc = col[0]
        for s in range(1, g):
            acc = comb(acc, col[s])
        return acc

    # ----------------------------------------------------------- all_gather
    def all_gather(self, x: Array, dims, *, axis: int,
                   algorithm: str = "pidcomm") -> Array:
        ax = self.cube.resolve_dims(dims)
        g = self.cube.group_size(ax)
        if g == 1:
            return x
        stage = _stage("all_gather", algorithm)
        if stage in ("im", "cm"):
            # direct tiled gather; with CM the consumer reads the gathered
            # layout in place (no post-reorder op survives fusion).
            return compat.all_gather(x, ax, axis=axis)
        if stage == "pr":
            gathered = compat.all_gather(x, ax, axis=0, tiled=False)
            return _merge_front_blocks(gathered, axis)
        # naive: root collects then broadcasts full copies -- emulated by a
        # masked psum carrying G full-size buffers over the bus.
        me = lax.axis_index(ax)
        stacked = jnp.zeros((g,) + x.shape, x.dtype)
        stacked = lax.dynamic_update_index_in_dim(stacked, x, me, axis=0)
        full = lax.psum(stacked, ax)
        return _merge_front_blocks(full, axis)

    # ----------------------------------------------------------- all_reduce
    def all_reduce(self, x: Array, dims, *, op: str = "add",
                   algorithm: str = "pidcomm") -> Array:
        ax = self.cube.resolve_dims(dims)
        if self.cube.group_size(ax) == 1:
            return x
        stage = _stage("all_reduce", algorithm)
        if stage == "im":
            fast, slow = self.cube.split_fast_slow(ax)
            if fast and slow and op == "add":
                # hierarchical §IX-A: ICI reduce-scatter, DCN all-reduce of
                # the 1/|ICI| shard, ICI all-gather. DCN bytes drop |ICI|x.
                gf = self.cube.group_size(fast)
                flat = x.reshape(-1)
                pad = (-flat.shape[0]) % gf
                if pad:
                    flat = jnp.pad(flat, (0, pad))
                shard = compat.psum_scatter(flat, fast, scatter_dimension=0)
                shard = lax.psum(shard, slow)
                full = compat.all_gather(shard, fast, axis=0)
                if pad:
                    full = full[:-pad]
                return full.reshape(x.shape)
            return _REDUCERS[op][0](x, ax)
        g = self.cube.group_size(ax)
        gathered = compat.all_gather(x, ax, axis=0, tiled=False)
        if stage == "pr":
            return _REDUCERS[op][1](gathered, axis=0)
        comb = _REDUCERS[op][2]
        acc = gathered[0]
        for s in range(1, g):
            acc = comb(acc, gathered[s])
        return acc

    # --------------------------------------------------- rooted (host) four
    # The host is always the root (paper §IV-B3). These run at the jit
    # boundary on global arrays; one buffer per cube slice, like the paper's
    # per-group host buffers. The ``algorithm`` request is resolved against
    # Table II for a uniform API, but the device path is stage-invariant:
    # at the jit boundary the runtime's native host<->device transfer *is*
    # the in-register path, so naive/pr only differ in the emulated host
    # flow the paper ablates, not in bytes placed on devices.
    def scatter(self, host_value, dims, *, axis: int,
                algorithm: str = "pidcomm"):
        """Host -> PEs: partition ``host_value`` along ``axis`` over ``dims``."""
        _stage("scatter", algorithm)
        ax = self.cube.resolve_dims(dims)
        spec = [None] * host_value.ndim
        spec[axis] = ax if len(ax) > 1 else ax[0]
        return jax.device_put(host_value, self.cube.sharding(P(*spec)))

    def broadcast(self, host_value, *, algorithm: str = "pidcomm"):
        """Host -> PEs: replicate to every node."""
        _stage("broadcast", algorithm)
        return jax.device_put(host_value, self.cube.sharding(P()))

    def gather(self, x, *, algorithm: str = "pidcomm"):
        """PEs -> host: materialize the global array in host memory."""
        _stage("gather", algorithm)
        return jax.device_get(x)

    def reduce(self, x, *, op: str = "add", axis: int = 0,
               algorithm: str = "pidcomm"):
        """PEs -> host: reduction over the sharded axis, result on host."""
        _stage("reduce", algorithm)
        reducer = {"add": jnp.sum, "max": jnp.max, "min": jnp.min}[op]
        return jax.device_get(reducer(x, axis=axis))


# ------------------------------------------------------------------ topology
# Fig 23(a) comparison topologies over one dim (per-shard, inside shard_map).
def ring_all_reduce(x: Array, cube: Hypercube, dim: str) -> Array:
    """Bandwidth-optimal ring: (G-1) reduce-scatter steps + (G-1) all-gather
    steps of 1/G-size chunks, realized with ppermute."""
    ax = (dim,)
    g = cube.size(dim)
    if g == 1:
        return x
    me = lax.axis_index(ax)
    orig_len = x.shape[0]
    pad = (-orig_len) % g
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else x
    chunks = jnp.stack(jnp.split(xp, g, axis=0), axis=0)   # (G, n/G, ...)
    fwd = [(i, (i + 1) % g) for i in range(g)]
    # reduce-scatter phase: after g-1 hops, i holds reduced chunk (i+1)%g.
    cur = lax.dynamic_index_in_dim(chunks, me, axis=0, keepdims=False)
    for step in range(g - 1):
        got = lax.ppermute(cur, ax, fwd)
        idx = (me - 1 - step) % g
        cur = got + lax.dynamic_index_in_dim(chunks, idx, axis=0, keepdims=False)
    red_idx = (me + 1) % g
    # all-gather phase: h_s = (me + 1 - s) % g after s hops.
    out = jnp.zeros_like(chunks)
    out = lax.dynamic_update_index_in_dim(out, cur, red_idx, axis=0)
    for s in range(1, g):
        cur = lax.ppermute(cur, ax, fwd)
        out = lax.dynamic_update_index_in_dim(out, cur, (me + 1 - s) % g, axis=0)
    full = out.reshape((-1,) + x.shape[1:])
    return full[:orig_len] if pad else full


def tree_all_reduce(x: Array, cube: Hypercube, dim: str) -> Array:
    """Recursive-doubling (hypercube-exchange) all-reduce: log2(G) steps of
    full-payload XOR-partner exchanges -- latency-optimal, bandwidth-
    suboptimal; stands in for the two-tree comparison of Fig 23(a)."""
    ax = (dim,)
    g = cube.size(dim)
    if g & (g - 1):
        raise ValueError("tree_all_reduce needs a power-of-two group")
    acc = x
    level = 1
    while level < g:
        perm = [(i, i ^ level) for i in range(g)]
        got = lax.ppermute(acc, ax, perm)
        acc = acc + got
        level <<= 1
    return acc
