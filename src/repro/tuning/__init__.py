# Tuning subsystem: microbenchmark the registered collectives on the live
# substrate, fit per-(flow, stage, domain) alpha-beta models, persist them
# as fingerprint-keyed CommProfiles, and let the planner price candidates
# from measured data (`planner.install_profile` / `algorithm="auto"`).
from repro.tuning.profile import (
    SCHEMA_VERSION, CommProfile, LinkModel, MeasuredSample, OverlapModel,
    OverlapSample, ProfileMismatchError, fingerprint_key, fit_models,
    fit_overlap, overlap_key, topology_fingerprint)
from repro.tuning.microbench import (
    DEFAULT_OVERLAP_SIZES, DEFAULT_SIZES, measure_cell,
    measure_overlap_pair, measure_program, overlap_sweep, sweep)
from repro.tuning.tuner import DEFAULT_CACHE_DIR, Tuner

__all__ = [
    "SCHEMA_VERSION", "CommProfile", "LinkModel", "MeasuredSample",
    "OverlapModel", "OverlapSample", "ProfileMismatchError",
    "fingerprint_key", "fit_models", "fit_overlap", "overlap_key",
    "topology_fingerprint",
    "DEFAULT_OVERLAP_SIZES", "DEFAULT_SIZES", "measure_cell",
    "measure_overlap_pair", "measure_program", "overlap_sweep", "sweep",
    "DEFAULT_CACHE_DIR", "Tuner",
]
