# Tuning subsystem: microbenchmark the registered collectives on the live
# substrate, fit per-(flow, stage, domain) alpha-beta models, persist them
# as fingerprint-keyed CommProfiles, and let the planner price candidates
# from measured data (`planner.install_profile` / `algorithm="auto"`).
from repro.tuning.profile import (
    SCHEMA_VERSION, CommProfile, LinkModel, MeasuredSample,
    ProfileMismatchError, fingerprint_key, fit_models, topology_fingerprint)
from repro.tuning.microbench import (
    DEFAULT_SIZES, measure_cell, sweep)
from repro.tuning.tuner import DEFAULT_CACHE_DIR, Tuner

__all__ = [
    "SCHEMA_VERSION", "CommProfile", "LinkModel", "MeasuredSample",
    "ProfileMismatchError", "fingerprint_key", "fit_models",
    "topology_fingerprint",
    "DEFAULT_SIZES", "measure_cell", "sweep",
    "DEFAULT_CACHE_DIR", "Tuner",
]
