"""Collective microbenchmarks on the live substrate (the tuning sweep).

Measures the eight registered PID-Comm primitives through the real
``Communicator`` dispatch path -- each cell is one (primitive, candidate
algorithm, dim selection, payload size) and is timed with the benchmark
harness's median-of-reps wall clock (``benchmarks/_timing.bench``; a local
fallback keeps the module importable when the repo-root ``benchmarks``
package is not on the path).

Every cell runs under a :class:`~repro.core.comm.CommTrace`, so the
recorded :class:`~repro.core.comm.CommEvent` supplies the *structural*
facts of the executed flow (Table II stage, analytic per-device ICI/DCN
bytes) and the measurement supplies the time; the pair becomes one
:class:`~repro.tuning.profile.MeasuredSample` for the alpha-beta fit.

Candidate set per cell mirrors :func:`repro.core.planner.plan`'s race:
``naive`` and ``direct`` everywhere, plus ``hierarchical`` for additive
all-reduces whose group spans both domains (where the dispatcher escalates
``direct`` away, it is skipped rather than mis-measured), plus the
compute-fused ring flows (``ring_fused``/``ag_prologue`` for all_gather,
``rs_epilogue`` for reduce_scatter) so a tuned profile prices fused
against unfused and ``algorithm="auto"`` can flip call sites between
them.

Program-level cells (the overlap sweep) measure *schedules* rather than
single ops: :func:`measure_overlap_pair` times two independent collectives
dispatched back-to-back inside one compiled schedule against each op alone,
yielding an :class:`~repro.tuning.profile.OverlapSample` whose implied
serialization factor (0 = the smaller op hides entirely, 1 = fully serial)
is what ``planner.plan_program`` needs to price an interleaving order from
data instead of the analytic both-links-stream assumption.
:func:`measure_program` is the end-to-end analogue for a whole lowered
``CommProgram`` (used by the benchmark harness to validate joint plans).
"""
from __future__ import annotations

import time
from typing import Sequence

import numpy as np

from repro.tuning.profile import MeasuredSample, OverlapSample

# Sweep defaults: payload sizes (per-device bytes) chosen to straddle the
# latency- and bandwidth-dominated regimes on the CPU substrate without
# making a full sweep slow.
DEFAULT_SIZES = (64 * 1024, 256 * 1024, 1024 * 1024)

PE_PRIMITIVES = ("all_to_all", "reduce_scatter", "all_reduce", "all_gather")
ROOTED_PRIMITIVES = ("scatter", "gather", "reduce", "broadcast")

# executed registry flow -> the planner candidate it prices as (everything
# unlisted ran the native direct flow).
_FLOW_TO_CANDIDATE = {
    "naive": "naive",
    "hierarchical": "hierarchical",
    "compressed": "compressed",
    "ring_fused": "ring_fused",
    "ag_prologue": "ag_prologue",
    "rs_epilogue": "rs_epilogue",
}


def _bench_fallback(fn, *, warmup: int = 2, reps: int = 5) -> float:
    """Median wall-time per call in microseconds (mirror of
    ``benchmarks/_timing.bench`` for installs without the repo root)."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


try:
    from benchmarks._timing import bench as _bench
except ImportError:      # pragma: no cover - repo-root package not on path
    _bench = _bench_fallback


def _candidates(cube, primitive: str, dims) -> list[str]:
    """Dispatch algorithm requests to measure for one cell."""
    sel = cube.resolve_dims(dims)
    fast, slow = cube.split_fast_slow(sel)
    if primitive == "all_reduce" and fast and slow:
        # the dispatcher escalates any direct request to the hierarchical
        # split here, so "direct" is unreachable -- measure what runs.
        return ["naive", "hierarchical"]
    if primitive == "broadcast":
        return ["naive"]             # single registered flow
    out = ["naive", "pidcomm"]
    # compute-fused ring flows (repro.kernels.collective): sweeping them
    # without a consumer/tile_fn times the pure ring movement, which is the
    # comm term a measured profile prices against the unfused stages
    if primitive == "all_gather":
        out += ["ring_fused", "ag_prologue"]
    elif primitive == "reduce_scatter":
        out += ["rs_epilogue"]
    return out


def _smap_call(cube, f, in_specs, out_specs, *args):
    import jax
    from repro.compat import shard_map
    fn = jax.jit(shard_map(f, mesh=cube.mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False))
    return lambda: jax.block_until_ready(fn(*args))


def _pe_cell(cube, comm, primitive: str, n: int, algorithm: str):
    """Build the timed callable for one PE<->PE cell.  The payload is a
    fully-sharded ``(*dim_sizes, n)`` fp32 array, so each PE sees an
    ``(1, ..., 1, n)`` shard -- per-device payload ``4 * n`` bytes."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    spec = P(*cube.dim_names, None)
    x = jnp.ones(tuple(cube.dim_sizes) + (n,), jnp.float32)
    axis = len(cube.dim_sizes)      # the payload axis, per shard
    if primitive == "all_reduce":
        f = lambda v: comm.all_reduce(v, algorithm=algorithm)
    elif primitive == "reduce_scatter":
        f = lambda v: comm.reduce_scatter(v, axis=axis, algorithm=algorithm)
    elif primitive == "all_gather":
        f = lambda v: comm.all_gather(v, axis=axis, algorithm=algorithm)
    elif primitive == "all_to_all":
        f = lambda v: comm.all_to_all(v, split_axis=axis, concat_axis=axis,
                                      algorithm=algorithm)
    else:
        raise ValueError(primitive)
    return _smap_call(cube, f, (spec,), spec, x)


def _rooted_cell(cube, comm, primitive: str, n: int, algorithm: str):
    """Timed callable for one host-rooted cell (jit-boundary transfer)."""
    import jax
    g = comm.group_size
    host = np.ones((g, n), np.float32)
    if primitive == "scatter":
        return lambda: jax.block_until_ready(
            comm.scatter(host, axis=0, algorithm=algorithm))
    if primitive == "broadcast":
        return lambda: jax.block_until_ready(
            comm.broadcast(host, algorithm=algorithm))
    dev = comm.scatter(host, axis=0)
    if primitive == "gather":
        return lambda: comm.gather(dev, algorithm=algorithm)
    if primitive == "reduce":
        return lambda: comm.reduce(dev, axis=0, algorithm=algorithm)
    raise ValueError(primitive)


def measure_cell(cube, primitive: str, dims, nbytes: int,
                 algorithms: Sequence[str] | None = None, *,
                 reps: int = 5, warmup: int = 2) -> list[MeasuredSample]:
    """Measure one (primitive, dim selection, size) cell across candidate
    dispatch algorithms; returns one sample per executed flow."""
    from repro.core.comm import CommTrace
    sel = cube.resolve_dims(dims)
    comm = cube.comm(sel)
    g = comm.group_size
    # per-device fp32 elements; keep divisibility for rs/aa splits
    n = max(int(nbytes) // 4, g)
    n -= n % g
    if algorithms is None:
        algorithms = _candidates(cube, primitive, sel)
    samples: list[MeasuredSample] = []
    for alg in algorithms:
        if primitive in PE_PRIMITIVES:
            call = _pe_cell(cube, comm, primitive, n, alg)
        else:
            call = _rooted_cell(cube, comm, primitive, n, alg)
        with CommTrace() as tr:
            us = _bench(call, warmup=warmup, reps=reps)
        ev = next((e for e in tr.events if e.primitive == primitive), None)
        if ev is None:       # group of 1: nothing dispatched
            continue
        samples.append(MeasuredSample(
            primitive=primitive,
            algorithm=_FLOW_TO_CANDIDATE.get(ev.flow, "direct"),
            stage=ev.stage, bitmap=ev.bitmap, nbytes=4 * n,
            ici_bytes=ev.ici_bytes, dcn_bytes=ev.dcn_bytes,
            seconds=us * 1e-6))
    return samples


# ------------------------------------------------- program-level overlap
# Overlap cells default to one mid-range payload: the serialization factor
# is a ratio of same-size runs, so it is far less size-sensitive than the
# alpha-beta terms (two sizes still give the median fit a noise anchor).
DEFAULT_OVERLAP_SIZES = (256 * 1024, 1024 * 1024)


def _domain_comms(cube) -> dict:
    """One communicator per link domain of the cube: ``"ici"`` over the
    fast dims, ``"dcn"`` over the pod-crossing dims (when present).  An
    all_reduce over each is the domain's representative flow -- its
    analytic ``dominant()`` matches the key by construction."""
    fast = tuple(d for d in cube.dim_names if d not in cube.dcn_dims)
    out = {}
    if fast:
        out["ici"] = cube.comm(fast)
    if cube.dcn_dims:
        out["dcn"] = cube.comm(tuple(cube.dcn_dims))
    return out


def _overlap_payload_elems(nbytes: int) -> int:
    """Per-device fp32 elements of one overlap-cell payload (all_reduce
    needs no divisibility, so the size is shared by every pair at this
    nbytes -- which is what lets solo timings be hoisted per domain)."""
    return max(int(nbytes) // 4, 1)


def _solo_seconds(cube, comm, n: int, *, reps: int, warmup: int) -> float:
    """Measured seconds of one domain-representative all_reduce alone."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    spec = P(*cube.dim_names, None)
    x = jnp.ones(tuple(cube.dim_sizes) + (n,), jnp.float32)
    call = _smap_call(cube, lambda v: comm.all_reduce(v), (spec,), spec, x)
    return _bench(call, warmup=warmup, reps=reps) * 1e-6


def _pair_seconds(cube, comm_a, comm_b, n: int, *,
                  reps: int, warmup: int) -> float:
    """Measured seconds of A-then-B in one compiled schedule: A dispatches
    textually before B inside one jitted shard_map, so the module sees
    exactly the ordered two-op program the planner would emit."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    spec = P(*cube.dim_names, None)
    x = jnp.ones(tuple(cube.dim_sizes) + (n,), jnp.float32)
    y = jnp.ones(tuple(cube.dim_sizes) + (n,), jnp.float32) * 2.0

    def pair(u, v):
        ra = comm_a.all_reduce(u)
        rb = comm_b.all_reduce(v)
        return ra, rb

    call = _smap_call(cube, pair, (spec, spec), (spec, spec), x, y)
    return _bench(call, warmup=warmup, reps=reps) * 1e-6


def measure_overlap_pair(cube, dom_a: str, dom_b: str, nbytes: int, *,
                         reps: int = 5, warmup: int = 2,
                         solo: dict | None = None) -> OverlapSample | None:
    """Measure one ordered domain pair; None when the cube lacks a domain
    (a single-pod cube has no DCN leg to overlap).  ``solo`` optionally
    supplies pre-measured {domain: seconds} at this payload size so a
    sweep benches each domain's solo op once, not once per pair."""
    comms = _domain_comms(cube)
    if dom_a not in comms or dom_b not in comms:
        return None
    comm_a, comm_b = comms[dom_a], comms[dom_b]
    n = _overlap_payload_elems(nbytes)
    solo = solo or {}
    sec_a = solo.get(dom_a)
    if sec_a is None:
        sec_a = _solo_seconds(cube, comm_a, n, reps=reps, warmup=warmup)
    sec_b = solo.get(dom_b)
    if sec_b is None:
        sec_b = _solo_seconds(cube, comm_b, n, reps=reps, warmup=warmup)
    sec_pair = _pair_seconds(cube, comm_a, comm_b, n,
                             reps=reps, warmup=warmup)
    return OverlapSample(
        dom_a=dom_a, dom_b=dom_b,
        primitive_a="all_reduce", primitive_b="all_reduce",
        bitmap_a=comm_a.bitmap, bitmap_b=comm_b.bitmap,
        nbytes=4 * n, seconds_a=sec_a, seconds_b=sec_b,
        seconds_pair=sec_pair)


def overlap_sweep(cube, *, sizes: Sequence[int] = DEFAULT_OVERLAP_SIZES,
                  reps: int = 5, warmup: int = 2,
                  progress=None) -> list[OverlapSample]:
    """Every ordered domain pair the cube can express, at each size.  On a
    single-domain cube that is just ("ici", "ici"); a pod-crossing cube
    adds the cross-domain pairs whose factors decide the interleaving.
    Solo ops are benchmarked once per (domain, size) and shared across the
    ordered pairs."""
    comms = _domain_comms(cube)
    domains = tuple(comms)
    samples: list[OverlapSample] = []
    for nbytes in sizes:
        n = _overlap_payload_elems(nbytes)
        solo = {d: _solo_seconds(cube, comms[d], n, reps=reps,
                                 warmup=warmup) for d in domains}
        for dom_a in domains:
            for dom_b in domains:
                s = measure_overlap_pair(cube, dom_a, dom_b, nbytes,
                                         reps=reps, warmup=warmup,
                                         solo=solo)
                if s is None:
                    continue
                samples.append(s)
                if progress is not None:
                    progress(dom_a, dom_b, nbytes, s)
    return samples


def measure_program(cube, lowered, global_inputs, in_specs, out_specs, *,
                    reps: int = 5, warmup: int = 2) -> float:
    """End-to-end seconds of one lowered ``CommProgram`` schedule executed
    through a jitted shard_map over the cube's mesh -- the measurement the
    joint plan's ``seconds`` is validated against."""
    call = _smap_call(cube, lambda *vs: lowered.execute(*vs),
                      in_specs, out_specs, *global_inputs)
    return _bench(call, warmup=warmup, reps=reps) * 1e-6


def sweep(cube, *, sizes: Sequence[int] = DEFAULT_SIZES,
          primitives: Sequence[str] | None = None,
          reps: int = 5, warmup: int = 2,
          progress=None) -> list[MeasuredSample]:
    """The full tuning sweep: every primitive x candidate x size, over the
    innermost dim and (when the cube has more than one dim) the whole cube
    -- two group shapes give the fit both a small-group and a large-group
    anchor, and on a pod-spanning cube the second selection exercises the
    DCN-domain models."""
    prims = tuple(primitives) if primitives is not None \
        else PE_PRIMITIVES + ROOTED_PRIMITIVES
    selections = [(cube.dim_names[-1],)]
    if len(cube.dim_names) > 1:
        selections.append(tuple(cube.dim_names))
    samples: list[MeasuredSample] = []
    for primitive in prims:
        for sel in selections:
            for nbytes in sizes:
                cell = measure_cell(cube, primitive, sel, nbytes,
                                    reps=reps, warmup=warmup)
                samples.extend(cell)
                if progress is not None:
                    progress(primitive, sel, nbytes, cell)
    return samples


__all__ = ["DEFAULT_OVERLAP_SIZES", "DEFAULT_SIZES", "PE_PRIMITIVES",
           "ROOTED_PRIMITIVES", "measure_cell", "measure_overlap_pair",
           "measure_program", "overlap_sweep", "sweep"]
