"""Measured communication cost profiles (the tuning subsystem's data model).

PID-Comm's planner prices candidate flows; until now it priced them with
hardcoded TPU-v5e analytic constants, which ROADMAP flags as "a calibration
curve, not a validation".  This module closes the measure->fit->plan loop:

  samples
      raw microbenchmark observations (one per (primitive, flow, size)
      cell), produced by :mod:`repro.tuning.microbench` on the live
      substrate.

  alpha-beta models
      per-(flow, stage, ICI/DCN-domain) latency/bandwidth fits:
      ``seconds ~= alpha + beta * bytes`` per domain, least-squares over the
      samples of that (flow, stage) -- the classical alpha-beta collective
      cost model, but with *measured* coefficients.  The structural byte
      counts stay analytic (they are properties of the flow, not of the
      hardware); only the time-per-byte and fixed-latency terms are fitted.

  overlap factors
      program-level measurements (schema v2): ordered domain-pair
      serialization factors fitted from :func:`repro.tuning.microbench.
      overlap_sweep` observations.  ``factor("ici", "dcn")`` answers "when
      an ICI-dominant op is dispatched immediately before a DCN-dominant
      one, what fraction of the smaller op's time is *not* hidden?" --
      0.0 is perfect overlap, 1.0 fully serial.  ``planner.plan_program``
      prices its interleaving order and shared budget from these factors
      when the profile covers them, closing the last analytic island in
      the measure->fit->plan loop (per-op ``seconds`` were measured
      already; the interleaving model was not).

  CommProfile
      a versioned, JSON-persistable bundle of fingerprint + samples +
      models (+ overlap).  The topology fingerprint (device count,
      hypercube shape, pod split, jax version) keys the profile: loading
      against a different topology is rejected with a retune recipe, and
      profiles for the same fingerprint merge (union of samples, refit) so
      partial sweeps accumulate.

A profile is consumed by :func:`repro.core.planner.install_profile` /
the ``profile=`` kwargs of ``plan()``/``estimate()``/``plan_program()``:
when a model covers a candidate's (flow, stage, domains), the candidate is
priced from the fit and the resulting estimate (and every CommEvent built
from it) carries ``est_source="measured"``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Mapping, Sequence

import numpy as np

# Bump whenever the JSON layout changes incompatibly; load() rejects newer
# versions with a retune recipe rather than mis-reading them.  Older
# versions with a defined migration load in place: v1 (pre-overlap) files
# are valid v2 profiles with an empty overlap section.
SCHEMA_VERSION = 2
_MIGRATABLE_VERSIONS = (1, 2)

# A fit is trusted ("confident") when it has at least this many samples and
# explains at least this fraction of the variance; below either bound the
# Tuner falls back to exhaustive measurement.
MIN_SAMPLES = 3
MIN_R2 = 0.5

RETUNE_RECIPE = ("regenerate it with "
                 "`repro.tuning.Tuner(cache_dir).tune(cube)` or "
                 "`python -m benchmarks.run --profile`")


def topology_fingerprint(cube) -> dict:
    """The identity a profile is valid for: measurements transfer across
    runs only when the substrate (device count, hypercube shape, pod split)
    and the jax runtime are the same."""
    import jax
    fast = [d for d in cube.dim_names if d not in cube.dcn_dims]
    pod_split = int(np.prod([cube.size(d) for d in fast])) if fast else 1
    return {
        "ndev": int(cube.ndev),
        "dims": {n: int(s) for n, s in zip(cube.dim_names, cube.dim_sizes)},
        "dcn_dims": list(cube.dcn_dims),
        "pod_split": pod_split,
        "jax": jax.__version__,
    }


def fingerprint_key(fingerprint: Mapping) -> str:
    """Stable short hash of a fingerprint -- used for cache file names."""
    blob = json.dumps(fingerprint, sort_keys=True).encode()
    return hashlib.sha1(blob).hexdigest()[:12]


@dataclasses.dataclass(frozen=True)
class MeasuredSample:
    """One microbenchmark observation."""
    primitive: str
    algorithm: str          # planner candidate name (naive/direct/...)
    stage: str              # Table II stage of the executed flow
    bitmap: str             # dim selection measured
    nbytes: int             # per-device payload
    ici_bytes: float        # analytic per-device bytes of the flow
    dcn_bytes: float
    seconds: float          # measured median wall time

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Mapping) -> "MeasuredSample":
        return MeasuredSample(**d)


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """One fitted alpha-beta term: ``seconds = alpha + beta * bytes`` over
    one domain (ici or dcn) of one (flow, stage)."""
    alpha: float            # seconds (fixed latency)
    beta: float             # seconds per byte (inverse bandwidth)
    n: int                  # samples behind the fit
    r2: float               # goodness of the joint (flow, stage) fit

    def seconds(self, nbytes: float) -> float:
        return self.alpha + self.beta * nbytes

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Mapping) -> "LinkModel":
        return LinkModel(**d)


@dataclasses.dataclass(frozen=True)
class OverlapSample:
    """One program-level overlap observation: op A dispatched immediately
    before op B inside one compiled schedule, against each op timed alone.

    ``dom_a``/``dom_b`` are the analytic dominant domains ("ici"/"dcn") of
    the two flows -- the key the fitted factor generalizes over; the
    primitive/bitmap fields are provenance for debugging a bad fit."""
    dom_a: str
    dom_b: str
    primitive_a: str
    primitive_b: str
    bitmap_a: str
    bitmap_b: str
    nbytes: int             # per-device payload of each op
    seconds_a: float        # measured, op A alone
    seconds_b: float        # measured, op B alone
    seconds_pair: float     # measured, A-then-B in one schedule

    def factor(self) -> float:
        """Serialization fraction in [0, 1] implied by this observation:
        ``pair ~= max(a, b) + factor * min(a, b)`` -- 0 is perfect overlap
        (the smaller op hides entirely), 1 is fully serial."""
        lo = min(self.seconds_a, self.seconds_b)
        hi = max(self.seconds_a, self.seconds_b)
        if lo <= 0.0:
            return 1.0
        return float(np.clip((self.seconds_pair - hi) / lo, 0.0, 1.0))

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Mapping) -> "OverlapSample":
        return OverlapSample(**d)


@dataclasses.dataclass(frozen=True)
class OverlapModel:
    """Fitted serialization factor for one *ordered* domain pair
    (``"{dom_a}->{dom_b}"``): the median of the observations' implied
    factors (median, not mean -- single-run wall times on a shared host
    have heavy-tailed noise)."""
    factor: float           # [0, 1]: 0 = perfect overlap, 1 = serial
    n: int                  # observations behind the fit

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Mapping) -> "OverlapModel":
        return OverlapModel(**d)


def overlap_key(dom_a: str, dom_b: str) -> str:
    return f"{dom_a}->{dom_b}"


def fit_overlap(samples: Sequence[OverlapSample]
                ) -> dict[str, OverlapModel]:
    """Fit one :class:`OverlapModel` per ordered domain pair present."""
    groups: dict[str, list[float]] = {}
    for s in samples:
        groups.setdefault(overlap_key(s.dom_a, s.dom_b),
                          []).append(s.factor())
    return {k: OverlapModel(factor=float(np.median(fs)), n=len(fs))
            for k, fs in sorted(groups.items())}


def _r2(y: np.ndarray, pred: np.ndarray) -> float:
    """Fit quality in [0, 1]: classic r^2, floored by relative predictive
    accuracy (1 - relative RMS error).  The floor matters for
    latency-dominated cells, where y is nearly constant: a constant-alpha
    model that predicts within noise deserves trust even though it
    explains no variance."""
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - np.mean(y)) ** 2))
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0.0 else \
        (1.0 if ss_res <= 1e-18 else 0.0)
    mean = float(np.mean(y))
    rrmse = float(np.sqrt(ss_res / len(y))) / mean if mean > 0.0 else 1.0
    return float(np.clip(max(r2, 1.0 - rrmse), 0.0, 1.0))


def _fit_group(rows: Sequence[MeasuredSample]) -> dict[str, LinkModel]:
    """Least-squares alpha-beta fit of one (flow, stage) sample group.

    Design matrix columns: intercept, ici_bytes and (when the flow moves any
    DCN traffic) dcn_bytes.  Negative coefficients -- possible on noisy or
    degenerate sweeps -- are clamped by dropping the column and refitting,
    so priced times stay monotone in payload size.
    """
    y = np.array([s.seconds for s in rows], dtype=np.float64)
    ici = np.array([s.ici_bytes for s in rows], dtype=np.float64)
    dcn = np.array([s.dcn_bytes for s in rows], dtype=np.float64)
    cols: list[tuple[str, np.ndarray]] = [("alpha", np.ones_like(y))]
    if float(ici.max(initial=0.0)) > 0.0:
        cols.append(("ici", ici))
    if float(dcn.max(initial=0.0)) > 0.0:
        cols.append(("dcn", dcn))

    while True:
        A = np.stack([c for _, c in cols], axis=1)
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        bad = [i for i, c in enumerate(coef) if c < 0.0]
        if not bad or len(cols) == 1:
            break
        # drop the most negative column (never the intercept) and refit
        drop = max((i for i in bad if cols[i][0] != "alpha"),
                   key=lambda i: -coef[i], default=None)
        if drop is None:
            coef = np.clip(coef, 0.0, None)
            break
        del cols[drop]

    by_name = {name: float(c) for (name, _), c in zip(cols, coef)}
    alpha = max(by_name.get("alpha", 0.0), 0.0)
    beta_ici = max(by_name.get("ici", 0.0), 0.0)
    beta_dcn = max(by_name.get("dcn", 0.0), 0.0)
    pred = alpha + beta_ici * ici + beta_dcn * dcn
    r2 = _r2(y, pred)
    out = {"ici": LinkModel(alpha=alpha, beta=beta_ici, n=len(rows), r2=r2)}
    if float(dcn.max(initial=0.0)) > 0.0:
        out["dcn"] = LinkModel(alpha=0.0, beta=beta_dcn, n=len(rows), r2=r2)
    return out


def fit_models(samples: Sequence[MeasuredSample]
               ) -> dict[str, LinkModel]:
    """Fit every (flow, stage, domain) model present in ``samples``.

    Keys are ``"{algorithm}/{stage}/{domain}"`` -- the same key
    :meth:`CommProfile.seconds_for` resolves at pricing time."""
    groups: dict[tuple[str, str], list[MeasuredSample]] = {}
    for s in samples:
        groups.setdefault((s.algorithm, s.stage), []).append(s)
    models: dict[str, LinkModel] = {}
    for (alg, stage), rows in sorted(groups.items()):
        for domain, model in _fit_group(rows).items():
            models[f"{alg}/{stage}/{domain}"] = model
    return models


class ProfileMismatchError(ValueError):
    """A profile was loaded against the wrong schema or topology."""


class CommProfile:
    """Versioned bundle of measured samples + fitted alpha-beta models,
    keyed by a topology fingerprint.  See module docstring."""

    def __init__(self, fingerprint: Mapping,
                 samples: Sequence[MeasuredSample] = (),
                 models: Mapping[str, LinkModel] | None = None,
                 overlap_samples: Sequence[OverlapSample] = (),
                 overlap: Mapping[str, OverlapModel] | None = None):
        self.fingerprint = dict(fingerprint)
        self.samples = list(samples)
        self.models: dict[str, LinkModel] = (
            dict(models) if models is not None else fit_models(self.samples))
        self.overlap_samples = list(overlap_samples)
        self.overlap: dict[str, OverlapModel] = (
            dict(overlap) if overlap is not None
            else fit_overlap(self.overlap_samples))

    # ------------------------------------------------------------- pricing
    def seconds_for(self, algorithm: str, stage: str,
                    ici_bytes: float, dcn_bytes: float) -> float | None:
        """Measured-model price of one candidate, or None when the profile
        does not cover every domain the flow touches (the planner then
        falls back to the analytic constants for that candidate)."""
        mi = self.models.get(f"{algorithm}/{stage}/ici")
        if mi is None:
            return None
        t = mi.seconds(ici_bytes)
        if dcn_bytes > 0.0:
            md = self.models.get(f"{algorithm}/{stage}/dcn")
            if md is None:
                return None
            t += md.seconds(dcn_bytes)
        return t

    def overlap_factor(self, dom_a: str, dom_b: str) -> float | None:
        """Measured serialization factor for dispatching a ``dom_a``-
        dominant op immediately before a ``dom_b``-dominant one, or None
        when this ordered pair was never measured (the planner then falls
        back to its analytic overlap assumption for the pair)."""
        m = self.overlap.get(overlap_key(dom_a, dom_b))
        return m.factor if m is not None else None

    @property
    def has_overlap(self) -> bool:
        return bool(self.overlap)

    def token(self) -> str:
        """Content hash of the fitted models + overlap factors -- a cheap
        identity for caches (e.g. the program lower cache) that must not
        reuse a plan priced under a different profile."""
        blob = json.dumps(
            {"fp": self.fingerprint,
             "models": {k: m.to_json() for k, m in sorted(self.models.items())},
             "overlap": {k: m.to_json()
                         for k, m in sorted(self.overlap.items())}},
            sort_keys=True).encode()
        return hashlib.sha1(blob).hexdigest()[:12]

    def confidence(self, algorithm: str, stage: str,
                   *, needs_dcn: bool = False) -> float:
        """[0, 1] trust in this candidate's fit: 0 when uncovered or
        under-sampled, else the fit's r^2."""
        needed = [f"{algorithm}/{stage}/ici"]
        if needs_dcn:
            needed.append(f"{algorithm}/{stage}/dcn")
        conf = 1.0
        for key in needed:
            m = self.models.get(key)
            if m is None or m.n < MIN_SAMPLES:
                return 0.0
            conf = min(conf, m.r2)
        return conf

    def is_confident(self, algorithm: str, stage: str,
                     *, needs_dcn: bool = False) -> bool:
        return self.confidence(algorithm, stage,
                               needs_dcn=needs_dcn) >= MIN_R2

    # ------------------------------------------------------------ identity
    def check_fingerprint(self, cube) -> None:
        """Raise unless this profile was measured on ``cube``'s topology."""
        want = topology_fingerprint(cube)
        if self.fingerprint != want:
            diff = sorted(k for k in set(want) | set(self.fingerprint)
                          if want.get(k) != self.fingerprint.get(k))
            raise ProfileMismatchError(
                f"profile fingerprint mismatch on {diff}: profile was "
                f"measured on {self.fingerprint} (jax "
                f"{self.fingerprint.get('jax')}), this substrate is {want} "
                f"(jax {want.get('jax')}); {RETUNE_RECIPE}")

    def merge(self, other: "CommProfile") -> "CommProfile":
        """Union of two partial sweeps over the *same* topology: samples
        (per-op and overlap) concatenate with exact duplicates dropped,
        models and overlap factors refit over the union."""
        if other.fingerprint != self.fingerprint:
            raise ProfileMismatchError(
                "cannot merge profiles of different topologies: "
                f"{self.fingerprint} vs {other.fingerprint}; {RETUNE_RECIPE}")

        def union(a, b):
            seen, out = set(), []
            for s in list(a) + list(b):
                key = json.dumps(s.to_json(), sort_keys=True)
                if key not in seen:
                    seen.add(key)
                    out.append(s)
            return out

        return CommProfile(
            self.fingerprint, union(self.samples, other.samples),
            overlap_samples=union(self.overlap_samples,
                                  other.overlap_samples))

    # --------------------------------------------------------- persistence
    def to_json(self) -> dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "fingerprint": self.fingerprint,
            "samples": [s.to_json() for s in self.samples],
            "models": {k: m.to_json()
                       for k, m in sorted(self.models.items())},
            "overlap_samples": [s.to_json() for s in self.overlap_samples],
            "overlap": {k: m.to_json()
                        for k, m in sorted(self.overlap.items())},
        }

    @staticmethod
    def from_json(data: Mapping) -> "CommProfile":
        version = data.get("schema_version")
        if version not in _MIGRATABLE_VERSIONS:
            raise ProfileMismatchError(
                f"profile schema v{version} is not readable by this build "
                f"(expects v{SCHEMA_VERSION} or a migratable "
                f"{_MIGRATABLE_VERSIONS}); {RETUNE_RECIPE}")
        # v1 -> v2 migration: pre-overlap profiles are valid v2 profiles
        # with an empty overlap section (the per-op fits carry over as-is;
        # plan_program simply keeps its analytic overlap assumption until
        # an overlap sweep lands).
        return CommProfile(
            fingerprint=data["fingerprint"],
            samples=[MeasuredSample.from_json(s) for s in data["samples"]],
            models={k: LinkModel.from_json(m)
                    for k, m in data["models"].items()},
            overlap_samples=[OverlapSample.from_json(s)
                             for s in data.get("overlap_samples", ())],
            overlap={k: OverlapModel.from_json(m)
                     for k, m in data.get("overlap", {}).items()}
            if "overlap" in data else None)

    def save(self, path: str | os.PathLike) -> str:
        """Write deterministic JSON (sorted keys, fixed layout): saving the
        same profile twice is byte-identical, so round-trips diff clean."""
        path = os.fspath(path)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        return path

    @staticmethod
    def load(path: str | os.PathLike, *, cube=None) -> "CommProfile":
        """Load and (when ``cube`` is given) fingerprint-check a profile."""
        with open(path) as f:
            prof = CommProfile.from_json(json.load(f))
        if cube is not None:
            prof.check_fingerprint(cube)
        return prof

    def describe(self) -> str:
        dims = ",".join(f"{k}={v}"
                        for k, v in self.fingerprint["dims"].items())
        return (f"CommProfile[{dims} jax={self.fingerprint['jax']} "
                f"samples={len(self.samples)} models={len(self.models)} "
                f"overlap={len(self.overlap)}]")


__all__ = [
    "SCHEMA_VERSION", "MIN_SAMPLES", "MIN_R2",
    "CommProfile", "LinkModel", "MeasuredSample", "OverlapModel",
    "OverlapSample", "ProfileMismatchError",
    "fingerprint_key", "fit_models", "fit_overlap", "overlap_key",
    "topology_fingerprint",
]
