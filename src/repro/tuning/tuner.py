"""Tuner front-end: tune -> persist -> select, with a measurement fallback.

This is the user-facing surface of the tuning subsystem:

  ``Tuner(cache_dir).tune(cube, sizes=...)``
      runs the :mod:`repro.tuning.microbench` sweep on the live substrate,
      fits the per-(flow, stage, domain) alpha-beta models, runs the
      program-level overlap sweep (``overlap=True``, the default) so
      ``plan_program``'s interleaving budget is priced from measured
      domain-pair serialization factors, merges into any existing profile
      for the same topology fingerprint (partial sweeps accumulate) and
      persists the result in the cache dir.

  ``tuner.select(primitive, nbytes, comm)``
      the measured analogue of :func:`repro.core.planner.plan`: prices the
      candidate flows from the profile and returns the dispatch algorithm
      to request.  When any candidate's fit is low-confidence (uncovered,
      under-sampled, or poor r^2) it falls back to *exhaustively measuring*
      the candidates at the requested size, folds those samples back into
      the cached profile, and picks the measured winner.

  ``install()``
      convenience wrapper around
      :func:`repro.core.planner.install_profile` for the cube's cached
      profile, so ``algorithm="auto"`` dispatch anywhere under the context
      prices from measurements::

          tuner = Tuner(cache_dir=".tuning-cache")
          profile = tuner.tune(cube)
          with planner.install_profile(profile):
              comm.all_reduce(x)          # auto now dispatches on data

Cache layout: one JSON per topology fingerprint,
``{cache_dir}/commprofile-{fingerprint_hash}.json``.
"""
from __future__ import annotations

import os
from typing import Sequence

from repro.tuning import microbench
from repro.tuning.profile import (
    CommProfile, MIN_R2, fingerprint_key, topology_fingerprint)

DEFAULT_CACHE_DIR = os.path.join("~", ".cache", "repro", "tuning")

# planner candidate name -> the Communicator dispatch request executing it
_CANDIDATE_TO_DISPATCH = {
    "naive": "naive",
    "direct": "pidcomm",
    "hierarchical": "hierarchical",
    "compressed": "compressed",
}


class Tuner:
    """Measured-profile manager bound to one persistent cache directory."""

    def __init__(self, cache_dir: str | os.PathLike | None = None):
        cache = cache_dir or os.environ.get("REPRO_TUNING_CACHE") \
            or DEFAULT_CACHE_DIR
        self.cache_dir = os.path.expanduser(os.fspath(cache))
        self._profiles: dict[str, CommProfile] = {}   # by fingerprint hash

    # ----------------------------------------------------------- identity
    def profile_path(self, cube) -> str:
        key = fingerprint_key(topology_fingerprint(cube))
        return os.path.join(self.cache_dir, f"commprofile-{key}.json")

    # --------------------------------------------------------------- tune
    def tune(self, cube, *,
             sizes: Sequence[int] = microbench.DEFAULT_SIZES,
             primitives: Sequence[str] | None = None,
             reps: int = 5, warmup: int = 2,
             overlap: bool = True,
             overlap_sizes: Sequence[int] = microbench.DEFAULT_OVERLAP_SIZES,
             save: bool = True, progress=None) -> CommProfile:
        """Sweep, fit, merge with any cached profile of this topology, and
        persist.  Returns the merged profile (also memoized for
        :meth:`select`).  ``overlap=False`` skips the program-level
        domain-pair sweep (a per-op-only partial tune)."""
        samples = microbench.sweep(cube, sizes=sizes, primitives=primitives,
                                   reps=reps, warmup=warmup,
                                   progress=progress)
        overlap_samples = microbench.overlap_sweep(
            cube, sizes=overlap_sizes, reps=reps, warmup=warmup) \
            if overlap else []
        prof = CommProfile(topology_fingerprint(cube), samples,
                           overlap_samples=overlap_samples)
        existing = self._load_if_cached(cube)
        if existing is not None:
            prof = existing.merge(prof)
        if save:
            prof.save(self.profile_path(cube))
        self._profiles[fingerprint_key(prof.fingerprint)] = prof
        return prof

    def load(self, cube) -> CommProfile:
        """Load the cached profile for ``cube``'s fingerprint (raising
        ``FileNotFoundError`` when never tuned, ``ProfileMismatchError`` on
        schema/topology drift)."""
        prof = CommProfile.load(self.profile_path(cube), cube=cube)
        self._profiles[fingerprint_key(prof.fingerprint)] = prof
        return prof

    def _load_if_cached(self, cube) -> CommProfile | None:
        key = fingerprint_key(topology_fingerprint(cube))
        if key in self._profiles:
            return self._profiles[key]
        try:
            return self.load(cube)
        except FileNotFoundError:
            return None

    def profile_for(self, cube, *, tune_if_missing: bool = False,
                    **tune_kwargs) -> CommProfile:
        """The cube's profile: memoized, else loaded from cache, else
        (opt-in) measured on the spot."""
        prof = self._load_if_cached(cube)
        if prof is None:
            if not tune_if_missing:
                raise FileNotFoundError(
                    f"no tuned profile for {cube.describe()} in "
                    f"{self.cache_dir}; run Tuner.tune(cube) first")
            prof = self.tune(cube, **tune_kwargs)
        return prof

    def install(self, cube, **kwargs):
        """``planner.install_profile`` context for the cube's profile."""
        from repro.core import planner
        return planner.install_profile(self.profile_for(cube, **kwargs))

    # ------------------------------------------------------------- select
    def select(self, primitive: str, nbytes: int, comm, *,
               op: str = "add", confidence: float = MIN_R2,
               reps: int = 3, warmup: int = 1) -> str:
        """Pick the dispatch algorithm for one call site from measured data.

        Prices the planner's candidate race through the profile; when every
        candidate's fit clears ``confidence``, returns the cheapest.  A
        low-confidence fit triggers the exhaustive fallback: measure the
        candidates at exactly this size, merge the new samples into the
        cached profile (so the next call is covered), and return the
        measured winner's dispatch request.
        """
        from repro.core import planner
        cube = comm.cube
        prof = self.profile_for(cube, tune_if_missing=False) \
            if os.path.exists(self.profile_path(cube)) \
            or fingerprint_key(topology_fingerprint(cube)) in self._profiles \
            else CommProfile(topology_fingerprint(cube))

        algs = ["naive", "direct"]
        if primitive == "all_reduce" and op == "add" \
                and comm.fast_dims and comm.slow_dims:
            algs.append("pidcomm")      # resolves to the hierarchical split
        priced = []
        trusted = True
        for alg in algs:
            est = planner.estimate(cube, primitive, comm.dims, nbytes, alg,
                                   profile=prof)
            conf = prof.confidence(est.algorithm, est.stage,
                                   needs_dcn=est.dcn_bytes > 0)
            trusted = trusted and conf >= confidence
            priced.append(est)
        if trusted:
            best = min(priced, key=lambda e: (e.seconds,
                                              e.algorithm == "naive"))
            return _CANDIDATE_TO_DISPATCH[best.algorithm]

        # exhaustive-measure fallback: run the candidates at this size
        samples = microbench.measure_cell(
            cube, primitive, comm.dims, nbytes,
            [_CANDIDATE_TO_DISPATCH[e.algorithm] for e in priced],
            reps=reps, warmup=warmup)
        if not samples:
            return "pidcomm"            # group of 1: nothing to choose
        merged = prof.merge(CommProfile(prof.fingerprint, samples))
        merged.save(self.profile_path(cube))
        self._profiles[fingerprint_key(merged.fingerprint)] = merged
        best = min(samples, key=lambda s: s.seconds)
        return _CANDIDATE_TO_DISPATCH[best.algorithm]


__all__ = ["DEFAULT_CACHE_DIR", "Tuner"]
