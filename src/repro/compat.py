"""Version-compat layer: one import site for every jax API whose home or
signature moved between 0.4.x and 0.5+.

Everything in the repo that needs ``shard_map``, mesh construction, or the
varying-axes (vma) machinery goes through this module, so the codebase runs
unchanged on jax 0.4.37 (no ``jax.shard_map``, no ``jax.sharding.AxisType``,
no ``jax.typeof``/``lax.pcast``) and on 0.5+/0.8+ where those are canonical.

Exports:
  shard_map(f, *, mesh, in_specs, out_specs, check_vma=True)
      Top-level ``jax.shard_map`` when available; otherwise
      ``jax.experimental.shard_map.shard_map`` with ``check_vma`` translated
      to the old ``check_rep`` keyword.
  make_mesh(shape, axes)
      ``jax.make_mesh`` with explicit Auto axis types when the installed jax
      has ``AxisType``; plain ``jax.make_mesh`` (or a raw ``Mesh``) otherwise.
  vma_of(x) / pvary(x, axes)
      Read / extend an array's varying-axes set. On jax without the vma
      system these degrade to ``frozenset()`` / identity, which is exactly
      the old semantics (everything implicitly varying, nothing tracked).
  psum_scatter / all_gather
      Keyword-stable wrappers over the ``jax.lax`` collectives.
  axis_index / dynamic_update_slice / dynamic_slice / fori_loop
      Re-exports of the non-collective lax helpers the app layer uses, so
      application code never imports ``jax.lax`` directly (grep-enforced).
  HAS_VMA
      True when the installed jax tracks varying axes in avals.
"""
from __future__ import annotations

import inspect

import jax
import numpy as np
from jax import lax

# --------------------------------------------------------------- shard_map
try:  # jax >= 0.6: top-level export, check_vma keyword
    from jax import shard_map as _shard_map_impl
except ImportError:  # jax 0.4.x / 0.5.x: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(_shard_map_impl).parameters
    else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` signature on every supported jax version.

    On vma-capable jax (0.5+), ``check_vma`` is passed through: the
    varying-axes machinery both checks out_specs replication and lets
    autodiff insert the gradient psums for replicated leaves.

    On pre-vma jax (0.4.x), the old ``check_rep`` checker cannot see through
    a ``value_and_grad`` inside the body (replication is not part of avals),
    so ``check_vma=True`` would reject valid programs. It therefore degrades
    to ``check_rep=False``; gradient correctness for replicated params is
    restored explicitly by ``repro.runtime.trainer.sync_replicated_grads``
    (a no-op when HAS_VMA is True).
    """
    if _CHECK_KW == "check_rep":
        kwargs = {"check_rep": False}
    else:
        kwargs = {"check_vma": check_vma}
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


# -------------------------------------------------------------------- mesh
try:
    from jax.sharding import AxisType as _AxisType
except ImportError:
    _AxisType = None


def make_mesh(shape, axes):
    """Device mesh of ``shape`` over ``axes``, Auto-typed where that exists."""
    if _AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(_AxisType.Auto,) * len(axes))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.sharding import Mesh
    ndev = int(np.prod(shape))
    return Mesh(np.asarray(jax.devices()[:ndev]).reshape(shape), axes)


# ---------------------------------------------------------- varying axes
HAS_VMA: bool = hasattr(jax, "typeof") and (
    hasattr(lax, "pvary") or hasattr(lax, "pcast"))


def vma_of(x) -> frozenset:
    """The varying-axes set of ``x`` (empty when jax doesn't track vma)."""
    if not HAS_VMA:
        return frozenset()
    return getattr(jax.typeof(x), "vma", frozenset())


def pvary(x, axes):
    """Mark ``x`` varying over ``axes``; identity on pre-vma jax."""
    axes = tuple(axes)
    if not axes or not HAS_VMA:
        return x
    if hasattr(lax, "pvary"):
        return lax.pvary(x, axes)
    return lax.pcast(x, axes, to="varying")


# -------------------------------------------------------- lax collectives
import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _terminal_psum(x, axes):
    return lax.psum(x, axes)


def _terminal_psum_fwd(x, axes):
    return lax.psum(x, axes), None


def _terminal_psum_bwd(axes, _, ct):
    return (ct,)


_terminal_psum.defvjp(_terminal_psum_fwd, _terminal_psum_bwd)


def replicated_psum(x, axes):
    """psum for *terminal* reductions: ones whose output is consumed only by
    group-replicated compute (loss totals, logsumexp/normalizer denominators).

    On vma-tracking jax this is plain ``lax.psum`` -- the varying-axes
    autodiff transposes it to the identity-shaped pvary, which is exact. On
    pre-vma jax, ``lax.psum`` transposes to another psum (the old
    psum-as-psum+pbroadcast convention): correct when cotangents arrive as
    per-shard partials from sharded downstream use, but a terminal
    reduction's cotangent is replicated, so that convention over-counts by
    the group size. A custom_vjp with identity backward restores the exact
    gradient there.
    """
    if HAS_VMA:
        return lax.psum(x, axes)
    return _terminal_psum(x, axes if isinstance(axes, str) else tuple(axes))


def psum_scatter(x, axis_name, *, scatter_dimension: int = 0,
                 tiled: bool = True):
    return lax.psum_scatter(x, axis_name,
                            scatter_dimension=scatter_dimension, tiled=tiled)


def all_gather(x, axis_name, *, axis: int = 0, tiled: bool = True):
    return lax.all_gather(x, axis_name, axis=axis, tiled=tiled)


# ----------------------------------------------- lax index/update helpers
# Stable re-exports of the non-collective ``jax.lax`` helpers application
# code needs (shard index, windowed updates, loops), so the app layer's
# "import through repro.compat, never jax directly" rule is grep-enforceable
# (CI greps src/repro/apps for raw ``jax.lax`` / ``from jax import lax``).
# Collectives are NOT re-exported here: those must go through
# ``cube.comm(...)`` / ``topo.comm(...)``.
axis_index = lax.axis_index
dynamic_update_slice = lax.dynamic_update_slice
dynamic_slice = lax.dynamic_slice
fori_loop = lax.fori_loop
