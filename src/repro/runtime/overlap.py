"""Backward-overlapped gradient sync: fire bucket futures *during* backward.

The barrier path (:func:`repro.runtime.trainer.sync_replicated_grads`) runs
backward to completion and then executes one coalesced grad-sync program --
the classic bucketed-DDP gap (ROADMAP open item #1; PID-Comm §VI makes the
same move for rotate/gather phases).  This module closes it:

  bucketing
      Replicated gradient leaves are partitioned into **reverse-layer-
      ordered buckets** by the top-level parameter group that produces them
      last during backward: the loss head (``lm_head``/``final_norm``)
      gradients materialize first, the trunk stack (``units``) next, the
      encoder tower after the decoder's backward reaches it, and the input
      embeddings (``embed``/``frontend_proj``) last.  Finer granularity is
      not reachable from the trainer: the trunk runs ``lax.scan`` over the
      stacked unit parameters, so all per-layer gradients of the stack
      arrive together as one stacked cotangent.

  firing during backward (:func:`with_backward_bucket_sync`)
      Each bucket's leaves pass through an identity ``jax.custom_vjp`` hook
      *in forward-production order*; jaxpr transposition processes
      equations in reverse emission order, so each hook's backward rule --
      which records the bucket's all-reduces as one CommProgram and
      dispatches it via ``execute_async`` -- is traced the moment backward
      has produced the bucket's last contributing cotangent.  The head
      bucket's sync therefore sits *inside* the backward dataflow, data-
      dependent only on the head cotangents, and XLA is free to run it
      under the remaining backward compute.

  double-buffered staging (:func:`sync_replicated_grads_overlapped`)
      The post-backward dispatch path (for callers that already hold the
      full gradient tree) pipelines bucket programs through
      ``ProgramExecution.stage()``: the compress/concat of bucket k+1's
      coalesced payload is emitted before bucket k's wire op is forced, so
      the memory-side half of the next dispatch overlaps the previous
      bucket's wire time.

Both paths are bit-identical to the barrier sync: every leaf still gets a
psum over exactly its replication axes, and a psum of concatenated leaves
equals the concatenation of per-leaf psums regardless of which bucket the
leaf landed in (tests/parallel_check.py asserts exact equality for all 10
``configs/`` architectures).

No-op on vma-tracking jax (``compat.HAS_VMA``): there autodiff inserts the
psums itself, already interleaved with backward -- the hooks would
double-reduce.  Per-bucket programs have stable structure across traces, so
the cross-program lower cache (:mod:`repro.core.program`) hands every step
after the first its cached buckets and joint plan.
"""
from __future__ import annotations

import jax

from repro.runtime.trainer import replication_dims

# Top-level parameter groups in *forward* production order; backward
# produces their gradients in reverse, which is the bucket dispatch order.
# Unknown groups ride with the trunk (middle of the pipeline).
FORWARD_STAGES: tuple[tuple[str, ...], ...] = (
    ("embed", "frontend_proj"),            # inputs: backward reaches last
    ("enc_units", "enc_final_norm"),       # encoder tower (enc-dec models)
    ("units",),                            # decoder/trunk stack
    ("lm_head", "final_norm"),             # loss head: first grads out
)
_TRUNK_STAGE = 2


def _stage_of(key: str) -> int:
    for rank, names in enumerate(FORWARD_STAGES):
        if key in names:
            return rank
    return _TRUNK_STAGE


def _top_key(path) -> str:
    if not path:
        return ""
    entry = path[0]
    return str(getattr(entry, "key", getattr(entry, "name", entry)))


def bucket_leaf_indices(tree) -> list[list[int]]:
    """Partition ``tree``'s flat-leaf indices into reverse-layer-ordered
    buckets: index 0 is the loss-head bucket (its gradients are the first
    backward produces), the last is the embedding bucket.  Leaf order
    inside a bucket follows flattening order.  Empty buckets are dropped.
    """
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    by_stage: dict[int, list[int]] = {}
    for i, (path, _) in enumerate(leaves):
        by_stage.setdefault(_stage_of(_top_key(path)), []).append(i)
    # dispatch order = reverse of forward production order
    return [by_stage[s] for s in sorted(by_stage, reverse=True)]


def _record_bucket(flat, sflat, idxs, cube, name):
    """Record one bucket's replicated-leaf all-reduces as a CommProgram.
    Returns ``(prog, deferred)``: the flat indices routed through the
    program, in output order (sharded leaves need no reduction and are
    skipped)."""
    prog = cube.program(name=name)
    deferred: list[int] = []
    with prog:
        vals = []
        for i in idxs:
            missing = replication_dims(sflat[i], cube)
            if not missing:
                continue
            vals.append(cube.comm(missing).all_reduce(flat[i]))
            deferred.append(i)
        prog.output(*vals)
    return prog, deferred


def _scatter_results(out, deferred, results) -> None:
    if len(deferred) == 1:
        results = (results,)
    for i, r in zip(deferred, results):
        out[i] = r


def _bucket_hook(cube, leaf_specs, name):
    """Identity custom_vjp over one bucket's leaves whose backward rule
    records + async-dispatches the bucket's gradient all-reduces -- the
    sync becomes part of the backward dataflow itself."""

    @jax.custom_vjp
    def hook(*leaves):
        return leaves

    def fwd(*leaves):
        return leaves, None

    def bwd(_, cts):
        flat = list(cts)
        prog, deferred = _record_bucket(flat, leaf_specs,
                                        range(len(flat)), cube, name)
        if deferred:
            ex = prog.execute_async()
            ex.stage()                  # concat the bucket before the wire op
            _scatter_results(flat, deferred, ex.outputs())
        return tuple(flat)

    hook.defvjp(fwd, bwd)
    return hook


def with_backward_bucket_sync(loss_fn, specs, cube):
    """Wrap ``loss_fn(params, *rest)`` so that differentiating the wrapper
    yields gradients already synced over their replication axes, with each
    bucket's CommProgram fired as soon as backward produces its last
    contributing leaf (reverse-layer order: head bucket first, embeddings
    last).  Replaces the post-backward
    :func:`~repro.runtime.trainer.sync_replicated_grads` call --
    bit-identically, but inside the backward dataflow.

    Returns ``loss_fn`` unchanged on vma-tracking jax, where autodiff
    inserts (and interleaves) the reductions itself.
    """
    from repro import compat
    if compat.HAS_VMA:
        return loss_fn

    def wrapped(params, *rest):
        flat, tdef = jax.tree.flatten(params)
        sflat = tdef.flatten_up_to(specs)
        buckets = [idxs for idxs in bucket_leaf_indices(params)
                   if any(replication_dims(sflat[i], cube) for i in idxs)]
        new_flat = list(flat)
        # Hooks are *emitted* in forward-production order (reversed bucket
        # order): transposition walks the jaxpr backwards, so the head
        # bucket's sync is the first one traced during backward.
        for k, idxs in reversed(list(enumerate(buckets))):
            hook = _bucket_hook(cube, tuple(sflat[i] for i in idxs),
                                f"grad-sync-b{k}")
            synced = hook(*(new_flat[i] for i in idxs))
            for i, v in zip(idxs, synced):
                new_flat[i] = v
        return loss_fn(jax.tree.unflatten(tdef, new_flat), *rest)

    return wrapped


def sync_replicated_grads_overlapped(grads, specs, cube):
    """Post-backward bucketed dispatch: the fallback when the caller holds
    the full gradient tree (no hook placement possible).  Records one
    program per reverse-layer bucket and pipelines them double-buffered:
    bucket k+1 is staged (coalesced payloads concatenated) before bucket
    k's wire op is forced, so staging overlaps wire time.  Bit-identical
    to :func:`~repro.runtime.trainer.sync_replicated_grads`.

    No-op on vma-tracking jax (autodiff already inserted the psums).
    """
    from repro import compat
    if compat.HAS_VMA:
        return grads
    flat, tdef = jax.tree.flatten(grads)
    sflat = tdef.flatten_up_to(specs)
    out = list(flat)
    recorded = []
    for idxs in bucket_leaf_indices(grads):
        prog, deferred = _record_bucket(
            flat, sflat, idxs, cube, f"grad-sync-b{len(recorded)}")
        if deferred:
            recorded.append((prog, deferred))
    execs = [prog.execute_async() for prog, _ in recorded]
    if execs:
        execs[0].stage()
    for k, (ex, (_, deferred)) in enumerate(zip(execs, recorded)):
        if k + 1 < len(execs):
            execs[k + 1].stage()        # double-buffer: stage the next
        _scatter_results(out, deferred, ex.outputs())  # ...force this one
    return jax.tree.unflatten(tdef, out)
