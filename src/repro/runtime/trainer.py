"""The distributed train step and training loop driver.

The step is one shard_map over the architecture's hypercube:

  fwd/bwd (FSDP AllGather / ReduceScatter + TP AllGather/ReduceScatter +
  EP AlltoAll, all dispatched through topology-bound communicators with
  ``algorithm="auto"``) -> tagged gradient all-reduces -> cross-pod gradient
  all-reduce over the DCN axis (hierarchical §IX-A via the planner's pick;
  optionally int8 §V-C when ``compress_pod_grads`` is set) -> global-norm
  clip -> AdamW(8-bit moments).

The loop driver adds microbatch accumulation, per-step deadlines (straggler
mitigation) and checkpoint/restart.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import pvary_axes
from repro.models.lm import Model
from repro.models.params import param_defs, param_specs, ParamDef
from repro.models.topology import Topology
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000
    clip_norm: float = 1.0
    adamw: adamw.AdamWConfig = adamw.AdamWConfig()
    # int8 DCN gradient hop (paper §V-C): pod-crossing replicated-gradient
    # all-reduces dispatch the registry's "compressed" algorithm (a
    # custom_vjp-bounded hierarchical flow whose DCN hop is blockwise-absmax
    # int8, core/compress.py). Effective on the explicit pre-vma gradient
    # sync path; on vma-tracking jax the autodiff-inserted psums already ran
    # and the flag is a no-op (make_train_step warns).
    compress_pod_grads: bool = False
    step_deadline_s: float = 0.0       # 0 = no straggler deadline


def _spec_axes(spec) -> set:
    """Mesh axes a PartitionSpec shards over."""
    present = set()
    for entry in tuple(spec):
        if entry is None:
            continue
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            present.add(ax)
    return present


def _replication_factor(spec, topo: Topology) -> int:
    present = _spec_axes(spec)
    repl = 1
    for name, size in zip(topo.cube.dim_names, topo.cube.dim_sizes):
        if name not in present:
            repl *= size
    return repl


def sync_replicated_grads(grads, specs, cube, *, compress_pod: bool = False):
    """Insert the gradient all-reduces that vma-aware autodiff
    (check_vma=True on jax 0.5+) derives automatically: each leaf's
    per-shard gradient must be summed over every cube axis its spec does
    not shard (its replication axes), because sharded compute feeding a
    replicated parameter leaves one partial contribution per shard.

    Each reduction dispatches through ``cube.comm(missing)`` with
    ``algorithm="auto"``, so a pod-crossing gradient sum executes the
    planner's pick -- the hierarchical §IX-A split -- and is recorded by any
    active CommTrace.  With ``compress_pod`` the DCN-crossing reductions
    take the registry's "compressed" int8 flow (§V-C) instead.

    No-op when the installed jax tracks varying axes in avals
    (compat.HAS_VMA): there the psums were already inserted by autodiff.
    """
    from repro import compat
    if compat.HAS_VMA:
        return grads
    flat, tdef = jax.tree.flatten(grads)
    sflat = tdef.flatten_up_to(specs)
    out = []
    for g, s in zip(flat, sflat):
        present = _spec_axes(s)
        missing = tuple(d for d, n in zip(cube.dim_names, cube.dim_sizes)
                        if d not in present and n > 1)
        if not missing:
            out.append(g)
            continue
        comm = cube.comm(missing)
        if compress_pod and comm.crosses_dcn:
            out.append(comm.all_reduce(g, algorithm="compressed"))
        else:
            out.append(comm.all_reduce(g))
    return jax.tree.unflatten(tdef, out)


def make_train_step(cfg: ModelConfig, topo: Topology, tc: TrainConfig):
    """Returns (jitted step fn, batch_specs-less). Step signature:
    (params, opt_state, batch) -> (params, opt_state, metrics)."""
    model = Model(cfg, topo)
    specs = param_specs(cfg, topo)
    lr_fn = adamw.cosine_schedule(tc.lr, tc.warmup, tc.total_steps)
    from repro import compat
    if tc.compress_pod_grads and compat.HAS_VMA:
        import warnings
        warnings.warn(
            "compress_pod_grads is a no-op on vma-tracking jax: gradient "
            "reductions are inserted by autodiff before the trainer can "
            "route them through the compressed collective")

    def step_shard(params, opt_state, batch):
        # Gradient reductions are inserted by shard_map's vma-aware autodiff
        # (check_vma=True): the FSDP AllGather transposes to a ReduceScatter
        # over `data`, and replicated-parameter gradients (norms, routers,
        # replicated KV, cross-pod) get their psums from the varying-axes
        # tracker -- the hierarchical schedule of paper §IX-A falls out of
        # the sharding structure.
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_shard, has_aux=True)(params, batch)
        # pre-vma jax: restore the replicated-leaf all-reduces by hand,
        # planner-dispatched (hierarchical across pods; int8 when enabled)
        grads = sync_replicated_grads(grads, specs, topo.cube,
                                      compress_pod=tc.compress_pod_grads)

        # global-norm clip (replication-aware: local sum-of-squares divided
        # by each leaf's replication degree, then summed over the full cube)
        sq = 0.0
        flat, tdef = jax.tree.flatten(grads)
        sflat = tdef.flatten_up_to(specs)
        for g, s in zip(flat, sflat):
            sq = sq + jnp.sum(jnp.square(g.astype(jnp.float32))
                              ) / _replication_factor(s, topo)
        sq = pvary_axes(sq, topo.cube.dim_names)
        gnorm = jnp.sqrt(topo.comm(topo.cube.dim_names).all_reduce(sq))
        scale = jnp.minimum(1.0, tc.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

        lr = lr_fn(opt_state["step"])
        params, opt_state = adamw.update(params, opt_state, grads,
                                         lr=lr, cfg=tc.adamw)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    opt_specs = _opt_specs(cfg, topo, tc)
    batch_specs = input_batch_specs(cfg, topo)
    metric_specs = {k: P() for k in
                    ("ce_loss", "aux_loss", "tokens", "loss", "grad_norm",
                     "lr")}
    fn = shard_map(
        step_shard, mesh=topo.cube.mesh,
        in_specs=(specs, opt_specs, batch_specs),
        out_specs=(specs, opt_specs, metric_specs),
        check_vma=True)
    return jax.jit(fn, donate_argnums=(0, 1))


def _opt_specs(cfg, topo, tc: TrainConfig):
    defs = param_defs(cfg, topo)
    sd = adamw.state_defs(defs, tc.adamw,
                          is_leaf=lambda x: isinstance(x, ParamDef),
                          cube=topo.cube)
    return jax.tree.map(
        lambda d: d[1], sd,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
        and not isinstance(x[0], dict))


def opt_structs(cfg, topo, tc: TrainConfig):
    defs = param_defs(cfg, topo)
    sd = adamw.state_defs(defs, tc.adamw,
                          is_leaf=lambda x: isinstance(x, ParamDef),
                          cube=topo.cube)
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d[0], d[2],
                                       sharding=topo.cube.sharding(d[1])),
        sd, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
        and not isinstance(x[0], dict))


def input_batch_specs(cfg: ModelConfig, topo: Topology):
    dp = topo.dp
    specs = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.frontend == "patch":
        specs["patches"] = P(dp, None, None)
    if cfg.is_encoder_decoder:
        specs["frames"] = P(dp, None, None)
    return specs


# ------------------------------------------------------------------ driver
class Trainer:
    """Training loop with microbatch accumulation, straggler deadlines and
    checkpoint/restart hooks."""

    def __init__(self, cfg, topo, tc: TrainConfig, checkpointer=None):
        self.cfg, self.topo, self.tc = cfg, topo, tc
        self.step_fn = make_train_step(cfg, topo, tc)
        self.checkpointer = checkpointer
        self.slow_steps = 0

    def run(self, params, opt_state, batches, *, start_step=0,
            checkpoint_every=0, log_every=1, log=print):
        step = start_step
        history = []
        for batch in batches:
            t0 = time.monotonic()
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.monotonic() - t0
            if self.tc.step_deadline_s and dt > self.tc.step_deadline_s:
                # straggler mitigation: record and continue -- on a real
                # cluster this triggers the runtime's slow-host report
                self.slow_steps += 1
                metrics["straggler"] = 1.0
            step += 1
            history.append(metrics)
            if log_every and step % log_every == 0:
                log(f"step {step}: loss={metrics['loss']:.4f} "
                    f"gnorm={metrics['grad_norm']:.3f} {dt*1e3:.0f}ms")
            if (checkpoint_every and self.checkpointer
                    and step % checkpoint_every == 0):
                self.checkpointer.save(step, params, opt_state)
        return params, opt_state, history
