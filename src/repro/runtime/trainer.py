"""The distributed train step and training loop driver.

The step is one shard_map over the architecture's hypercube:

  fwd/bwd (FSDP AllGather / ReduceScatter + TP AllGather/ReduceScatter +
  EP AlltoAll, all dispatched through topology-bound communicators with
  ``algorithm="auto"``) -> tagged gradient all-reduces -> cross-pod gradient
  all-reduce over the DCN axis (hierarchical §IX-A via the planner's pick;
  optionally int8 §V-C when ``compress_pod_grads`` is set) -> global-norm
  clip -> AdamW(8-bit moments).

The loop driver adds microbatch accumulation, per-step deadlines (straggler
mitigation) and checkpoint/restart.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import pvary_axes
from repro.models.lm import Model
from repro.models.params import param_defs, param_specs, ParamDef
from repro.models.topology import Topology
from repro.optim import adamw
from repro.telemetry import metrics as _telemetry
from repro.telemetry import spans as _spans


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000
    clip_norm: float = 1.0
    adamw: adamw.AdamWConfig = adamw.AdamWConfig()
    # int8 DCN gradient hop (paper §V-C): pod-crossing replicated-gradient
    # all-reduces dispatch the registry's "compressed" algorithm (a
    # custom_vjp-bounded hierarchical flow whose DCN hop is blockwise-absmax
    # int8, core/compress.py). Effective on the explicit pre-vma gradient
    # sync path; on vma-tracking jax the autodiff-inserted psums already ran
    # and the flag is a no-op (make_train_step warns).
    compress_pod_grads: bool = False
    # Error feedback for the compressed hop: persist each leaf's int8
    # quantization residual in ``opt_state["ef"]`` and fold it into the next
    # step's gradient, so the lossy DCN compression's bias does not
    # accumulate (effective only with compress_pod_grads on the explicit
    # pre-vma sync path over a DCN-crossing cube -- see use_error_feedback).
    error_feedback: bool = True
    # Backward-overlapped gradient sync (ROADMAP open item #1): bucket the
    # replicated-leaf all-reduces by reverse-layer order and fire each
    # bucket's program *during* backward via custom_vjp hooks
    # (repro.runtime.overlap), instead of one barrier sync after backward
    # completes.  Bit-identical to the barrier path.  Effective on the
    # explicit pre-vma sync path without compressed pod gradients; the
    # compressed/error-feedback flow keeps the barrier sync (blockwise
    # int8 quantization is bucketing-sensitive), and on vma jax autodiff
    # already interleaves the reductions.
    overlap_grad_sync: bool = True
    step_deadline_s: float = 0.0       # 0 = no straggler deadline
    # Diagnostics mode for the telemetry step-time split: run the step as
    # three separately-jitted phases (fwd+bwd / grad-sync / clip+opt) and
    # time each into the ``train.*_seconds`` histograms, plus a
    # separately-timed forward-only pass so the backward share is
    # attributable (reverse-mode AD fuses fwd and bwd into one
    # computation; the forward re-run is extra compute, which is why this
    # is opt-in and not the production path).  Plain sync path only.
    telemetry_split: bool = False


def _spec_axes(spec) -> set:
    """Mesh axes a PartitionSpec shards over."""
    present = set()
    for entry in tuple(spec):
        if entry is None:
            continue
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            present.add(ax)
    return present


def _replication_factor(spec, topo: Topology) -> int:
    present = _spec_axes(spec)
    repl = 1
    for name, size in zip(topo.cube.dim_names, topo.cube.dim_sizes):
        if name not in present:
            repl *= size
    return repl


def replication_dims(spec, cube) -> tuple[str, ...]:
    """Cube axes a leaf with PartitionSpec ``spec`` is replicated over."""
    present = _spec_axes(spec)
    return tuple(d for d, n in zip(cube.dim_names, cube.dim_sizes)
                 if d not in present and n > 1)


def sync_replicated_grads(grads, specs, cube, *, compress_pod: bool = False,
                          ef=None):
    """Insert the gradient all-reduces that vma-aware autodiff
    (check_vma=True on jax 0.5+) derives automatically: each leaf's
    per-shard gradient must be summed over every cube axis its spec does
    not shard (its replication axes), because sharded compute feeding a
    replicated parameter leaves one partial contribution per shard.

    The per-leaf reductions are recorded into **one deferred CommProgram**
    (``cube.program()``): lowering coalesces the many small same-group
    all-reduces into bucketed dispatches and jointly plans the schedule, so
    a trainer with dozens of replicated leaves issues a handful of
    collectives instead of one per leaf -- bit-identically, since a psum of
    concatenated leaves equals the concatenation of per-leaf psums.  The
    recorded structure is identical every step (only the captured gradient
    tracers change), so the program lower cache
    (:mod:`repro.core.program` ``LOWER_STATS``) hands every sync after the
    first its already-built buckets and joint plan -- re-tracing does not
    re-run the rewrite passes.  Every
    dispatch still runs ``algorithm="auto"`` through the registry (a
    pod-crossing gradient sum executes the planner's hierarchical §IX-A
    pick) and is recorded by any active CommTrace with program provenance.

    With ``compress_pod`` the DCN-crossing reductions take the registry's
    "compressed" int8 flow (§V-C) instead.  ``ef`` (a dict of
    flat-leaf-index -> error-feedback buffer, see
    :func:`init_error_feedback`) additionally threads the compressed hop's
    quantization error across steps: the leaf gradient is pre-corrected by
    the stored error and the new residual is returned --
    ``(synced_grads, new_ef)`` when ``ef`` is given.

    No-op when the installed jax tracks varying axes in avals
    (compat.HAS_VMA): there the psums were already inserted by autodiff.
    """
    from repro import compat
    if compat.HAS_VMA:
        return grads if ef is None else (grads, ef)
    flat, tdef = jax.tree.flatten(grads)
    sflat = tdef.flatten_up_to(specs)
    out: list = [None] * len(flat)
    new_ef = dict(ef) if ef is not None else None
    deferred: list[tuple[int, object]] = []   # (leaf index, ProgramValue)
    prog = cube.program(name="grad-sync")
    with prog:
        for i, (g, s) in enumerate(zip(flat, sflat)):
            missing = replication_dims(s, cube)
            if not missing:
                out[i] = g
                continue
            comm = cube.comm(missing)
            if compress_pod and comm.crosses_dcn:
                if new_ef is not None and str(i) in new_ef:
                    # eager two-output flow: correct by the carried error,
                    # persist the fresh quantization residual
                    red, err = comm.all_reduce_with_error(
                        g.astype(jnp.float32), error=new_ef[str(i)][0])
                    out[i] = red.astype(g.dtype)
                    new_ef[str(i)] = err[jnp.newaxis]
                else:
                    deferred.append(
                        (i, comm.all_reduce(g, algorithm="compressed")))
            else:
                deferred.append((i, comm.all_reduce(g)))
        prog.output(*(v for _, v in deferred))
    if deferred:
        results = prog.execute()
        if len(deferred) == 1:
            results = (results,)
        for (i, _), r in zip(deferred, results):
            out[i] = r
    synced = jax.tree.unflatten(tdef, out)
    return synced if ef is None else (synced, new_ef)


def init_error_feedback(params, specs, cube):
    """Zero error-feedback buffers for the §V-C compressed gradient hop.

    One buffer per gradient leaf whose replication axes cross DCN: shape
    ``(n_slow, *leaf.shape)`` sharded ``P(dcn_dims, *leaf_spec)`` -- the
    quantization error is identical within a pod (it is all-gathered over
    the ICI group) but differs across pods, so the pod axis must be
    materialized.  Keyed by flattened leaf index (a string, so the dict is
    a plain pytree for checkpointing).
    """
    flat, tdef = jax.tree.flatten(params)
    sflat = tdef.flatten_up_to(specs)
    slow = cube.dcn_dims
    n_slow = int(np.prod([cube.size(d) for d in slow])) if slow else 1
    out = {}
    for i, (p, s) in enumerate(zip(flat, sflat)):
        missing = replication_dims(s, cube)
        if missing and any(d in cube.dcn_dims for d in missing):
            buf = jnp.zeros((n_slow,) + tuple(p.shape), jnp.float32)
            out[str(i)] = jax.device_put(
                buf, cube.sharding(P(slow, *tuple(s))))
    return out


def error_feedback_specs(cfg, topo, tc: "TrainConfig"):
    """PartitionSpecs matching :func:`init_error_feedback` (for shard_map
    in/out specs and dry-run structs)."""
    defs = param_defs(cfg, topo)
    flat, tdef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    specs = param_specs(cfg, topo)
    sflat = tdef.flatten_up_to(specs)
    cube = topo.cube
    out = {}
    for i, (d, s) in enumerate(zip(flat, sflat)):
        missing = replication_dims(s, cube)
        if missing and any(x in cube.dcn_dims for x in missing):
            out[str(i)] = P(cube.dcn_dims, *tuple(s))
    return out


def use_error_feedback(tc: "TrainConfig", cube) -> bool:
    """Whether this run threads an error-feedback buffer through opt_state:
    compressed pod gradients requested, the explicit (pre-vma) sync path is
    active, and the cube actually crosses DCN."""
    from repro import compat
    return bool(tc.compress_pod_grads and tc.error_feedback
                and not compat.HAS_VMA and cube.dcn_dims)


def make_train_step(cfg: ModelConfig, topo: Topology, tc: TrainConfig):
    """Returns (jitted step fn, batch_specs-less). Step signature:
    (params, opt_state, batch) -> (params, opt_state, metrics)."""
    model = Model(cfg, topo)
    specs = param_specs(cfg, topo)
    lr_fn = adamw.cosine_schedule(tc.lr, tc.warmup, tc.total_steps)
    from repro import compat
    if tc.compress_pod_grads and compat.HAS_VMA:
        import warnings
        warnings.warn(
            "compress_pod_grads is a no-op on vma-tracking jax: gradient "
            "reductions are inserted by autodiff before the trainer can "
            "route them through the compressed collective")

    with_ef = use_error_feedback(tc, topo.cube)
    # backward-overlapped sync: pre-vma explicit path only, and not under
    # the compressed/error-feedback flow (blockwise int8 quantization is
    # bucketing-sensitive; the barrier path keeps its accuracy contract)
    overlap_sync = (tc.overlap_grad_sync and not compat.HAS_VMA
                    and not with_ef and not tc.compress_pod_grads)
    if overlap_sync:
        from repro.runtime.overlap import with_backward_bucket_sync
        loss_overlapped = with_backward_bucket_sync(
            model.loss_shard, specs, topo.cube)

    def step_shard(params, opt_state, batch):
        # Gradient reductions are inserted by shard_map's vma-aware autodiff
        # (check_vma=True): the FSDP AllGather transposes to a ReduceScatter
        # over `data`, and replicated-parameter gradients (norms, routers,
        # replicated KV, cross-pod) get their psums from the varying-axes
        # tracker -- the hierarchical schedule of paper §IX-A falls out of
        # the sharding structure.
        if overlap_sync:
            # pre-vma jax, overlapped: per-bucket custom_vjp hooks fire
            # each bucket's grad-sync program during backward (reverse-
            # layer order), so grads come out already synced
            (loss, metrics), grads = jax.value_and_grad(
                loss_overlapped, has_aux=True)(params, batch)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                model.loss_shard, has_aux=True)(params, batch)
        # pre-vma jax, barrier path: restore the replicated-leaf
        # all-reduces by hand -- recorded as one coalesced CommProgram,
        # planner-dispatched (hierarchical across pods; int8 + error
        # feedback when enabled)
        if with_ef:
            grads, new_ef = sync_replicated_grads(
                grads, specs, topo.cube, compress_pod=True,
                ef=opt_state["ef"])
        elif not overlap_sync:
            grads = sync_replicated_grads(grads, specs, topo.cube,
                                          compress_pod=tc.compress_pod_grads)

        # global-norm clip (replication-aware: local sum-of-squares divided
        # by each leaf's replication degree, then summed over the full cube)
        sq = 0.0
        flat, tdef = jax.tree.flatten(grads)
        sflat = tdef.flatten_up_to(specs)
        for g, s in zip(flat, sflat):
            sq = sq + jnp.sum(jnp.square(g.astype(jnp.float32))
                              ) / _replication_factor(s, topo)
        sq = pvary_axes(sq, topo.cube.dim_names)
        gnorm = jnp.sqrt(topo.comm(topo.cube.dim_names).all_reduce(sq))
        scale = jnp.minimum(1.0, tc.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

        lr = lr_fn(opt_state["step"])
        params, opt_state = adamw.update(params, opt_state, grads,
                                         lr=lr, cfg=tc.adamw)
        if with_ef:
            opt_state["ef"] = new_ef
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    opt_specs = _opt_specs(cfg, topo, tc)
    batch_specs = input_batch_specs(cfg, topo)
    metric_specs = {k: P() for k in
                    ("ce_loss", "aux_loss", "tokens", "loss", "grad_norm",
                     "lr")}
    fn = shard_map(
        step_shard, mesh=topo.cube.mesh,
        in_specs=(specs, opt_specs, batch_specs),
        out_specs=(specs, opt_specs, metric_specs),
        check_vma=True)
    return jax.jit(fn, donate_argnums=(0, 1))


def make_split_train_step(cfg: ModelConfig, topo: Topology,
                          tc: TrainConfig):
    """The train step as separately-jitted phases, for the telemetry
    step-time split (``TrainConfig.telemetry_split``).

    Returns ``(fwd, fwd_bwd, sync, opt)``:

    * ``fwd(params, batch) -> (loss, aux)`` -- forward only, timed so the
      backward share of ``fwd_bwd`` is attributable (bwd = fwd_bwd - fwd);
    * ``fwd_bwd(params, batch) -> (loss, aux, grads)``;
    * ``sync(grads) -> grads`` -- the explicit replicated-leaf gradient
      sync; ``None`` on vma-tracking jax (autodiff already inserted the
      reductions inside ``fwd_bwd``, so there is no separable phase);
    * ``opt(params, opt_state, grads) -> (params, opt_state, metrics)`` --
      global-norm clip + AdamW.

    Phase boundaries materialize intermediates the fused step would keep
    on-device, so the *sum* of phase times brackets, rather than equals,
    the fused step time -- the split is for attribution, not for the
    ``train_step`` bench rows.  Plain sync path only (no compressed pod
    gradients / error feedback).
    """
    from repro import compat
    if tc.compress_pod_grads:
        raise ValueError(
            "telemetry_split supports the plain gradient-sync path only "
            "(compress_pod_grads records inside the fused step)")
    model = Model(cfg, topo)
    specs = param_specs(cfg, topo)
    lr_fn = adamw.cosine_schedule(tc.lr, tc.warmup, tc.total_steps)
    mesh = topo.cube.mesh
    opt_specs = _opt_specs(cfg, topo, tc)
    batch_specs = input_batch_specs(cfg, topo)
    aux_specs = {k: P() for k in ("ce_loss", "aux_loss", "tokens")}

    def fwd_shard(params, batch):
        return model.loss_shard(params, batch)

    def fwd_bwd_shard(params, batch):
        (loss, aux), grads = jax.value_and_grad(
            model.loss_shard, has_aux=True)(params, batch)
        return loss, aux, grads

    def sync_shard(grads):
        return sync_replicated_grads(grads, specs, topo.cube)

    def opt_shard(params, opt_state, grads):
        sq = 0.0
        flat, tdef = jax.tree.flatten(grads)
        sflat = tdef.flatten_up_to(specs)
        for g, s in zip(flat, sflat):
            sq = sq + jnp.sum(jnp.square(g.astype(jnp.float32))
                              ) / _replication_factor(s, topo)
        sq = pvary_axes(sq, topo.cube.dim_names)
        gnorm = jnp.sqrt(topo.comm(topo.cube.dim_names).all_reduce(sq))
        scale = jnp.minimum(1.0, tc.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)
        lr = lr_fn(opt_state["step"])
        params, opt_state = adamw.update(params, opt_state, grads,
                                         lr=lr, cfg=tc.adamw)
        return params, opt_state, {"grad_norm": gnorm, "lr": lr}

    fwd = jax.jit(shard_map(
        fwd_shard, mesh=mesh, in_specs=(specs, batch_specs),
        out_specs=(P(), aux_specs), check_vma=True))
    fwd_bwd = jax.jit(shard_map(
        fwd_bwd_shard, mesh=mesh, in_specs=(specs, batch_specs),
        out_specs=(P(), aux_specs, specs), check_vma=True))
    sync = None
    if not compat.HAS_VMA:
        sync = jax.jit(shard_map(
            sync_shard, mesh=mesh, in_specs=(specs,), out_specs=specs,
            check_vma=False))
    opt = jax.jit(shard_map(
        opt_shard, mesh=mesh, in_specs=(specs, opt_specs, specs),
        out_specs=(specs, opt_specs, {"grad_norm": P(), "lr": P()}),
        check_vma=True))
    return fwd, fwd_bwd, sync, opt


def init_opt_state(params, cfg, topo, tc: TrainConfig):
    """Optimizer state for :func:`make_train_step`: AdamW moments plus the
    compressed-hop error-feedback buffers when this run threads them."""
    state = adamw.init_state(params, tc.adamw)
    if use_error_feedback(tc, topo.cube):
        state["ef"] = init_error_feedback(
            params, param_specs(cfg, topo), topo.cube)
    return state


def _opt_specs(cfg, topo, tc: TrainConfig):
    defs = param_defs(cfg, topo)
    sd = adamw.state_defs(defs, tc.adamw,
                          is_leaf=lambda x: isinstance(x, ParamDef),
                          cube=topo.cube)
    specs = jax.tree.map(
        lambda d: d[1], sd,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
        and not isinstance(x[0], dict))
    if use_error_feedback(tc, topo.cube):
        specs["ef"] = error_feedback_specs(cfg, topo, tc)
    return specs


def opt_specs(cfg, topo, tc: TrainConfig):
    """Placement specs for :func:`init_opt_state`'s tree -- the opt half of
    a topology-bound :class:`~repro.checkpoint.CheckpointManager`'s
    ``specs={"params": ..., "opt": ...}`` binding."""
    return _opt_specs(cfg, topo, tc)


def opt_structs(cfg, topo, tc: TrainConfig):
    defs = param_defs(cfg, topo)
    sd = adamw.state_defs(defs, tc.adamw,
                          is_leaf=lambda x: isinstance(x, ParamDef),
                          cube=topo.cube)
    structs = jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d[0], d[2],
                                       sharding=topo.cube.sharding(d[1])),
        sd, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
        and not isinstance(x[0], dict))
    if use_error_feedback(tc, topo.cube):
        cube = topo.cube
        n_slow = int(np.prod([cube.size(d) for d in cube.dcn_dims]))
        flat, tdef = jax.tree.flatten(
            param_defs(cfg, topo), is_leaf=lambda x: isinstance(x, ParamDef))
        shapes = {str(i): (n_slow,) + tuple(d.shape)
                  for i, d in enumerate(flat)}
        structs["ef"] = {
            k: jax.ShapeDtypeStruct(shapes[k], jnp.float32,
                                    sharding=topo.cube.sharding(spec))
            for k, spec in error_feedback_specs(cfg, topo, tc).items()}
    return structs


def input_batch_specs(cfg: ModelConfig, topo: Topology):
    dp = topo.dp
    specs = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.frontend == "patch":
        specs["patches"] = P(dp, None, None)
    if cfg.is_encoder_decoder:
        specs["frames"] = P(dp, None, None)
    return specs


# ------------------------------------------------------------------ driver
class Trainer:
    """Training loop with microbatch accumulation, straggler deadlines and
    checkpoint/restart hooks."""

    def __init__(self, cfg, topo, tc: TrainConfig, checkpointer=None):
        self.cfg, self.topo, self.tc = cfg, topo, tc
        self.step_fn = make_train_step(cfg, topo, tc)
        self.split_fns = (make_split_train_step(cfg, topo, tc)
                          if tc.telemetry_split else None)
        self.checkpointer = checkpointer
        self.slow_steps = 0
        self._sync_priced = False

    def _record_step_telemetry(self, dt: float, straggler: bool) -> None:
        """Per-step metric updates (also the enabled-path payload the
        ``telemetry_overhead`` bench row measures)."""
        _telemetry.inc("train.steps")
        _telemetry.observe("train.step_seconds", dt)
        if straggler:
            _telemetry.inc("train.straggler_steps")

    def _price_sync_estimates(self, events) -> None:
        """Set the grad-sync planner-estimate gauges from the traced
        step's CommEvents: serial = every program-recorded sync second on
        the critical path; exposed = only the final bucket's, the one the
        overlap path cannot hide under backward."""
        by_prog: dict = {}
        for e in events:
            if e.program_id and str(e.program_id).startswith("grad-sync"):
                by_prog.setdefault(e.program_id, []).append(e)
        if not by_prog:
            return
        serial = sum(e.seconds for evs in by_prog.values() for e in evs)
        # overlap buckets are named grad-sync-b{k}; the highest k is the
        # final bucket.  The barrier path's single unsuffixed program is
        # then also the "last" -- fully exposed.
        last = max(by_prog, key=lambda pid: int(pid.rsplit("-b", 1)[1])
                   if "-b" in pid else -1)
        exposed = sum(e.seconds for e in by_prog[last])
        _telemetry.set_gauge("train.sync_serial_est_us", serial * 1e6)
        _telemetry.set_gauge("train.sync_exposed_est_us", exposed * 1e6)

    def _run_split_step(self, params, opt_state, batch):
        """telemetry_split mode: phase-timed fwd / fwd+bwd / sync / opt."""
        fwd, fwd_bwd, sync, opt = self.split_fns
        t0 = time.monotonic()
        jax.block_until_ready(fwd(params, batch))
        t1 = time.monotonic()
        loss, aux, grads = fwd_bwd(params, batch)
        jax.block_until_ready(grads)
        t2 = time.monotonic()
        if sync is not None:
            grads = sync(grads)
            jax.block_until_ready(grads)
        t3 = time.monotonic()
        params, opt_state, om = opt(params, opt_state, grads)
        jax.block_until_ready((params, opt_state))
        t4 = time.monotonic()
        _telemetry.observe("train.fwd_seconds", t1 - t0)
        _telemetry.observe("train.fwd_bwd_seconds", t2 - t1)
        _telemetry.observe("train.sync_seconds", t3 - t2)
        _telemetry.observe("train.opt_seconds", t4 - t3)
        metrics = dict(aux, loss=loss, **om)
        return params, opt_state, metrics

    def run(self, params, opt_state, batches, *, start_step=0,
            checkpoint_every=0, log_every=1, log=print):
        step = start_step
        history = []
        for batch in batches:
            t0 = time.monotonic()
            with _spans.maybe_span("train-step", cat="wall", step=step):
                # getattr: tests drive partially-constructed Trainers
                # (object.__new__) through run()
                if getattr(self, "split_fns", None) is not None:
                    params, opt_state, metrics = self._run_split_step(
                        params, opt_state, batch)
                elif (_telemetry.enabled()
                      and not getattr(self, "_sync_priced", True)):
                    # first metered step: trace the grad-sync events once
                    # to price the serial/exposed sync-estimate gauges
                    from repro.core.comm import CommTrace
                    with CommTrace() as ct:
                        params, opt_state, metrics = self.step_fn(
                            params, opt_state, batch)
                    self._price_sync_estimates(ct.events)
                    self._sync_priced = True
                else:
                    params, opt_state, metrics = self.step_fn(
                        params, opt_state, batch)
                # block on the step's real outputs before reading the
                # clock: the param/opt_state updates are not
                # data-dependent on the logged metrics, so coercing
                # metrics alone lets async dispatch leak their compute out
                # of dt -- the straggler deadline and the logged per-step
                # ms would undercount
                jax.block_until_ready((params, opt_state))
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.monotonic() - t0
            straggler = bool(self.tc.step_deadline_s
                             and dt > self.tc.step_deadline_s)
            if straggler:
                # straggler mitigation: record and continue -- on a real
                # cluster this triggers the runtime's slow-host report
                self.slow_steps += 1
                metrics["straggler"] = 1.0
            if _telemetry.enabled():
                self._record_step_telemetry(dt, straggler)
            step += 1
            history.append(metrics)
            if log_every and step % log_every == 0:
                log(f"step {step}: loss={metrics['loss']:.4f} "
                    f"gnorm={metrics['grad_norm']:.3f} {dt*1e3:.0f}ms")
            if (checkpoint_every and self.checkpointer
                    and step % checkpoint_every == 0):
                # gather-at-dispatch: save() snapshots params/opt to host
                # before returning (the jitted step donates both buffers),
                # then overlaps serialization + disk writes with the next
                # steps
                from repro.checkpoint.manager import TrainState
                self.checkpointer.save(
                    step, TrainState(params=params, opt=opt_state))
        return params, opt_state, history
