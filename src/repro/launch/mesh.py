"""Physical mesh construction for the production deployment.

The production target is TPU v5e: one pod = a 16x16 ICI-connected slice
(256 chips), two pods connected over DCN for the multi-pod configuration.
``make_production_mesh`` is a function (never a module-level constant) so that
importing this module never touches jax device state.

Mesh construction is version-sensitive (``AxisType`` only exists on jax
0.5+), so it lives in :mod:`repro.compat`; this module re-exports it so all
launch-path callers keep their import site.
"""
from __future__ import annotations

from repro.compat import make_mesh

__all__ = ["make_mesh", "make_production_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """The deployment mesh: 16x16 chips per pod; 2 pods over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
