"""Physical mesh construction for the production deployment.

The production target is TPU v5e: one pod = a 16x16 ICI-connected slice
(256 chips), two pods connected over DCN for the multi-pod configuration.
``make_production_mesh`` is a function (never a module-level constant) so that
importing this module never touches jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types (JAX 0.8/0.9 compatible)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """The deployment mesh: 16x16 chips per pod; 2 pods over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
