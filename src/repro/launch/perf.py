"""Perf-iteration harness (EXPERIMENTS.md §Perf).

Lowers one (arch x shape) cell under a named optimization variant, derives
the roofline terms via the two-point cost probe, and writes
results/perf/<cell>__<variant>.json for the hypothesis -> change -> measure
log.

    python -m repro.launch.perf --arch mixtral-8x7b --shape train_4k \
        --variant sort_dispatch
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses as dc
import json

from repro import configs
from repro.launch import dryrun

VARIANTS = {
    # name -> (cfg transform, lower kwargs)
    "baseline": (None, {}),
    "sort_dispatch": (lambda c: dc.replace(c, moe_dispatch="sort"), {}),
    "resident_weights": (None, {"resident": True}),
    "int8_kv": (None, {"cache_dtype": "int8"}),
    "resident+int8_kv": (None, {"resident": True, "cache_dtype": "int8"}),
    "cap1.0": (lambda c: dc.replace(c, capacity_factor=1.0), {}),
    "sort+cap1.0": (lambda c: dc.replace(c, capacity_factor=1.0,
                                         moe_dispatch="sort"), {}),
    "lowp": (None, {"lowp": 1}),
    "lowp2": (None, {"lowp": 2}),
    "lowp2+sort": (lambda c: dc.replace(c, moe_dispatch="sort"),
                   {"lowp": 2}),
    "serve_bf16": (None, {"resident": True, "serve_bf16": True}),
    "serve_bf16+int8_kv": (None, {"resident": True, "serve_bf16": True,
                                  "cache_dtype": "int8"}),
}


def run(arch: str, shape: str, variant: str, *, multi_pod=False,
        out="results/perf"):
    tfm, kw = VARIANTS[variant]
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "variant": variant, "status": "ok",
           "params_total": configs.get(arch).param_count(),
           "params_active": configs.get(arch).active_param_count()}
    probe = dryrun.run_probe(arch, shape, multi_pod=multi_pod,
                             cfg_transform=tfm, **kw)
    rec.update(probe)
    rec["cost"] = rec["cost_x"]

    import sys
    sys.path.insert(0, os.getcwd())
    from benchmarks import roofline
    terms = roofline.analyse(rec)
    rec["terms"] = {k: terms[k] for k in
                    ("compute_s", "memory_s", "collective_s", "dominant",
                     "useful_ratio", "roofline_frac")}
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, f"{arch}_{shape}__{variant}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    t = rec["terms"]
    print(f"{arch}/{shape}/{variant}: compute={t['compute_s']:.3e}s "
          f"memory={t['memory_s']:.3e}s collective={t['collective_s']:.3e}s "
          f"dominant={t['dominant']} frac={t['roofline_frac']:.2%}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--multipod", action="store_true")
    args = ap.parse_args()
    run(args.arch, args.shape, args.variant, multi_pod=args.multipod)


if __name__ == "__main__":
    main()
