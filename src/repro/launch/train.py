"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 200 --batch 8 --seq 256 [--smoke] [--ckpt-dir ckpts]

With --smoke the architecture is reduced to its CPU-runnable family config
(single device). On a real TPU deployment the same entry point runs the full
config on the production mesh (``--production`` / ``--multipod``).
"""
from __future__ import annotations

import argparse
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fp32-moments", action="store_true")
    args = ap.parse_args()

    import jax
    from repro import configs
    from repro.checkpoint.manager import CheckpointManager
    from repro.data.pipeline import DataConfig, TokenStream
    from repro.launch.mesh import make_mesh, make_production_mesh
    from repro.models.params import init_params, param_specs
    from repro.models.topology import build_topology
    from repro.optim import adamw
    from repro.runtime.trainer import (
        Trainer, TrainConfig, init_opt_state, make_train_step,
        input_batch_specs, opt_specs)

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.scaled_for_smoke()
    if args.production or args.multipod:
        mesh = make_production_mesh(multi_pod=args.multipod)
    else:
        n = len(jax.devices())
        mp = min(cfg.model_parallel, n)
        if args.smoke:
            mp = 1
        mesh = make_mesh((n // mp, mp), ("data", "model"))
    topo = build_topology(cfg, mesh, global_batch=args.batch)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"cube={topo.cube.describe()}")

    tc = TrainConfig(lr=args.lr, warmup=args.warmup,
                     total_steps=args.steps,
                     adamw=adamw.AdamWConfig(use_8bit=not args.fp32_moments))
    params = init_params(cfg, topo, seed=0)
    opt = init_opt_state(params, cfg, topo, tc)

    ckpt = None
    if args.ckpt_dir:
        # topology-bound: save gathers through one rooted-gather program,
        # restore re-places every leaf through one rooted-scatter program
        # planned for THIS cube -- resuming on a different mesh shape than
        # the checkpoint was written on needs no conversion step
        ckpt = CheckpointManager(
            args.ckpt_dir, topo=topo,
            specs={"params": param_specs(cfg, topo),
                   "opt": opt_specs(cfg, topo, tc)})
    start = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        start = ckpt.latest_step()
        st = ckpt.restore(start)
        params, opt = st.params, st.opt
        print(f"resumed from step {start}")

    dc = DataConfig(seq_len=args.seq, global_batch=args.batch,
                    vocab_size=cfg.vocab_size)
    stream = TokenStream(cfg, dc)

    trainer = Trainer(cfg, topo, tc, checkpointer=ckpt)

    def batches():
        import jax.numpy as jnp
        for step in range(start, args.steps):
            b = stream.global_batch_at(step)
            yield {k: jnp.asarray(v) for k, v in b.items()}

    params, opt, hist = trainer.run(
        params, opt, batches(), start_step=start,
        checkpoint_every=args.ckpt_every, log_every=max(args.steps // 20, 1))
    if ckpt:
        ckpt.wait()
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(first {hist[0]['loss']:.4f}); straggler steps: "
          f"{trainer.slow_steps}")


if __name__ == "__main__":
    main()
