"""Serving launcher: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro import configs
    from repro.launch.mesh import make_mesh
    from repro.models.params import init_params, param_specs
    from repro.models.serving import (
        Server, make_serve_plan, cache_specs, init_cache)
    from repro.models.topology import build_serve_topology

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.scaled_for_smoke()
    n = len(jax.devices())
    mesh = make_mesh((n, 1), ("data", "model"))
    topo = build_serve_topology(cfg, mesh)
    S_ctx = args.prompt_len + args.gen
    plan = make_serve_plan(cfg, topo, S_ctx=S_ctx, global_batch=args.batch)
    server = Server(cfg, topo, plan)
    print(f"arch={cfg.name} cube={topo.cube.describe()} "
          f"cache={plan.S_cache}")

    params = init_params(cfg, topo, seed=0)
    cache = init_cache(cfg, topo, plan)
    specs = param_specs(cfg, topo)
    cspecs = cache_specs(cfg, topo, plan)
    ba = plan.batch_axes or None

    step = jax.jit(shard_map(
        server.decode_shard, mesh=topo.cube.mesh,
        in_specs=(specs, cspecs, P(ba), P(ba)),
        out_specs=(P(ba, topo.tp), cspecs), check_vma=False),
        donate_argnums=(1,))

    rng = np.random.RandomState(0)
    B = args.batch
    prompt = rng.randint(0, cfg.vocab_size, (B, args.prompt_len))
    toks = jnp.asarray(prompt[:, 0], jnp.int32)
    out = []
    # teacher-forced "prefill" via decode steps (keeps the demo single-path),
    # then free-running generation
    for t in range(S_ctx - 1):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = step(params, cache, toks, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if t + 1 < args.prompt_len:
            toks = jnp.asarray(prompt[:, t + 1], jnp.int32)
        else:
            toks = nxt
            out.append(np.asarray(nxt))
    gen = np.stack(out, axis=1)
    print(f"generated {gen.shape} tokens; sample row: {gen[0][:12]}")


if __name__ == "__main__":
    main()
