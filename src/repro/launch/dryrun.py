"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production mesh and record memory / cost / collective-schedule
analysis for the roofline.

MUST set the fake device count before any other import -- jax locks the
device count on first backend init.
"""
import os
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                               + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core.comm import CommTrace
from repro.telemetry import drift as drift_mod
from repro.telemetry import metrics as telemetry_metrics
from repro.launch.mesh import make_production_mesh
from repro.models.config import ModelConfig
from repro.models.lm import Model
from repro.models.params import param_structs, param_specs
from repro.models.serving import (
    Server, make_serve_plan, cache_structs, cache_specs)
from repro.models.topology import build_topology, build_serve_topology
from repro.runtime.trainer import (
    TrainConfig, make_train_step, opt_structs, input_batch_specs)

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")
TYPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred)"
                     r"\[([0-9,]*)\]")
DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
               "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1}


def _shape_bytes(m) -> int:
    dt, dims = m
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dt]


def parse_collectives(hlo: str) -> dict:
    """Sum result bytes of every collective op in (post-optimization) HLO.

    Post-opt HLO prints operands as names, so we account with the *result*
    type (between '=' and the op name); the roofline converts result bytes to
    wire bytes per-primitive (AG: (g-1)/g x result; RS: result x (g-1);
    AR: 2 x (g-1)/g x result; AA: (g-1)/g x result; permute: 1x)."""
    out = {}
    for line in hlo.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        op = m.group(1)
        sync = f" {op}(" in line
        start = f" {op}-start(" in line
        if not (sync or start):
            continue
        lhs = line.split(f" {op}", 1)[0]
        lhs = lhs.split("=", 1)[-1]
        types = TYPE_RE.findall(lhs)
        if not types:
            continue
        # sync ops: single result type; -start ops: tuple (operand, result)
        nbytes = _shape_bytes(types[-1])
        g = 0
        rg = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
        if rg:
            g = len(rg.group(1).split(","))
        else:
            rg = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
            if rg:
                g = int(rg.group(2))
        d = out.setdefault(op, {"count": 0, "result_bytes": 0,
                                "by_group": {}})
        d["count"] += 1
        d["result_bytes"] += nbytes
        bg = d["by_group"].setdefault(str(g), {"count": 0, "bytes": 0})
        bg["count"] += 1
        bg["bytes"] += nbytes
    return out


# HLO collective ops each (primitive, executed flow) must leave in the
# compiled module.  Rooted host primitives map to boundary transfers (no
# collective op) and are excluded.  The registry bodies are the source of
# truth: naive/pr emulate the host flow with a full all-gather, im ladders
# are ppermute chains, the hierarchical/compressed splits are RS + AG.
_EXPECTED_HLO = {
    ("all_reduce", "hierarchical"): {"reduce-scatter", "all-gather"},
    ("all_reduce", "compressed"): {"reduce-scatter", "all-gather"},
    ("all_reduce", "im"): {"all-reduce"},
    ("all_reduce", "naive"): {"all-gather"},
    ("all_reduce", "pr"): {"all-gather"},
    ("all_reduce", "ring"): {"collective-permute"},
    ("all_reduce", "tree"): {"collective-permute"},
    ("reduce_scatter", "im"): {"reduce-scatter"},
    ("reduce_scatter", "naive"): {"all-gather"},
    ("reduce_scatter", "pr"): {"all-gather"},
    ("all_gather", "im"): {"all-gather"},
    ("all_gather", "cm"): {"all-gather"},
    ("all_gather", "pr"): {"all-gather"},
    ("all_gather", "naive"): {"all-reduce"},
    ("all_to_all", "cm"): {"all-to-all"},
    ("all_to_all", "im"): {"collective-permute"},
    ("all_to_all", "naive"): {"all-gather"},
    ("all_to_all", "pr"): {"all-gather"},
}


def comm_drift(trace_summary: dict, collectives: dict) -> dict:
    """Cross-check the planner's recorded schedule (``CommTrace.summary()``)
    against the HLO-parsed ``collectives`` section of the same cell.

    Every (primitive, flow) the communicator dispatched must leave its
    expected collective ops in the compiled module; an expected op kind that
    never appears means the runtime executed something other than what the
    planner recorded (planner/runtime drift).  The byte comparison is
    informational only -- the HLO additionally contains autodiff-transposed
    collectives the trace cannot see -- except in one direction: compiled
    wire traffic below the drift band's low edge
    (:data:`repro.telemetry.drift.DEFAULT_BAND`, shared with the live
    drift monitor) flags over-estimation.
    """
    expected: set[str] = set()
    flows = []
    for key in trace_summary.get("by_flow", {}):
        primitive, flow = key.split("/", 1)
        want = _EXPECTED_HLO.get((primitive, flow))
        if want is None:       # rooted primitives: boundary transfer, no op
            continue
        flows.append(key)
        expected |= want
    present = {op for op, d in collectives.items() if d.get("count")}
    missing = sorted(expected - present)

    trace_bytes = (trace_summary.get("ici_bytes", 0.0)
                   + trace_summary.get("dcn_bytes", 0.0))
    hlo_bytes = sum(d.get("result_bytes", 0) for d in collectives.values())
    ratio = (hlo_bytes / trace_bytes) if trace_bytes > 0 else None
    drift = bool(missing) or (bool(flows) and trace_bytes > 0
                              and (hlo_bytes == 0
                                   or (ratio is not None
                                       and drift_mod.underrun(ratio))))
    return {"drift": drift, "missing_ops": missing,
            "checked_flows": sorted(flows),
            "expected_ops": sorted(expected), "hlo_ops": sorted(present),
            "hlo_over_trace_bytes": ratio}


def input_structs(cfg: ModelConfig, topo, shape: dict):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    S, B = shape["seq"], shape["batch"]
    sh = topo.cube.sharding
    dp = topo.dp

    def struct(shp, dt, spec):
        return jax.ShapeDtypeStruct(shp, dt, sharding=sh(spec))

    if shape["kind"] in ("train", "prefill"):
        batch = {"tokens": struct((B, S), jnp.int32, P(dp, None)),
                 "labels": struct((B, S), jnp.int32, P(dp, None))}
        if cfg.frontend == "patch":
            batch["patches"] = struct((B, cfg.frontend_tokens,
                                       cfg.frontend_dim), jnp.bfloat16,
                                      P(dp, None, None))
        if cfg.is_encoder_decoder:
            batch["frames"] = struct((B, S, cfg.frontend_dim), jnp.bfloat16,
                                     P(dp, None, None))
            # decoder operates on S/4 text tokens
            batch["tokens"] = struct((B, S // 4), jnp.int32, P(dp, None))
            batch["labels"] = struct((B, S // 4), jnp.int32, P(dp, None))
        if shape["kind"] == "prefill":
            batch.pop("labels")
        return batch
    raise ValueError(shape)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool) -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "params_total": cfg.param_count(),
           "params_active": cfg.active_param_count()}

    if shape_name == "long_500k" and not cfg.subquadratic:
        rec["status"] = "skipped"
        rec["reason"] = ("pure full attention: 500k-token decode requires "
                         "sub-quadratic attention memory (see DESIGN.md)")
        return rec

    t0 = time.monotonic()
    # Dispatch happens at trace time, so lowering under a CommTrace records
    # the planned schedule of every communicator call site (one event per
    # textual site; scanned layers trace once).
    trace = CommTrace()
    from repro.core import program as program_mod
    lower_stats0 = dict(program_mod.LOWER_STATS)
    # per-cell telemetry scope: the comm/program/planner counters fired
    # while this cell lowers land in a fresh registry (no cross-cell
    # pollution), snapshotted into rec["telemetry"] below
    tscope = telemetry_metrics.scoped_metrics()
    if shape["kind"] == "train":
        topo = build_topology(cfg, mesh, global_batch=shape["batch"])
        tc = TrainConfig()
        step = make_train_step(cfg, topo, tc)
        pst = param_structs(cfg, topo)
        ost = opt_structs(cfg, topo, tc)
        bst = input_structs(cfg, topo, shape)
        with trace, tscope as treg:
            lowered = step.lower(pst, ost, bst)
    elif shape["kind"] == "prefill":
        topo = build_topology(cfg, mesh, global_batch=shape["batch"])
        server = Server(cfg, topo, None)
        specs = param_specs(cfg, topo)
        bst = input_structs(cfg, topo, shape)
        bspecs = {k: P(topo.dp, *([None] * (len(v.shape) - 1)))
                  for k, v in bst.items()}
        fn = shard_map(server.prefill_shard, mesh=topo.cube.mesh,
                       in_specs=(specs, bspecs),
                       out_specs=(P(topo.dp, topo.tp), _prefill_cache_spec(
                           server, cfg, topo)),
                       check_vma=False)
        with trace, tscope as treg:
            lowered = jax.jit(fn).lower(param_structs(cfg, topo), bst)
    else:  # decode
        topo = build_serve_topology(cfg, mesh)
        plan = make_serve_plan(cfg, topo, S_ctx=shape["seq"],
                               global_batch=shape["batch"])
        rec["serve_plan"] = dict(S_cache=plan.S_cache,
                                 batch_axes=plan.batch_axes,
                                 kv_axes=plan.kv_axes)
        server = Server(cfg, topo, plan)
        specs = param_specs(cfg, topo)
        cspecs = cache_specs(cfg, topo, plan)
        ba = plan.batch_axes or None
        B = plan.global_batch
        tok = jax.ShapeDtypeStruct((B,), jnp.int32,
                                   sharding=topo.cube.sharding(P(ba)))
        pos = jax.ShapeDtypeStruct((B,), jnp.int32,
                                   sharding=topo.cube.sharding(P(ba)))
        fn = shard_map(server.decode_shard, mesh=topo.cube.mesh,
                       in_specs=(specs, cspecs, P(ba), P(ba)),
                       out_specs=(P(ba, topo.tp), cspecs),
                       check_vma=False)
        with trace, tscope as treg:
            lowered = jax.jit(fn, donate_argnums=(1,)).lower(
                param_structs(cfg, topo), cache_structs(cfg, topo, plan),
                tok, pos)
    rec["cube"] = topo.cube.describe()
    rec["comm_trace"] = trace.summary()
    # estimate provenance: which cost model priced this cell's schedule
    # ("analytic" constants vs an installed measured CommProfile)
    rec["est_sources"] = rec["comm_trace"].get("est_sources", {})
    # deferred-program reuse during this cell's trace: schedules built vs
    # served from the cross-program lower cache (grad-sync reuse shows up
    # here when a cell traces the same program structure more than once)
    rec["program_cache"] = {
        k: program_mod.LOWER_STATS[k] - lower_stats0[k]
        for k in program_mod.LOWER_STATS}
    # metrics fired while the cell lowered (dispatch happens at trace
    # time, so comm/program/planner instrumentation all landed in treg)
    rec["telemetry"] = treg.snapshot()
    rec["lower_s"] = round(time.monotonic() - t0, 1)

    t1 = time.monotonic()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.monotonic() - t1, 1)

    mem = compiled.memory_analysis()
    print(mem)
    rec["memory"] = {
        k: int(getattr(mem, k)) for k in
        ("argument_size_in_bytes", "output_size_in_bytes",
         "temp_size_in_bytes", "generated_code_size_in_bytes")
        if hasattr(mem, k)}
    cost = compiled.cost_analysis()
    print({k: v for k, v in cost.items()
           if k in ("flops", "bytes accessed")})
    rec["cost"] = {k: float(cost[k]) for k in
                   ("flops", "bytes accessed", "transcendentals",
                    "optimal_seconds") if k in cost}
    hlo = compiled.as_text()
    rec["collectives"] = parse_collectives(hlo)
    rec["planner_drift"] = comm_drift(rec["comm_trace"], rec["collectives"])
    rec["status"] = "ok"
    return rec


def _prefill_cache_spec(server, cfg, topo):
    """out_specs for the prefill cache: sequence over sp, stacked leaves."""
    from repro.models.config import ATTN, MAMBA, RWKV, RWKVCM
    unit = cfg.unit()
    out = {}
    for p in range(unit):
        mixer = cfg.mixers()[p]
        d = {}
        if mixer == ATTN:
            d["k"] = P(None, topo.dp, topo.sp, None, None)
            d["v"] = P(None, topo.dp, topo.sp, None, None)
            if cfg.is_encoder_decoder:
                d["xk"] = P(None, topo.dp, topo.sp, None, None)
                d["xv"] = P(None, topo.dp, topo.sp, None, None)
        elif mixer == MAMBA:
            d["ssm"] = P(None, topo.dp, topo.tp, None)
            d["conv"] = P(None, topo.dp, None, topo.tp)
        elif mixer == RWKV:
            d["state"] = P(None, topo.dp, topo.tp, None, None)
            d["shift"] = P(None, topo.dp, None)
        if cfg.ffns()[p] == RWKVCM:
            d["cm_shift"] = P(None, topo.dp, None)
        out[f"p{p}"] = d
    return out


def run_probe(arch: str, shape_name: str, *, multi_pod: bool,
              cfg_transform=None, **lower_kw) -> dict:
    """Two-point cost probe: XLA's cost_analysis counts a scan body once
    (not x trip count), so lower the same cell with n_layers = 1 unit and
    2 units and extrapolate linearly:

        cost(L units) = c1 + (L - 1) * (c2 - c1)

    which captures every per-layer term (fwd scan, remat bwd scan, per-layer
    collectives) exactly, and constant terms (embed/CE/IO) in the intercept.
    """
    import dataclasses as dc
    from repro.models import layers as layers_mod
    cfg0 = configs.get(arch)
    if cfg_transform is not None:
        cfg0 = cfg_transform(cfg0)
    unit = cfg0.unit()
    n_units = cfg0.n_layers // unit
    probes = []
    layers_mod.COST_PROBE = True
    try:
        for k in (1, 2):
            cfg = dc.replace(cfg0, n_layers=unit * k,
                             n_enc_layers=min(k, cfg0.n_enc_layers)
                             if cfg0.is_encoder_decoder else 0)
            probes.append(_lower_cell_cfg(cfg, shape_name,
                                          multi_pod=multi_pod, **lower_kw))
    finally:
        layers_mod.COST_PROBE = False
    c1, c2 = probes

    def xp(a, b):
        return a + (n_units - 1) * (b - a)

    cost = {k: xp(c1["cost"].get(k, 0.0), c2["cost"].get(k, 0.0))
            for k in set(c1["cost"]) | set(c2["cost"])}
    # extrapolate collective bytes per (op, group)
    coll = {}
    ops = set(c1["collectives"]) | set(c2["collectives"])
    for op in ops:
        d1 = c1["collectives"].get(op, {"by_group": {}})
        d2 = c2["collectives"].get(op, {"by_group": {}})
        groups = set(d1["by_group"]) | set(d2["by_group"])
        by_group = {}
        for g in groups:
            b1 = d1["by_group"].get(g, {"bytes": 0, "count": 0})
            b2 = d2["by_group"].get(g, {"bytes": 0, "count": 0})
            by_group[g] = {"bytes": xp(b1["bytes"], b2["bytes"]),
                           "count": xp(b1["count"], b2["count"])}
        coll[op] = {"by_group": by_group,
                    "result_bytes": sum(v["bytes"] for v in by_group.values()),
                    "count": sum(v["count"] for v in by_group.values())}
    return {"cost_x": cost, "collectives_x": coll,
            "probe_raw": [{"cost": c1["cost"], "collectives": c1["collectives"]},
                          {"cost": c2["cost"], "collectives": c2["collectives"]}],
            "n_units": n_units}


def _lower_cell_cfg(cfg, shape_name: str, *, multi_pod: bool,
                    resident: bool = False,
                    cache_dtype: str = "bf16",
                    serve_bf16: bool = False,
                    lowp: int = 0) -> dict:
    from repro.models import layers as layers_mod
    if lowp:
        layers_mod.LOWP = int(lowp)
    try:
        return _lower_cell_cfg_inner(
            cfg, shape_name, multi_pod=multi_pod, resident=resident,
            cache_dtype=cache_dtype, serve_bf16=serve_bf16)
    finally:
        layers_mod.LOWP = 0


def _lower_cell_cfg_inner(cfg, shape_name: str, *, multi_pod: bool,
                          resident: bool = False,
                          cache_dtype: str = "bf16",
                          serve_bf16: bool = False) -> dict:
    """Lower+compile one cell for an explicit cfg; return cost+collectives."""
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if shape["kind"] == "train":
        topo = build_topology(cfg, mesh, global_batch=shape["batch"])
        tc = TrainConfig()
        step = make_train_step(cfg, topo, tc)
        lowered = step.lower(param_structs(cfg, topo),
                             opt_structs(cfg, topo, tc),
                             input_structs(cfg, topo, shape))
    elif shape["kind"] == "prefill":
        topo = build_topology(cfg, mesh, global_batch=shape["batch"])
        server = Server(cfg, topo, None)
        specs = param_specs(cfg, topo)
        bst = input_structs(cfg, topo, shape)
        bspecs = {k: P(topo.dp, *([None] * (len(v.shape) - 1)))
                  for k, v in bst.items()}
        fn = shard_map(server.prefill_shard, mesh=topo.cube.mesh,
                       in_specs=(specs, bspecs),
                       out_specs=(P(topo.dp, topo.tp),
                                  _prefill_cache_spec(server, cfg, topo)),
                       check_vma=False)
        lowered = jax.jit(fn).lower(param_structs(cfg, topo), bst)
    else:
        topo = build_serve_topology(cfg, mesh)
        plan = make_serve_plan(cfg, topo, S_ctx=shape["seq"],
                               global_batch=shape["batch"],
                               cache_dtype=cache_dtype)
        server = Server(cfg, topo, plan, resident=resident)
        specs = server.model.specs
        cspecs = cache_specs(cfg, topo, plan)
        ba = plan.batch_axes or None
        B = plan.global_batch
        tok = jax.ShapeDtypeStruct((B,), jnp.int32,
                                   sharding=topo.cube.sharding(P(ba)))
        pos = jax.ShapeDtypeStruct((B,), jnp.int32,
                                   sharding=topo.cube.sharding(P(ba)))
        fn = shard_map(server.decode_shard, mesh=topo.cube.mesh,
                       in_specs=(specs, cspecs, P(ba), P(ba)),
                       out_specs=(P(ba, topo.tp), cspecs), check_vma=False)
        def _dt(d):
            if serve_bf16 and d.dtype == jnp.float32:
                return jnp.bfloat16        # bf16-resident serve weights
            return d.dtype
        pstructs = jax.tree.map(
            lambda d, s: jax.ShapeDtypeStruct(
                d.shape, _dt(d), sharding=topo.cube.sharding(s)),
            param_defs_tree(cfg, topo), specs,
            is_leaf=lambda x: not isinstance(x, dict))
        lowered = jax.jit(fn, donate_argnums=(1,)).lower(
            pstructs, cache_structs(cfg, topo, plan), tok, pos)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    return {"cost": {k: float(cost[k]) for k in
                     ("flops", "bytes accessed", "transcendentals")
                     if k in cost},
            "collectives": parse_collectives(compiled.as_text())}


def param_defs_tree(cfg, topo):
    from repro.models.params import param_defs, ParamDef
    defs = param_defs(cfg, topo)
    return jax.tree.map(lambda d: d, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="add two-point cost probes to existing cell JSONs")
    ap.add_argument("--profile", default=None, metavar="PROFILE_JSON",
                    help="price comm_trace estimates from a tuned "
                         "CommProfile instead of the analytic constants "
                         "(cells record est_source='measured')")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    import contextlib
    profile_ctx = contextlib.nullcontext()
    if args.profile:
        from repro.core.planner import install_profile
        from repro.tuning import CommProfile
        # no cube check: the production mesh is a fake-device stand-in, so
        # fingerprint enforcement is the caller's call here
        profile_ctx = install_profile(CommProfile.load(args.profile))

    if args.probe:
        with profile_ctx:
            probe_pass(args)
        return

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = list(configs.ALIASES) if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.all else [args.multipod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    with profile_ctx:
        for arch, shape, mp in cells:
            tag = f"{arch}_{shape}_{'multipod' if mp else 'pod'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"== {tag}: cached")
                continue
            print(f"== {tag}")
            try:
                rec = run_cell(arch, shape, multi_pod=mp)
            except Exception as e:
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "error", "error": repr(e),
                       "trace": traceback.format_exc()[-4000:]}
                print(rec["trace"])
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"   -> {rec['status']}")


def probe_pass(args):
    """Add cost probes to already-recorded cells (skips skipped/errored)."""
    archs = list(configs.ALIASES) if not args.arch else [args.arch]
    shapes = list(SHAPES) if not args.shape else [args.shape]
    meshes = [False, True] if not args.arch or args.all else [args.multipod]
    if args.arch and not args.all:
        meshes = [args.multipod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multipod' if mp else 'pod'}"
                path = os.path.join(args.out, tag + ".json")
                if not os.path.exists(path):
                    continue
                rec = json.load(open(path))
                if rec.get("status") != "ok" or "cost_x" in rec:
                    continue
                print(f"== probe {tag}")
                try:
                    rec.update(run_probe(arch, shape, multi_pod=mp))
                except Exception as e:
                    rec["probe_error"] = repr(e)
                    print("   probe failed:", repr(e))
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
