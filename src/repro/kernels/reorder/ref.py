"""Pure-jnp oracle for the reorder kernel."""
import jax
import jax.numpy as jnp


def tile_swizzle(x: jax.Array, perm) -> jax.Array:
    perm = jnp.asarray(perm)
    G = perm.shape[0]
    rows, D = x.shape
    b = rows // G
    return jnp.take(x.reshape(G, b, D), perm, axis=0).reshape(rows, D)


def block_transpose(x: jax.Array, g1: int, g2: int) -> jax.Array:
    rows, D = x.shape
    b = rows // (g1 * g2)
    return jnp.swapaxes(x.reshape(g1, g2, b, D), 0, 1).reshape(rows, D)
