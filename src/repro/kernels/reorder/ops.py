"""Backend-dispatching wrapper: Pallas on TPU, jnp oracle elsewhere."""
import jax

from repro.kernels.reorder import ref
from repro.kernels.reorder import reorder as _k


def tile_swizzle(x, perm):
    if jax.default_backend() == "tpu":
        return _k.tile_swizzle(x, perm)
    return ref.tile_swizzle(x, perm)


def block_transpose(x, g1, g2):
    if jax.default_backend() == "tpu":
        return _k.block_transpose(x, g1, g2)
    return ref.block_transpose(x, g1, g2)
