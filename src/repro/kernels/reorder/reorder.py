"""PE-assisted reordering as a Pallas TPU kernel (paper §V-A1, adapted).

On UPMEM, PEs locally rotate their data in WRAM before the bus transfer so
the host's modulation becomes a register-local shuffle. On TPU the analogue
is a *tile swizzle executed in VMEM*: the (E, C, D) dispatch buffer is
re-laid-out into the destination-contiguous order the AlltoAll wants, one
(tile_rows x D) tile per grid step, with the permutation folded into the
grid's index_map via scalar prefetch -- the data never round-trips through
HBM in the wrong order (in-register modulation, §V-A2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(perm_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("n_blocks", "interpret"))
def tile_swizzle_p(x: jax.Array, perm: jax.Array, *, n_blocks: int,
                   interpret: bool = False) -> jax.Array:
    """Permute equal row-blocks of ``x``: out block i = in block perm[i].

    x: (G*b, D) viewed as G row-blocks of b rows; perm: (G,) int32, passed
    as a scalar-prefetch operand so the permutation drives the DMA schedule
    directly (one VMEM-resident tile copy per grid step, no gather op).
    """
    G = n_blocks
    rows, D = x.shape
    assert rows % G == 0, (rows, G)
    b = rows // G

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G,),
        in_specs=[pl.BlockSpec((b, D), lambda i, perm_ref: (perm_ref[i], 0))],
        out_specs=pl.BlockSpec((b, D), lambda i, perm_ref: (i, 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
        interpret=interpret,
    )(perm.astype(jnp.int32), x)


def tile_swizzle(x: jax.Array, perm, *, interpret: bool = False) -> jax.Array:
    perm = jnp.asarray(perm, jnp.int32)
    return tile_swizzle_p(x, perm, n_blocks=int(perm.shape[0]),
                          interpret=interpret)


def block_transpose(x: jax.Array, g1: int, g2: int, *,
                    interpret: bool = False) -> jax.Array:
    """(g1*g2*b, D) block-grid transpose: block (i, j) -> block (j, i).

    Exactly the local pre-reorder AlltoAll needs when a hypercube dim spans
    multiple entangled groups (paper Fig. 9)."""
    perm = tuple(int(i * g2 + j) for j in range(g2) for i in range(g1))
    return tile_swizzle(x, perm, interpret=interpret)
