"""Backend-dispatching wrapper: Pallas kernel on TPU, jnp oracle elsewhere."""
import jax

from repro.kernels.rwkv6 import ref
from repro.kernels.rwkv6.rwkv6 import rwkv6_chunked as _pallas


def rwkv6_chunked(r, k, v, logw, u, *, chunk=64):
    if jax.default_backend() == "tpu":
        return _pallas(r, k, v, logw, u, chunk=chunk)
    return ref.rwkv6_chunked(r, k, v, logw, u, chunk=chunk)
