"""RWKV6 (Finch) chunked linear attention as a Pallas TPU kernel.

The data-dependent-decay recurrence S_t = diag(w_t) S_{t-1} + k_t v_t^T is
evaluated in chunks: the (K x V) per-head matrix state lives in VMEM scratch
across the chunk grid dimension, and all intra-chunk work is (C x K)-(K x C)
MXU matmuls -- the TPU-native re-blocking of an inherently sequential GPU
kernel (hardware adaptation per DESIGN.md).

Grid: (B*H, n_chunks), chunk dim sequential (state carried in scratch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *,
                 chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)          # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (C, V)
    lw = lw_ref[0].astype(jnp.float32)        # (C, K) log decays (<= 0)
    u = u_ref[0].astype(jnp.float32)          # (1, K) bonus

    cum = jnp.cumsum(lw, axis=0)              # inclusive
    # cross-chunk: o_cross[t] = (r_t * prod_{i<t} w) @ S0
    qd = r * jnp.exp(cum - lw)
    o_cross = qd @ s_ref[...]
    # intra-chunk: A[t,s] = <r_t e^{cum_t - l_t}, k_s e^{-cum_s}> for s < t
    kd = k * jnp.exp(-cum)
    A = qd @ kd.T                             # (C, C)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(s_idx < t_idx, A, 0.0)
    diag = jnp.sum(r * u * k, axis=1)         # bonus, s == t
    o = o_cross + A @ v + diag[:, None] * v
    o_ref[0] = o.astype(o_ref.dtype)

    # state update: S <- diag(e^{tot}) S + sum_s e^{tot - cum_s} k_s v_s^T
    tot = cum[-1]
    kw = k * jnp.exp(tot[None] - cum)
    s_ref[...] = jnp.exp(tot)[:, None] * s_ref[...] + kw.T @ v


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_chunked(r, k, v, logw, u, *, chunk: int = 64,
                  interpret: bool = False):
    """r,k,v,logw: (B, S, H, K/V); u: (H, K). Returns (B, S, H, V)."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n = S // chunk

    def lay(x, d):
        return jnp.moveaxis(x, 2, 1).reshape(B * H, S, d)

    rr, kk, lww = lay(r, K), lay(k, K), lay(logw, K)
    vv = lay(v, V)
    uu = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, 1, K)

    kernel = functools.partial(_rwkv_kernel, chunk=chunk, n_chunks=n)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n),
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, K), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, V), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, V), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, lww, uu)
    return jnp.moveaxis(out.reshape(B, H, S, V), 1, 2)
