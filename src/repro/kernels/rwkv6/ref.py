"""Oracle for the rwkv6 kernel: the jnp chunked form in repro.models.ssm
(itself validated against the naive sequential recurrence)."""
from repro.models.ssm import rwkv6_chunked as _chunked


def rwkv6_chunked(r, k, v, logw, u, *, chunk=64):
    out, _ = _chunked(r, k, v, logw, u, chunk=chunk)
    return out
