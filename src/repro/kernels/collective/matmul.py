"""Matmul comm fusions: all-gather prologues and reduce-scatter epilogues.

The tensor-parallel blocks in ``models.blocks`` bracket every matmul with a
sequence all_gather (assemble activations) and a reduce_scatter (fold the
partial sums).  These wrappers push that movement *into* the compute via
the registered ring flows, so the bracketing arrays never materialize:

* :func:`all_gather_matmul` -- ``ag_prologue``: row-wise compute (norm +
  up-projection) runs per source block as the ring delivers it.
  Bit-identical to compute-after-gather because row-wise maps commute with
  sequence concatenation.
* :func:`matmul_reduce_scatter` -- ``rs_epilogue``: the output projection's
  partial product is produced one 1/G tile at a time inside the ring
  reduce-scatter, so peak activation drops by the group size.  The ring's
  summation order differs from the native psum-scatter: integer-valued
  fp32 payloads are bit-identical (the conformance contract); real-valued
  ones agree to documented tolerance.
"""
from __future__ import annotations

import math

import jax

from repro.kernels.collective.ring import dispatch_fused, take_block

__all__ = ["all_gather_matmul", "matmul_reduce_scatter"]


def all_gather_matmul(comm, x, *, axis: int, block_fn):
    """Fused gather-then-map: ``block_fn(all_gather(x, axis))`` with
    ``block_fn`` applied per delivered block.  ``block_fn`` must be
    row-wise along ``axis`` (rms_norm / matmuls over the trailing dim
    qualify) -- that is what makes the fusion bit-identical."""
    if comm.group_size == 1:
        return block_fn(x)
    return dispatch_fused(comm, "all_gather", "ag_prologue", x,
                          axis=axis, block_fn=block_fn)


def matmul_reduce_scatter(comm, h, w, *, axis: int, op: str = "add"):
    """Fused ``reduce_scatter(h @ w, axis)``: tile ``t`` of the partial
    product is computed on demand (``h[tile t] @ w``) inside the ring, so
    the full ``(..., L, n)`` partial sum is never live.  ``h``'s ``axis``
    length must divide by the group size (the reduce_scatter contract)."""
    g = comm.group_size
    if g == 1:
        return h @ w
    L = h.shape[axis]
    if L % g:
        raise ValueError(
            f"matmul_reduce_scatter: axis {axis} length {L} not divisible "
            f"by group size {g}")
    size = L // g

    def tile_fn(t):
        return take_block(h, t, size, axis=axis) @ w

    # the logical pre-scatter buffer (g tiles of h @ w) never exists; its
    # byte count is what the planner prices, so hand it over explicitly
    tile = jax.eval_shape(tile_fn, 0)
    payload = g * math.prod(tile.shape) * tile.dtype.itemsize
    return dispatch_fused(comm, "reduce_scatter", "rs_epilogue", h,
                          payload_bytes=payload, axis=axis, op=op,
                          tile_fn=tile_fn)
