"""Ring attention: sequence-parallel flash attention over a communicator.

Each shard keeps its query block resident and rotates its (k, v) block
around the group's ring via the registered ``ring_fused`` all_gather flow;
the flash kv-loop (``chunked_attention(..., partial=True)``) consumes each
block the hop it lands, and the per-hop partials merge online-softmax
style.  The full-sequence k/v (and the S x S score matrix) never
materialize on any shard -- per-shard attention memory stays
O(S_loc * S_loc) instead of O(S_loc * S_global).

This replaces the ``all_gather(h, axis=1)`` + full-sequence attention pair
in ``models.blocks.attn_block``'s context-parallel path when
``ModelConfig.fused_comm`` is set (or when ``algorithm="auto"`` prices
``ring_fused`` measured-cheaper).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.collective.ring import dispatch_fused
from repro.models.layers import NEG_INF, chunked_attention, pvary_like

__all__ = ["RING_ATTN_TOL", "ring_attention"]

# Documented accuracy budget vs the gather-then-attend oracle.  The per-hop
# partials are merged by online-softmax rescaling, which reorders the
# exp/sum against the single-pass softmax -- bit-identity is impossible by
# construction, so conformance asserts these absolute tolerances instead
# (tests/test_collective_kernels.py + the fused conformance cells).
RING_ATTN_TOL = {"float32": 2e-5, "bfloat16": 2e-2}


def ring_attention(comm, q, k, v, *, causal: bool = True, window=-1,
                   chunk: int = 1024):
    """Sequence-parallel attention over ``comm``'s ring.

    q: (B, S_loc, H, hd) -- this shard's query block; k, v:
    (B, S_loc, KV, hd) -- this shard's key/value block.  The global
    sequence is the concatenation of the shards' blocks in group order, so
    global positions are ``rank * S_loc + arange(S_loc)`` (the same
    convention as ``attn_block``'s context-parallel q_offset).

    Returns (B, S_loc, H, hd): this shard's rows of the full-sequence
    attention, within ``RING_ATTN_TOL[dtype]`` of the oracle.
    """
    B, S_loc, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if comm.group_size == 1:
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 chunk=chunk)
    q_off = comm.axis_index() * S_loc

    def consume(state, src, kv_block):
        kb, vb = kv_block
        acc, m, l = state
        acc_h, m_h, l_h = chunked_attention(
            q, kb, vb, causal=causal, window=window, q_offset=q_off,
            k_offset=src * S_loc, chunk=chunk, partial=True)
        m_new = jnp.maximum(m, m_h)
        c = jnp.exp(m - m_new)
        c_h = jnp.exp(m_h - m_new)
        return (acc * c[..., None] + acc_h * c_h[..., None],
                m_new,
                l * c + l_h * c_h)

    init = (
        pvary_like(jnp.zeros((B, KV, G, S_loc, hd), jnp.float32), q, k, v),
        pvary_like(jnp.full((B, KV, G, S_loc), NEG_INF, jnp.float32),
                   q, k, v),
        pvary_like(jnp.zeros((B, KV, G, S_loc), jnp.float32), q, k, v),
    )
    acc, m, l = dispatch_fused(comm, "all_gather", "ring_fused", (k, v),
                               axis=1, consume_fn=consume, init=init)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, S_loc, H, hd)
    return out.astype(q.dtype)
