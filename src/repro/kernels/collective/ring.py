"""Ring-rotation algorithm bodies for the collective-fused kernels.

Registered here, and only here: the CI import-surface grep pins every raw
``jax.lax`` use in ``kernels/collective`` to this module, so the ppermute
rings live inside registered algorithm bodies exactly like the core
``comm.py`` flows.

``ring_fused``   (all_gather)  one source block delivered per ppermute hop;
                 an optional ``consume_fn`` merges each block in flight
                 (ring attention's kv loop), so the gathered array never
                 materializes.  Without a consumer the body assembles the
                 gather -- pure movement, bit-identical to the direct flow.
``ag_prologue``  (all_gather)  ring gather with a per-block prologue map:
                 row-wise compute (norm / matmul) runs on each source block
                 as it arrives.  The identity map is a plain ring gather,
                 so the conformance cell is bit-identical.
``rs_epilogue``  (reduce_scatter)  ring reduce-scatter whose per-tile
                 contribution is produced on demand (``tile_fn``), fusing a
                 matmul epilogue: the full partial-sum activation never
                 materializes.  The ring's reduction order differs from the
                 native psum-scatter, so bit-identity holds exactly for
                 order-insensitive payloads (integer-valued fp32 -- the
                 conformance contract) and to documented tolerance
                 otherwise.

All three are ``stage="cm"`` / ``table_ii=False`` registry entries (the
§V-C ``compressed`` flow's precedent): fusing comm into compute is
cross-domain modulation in PID-Comm's taxonomy, but none of these widens
the paper's Table II applicability rows.  They dispatch like any other
registered algorithm (``comm.all_gather(x, axis=1,
algorithm="ring_fused")``), which is what lets the planner race them and
the microbench sweep price them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.comm import (
    CommEvent, _REDUCERS, _TRACES, _emit, _merge_front_blocks,
    _payload_bytes, _split_axis_to_front, get_algorithm, register_algorithm)

__all__ = ["dispatch_fused", "take_block"]


def take_block(x, t, size, *, axis):
    """Block ``t`` (length ``size``, possibly traced ``t``) of ``x`` along
    ``axis`` -- the lazy-tile helper the fused matmul wrappers use so they
    never touch ``jax.lax`` directly."""
    return lax.dynamic_slice_in_dim(x, t * size, size, axis=axis)


def _ring_deliveries(comm, block, consume, state):
    """Rotate ``block`` (any pytree) around the group's ring.  Every
    shard's block is delivered to every member exactly once: hop ``s``
    brings the block owned by shard ``(me - s) % g``.
    ``consume(state, src, block) -> state`` folds each delivery; hop 0 is
    the shard's own block, so compute on it overlaps the first transfer."""
    g, ax = comm.group_size, comm.ax
    me = lax.axis_index(ax)
    fwd = [(i, (i + 1) % g) for i in range(g)]
    cur = block
    state = consume(state, me, cur)
    for s in range(1, g):
        cur = jax.tree_util.tree_map(
            lambda a: lax.ppermute(a, ax, fwd), cur)
        state = consume(state, (me - s) % g, cur)
    return state


@register_algorithm("all_gather", "ring_fused", stage="cm", table_ii=False)
def _ag_ring_fused(comm, x, *, axis, consume_fn=None, init=None):
    """Ring all-gather.  With ``consume_fn`` (state, src, block) -> state,
    each delivered block is merged in flight from ``init`` and the merged
    state is returned -- the full gather never materializes (ring
    attention).  Without it, assembles the gathered array (bit-identical
    to the direct gather: pure movement)."""
    if consume_fn is not None:
        return _ring_deliveries(comm, x, consume_fn, init)
    g = comm.group_size

    def place(out, src, blk):
        return lax.dynamic_update_index_in_dim(out, blk, src, axis=0)

    out = _ring_deliveries(comm, x, place, jnp.zeros((g,) + x.shape, x.dtype))
    return _merge_front_blocks(out, axis)


@register_algorithm("all_gather", "ag_prologue", stage="cm", table_ii=False)
def _ag_prologue(comm, x, *, axis, block_fn=None):
    """Ring all-gather with a fused per-block prologue: ``block_fn`` maps
    each source block as it arrives, so row-wise downstream compute runs
    per hop instead of on the assembled array.  Because ``block_fn`` is
    row-wise, the assembled result is bit-identical to
    ``block_fn(all_gather(x))`` -- concatenation is exact."""
    g = comm.group_size
    if block_fn is None:
        block_fn = lambda b: b
    mapped = jax.eval_shape(block_fn, x)

    def place(out, src, blk):
        return lax.dynamic_update_index_in_dim(out, block_fn(blk), src,
                                               axis=0)

    out = _ring_deliveries(
        comm, x, place, jnp.zeros((g,) + mapped.shape, mapped.dtype))
    return _merge_front_blocks(out, axis)


@register_algorithm("reduce_scatter", "rs_epilogue", stage="cm",
                    table_ii=False)
def _rs_epilogue(comm, x, *, axis, op="add", tile_fn=None):
    """Ring reduce-scatter with lazily produced tiles: ``tile_fn(t)`` is
    this shard's contribution to output tile ``t`` (default: the ``t``-th
    block of ``x`` along ``axis``).  A matmul epilogue passes a ``tile_fn``
    that computes ``h[tile t] @ w`` on demand, so only one 1/G tile of the
    partial product is live per hop.

    Ring schedule (shifted so shard ``i`` finishes holding tile ``i``, the
    reduce_scatter placement contract): start from tile ``(me - 1) % g``;
    each of the ``g - 1`` hops forwards the running partial and folds in
    the local contribution to the tile just received."""
    g, ax = comm.group_size, comm.ax
    if tile_fn is None:
        blocks = _split_axis_to_front(x, axis, g)
        tile_fn = lambda t: lax.dynamic_index_in_dim(
            blocks, t, axis=0, keepdims=False)
    comb = _REDUCERS[op][2]
    me = lax.axis_index(ax)
    fwd = [(i, (i + 1) % g) for i in range(g)]
    cur = tile_fn((me - 1) % g)
    for s in range(g - 1):
        got = lax.ppermute(cur, ax, fwd)
        cur = comb(got, tile_fn((me - 2 - s) % g))
    return cur


def dispatch_fused(comm, primitive, flow, x, *, payload_bytes=None,
                   **kwargs):
    """Eagerly dispatch a compute-fused registry flow with the same
    planner-estimated :class:`~repro.core.comm.CommEvent` a plain dispatch
    emits (the ``all_reduce_with_error`` precedent: callable-carrying
    flows cannot be recorded into a CommProgram, so they always run
    eagerly).

    ``x`` may be a pytree (ring attention rotates the ``(k, v)`` pair);
    payload accounting sums the leaves unless ``payload_bytes`` overrides
    it (a lazy-tile epilogue's logical buffer never exists, so its bytes
    are supplied by the wrapper)."""
    spec = get_algorithm(primitive, flow)
    if payload_bytes is None:
        payload_bytes = sum(
            _payload_bytes(leaf) for leaf in jax.tree_util.tree_leaves(x))
    if _TRACES:
        from repro.core import planner
        est = planner.estimate(comm.cube, primitive, comm.dims,
                               payload_bytes, algorithm=flow)
        _emit(CommEvent(
            primitive=primitive, bitmap=comm.bitmap, dims=comm.dims,
            algorithm=flow, flow=flow, stage=spec.stage,
            group_size=comm.group_size, num_instances=comm.num_instances,
            payload_bytes=payload_bytes, ici_bytes=est.ici_bytes,
            dcn_bytes=est.dcn_bytes, seconds=est.seconds,
            est_source=est.est_source))
    return spec.fn(comm, x, **kwargs)
