"""Collective-fused kernels: comm woven through compute, registry-first.

PID-Comm's last-mile lesson is that collectives should run *where the data
lives* instead of bouncing a whole array through a mediator between
kernels.  This package is the repo's analogue for the jax_pallas
substrate: ring-rotation flows whose per-hop deliveries feed compute
directly, registered in the algorithm registry (``ring.py``) so they
dispatch, trace, microbench, and race under ``algorithm="auto"`` exactly
like the Table II stages.

Entry points:

* :func:`ring_attention` -- sequence-parallel flash attention; kv blocks
  rotate while the flash kv-loop consumes them (``ring_fused``).
* :func:`all_gather_matmul` -- per-block prologue compute fused onto a
  ring gather (``ag_prologue``; bit-identical).
* :func:`matmul_reduce_scatter` -- lazy-tile matmul epilogue fused onto a
  ring reduce-scatter (``rs_epilogue``; bit-identical on integer-valued
  fp32, documented tolerance otherwise).

``FUSED_ENTRIES`` is the accounting surface: the conformance meta-test
requires one sweep cell per entry, so deleting a fused sweep fails the
accounting the same way a missing Table II cell does.
"""
from repro.kernels.collective import ring as _ring  # registers the flows
from repro.kernels.collective.attention import RING_ATTN_TOL, ring_attention
from repro.kernels.collective.matmul import (all_gather_matmul,
                                             matmul_reduce_scatter)
from repro.kernels.collective.ring import dispatch_fused, take_block

# (primitive, registry name, bit_identical?) -- the registered fused flows.
# Conformance accounting in tests/test_conformance.py is keyed off this.
FUSED_ENTRIES = (
    ("all_gather", "ring_fused", True),       # pure movement w/o consumer
    ("all_gather", "ag_prologue", True),      # row-wise map commutes
    ("reduce_scatter", "rs_epilogue", False),  # ring sum order differs
)

__all__ = [
    "FUSED_ENTRIES", "RING_ATTN_TOL", "all_gather_matmul", "dispatch_fused",
    "matmul_reduce_scatter", "ring_attention", "take_block",
]
