"""Blockwise (flash) attention forward as a Pallas TPU kernel.

Grid: (batch*kv_heads*groups, n_q_blocks, n_kv_blocks), kv innermost so the
running max / denominator / accumulator live in VMEM scratch across kv steps
(the classic TPU flash schedule). Supports causal masking and sliding
windows (paper-relevant: mixtral SWA-4096, gemma3 local:global).

``q_offset``/``k_offset`` place the q/k blocks at global sequence positions
(causal/window masks compare global positions), which is what lets the
kernel attend one *shard-local* q block against one rotated kv block of the
ring-attention schedule (``repro.kernels.collective``): each ppermute hop
calls the kernel on the delivered block at its source offset and
LSE-merges the partial outputs.

Block shapes are MXU-aligned: (block_q, head_dim) x (block_k, head_dim)
matmuls with block_q = block_k = 128 by default (head_dim 64..256 are all
multiples of the 128-lane register tile in the minor dim after padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, causal: bool, window: int,
                  q_offset: int, k_offset: int, scale: float, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = q @ k.T                                       # (bq, bk)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = k_offset + ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    ok = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _out():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_offset", "k_offset",
                              "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = -1,
                    q_offset: int = 0, k_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd), H % KV == 0.

    GQA folded into the grid: head h uses kv head h * KV // G.
    ``q_offset``/``k_offset`` are the static global positions of q[0]/k[0]
    (ring-attention hops, context-parallel prefill blocks); masks compare
    global positions, so an off-diagonal (q block, kv block) pair masks
    exactly as its slice of the full-sequence kernel would.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_q, n_kv = Sq // block_q, Sk // block_k

    # layout: (B*H, Sq, hd) for q/o; (B*KV, Sk, hd) for k/v
    qr = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, hd)
    kr = jnp.moveaxis(k, 2, 1).reshape(B * KV, Sk, hd)
    vr = jnp.moveaxis(v, 2, 1).reshape(B * KV, Sk, hd)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        window=int(window), q_offset=int(q_offset), k_offset=int(k_offset),
        scale=hd ** -0.5, n_kv=n_kv)

    def kv_index(b, i, j):
        # b = batch * H + h  ->  kv row = batch * KV + h // G
        return ((b // H) * KV + (b % H) // G, j, 0)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return jnp.moveaxis(out.reshape(B, H, Sq, hd), 1, 2)
