"""Backend-dispatching wrapper: Pallas kernel on TPU, jnp oracle elsewhere."""
import jax

from repro.kernels.attention import ref
from repro.kernels.attention.flash import flash_attention as _pallas


def flash_attention(q, k, v, *, causal=True, window=-1, q_offset=0,
                    k_offset=0):
    if jax.default_backend() == "tpu":
        return _pallas(q, k, v, causal=causal, window=window,
                       q_offset=q_offset, k_offset=k_offset)
    return ref.flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, k_offset=k_offset)
