"""Backend-dispatching wrapper: Pallas kernel on TPU, jnp oracle elsewhere."""
import jax

from repro.kernels.attention import ref
from repro.kernels.attention.flash import flash_attention as _pallas


def flash_attention(q, k, v, *, causal=True, window=-1):
    if jax.default_backend() == "tpu":
        return _pallas(q, k, v, causal=causal, window=window)
    return ref.flash_attention(q, k, v, causal=causal, window=window)
