"""Oracle for the flash kernel: the pure-jnp blockwise implementation in
repro.models.layers (itself validated against the naive O(S^2) form)."""
from repro.models.layers import chunked_attention, reference_attention


def flash_attention(q, k, v, *, causal=True, window=-1, q_offset=0,
                    k_offset=0):
    return chunked_attention(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, k_offset=k_offset)
