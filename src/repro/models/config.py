"""Unified model configuration for the 10 assigned architectures.

One ``ModelConfig`` drives every family (dense / MoE / VLM / audio / SSM /
hybrid). Layers are described by a ``layer_plan``: a per-layer (mixer, ffn)
spec plus a per-layer attention-window array. Layers are grouped into the
smallest repeating *unit* with identical parameter structure so the model can
``lax.scan`` over stacked unit parameters (keeps HLO small and compile time
bounded for 72-layer 398B configs).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

# mixer kinds
ATTN = "attn"
MAMBA = "mamba"
RWKV = "rwkv"
# ffn kinds
DENSE = "dense"
MOE = "moe"
RWKVCM = "rwkvcm"   # RWKV channel-mix (receptance-gated 2-matrix FFN)
NONE = "none"

FULL_WINDOW = -1  # sentinel: full (global) attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # attention details
    rope_theta: float = 1e4
    qk_norm: bool = False
    window: int = FULL_WINDOW                  # default per-layer window
    local_global_ratio: int = 0                # gemma3: N local per 1 global
    local_window: int = 1024

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_period: int = 1            # MoE every `period` layers (jamba: 2)
    capacity_factor: float = 1.25
    moe_dispatch: str = "scatter"  # "scatter" (baseline) | "sort" (PR-style)

    # SSM / hybrid
    mixer_pattern: str = ""        # e.g. "mmmmAmmm" repeated; "" -> all attn
    d_state: int = 16
    mamba_expand: int = 2
    conv_kernel: int = 4
    rwkv_head_dim: int = 64

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0

    # multimodal stub frontends
    frontend: str = ""             # "" | "patch" | "audio"
    frontend_tokens: int = 1024    # patches prepended (vlm)
    frontend_dim: int = 0          # raw embedding dim fed by input_specs

    # norms / misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # ---- parallelism (single-pod model-axis decomposition; data fills rest)
    tp: int = 16                   # attention/FFN tensor-parallel degree
    ep: int = 1                    # expert-parallel degree (divides tp*etp)
    etp: int = 1                   # per-expert tensor parallel
    serve_tp: int = 0              # cap on decode-time TP (0 = whole pod);
                                   # RWKV needs whole heads per shard
    fused_comm: bool = False       # route attn_block/dense_ffn through the
                                   # collective-fused kernels (ring attention
                                   # over cp, matmul gather-prologues /
                                   # scatter-epilogues over tp)

    # long-context capability marker (sub-quadratic attention memory)
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))
        if self.n_experts and self.d_ff_expert == 0:
            object.__setattr__(self, "d_ff_expert", self.d_ff)

    # ------------------------------------------------------------ structure
    @property
    def model_parallel(self) -> int:
        """Total model-axis extent (= tp for dense; ep*etp for MoE)."""
        return self.ep * self.etp if self.n_experts else self.tp

    @property
    def n_experts_padded(self) -> int:
        if not self.n_experts:
            return 0
        return int(math.ceil(self.n_experts / self.ep) * self.ep)

    def mixers(self) -> list[str]:
        """Per-layer mixer kinds."""
        if not self.mixer_pattern:
            return [ATTN] * self.n_layers
        pat = self.mixer_pattern
        reps = int(math.ceil(self.n_layers / len(pat)))
        full = (pat * reps)[: self.n_layers]
        return [{"A": ATTN, "m": MAMBA, "r": RWKV}[c] for c in full]

    def ffns(self) -> list[str]:
        """Per-layer FFN kinds."""
        mixers = self.mixers()
        out = []
        for i in range(self.n_layers):
            if mixers[i] == RWKV:
                out.append(RWKVCM)
            elif self.n_experts and (i % self.moe_period == self.moe_period - 1):
                out.append(MOE)
            else:
                out.append(DENSE)
        return out

    def windows(self) -> np.ndarray:
        """Per-layer attention windows (-1 = full)."""
        w = np.full(self.n_layers, self.window, dtype=np.int32)
        if self.local_global_ratio:
            r = self.local_global_ratio
            for i in range(self.n_layers):
                w[i] = FULL_WINDOW if (i % (r + 1)) == r else self.local_window
        return w

    def unit(self) -> int:
        """Smallest repeating (mixer, ffn) unit length that divides n_layers.

        Windows are data (passed as scan xs), so they do not affect the unit.
        """
        plan = list(zip(self.mixers(), self.ffns()))
        for p in range(1, self.n_layers + 1):
            if self.n_layers % p:
                continue
            if all(plan[i] == plan[i % p] for i in range(self.n_layers)):
                return p
        return self.n_layers

    # -------------------------------------------------------------- scaling
    def scaled_for_smoke(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests (single device)."""
        unit = self.unit()
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2 * unit),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=96,
            d_ff_expert=96 if self.n_experts else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            n_enc_layers=min(self.n_enc_layers, 2),
            frontend_tokens=8 if self.frontend else 0,
            frontend_dim=32 if self.frontend else 0,
            local_window=8,
            window=8 if self.window != FULL_WINDOW else FULL_WINDOW,
            rwkv_head_dim=16,
            tp=1, ep=1, etp=1,
        )

    # ------------------------------------------------------------ accounting
    def param_count(self) -> int:
        """Exact parameter count (embeddings included)."""
        D, H, KV, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        total = self.vocab_size * D  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * D
        d_in = self.mamba_expand * D

        for mixer, ffn in zip(self.mixers(), self.ffns()):
            if mixer == ATTN:
                total += D * H * hd + 2 * D * KV * hd + H * hd * D
                if self.qk_norm:
                    total += 2 * hd
            elif mixer == MAMBA:
                total += D * 2 * d_in          # in_proj
                total += d_in * self.conv_kernel  # depthwise conv
                total += d_in * (2 * self.d_state + 1)  # x_proj(B,C) + dt
                total += d_in * self.d_state + d_in     # A_log, D
                total += d_in * D              # out_proj
            elif mixer == RWKV:
                total += 5 * D * D             # r,k,v,g,out
                total += 2 * D                 # decay base, bonus u
            if ffn == DENSE:
                total += 3 * D * self.d_ff
            elif ffn == RWKVCM:
                total += D * D + 2 * D * self.d_ff   # receptance + k/v
            elif ffn == MOE:
                total += self.n_experts * 3 * D * self.d_ff_expert
                total += D * self.n_experts    # router
                if self.n_shared_experts:
                    total += self.n_shared_experts * 3 * D * self.d_ff_expert
            total += 2 * D                     # two norms per layer
        if self.is_encoder_decoder:
            # encoder layers (attn + dense ffn) + cross-attention in decoder
            enc = self.n_enc_layers * (
                D * H * hd + 2 * D * KV * hd + H * hd * D + 3 * D * self.d_ff + 2 * D)
            cross = self.n_layers * (D * H * hd + 2 * D * KV * hd + H * hd * D + D)
            total += enc + cross
        if self.frontend:
            total += (self.frontend_dim or D) * D
        total += D  # final norm
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        n_moe_layers = sum(1 for f in self.ffns() if f == MOE)
        unused = (self.n_experts - self.top_k) * 3 * self.d_model * self.d_ff_expert
        return int(full - n_moe_layers * unused)
