"""The unified model: embedding -> scanned unit stack -> vocab-parallel loss,
plus prefill / flash-decode serving paths. All per-shard (manual SPMD) code;
callers wrap entry points in shard_map over ``topo.cube.mesh``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import compat
from repro.models import blocks, layers
from repro.models.config import (
    ModelConfig, ATTN, MAMBA, RWKV, DENSE, MOE, RWKVCM, FULL_WINDOW)
from repro.models.layers import rms_norm
from repro.models.params import (
    param_defs, param_specs, vocab_padded, COMPUTE_DTYPE, ParamDef)
from repro.models.topology import Topology

Array = jax.Array
AUX_COEF = 0.01
CE_CHUNK = 512


class Model:
    def __init__(self, cfg: ModelConfig, topo: Topology,
                 resident: bool = False):
        """``resident``: serve-time weights replicated over the data axis
        (no per-step FSDP regather; see params.drop_axis)."""
        self.cfg = cfg
        self.topo = topo
        self.specs = param_specs(cfg, topo)
        if resident:
            from repro.models.params import drop_axis
            self.specs = drop_axis(self.specs)
        self.unit = cfg.unit()
        self.n_units = cfg.n_layers // self.unit
        self.mixers = cfg.mixers()[: self.unit]
        self.ffns = cfg.ffns()[: self.unit]
        # per-position window: static if identical across units, else traced
        wins = cfg.windows().reshape(self.n_units, self.unit)
        self.static_window = [
            int(wins[0, p]) if (wins[:, p] == wins[0, p]).all() else None
            for p in range(self.unit)]
        self.window_xs = {
            f"p{p}": jnp.asarray(wins[:, p])
            for p in range(self.unit) if self.static_window[p] is None}
        # per-position specs without the unit-stack dim (for FSDP gather)
        self.unit_specs = {
            pos: {k: jax.sharding.PartitionSpec(*tuple(s)[1:])
                  for k, s in self.specs["units"][pos].items()}
            for pos in self.specs["units"]}
        if cfg.is_encoder_decoder:
            self.enc_specs = {
                k: jax.sharding.PartitionSpec(*tuple(s)[1:])
                for k, s in self.specs["enc_units"]["p0"].items()}

    # ------------------------------------------------------------ embedding
    def _gather_embed(self, params):
        emb = params["embed"].astype(COMPUTE_DTYPE)
        spec = tuple(self.specs["embed"])
        if "data" in spec:
            emb = self.topo.comm(("data",)).all_gather(
                emb, axis=spec.index("data"))
        return emb

    def _embed_tokens(self, emb_l, tokens):
        """Vocab-parallel lookup -> partial (B, S, D) (needs psum over tp)."""
        Vl = emb_l.shape[0]
        me = lax.axis_index(self.topo.tp)
        ids = tokens - me * Vl
        valid = (ids >= 0) & (ids < Vl)
        x = jnp.take(emb_l, jnp.clip(ids, 0, Vl - 1), axis=0)
        return jnp.where(valid[..., None], x, 0)

    def _to_sp(self, x_partial):
        """Partial-over-tp full-seq (B,S,D) -> sequence-sharded (B,S_sp,D)."""
        topo = self.topo
        if topo.cp:
            S_cp = x_partial.shape[1] // topo.size(topo.cp)
            me = lax.axis_index(topo.cp)
            x_partial = lax.dynamic_slice_in_dim(x_partial, me * S_cp, S_cp, 1)
        return topo.comm(topo.tp).reduce_scatter(x_partial, axis=1)

    def _slice_sp(self, x_full):
        """Replicated full-seq -> my sp chunk (no reduction)."""
        topo = self.topo
        S_sp = x_full.shape[1] // topo.size(topo.sp)
        me = lax.axis_index(topo.sp)
        return lax.dynamic_slice_in_dim(x_full, me * S_sp, S_sp, axis=1)

    def embed_input(self, params, batch):
        """-> x_sp (B, S_sp, D) for the decoder/self stack."""
        cfg, topo = self.cfg, self.topo
        emb_l = self._gather_embed(params)
        x = self._embed_tokens(emb_l, batch["tokens"])
        if cfg.frontend == "patch":
            wf = blocks.gather_params(
                {"w": params["frontend_proj"]},
                {"w": self.specs["frontend_proj"]}, topo)["w"]
            patches = (batch["patches"].astype(COMPUTE_DTYPE) @ wf)
            F = patches.shape[1]
            me = lax.axis_index(topo.tp)
            patch_part = jnp.where(me == 0, patches, 0)
            x = x.at[:, :F].set(patch_part.astype(x.dtype))
        return self._to_sp(x)

    # ------------------------------------------------------------ the trunk
    def _position_fn(self, x_sp, w_shards, window, *, p, enc_out=None):
        """One layer (mixer + ffn) at unit position ``p``, from sharded
        params. Checkpointed individually so the backward working set is one
        layer's gathered weights + activations (not a whole unit's)."""
        cfg, topo = self.cfg, self.topo
        key = f"p{p}"
        w = blocks.gather_params(w_shards, self.unit_specs[key], topo)
        aux = jnp.zeros((), jnp.float32)
        mixer = self.mixers[p]
        if mixer == ATTN:
            x_sp = blocks.attn_block(cfg, topo, w, x_sp, window=window)
            if enc_out is not None:
                x_sp = blocks.attn_block(cfg, topo, w, x_sp,
                                         window=FULL_WINDOW,
                                         cross_src=enc_out, prefix="x")
        elif mixer == MAMBA:
            x_sp = blocks.mamba_mix(cfg, topo, w, x_sp)
        elif mixer == RWKV:
            x_sp = blocks.rwkv_mix(cfg, topo, w, x_sp)
        ffn = self.ffns[p]
        if ffn == DENSE:
            x_sp = blocks.dense_ffn(cfg, topo, w, x_sp)
        elif ffn == MOE:
            x_sp, a = blocks.moe_ffn(cfg, topo, w, x_sp)
            aux = aux + a
        elif ffn == RWKVCM:
            x_sp = blocks.rwkv_channel_mix(cfg, topo, w, x_sp)
        return x_sp, aux

    def _unit_fn(self, x_sp, xs, *, enc_out=None, remat=False):
        """Apply one unit (``self.unit`` layers). xs: per-position params
        (+ traced windows). Returns (x_sp, aux)."""
        aux = jnp.zeros((), jnp.float32)
        for p in range(self.unit):
            key = f"p{p}"
            window = self.static_window[p]
            if window is None:
                # traced per-layer window (gemma local:global pattern)
                def f(x, ws, win, _p=p):
                    return self._position_fn(x, ws, win, p=_p,
                                             enc_out=enc_out)
                args = (x_sp, xs[key], xs["windows"][key])
            else:
                # static window stays static through the checkpoint wrapper
                def f(x, ws, _p=p, _w=window):
                    return self._position_fn(x, ws, _w, p=_p,
                                             enc_out=enc_out)
                args = (x_sp, xs[key])
            if remat:
                f = jax.checkpoint(f)
            x_sp, a = f(*args)
            aux = aux + a
        return x_sp, aux

    def trunk(self, params, x_sp, *, enc_out=None, remat=True):
        """Scan the unit stack. Returns (x_sp, total_aux)."""
        xs = dict(params["units"])
        if self.window_xs:
            xs["windows"] = self.window_xs

        def body(carry, xs_slice):
            return self._unit_fn(carry, xs_slice, enc_out=enc_out,
                                 remat=remat)

        x_sp, auxs = layers.pscan(body, x_sp, xs)
        return x_sp, auxs.sum()

    def encode(self, params, frames):
        """Whisper encoder. frames: (B, S_enc, fdim). Returns full (B,S,D)."""
        cfg, topo = self.cfg, self.topo
        wf = blocks.gather_params(
            {"w": params["frontend_proj"]},
            {"w": self.specs["frontend_proj"]}, topo)["w"]
        x = frames.astype(COMPUTE_DTYPE) @ wf                  # replicated
        x_sp = self._slice_sp(x)

        def body(carry, xs_slice):
            w = blocks.gather_params(xs_slice, self.enc_specs, topo)
            x = blocks.attn_block(cfg, topo, w, carry, window=FULL_WINDOW,
                                  causal=False)
            x = blocks.dense_ffn(cfg, topo, w, x)
            return x, None

        body = jax.checkpoint(body)
        x_sp, _ = layers.pscan(body, x_sp, params["enc_units"]["p0"])
        full = topo.comm(topo.sp).all_gather(x_sp, axis=1)
        fn = blocks.gather_params(
            {"n": params["enc_final_norm"]},
            {"n": self.specs["enc_final_norm"]}, topo)["n"]
        return rms_norm(full, fn, cfg.norm_eps)

    # ------------------------------------------------------------- the loss
    def _head(self, params):
        topo = self.topo
        if self.cfg.tie_embeddings:
            return self._gather_embed(params).T                # (D, Vl)
        return blocks.gather_params(
            {"h": params["lm_head"]}, {"h": self.specs["lm_head"]}, topo)["h"]

    def loss_shard(self, params, batch):
        """Per-shard training loss (scalar, replicated). batch["tokens"],
        batch["labels"]: (B_l, S); labels < 0 are masked out."""
        cfg, topo = self.cfg, self.topo
        assert not topo.cp, "context parallelism is an inference-only path"
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = self.encode(params, batch["frames"])
        x_sp = self.embed_input(params, batch)
        x_sp, aux = self.trunk(params, x_sp, enc_out=enc_out)
        full = topo.comm(topo.sp).all_gather(x_sp, axis=1)
        fn = blocks.gather_params(
            {"n": params["final_norm"]}, {"n": self.specs["final_norm"]},
            topo)["n"]
        hn = rms_norm(full, fn, cfg.norm_eps)
        head = self._head(params)
        labels = batch["labels"]
        if cfg.frontend == "patch":
            F = cfg.frontend_tokens
            pos_ids = jnp.arange(labels.shape[1])[None]
            labels = jnp.where(pos_ids < F, -1, labels)

        Vl = head.shape[1]
        lo = lax.axis_index(topo.tp) * Vl
        B, S, D = hn.shape
        nck = layers.probe_trips(max(S // min(CE_CHUNK, S), 1))
        Ck = S // nck

        @jax.checkpoint  # recompute the (B,Ck,Vl) logits chunk in bwd
        def ce(carry, i):
            tot, cnt = carry
            hc = lax.dynamic_slice_in_dim(hn, i * Ck, Ck, axis=1)
            lc = lax.dynamic_slice_in_dim(labels, i * Ck, Ck, axis=1)
            logits = (hc @ head).astype(jnp.float32)           # (B,Ck,Vl)
            m = topo.comm(topo.tp).all_reduce(
                lax.stop_gradient(logits.max(-1)), op="max")
            se = compat.replicated_psum(
                jnp.exp(logits - m[..., None]).sum(-1), topo.tp)
            lse = jnp.log(se) + m
            ids = lc - lo
            ok = (ids >= 0) & (ids < Vl)
            tl = jnp.take_along_axis(
                logits, jnp.clip(ids, 0, Vl - 1)[..., None], axis=-1)[..., 0]
            tl = compat.replicated_psum(jnp.where(ok, tl, 0.0), topo.tp)
            msk = (lc >= 0).astype(jnp.float32)
            tot = tot + ((lse - tl) * msk).sum()
            cnt = cnt + msk.sum()
            return (tot, cnt), None

        zero = layers.pvary_axes(jnp.zeros(()), topo.dp)
        (tot, cnt), _ = layers.pscan(ce, (zero, zero + 0.0), jnp.arange(nck))
        tot = compat.replicated_psum(layers.pvary_axes(tot, topo.dp),
                                     topo.dp)
        cnt = compat.replicated_psum(layers.pvary_axes(cnt, topo.dp),
                                     topo.dp)
        loss = tot / jnp.maximum(cnt, 1.0)
        aux = layers.pvary_axes(aux, topo.dp + topo.tp)
        aux_all = compat.replicated_psum(aux, topo.dp + topo.tp) / (
            topo.dp_size * topo.tp_size)
        metrics = {"ce_loss": loss, "aux_loss": aux_all, "tokens": cnt}
        return loss + AUX_COEF * aux_all, metrics

    def forward_logits(self, params, batch):
        """Full-sequence logits (tests / tiny eval). Returns (B, S, Vl)."""
        cfg, topo = self.cfg, self.topo
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = self.encode(params, batch["frames"])
        x_sp = self.embed_input(params, batch)
        x_sp, _ = self.trunk(params, x_sp, enc_out=enc_out, remat=False)
        full = topo.comm(topo.sp).all_gather(x_sp, axis=1)
        fn = blocks.gather_params(
            {"n": params["final_norm"]}, {"n": self.specs["final_norm"]},
            topo)["n"]
        hn = rms_norm(full, fn, cfg.norm_eps)
        return (hn @ self._head(params)).astype(jnp.float32)
