"""Per-shard layer math shared by every architecture family.

``chunked_attention`` is the pure-jnp flash-attention formulation (blockwise
log-sum-exp accumulation). It doubles as the oracle for the Pallas kernel in
``repro.kernels.attention`` and keeps the dry-run's peak memory honest (no
S x S score materialization in the HLO).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat

Array = jax.Array

NEG_INF = -1e30

# Cost-probe mode (see launch/dryrun.py run_probe): XLA cost_analysis counts
# a while-loop body once regardless of trip count, so probe lowerings unroll
# every scan (and cap inner-chunk trip counts at 4 -- identical FLOPs).
COST_PROBE = False

# Low-precision-stats mode (§Perf variants): 0 = off; 1 = bf16 operands with
# f32 dot accumulation ("lowp"); 2 = additionally keep the attention
# score/probability space in bf16, f32 only for the running max/denominator
# ("lowp2" -- what the fused Pallas kernel does in VMEM on real TPU).
LOWP = 0


def pscan(f, init, xs, unroll_hint: int = 1):
    return lax.scan(f, init, xs, unroll=True if COST_PROBE else unroll_hint)


def probe_trips(n: int) -> int:
    """Cap sequential trips in probe mode (FLOPs-preserving re-chunk)."""
    return min(n, 4) if COST_PROBE else n


def pvary_like(x, *refs):
    """Promote ``x``'s varying-axes (shard_map vma) to the union of the
    refs' -- needed for scan carries initialized from constants. No-op on
    pre-vma jax (compat.HAS_VMA False), where nothing is tracked."""
    want = frozenset()
    for r in refs:
        want = want | compat.vma_of(r)
    need = tuple(sorted(want - compat.vma_of(x)))
    return compat.pvary(x, need) if need else x


def pvary_axes(x, axes):
    """Mark ``x`` as varying over ``axes`` (no-op outside shard_map/vma)."""
    need = tuple(a for a in axes if a not in compat.vma_of(x))
    if not need:
        return x
    try:
        return compat.pvary(x, need)
    except Exception:
        return x


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    if LOWP >= 1 and dt == jnp.bfloat16:
        # f32 only in the reduction; the (.., D) tensor never converts
        var = jnp.mean(jnp.square(x).astype(jnp.float32), axis=-1,
                       keepdims=True)
        r = lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
        return x * r.astype(dt)
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(dt)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding. x: (..., S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: Array, wg: Array, wu: Array, wd: Array) -> Array:
    h = jax.nn.silu(x @ wg) * (x @ wu)
    return h @ wd


def _mask(q_pos: Array, k_pos: Array, causal: bool, window) -> Array:
    """(Sq, Sk) boolean visibility mask. window: python int or traced scalar;
    negative = full attention. Negative key positions (banded-path padding)
    are never visible."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = dk >= 0
    if causal:
        ok &= dk <= dq
    w = jnp.asarray(window)
    ok &= jnp.where(w < 0, True, (dq - dk) < w)
    return ok


def chunked_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                      window=-1, q_offset=0, k_offset=0,
                      chunk: int = 1024, partial: bool = False):
    """Blockwise (flash) attention with GQA, sliding window, offsets.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) with H % KV == 0.
    ``q_offset``/``k_offset`` are the global positions of q[0]/k[0] (ints or
    traced scalars) -- used by context-parallel prefill and decode.

    Static sliding windows on aligned self-attention take the *banded* path:
    each query block only visits the (window + block) keys it can see,
    cutting attention FLOPs/bytes by ~Sk/(window+block) (mixtral SWA-4096 at
    32k prefill: ~6.4x).

    Returns (B, Sq, H, hd); if ``partial``, returns (acc, m, l) unnormalized
    so callers can LSE-combine partial results across shards (flash-decode).
    """
    if (isinstance(window, int) and window > 0 and causal and not partial
            and q.shape[1] == k.shape[1] and q.shape[1] > window
            and isinstance(q_offset, int) and q_offset == 0
            and isinstance(k_offset, int) and k_offset == 0):
        return banded_attention(q, k, v, window=window, chunk=chunk)
    return _chunked_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, k_offset=k_offset,
                              chunk=chunk, partial=partial)


def banded_attention(q: Array, k: Array, v: Array, *, window: int,
                     chunk: int = 1024):
    """Causal sliding-window attention visiting only the in-band keys.

    Scans over query blocks; each block attends to a static-size
    (window_pad + block) key slice ending at its last position.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    Cq = min(chunk, S)
    nq = S // Cq              # vmapped (batched), not scanned: probe-exact
    W = min(window, S)
    # pad keys on the left so every block's band is a static-size slice
    Wp = ((W - 1) // Cq + 1) * Cq                   # band rounded to blocks
    band = Wp + Cq
    kp = jnp.pad(k, ((0, 0), (Wp, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (Wp, 0), (0, 0), (0, 0)))
    qb = q.reshape(B, nq, Cq, H, hd)

    def block(qi, i):
        # keys for block i: global positions [i*Cq - Wp, i*Cq + Cq)
        kb = lax.dynamic_slice_in_dim(kp, i * Cq, band, axis=1)
        vb = lax.dynamic_slice_in_dim(vp, i * Cq, band, axis=1)
        o = _chunked_attention(
            qi, kb, vb, causal=True, window=window,
            q_offset=i * Cq, k_offset=i * Cq - Wp, chunk=band)
        return o

    outs = jax.vmap(block, in_axes=(1, 0), out_axes=1)(
        qb, jnp.arange(nq))
    return outs.reshape(B, S, H, hd)


def _chunked_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                       window=-1, q_offset=0, k_offset=0,
                       chunk: int = 1024, partial: bool = False):
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5
    nc = probe_trips(max(Sk // min(chunk, Sk), 1))
    C = Sk // nc
    if LOWP >= 1 and q.dtype == jnp.bfloat16:
        # bf16 operands, f32 accumulation inside the dots -- no (B,S,..)
        # converts / f32 spills of q,k,v
        qf = (q * scale).reshape(B, Sq, KV, G, hd)
        kc = k.reshape(B, nc, C, KV, hd)
        vc = v.reshape(B, nc, C, KV, hd)
    else:
        qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, G, hd)
        kc = k.astype(jnp.float32).reshape(B, nc, C, KV, hd)
        vc = v.astype(jnp.float32).reshape(B, nc, C, KV, hd)
    q_pos = q_offset + jnp.arange(Sq)

    bf16_scores = LOWP >= 2 and q.dtype == jnp.bfloat16

    def step(carry, inp):
        acc, m, l = carry
        ci, kb, vb = inp
        k_pos = k_offset + ci * C + jnp.arange(C)
        if bf16_scores:
            # score/probability space stays bf16 (as the fused TPU kernel
            # keeps it in VMEM); only m/l/acc accumulate in f32
            s = jnp.einsum("bqkgh,bckh->bkgqc", qf, kb)          # bf16
            msk = _mask(q_pos, k_pos, causal, window)
            s = jnp.where(msk[None, None, None], s,
                          jnp.bfloat16(NEG_INF))
            m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
            p = jnp.exp(s - m_new[..., None].astype(jnp.bfloat16))
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1, dtype=jnp.float32)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckh->bkgqh", p, vb,
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new), None
        s = jnp.einsum("bqkgh,bckh->bkgqc", qf, kb,
                       preferred_element_type=jnp.float32)      # scores
        msk = _mask(q_pos, k_pos, causal, window)               # (Sq, C)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))                  # (B,KV,G,Sq)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckh->bkgqh", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new), None

    acc0 = pvary_like(jnp.zeros((B, KV, G, Sq, hd), jnp.float32), qf, kc, vc)
    m0 = pvary_like(jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32),
                    qf, kc, vc)
    l0 = pvary_like(jnp.zeros((B, KV, G, Sq), jnp.float32), qf, kc, vc)
    idx = jnp.arange(nc)
    kb = jnp.moveaxis(kc, 1, 0)
    vb = jnp.moveaxis(vc, 1, 0)
    (acc, m, l), _ = pscan(step, (acc0, m0, l0), (idx, kb, vb))
    if partial:
        return acc, m, l
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd)         # (B,Sq,KV,G,hd)
    return out.astype(q.dtype)


def finish_partial_attention(acc, m, l, *, comm, B, Sq, H, hd, dtype):
    """LSE-combine ``partial=True`` results across the shards of ``comm``
    (a :class:`repro.core.comm.Communicator` bound to the flash-decode
    axes)."""
    m_max = comm.all_reduce(m, op="max")
    w = jnp.exp(m - m_max)
    acc = comm.all_reduce(acc * w[..., None])
    l = comm.all_reduce(l * w)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd)
    return out.astype(dtype)


def reference_attention(q, k, v, *, causal=True, window=-1, q_offset=0,
                        k_offset=0):
    """Naive O(S^2)-memory oracle (tests only)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, hd) * hd ** -0.5
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k.astype(jnp.float32))
    msk = _mask(q_offset + jnp.arange(Sq), k_offset + jnp.arange(Sk),
                causal, window)
    s = jnp.where(msk[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd).astype(q.dtype)
