"""Per-(architecture x workload) virtual hypercube construction.

This is PID-Comm's user-facing flexibility (paper §IV, Fig. 20) doing real
work: each architecture re-views the fixed physical mesh as its own logical
hypercube --

  dense   : (pod) x data x tp
  moe     : (pod) x data x ep x etp        (attention TP = ep*etp)
  prefill with batch < data capacity: (pod) x data x cp x tp
            (cp = context/sequence parallelism over query chunks)

All model collectives go through topology-bound
:class:`repro.core.comm.Communicator` handles (``topo.comm(axes)``), so
every transfer is planned, dispatched through the algorithm registry, and
observable via :class:`repro.core.comm.CommTrace`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.collectives import Collectives
from repro.core.comm import Communicator
from repro.core.hypercube import Hypercube
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Topology:
    cube: Hypercube
    dp: tuple[str, ...]      # batch axes, e.g. ("pod", "data")
    fsdp: tuple[str, ...]    # param-shard axes, e.g. ("data",)
    tp: tuple[str, ...]      # attention/FFN tensor-parallel axes
    cp: tuple[str, ...]      # context-parallel axes (may be empty)
    ep: tuple[str, ...]      # expert-parallel axes (may be empty)
    etp: tuple[str, ...]     # per-expert TP axes (may be empty)
    # Default dispatch mode of every bound communicator: "auto" = the
    # planner's pick at trace time; a Table II stage name ("naive", ...)
    # turns the knob for end-to-end application ablations (Fig. 15/16).
    comm_algorithm: str = "auto"
    _comms: dict = dataclasses.field(
        default_factory=dict, compare=False, repr=False)

    def comm(self, dims) -> Communicator:
        """The cached communicator bound to ``dims`` (axis names, a bitmap,
        or a single name), defaulting to this topology's algorithm knob."""
        key = (self.comm_algorithm, self.cube.resolve_dims(dims))
        got = self._comms.get(key)
        if got is None:
            got = self._comms[key] = self.cube.comm(
                key[1], algorithm=self.comm_algorithm)
        return got

    def program(self, *, name: str = ""):
        """Deferred CommProgram recording scope over this topology's cube:
        inside it, every ``topo.comm(axes)`` primitive appends to the
        program (multi-communicator mixes record into one schedule)."""
        return self.cube.program(name=name)

    @property
    def col(self) -> Collectives:
        """Deprecated per-call shim, constructed lazily on first access
        (emits the shim's DeprecationWarning)."""
        got = self._comms.get("__shim__")
        if got is None:
            got = self._comms["__shim__"] = Collectives(self.cube)
        return got

    def size(self, axes: tuple[str, ...]) -> int:
        return int(np.prod([self.cube.size(a) for a in axes])) if axes else 1

    @property
    def sp(self) -> tuple[str, ...]:
        """Sequence-parallel axes: activations between blocks are sharded
        along sequence over cp+tp (Megatron-SP generalized)."""
        return self.cp + self.tp

    @property
    def tp_size(self) -> int:
        return self.size(self.tp)

    @property
    def dp_size(self) -> int:
        return self.size(self.dp)

    @property
    def kv_sharded(self) -> bool:
        return False  # set in build()


def build_topology(cfg: ModelConfig, mesh, *, global_batch: int = 0,
                   decode: bool = False) -> Topology:
    """Derive the logical hypercube for this config on a physical mesh.

    ``global_batch`` (if given) bounds the data-parallel degree; leftover
    intra-pod parallelism becomes context parallelism (cp) for prefill
    workloads whose batch is too small to fill the data axis.
    """
    phys = dict(zip(mesh.axis_names, mesh.devices.shape))
    pods = phys.get("pod", 1)
    per_pod = int(np.prod(mesh.devices.shape)) // pods
    mp = cfg.model_parallel
    if per_pod % mp:
        raise ValueError(f"{cfg.name}: model parallel {mp} does not divide "
                         f"pod size {per_pod}")
    data = per_pod // mp
    cp = 1
    if global_batch:
        batch_per_pod = max(global_batch // pods, 1)
        if batch_per_pod < data:
            # shrink data to the batch; surplus becomes context parallelism
            cp = data // batch_per_pod
            data = batch_per_pod

    dims: dict[str, int] = {}
    if pods > 1:
        dims["pod"] = pods
    dims["data"] = data
    if cp > 1:
        dims["cp"] = cp
    if cfg.n_experts:
        dims["ep"] = cfg.ep
        dims["etp"] = cfg.etp
        tp_axes = tuple(a for a in ("ep", "etp") if dims[a] >= 1)
        ep_axes, etp_axes = ("ep",), ("etp",)
    else:
        dims["tp"] = cfg.tp
        tp_axes, ep_axes, etp_axes = ("tp",), (), ()

    cube = Hypercube.build(mesh, dims)
    return Topology(
        cube=cube,
        dp=(("pod",) if pods > 1 else ()) + ("data",),
        fsdp=("data",),
        tp=tp_axes,
        cp=("cp",) if cp > 1 else (),
        ep=ep_axes,
        etp=etp_axes,
    )


def build_serve_topology(cfg: ModelConfig, mesh) -> Topology:
    """Decode topology: maximal model sharding, batch replicated within a pod
    (weights fully resident -- no per-token FSDP regather), KV caches
    sequence-sharded over the model axes (flash-decode).

    The ``data`` axis survives with size 1 (or the head-parallel remainder
    for RWKV) so parameter specs stay identical to training.
    """
    phys = dict(zip(mesh.axis_names, mesh.devices.shape))
    pods = phys.get("pod", 1)
    per_pod = int(np.prod(mesh.devices.shape)) // pods

    dims: dict[str, int] = {}
    if pods > 1:
        dims["pod"] = pods
    if cfg.n_experts:
        ep = min(cfg.n_experts_padded, per_pod)
        etp = per_pod // ep
        dims.update(data=1, ep=ep, etp=etp)
        tp_axes, ep_axes, etp_axes = ("ep", "etp"), ("ep",), ("etp",)
    else:
        tp = per_pod
        if cfg.serve_tp:
            tp = min(tp, cfg.serve_tp)
        dims.update(data=per_pod // tp, tp=tp)
        tp_axes, ep_axes, etp_axes = ("tp",), (), ()

    cube = Hypercube.build(mesh, dims)
    return Topology(
        cube=cube,
        dp=(("pod",) if pods > 1 else ()) + ("data",),
        fsdp=("data",),
        tp=tp_axes,
        cp=(),
        ep=ep_axes,
        etp=etp_axes,
    )
