from repro.models.config import ModelConfig
from repro.models.topology import (
    Topology, build_topology, build_serve_topology)
from repro.models.lm import Model
from repro.models.serving import Server, ServePlan, make_serve_plan

__all__ = ["ModelConfig", "Topology", "build_topology",
           "build_serve_topology", "Model", "Server", "ServePlan",
           "make_serve_plan"]
