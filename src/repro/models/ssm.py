"""Sequence-mixing recurrences: RWKV6 ("Finch", data-dependent decay linear
attention with per-head matrix state) and Mamba selective SSM.

Both are implemented in chunked form -- O(S/C) sequential chunk steps with
parallel intra-chunk math -- which is the TPU-native adaptation of the
recurrences (MXU-friendly matmuls inside chunks, tiny carried state). These
functions are the oracles for the Pallas kernels in ``repro.kernels.rwkv6``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import pvary_like, pscan, probe_trips

Array = jax.Array


# ----------------------------------------------------------------- RWKV6
def rwkv6_chunked(r: Array, k: Array, v: Array, logw: Array, u: Array,
                  state: Array | None = None, chunk: int = 64):
    """RWKV6 time-mix recurrence, chunked.

    r, k, v: (B, S, H, K) / logw: (B, S, H, K) with logw = -exp(w_dd) <= 0
    (per-channel log decay); u: (H, K) bonus.
    state: (B, H, K, V) or None.

    Per step: o_t = (S_{t-1} + (u*k_t) v_t^T)^T r_t ; S_t = diag(w_t) S_{t-1}
    + k_t v_t^T. Returns (out (B,S,H,V), final_state).
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    C = min(chunk, S)
    n = probe_trips(S // C)
    C = S // n
    assert n * C == S, (S, C)
    rf = r.astype(jnp.float32).reshape(B, n, C, H, K)
    kf = k.astype(jnp.float32).reshape(B, n, C, H, K)
    vf = v.astype(jnp.float32).reshape(B, n, C, H, V)
    lw = logw.astype(jnp.float32).reshape(B, n, C, H, K)
    uf = u.astype(jnp.float32)

    if state is None:
        state = jnp.zeros((B, H, K, V), jnp.float32)
    state = pvary_like(state, rf, kf, vf, lw)

    @jax.checkpoint  # chunk internals are O(C^2 + C*K*V): recompute in bwd
    def step(S0, inp):
        rc, kc, vc, lc = inp                       # (B, C, H, *)
        cum = jnp.cumsum(lc, axis=1)               # inclusive logs
        # decay from chunk start up to *before* t: prod_{i<t} w_i
        dec_in = jnp.exp(cum - lc)                 # (B,C,H,K)
        # cross-chunk: o_cross[t] = (r_t * dec_in[t]) @ S0
        o_cross = jnp.einsum("bchk,bhkv->bchv", rc * dec_in, S0)
        # intra-chunk: A[t,s] = sum_k r_t[k] * w(s+1..t-? ) ...
        #   key s contributes to query t>s with decay prod_{i=s+1..t-1? }
        # recurrence applies decay before add: S_t = w_t*S_{t-1} + k_t v_t^T,
        # o_t reads S_{t-1} + u*k_t v_t^T
        #   => key s (s<t) reaches t with prod_{i=s+1..t-1} w_i ... times w_?:
        # S_{t-1} = sum_{s<=t-1} (prod_{i=s+1..t-1} w_i) k_s v_s^T
        # decay(s,t) = exp(cum[t-1] - cum[s]) = exp((cum[t]-l[t]) - cum[s])
        qd = rc * jnp.exp(cum - lc)                # r_t * exp(cum[t]-l[t])
        kd = kf_div = kc * jnp.exp(-cum)           # k_s * exp(-cum[s])
        A = jnp.einsum("bchk,bshk->bhcs", qd, kd)  # (B,H,C,C): s<t part
        tri = jnp.tril(jnp.ones((C, C), bool), k=-1)
        A = jnp.where(tri[None, None], A, 0.0)
        diag = jnp.einsum("bchk,hk,bchk->bch", rc, uf, kc)  # s == t bonus
        o_intra = jnp.einsum("bhcs,bshv->bchv", A, vc)
        o_intra += diag[..., None] * vc
        # state update: S' = diag(exp(cum[C-1])) S0 + sum_s exp(cum[C-1]-cum[s]) k_s v_s^T
        tot = cum[:, -1]                           # (B,H,K)
        S1 = jnp.exp(tot)[..., None] * S0 + jnp.einsum(
            "bshk,bshv->bhkv", kc * jnp.exp(tot[:, None] - cum), vc)
        return S1, o_cross + o_intra

    xs = (jnp.moveaxis(rf, 1, 0), jnp.moveaxis(kf, 1, 0),
          jnp.moveaxis(vf, 1, 0), jnp.moveaxis(lw, 1, 0))
    state, out = pscan(step, state, xs)
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, V)
    return out.astype(r.dtype), state


def rwkv6_step(r, k, v, logw, u, state):
    """Single-token decode. r,k,v,logw: (B,H,K); state: (B,H,K,V)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    kv = kf[..., :, None] * vf[..., None, :]              # (B,H,K,V)
    o = jnp.einsum("bhk,bhkv->bhv",
                   rf, state + u[None, :, :, None].astype(jnp.float32) * kv)
    new_state = w[..., None] * state + kv
    return o.astype(r.dtype), new_state


def rwkv6_reference(r, k, v, logw, u, state=None):
    """Naive sequential oracle (tests only)."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    if state is None:
        state = jnp.zeros((B, H, K, V), jnp.float32)
    outs = []
    for t in range(S):
        o, state = rwkv6_step(r[:, t], k[:, t], v[:, t], logw[:, t], u, state)
        outs.append(o)
    return jnp.stack(outs, axis=1), state


# ----------------------------------------------------------------- Mamba
def mamba_scan_chunked(u: Array, delta: Array, A: Array, Bm: Array, Cm: Array,
                       state: Array | None = None, chunk: int = 32):
    """Selective SSM: h_t = exp(delta_t A) h_{t-1} + delta_t B_t u_t;
    y_t = C_t . h_t.

    u, delta: (B, S, Din); A: (Din, N); Bm, Cm: (B, S, N).
    Chunked: within-chunk associative scan, sequential chunk carry.
    Returns (y (B,S,Din), final_state (B,Din,N)).
    """
    B, S, Din = u.shape
    N = A.shape[-1]
    C = min(chunk, S)
    n = probe_trips(S // C)
    C = S // n
    assert n * C == S, (S, C)
    uf = u.astype(jnp.float32).reshape(B, n, C, Din)
    df = delta.astype(jnp.float32).reshape(B, n, C, Din)
    Bf = Bm.astype(jnp.float32).reshape(B, n, C, N)
    Cf = Cm.astype(jnp.float32).reshape(B, n, C, N)
    Af = A.astype(jnp.float32)

    if state is None:
        state = jnp.zeros((B, Din, N), jnp.float32)
    state = pvary_like(state, uf, df, Bf, Cf)

    @jax.checkpoint  # da/db/aa/bb are O(C*Din*N) fp32: recompute in bwd,
    def step(h0, inp):  # keeping only the (B,Din,N) chunk carry
        uc, dc, bc, cc = inp                        # (B, C, *)
        da = jnp.exp(dc[..., None] * Af)            # (B,C,Din,N)
        db = dc[..., None] * bc[:, :, None, :] * uc[..., None]  # (B,C,Din,N)

        def comb(x, y):
            a1, b1 = x
            a2, b2 = y
            return a2 * a1, a2 * b1 + b2
        aa, bb = lax.associative_scan(comb, (da, db), axis=1)
        h = aa * h0[:, None] + bb                   # (B,C,Din,N)
        y = jnp.einsum("bcdn,bcn->bcd", h, cc)
        return h[:, -1], y

    xs = (jnp.moveaxis(uf, 1, 0), jnp.moveaxis(df, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    state, ys = pscan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, Din)
    return y.astype(u.dtype), state


def mamba_step(u, delta, A, Bm, Cm, state):
    """Single-token decode. u, delta: (B, Din); Bm, Cm: (B, N)."""
    da = jnp.exp(delta.astype(jnp.float32)[..., None] * A.astype(jnp.float32))
    db = (delta.astype(jnp.float32)[..., None] * Bm.astype(jnp.float32)[:, None, :]
          * u.astype(jnp.float32)[..., None])
    h = da * state + db
    y = jnp.einsum("bdn,bn->bd", h, Cm.astype(jnp.float32))
    return y.astype(u.dtype), h


def causal_conv1d(x: Array, w: Array, b: Array,
                  carry: Array | None = None):
    """Depthwise causal conv along seq. x: (B, S, D); w: (K, D); b: (D,).

    carry: (B, K-1, D) previous-token tail for decode; returns (y, new_tail).
    """
    B, S, D = x.shape
    K = w.shape[0]
    if carry is None:
        carry = jnp.zeros((B, K - 1, D), x.dtype)
    carry = pvary_like(carry, x)
    xp = jnp.concatenate([carry, x], axis=1)        # (B, S+K-1, D)
    y = jnp.zeros((B, S, D), jnp.float32)
    for i in range(K):
        y = y + xp[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    return y.astype(x.dtype), xp[:, -(K - 1):] if K > 1 else carry
