"""Sharded transformer / SSM / MoE blocks (manual SPMD, per-shard code).

Every cross-device transfer in these blocks goes through a topology-bound
:class:`repro.core.comm.Communicator` (``topo.comm(axes)``) --
AllGather/ReduceScatter implement Megatron-style sequence-parallel tensor
parallelism, AlltoAll implements expert-parallel MoE dispatch, and additive/
max all-reduces implement flash-decode LSE combines. Dispatch defaults to
``algorithm="auto"`` (the planner's pick at trace time); the
``topo.comm_algorithm`` knob swaps every collective onto the paper's
``naive`` (host-mediated analogue) flows for end-to-end application
ablations (paper Fig. 15/16), and a :class:`repro.core.comm.CommTrace`
observes every dispatched transfer.

Training-path activations are sequence-sharded over ``topo.sp`` between
blocks; decode-path activations are replicated over the model axes with the
KV cache sequence-sharded (flash-decode).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.hypercube import Hypercube
from repro.models import ssm
from repro.models.config import ModelConfig, FULL_WINDOW
from repro.models.layers import (
    rms_norm, rope, chunked_attention, NEG_INF)
from repro.models.params import kv_is_sharded, dt_rank, COMPUTE_DTYPE
from repro.models.topology import Topology

Array = jax.Array


# ------------------------------------------------------------- param gather
def gather_params(w: dict, specs: dict, topo: Topology) -> dict:
    """FSDP: bf16-cast then AllGather each leaf over the ``data`` axis.

    Casting *before* the gather halves FSDP traffic (fp32 master, bf16 wire).
    The AllGather's autodiff transpose reduce-scatters gradients back to the
    ZeRO shards.
    """
    out = {}
    for k, v in w.items():
        spec = tuple(specs[k])
        v = v.astype(COMPUTE_DTYPE)
        if "data" in spec:
            axis = spec.index("data")
            v = topo.comm(("data",)).all_gather(v, axis=axis)
        out[k] = v
    return out


def _tp_rank(topo: Topology) -> Array:
    return lax.axis_index(topo.tp)


# ---------------------------------------------------------------- attention
def _split_qkv(cfg: ModelConfig, topo: Topology, hn_q, hn_kv, w, prefix=""):
    """Project and reshape q/k/v with GQA head bookkeeping.

    Returns q: (B,Sq,Hl,hd), k,v: (B,Sk,KVl,hd), group count handled inside
    chunked_attention via shapes.
    """
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    t = topo.tp_size
    Hl = H // t
    q = (hn_q @ w[prefix + "wq"])
    B, Sq, _ = q.shape
    q = q.reshape(B, Sq, Hl, hd)
    # wkv columns are laid out (KV, 2, hd) -- whole kv heads stay contiguous
    # so column-sharding over tp slices whole (k,v) head pairs.
    kvp = hn_kv @ w[prefix + "wkv"]
    Sk = kvp.shape[1]
    if kv_is_sharded(cfg, topo):
        KVl = KV // t
        kv = kvp.reshape(B, Sk, KVl, 2, hd)
        k, v = kv[:, :, :, 0], kv[:, :, :, 1]
    else:
        kv = kvp.reshape(B, Sk, KV, 2, hd)
        kf, vf = kv[:, :, :, 0], kv[:, :, :, 1]
        G = H // KV
        me = _tp_rank(topo)
        if Hl >= G:
            cnt = Hl // G
            lo = me * cnt
        else:
            cnt = 1
            lo = (me * Hl) // G
        k = lax.dynamic_slice_in_dim(kf, lo, cnt, axis=2)
        v = lax.dynamic_slice_in_dim(vf, lo, cnt, axis=2)
    return q, k, v


def attn_block(cfg: ModelConfig, topo: Topology, w: dict, x_sp: Array, *,
               window, causal=True, cross_src: Array | None = None,
               prefix: str = "", out_cache: bool = False):
    """Sequence-parallel attention block. x_sp: (B, S_sp, D).

    cross_src: encoder output (B, S_enc, D) full -- used as KV source for
    cross-attention (whisper decoder). Returns new x_sp (and optionally the
    full-seq K/V for prefill caching).

    ``cfg.fused_comm`` reroutes the collectives through
    ``repro.kernels.collective``: the tp gather fuses the pre-attention
    norm into its ring (bit-identical), the context-parallel full-sequence
    gather is replaced by ring attention (kv blocks rotate over the cp
    ring, within the documented tolerance), and the out-projection's
    reduce_scatter becomes a lazy-tile matmul epilogue.
    """
    tpc = topo.comm(topo.tp)
    fused = getattr(cfg, "fused_comm", False) and cross_src is None \
        and not out_cache
    if fused:
        from repro.kernels.collective import (
            all_gather_matmul, matmul_reduce_scatter, ring_attention)
        # gather seq over tp with the norm fused into the ring; the cp
        # gather disappears entirely -- k/v stay chunk-local and rotate
        hn = all_gather_matmul(
            tpc, x_sp, axis=1,
            block_fn=lambda b: rms_norm(b, w[prefix + "ln"], cfg.norm_eps))
        kv_src = hn                                           # (B, S_cp, D)
    else:
        # gather seq over tp (within the cp chunk)
        h = tpc.all_gather(x_sp, axis=1)                      # (B, S_cp, D)
        hn = rms_norm(h, w[prefix + "ln"], cfg.norm_eps)
        if cross_src is not None:
            kv_src = cross_src
            causal = False
            window = FULL_WINDOW
        elif topo.cp:
            full = topo.comm(topo.cp).all_gather(h, axis=1)   # (B, S, D)
            kv_src = rms_norm(full, w[prefix + "ln"], cfg.norm_eps)
        else:
            kv_src = hn
    q, k, v = _split_qkv(cfg, topo, hn, kv_src, w, prefix)
    B, Sq = q.shape[:2]
    if cfg.qk_norm and not prefix:
        q = rms_norm(q, w["q_norm"], cfg.norm_eps)
        k = rms_norm(k, w["k_norm"], cfg.norm_eps)
    q_off = 0
    if topo.cp:
        q_off = lax.axis_index(topo.cp) * Sq
    if cross_src is None:
        q = rope(q, q_off + jnp.arange(Sq), cfg.rope_theta)
        # fused: k is this shard's chunk, so its positions carry the same
        # global offset as q; unfused: k is the assembled sequence from 0
        k_off = q_off if fused else 0
        k = rope(k, k_off + jnp.arange(k.shape[1]), cfg.rope_theta)
    if fused and topo.cp:
        o = ring_attention(topo.comm(topo.cp), q, k, v,
                           causal=causal, window=window)
    else:
        o = chunked_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_off)
    o = o.reshape(B, Sq, -1)
    if fused:
        out = matmul_reduce_scatter(tpc, o, w[prefix + "wo"], axis=1)
    else:
        out = o @ w[prefix + "wo"]                 # partial over tp
        out = tpc.reduce_scatter(out, axis=1)
    y = x_sp + out
    if out_cache:
        # cache layout: sequence-sharded over sp, local kv heads
        sp_n = topo.size(topo.sp)
        S_loc = k.shape[1] // sp_n
        me = lax.axis_index(topo.sp)
        k_c = lax.dynamic_slice_in_dim(k, me * S_loc, S_loc, axis=1)
        v_c = lax.dynamic_slice_in_dim(v, me * S_loc, S_loc, axis=1)
        return y, (k_c, v_c)
    return y


def attn_decode(cfg: ModelConfig, topo: Topology, w: dict, x: Array,
                c: dict, pos: Array, *,
                window, kv_axes, rolling: bool, prefix: str = "",
                cross: bool = False, keys=("k", "v")):
    """Flash-decode one token. x: (B, D) replicated over model axes.

    c[keys[0]]/c[keys[1]]: (B, S_loc, KVc, hd) cache, sequence-sharded over
    ``kv_axes``; optional c[key+"_s"] per-(slot, head) scales mark an int8
    cache (8-bit cross-domain modulation, paper §V-C, applied to KV).
    pos: (B,) int32 per-request positions. ``rolling``: cache length <
    context (sliding window), slot = pos % S_cache.
    Returns (out (B, D), updated cache dict).
    """
    kk, vk = keys
    cache_k, cache_v = c[kk], c[vk]
    int8_cache = (kk + "_s") in c
    tpc = topo.comm(topo.tp)
    kvc = topo.comm(kv_axes)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    B = x.shape[0]
    hn = rms_norm(x[:, None], w[prefix + "ln"], cfg.norm_eps)  # (B,1,D)
    t = topo.tp_size

    # q: local columns -> gather flat then reshape (supports tp > heads)
    q = hn @ w[prefix + "wq"]                                  # (B,1,cols)
    q = tpc.all_gather(q, axis=2).reshape(B, 1, H, hd)
    if not cross:
        kvp = hn @ w[prefix + "wkv"]
        if kv_is_sharded(cfg, topo):
            kvp = tpc.all_gather(kvp, axis=2)
        kvp = kvp.reshape(B, 1, KV, 2, hd)
        k_new, v_new = kvp[:, 0, :, 0], kvp[:, 0, :, 1]        # (B,KV,hd)
        if cfg.qk_norm and not prefix:
            q = rms_norm(q, w["q_norm"], cfg.norm_eps)
            k_new = rms_norm(k_new, w["k_norm"], cfg.norm_eps)
        q = _rope_decode(q, pos, cfg.rope_theta)
        k_new = _rope_decode(k_new[:, None], pos, cfg.rope_theta)[:, 0]

        # write into my cache chunk
        n_shards = topo.size(kv_axes)
        S_loc = cache_k.shape[1]
        S_cache = S_loc * n_shards
        my_lo = lax.axis_index(kv_axes) * S_loc
        slot = (pos % S_cache) if rolling else pos             # (B,)
        loc = slot - my_lo
        in_rng = (loc >= 0) & (loc < S_loc)
        idx = jnp.clip(loc, 0, S_loc - 1)
        bidx = jnp.arange(B)
        if int8_cache:
            ks = jnp.maximum(jnp.abs(k_new).max(-1), 1e-6) / 127.0
            vs = jnp.maximum(jnp.abs(v_new).max(-1), 1e-6) / 127.0
            k_q = jnp.round(k_new / ks[..., None]).astype(jnp.int8)
            v_q = jnp.round(v_new / vs[..., None]).astype(jnp.int8)
            c[kk + "_s"] = c[kk + "_s"].at[bidx, idx].set(
                jnp.where(in_rng[:, None], ks.astype(jnp.float32),
                          c[kk + "_s"][bidx, idx]))
            c[vk + "_s"] = c[vk + "_s"].at[bidx, idx].set(
                jnp.where(in_rng[:, None], vs.astype(jnp.float32),
                          c[vk + "_s"][bidx, idx]))
            k_new, v_new = k_q, v_q
        upd_k = jnp.where(in_rng[:, None, None],
                          k_new.astype(cache_k.dtype), cache_k[bidx, idx])
        upd_v = jnp.where(in_rng[:, None, None],
                          v_new.astype(cache_v.dtype), cache_v[bidx, idx])
        cache_k = cache_k.at[bidx, idx].set(upd_k)
        cache_v = cache_v.at[bidx, idx].set(upd_v)
        # key positions of my slots
        slots = my_lo + jnp.arange(S_loc)                      # (S_loc,)
        if rolling:
            k_pos = pos[:, None] - (pos[:, None] - slots[None]) % S_cache
        else:
            k_pos = jnp.broadcast_to(slots[None], (B, S_loc))
    else:
        # cross-attention: cache holds precomputed encoder K/V, all valid
        k_pos = jnp.broadcast_to(
            jnp.arange(cache_k.shape[1])[None], (B, cache_k.shape[1]))
        my_lo = 0

    # partial attention over my chunk (all heads), LSE-combined over shards
    G = H // cache_k.shape[2]
    qf = q.reshape(B, H, hd).astype(jnp.float32) * hd ** -0.5
    kf = cache_k.astype(jnp.float32)
    if int8_cache:
        kf = kf * c[kk + "_s"][..., None]
    s = _decode_scores(qf, kf, G)
    if cross:
        ok = jnp.ones_like(s, bool)
    else:
        dq = pos[:, None, None]
        dk = k_pos[:, None, :]
        ok = (dk <= dq) & (dk >= 0)
        wnd = jnp.asarray(window)
        ok &= jnp.where(wnd < 0, True, (dq - dk) < wnd)
    s = jnp.where(ok, s, NEG_INF)
    m = s.max(axis=-1)                                         # (B,H)
    m_all = kvc.all_reduce(m, op="max")
    p = jnp.exp(s - m_all[..., None])
    l = kvc.all_reduce(p.sum(-1))
    vf = cache_v.astype(jnp.float32)
    if int8_cache:
        vf = vf * c[vk + "_s"][..., None]
    o = _decode_out(p, vf, G)                                  # (B,H,hd)
    o = kvc.all_reduce(o) / jnp.maximum(l, 1e-30)[..., None]

    # out projection: my slice of the flattened head dim (wo row shard)
    me = _tp_rank(topo)
    rows = (H * hd) // t
    o_flat = o.reshape(B, H * hd).astype(COMPUTE_DTYPE)
    o_loc = lax.dynamic_slice_in_dim(o_flat, me * rows, rows, axis=1)
    out = o_loc @ w[prefix + "wo"]
    out = tpc.all_reduce(out)
    c = dict(c)
    c[kk], c[vk] = cache_k, cache_v
    return x + out.astype(x.dtype), c


def _rope_decode(q, pos, theta):
    """q: (B, 1, H, hd), per-row positions (B,)."""
    B = q.shape[0]
    return rope(q.reshape(B, 1, -1, q.shape[-1]), pos[:, None], theta)


def _decode_scores(qf, kf, G):
    """qf: (B,H,hd); kf: (B,S,KVc,hd) -> scores (B,H,S) with GQA groups."""
    B, H, hd = qf.shape
    KVc = kf.shape[2]
    q_g = qf.reshape(B, KVc, G, hd)
    return jnp.einsum("bkgd,bskd->bkgs", q_g, kf).reshape(B, H, -1)


def _decode_out(p, vf, G):
    B, H, S = p.shape
    KVc = vf.shape[2]
    p_g = p.reshape(B, KVc, G, S)
    o = jnp.einsum("bkgs,bskd->bkgd", p_g, vf)
    return o.reshape(B, H, -1)


# --------------------------------------------------------------------- FFNs
def dense_ffn(cfg, topo, w, x_sp, keys=("fln", "wg", "wu", "wd")):
    tpc = topo.comm(topo.tp)
    ln, wg, wu, wd = (w[k] for k in keys)
    if getattr(cfg, "fused_comm", False):
        from repro.kernels.collective import (
            all_gather_matmul, matmul_reduce_scatter)

        def up(b):
            bn = rms_norm(b, ln, cfg.norm_eps)
            return jax.nn.silu(bn @ wg) * (bn @ wu)

        # norm + up-projection fused into the gather ring (row-wise, so
        # bit-identical); the down-projection's partial sum is scattered
        # tile-by-tile without ever materializing (B, S_cp, D) in full
        h_act = all_gather_matmul(tpc, x_sp, axis=1, block_fn=up)
        out = matmul_reduce_scatter(tpc, h_act, wd, axis=1)
        return x_sp + out
    h = tpc.all_gather(x_sp, axis=1)
    hn = rms_norm(h, ln, cfg.norm_eps)
    out = (jax.nn.silu(hn @ wg) * (hn @ wu)) @ wd
    out = tpc.reduce_scatter(out, axis=1)
    return x_sp + out


def dense_ffn_decode(cfg, topo, w, x, keys=("fln", "wg", "wu", "wd")):
    ln, wg, wu, wd = (w[k] for k in keys)
    hn = rms_norm(x, ln, cfg.norm_eps)
    out = (jax.nn.silu(hn @ wg) * (hn @ wu)) @ wd
    return x + topo.comm(topo.tp).all_reduce(out).astype(x.dtype)


def _route(cfg, hn2d, router):
    """Top-k routing. hn2d: (T, D). Returns (topi, topv) (T, k)."""
    logits = hn2d @ router
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = lax.top_k(probs, cfg.top_k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    return topi, topv.astype(hn2d.dtype), probs


def moe_ffn(cfg, topo, w, x_sp):
    """Expert-parallel MoE with PID-Comm AlltoAll dispatch (paper's flagship
    primitive, used exactly like DLRM embedding exchange, Fig. 11).

    Returns (new_x_sp, aux_loss)."""
    ep_size = topo.size(topo.ep)
    etp_size = topo.size(topo.etp)
    Ep = cfg.n_experts_padded
    E_loc = Ep // ep_size

    x_e = x_sp
    if etp_size > 1:
        x_e = topo.comm(topo.etp).all_gather(x_sp, axis=1)
    B, S_e, D = x_e.shape
    hn = rms_norm(x_e, w["fln"], cfg.norm_eps)
    T = B * S_e
    h2 = hn.reshape(T, D)
    topi, topv, probs = _route(cfg, h2, w["router"])

    # aux load-balance loss (switch-style), over the real experts only
    pe = probs[:, :cfg.n_experts].mean(0)
    fe = jnp.zeros(cfg.n_experts, jnp.float32).at[
        jnp.clip(topi.reshape(-1), 0, cfg.n_experts - 1)].add(
        1.0 / (T * cfg.top_k))
    aux = cfg.n_experts * jnp.sum(pe * fe)

    C = int(math.ceil(T * cfg.top_k / Ep * cfg.capacity_factor))
    flat_e = topi.reshape(-1)                                  # (T*k,)
    tok = jnp.repeat(jnp.arange(T), cfg.top_k)
    if cfg.moe_dispatch == "sort":
        # PE-assisted reordering (paper §V-A1) applied to dispatch: sort the
        # (token, expert) pairs so the buffer build is one contiguous gather
        # instead of a scatter-add into a zero-initialized buffer -- the
        # AlltoAll then moves pre-ordered tiles (cf. kernels/reorder).
        order = jnp.argsort(flat_e)                            # stable
        sorted_e = flat_e[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(Ep))    # (Ep,)
        slot_idx = starts[:, None] + jnp.arange(C)[None]       # (Ep, C)
        in_seg = slot_idx < jnp.append(starts[1:], T * cfg.top_k)[:, None]
        src = jnp.where(in_seg, order[jnp.clip(slot_idx, 0, T * cfg.top_k - 1)],
                        0)
        disp = jnp.where(in_seg[..., None], h2[tok[src]], 0)   # (Ep, C, D)
        # slot of each (token,choice) for the combine gather
        rank_in_seg = jnp.zeros((T * cfg.top_k,), jnp.int32).at[order].set(
            jnp.arange(T * cfg.top_k, dtype=jnp.int32) - starts[sorted_e])
        pos_in_e = rank_in_seg
        keep = pos_in_e < C
    else:
        # baseline: one-hot cumsum slots + scatter-add ("host modulation")
        oh = jax.nn.one_hot(flat_e, Ep, dtype=jnp.int32)
        pos_in_e = (jnp.cumsum(oh, axis=0) - oh)[
            jnp.arange(T * cfg.top_k), flat_e]
        keep = pos_in_e < C
        disp = jnp.zeros((Ep, C, D), h2.dtype)
        disp = disp.at[flat_e, jnp.clip(pos_in_e, 0, C - 1)].add(
            jnp.where(keep[:, None], h2[tok], 0))

    # AlltoAll over the expert dimension of the hypercube
    epc = topo.comm(topo.ep)
    recv = epc.all_to_all(disp, split_axis=0, concat_axis=1)   # (E_loc, ep*C, D)
    hh = jnp.einsum("ecd,edf->ecf", recv, w["we_g"])
    hh = jax.nn.silu(hh) * jnp.einsum("ecd,edf->ecf", recv, w["we_u"])
    oo = jnp.einsum("ecf,efd->ecd", hh, w["we_d"])
    if etp_size > 1:
        oo = topo.comm(topo.etp).all_reduce(oo)
    back = epc.all_to_all(oo, split_axis=1, concat_axis=0)     # (Ep, C, D)

    vals = back[flat_e, jnp.clip(pos_in_e, 0, C - 1)]          # (T*k, D)
    vals = jnp.where(keep[:, None], vals, 0) * topv.reshape(-1)[:, None]
    out = jnp.zeros((T, D), vals.dtype).at[tok].add(vals).reshape(B, S_e, D)

    if cfg.n_shared_experts:
        out = out + (jax.nn.silu(hn @ w["ws_g"]) * (hn @ w["ws_u"])) @ w["ws_d"]

    if etp_size > 1:
        me = lax.axis_index(topo.etp)
        S_sp = x_sp.shape[1]
        out = lax.dynamic_slice_in_dim(out, me * S_sp, S_sp, axis=1)
    return x_sp + out, aux


def moe_ffn_decode(cfg, topo, w, x):
    """Decode-path MoE: tokens replicated over model axes; dispatch over ep."""
    epc = topo.comm(topo.ep)
    ep_size = topo.size(topo.ep)
    etp_size = topo.size(topo.etp)
    Ep = cfg.n_experts_padded
    B, D = x.shape
    hn = rms_norm(x, w["fln"], cfg.norm_eps)
    topi, topv, _ = _route(cfg, hn, w["router"])
    C = max(int(math.ceil(B * cfg.top_k / Ep * cfg.capacity_factor)), 1)
    flat_e = topi.reshape(-1)
    oh = jax.nn.one_hot(flat_e, Ep, dtype=jnp.int32)
    pos_in_e = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(flat_e.size), flat_e]
    keep = pos_in_e < C
    tok = jnp.repeat(jnp.arange(B), cfg.top_k)
    disp = jnp.zeros((Ep, C, D), hn.dtype).at[
        flat_e, jnp.clip(pos_in_e, 0, C - 1)].add(
        jnp.where(keep[:, None], hn[tok], 0))
    recv = epc.all_to_all(disp, split_axis=0, concat_axis=1)
    hh = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, w["we_g"]))
    hh = hh * jnp.einsum("ecd,edf->ecf", recv, w["we_u"])
    oo = jnp.einsum("ecf,efd->ecd", hh, w["we_d"])
    if etp_size > 1:
        oo = topo.comm(topo.etp).all_reduce(oo)
    back = epc.all_to_all(oo, split_axis=1, concat_axis=0)
    vals = back[flat_e, jnp.clip(pos_in_e, 0, C - 1)]
    vals = jnp.where(keep[:, None], vals, 0) * topv.reshape(-1)[:, None]
    out = jnp.zeros((B, D), vals.dtype).at[tok].add(vals)
    if cfg.n_shared_experts:
        out = out + (jax.nn.silu(hn @ w["ws_g"]) * (hn @ w["ws_u"])) @ w["ws_d"]
    return x + out.astype(x.dtype), None


def rwkv_channel_mix(cfg, topo, w, x_sp, out_cache: bool = False):
    tpc = topo.comm(topo.tp)
    h = tpc.all_gather(x_sp, axis=1)                           # (B, S, D)
    hn = rms_norm(h, w["fln"], cfg.norm_eps)
    prev = jnp.pad(hn, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xk = hn + w["cm_mu"][0] * (prev - hn)
    xr = hn + w["cm_mu"][1] * (prev - hn)
    kk = jnp.square(jax.nn.relu(xk @ w["cm_k"]))
    out = kk @ w["cm_v"]                                       # partial (tp)
    out = tpc.reduce_scatter(out, axis=1)
    gate = jax.nn.sigmoid(xr @ w["cm_r"])                      # (B,S,D) repl.
    me = _tp_rank(topo)
    S_sp = x_sp.shape[1]
    gate = lax.dynamic_slice_in_dim(gate, me * S_sp, S_sp, axis=1)
    y = x_sp + out * gate.astype(out.dtype)
    if out_cache:
        return y, hn[:, -1]
    return y


def rwkv_channel_mix_decode(cfg, topo, w, x, prev):
    hn = rms_norm(x, w["fln"], cfg.norm_eps)
    xk = hn + w["cm_mu"][0] * (prev - hn)
    xr = hn + w["cm_mu"][1] * (prev - hn)
    kk = jnp.square(jax.nn.relu(xk @ w["cm_k"]))
    out = topo.comm(topo.tp).all_reduce(kk @ w["cm_v"])
    gate = jax.nn.sigmoid(xr @ w["cm_r"])
    return x + (out * gate).astype(x.dtype), hn


# ------------------------------------------------------------------ mixers
def rwkv_mix(cfg, topo, w, x_sp, out_cache: bool = False):
    """RWKV6 time-mix. Training path: x_sp (B, S_sp, D)."""
    spc = topo.comm(topo.sp)
    h = spc.all_gather(x_sp, axis=1)                           # (B, S, D)
    hn = rms_norm(h, w["ln"], cfg.norm_eps)
    hprev = jnp.pad(hn, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    mu = w["mu"]
    xr, xk, xv, xg, xw = (hn + mu[i] * (hprev - hn) for i in range(5))
    hd = cfg.rwkv_head_dim
    Dl = w["wr"].shape[1]
    Hl = Dl // hd
    B, S = hn.shape[:2]
    r = (xr @ w["wr"]).reshape(B, S, Hl, hd)
    k = (xk @ w["wk"]).reshape(B, S, Hl, hd)
    v = (xv @ w["wv"]).reshape(B, S, Hl, hd)
    g = jax.nn.silu(xg @ w["wg"])
    wdd = w["decay_w0"] + jnp.tanh(xw @ w["w_lora_a"]) @ w["w_lora_b"]
    logw = -jnp.exp(wdd.astype(jnp.float32)).reshape(B, S, Hl, hd)
    u = w["bonus_u"].reshape(Hl, hd)
    o, state = ssm.rwkv6_chunked(r, k, v, logw, u)
    out = (o.reshape(B, S, Dl) * g) @ w["wo"]                  # partial (tp)
    out = spc.reduce_scatter(out, axis=1)
    y = x_sp + out
    if out_cache:
        return y, (state, hn[:, -1])
    return y


def rwkv_mix_decode(cfg, topo, w, x, state, prev):
    """x: (B, D); state: (B, Hl, hd, hd); prev: (B, D) previous hidden."""
    hn = rms_norm(x, w["ln"], cfg.norm_eps)
    mu = w["mu"]
    xr, xk, xv, xg, xw = (hn + mu[i] * (prev - hn) for i in range(5))
    hd = cfg.rwkv_head_dim
    Dl = w["wr"].shape[1]
    Hl = Dl // hd
    B = hn.shape[0]
    r = (xr @ w["wr"]).reshape(B, Hl, hd)
    k = (xk @ w["wk"]).reshape(B, Hl, hd)
    v = (xv @ w["wv"]).reshape(B, Hl, hd)
    g = jax.nn.silu(xg @ w["wg"])
    wdd = w["decay_w0"] + jnp.tanh(xw @ w["w_lora_a"]) @ w["w_lora_b"]
    logw = -jnp.exp(wdd.astype(jnp.float32)).reshape(B, Hl, hd)
    u = w["bonus_u"].reshape(Hl, hd)
    o, state = ssm.rwkv6_step(r, k, v, logw, u, state)
    out = (o.reshape(B, Dl) * g) @ w["wo"]
    out = topo.comm(topo.tp).all_reduce(out)
    return x + out.astype(x.dtype), state, hn


def mamba_mix(cfg, topo, w, x_sp, out_cache: bool = False):
    spc = topo.comm(topo.sp)
    h = spc.all_gather(x_sp, axis=1)                           # (B, S, D)
    hn = rms_norm(h, w["ln"], cfg.norm_eps)
    B, S = hn.shape[:2]
    # in_proj columns laid out (din, 2): (x, z) stay paired per channel so
    # column-sharding over tp slices whole channels.
    xz = hn @ w["in_proj"]                                     # (B,S,2*din_l)
    din_l = xz.shape[-1] // 2
    xz = xz.reshape(B, S, din_l, 2)
    xc_raw, z = xz[..., 0], xz[..., 1]
    xc, conv_tail = ssm.causal_conv1d(xc_raw, w["conv_w"], w["conv_b"])
    xc = jax.nn.silu(xc)
    R = dt_rank(cfg)
    n = cfg.d_state
    dbc = xc @ w["x_proj"]                                     # partial (tp)
    dbc = topo.comm(topo.tp).all_reduce(dbc)                   # (B,S,R+2n)
    dt = jax.nn.softplus(dbc[..., :R] @ w["dt_proj"] + w["dt_bias"])
    Bm, Cm = dbc[..., R:R + n], dbc[..., R + n:]
    A = -jnp.exp(w["a_log"])
    y, state = ssm.mamba_scan_chunked(xc, dt, A, Bm, Cm)
    out = (y * jax.nn.silu(z) + xc * w["d_skip"]) @ w["out_proj"]
    out = spc.reduce_scatter(out, axis=1)
    y_sp = x_sp + out
    if out_cache:
        return y_sp, (state, conv_tail)
    return y_sp


def mamba_mix_decode(cfg, topo, w, x, ssm_state, conv_tail):
    """x: (B, D); ssm_state: (B, din_l, N); conv_tail: (B, K-1, din_l)."""
    hn = rms_norm(x, w["ln"], cfg.norm_eps)
    xz = hn[:, None] @ w["in_proj"]
    din_l = xz.shape[-1] // 2
    xz = xz.reshape(xz.shape[0], 1, din_l, 2)
    xc, z = xz[..., 0], xz[..., 1]
    xc, conv_tail = ssm.causal_conv1d(xc, w["conv_w"], w["conv_b"], conv_tail)
    xc = jax.nn.silu(xc)[:, 0]
    z = z[:, 0]
    R = dt_rank(cfg)
    n = cfg.d_state
    tpc = topo.comm(topo.tp)
    dbc = tpc.all_reduce(xc @ w["x_proj"])
    dt = jax.nn.softplus(dbc[..., :R] @ w["dt_proj"] + w["dt_bias"])
    Bm, Cm = dbc[..., R:R + n], dbc[..., R + n:]
    A = -jnp.exp(w["a_log"])
    y, ssm_state = ssm.mamba_step(xc, dt, A, Bm, Cm, ssm_state)
    out = (y * jax.nn.silu(z) + xc * w["d_skip"]) @ w["out_proj"]
    out = tpc.all_reduce(out)
    return x + out.astype(x.dtype), ssm_state, conv_tail
