"""Parameter definitions: global shapes, shardings, init, grad-sync tags.

Storage layout (ZeRO-3 / FSDP): every weight is sharded over the ``data``
axis on one dimension (gathered with pidcomm AllGather inside the layer scan;
the AllGather's autodiff transpose reduce-scatters the gradients -- no
separate gradient all-reduce on the fast domain). Model-parallel dimensions
are sharded over the ``tp`` (= ``(ep, etp)`` for MoE) axes.

``sum_axes`` marks parameters whose per-shard gradients are *partial* and
must be psum'ed over those logical axes after backward (e.g. norms, routers,
replicated KV projections). Correctness is pinned by
tests/test_parallel_consistency.py against a single-device oracle.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.config import (
    ModelConfig, ATTN, MAMBA, RWKV, DENSE, MOE, RWKVCM)
from repro.models.topology import Topology

MASTER_DTYPE = jnp.float32
COMPUTE_DTYPE = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    spec: P
    init: str = "normal"       # normal | zeros | ones | out_proj | a_log | dt
    sum_axes: str = ""         # "" | "tp" | "ep" -- grad psum group
    dtype: Any = MASTER_DTYPE


def _round_up(x: int, m: int) -> int:
    return int(math.ceil(x / m) * m)


def kv_is_sharded(cfg: ModelConfig, topo: Topology) -> bool:
    t = topo.tp_size
    return cfg.n_kv_heads >= t and cfg.n_kv_heads % t == 0


def vocab_padded(cfg: ModelConfig, topo: Topology) -> int:
    return _round_up(cfg.vocab_size, topo.tp_size)


def dt_rank(cfg: ModelConfig) -> int:
    return _round_up(cfg.d_model // 16, 8)


# --------------------------------------------------------------------- defs
def _attn_defs(cfg, topo, prefix=""):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    tp = topo.tp
    kv_spec = P("data", tp) if kv_is_sharded(cfg, topo) else P("data", None)
    kv_sum = "" if kv_is_sharded(cfg, topo) else "tp"
    d = {
        prefix + "ln": ParamDef((D,), P("data"), "zeros", "tp"),
        prefix + "wq": ParamDef((D, H * hd), P("data", tp)),
        prefix + "wkv": ParamDef((D, 2 * KV * hd), kv_spec, "normal", kv_sum),
        prefix + "wo": ParamDef((H * hd, D), P(tp, "data"), "out_proj"),
    }
    if cfg.qk_norm and not prefix:
        d["q_norm"] = ParamDef((hd,), P(None), "zeros", "tp")
        d["k_norm"] = ParamDef((hd,), P(None), "zeros", "tp")
    return d


def _mamba_defs(cfg, topo):
    D = cfg.d_model
    din = cfg.mamba_expand * D
    n = cfg.d_state
    R = dt_rank(cfg)
    tp = topo.tp
    return {
        "ln": ParamDef((D,), P("data"), "zeros", "tp"),
        "in_proj": ParamDef((D, 2 * din), P("data", tp)),
        "conv_w": ParamDef((cfg.conv_kernel, din), P(None, tp)),
        "conv_b": ParamDef((din,), P(tp), "zeros"),
        "x_proj": ParamDef((din, R + 2 * n), P(tp, None)),
        "dt_proj": ParamDef((R, din), P(None, tp)),
        "dt_bias": ParamDef((din,), P(tp), "dt"),
        "a_log": ParamDef((din, n), P(tp, None), "a_log"),
        "d_skip": ParamDef((din,), P(tp), "ones"),
        "out_proj": ParamDef((din, D), P(tp, "data"), "out_proj"),
    }


def _rwkv_defs(cfg, topo):
    D = cfg.d_model
    tp = topo.tp
    lora = 64
    return {
        "ln": ParamDef((D,), P("data"), "zeros", "tp"),
        "mu": ParamDef((5, D), P(None, "data"), "normal", "tp"),
        "wr": ParamDef((D, D), P("data", tp)),
        "wk": ParamDef((D, D), P("data", tp)),
        "wv": ParamDef((D, D), P("data", tp)),
        "wg": ParamDef((D, D), P("data", tp)),
        "w_lora_a": ParamDef((D, lora), P("data", None), "normal", "tp"),
        "w_lora_b": ParamDef((lora, D), P(None, tp)),
        "decay_w0": ParamDef((D,), P(tp), "decay"),
        "bonus_u": ParamDef((D,), P(tp)),
        "wo": ParamDef((D, D), P(tp, "data"), "out_proj"),
    }


def _dense_ffn_defs(cfg, topo):
    D, F = cfg.d_model, cfg.d_ff
    tp = topo.tp
    return {
        "fln": ParamDef((D,), P("data"), "zeros", "tp"),
        "wg": ParamDef((D, F), P("data", tp)),
        "wu": ParamDef((D, F), P("data", tp)),
        "wd": ParamDef((F, D), P(tp, "data"), "out_proj"),
    }


def _moe_ffn_defs(cfg, topo):
    D, Fe = cfg.d_model, cfg.d_ff_expert
    Ep = cfg.n_experts_padded
    ep, etp = topo.ep, topo.etp
    d = {
        "fln": ParamDef((D,), P("data"), "zeros", "ep"),
        "router": ParamDef((D, Ep), P("data", None), "normal", "ep"),
        "we_g": ParamDef((Ep, D, Fe), P(ep, "data", etp)),
        "we_u": ParamDef((Ep, D, Fe), P(ep, "data", etp)),
        "we_d": ParamDef((Ep, Fe, D), P(ep, etp, "data"), "out_proj"),
    }
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * Fe
        d["ws_g"] = ParamDef((D, Fs), P("data", None), "normal", "ep")
        d["ws_u"] = ParamDef((D, Fs), P("data", None), "normal", "ep")
        d["ws_d"] = ParamDef((Fs, D), P(None, "data"), "out_proj", "ep")
    return d


def _rwkvcm_defs(cfg, topo):
    D, F = cfg.d_model, cfg.d_ff
    tp = topo.tp
    return {
        "fln": ParamDef((D,), P("data"), "zeros", "tp"),
        "cm_mu": ParamDef((2, D), P(None, "data"), "normal", "tp"),
        "cm_r": ParamDef((D, D), P("data", None), "normal", "tp"),
        "cm_k": ParamDef((D, F), P("data", tp)),
        "cm_v": ParamDef((F, D), P(tp, "data"), "out_proj"),
    }


_MIXER_DEFS = {ATTN: _attn_defs, MAMBA: _mamba_defs, RWKV: _rwkv_defs}
_FFN_DEFS = {DENSE: _dense_ffn_defs, MOE: _moe_ffn_defs, RWKVCM: _rwkvcm_defs}


def _stack(defs: dict, n: int) -> dict:
    """Prepend the unit-stack dimension to every leaf."""
    out = {}
    for k, d in defs.items():
        out[k] = ParamDef((n,) + d.shape, P(*((None,) + tuple(d.spec))),
                          d.init, d.sum_axes, d.dtype)
    return out


def param_defs(cfg: ModelConfig, topo: Topology) -> dict:
    D = cfg.d_model
    tp = topo.tp
    Vp = vocab_padded(cfg, topo)
    unit = cfg.unit()
    n_units = cfg.n_layers // unit
    mixers, ffns = cfg.mixers(), cfg.ffns()

    units = {}
    for pos in range(unit):
        d = dict(_MIXER_DEFS[mixers[pos]](cfg, topo))
        d.update(_FFN_DEFS[ffns[pos]](cfg, topo))
        if cfg.is_encoder_decoder and mixers[pos] == ATTN:
            d.update(_attn_defs(cfg, topo, prefix="x"))   # cross-attention
        units[f"p{pos}"] = _stack(d, n_units)

    tree = {
        "embed": ParamDef((Vp, D), P(tp, "data"), "embed"),
        "units": units,
        "final_norm": ParamDef((D,), P("data"), "zeros", "tp"),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamDef((D, Vp), P("data", tp))
    if cfg.frontend:
        fin = cfg.frontend_dim or D
        tree["frontend_proj"] = ParamDef((fin, D), P(None, "data"),
                                         "normal", "tp")
    if cfg.is_encoder_decoder:
        enc = {}
        e_units = cfg.n_enc_layers  # encoder is uniform attention+dense
        d = dict(_attn_defs(cfg, topo))
        d.update(_dense_ffn_defs(cfg, topo))
        enc["p0"] = _stack(d, e_units)
        tree["enc_units"] = enc
        tree["enc_final_norm"] = ParamDef((D,), P("data"), "zeros", "tp")
    return tree


# --------------------------------------------------------------------- init
def _init_leaf(key, d: ParamDef, cfg: ModelConfig):
    shape = d.shape
    if d.init == "zeros":
        return jnp.zeros(shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(shape, d.dtype)
    if d.init == "a_log":
        n = shape[-1]
        a = jnp.tile(jnp.arange(1, n + 1, dtype=d.dtype), shape[:-1] + (1,))
        return jnp.log(a)
    if d.init == "dt":
        lo, hi = math.log(1e-3), math.log(1e-1)
        u = jax.random.uniform(key, shape, d.dtype)
        dt = jnp.exp(lo + u * (hi - lo))
        return dt + jnp.log(-jnp.expm1(-dt))  # inv softplus
    if d.init == "decay":
        return jnp.linspace(-6.0, -1.0, shape[-1], dtype=d.dtype
                            ) * jnp.ones(shape, d.dtype)
    scale = 0.02
    if d.init == "out_proj":
        scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    if d.init == "embed":
        scale = 1.0 / math.sqrt(cfg.d_model)
    return jax.random.normal(key, shape, d.dtype) * scale


def init_params(cfg: ModelConfig, topo: Topology, seed: int = 0):
    """Materialize global parameter arrays (host-side; smoke-scale only)."""
    defs = param_defs(cfg, topo)
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    vals = [_init_leaf(k, d, cfg) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def param_specs(cfg: ModelConfig, topo: Topology):
    defs = param_defs(cfg, topo)
    return jax.tree.map(lambda d: d.spec, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def param_structs(cfg: ModelConfig, topo: Topology):
    """ShapeDtypeStructs with shardings (no allocation) for the dry-run."""
    defs = param_defs(cfg, topo)
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(
            d.shape, d.dtype, sharding=topo.cube.sharding(d.spec)),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def drop_axis(spec_tree, axis: str = "data"):
    """Replace ``axis`` with None in every PartitionSpec of a tree --
    serve-time *resident weights*: parameters are replicated over the data
    axis so decode never re-gathers them per token (ZeRO-inference off)."""
    def fix(spec):
        out = []
        for e in tuple(spec):
            if e == axis:
                out.append(None)
            elif isinstance(e, tuple):
                kept = tuple(a for a in e if a != axis)
                out.append(kept if kept else None)
            else:
                out.append(e)
        return P(*out)
    return jax.tree.map(
        fix, spec_tree, is_leaf=lambda x: isinstance(x, P))


def grad_sum_spec(cfg: ModelConfig, topo: Topology):
    """Per-leaf tuple of logical axes over which grads must be psum'ed.

    NOTE: superseded by shard_map's vma-aware autodiff (check_vma=True),
    which derives these reductions from the sharding structure; kept as
    executable documentation of the manual rule and for audits."""
    defs = param_defs(cfg, topo)

    def axes(d: ParamDef):
        if d.sum_axes == "tp":
            return topo.tp
        if d.sum_axes == "ep":
            return topo.ep if topo.ep else topo.tp
        return ()
    return jax.tree.map(axes, defs, is_leaf=lambda x: isinstance(x, ParamDef))
