"""Serving paths: prefill (cache-building forward) and flash-decode.

Decode runs on the serve topology (maximal model sharding, see
``build_serve_topology``): activations are replicated over the model axes,
the KV cache is sequence-sharded over them, and every layer's partial
attention is LSE-combined with a pidcomm psum -- the TPU translation of
PID-Comm's "entangled group works in unison" rule (all shards cooperate on
every token instead of idling).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import blocks
from repro.models.config import (
    ModelConfig, ATTN, MAMBA, RWKV, DENSE, MOE, RWKVCM, FULL_WINDOW)
from repro.models.layers import rms_norm, pscan
from repro.models.lm import Model
from repro.models.params import COMPUTE_DTYPE, dt_rank, vocab_padded
from repro.models.topology import Topology

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ServePlan:
    """Static decode-cell geometry."""
    S_ctx: int                  # context length (max position + 1)
    S_cache: int                # allocated cache length (< S_ctx if rolling)
    global_batch: int
    batch_axes: tuple[str, ...]  # axes sharding the batch ('' = replicated)
    kv_axes: tuple[str, ...]     # axes sharding the cache sequence
    cache_dtype: str = "bf16"    # "bf16" | "int8" (8-bit CM on the KV cache)


def make_serve_plan(cfg: ModelConfig, topo: Topology, *, S_ctx: int,
                    global_batch: int, cache_dtype: str = "bf16"
                    ) -> ServePlan:
    if cache_dtype not in ("bf16", "int8"):
        raise ValueError(
            f"cache_dtype must be 'bf16' or 'int8', got {cache_dtype!r} "
            "(the KV cache is either compute-dtype or the §V-C 8-bit "
            "cross-domain-modulated layout; nothing else has a decode path)")
    pods = topo.size(("pod",)) if "pod" in topo.cube.dim_names else 1
    batch_axes: tuple[str, ...] = ()
    b = global_batch
    if pods > 1 and b % pods == 0 and b >= pods:
        batch_axes += ("pod",)
        b //= pods
    dsz = topo.cube.size("data") if "data" in topo.cube.dim_names else 1
    if dsz > 1 and b % dsz == 0 and b >= dsz:
        batch_axes += ("data",)
        b //= dsz
    # uniform static sliding window => rolling cache bounded by the window
    wins = cfg.windows()
    S_cache = S_ctx
    if (wins >= 0).all() and len(set(wins.tolist())) == 1:
        S_cache = min(S_ctx, int(wins[0]))
    kv_axes = topo.tp
    # pad cache length to shard evenly
    n = topo.size(kv_axes)
    S_cache = int(np.ceil(S_cache / n) * n)
    return ServePlan(S_ctx=S_ctx, S_cache=S_cache, global_batch=global_batch,
                     batch_axes=batch_axes, kv_axes=kv_axes,
                     cache_dtype=cache_dtype)


# ------------------------------------------------------------- cache layout
def cache_defs(cfg: ModelConfig, topo: Topology, plan: ServePlan):
    """(global shape, spec, dtype) tree for the decode cache."""
    unit = cfg.unit()
    n_units = cfg.n_layers // unit
    B = plan.global_batch
    ba = plan.batch_axes or None
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    din = cfg.mamba_expand * cfg.d_model
    tree = {}
    for p, (mixer, ffn) in enumerate(zip(cfg.mixers()[:unit],
                                         cfg.ffns()[:unit])):
        d = {}
        if mixer == ATTN:
            cdt = jnp.int8 if plan.cache_dtype == "int8" else COMPUTE_DTYPE
            shp = (n_units, B, plan.S_cache, KV, hd)
            spec = P(None, ba, plan.kv_axes, None, None)
            d["k"] = (shp, spec, cdt)
            d["v"] = (shp, spec, cdt)
            if plan.cache_dtype == "int8":
                s_shp = (n_units, B, plan.S_cache, KV)
                s_spec = P(None, ba, plan.kv_axes, None)
                d["k_s"] = (s_shp, s_spec, jnp.float32)
                d["v_s"] = (s_shp, s_spec, jnp.float32)
            if cfg.is_encoder_decoder:
                xshp = (n_units, B, plan.S_ctx, KV, hd)
                d["xk"] = (xshp, P(None, ba, plan.kv_axes, None, None),
                           COMPUTE_DTYPE)
                d["xv"] = (xshp, P(None, ba, plan.kv_axes, None, None),
                           COMPUTE_DTYPE)
        elif mixer == MAMBA:
            d["ssm"] = ((n_units, B, din, cfg.d_state),
                        P(None, ba, topo.tp, None), jnp.float32)
            d["conv"] = ((n_units, B, cfg.conv_kernel - 1, din),
                         P(None, ba, None, topo.tp), COMPUTE_DTYPE)
        elif mixer == RWKV:
            H = cfg.d_model // cfg.rwkv_head_dim
            d["state"] = ((n_units, B, H, cfg.rwkv_head_dim,
                           cfg.rwkv_head_dim),
                          P(None, ba, topo.tp, None, None), jnp.float32)
            d["shift"] = ((n_units, B, cfg.d_model),
                          P(None, ba, None), COMPUTE_DTYPE)
        if ffn == RWKVCM:
            d["cm_shift"] = ((n_units, B, cfg.d_model),
                             P(None, ba, None), COMPUTE_DTYPE)
        tree[f"p{p}"] = d
    return tree


def cache_structs(cfg, topo, plan):
    defs = cache_defs(cfg, topo, plan)
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(
            d[0], d[2], sharding=topo.cube.sharding(d[1])),
        defs, is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))


def cache_specs(cfg, topo, plan):
    defs = cache_defs(cfg, topo, plan)
    return jax.tree.map(
        lambda d: d[1], defs,
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))


def init_cache(cfg, topo, plan):
    """Zero cache (smoke-scale only)."""
    defs = cache_defs(cfg, topo, plan)
    return jax.tree.map(
        lambda d: jnp.zeros(d[0], d[2]), defs,
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))


# ------------------------------------------------------------------ decode
class Server:
    def __init__(self, cfg: ModelConfig, topo: Topology, plan: ServePlan,
                 resident: bool = False):
        self.cfg, self.topo, self.plan = cfg, topo, plan
        self.model = Model(cfg, topo, resident=resident)

    def decode_shard(self, params, cache, tokens: Array, pos: Array):
        """One decode step. tokens, pos: (B_l,) int32. Returns
        (logits (B_l, V_local), new cache)."""
        cfg, topo, plan = self.cfg, self.topo, self.plan
        m = self.model
        emb_l = m._gather_embed(params)
        x = topo.comm(topo.tp).all_reduce(
            m._embed_tokens(emb_l, tokens[:, None]))[:, 0]

        def unit_fn(x, slices):
            xs, cin = slices
            cout = {}
            for p in range(m.unit):
                key = f"p{p}"
                w = blocks.gather_params(xs[key], m.unit_specs[key], topo)
                window = m.static_window[p]
                if window is None:
                    window = xs["windows"][key]
                mixer = m.mixers[p]
                c = dict(cin[key])
                if mixer == ATTN:
                    rolling = plan.S_cache < plan.S_ctx
                    x, c = blocks.attn_decode(
                        cfg, topo, w, x, c, pos,
                        window=window, kv_axes=plan.kv_axes, rolling=rolling)
                    if cfg.is_encoder_decoder:
                        x, c = blocks.attn_decode(
                            cfg, topo, w, x, c, pos,
                            window=FULL_WINDOW, kv_axes=plan.kv_axes,
                            rolling=False, prefix="x", cross=True,
                            keys=("xk", "xv"))
                elif mixer == MAMBA:
                    x, c["ssm"], c["conv"] = blocks.mamba_mix_decode(
                        cfg, topo, w, x, c["ssm"], c["conv"])
                elif mixer == RWKV:
                    x, c["state"], shift = blocks.rwkv_mix_decode(
                        cfg, topo, w, x, c["state"], c["shift"])
                    c["shift"] = shift.astype(c["shift"].dtype)
                ffn = m.ffns[p]
                if ffn == DENSE:
                    x = blocks.dense_ffn_decode(cfg, topo, w, x)
                elif ffn == MOE:
                    x, _ = blocks.moe_ffn_decode(cfg, topo, w, x)
                elif ffn == RWKVCM:
                    x, shift = blocks.rwkv_channel_mix_decode(
                        cfg, topo, w, x, c["cm_shift"])
                    c["cm_shift"] = shift.astype(c["cm_shift"].dtype)
                cout[key] = c
            return x, cout

        xs = dict(params["units"])
        if m.window_xs:
            xs["windows"] = m.window_xs
        x, new_cache = pscan(unit_fn, x, (xs, cache))
        fn = blocks.gather_params(
            {"n": params["final_norm"]}, {"n": m.specs["final_norm"]},
            topo)["n"]
        hn = rms_norm(x, fn, cfg.norm_eps)
        logits = (hn @ m._head(params)).astype(jnp.float32)
        return logits, new_cache

    # ------------------------------------------------------------- prefill
    def prefill_shard(self, params, batch):
        """Forward over the full prompt, emitting an sp-sharded cache and the
        last-position logits. Runs on a *training-style* topology."""
        cfg, topo = self.cfg, self.topo
        m = self.model
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = m.encode(params, batch["frames"])
        x_sp = m.embed_input(params, batch)

        def unit_fn(x_sp, xs):
            cout = {}
            for p in range(m.unit):
                key = f"p{p}"
                w = blocks.gather_params(xs[key], m.unit_specs[key], topo)
                window = m.static_window[p]
                if window is None:
                    window = xs["windows"][key]
                mixer = m.mixers[p]
                c = {}
                if mixer == ATTN:
                    x_sp, (c["k"], c["v"]) = blocks.attn_block(
                        cfg, topo, w, x_sp, window=window, out_cache=True)
                    if enc_out is not None:
                        x_sp, (c["xk"], c["xv"]) = blocks.attn_block(
                            cfg, topo, w, x_sp, window=FULL_WINDOW,
                            cross_src=enc_out, prefix="x", out_cache=True)
                elif mixer == MAMBA:
                    x_sp, (c["ssm"], c["conv"]) = blocks.mamba_mix(
                        cfg, topo, w, x_sp, out_cache=True)
                elif mixer == RWKV:
                    x_sp, (c["state"], c["shift"]) = blocks.rwkv_mix(
                        cfg, topo, w, x_sp, out_cache=True)
                ffn = m.ffns[p]
                if ffn == DENSE:
                    x_sp = blocks.dense_ffn(cfg, topo, w, x_sp)
                elif ffn == MOE:
                    x_sp, _ = blocks.moe_ffn(cfg, topo, w, x_sp)
                elif ffn == RWKVCM:
                    x_sp, c["cm_shift"] = blocks.rwkv_channel_mix(
                        cfg, topo, w, x_sp, out_cache=True)
                cout[f"p{p}"] = c
            return x_sp, cout

        xs = dict(params["units"])
        if m.window_xs:
            xs["windows"] = m.window_xs
        x_sp, cache = pscan(unit_fn, x_sp, xs)
        full = topo.comm(topo.sp).all_gather(x_sp, axis=1)
        fn = blocks.gather_params(
            {"n": params["final_norm"]}, {"n": m.specs["final_norm"]},
            topo)["n"]
        hn = rms_norm(full[:, -1:], fn, cfg.norm_eps)
        logits = (hn[:, 0] @ m._head(params)).astype(jnp.float32)
        return logits, cache
