"""AdamW with optional 8-bit block-quantized moments.

Moments are quantized per last-dim row (absmax int8), the TPU analogue of the
paper's 8-bit cross-domain trick (§V-C): the optimizer state never leaves the
narrow domain, cutting its HBM footprint 4x -- what makes a 398B model's
state fit 256 chips next to fp32 master weights.

State is sharded identically to the parameters (ZeRO); all math is local to
the shard (no collectives in the optimizer).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    use_8bit: bool = True


def _quant_m(x):
    """Signed sqrt-companded int8 (precision concentrated near zero)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-12)
    q = jnp.round(127.0 * jnp.sign(x) * jnp.sqrt(jnp.abs(x) / amax))
    return q.astype(jnp.int8), amax.astype(jnp.float32)


def _dequant_m(q, amax):
    qf = q.astype(jnp.float32)
    return jnp.sign(qf) * jnp.square(qf / 127.0) * amax


def _quant_v(x):
    """Non-negative 4th-root-companded int8: second moments span many
    orders of magnitude; linear absmax would zero small rows and blow up
    1/sqrt(v) updates."""
    amax = jnp.maximum(jnp.max(x, axis=-1, keepdims=True), 1e-20)
    q = jnp.round(127.0 * jnp.power(x / amax, 0.25))
    return q.astype(jnp.int8), amax.astype(jnp.float32)


def _dequant_v(q, amax):
    return jnp.power(q.astype(jnp.float32) / 127.0, 4.0) * amax


def init_state(params, cfg: AdamWConfig):
    def leaf(p):
        if cfg.use_8bit:
            return {"m_q": jnp.zeros(p.shape, jnp.int8),
                    "m_s": jnp.zeros(p.shape[:-1] + (1,), jnp.float32),
                    "v_q": jnp.zeros(p.shape, jnp.int8),
                    "v_s": jnp.zeros(p.shape[:-1] + (1,), jnp.float32)}
        return {"m": jnp.zeros_like(p, jnp.float32),
                "v": jnp.zeros_like(p, jnp.float32)}
    return {"mu": jax.tree.map(leaf, params),
            "step": jnp.zeros((), jnp.int32)}


def state_defs(param_defs_tree, cfg: AdamWConfig, is_leaf, cube=None):
    """(shape, spec, dtype) tree mirroring init_state, for dry-run structs.

    Quantization scales are per-row *per last-dim shard*: if a weight's last
    dim is sharded over axes X, the global scale array has size(X) columns
    sharded over X (each shard quantizes its own columns independently)."""
    from jax.sharding import PartitionSpec as P

    def axis_size(entry):
        if entry is None or cube is None:
            return 1
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= cube.size(a)
        return n

    def leaf(d):
        spec = tuple(d.spec)
        last = spec[-1] if spec else None
        n = axis_size(last)
        s_spec = P(*(spec[:-1] + (last,))) if spec else P()
        s_shape = d.shape[:-1] + (n,)
        if cfg.use_8bit:
            return {"m_q": (d.shape, d.spec, jnp.int8),
                    "m_s": (s_shape, s_spec, jnp.float32),
                    "v_q": (d.shape, d.spec, jnp.int8),
                    "v_s": (s_shape, s_spec, jnp.float32)}
        return {"m": (d.shape, d.spec, jnp.float32),
                "v": (d.shape, d.spec, jnp.float32)}
    return {"mu": jax.tree.map(leaf, param_defs_tree, is_leaf=is_leaf),
            "step": ((), P(), jnp.int32)}


def update(params, state, grads, *, lr, cfg: AdamWConfig):
    """One AdamW step (local shard math). Returns (params, state)."""
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def leaf(p, mu, g):
        g = g.astype(jnp.float32)
        if cfg.use_8bit:
            m = _dequant_m(mu["m_q"], mu["m_s"])
            v = _dequant_v(mu["v_q"], mu["v_s"])
        else:
            m, v = mu["m"], mu["v"]
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        upd = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        decay = cfg.weight_decay if p.ndim > 1 else 0.0
        new_p = (p.astype(jnp.float32)
                 - lr * (upd + decay * p.astype(jnp.float32))).astype(p.dtype)
        if cfg.use_8bit:
            mq, ms = _quant_m(m)
            vq, vs = _quant_v(v)
            return new_p, {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
        return new_p, {"m": m, "v": v}

    flat_p, tdef = jax.tree.flatten(params)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_g = tdef.flatten_up_to(grads)
    out = [leaf(p, mu, g) for p, mu, g in zip(flat_p, flat_mu, flat_g)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    return new_params, {"mu": new_mu, "step": step}


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)
    return lr
