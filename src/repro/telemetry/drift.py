"""Plan-drift monitoring: is the installed CommProfile still telling the
truth?

PR 4 fitted measured alpha-beta link models once and trusted them forever.
This module closes that loop: a :class:`DriftMonitor` accumulates
``meas_over_est`` residuals per ``(flow, stage, domain)`` key -- the same
key shape the profile's fitted models use -- from live executions, and
raises exactly one structured :class:`ProfileStalenessWarning` per key
(naming the offending key, the rolling median, the band, and the retune
recipe) when the rolling median leaves a configurable band.

Residual sources:

* :meth:`DriftMonitor.observe_event` -- a live
  :class:`~repro.core.comm.CommEvent` whose ``seconds`` estimate was
  priced by the installed profile, paired with a measured wall time;
* :meth:`DriftMonitor.observe_plan` -- a whole
  :class:`~repro.core.planner.ProgramPlan` against the measured wall time
  of one execution (the serving engine feeds this each step): the shared
  ``wall / plan.seconds`` ratio is filed under every op's key;
* :meth:`DriftMonitor.observe` -- a raw (key, measured, estimated) pair.

By default only ``est_source == "measured"`` estimates are monitored
(``require_measured=True``): an analytic estimate going stale is not a
*profile* problem, and the analytic constants are deliberately loose.

The module also owns the canonical drift band so other consumers
(``launch/dryrun.comm_drift``) share one definition of "suspiciously far
from the estimate" instead of re-inventing thresholds.
"""
from __future__ import annotations

import collections
import contextlib
import statistics
import warnings

from repro.telemetry import metrics as _metrics

# meas_over_est band: below 0.5 the profile over-prices (or the payload
# accounting under-counts); above 2.0 it under-prices.  Half/double is the
# historical dryrun byte-underrun threshold, now shared.
DEFAULT_BAND = (0.5, 2.0)


def outside_band(ratio: float, band=DEFAULT_BAND) -> bool:
    return ratio < band[0] or ratio > band[1]


def underrun(ratio: float, band=DEFAULT_BAND) -> bool:
    """The low edge only -- dryrun's historical byte-underrun check."""
    return ratio < band[0]


def _retune_recipe() -> str:
    try:
        from repro.tuning.profile import RETUNE_RECIPE
        return RETUNE_RECIPE
    except Exception:  # pragma: no cover - profile module always present
        return ("regenerate the profile with "
                "`repro.tuning.Tuner(cache_dir).tune(cube)`")


class ProfileStalenessWarning(UserWarning):
    """Structured staleness signal: the rolling meas_over_est median for
    one (flow, stage, domain) key left the drift band."""

    def __init__(self, flow: str, stage: str, domain: str,
                 median: float, band: tuple, n: int):
        self.flow, self.stage, self.domain = flow, stage, domain
        self.median, self.band, self.n = median, band, n
        self.recipe = _retune_recipe()
        super().__init__(
            f"CommProfile looks stale for ({flow}, {stage}, {domain}): "
            f"rolling median meas_over_est={median:.3g} over {n} samples "
            f"is outside [{band[0]:g}, {band[1]:g}]; {self.recipe}")


class DriftMonitor:
    """Accumulates meas_over_est residuals and warns once per stale key.

    Parameters
    ----------
    band:
        ``(lo, hi)`` acceptance band for the rolling median.
    window:
        Residuals retained per key (rolling deque).
    min_samples:
        Median is not judged before a key has this many residuals.
    require_measured:
        Only monitor estimates priced by an installed profile
        (``est_source == "measured"``).  Set False to track analytic
        estimates too (unit tests, exploratory runs).
    """

    def __init__(self, *, band=DEFAULT_BAND, window: int = 64,
                 min_samples: int = 8, require_measured: bool = True):
        self.band = (float(band[0]), float(band[1]))
        self.window = int(window)
        self.min_samples = int(min_samples)
        self.require_measured = bool(require_measured)
        self.residuals: dict[tuple, collections.deque] = {}
        self.warned: set[tuple] = set()

    # ------------------------------------------------------------ feeding
    def observe(self, flow: str, stage: str, domain: str,
                measured_s: float, estimated_s: float) -> None:
        if estimated_s <= 0.0 or measured_s < 0.0:
            return
        key = (flow, stage, domain)
        dq = self.residuals.get(key)
        if dq is None:
            dq = self.residuals[key] = collections.deque(maxlen=self.window)
        dq.append(measured_s / estimated_s)
        _metrics.inc("drift.observations")
        self._judge(key, dq)

    def observe_event(self, event, measured_s: float) -> None:
        """A live CommEvent paired with its measured wall seconds."""
        if self.require_measured and event.est_source != "measured":
            return
        domain = "dcn" if event.dcn_bytes > 0 else "ici"
        self.observe(event.flow, event.stage, domain,
                     measured_s, event.seconds)

    def observe_plan(self, plan, measured_s: float) -> None:
        """A whole ProgramPlan against one measured execution: the shared
        wall/plan ratio is filed under every op's (flow, stage, domain)."""
        if self.require_measured and plan.est_source != "measured":
            return
        if plan.seconds <= 0.0:
            return
        ratio = measured_s / plan.seconds
        for est in plan.estimates.values():
            key = (est.algorithm, est.stage, est.dominant())
            dq = self.residuals.get(key)
            if dq is None:
                dq = self.residuals[key] = \
                    collections.deque(maxlen=self.window)
            dq.append(ratio)
            _metrics.inc("drift.observations")
            self._judge(key, dq)

    # ------------------------------------------------------------ judging
    def _judge(self, key: tuple, dq: collections.deque) -> None:
        if key in self.warned or len(dq) < self.min_samples:
            return
        med = statistics.median(dq)
        if outside_band(med, self.band):
            self.warned.add(key)
            _metrics.inc("drift.stale_keys")
            warnings.warn(ProfileStalenessWarning(
                key[0], key[1], key[2], med, self.band, len(dq)),
                stacklevel=3)

    # ------------------------------------------------------------ reading
    def medians(self) -> dict:
        return {k: statistics.median(dq)
                for k, dq in sorted(self.residuals.items()) if dq}

    def stale(self) -> list:
        return sorted(self.warned)

    def summary(self) -> dict:
        """JSON-friendly snapshot (keys joined as flow/stage/domain)."""
        return {
            "band": list(self.band),
            "medians": {"/".join(k): round(v, 6)
                        for k, v in self.medians().items()},
            "samples": {"/".join(k): len(dq)
                        for k, dq in sorted(self.residuals.items())},
            "stale": ["/".join(k) for k in self.stale()],
        }


# ------------------------------------------------------ installed monitor
_MONITORS: list[DriftMonitor] = []


def active_monitor() -> DriftMonitor | None:
    return _MONITORS[-1] if _MONITORS else None


@contextlib.contextmanager
def install_monitor(monitor: DriftMonitor):
    """Make ``monitor`` the active drift monitor for the scope; live
    executions (serving engine steps) feed it automatically."""
    _MONITORS.append(monitor)
    try:
        yield monitor
    finally:
        _MONITORS.remove(monitor)


__all__ = [
    "DEFAULT_BAND", "DriftMonitor", "ProfileStalenessWarning",
    "active_monitor", "install_monitor", "outside_band", "underrun",
]
