"""Process-wide metrics registry: counters, gauges, fixed-bucket histograms.

Zero-dependency (stdlib only -- no jax, no numpy) so every layer of the
stack can import it unconditionally.  Two usage modes:

module-level instrumentation (default OFF)
    Hot paths call the module helpers (:func:`inc`, :func:`observe`,
    :func:`set_gauge`); each is a single ``if not _ENABLED: return`` branch
    when telemetry is off, so the disabled path adds no measurable overhead
    (asserted by the ``telemetry_overhead`` bench row).  :func:`enable` /
    :func:`disable` flip the switch; the helpers write to the *active*
    registry -- the process-wide :data:`REGISTRY` unless a
    :func:`scoped_metrics` scope pushed a fresh one (dryrun records a
    per-cell snapshot this way without polluting the global registry).

owned registries (always on)
    Long-lived components that already do equivalent bookkeeping
    (``serving.ServeEngine``) hold their own :class:`MetricsRegistry` and
    talk to instruments directly; the enabled flag does not apply.

Exports are deterministic: :meth:`MetricsRegistry.to_jsonl` (one sorted
JSON object per line) and :meth:`MetricsRegistry.to_prometheus` (text
exposition format) emit byte-identical output for equal registry state.

Histograms are fixed-bucket (cumulative ``le`` counts) but additionally
retain up to ``keep_samples`` raw observations so
:meth:`Histogram.quantile` can answer exact percentiles for bounded runs
(the serving bench's p50/p99 cells); past the cap it falls back to bucket
upper-bound interpolation.

Every name the instrumentation layer uses is declared in
:data:`DECLARED` -- the docs table (``docs/TELEMETRY.md``) is meta-tested
against it, so an undeclared metric is a test failure, not silent drift.
"""
from __future__ import annotations

import contextlib
import json
import math
import threading

# Default histogram buckets: exponential sweep over seconds, microsecond
# resolution at the bottom (collective estimates) to minutes at the top
# (whole train steps on the CPU substrate).
DEFAULT_BUCKETS = tuple(10.0 ** e for e in range(-7, 3))

# name -> (kind, help).  The single source of truth for the docs table and
# the meta-test; instrumentation sites must use declared names.
DECLARED: dict[str, tuple[str, str]] = {
    # core/comm.py -- one increment per executed (non-recorded) dispatch
    "comm.dispatches": ("counter", "Collective dispatches executed (eager "
                        "or program-replayed; recorded ops excluded)"),
    "comm.est_source.analytic": ("counter", "Dispatches priced by the "
                                 "analytic constants"),
    "comm.est_source.measured": ("counter", "Dispatches priced by an "
                                 "installed measured CommProfile"),
    # core/program.py -- lower-cache traffic and rewrite-pass yield
    "program.lowered": ("counter", "CommPrograms lowered from scratch "
                        "(lower-cache misses)"),
    "program.lower_cache_hits": ("counter", "CommProgram lowerings served "
                                 "by the structural-fingerprint cache"),
    "program.fused_ops": ("counter", "Lowered ops produced by rs+ag fusion "
                          "or the all_reduce split rewrite"),
    "program.coalesced_ops": ("counter", "Lowered ops produced by "
                              "same-group small-message coalescing"),
    "program.chained_ops": ("counter", "Lowered ops produced by the "
                            "multi-dim all_to_all merge"),
    # core/planner.py -- joint-plan pricing
    "planner.plan_program_calls": ("counter", "plan_program invocations"),
    "planner.plan_seconds_us": ("histogram", "Jointly-planned program "
                                "seconds (overlap-priced budget), in us"),
    "planner.serial_seconds_us": ("histogram", "Serial (sum of per-op "
                                  "estimates) program seconds, in us"),
    "planner.est_source.analytic": ("counter", "Program plans priced "
                                    "entirely by analytic constants"),
    "planner.est_source.mixed": ("counter", "Program plans with partial "
                                 "measured coverage"),
    "planner.est_source.measured": ("counter", "Program plans priced "
                                    "entirely from measured models"),
    # runtime/trainer.py -- step loop (split phases only under
    # TrainConfig.telemetry_split)
    "train.steps": ("counter", "Optimizer steps completed"),
    "train.step_seconds": ("histogram", "Wall seconds per train step"),
    "train.straggler_steps": ("counter", "Steps exceeding the straggler "
                              "deadline"),
    "train.fwd_seconds": ("histogram", "Wall seconds of the forward pass "
                          "(telemetry_split mode; timed separately)"),
    "train.fwd_bwd_seconds": ("histogram", "Wall seconds of the fused "
                              "forward+backward phase (telemetry_split "
                              "mode; reverse-mode AD interleaves fwd and "
                              "bwd in one computation -- bwd alone is "
                              "fwd_bwd minus fwd)"),
    "train.sync_seconds": ("histogram", "Wall seconds of the gradient-sync "
                           "phase (telemetry_split mode)"),
    "train.opt_seconds": ("histogram", "Wall seconds of the clip+AdamW "
                          "phase (telemetry_split mode)"),
    "train.sync_serial_est_us": ("gauge", "Planner estimate of the step's "
                                 "grad-sync wire time, all on the critical "
                                 "path (us; from the traced first step)"),
    "train.sync_exposed_est_us": ("gauge", "Planner estimate of the "
                                  "*exposed* grad-sync wire time under "
                                  "the overlap model: only the final "
                                  "bucket cannot hide under backward (us)"),
    # serving/engine.py -- per-engine registry (always on)
    "serve.steps": ("counter", "Engine decode steps"),
    "serve.generated_tokens": ("counter", "Generated (post-prefill) "
                               "tokens"),
    "serve.step_seconds": ("histogram", "Wall seconds per engine step"),
    "serve.token_seconds": ("histogram", "Per-token latency: the wall "
                            "seconds of the step that produced each "
                            "generated token"),
    "serve.tokens_per_s": ("gauge", "Aggregate decode throughput of the "
                           "last run() (tokens / wall second)"),
    "serve.admitted": ("counter", "Requests admitted into batch lanes "
                       "(re-admissions after preemption included)"),
    "serve.evicted": ("counter", "Finished requests evicted from lanes"),
    "serve.preempted": ("counter", "Preemptions (lazy admission: a dry "
                        "shard swapped out the youngest holder)"),
    "serve.page_occupancy": ("gauge", "Fraction of KV-cache pages in use "
                             "across all shard pools after this step's "
                             "allocation"),
    "serve.lower_cache_hit_ratio": ("gauge", "Cumulative hit ratio of the "
                                    "per-step program's lower-cache "
                                    "lookups"),
    # checkpoint/manager.py -- elastic checkpoint subsystem
    "ckpt.saves": ("counter", "Checkpoint saves dispatched"),
    "ckpt.restores": ("counter", "Checkpoint restores completed "
                      "(params-only restores included)"),
    "ckpt.save_seconds": ("histogram", "Wall seconds from save() dispatch "
                          "to the atomic rename (gather + write; runs on "
                          "the background executor when async)"),
    "ckpt.restore_seconds": ("histogram", "Wall seconds per restore: host "
                             "load plus program-scattered placement"),
    "ckpt.saved_bytes": ("gauge", "Host bytes gathered and written by the "
                         "last durable save"),
    "ckpt.restored_bytes": ("gauge", "Host bytes loaded and placed by the "
                            "last restore"),
    "ckpt.write_errors": ("counter", "Background save failures captured "
                          "for re-raise at wait()/next save()"),
    # telemetry/drift.py
    "drift.observations": ("counter", "meas_over_est residuals recorded by "
                           "the installed drift monitor"),
    "drift.stale_keys": ("counter", "(flow, stage, domain) keys whose "
                         "rolling median left the drift band"),
}


class Counter:
    """Monotonic counter."""

    kind = "counter"
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def inc(self, value: float = 1.0) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        self.value += value

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Point-in-time value."""

    kind = "gauge"
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed cumulative buckets plus a bounded raw-sample reservoir.

    ``quantile`` is exact (sorted-sample index ``min(n-1, ceil(q*n)-1)``,
    matching the serving engine's historical percentile formula) while the
    reservoir holds every observation; once ``keep_samples`` is exceeded it
    degrades to bucket upper-bound interpolation.
    """

    kind = "histogram"
    __slots__ = ("name", "help", "buckets", "bucket_counts", "count", "sum",
                 "keep_samples", "samples")

    def __init__(self, name: str, help: str = "",
                 buckets: tuple = DEFAULT_BUCKETS,
                 keep_samples: int = 65536):
        self.name, self.help = name, help
        self.buckets = tuple(sorted(buckets))
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +inf last
        self.count = 0
        self.sum = 0.0
        self.keep_samples = keep_samples
        self.samples: list[float] = []

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        for i, le in enumerate(self.buckets):
            if v <= le:
                self.bucket_counts[i] += 1
                break
        else:
            self.bucket_counts[-1] += 1
        if len(self.samples) < self.keep_samples:
            self.samples.append(v)

    def quantile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        if self.count <= len(self.samples):
            lat = sorted(self.samples)
            n = len(lat)
            return lat[min(n - 1, int(math.ceil(q * n)) - 1)]
        # truncated reservoir: cumulative-bucket upper bound
        target = int(math.ceil(q * self.count))
        seen = 0
        for i, c in enumerate(self.bucket_counts):
            seen += c
            if seen >= target:
                return (self.buckets[i] if i < len(self.buckets)
                        else self.buckets[-1])
        return self.buckets[-1]

    def snapshot(self) -> dict:
        cum, out = 0, {}
        for le, c in zip(self.buckets, self.bucket_counts):
            cum += c
            out[f"{le:g}"] = cum
        out["+Inf"] = self.count
        return {"type": "histogram", "count": self.count,
                "sum": self.sum, "buckets": out}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """A named collection of instruments with deterministic exports."""

    def __init__(self):
        self._instruments: dict[str, object] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------ get-or-create
    def _get(self, name: str, kind: str, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(name)
                if inst is None:
                    decl = DECLARED.get(name)
                    if decl is not None and decl[0] != kind:
                        raise TypeError(
                            f"metric {name!r} is declared as {decl[0]}, "
                            f"requested as {kind}")
                    help = decl[1] if decl else ""
                    inst = _KINDS[kind](name, help, **kw)
                    self._instruments[name] = inst
        if inst.kind != kind:
            raise TypeError(f"metric {name!r} is a {inst.kind}, "
                            f"not a {kind}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get(name, "gauge")

    def histogram(self, name: str, *, buckets: tuple = DEFAULT_BUCKETS
                  ) -> Histogram:
        return self._get(name, "histogram", buckets=buckets)

    def get(self, name: str):
        """The instrument, or None when it was never touched."""
        return self._instruments.get(name)

    # ------------------------------------------------------- conveniences
    def value(self, name: str) -> float:
        inst = self._instruments.get(name)
        return float(inst.value) if inst is not None else 0.0

    def quantile(self, name: str, q: float) -> float:
        inst = self._instruments.get(name)
        return inst.quantile(q) if inst is not None else 0.0

    def reset(self) -> None:
        self._instruments.clear()

    # ------------------------------------------------------------ exports
    def snapshot(self) -> dict:
        """name -> snapshot dict, sorted by name (deterministic)."""
        return {name: self._instruments[name].snapshot()
                for name in sorted(self._instruments)}

    def to_jsonl(self) -> str:
        """One sorted-key JSON object per metric, one per line."""
        lines = []
        for name, snap in self.snapshot().items():
            lines.append(json.dumps(dict(snap, name=name), sort_keys=True))
        return "\n".join(lines) + ("\n" if lines else "")

    def to_prometheus(self, *, prefix: str = "repro_") -> str:
        """Prometheus text exposition format (0.0.4)."""
        out = []
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            pname = prefix + name.replace(".", "_").replace("-", "_")
            if inst.help:
                out.append(f"# HELP {pname} {inst.help}")
            out.append(f"# TYPE {pname} {inst.kind}")
            if inst.kind in ("counter", "gauge"):
                out.append(f"{pname} {_fmt(inst.value)}")
            else:
                cum = 0
                for le, c in zip(inst.buckets, inst.bucket_counts):
                    cum += c
                    out.append(f'{pname}_bucket{{le="{le:g}"}} {cum}')
                out.append(f'{pname}_bucket{{le="+Inf"}} {inst.count}')
                out.append(f"{pname}_sum {_fmt(inst.sum)}")
                out.append(f"{pname}_count {inst.count}")
        return "\n".join(out) + ("\n" if out else "")


def _fmt(v: float) -> str:
    return f"{v:.10g}"


# -------------------------------------------- process-wide default registry
REGISTRY = MetricsRegistry()

_ENABLED = False
_SCOPED: list[MetricsRegistry] = []


def enable() -> None:
    """Turn the module-level instrumentation helpers on."""
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


def active_registry() -> MetricsRegistry:
    """The registry the module helpers write to: the innermost
    :func:`scoped_metrics` registry, else the process-wide one."""
    return _SCOPED[-1] if _SCOPED else REGISTRY


@contextlib.contextmanager
def scoped_metrics():
    """Enable telemetry into a fresh registry for the scope's duration;
    yields the registry (snapshot it on the way out).  Nests; restores the
    previous enabled state on exit."""
    global _ENABLED
    reg = MetricsRegistry()
    _SCOPED.append(reg)
    was = _ENABLED
    _ENABLED = True
    try:
        yield reg
    finally:
        _ENABLED = was
        _SCOPED.remove(reg)


def inc(name: str, value: float = 1.0) -> None:
    if not _ENABLED:
        return
    active_registry().counter(name).inc(value)


def observe(name: str, value: float) -> None:
    if not _ENABLED:
        return
    active_registry().histogram(name).observe(value)


def set_gauge(name: str, value: float) -> None:
    if not _ENABLED:
        return
    active_registry().gauge(name).set(value)


__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "DECLARED", "DEFAULT_BUCKETS", "active_registry", "disable", "enable",
    "enabled", "inc", "observe", "scoped_metrics", "set_gauge",
]
