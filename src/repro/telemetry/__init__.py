"""Unified telemetry: span timelines, metrics registry, drift monitors.

Pure-stdlib package (no jax imports) threaded through comm / program /
planner / trainer / serving.  See ``docs/TELEMETRY.md`` for the metric
catalogue and usage recipes.

* :mod:`repro.telemetry.spans` -- nested span timelines with Chrome-trace
  (Perfetto) and plain-text exports; ingests live CommEvents.
* :mod:`repro.telemetry.metrics` -- counters / gauges / fixed-bucket
  histograms with JSON-lines and Prometheus text exports; default-off
  module helpers plus per-component registries.
* :mod:`repro.telemetry.drift` -- rolling meas_over_est residuals per
  (flow, stage, domain) with structured profile-staleness warnings.
"""
from repro.telemetry.drift import (DEFAULT_BAND, DriftMonitor,
                                   ProfileStalenessWarning, active_monitor,
                                   install_monitor)
from repro.telemetry.metrics import (DECLARED, REGISTRY, MetricsRegistry,
                                     active_registry, inc, observe,
                                     scoped_metrics, set_gauge)
from repro.telemetry.metrics import disable as disable_metrics
from repro.telemetry.metrics import enable as enable_metrics
from repro.telemetry.metrics import enabled as metrics_enabled
from repro.telemetry.spans import (Tracer, current_tracer, maybe_instant,
                                   maybe_span)

__all__ = [
    "DECLARED", "DEFAULT_BAND", "DriftMonitor", "MetricsRegistry",
    "ProfileStalenessWarning", "REGISTRY", "Tracer", "active_monitor",
    "active_registry", "current_tracer", "disable_metrics",
    "enable_metrics", "inc", "install_monitor", "maybe_instant",
    "maybe_span", "metrics_enabled", "observe", "scoped_metrics",
    "set_gauge",
]
