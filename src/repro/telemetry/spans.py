"""Nested span timelines with Chrome-trace and plain-text exports.

A :class:`Tracer` is a zero-dependency (stdlib-only) span recorder.  While
active it also registers itself on the comm layer's trace stack, so every
live :class:`~repro.core.comm.CommEvent` lands as a child span of whatever
span is currently open -- carrying flow / stage / est_source / program_id /
fused_from provenance into the timeline.  Spans come in two time domains,
distinguished by the ``cat`` field rather than separate clocks:

* ``trace`` -- host-side work that happens at trace/lower/plan time
  (program recording, lowering passes, joint planning);
* ``wall``  -- wall-clock phases (dispatch, train/serve step loops).

Both are stamped with the same injectable monotonic clock (default
``time.perf_counter``); tests inject a fake clock so exports are
byte-deterministic.  CommEvent child spans get their *duration* from the
event's planner estimate (``event.seconds``) -- the timeline shows where
time is *expected* to go inside a step whose envelope is measured.

Exports:

* :meth:`Tracer.to_chrome_trace` / :meth:`Tracer.chrome_trace_json` --
  ``trace_event``-format JSON (complete ``"X"`` events plus ``"i"``
  instants), loadable in Perfetto / ``chrome://tracing``;
* :meth:`Tracer.timeline` -- an indented plain-text tree for CI logs.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time

_ACTIVE: list["Tracer"] = []


def current_tracer() -> "Tracer | None":
    """The innermost active tracer, or None."""
    return _ACTIVE[-1] if _ACTIVE else None


@contextlib.contextmanager
def maybe_span(name: str, cat: str = "wall", **args):
    """Open a span on the active tracer if there is one; no-op otherwise.

    The disabled path is one list check -- cheap enough for hot loops.
    """
    if not _ACTIVE:
        yield None
        return
    tr = _ACTIVE[-1]
    handle = tr.begin(name, cat=cat, **args)
    try:
        yield handle
    finally:
        tr.end(handle)


def maybe_instant(name: str, **args) -> None:
    """Record an instant annotation on the active tracer, if any."""
    if _ACTIVE:
        _ACTIVE[-1].instant(name, **args)


class _Span:
    __slots__ = ("name", "cat", "args", "ts", "dur", "depth", "ph", "tid")

    def __init__(self, name, cat, args, ts, depth, ph="X", dur=0.0, tid=1):
        self.name, self.cat, self.args = name, cat, args
        self.ts, self.dur, self.depth, self.ph = ts, dur, depth, ph
        self.tid = tid


class Tracer:
    """Records nested spans; context manager.

    Thread-aware: each OS thread gets its own open-span stack and its own
    exported ``tid`` lane (the thread that entered the tracer keeps the
    constructor's ``tid``; later threads get the next integers in first-use
    order), so background workers — e.g. the checkpoint save executor —
    can begin/end spans concurrently with the main loop without corrupting
    its nesting.  ``end()`` must be called on the span's own thread.

    Parameters
    ----------
    clock:
        Monotonic ``() -> float`` seconds source; defaults to
        ``time.perf_counter``.  Inject a fake for deterministic exports.
    pid, tid:
        Identifiers stamped on every exported trace event.
    """

    def __init__(self, clock=None, *, pid: int = 1, tid: int = 1):
        self.clock = clock if clock is not None else time.perf_counter
        self.pid, self.tid = pid, tid
        self._t0: float | None = None
        self._stacks: dict[int, list[_Span]] = {}
        self._tids: dict[int, int] = {}
        self._lock = threading.Lock()
        self._events: list[_Span] = []
        self.comm_events: list = []

    @property
    def _stack(self) -> list[_Span]:
        """This thread's open-span stack."""
        ident = threading.get_ident()
        stack = self._stacks.get(ident)
        if stack is None:
            stack = self._stacks.setdefault(ident, [])
        return stack

    def _tid_here(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.get(ident)
                if tid is None:
                    tid = self.tid if not self._tids \
                        else max(self._tids.values()) + 1
                    self._tids[ident] = tid
        return tid

    # ------------------------------------------------------------ lifecycle
    def __enter__(self) -> "Tracer":
        if self._t0 is None:
            self._t0 = self.clock()
        self._tid_here()  # the entering thread claims the base tid lane
        _ACTIVE.append(self)
        # Register on the comm trace stack so live CommEvents flow in.
        # Imported lazily: telemetry must stay importable without jax.
        from repro.core import comm as _comm
        _comm._TRACES.append(self)
        return self

    def __exit__(self, *exc) -> None:
        from repro.core import comm as _comm
        if self in _comm._TRACES:
            _comm._TRACES.remove(self)
        if self in _ACTIVE:
            _ACTIVE.remove(self)

    def _now_us(self) -> float:
        if self._t0 is None:
            self._t0 = self.clock()
        return round((self.clock() - self._t0) * 1e6, 3)

    # ------------------------------------------------------------ recording
    def begin(self, name: str, cat: str = "wall", **args) -> _Span:
        stack = self._stack
        sp = _Span(name, cat, args, self._now_us(), len(stack),
                   tid=self._tid_here())
        stack.append(sp)
        return sp

    def end(self, handle: _Span) -> None:
        stack = self._stack
        while stack:
            sp = stack.pop()
            sp.dur = round(self._now_us() - sp.ts, 3)
            self._events.append(sp)
            if sp is handle:
                return
        raise RuntimeError(f"span {handle.name!r} is not open on this thread")

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "wall", **args):
        handle = self.begin(name, cat=cat, **args)
        try:
            yield handle
        finally:
            self.end(handle)

    def instant(self, name: str, **args) -> None:
        self._events.append(_Span(name, "annotation", args,
                                  self._now_us(), len(self._stack), ph="i",
                                  tid=self._tid_here()))

    def record(self, event) -> None:
        """CommTrace duck-type hook: ingest a live CommEvent as a child
        span whose duration is the event's planner estimate."""
        self.comm_events.append(event)
        args = {
            "primitive": event.primitive,
            "bitmap": event.bitmap,
            "algorithm": event.algorithm,
            "flow": event.flow,
            "stage": event.stage,
            "est_source": event.est_source,
            "program_id": event.program_id,
            "fused_from": list(event.fused_from),
            "payload_bytes": event.payload_bytes,
            "ici_bytes": event.ici_bytes,
            "dcn_bytes": event.dcn_bytes,
            "est_seconds": event.seconds,
        }
        self._events.append(_Span(
            f"comm:{event.primitive}", "comm", args, self._now_us(),
            len(self._stack) + 1, dur=round(event.seconds * 1e6, 3),
            tid=self._tid_here()))

    # -------------------------------------------------------------- exports
    def finished(self) -> list:
        """Finished spans in deterministic (ts, then insertion) order."""
        return sorted(self._events, key=lambda s: (s.ts, s.depth))

    def to_chrome_trace(self) -> dict:
        events = []
        for sp in self.finished():
            ev = {"name": sp.name, "cat": sp.cat, "ph": sp.ph,
                  "ts": sp.ts, "pid": self.pid, "tid": sp.tid,
                  "args": sp.args}
            if sp.ph == "X":
                ev["dur"] = sp.dur
            else:
                ev["s"] = "t"
            events.append(ev)
        return {"displayTimeUnit": "ms", "traceEvents": events}

    def chrome_trace_json(self) -> str:
        """Deterministic serialization of :meth:`to_chrome_trace`."""
        return json.dumps(self.to_chrome_trace(), sort_keys=True, indent=1)

    def timeline(self) -> str:
        """Plain-text indented timeline for CI logs."""
        lines = []
        for sp in self.finished():
            pad = "  " * sp.depth
            if sp.ph == "i":
                head = f"{pad}@ {sp.name}"
            else:
                head = f"{pad}{sp.name} [{sp.cat}] {sp.dur:.1f}us"
            keys = ("flow", "stage", "est_source", "program_id")
            tail = " ".join(f"{k}={sp.args[k]}" for k in keys
                            if sp.args.get(k) is not None)
            lines.append(f"{head} {tail}".rstrip())
        return "\n".join(lines) + ("\n" if lines else "")


__all__ = ["Tracer", "current_tracer", "maybe_instant", "maybe_span"]
