"""Verification subsystem: NumPy golden oracles for the eight PID-Comm
primitives and a virtual-PE substrate for differential conformance testing.

``oracles``    pure-NumPy reference semantics, multi-instance included.
``substrate``  boots an N-device host-platform hypercube and runs per-shard
               collectives under shard_map for comparison against the oracles.
``paging``     pure-NumPy page-table + paged-view oracle for the serving
               subsystem's block KV cache (``repro.serving.pages``).
"""
