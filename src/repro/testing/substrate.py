"""Virtual-PE substrate: boot a multi-device hypercube on one host and run
per-shard collectives under ``shard_map`` for differential comparison
against the NumPy oracles.

The XLA host platform can emulate any device count
(``--xla_force_host_platform_device_count``), but only if the flag is set
*before* jax initializes its backends -- ``ensure_virtual_devices`` handles
the env var, ``tests/conftest.py`` calls it before anything imports jax.

Global layout matches :mod:`repro.testing.oracles`: arrays are
``(*cube.dim_sizes, *payload)``, fully sharded over the logical mesh, so
every PE's per-shard view is ``(1, ..., 1, *payload)`` and the runner's
output is directly comparable to an oracle result. Payload axis arguments
to the real collectives are therefore ``cube.ndim + payload_axis``.
"""
from __future__ import annotations

import os
from typing import Callable, Mapping, Sequence

import numpy as np

_FLAG = "--xla_force_host_platform_device_count"


def ensure_virtual_devices(n: int = 8) -> None:
    """Arrange for >= ``n`` host devices. Must run before jax initializes;
    raises with a recipe if jax is already up with too few devices."""
    flags = os.environ.get("XLA_FLAGS", "")
    if _FLAG not in flags:
        os.environ["XLA_FLAGS"] = f"{_FLAG}={n} {flags}".strip()
    import jax  # deferred: the env var must be set before backend init
    if jax.device_count() < n:
        raise RuntimeError(
            f"need {n} devices, have {jax.device_count()}; set "
            f"XLA_FLAGS={_FLAG}={n} before importing jax "
            "(tests/conftest.py does this for the suite)")


# ------------------------------------------------------------------- cubes
# The conformance shapes: a 1-D ring, a 2-D rectangle, a 3-D cube whose
# bitmap selections exercise multi-instance groups, and a pod-crossing cube
# whose outermost dim lives on the DCN (slow) domain.
CUBE_SPECS: Mapping[str, tuple[tuple[int, ...], tuple[str, ...], dict]] = {
    "ring8": ((8,), ("d",), {"d": 8}),
    "2x4": ((2, 4), ("data", "model"), {"r": 2, "c": 4}),
    "2x2x2": ((2, 2, 2), ("a", "b", "c"), {"a": 2, "b": 2, "c": 2}),
    "pod2x2x2": ((2, 2, 2), ("pod", "data", "model"),
                 {"pod": 2, "dp": 2, "tp": 2}),
    # 16-device shapes (subprocess sweeps only: the in-process suite boots
    # 8 virtual devices; tests/multidev16_check.py boots its own 16).
    "4d16": ((2, 2, 2, 2), ("w", "x", "y", "z"),
             {"w": 2, "x": 2, "y": 2, "z": 2}),
    "ring16": ((16,), ("d",), {"d": 16}),
    "pod2x4x2": ((2, 4, 2), ("pod", "data", "model"),
                 {"pod": 2, "dp": 4, "tp": 2}),
}


def build_cube(name: str):
    """Build one of the named conformance hypercubes."""
    from repro.compat import make_mesh
    from repro.core.hypercube import Hypercube
    shape, axes, dims = CUBE_SPECS[name]
    return Hypercube.build(make_mesh(shape, axes), dims)


class _FakeMesh:
    """Device-free Mesh stand-in: Hypercube.build only reads ``.devices``
    (shape + flat order) and ``.axis_names``, so a numpy arange works."""

    def __init__(self, shape, names):
        self.devices = np.arange(int(np.prod(shape))).reshape(shape)
        self.axis_names = names


def fake_cube(phys_shape, phys_names, dims):
    """Hypercube over a fake physical mesh -- exercises the mapping and
    validation logic (pod-boundary rule, power-of-two rule, planner inputs)
    for arbitrary device counts without touching jax device state."""
    import repro.core.hypercube as hc
    mesh = _FakeMesh(phys_shape, phys_names)
    orig = hc.Mesh
    hc.Mesh = lambda devs, names: type(
        "M", (), {"devices": devs, "axis_names": tuple(names)})()
    try:
        return hc.Hypercube.build(mesh, dims)
    finally:
        hc.Mesh = orig


# ------------------------------------------------------------------ layout
def global_spec(cube, payload_ndim: int):
    """PartitionSpec sharding every cube axis, payload unsharded."""
    from jax.sharding import PartitionSpec as P
    return P(*cube.dim_names, *([None] * payload_ndim))


def integer_payload(cube, payload_shape: Sequence[int], dtype=np.float32,
                    *, seed: int = 0, lo: int = -4, hi: int = 5
                    ) -> np.ndarray:
    """Global-layout array of small random integers. Integer values make
    fp32/bf16 sums exact, so different reduction orders (naive sequential,
    pr vectorized, im psum) must agree *bit-identically* -- the conformance
    suite's stage-equivalence contract."""
    rng = np.random.RandomState(seed)
    shape = tuple(cube.dim_sizes) + tuple(payload_shape)
    return rng.randint(lo, hi, shape).astype(dtype)


def run_per_shard(cube, fn: Callable, x: np.ndarray,
                  payload_ndim: int | None = None,
                  out_payload_ndim: int | None = None) -> np.ndarray:
    """Run per-shard ``fn`` under shard_map over ``cube`` on a global-layout
    array; returns the global-layout result as NumPy.

    In/out specs shard every cube axis, so each shard sees
    ``(1, ..., 1, *payload)`` and the output lands back in oracle layout
    (for group-replicated results, every member's copy is materialized --
    exactly what the oracles produce)."""
    import jax
    from repro.compat import shard_map
    if payload_ndim is None:
        payload_ndim = x.ndim - len(cube.dim_sizes)
    if out_payload_ndim is None:
        out_payload_ndim = payload_ndim
    fn_sharded = jax.jit(shard_map(
        fn, mesh=cube.mesh,
        in_specs=global_spec(cube, payload_ndim),
        out_specs=global_spec(cube, out_payload_ndim),
        check_vma=False))
    return np.asarray(fn_sharded(x))


def local_blocks(cube, arr) -> np.ndarray:
    """Per-PE local blocks of a sharded global array, in oracle layout
    ``(*cube.dim_sizes, *local_shape)`` -- used to check that rooted
    scatter/broadcast place the bytes the oracle says each PE owns."""
    devs = cube.mesh.devices
    by_id = {s.device.id: np.asarray(s.data) for s in arr.addressable_shards}
    sample = next(iter(by_id.values()))
    out = np.empty(devs.shape + sample.shape, sample.dtype)
    for coord in np.ndindex(*devs.shape):
        out[coord] = by_id[devs[coord].id]
    return out


def lowered_text(cube, fn: Callable, x: np.ndarray,
                 payload_ndim: int | None = None) -> str:
    """Lowered HLO of ``fn`` under shard_map -- for schedule assertions
    (e.g. the §IX-A hierarchical all-reduce must contain reduce-scatter and
    all-gather ops on the fast domain)."""
    import jax
    import jax.numpy as jnp
    from repro.compat import shard_map
    if payload_ndim is None:
        payload_ndim = x.ndim - len(cube.dim_sizes)
    spec = global_spec(cube, payload_ndim)
    return jax.jit(shard_map(
        fn, mesh=cube.mesh, in_specs=spec, out_specs=spec,
        check_vma=False)).lower(
            jax.ShapeDtypeStruct(x.shape, jnp.dtype(x.dtype))).as_text()
