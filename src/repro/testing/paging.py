"""Pure-NumPy oracle for the paged KV cache (:mod:`repro.serving.pages`).

Mirrors the host-side page-table semantics (shard-local block ownership,
lazy allocation, LIFO free lists, full-footprint admission math) and the
device-side view reconstruction (gather of a shard's local pages into its
contiguous cache extent, zero-filled where unallocated) with nothing but
NumPy, so the differential tests can check the jax implementation --
including the bit-identity of paged decode -- against an independently
written reference.
"""
from __future__ import annotations

import numpy as np


class PageTableOracle:
    """Reference page table: identical observable behaviour to
    ``repro.serving.pages.PageTable`` (same allocation order, same free-list
    discipline), implemented independently and minimally."""

    def __init__(self, page_size: int, pages_per_shard: int, n_shards: int,
                 S_cache: int, max_slots: int):
        if (S_cache // n_shards) % page_size:
            raise ValueError("page_size must divide the per-shard extent")
        self.page_size = page_size
        self.pages_per_shard = pages_per_shard
        self.n_shards = n_shards
        self.S_loc = S_cache // n_shards
        self.blocks_per_shard = self.S_loc // page_size
        self.n_blocks = self.blocks_per_shard * n_shards
        self.table = np.full((max_slots, self.n_blocks), -1, np.int32)
        self.free = [list(range(pages_per_shard - 1, -1, -1))
                     for _ in range(n_shards)]

    def owner(self, block: int) -> int:
        return block // self.blocks_per_shard

    def ensure(self, slot: int, cache_pos: int) -> bool:
        j = int(cache_pos) // self.page_size
        if self.table[slot, j] >= 0:
            return True
        if not self.free[self.owner(j)]:
            return False
        self.table[slot, j] = self.free[self.owner(j)].pop()
        return True

    def free_slot(self, slot: int) -> int:
        n = 0
        for j in range(self.n_blocks):
            if self.table[slot, j] >= 0:
                self.free[self.owner(j)].append(int(self.table[slot, j]))
                self.table[slot, j] = -1
                n += 1
        return n

    def blocks_needed(self, n_positions: int) -> list[int]:
        nb = min(-(-int(n_positions) // self.page_size), self.n_blocks)
        need = [0] * self.n_shards
        for j in range(nb):
            need[self.owner(j)] += 1
        return need

    def can_admit(self, n_positions: int) -> bool:
        return all(len(f) >= n for f, n in zip(self.free,
                                               self.blocks_needed(n_positions)))


def paged_view(pool: np.ndarray, table: np.ndarray, shard: int,
               page_size: int, blocks_per_shard: int) -> np.ndarray:
    """Reference for ``pages.gather_view``: one shard's local pool
    ``(n_units, pool_pages, page_size, *tail)`` plus the **global** table
    ``(B, n_blocks)`` -> that shard's contiguous ``(n_units, B, S_loc, *tail)``
    cache view, zeros where a block is unallocated."""
    n_units = pool.shape[0]
    tail = pool.shape[3:]
    B = table.shape[0]
    S_loc = blocks_per_shard * page_size
    out = np.zeros((n_units, B, S_loc) + tail, pool.dtype)
    myt = table[:, shard * blocks_per_shard:(shard + 1) * blocks_per_shard]
    for b in range(B):
        for jj in range(blocks_per_shard):
            pid = int(myt[b, jj])
            if pid >= 0:
                out[:, b, jj * page_size:(jj + 1) * page_size] = pool[:, pid]
    return out


__all__ = ["PageTableOracle", "paged_view"]
