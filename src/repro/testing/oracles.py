"""Pure-NumPy golden implementations of the eight PID-Comm primitives.

These are the independent references the conformance suite checks every
``(primitive, stage, dim-selection)`` cell of ``collectives.APPLICABILITY``
against, the way SimplePIM validates its PIM operators against host code.

Layout convention -- the paper's multi-instance block layout (§IV-B3):

  A *global* array has shape ``(*cube_shape, *payload)``: one leading axis
  per hypercube dimension (outermost first, matching
  ``Hypercube.dim_names``), then the per-PE local payload. Entry
  ``x[i0, i1, ..., ik]`` is PE ``(i0, ..., ik)``'s local block.

  A collective over ``group_axes`` (indices into the leading cube axes)
  runs one independent instance per assignment of the remaining (instance)
  axes -- the cube slices of §IV-B3. Group members are linearized in cube
  (major -> minor) order, which is how ``jax.lax`` linearizes a tuple of
  axis names, so oracle member ``r`` is the PE with
  ``lax.axis_index(dims) == r``.

Payload axis arguments (``axis`` / ``split_axis`` / ``concat_axis``) are
*payload-relative*: 0 is the first payload axis. Callers running the real
collectives inside ``shard_map`` over the same layout pass
``cube_ndim + axis`` instead, because per-shard arrays keep their leading
singleton cube axes.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

_REDUCE = {"add": np.sum, "max": np.max, "min": np.min}


def _norm_axes(cube_ndim: int, group_axes: Sequence[int]) -> tuple[int, ...]:
    axes = tuple(sorted(int(a) for a in group_axes))
    if len(set(axes)) != len(axes) or not axes:
        raise ValueError(f"bad group axes {group_axes}")
    if any(a < 0 or a >= cube_ndim for a in axes):
        raise ValueError(f"group axes {axes} outside cube ndim {cube_ndim}")
    return axes


def _to_group_view(x: np.ndarray, cube_ndim: int, axes: tuple[int, ...]):
    """(*cube, *payload) -> (G, *instance, *payload) plus the inverse perm.

    Group axes move to the front (cube order preserved) and flatten to one
    axis of size G; member r is the cube-order linearization of the selected
    coordinates, matching ``lax.axis_index`` over a tuple of names.
    """
    inst = tuple(i for i in range(cube_ndim) if i not in axes)
    perm = axes + inst + tuple(range(cube_ndim, x.ndim))
    y = np.transpose(x, perm)
    gshape = y.shape[:len(axes)]
    g = int(np.prod(gshape)) if gshape else 1
    y = y.reshape((g,) + y.shape[len(axes):])

    def inverse(z: np.ndarray) -> np.ndarray:
        """(G, *instance, *payload') -> (*cube, *payload')."""
        z = z.reshape(gshape + z.shape[1:])
        inv = np.argsort(perm)
        return np.transpose(z, inv)

    return y, g, inverse


def all_reduce(x: np.ndarray, cube_ndim: int, group_axes, op: str = "add"
               ) -> np.ndarray:
    """Every member of every group holds the group reduction. Same shape."""
    axes = _norm_axes(cube_ndim, group_axes)
    y, g, inv = _to_group_view(x, cube_ndim, axes)
    red = _REDUCE[op](y, axis=0, keepdims=True)
    return inv(np.broadcast_to(red, y.shape).copy())


def reduce_scatter(x: np.ndarray, cube_ndim: int, group_axes, *, axis: int,
                   op: str = "add") -> np.ndarray:
    """Member r keeps chunk r of the group reduction along payload ``axis``.
    Output payload axis shrinks by the group size."""
    axes = _norm_axes(cube_ndim, group_axes)
    y, g, inv = _to_group_view(x, cube_ndim, axes)
    pay_axis = (y.ndim - (x.ndim - cube_ndim)) + axis
    if y.shape[pay_axis] % g:
        raise ValueError(
            f"payload axis {axis} ({y.shape[pay_axis]}) not divisible by {g}")
    red = _REDUCE[op](y, axis=0)                        # (*inst, *payload)
    chunks = np.split(red, g, axis=pay_axis - 1)        # one axis gone
    return inv(np.stack(chunks, axis=0))


def all_gather(x: np.ndarray, cube_ndim: int, group_axes, *, axis: int
               ) -> np.ndarray:
    """Every member holds the group-order concatenation along ``axis``.
    Output payload axis grows by the group size."""
    axes = _norm_axes(cube_ndim, group_axes)
    y, g, inv = _to_group_view(x, cube_ndim, axes)
    pay_axis = (y.ndim - (x.ndim - cube_ndim)) + axis
    full = np.concatenate([y[r] for r in range(g)], axis=pay_axis - 1)
    return inv(np.broadcast_to(full[None], (g,) + full.shape).copy())


def all_to_all(x: np.ndarray, cube_ndim: int, group_axes, *,
               split_axis: int, concat_axis: int) -> np.ndarray:
    """Member j's output block i (along ``concat_axis``) is member i's input
    block j (along ``split_axis``) -- the paper's transpose semantics."""
    axes = _norm_axes(cube_ndim, group_axes)
    y, g, inv = _to_group_view(x, cube_ndim, axes)
    pay0 = y.ndim - (x.ndim - cube_ndim)        # first payload axis in view
    sa, ca = pay0 + split_axis, pay0 + concat_axis
    if y.shape[sa] % g:
        raise ValueError(
            f"split axis {split_axis} ({y.shape[sa]}) not divisible by {g}")
    b = y.shape[sa] // g
    # (G_src, ..., G_blk * b, ...) -> (G_src, G_blk, ..., b, ...)
    blocks = np.stack(np.split(y, g, axis=sa), axis=1)
    swapped = np.swapaxes(blocks, 0, 1)         # member j <- block j of all
    out = np.concatenate([swapped[:, s] for s in range(g)], axis=ca)
    return inv(out)


# ------------------------------------------------------------- rooted four
def scatter(host_value: np.ndarray, cube_shape: Sequence[int], group_axes, *,
            axis: int) -> np.ndarray:
    """Host -> PEs. Expected *local block* of every PE, in global layout:
    member r of the selected group gets chunk r of ``host_value`` along
    ``axis``; the result is replicated over the instance axes."""
    cube_shape = tuple(int(s) for s in cube_shape)
    cube_ndim = len(cube_shape)
    axes = _norm_axes(cube_ndim, group_axes)
    g = int(np.prod([cube_shape[a] for a in axes]))
    if host_value.shape[axis] % g:
        raise ValueError(
            f"axis {axis} ({host_value.shape[axis]}) not divisible by {g}")
    chunks = np.stack(np.split(host_value, g, axis=axis), axis=0)
    out = np.empty(cube_shape + chunks.shape[1:], chunks.dtype)
    gsizes = [cube_shape[a] for a in axes]
    for coord in np.ndindex(*cube_shape):
        r = 0
        for a, s in zip(axes, gsizes):
            r = r * s + coord[a]
        out[coord] = chunks[r]
    return out


def gather(local_blocks: np.ndarray, cube_ndim: int, group_axes, *,
           axis: int) -> np.ndarray:
    """PEs -> host: reassemble the global array from the per-PE blocks in
    global layout -- the inverse of :func:`scatter` (instance axis 0 slice)."""
    axes = _norm_axes(cube_ndim, group_axes)
    y, g, _ = _to_group_view(local_blocks, cube_ndim, axes)
    inst_ndim = cube_ndim - len(axes)
    first = y[(slice(None),) + (0,) * inst_ndim]     # instance-replicated
    pay_axis = axis
    return np.concatenate([first[r] for r in range(g)], axis=pay_axis)


def reduce(x: np.ndarray, *, axis: int = 0, op: str = "add") -> np.ndarray:
    """PEs -> host: reduction of the global array over the sharded axis
    (the runtime's rooted reduce runs on the global view at the jit
    boundary, so the oracle is a plain NumPy reduction)."""
    return _REDUCE[op](x, axis=axis)


def broadcast(host_value: np.ndarray, cube_shape: Sequence[int]
              ) -> np.ndarray:
    """Host -> PEs: every PE holds the full buffer."""
    cube_shape = tuple(int(s) for s in cube_shape)
    return np.broadcast_to(
        host_value, cube_shape + host_value.shape).copy()


# ------------------------------------------------------- reshard (checkpoint)
def placed_shard(x: np.ndarray, cube_shape: Sequence[int],
                 dim_names: Sequence[str], spec, coords: Sequence[int]
                 ) -> np.ndarray:
    """The block PE ``coords`` holds of the *global* array ``x`` under a
    PartitionSpec-shaped ``spec`` (one entry per array axis: ``None`` /
    dim name / tuple of dim names, missing trailing axes replicated).

    This is the pure-NumPy reshard oracle for elastic checkpoint restore:
    a checkpoint holds the global value, and a restore onto any cube must
    leave exactly this block on each PE.  Multi-name entries linearize
    cube-major (outer dim varies slowest), matching ``NamedSharding``.
    """
    cube_shape = tuple(int(s) for s in cube_shape)
    sizes = dict(zip(dim_names, cube_shape))
    pos = dict(zip(dim_names, (int(c) for c in coords)))
    entries = tuple(spec) + (None,) * (x.ndim - len(tuple(spec)))
    idx = []
    for axis, entry in enumerate(entries):
        names = () if entry is None else (
            (entry,) if isinstance(entry, str) else tuple(entry))
        groups = 1
        rank = 0
        for n in names:
            groups *= sizes[n]
            rank = rank * sizes[n] + pos[n]
        if x.shape[axis] % groups:
            raise ValueError(
                f"axis {axis} of {x.shape} not divisible by {groups} "
                f"(spec entry {entry!r})")
        block = x.shape[axis] // groups
        idx.append(slice(rank * block, (rank + 1) * block))
    return x[tuple(idx)]


def reshard(x: np.ndarray, cube_shape: Sequence[int],
            dim_names: Sequence[str], spec) -> dict:
    """Every PE's block of ``x`` on the target cube: ``coords -> shard``.
    The full placement map an elastic restore must realize."""
    cube_shape = tuple(int(s) for s in cube_shape)
    return {tuple(int(c) for c in coords):
            placed_shard(x, cube_shape, dim_names, spec, coords)
            for coords in np.ndindex(*cube_shape)}
