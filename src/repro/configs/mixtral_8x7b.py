"""Mixtral 8x7B [arXiv:2401.04088; hf]: 32L d4096 32H GQA(kv=8) per-expert
d_ff 14336, vocab 32000, 8 experts top-2, sliding-window attention 4096."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    n_experts=8, top_k=2, d_ff_expert=14336, moe_period=1,
    window=4096,                      # SWA: bounded KV => long-context capable
    rope_theta=1e6,
    tp=16, ep=8, etp=2,               # model axis 16 = 8 experts x 2-way etp
    subquadratic=True,
)
