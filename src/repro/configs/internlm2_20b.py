"""InternLM2-20B [arXiv:2403.17297]: 48L d6144 48H GQA(kv=8) d_ff 16384,
vocab 92544."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92544,
    rope_theta=1e6,
    tp=16,
)
