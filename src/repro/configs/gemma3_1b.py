"""Gemma3-1B [hf:google/gemma-3-1b-pt; unverified]: 26L d1152 4H GQA(kv=1)
d_ff 6912, vocab 262144, 5:1 local:global attention (local window 512)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    local_global_ratio=5, local_window=512, rope_theta=1e6,
    tie_embeddings=True,
    tp=4,                              # 4 q heads bound the head parallelism
    subquadratic=True,                 # local layers bounded; global layers
                                       # decode via seq-sharded flash-decode
)
