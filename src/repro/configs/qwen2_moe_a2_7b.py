"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d2048 16H GQA(kv=16)
expert d_ff 1408, vocab 151936, 60 routed experts top-4 + 4 shared experts."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=151936,
    n_experts=60, top_k=4, n_shared_experts=4, d_ff_expert=1408, moe_period=1,
    rope_theta=1e6,
    tp=16, ep=16, etp=1,              # 60 -> 64 padded experts, 4 per shard
)
