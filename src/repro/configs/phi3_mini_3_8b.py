"""Phi-3-mini 3.8B [arXiv:2404.14219; unverified]: 32L d3072 32H GQA(kv=32)
d_ff 8192, vocab 32064, RoPE + SwiGLU."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    rope_theta=1e4,
    tp=16,
)
