"""Assigned architecture registry: one module per architecture.

Each module exports ``CONFIG`` (the exact published configuration) --
selectable via ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "mixtral_8x7b",
    "qwen2_moe_a2_7b",
    "qwen3_1_7b",
    "gemma3_1b",
    "internlm2_20b",
    "phi3_mini_3_8b",
    "llava_next_34b",
    "whisper_base",
    "rwkv6_7b",
    "jamba_1_5_large",
)

# canonical ids as given in the assignment
ALIASES = {
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen3-1.7b": "qwen3_1_7b",
    "gemma3-1b": "gemma3_1b",
    "internlm2-20b": "internlm2_20b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "llava-next-34b": "llava_next_34b",
    "whisper-base": "whisper_base",
    "rwkv6-7b": "rwkv6_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
}


def get(arch: str):
    mod = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if mod not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; know {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


def all_configs():
    return {a: get(a) for a in ARCH_IDS}
