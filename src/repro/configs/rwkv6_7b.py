"""RWKV6 (Finch) 7B [arXiv:2404.05892]: 32L d4096 attention-free
(data-dependent decay linear attention), channel-mix d_ff 14336,
vocab 65536."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0, head_dim=64,
    d_ff=14336, vocab_size=65536,
    mixer_pattern="r", rwkv_head_dim=64,
    tp=16, serve_tp=64,
    subquadratic=True,
)
