"""Jamba-1.5-Large 398B [arXiv:2403.19887]: 72L d8192, Mamba:attention 7:1
interleave (one attention layer per 8), 64H GQA(kv=8), MoE every 2nd layer
(16 experts top-2, expert d_ff 24576), vocab 65536."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    mixer_pattern="mmmmAmmm",          # attention at position 5 of each 8
    n_experts=16, top_k=2, d_ff_expert=24576, moe_period=2,
    d_state=16, mamba_expand=2, conv_kernel=4,
    rope_theta=1e6,
    tp=16, ep=16, etp=1,
    subquadratic=True,                 # mamba state O(1); 9 attn layers
                                       # decode via seq-sharded flash-decode
)
