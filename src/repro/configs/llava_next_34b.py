"""LLaVA-NeXT-34B backbone [hf:llava-hf/llava-v1.6-*; unverified]: 60L d7168
56H GQA(kv=8) d_ff 20480, vocab 64000; anyres patch frontend is a STUB --
input_specs feeds precomputed patch embeddings (CLIP-L hidden 1024)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    rope_theta=5e6,
    frontend="patch", frontend_tokens=2880, frontend_dim=1024,
    tp=8,                              # 56 heads: 7 per shard
)
