"""Whisper-base [arXiv:2212.04356; unverified]: 6L enc + 6L dec, d512 8H
d_ff 2048, vocab 51865; conv frontend is a STUB -- input_specs feeds
precomputed log-mel frame embeddings (80-dim), projected linearly."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    is_encoder_decoder=True, n_enc_layers=6,
    frontend="audio", frontend_dim=80, frontend_tokens=0,
    rope_theta=1e4,
    tp=8,
)
