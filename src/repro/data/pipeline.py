"""Deterministic sharded synthetic data pipeline.

Every (step, shard) pair maps to a unique counter-based stream (threefry via
jax.random on CPU-side numpy is too slow at scale; we use a splitmix64-style
hash), so:
  * shards are disjoint by construction,
  * resume-after-restart needs only the step number (no iterator state),
  * elastic re-sharding (different dp degree after restart) re-partitions the
    same global stream deterministically.

The stream mimics a tokenized corpus: Zipfian token ids + document breaks,
next-token labels, pad tails. Frontend stubs (patches/frames) are hashed from
the same counters.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.models.config import ModelConfig

_MASK = (1 << 64) - 1


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + 0x9E3779B97F4A7C15) & _MASK
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
    return z ^ (z >> 31)


def _hash_u64(counters: np.ndarray, salt: int) -> np.ndarray:
    return _splitmix64((counters.astype(np.uint64) ^ np.uint64(salt)))


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    doc_len_mean: int = 512


class TokenStream:
    """Global synthetic stream; slice per host/shard as needed."""

    def __init__(self, cfg: ModelConfig, dc: DataConfig):
        self.cfg, self.dc = cfg, dc

    def global_batch_at(self, step: int) -> dict[str, np.ndarray]:
        dc = self.dc
        B, S = dc.global_batch, dc.seq_len
        base = (np.uint64(step) << np.uint64(32)) ^ np.uint64(dc.seed)
        counters = (base + np.arange(B * (S + 1), dtype=np.uint64)
                    ).reshape(B, S + 1)
        u = _hash_u64(counters, 0xA5A5)
        # Zipf-ish: id = floor(V * (u01 ** 3)) concentrates mass at low ids
        u01 = (u >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        ids = np.minimum((dc.vocab_size * (u01 ** 3.0)).astype(np.int64),
                         dc.vocab_size - 1)
        # document breaks -> loss masking across docs (label -1)
        brk = (_hash_u64(counters, 0x5A5A) % np.uint64(dc.doc_len_mean)) == 0
        tokens = ids[:, :S].astype(np.int32)
        labels = ids[:, 1:].astype(np.int32)
        labels = np.where(brk[:, 1:], -1, labels)
        out = {"tokens": tokens, "labels": labels}
        if self.cfg.frontend == "patch":
            F, fd = self.cfg.frontend_tokens, self.cfg.frontend_dim
            pc = (base + np.uint64(1 << 20)
                  + np.arange(B * F * fd, dtype=np.uint64)).reshape(B, F, fd)
            out["patches"] = (
                (_hash_u64(pc, 0x77) >> np.uint64(40)).astype(np.float32)
                / float(1 << 24) - 0.5)
            # patch positions carry no next-token loss
            out["labels"][:, :F] = -1
        if self.cfg.is_encoder_decoder:
            fd = self.cfg.frontend_dim
            fc = (base + np.uint64(1 << 21)
                  + np.arange(B * S * fd, dtype=np.uint64)).reshape(B, S, fd)
            out["frames"] = (
                (_hash_u64(fc, 0x99) >> np.uint64(40)).astype(np.float32)
                / float(1 << 24) - 0.5)
        return out

    def shard_batch_at(self, step: int, shard: int, n_shards: int):
        """The rows of the global batch owned by ``shard`` -- what each host
        feeds its local devices. Disjoint across shards by slicing."""
        g = self.global_batch_at(step)
        B = self.dc.global_batch
        assert B % n_shards == 0, (B, n_shards)
        lo = shard * (B // n_shards)
        hi = lo + B // n_shards
        return {k: v[lo:hi] for k, v in g.items()}

    def batches(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.global_batch_at(step)
            step += 1
