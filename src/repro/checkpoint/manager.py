"""Sharded, atomic, async-capable checkpointing with elastic restore.

Layout (one directory per step):

    <root>/step_000100.tmp/     -> renamed atomically to step_000100/
        manifest.json           # step, tree structure, shapes/dtypes, cube
        arr_<i>.npy             # one file per leaf (host-gathered)

Restore takes a *target* topology that may differ from the one that saved
(elastic scaling): leaves are re-sharded via pidcomm Scatter (device_put with
the new NamedSharding). Data-stream resume needs only the step number
(see repro.data.pipeline).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

Array = jax.Array


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, root: str, *, async_save: bool = True,
                 keep_last: int = 3):
        self.root = root
        self.async_save = async_save
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------ io
    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def save(self, step: int, params, opt_state, *, extra: dict | None = None):
        """Gather to host and write. Atomic via tmp-dir rename."""
        tree = {"params": params, "opt": opt_state}
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]

        # jax flattens the {"opt", "params"} dict in sorted-key order, so
        # the opt leaves occupy a contiguous prefix and the params leaves a
        # contiguous suffix; recording the section sizes lets a params-only
        # consumer (restore-for-serving) address its leaves without an
        # opt_state skeleton
        n_opt = len(jax.tree.leaves(opt_state))

        def write():
            tmp = self._dir(step) + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            for i, a in enumerate(host):
                np.save(os.path.join(tmp, f"arr_{i}.npy"), a)
            manifest = {
                "step": step,
                "n_leaves": len(host),
                "sections": {"opt": n_opt, "params": len(host) - n_opt},
                "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
                if False else None,
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = self._dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if self.async_save:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, params_like, opt_like, *, topo=None,
                param_specs=None, opt_specs=None):
        """Restore into the structure of (params_like, opt_like). If ``topo``
        and spec trees are given, leaves are placed with the *target*
        sharding (elastic restore onto a different mesh/hypercube)."""
        self.wait()
        d = self._dir(step)
        tree = {"params": params_like, "opt": opt_like}
        leaves, treedef = _flatten(tree)
        out = []
        specs = None
        if topo is not None and param_specs is not None:
            specs, _ = _flatten({"params": param_specs, "opt": opt_specs})
        for i, like in enumerate(leaves):
            a = np.load(os.path.join(d, f"arr_{i}.npy"))
            if specs is not None:
                out.append(jax.device_put(a, topo.cube.sharding(specs[i])))
            else:
                out.append(jax.numpy.asarray(a))
        tree = jax.tree.unflatten(treedef, out)
        return tree["params"], tree["opt"]

    def restore_params(self, step: int, params_like, *, topo=None,
                       param_specs=None):
        """Restore **params only** onto a target topology -- the
        restore-for-serving path: a checkpoint saved on the train cube loads
        directly onto ``build_serve_topology``'s cube (pass the *serve*
        topology and the serve-side ``param_specs(cfg, serve_topo)``), each
        leaf re-sharded by ``device_put`` with the target NamedSharding, no
        manual re-sharding and no optimizer-state skeleton required.

        Leaf addressing uses the manifest's ``sections`` (params leaves are
        the trailing section of the flat order); checkpoints from before
        sections were recorded fall back to ``n_leaves - len(params leaves)``,
        which is the same offset because ``"params"`` sorts after ``"opt"``
        in the save-time flatten.
        """
        self.wait()
        d = self._dir(step)
        leaves, treedef = _flatten(params_like)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        sections = manifest.get("sections")
        n_params = (sections["params"] if sections else len(leaves))
        if n_params != len(leaves):
            raise ValueError(
                f"checkpoint step {step} holds {n_params} params leaves but "
                f"the target structure has {len(leaves)} -- architecture "
                "mismatch between save and restore")
        offset = manifest["n_leaves"] - n_params
        specs = None
        if topo is not None and param_specs is not None:
            specs, _ = _flatten(param_specs)
        out = []
        for i in range(len(leaves)):
            a = np.load(os.path.join(d, f"arr_{offset + i}.npy"))
            if specs is not None:
                out.append(jax.device_put(a, topo.cube.sharding(specs[i])))
            else:
                out.append(jax.numpy.asarray(a))
        return jax.tree.unflatten(treedef, out)
