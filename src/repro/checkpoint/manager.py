"""Topology-bound checkpoint manager: async save, elastic restore.

The redesigned surface binds placement once at construction::

    mgr = CheckpointManager(root, topo=topo, specs=TrainState(params=pspecs,
                                                              opt=ospecs))
    mgr.save(step, TrainState(params=params, opt=opt_state))
    state = mgr.restore(step)                       # onto mgr's topology
    params = mgr.restore_params(step, serve_topo=stopo, specs=sspecs)

and the state tree is a single :class:`TrainState` instead of parallel
``params``/``opt_state`` arguments.  The pre-redesign positional
signatures — ``save(step, params, opt_state)``, ``restore(step,
params_like, opt_like, topo=..., param_specs=..., opt_specs=...)`` and
``restore_params(step, params_like, topo=..., param_specs=...)`` — keep
working as deprecated shims (``DeprecationWarning``, same pattern as
``core.collectives.Collectives``).

Data movement is collective programs (:mod:`repro.checkpoint.reshard`):
save records one rooted-gather CommProgram per section, restore one
rooted-scatter program per section planned under the installed
CommProfile, with ``program_id`` provenance on every CommEvent.

**Async save** splits along the donation boundary: the gather programs
execute at ``save()`` dispatch — the train step donates its params/opt
buffers, so the device→host copy must complete before the next step runs —
while serialization and disk writes run on a bounded background executor
(``checkpoint:{section}`` spans, ``ckpt.*`` metrics).  Worker failures are
captured and re-raised at ``wait()`` or the next ``save()``, never
swallowed in the thread.  The manifest is written and the ``.tmp``
directory renamed only after every section landed, so a killed-mid-write
checkpoint is invisible to ``all_steps()``/``restore()`` and simply
overwritten by the retry.
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import time
import warnings
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import layout, reshard
from repro.telemetry import metrics as _telemetry
from repro.telemetry import spans as _spans

Array = jax.Array


@dataclasses.dataclass
class TrainState:
    """The checkpointed unit: model params plus optimizer state, one tree."""
    params: Any
    opt: Any = None


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt), None),
    lambda _, children: TrainState(*children),
)


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new} (topology-bound CheckpointManager "
        "surface)", DeprecationWarning, stacklevel=3)


class CheckpointManager:
    """Sharded, atomic, async-capable checkpointing with elastic restore.

    Parameters
    ----------
    root:
        Checkpoint directory (one ``step_<n>`` subdirectory per step).
    topo:
        The topology (or bare Hypercube) whose cube save gathers from and
        restore scatters onto.  ``None`` falls back to a plain host loop
        (``device_get`` / ``jnp.asarray``) with no recorded programs.
    specs:
        TrainState-shaped tree of PartitionSpecs for restore placement
        (also accepted as ``{"params": ..., "opt": ...}``).
    keep_last:
        GC horizon: completed checkpoints beyond the newest ``keep_last``
        are deleted after each successful save.  The step currently being
        written is never collected.
    max_workers:
        Bound on the background write executor.
    """

    def __init__(self, root: str, *, topo=None, specs=None,
                 async_save: bool = True, keep_last: int = 3,
                 max_workers: int = 2):
        self.root = root
        self.topo = topo
        self.specs = specs
        self.async_save = async_save
        self.keep_last = keep_last
        self.max_workers = max(1, int(max_workers))
        self._executor: ThreadPoolExecutor | None = None
        self._pending: list[Future] = []
        self._writing: set[int] = set()
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------ io
    def _dir(self, step: int) -> str:
        return layout.step_dir(self.root, step)

    def _ensure_executor(self) -> ThreadPoolExecutor:
        if self._executor is None:
            self._executor = ThreadPoolExecutor(
                max_workers=self.max_workers,
                thread_name_prefix="ckpt-write")
        return self._executor

    def _specs_sections(self) -> dict | None:
        return _sections_of(self.specs) if self.specs is not None else None

    # ---------------------------------------------------------------- save
    def save(self, step: int, state, opt_state=None, *,
             extra: dict | None = None) -> None:
        """Write ``state`` (a :class:`TrainState`) as checkpoint ``step``.

        Gathers to host via one rooted-gather program per section at
        dispatch, then (``async_save``) hands serialization and the atomic
        rename to the background executor.  The deprecated form
        ``save(step, params, opt_state)`` still works.
        """
        if opt_state is not None or not isinstance(state, TrainState):
            _deprecated("save(step, params, opt_state)",
                        "save(step, TrainState(params=..., opt=...))")
            state = TrainState(params=state, opt=opt_state)
        self.wait()  # one save in flight; re-raises captured write errors
        t0 = time.monotonic()
        _telemetry.inc("ckpt.saves")

        tree = {"opt": state.opt, "params": state.params}
        leaves, _ = jax.tree.flatten(tree)
        n_opt = len(jax.tree.leaves(state.opt))
        records = layout.leaf_records(tree)
        manifest = layout.build_manifest(
            step, records, n_opt=n_opt, cube_dims=self._cube_dims(),
            extra=extra)

        # device -> host: one recorded rooted-gather program per section.
        # The program's structural fingerprint is step-invariant, so this
        # lowers once and then hits the cube's lower cache every save.
        # Runs at dispatch because the train step donates these buffers.
        sections = {"opt": (0, n_opt), "params": (n_opt, len(leaves))}
        host: list[np.ndarray] = [None] * len(leaves)  # type: ignore
        for name, (lo, hi) in sections.items():
            if hi == lo:
                continue
            with _spans.maybe_span(f"checkpoint:gather:{name}", cat="wall",
                                   step=step, leaves=hi - lo):
                if self.topo is not None:
                    host[lo:hi] = reshard.gather_to_host(
                        self.topo, leaves[lo:hi],
                        name=f"ckpt-gather-{name}")
                else:
                    host[lo:hi] = [np.asarray(jax.device_get(l))
                                   for l in leaves[lo:hi]]

        tmp = self._dir(step) + ".tmp"
        if os.path.exists(tmp):  # debris from a killed writer: retry wins
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        self._writing.add(step)

        def write_section(name: str, lo: int, hi: int) -> int:
            with _spans.maybe_span(f"checkpoint:{name}", cat="wall",
                                   step=step, leaves=hi - lo):
                nbytes = 0
                for i in range(lo, hi):
                    np.save(os.path.join(tmp, f"arr_{i}.npy"), host[i])
                    nbytes += host[i].nbytes
            return nbytes

        def finalize(section_bytes: list[int]) -> None:
            try:
                layout.write_manifest(tmp, manifest)
                layout.atomic_finalize(tmp, self._dir(step))
                total = int(sum(section_bytes))
                _telemetry.set_gauge("ckpt.saved_bytes", total)
                _telemetry.observe("ckpt.save_seconds",
                                   time.monotonic() - t0)
                _spans.maybe_instant("checkpoint-durable", step=step,
                                     bytes=total)
            finally:
                self._writing.discard(step)
            self._gc(protect={step})

        spans = sections.items()
        if self.async_save:
            ex = self._ensure_executor()
            futs = [ex.submit(write_section, name, lo, hi)
                    for name, (lo, hi) in spans if hi > lo]

            def run_finalize(section_futs=tuple(futs)):
                # FIFO executor: the sections queued above finish (or fail)
                # before this task runs its .result() calls, so this never
                # blocks a worker on a task behind it in the queue
                finalize([f.result() for f in section_futs])

            self._pending = futs + [ex.submit(run_finalize)]
        else:
            try:
                finalize([write_section(name, lo, hi)
                          for name, (lo, hi) in spans if hi > lo])
            finally:
                self._writing.discard(step)

    def wait(self) -> None:
        """Block until the in-flight save is durable; re-raise the first
        captured write error (each error is surfaced exactly once)."""
        pending, self._pending = self._pending, []
        errors: list[BaseException] = []
        for f in pending:
            try:
                f.result()
            except BaseException as e:  # noqa: BLE001 - re-raised below
                if all(e is not seen for seen in errors):
                    errors.append(e)
        if errors:
            _telemetry.inc("ckpt.write_errors", len(errors))
            raise errors[0]

    def _gc(self, *, protect: set[int] = frozenset()) -> None:
        steps = self.all_steps()
        keep = set(steps[-self.keep_last:]) if self.keep_last > 0 \
            else set(steps)
        for s in steps:
            if s in keep or s in protect or s in self._writing:
                continue
            shutil.rmtree(self._dir(s), ignore_errors=True)

    def all_steps(self) -> list[int]:
        return layout.list_steps(self.root)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _cube_dims(self) -> dict | None:
        cube = getattr(self.topo, "cube", self.topo)
        if cube is None or not hasattr(cube, "dim_names"):
            return None
        return dict(zip(cube.dim_names, cube.dim_sizes))

    # ------------------------------------------------------------- restore
    def restore(self, step: int, params_like=None, opt_like=None, *,
                topo=None, param_specs=None, opt_specs=None):
        """Restore checkpoint ``step``.

        New surface: ``restore(step)`` returns a :class:`TrainState` placed
        on the manager's bound topology under its bound specs (structure
        from the specs tree, falling back to the manifest's leaf records).

        Deprecated shim: ``restore(step, params_like, opt_like, ...)``
        returns the old ``(params, opt)`` tuple.
        """
        if params_like is not None:
            _deprecated("restore(step, params_like, opt_like)",
                        "restore(step)")
            like = {"opt": opt_like, "params": params_like}
            specs = None
            if topo is not None and param_specs is not None:
                specs = {"opt": opt_specs, "params": param_specs}
            state = self._restore_state(step, like=like, specs=specs,
                                        topo=topo)
            return state.params, state.opt
        return self._restore_state(step, like=None,
                                   specs=self._specs_sections(),
                                   topo=self.topo)

    def restore_params(self, step: int, params_like=None, *,
                       serve_topo=None, specs=None, topo=None,
                       param_specs=None):
        """Restore **params only** — the restore-for-serving path.

        New surface: ``restore_params(step, serve_topo=stopo, specs=sspecs)``
        places the params section onto the serve topology (defaults to the
        manager's bound topology/specs when omitted).  Elastic: the serve
        cube may have different dims than the cube that saved.

        Deprecated shim: ``restore_params(step, params_like, topo=...,
        param_specs=...)``.
        """
        if params_like is not None:
            _deprecated("restore_params(step, params_like)",
                        "restore_params(step, serve_topo=..., specs=...)")
            serve_topo, specs = topo, param_specs
            like = params_like
        else:
            like = None
            if serve_topo is None:
                serve_topo = self.topo
            if specs is None:
                bound = self._specs_sections()
                specs = bound["params"] if bound else None
        return self._restore_section(step, "params", like=like,
                                     specs=specs, topo=serve_topo)

    # ------------------------------------------------------ restore internals
    def _load_manifest(self, step: int) -> dict:
        d = self._dir(step)
        if not os.path.isdir(d):
            raise FileNotFoundError(
                f"no checkpoint for step {step} under {self.root} "
                f"(have steps {self.all_steps()})")
        return layout.read_manifest(d)

    def _restore_state(self, step: int, *, like, specs, topo) -> TrainState:
        self.wait()
        t0 = time.monotonic()
        manifest = self._load_manifest(step)
        n_leaves = int(manifest["n_leaves"])
        n_opt = int(manifest["sections"]["opt"])
        records = manifest.get("leaves")

        if like is not None:
            flat_like, treedef = jax.tree.flatten(like)
            if records is not None:
                layout.validate_records(records, layout.leaf_records(like),
                                        section="state", step=step)
            elif len(flat_like) != n_leaves:
                raise ValueError(
                    f"checkpoint step {step} holds {n_leaves} state leaves "
                    f"but the target structure has {len(flat_like)} -- "
                    "architecture mismatch between save and restore")
            n = len(flat_like)
        elif specs is not None:
            treedef, n = _spec_treedef(specs)
            if n != n_leaves:
                raise ValueError(
                    f"checkpoint step {step} holds {n_leaves} state leaves "
                    f"but the bound specs tree has {n} -- architecture "
                    "mismatch between save and restore")
        elif records is not None:
            tree = layout.tree_from_records(
                records, list(range(n_leaves)))
            flat, treedef = jax.tree.flatten(tree)
            if flat != list(range(n_leaves)):
                raise ValueError(
                    "manifest leaf records do not reconstruct a stable "
                    "flat order; pass specs= to CheckpointManager")
            n = n_leaves
        else:
            raise ValueError(
                "checkpoint manifest predates leaf records; pass specs= to "
                "CheckpointManager or use the deprecated "
                "restore(step, params_like, opt_like) form")

        d = self._dir(step)
        host = [np.load(os.path.join(d, f"arr_{i}.npy"))
                for i in range(n_leaves)]
        placed: list[Any] = [None] * n_leaves
        for name, lo, hi in (("opt", 0, n_opt),
                             ("params", n_opt, n_leaves)):
            if hi == lo:
                continue
            sec_specs = _section_spec_leaves(specs, name, hi - lo)
            placed[lo:hi] = self._place(host[lo:hi], sec_specs, topo,
                                        section=name)
        tree = jax.tree.unflatten(treedef, placed)
        sections = _sections_of(tree)
        state = TrainState(params=sections["params"], opt=sections["opt"])
        _telemetry.inc("ckpt.restores")
        _telemetry.set_gauge("ckpt.restored_bytes",
                             int(sum(a.nbytes for a in host)))
        _telemetry.observe("ckpt.restore_seconds", time.monotonic() - t0)
        return state

    def _restore_section(self, step: int, section: str, *, like, specs,
                         topo):
        self.wait()
        t0 = time.monotonic()
        manifest = self._load_manifest(step)
        n_leaves = int(manifest["n_leaves"])
        sections = manifest.get("sections")
        records = manifest.get("leaves")

        if like is not None:
            flat_like, treedef = jax.tree.flatten(like)
            n = len(flat_like)
        elif specs is not None:
            treedef, n = _spec_treedef(specs)
        elif records is not None:
            n = sections[section]
            offset0 = n_leaves - sections["params"] \
                if section == "params" else 0
            # record paths are rooted at the full state tree; drop the
            # leading section key so the rebuilt tree is the bare section
            sec_records = [
                {**records[offset0 + i],
                 "path": list(records[offset0 + i]["path"])[1:]}
                for i in range(n)]
            tree = layout.tree_from_records(sec_records, list(range(n)))
            flat, treedef = jax.tree.flatten(tree)
            if flat != list(range(n)):
                raise ValueError(
                    "manifest leaf records do not reconstruct a stable "
                    "flat order; pass specs=")
        else:
            raise ValueError(
                "checkpoint manifest predates leaf records; pass specs= or "
                "the deprecated params_like skeleton")

        n_section = sections[section] if sections else n
        if n_section != n:
            raise ValueError(
                f"checkpoint step {step} holds {n_section} {section} leaves "
                f"but the target structure has {n} -- architecture "
                "mismatch between save and restore")
        # params leaves are the trailing section of the flat order
        # ("params" sorts after "opt" in the save-time flatten)
        offset = (n_leaves - n_section) if section == "params" else 0
        if records is not None and like is not None:
            # saved record paths are rooted at the full state tree; the
            # ``like`` skeleton is the bare section
            sec = [{**r, "path": list(r["path"])[1:]}
                   for r in records[offset:offset + n_section]]
            layout.validate_records(sec, layout.leaf_records(like),
                                    section=section, step=step)

        d = self._dir(step)
        host = [np.load(os.path.join(d, f"arr_{offset + i}.npy"))
                for i in range(n_section)]
        spec_leaves = reshard.flatten_specs(specs, host) \
            if specs is not None else None
        out = self._place(host, spec_leaves, topo, section=section)
        _telemetry.inc("ckpt.restores")
        _telemetry.set_gauge("ckpt.restored_bytes",
                             int(sum(a.nbytes for a in host)))
        _telemetry.observe("ckpt.restore_seconds", time.monotonic() - t0)
        return jax.tree.unflatten(treedef, out)

    def _place(self, host: list[np.ndarray], spec_leaves, topo, *,
               section: str) -> list:
        """Host arrays -> live arrays: one rooted-scatter program per
        section when placement is known, plain ``jnp.asarray`` otherwise."""
        if topo is not None and spec_leaves is not None:
            with _spans.maybe_span(f"checkpoint:restore:{section}",
                                   cat="wall", leaves=len(host)):
                return reshard.scatter_to_cube(
                    topo, host, spec_leaves,
                    name=f"ckpt-restore-{section}")
        return [jnp.asarray(a) for a in host]


def _sections_of(tree) -> dict:
    """Normalize a TrainState / {"params", "opt"} dict into sections."""
    if isinstance(tree, TrainState):
        return {"opt": tree.opt, "params": tree.params}
    if isinstance(tree, dict) and "params" in tree \
            and set(tree) <= {"opt", "params"}:
        return {"opt": tree.get("opt"), "params": tree["params"]}
    raise TypeError(
        "expected a TrainState or a {'params': ..., 'opt': ...} dict, got "
        f"{type(tree).__name__}")


def _is_spec_leaf(x) -> bool:
    # PartitionSpec is a tuple subclass; a None node stays a jax empty
    # subtree so a spec tree for ``opt=None`` flattens like the state did
    # at save time (use P() for an explicitly replicated leaf)
    return isinstance(x, tuple)


def _spec_treedef(specs):
    """(treedef, n_leaves) of a spec tree, treating PartitionSpecs (tuple
    subclass) and Nones as leaves."""
    flat, treedef = jax.tree.flatten(specs, is_leaf=_is_spec_leaf)
    return treedef, len(flat)


def _section_spec_leaves(specs, section: str, n: int):
    """Flat spec leaves of one section of a sections-dict spec tree, or
    None when no specs are bound."""
    if specs is None:
        return None
    sec = specs.get(section) if isinstance(specs, dict) else None
    if sec is None:
        return None
    flat, _ = jax.tree.flatten(sec, is_leaf=_is_spec_leaf)
    if len(flat) != n:
        raise ValueError(
            f"{section} spec tree has {len(flat)} leaves, checkpoint "
            f"section has {n}")
    return [() if s is None else tuple(s) for s in flat]


__all__ = ["CheckpointManager", "TrainState"]
