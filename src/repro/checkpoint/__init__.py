"""Elastic checkpointing as collective programs.

* :mod:`repro.checkpoint.manager` — the topology-bound
  :class:`CheckpointManager` surface (async save, elastic restore,
  deprecated positional shims) and the :class:`TrainState` container;
* :mod:`repro.checkpoint.layout` — on-disk step layout, manifest v2
  (leaf records + structural fingerprint), atomic finalize;
* :mod:`repro.checkpoint.reshard` — save/restore data movement as
  recorded rooted gather/scatter CommPrograms, planned under the
  installed CommProfile;
* :mod:`repro.checkpoint.hf_import` — Hugging Face safetensors /
  ``pytorch_model.bin`` import onto the ``configs/`` param trees.
"""
from repro.checkpoint.manager import CheckpointManager, TrainState

__all__ = ["CheckpointManager", "TrainState"]
