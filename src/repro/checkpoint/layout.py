"""On-disk checkpoint layout: step directories, manifest v2, fingerprints.

One directory per step, written to a ``.tmp`` sibling and renamed into
place, so a partially written checkpoint is never visible:

    <root>/step_00000100.tmp/   -> renamed atomically to step_00000100/
        manifest.json           # schema below
        arr_<i>.npy             # one file per leaf, flat-order index

The flat order is the sorted-key flatten of ``{"opt": ..., "params": ...}``:
opt leaves occupy a contiguous prefix and params leaves a contiguous
suffix, so a params-only consumer (restore-for-serving) addresses its
section without an optimizer-state skeleton.

Manifest v2 additionally records one entry per leaf — tree path, shape,
dtype — plus a structural fingerprint over those entries, replacing the
dead ``treedef`` field of v1.  Restore validates the target structure
against the records and raises an actionable architecture-mismatch error
instead of mis-loading; v1 manifests (no ``leaves`` key) skip validation.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
from typing import Any, Sequence

import jax

MANIFEST = "manifest.json"
FORMAT = 2

# step directories are exactly step_<8 digits>; anything else in the root
# (foreign files, leftover .tmp dirs from a killed writer) is ignored
_STEP_RE = re.compile(r"^step_(\d{8})$")


def step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def list_steps(root: str) -> list[int]:
    """Steps with a completed (renamed) directory under ``root``, sorted.

    Tolerates foreign entries: only ``step_<8 digits>`` *directories*
    count, so stray files, ``.tmp`` debris from a killed writer, and
    unrelated subdirectories never break enumeration.
    """
    try:
        entries = os.listdir(root)
    except FileNotFoundError:
        return []
    out = []
    for d in entries:
        m = _STEP_RE.match(d)
        if m and os.path.isdir(os.path.join(root, d)):
            out.append(int(m.group(1)))
    return sorted(out)


# ------------------------------------------------------------- leaf records
def _path_keys(path) -> list:
    """A jax key-path as plain JSON-able keys (dict key / index / attr)."""
    keys = []
    for k in path:
        if hasattr(k, "key"):
            keys.append(k.key)
        elif hasattr(k, "idx"):
            keys.append(k.idx)
        elif hasattr(k, "name"):
            keys.append(k.name)
        else:  # pragma: no cover - future key kinds degrade to str
            keys.append(str(k))
    return keys


def leaf_records(tree) -> list[dict]:
    """One record per leaf in flat order: ``{"path", "shape", "dtype"}``."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    records = []
    for path, leaf in flat:
        records.append({
            "path": _path_keys(path),
            "shape": [int(s) for s in getattr(leaf, "shape", ())],
            "dtype": str(jax.numpy.asarray(leaf).dtype)
            if not hasattr(leaf, "dtype") else str(leaf.dtype),
        })
    return records


def fingerprint(records: Sequence[dict]) -> str:
    """Structural sha1 over leaf paths + shapes + dtypes (not values)."""
    blob = json.dumps(list(records), sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()


def tree_from_records(records: Sequence[dict], values: Sequence[Any]):
    """Rebuild a nested-dict tree from manifest records and flat values.

    Checkpointed state trees are dicts all the way down (params trees,
    AdamW moment dicts), so path-keyed reconstruction recovers the exact
    structure; list-typed containers would come back as int-keyed dicts
    and need a ``specs``/``like`` skeleton instead.
    """
    if len(records) != len(values):
        raise ValueError(
            f"{len(records)} manifest records vs {len(values)} values")
    root: dict = {}
    for rec, val in zip(records, values):
        node = root
        path = rec["path"]
        if not path:
            return val  # single-leaf tree
        for key in path[:-1]:
            node = node.setdefault(key, {})
        node[path[-1]] = val
    return root


def validate_records(saved: Sequence[dict], target: Sequence[dict], *,
                     section: str, step: int) -> None:
    """Raise an actionable architecture-mismatch error when the saved
    section's structure does not match the restore target's."""
    if len(saved) != len(target):
        raise ValueError(
            f"checkpoint step {step} holds {len(saved)} {section} leaves "
            f"but the target structure has {len(target)} -- architecture "
            "mismatch between save and restore")
    diffs = []
    for s, t in zip(saved, target):
        if list(s["path"]) != list(t["path"]) \
                or list(s["shape"]) != list(t["shape"]) \
                or str(s["dtype"]) != str(t["dtype"]):
            diffs.append(
                f"  saved {s['path']} {s['shape']} {s['dtype']}"
                f" != target {t['path']} {t['shape']} {t['dtype']}")
        if len(diffs) >= 5:
            diffs.append("  ...")
            break
    if diffs:
        raise ValueError(
            f"checkpoint step {step} {section} structure does not match the "
            "restore target -- architecture mismatch between save and "
            "restore:\n" + "\n".join(diffs))


# ---------------------------------------------------------------- manifest
def build_manifest(step: int, records: Sequence[dict], *, n_opt: int,
                   cube_dims: dict | None = None,
                   extra: dict | None = None) -> dict:
    return {
        "format": FORMAT,
        "step": step,
        "n_leaves": len(records),
        "sections": {"opt": n_opt, "params": len(records) - n_opt},
        "fingerprint": fingerprint(records),
        "leaves": list(records),
        "cube": dict(cube_dims) if cube_dims else None,
        "extra": extra or {},
    }


def write_manifest(directory: str, manifest: dict) -> None:
    with open(os.path.join(directory, MANIFEST), "w") as f:
        json.dump(manifest, f)


def read_manifest(directory: str) -> dict:
    with open(os.path.join(directory, MANIFEST)) as f:
        return json.load(f)


def atomic_finalize(tmp: str, final: str) -> None:
    """Publish ``tmp`` as ``final``: a reader sees the old complete
    checkpoint or the new complete checkpoint, never a partial one."""
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)


__all__ = [
    "FORMAT", "MANIFEST", "atomic_finalize", "build_manifest",
    "fingerprint", "leaf_records", "list_steps", "read_manifest",
    "step_dir", "tree_from_records", "validate_records", "write_manifest",
]
