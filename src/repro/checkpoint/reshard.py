"""Checkpoint data movement as recorded CommPrograms.

PID-Comm's claim is that the eight collective patterns are a sufficient
vocabulary for any cross-PE data movement (PAPER.md §IV).  Checkpoint
traffic is exactly such movement, so it goes through the program layer
rather than around it:

* **Save** records ONE program of rooted ``gather`` collectives per
  checkpoint section (§IV-B3: the host is the root).  The program's
  structural fingerprint is stable across steps — same leaves, same
  shapes — so it lowers once and every later save hits the cube's lower
  cache.
* **Restore** records one program of rooted ``scatter`` collectives per
  section, each op carrying the leaf's full target PartitionSpec via the
  ``spec=`` form.  The program is planned by ``planner.plan_program``
  under the installed :class:`CommProfile`, and its CommEvents carry
  ``program_id`` provenance into any live :class:`CommTrace` — elastic
  restore is priced and traced like any other collective program.

``topo`` arguments accept either a :class:`~repro.models.topology.Topology`
or a bare :class:`~repro.core.hypercube.Hypercube` (duck-typed on
``.cube``): the quickstart drives this layer straight from a cube.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np


def _cube(topo):
    return getattr(topo, "cube", topo)


def gather_program(topo, leaves: Sequence[Any], *, name: str):
    """Record one rooted-gather program over all cube dims: one ``gather``
    op per leaf, inputs in leaf order, outputs the host arrays."""
    cube = _cube(topo)
    comm = cube.comm(cube.dim_names)
    prog = cube.program(name=name)
    with prog:
        ins = [prog.input(leaf) for leaf in leaves]
        prog.output(*[comm.gather(v) for v in ins])
    return prog


def scatter_program(topo, host_leaves: Sequence[Any],
                    specs: Sequence[Any], *, name: str):
    """Record one rooted-scatter program: one ``scatter`` op per leaf,
    each carrying that leaf's full target PartitionSpec."""
    if len(host_leaves) != len(specs):
        raise ValueError(
            f"{len(host_leaves)} leaves vs {len(specs)} placement specs")
    cube = _cube(topo)
    comm = cube.comm(cube.dim_names)
    prog = cube.program(name=name)
    with prog:
        ins = [prog.input(a) for a in host_leaves]
        prog.output(*[comm.scatter(v, spec=tuple(s))
                      for v, s in zip(ins, specs)])
    return prog


def _as_tuple(out, n: int) -> tuple:
    if n == 1:
        return (out,)
    return tuple(out)


def execute_gather(prog, leaves: Sequence[Any]) -> list[np.ndarray]:
    """Run a recorded gather program on the live leaves -> host arrays."""
    if not leaves:
        return []
    out = prog.execute(*leaves)
    return [np.asarray(a) for a in _as_tuple(out, len(leaves))]


def gather_to_host(topo, leaves: Sequence[Any], *,
                   name: str = "ckpt-gather") -> list[np.ndarray]:
    """Record + run the rooted-gather program for ``leaves``."""
    if not leaves:
        return []
    return execute_gather(gather_program(topo, leaves, name=name), leaves)


def scatter_to_cube(topo, host_leaves: Sequence[Any],
                    specs: Sequence[Any], *,
                    name: str = "ckpt-scatter") -> list[jax.Array]:
    """Record + run the rooted-scatter program: host arrays -> placed
    device arrays under each leaf's target spec."""
    if not host_leaves:
        return []
    prog = scatter_program(topo, host_leaves, specs, name=name)
    out = prog.execute(*host_leaves)
    return list(_as_tuple(out, len(host_leaves)))


def flatten_specs(specs, leaves: Sequence[Any]) -> list:
    """Flatten a spec tree in the same order as its value tree.

    PartitionSpec is a tuple subclass, so a bare flatten would explode each
    spec into its string entries; tuples are leaves here (``P()`` means
    replicated; a ``None`` node is an empty subtree, as in jax).
    """
    flat, _ = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, tuple))
    if len(flat) != len(leaves):
        raise ValueError(
            f"spec tree has {len(flat)} leaves, value tree has {len(leaves)}")
    return [tuple(s) for s in flat]


def reshard(tree, src_topo, dst_topo, specs, *, name: str = "reshard"):
    """Move a live pytree from ``src_topo``'s cube onto ``dst_topo``'s:
    a rooted-gather program on the source, a rooted-scatter program on the
    target.  ``specs`` is the target-side spec tree (same structure as
    ``tree``)."""
    leaves, treedef = jax.tree.flatten(tree)
    spec_leaves = flatten_specs(specs, leaves)
    host = gather_to_host(src_topo, leaves, name=f"{name}-gather")
    placed = scatter_to_cube(dst_topo, host, spec_leaves,
                             name=f"{name}-scatter")
    return jax.tree.unflatten(treedef, placed)


__all__ = [
    "execute_gather", "flatten_specs", "gather_program", "gather_to_host",
    "reshard", "scatter_program", "scatter_to_cube",
]
