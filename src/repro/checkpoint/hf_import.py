"""Hugging Face checkpoint import onto the ``configs/`` param trees.

Dependency-free readers/writers for the two HF weight formats —
**safetensors** (8-byte LE header length + JSON header + raw buffer) and
**pytorch_model.bin** (a zip archive whose ``data.pkl`` references per-
tensor storage files through pickle persistent ids) — plus the key-layout
mapping from transformer ``state_dict`` names onto this repo's stacked
unit trees (:func:`repro.models.params.param_defs`).  No ``torch`` and no
``safetensors`` package involved: both are parsed with numpy + stdlib.

Mapping conventions (see ``docs/CHECKPOINT.md`` for the matrix):

* torch ``Linear`` stores ``(out, in)`` and applies ``x @ W.T``; this repo
  stores the applied orientation, so every projection imports transposed.
* RMSNorm scales here are residual (``rms_norm`` applies ``1 + w``), so HF
  norm weights import as ``w - 1``.
* ``wkv`` interleaves k/v per head — column layout ``(KV, 2, hd)`` — so
  k_proj/v_proj stack head-wise, not concatenate.
* The vocab axis pads to ``vocab_padded(cfg, topo)`` with zero rows; the
  router pads expert columns to ``n_experts_padded`` with a large negative
  constant so softmax routes nothing to padding experts.
* Layer ``l`` lands at stack index ``l // unit``, position ``p{l % unit}``
  (the scan-over-units order of ``models.lm``).

Supported mixers/FFNs: attention + dense (LLaMA-style split projections
and the phi3 fused ``qkv_proj``/``gate_up_proj`` forms) and MoE
(mixtral ``block_sparse_moe`` and qwen2-moe ``mlp.experts`` layouts,
shared experts included).  Mamba/RWKV mixers and encoder-decoder trees
have no HF mapping here yet and raise :class:`UnsupportedArchitecture`.
"""
from __future__ import annotations

import json
import os
import pickle
import struct
import zipfile
from typing import Any

import numpy as np

from repro.models.config import ATTN, DENSE, MOE, ModelConfig

ROUTER_PAD = -1e9  # routed probability of a padding expert underflows to 0


class UnsupportedArchitecture(NotImplementedError):
    """The config's param tree has no HF key mapping (yet)."""


# ====================================================== safetensors format
_ST_DTYPES = {
    "F64": np.float64, "F32": np.float32, "F16": np.float16,
    "I64": np.int64, "I32": np.int32, "I16": np.int16, "I8": np.int8,
    "U8": np.uint8, "BOOL": np.bool_,
}


def _bfloat16():
    import ml_dtypes  # ships with jax
    return ml_dtypes.bfloat16


def _st_dtype(name: str):
    if name == "BF16":
        return _bfloat16()
    try:
        return _ST_DTYPES[name]
    except KeyError:
        raise ValueError(f"unsupported safetensors dtype {name!r}") from None


def _st_dtype_name(dtype) -> str:
    dtype = np.dtype(dtype)
    if dtype.name == "bfloat16":
        return "BF16"
    for name, np_t in _ST_DTYPES.items():
        if np.dtype(np_t) == dtype:
            return name
    raise ValueError(f"unsupported dtype {dtype} for safetensors")


def read_safetensors(path: str) -> dict[str, np.ndarray]:
    """Parse a ``.safetensors`` file into ``{name: array}``."""
    with open(path, "rb") as f:
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen).decode("utf-8"))
        buf = f.read()
    out = {}
    for name, meta in header.items():
        if name == "__metadata__":
            continue
        lo, hi = meta["data_offsets"]
        arr = np.frombuffer(buf[lo:hi], dtype=_st_dtype(meta["dtype"]))
        out[name] = arr.reshape(meta["shape"])
    return out


def write_safetensors(path: str, tensors: dict[str, np.ndarray], *,
                      metadata: dict[str, str] | None = None) -> None:
    """Write ``{name: array}`` as a ``.safetensors`` file."""
    header: dict[str, Any] = {}
    if metadata:
        header["__metadata__"] = dict(metadata)
    blobs = []
    offset = 0
    for name in sorted(tensors):
        a = np.ascontiguousarray(tensors[name])
        raw = a.tobytes()
        header[name] = {
            "dtype": _st_dtype_name(a.dtype),
            "shape": [int(s) for s in a.shape],
            "data_offsets": [offset, offset + len(raw)],
        }
        blobs.append(raw)
        offset += len(raw)
    hjson = json.dumps(header).encode("utf-8")
    with open(path, "wb") as f:
        f.write(struct.pack("<Q", len(hjson)))
        f.write(hjson)
        for raw in blobs:
            f.write(raw)


# ================================================= pytorch_model.bin format
_TORCH_DTYPES = {
    "FloatStorage": np.float32, "DoubleStorage": np.float64,
    "HalfStorage": np.float16, "LongStorage": np.int64,
    "IntStorage": np.int32, "ShortStorage": np.int16,
    "CharStorage": np.int8, "ByteStorage": np.uint8,
    "BoolStorage": np.bool_,
}


class _StorageStub:
    """Stands in for a ``torch.<T>Storage`` class object in the pickle."""

    def __init__(self, name: str):
        self.name = name


class _TensorStub:
    """Result of ``_rebuild_tensor_v2``: enough to realize a numpy view."""

    def __init__(self, storage_key, dtype, offset, size, stride):
        self.storage_key = storage_key
        self.dtype = dtype
        self.offset = int(offset)
        self.size = tuple(int(s) for s in size)
        self.stride = tuple(int(s) for s in stride)


def _rebuild_stub(storage, offset, size, stride, *args):
    key, dtype = storage
    return _TensorStub(key, dtype, offset, size, stride)


class _TorchUnpickler(pickle.Unpickler):
    """Unpickles a torch ``data.pkl`` without torch: any ``torch.*`` global
    resolves to a stub, and persistent ids resolve to (storage key, dtype)
    pairs realized lazily from the archive's ``data/<key>`` entries."""

    def find_class(self, module: str, name: str):
        if module.startswith("torch"):
            if name.endswith("Storage"):
                return _StorageStub(name)
            if name in ("_rebuild_tensor_v2", "_rebuild_tensor"):
                return _rebuild_stub
            if name == "OrderedDict":
                return dict
            return _StorageStub(f"{module}.{name}")
        if module == "collections" and name == "OrderedDict":
            return dict
        raise pickle.UnpicklingError(
            f"pytorch_model.bin pickles non-torch global {module}.{name}")

    def persistent_load(self, pid):
        kind, storage_type, key, _location, _numel = pid
        if kind != "storage":
            raise pickle.UnpicklingError(f"unknown persistent id {kind!r}")
        name = storage_type.name if isinstance(storage_type, _StorageStub) \
            else str(storage_type)
        return (key, np.dtype(_TORCH_DTYPES[name]))


def read_pytorch_bin(path: str) -> dict[str, np.ndarray]:
    """Parse a ``pytorch_model.bin`` (zip serialization) into
    ``{name: array}`` without torch."""
    out = {}
    with zipfile.ZipFile(path) as zf:
        names = zf.namelist()
        pkl_name = next(n for n in names if n.endswith("/data.pkl"))
        prefix = pkl_name[: -len("data.pkl")]
        with zf.open(pkl_name) as f:
            state = _TorchUnpickler(f).load()
        for name, t in state.items():
            if not isinstance(t, _TensorStub):
                continue
            raw = zf.read(f"{prefix}data/{t.storage_key}")
            flat = np.frombuffer(raw, dtype=t.dtype)
            if t.size == ():
                out[name] = flat[t.offset].copy().reshape(())
                continue
            out[name] = np.lib.stride_tricks.as_strided(
                flat[t.offset:],
                shape=t.size,
                strides=tuple(s * t.dtype.itemsize for s in t.stride),
            ).copy()
    return out


def _install_fake_torch() -> list[str]:
    """Pickling by reference re-imports each global to verify identity, so
    the writer needs ``torch._utils._rebuild_tensor_v2`` and the storage
    classes importable.  When torch is absent, install minimal fake modules
    into ``sys.modules`` for the duration of the dump; returns the names to
    remove afterwards (empty when real torch is importable)."""
    import sys
    import types
    if "torch" in sys.modules:
        return []
    torch_mod = types.ModuleType("torch")
    utils_mod = types.ModuleType("torch._utils")

    def _rebuild_tensor_v2(*a, **k):  # pragma: no cover - only pickled
        raise RuntimeError("fake torch._utils._rebuild_tensor_v2 invoked")

    _rebuild_tensor_v2.__module__ = "torch._utils"
    _rebuild_tensor_v2.__qualname__ = "_rebuild_tensor_v2"
    utils_mod._rebuild_tensor_v2 = _rebuild_tensor_v2
    for name in _TORCH_DTYPES:
        setattr(torch_mod, name, type(name, (), {"__module__": "torch"}))
    torch_mod._utils = utils_mod
    sys.modules["torch"] = torch_mod
    sys.modules["torch._utils"] = utils_mod
    return ["torch", "torch._utils"]


class _WriteTensor:
    """Pickles exactly like a torch tensor (rebuild call + storage pid)."""

    def __init__(self, key: str, array: np.ndarray):
        self.key = key
        self.array = array

    def __reduce__(self):
        import sys
        a = self.array
        stride = tuple(s // a.itemsize for s in
                       np.ascontiguousarray(a).strides)
        rebuild = sys.modules["torch._utils"]._rebuild_tensor_v2
        return (rebuild,
                (_WriteStorage(self.key, a), 0, a.shape, stride, False, {}))


class _WriteStorage:
    def __init__(self, key: str, array: np.ndarray):
        self.key = key
        self.array = array


_NP_TO_STORAGE = {np.dtype(v): k for k, v in _TORCH_DTYPES.items()}


class _TorchPickler(pickle.Pickler):
    def persistent_id(self, obj):
        if isinstance(obj, _WriteStorage):
            import sys
            cls = getattr(sys.modules["torch"],
                          _NP_TO_STORAGE[np.dtype(obj.array.dtype)])
            return ("storage", cls, obj.key, "cpu", int(obj.array.size))
        return None


def write_pytorch_bin(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write ``{name: array}`` in torch's zip serialization format,
    readable by :func:`read_pytorch_bin` and by real torch."""
    import io
    import sys
    state = {}
    arrays = {}
    for i, name in enumerate(sorted(tensors)):
        a = np.ascontiguousarray(tensors[name])
        if np.dtype(a.dtype) not in _NP_TO_STORAGE:
            raise ValueError(f"unsupported dtype {a.dtype} for {name}")
        key = str(i)
        state[name] = _WriteTensor(key, a)
        arrays[key] = a
    fakes = _install_fake_torch()
    try:
        buf = io.BytesIO()
        _TorchPickler(buf, protocol=2).dump(state)
    finally:
        for mod in fakes:
            sys.modules.pop(mod, None)
    with zipfile.ZipFile(path, "w", zipfile.ZIP_STORED) as zf:
        zf.writestr("archive/data.pkl", buf.getvalue())
        for key, a in arrays.items():
            zf.writestr(f"archive/data/{key}", a.tobytes())


def read_state_dict(path: str) -> dict[str, np.ndarray]:
    """Read either HF weight format, sniffed by extension then content."""
    if path.endswith(".safetensors"):
        return read_safetensors(path)
    if zipfile.is_zipfile(path):
        return read_pytorch_bin(path)
    return read_safetensors(path)


# ========================================================= key-layout maps
def _t(w: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(np.asarray(w).T)


def _norm(w: np.ndarray) -> np.ndarray:
    return np.asarray(w, dtype=np.float32) - 1.0


class _LayerView:
    """Pops a layer's keys out of the flat state dict, several aliases per
    logical tensor (llama/mixtral/qwen2-moe/phi3 spellings)."""

    def __init__(self, sd: dict, prefix: str):
        self.sd = sd
        self.prefix = prefix

    def take(self, *names: str, required: bool = True):
        for n in names:
            full = self.prefix + n
            if full in self.sd:
                return self.sd.pop(full)
        if required:
            raise KeyError(
                f"none of {[self.prefix + n for n in names]} present "
                "in the checkpoint")
        return None


def _attn_from_hf(lw: _LayerView, cfg: ModelConfig) -> dict[str, np.ndarray]:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    fused = lw.take("self_attn.qkv_proj.weight", required=False)
    if fused is not None:  # phi3: rows are [q; k; v]
        q = fused[: H * hd]
        k = fused[H * hd: H * hd + KV * hd]
        v = fused[H * hd + KV * hd:]
    else:
        q = lw.take("self_attn.q_proj.weight", "attention.wq.weight")
        k = lw.take("self_attn.k_proj.weight", "attention.wk.weight")
        v = lw.take("self_attn.v_proj.weight", "attention.wv.weight")
    kT = _t(k).reshape(D, KV, hd)
    vT = _t(v).reshape(D, KV, hd)
    out = {
        "ln": _norm(lw.take("input_layernorm.weight",
                            "attention_norm.weight")),
        "wq": _t(q),
        "wkv": np.ascontiguousarray(
            np.stack([kT, vT], axis=2).reshape(D, 2 * KV * hd)),
        "wo": _t(lw.take("self_attn.o_proj.weight",
                         "attention.wo.weight")),
    }
    if cfg.qk_norm:
        out["q_norm"] = _norm(lw.take("self_attn.q_norm.weight"))
        out["k_norm"] = _norm(lw.take("self_attn.k_norm.weight"))
    return out


def _dense_from_hf(lw: _LayerView, cfg: ModelConfig) -> dict[str, np.ndarray]:
    fln = _norm(lw.take("post_attention_layernorm.weight",
                        "ffn_norm.weight"))
    fused = lw.take("mlp.gate_up_proj.weight", required=False)
    if fused is not None:  # phi3: rows are [gate; up]
        g, u = fused[: cfg.d_ff], fused[cfg.d_ff:]
    else:
        g = lw.take("mlp.gate_proj.weight", "feed_forward.w1.weight")
        u = lw.take("mlp.up_proj.weight", "feed_forward.w3.weight")
    d = lw.take("mlp.down_proj.weight", "feed_forward.w2.weight")
    return {"fln": fln, "wg": _t(g), "wu": _t(u), "wd": _t(d)}


def _moe_from_hf(lw: _LayerView, cfg: ModelConfig) -> dict[str, np.ndarray]:
    D, Fe, E, Ep = (cfg.d_model, cfg.d_ff_expert, cfg.n_experts,
                    cfg.n_experts_padded)
    router = _t(lw.take("block_sparse_moe.gate.weight", "mlp.gate.weight"))
    if Ep > E:
        pad = np.full((D, Ep - E), ROUTER_PAD, dtype=router.dtype)
        router = np.concatenate([router, pad], axis=1)
    gates, ups, downs = [], [], []
    for e in range(E):
        gates.append(_t(lw.take(
            f"block_sparse_moe.experts.{e}.w1.weight",
            f"mlp.experts.{e}.gate_proj.weight")))
        ups.append(_t(lw.take(
            f"block_sparse_moe.experts.{e}.w3.weight",
            f"mlp.experts.{e}.up_proj.weight")))
        downs.append(_t(lw.take(
            f"block_sparse_moe.experts.{e}.w2.weight",
            f"mlp.experts.{e}.down_proj.weight")))
    for _ in range(Ep - E):
        gates.append(np.zeros((D, Fe), np.float32))
        ups.append(np.zeros((D, Fe), np.float32))
        downs.append(np.zeros((Fe, D), np.float32))
    out = {
        "fln": _norm(lw.take("post_attention_layernorm.weight",
                             "ffn_norm.weight")),
        "router": router,
        "we_g": np.stack(gates), "we_u": np.stack(ups),
        "we_d": np.stack(downs),
    }
    if cfg.n_shared_experts:
        out["ws_g"] = _t(lw.take("mlp.shared_expert.gate_proj.weight"))
        out["ws_u"] = _t(lw.take("mlp.shared_expert.up_proj.weight"))
        out["ws_d"] = _t(lw.take("mlp.shared_expert.down_proj.weight"))
        lw.take("mlp.shared_expert_gate.weight", required=False)
    return out


def _pad_rows(a: np.ndarray, n: int) -> np.ndarray:
    if a.shape[0] == n:
        return a
    pad = np.zeros((n - a.shape[0],) + a.shape[1:], a.dtype)
    return np.concatenate([a, pad], axis=0)


def import_state_dict(sd: dict[str, np.ndarray], cfg: ModelConfig,
                      topo=None, *, dtype=np.float32,
                      strict: bool = True) -> dict:
    """Map an HF ``state_dict`` onto this repo's param tree (numpy leaves,
    global shapes for ``topo`` — pass the topology the params will live on
    so the vocab axis pads to its ``tp_size``; ``None`` means no padding).

    ``strict`` raises if checkpoint keys remain unconsumed after mapping
    (catching silent architecture drift); rotary ``inv_freq`` buffers are
    always ignored.
    """
    mixers, ffns = cfg.mixers(), cfg.ffns()
    if cfg.is_encoder_decoder or any(m != ATTN for m in mixers) \
            or any(f not in (DENSE, MOE) for f in ffns):
        raise UnsupportedArchitecture(
            f"{cfg.name}: HF import supports attention mixers with "
            "dense/MoE FFNs; mamba/rwkv/encoder-decoder trees have no "
            "key mapping yet")

    tp_size = getattr(topo, "tp_size", 1) if topo is not None else 1
    import math as _math
    Vp = int(_math.ceil(cfg.vocab_size / tp_size) * tp_size)

    sd = dict(sd)
    for k in [k for k in sd if k.endswith("rotary_emb.inv_freq")]:
        del sd[k]

    unit = cfg.unit()
    n_units = cfg.n_layers // unit
    per_pos: dict[str, list[dict]] = {f"p{p}": [None] * n_units
                                      for p in range(unit)}
    for layer in range(cfg.n_layers):
        lw = _LayerView(sd, f"model.layers.{layer}.")
        leaves = dict(_attn_from_hf(lw, cfg))
        kind = ffns[layer]
        leaves.update(_moe_from_hf(lw, cfg) if kind == MOE
                      else _dense_from_hf(lw, cfg))
        per_pos[f"p{layer % unit}"][layer // unit] = leaves

    units = {}
    for pos, layers in per_pos.items():
        names = layers[0].keys()
        units[pos] = {
            name: np.stack([np.asarray(l[name], dtype=dtype)
                            for l in layers])
            for name in names}

    root = _LayerView(sd, "")
    embed = np.asarray(root.take("model.embed_tokens.weight",
                                 "tok_embeddings.weight"))
    tree: dict[str, Any] = {
        "embed": _pad_rows(embed, Vp).astype(dtype),
        "units": units,
        "final_norm": _norm(root.take("model.norm.weight",
                                      "norm.weight")).astype(dtype),
    }
    if not cfg.tie_embeddings:
        head = root.take("lm_head.weight", "output.weight", required=False)
        if head is None:  # tied on the HF side: reuse the embedding
            head = embed
        tree["lm_head"] = np.ascontiguousarray(
            _pad_rows(np.asarray(head), Vp).T).astype(dtype)
    else:
        root.take("lm_head.weight", required=False)

    if strict and sd:
        extra = sorted(sd)[:8]
        raise ValueError(
            f"{len(sd)} checkpoint keys have no mapping onto {cfg.name} "
            f"(first few: {extra}); pass strict=False to ignore")
    return tree


def export_state_dict(params, cfg: ModelConfig) -> dict[str, np.ndarray]:
    """The inverse map: this repo's param tree -> HF-style ``state_dict``
    (split llama-style projections, un-padded vocab).  The roundtrip
    ``import_state_dict(export_state_dict(p)) == p`` is exact for
    attention+dense architectures whose vocab needs no padding."""
    mixers, ffns = cfg.mixers(), cfg.ffns()
    if cfg.is_encoder_decoder or any(m != ATTN for m in mixers) \
            or any(f != DENSE for f in ffns):
        raise UnsupportedArchitecture(
            f"{cfg.name}: HF export supports attention+dense trees")
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    V = cfg.vocab_size
    unit = cfg.unit()
    sd: dict[str, np.ndarray] = {}
    sd["model.embed_tokens.weight"] = \
        np.asarray(params["embed"])[:V].copy()
    sd["model.norm.weight"] = np.asarray(params["final_norm"]) + 1.0
    if not cfg.tie_embeddings:
        sd["lm_head.weight"] = _t(np.asarray(params["lm_head"])[:, :V])
    for layer in range(cfg.n_layers):
        w = params["units"][f"p{layer % unit}"]
        u = layer // unit
        pre = f"model.layers.{layer}."
        sd[pre + "input_layernorm.weight"] = np.asarray(w["ln"][u]) + 1.0
        sd[pre + "self_attn.q_proj.weight"] = _t(w["wq"][u])
        kv = np.asarray(w["wkv"][u]).reshape(D, KV, 2, hd)
        sd[pre + "self_attn.k_proj.weight"] = _t(
            kv[:, :, 0].reshape(D, KV * hd))
        sd[pre + "self_attn.v_proj.weight"] = _t(
            kv[:, :, 1].reshape(D, KV * hd))
        sd[pre + "self_attn.o_proj.weight"] = _t(w["wo"][u])
        if cfg.qk_norm:
            sd[pre + "self_attn.q_norm.weight"] = \
                np.asarray(w["q_norm"][u]) + 1.0
            sd[pre + "self_attn.k_norm.weight"] = \
                np.asarray(w["k_norm"][u]) + 1.0
        sd[pre + "post_attention_layernorm.weight"] = \
            np.asarray(w["fln"][u]) + 1.0
        sd[pre + "mlp.gate_proj.weight"] = _t(w["wg"][u])
        sd[pre + "mlp.up_proj.weight"] = _t(w["wu"][u])
        sd[pre + "mlp.down_proj.weight"] = _t(w["wd"][u])
    return sd


def import_checkpoint(path: str, cfg: ModelConfig, topo=None, *,
                      dtype=np.float32, strict: bool = True,
                      specs=None) -> dict:
    """Read an HF weight file and map it onto the param tree.  With
    ``topo`` *and* ``specs`` (the target ``param_specs``), leaves are
    placed onto the cube through one rooted-scatter CommProgram — the same
    planned path elastic restore takes; otherwise numpy leaves return."""
    tree = import_state_dict(read_state_dict(path), cfg, topo,
                             dtype=dtype, strict=strict)
    if topo is not None and specs is not None:
        import jax
        from repro.checkpoint import reshard
        leaves, treedef = jax.tree.flatten(tree)
        spec_leaves = reshard.flatten_specs(specs, leaves)
        placed = reshard.scatter_to_cube(topo, leaves, spec_leaves,
                                         name="hf-import")
        return jax.tree.unflatten(treedef, placed)
    return tree


__all__ = [
    "UnsupportedArchitecture", "export_state_dict", "import_checkpoint",
    "import_state_dict", "read_pytorch_bin", "read_safetensors",
    "read_state_dict", "write_pytorch_bin", "write_safetensors",
]
