"""The paper's five benchmark applications (§VII), in JAX on the hypercube.

Each app threads every inter-PE exchange through the PID-Comm primitives with
a selectable ``algorithm`` ("naive" = conventional host-mediated flow,
"pidcomm" = optimized), reproducing the end-to-end speedup experiment
(Fig. 13/15). Sizes are scaled to the available devices; the communication
*structure* is the paper's.

  DLRM  3D cube (x=tables, y=rows, z=cols): lookup -> AA(xyz) -> RS(y) ->
        AA(xz) -> MLP                          [Fig. 11]
  GNN   2D tiles: SpGEMM -> RS(c) -> GeMM -> AR(c)   (RS&AR variant)
        or        SpGEMM -> AR(c) -> GeMM -> AG(c)   (AR&AG variant) [Fig.12]
  BFS   frontier relaxation, AllReduce(max/or) per iteration
  CC    min-label propagation, AllReduce(min) per iteration
  MLP   column-partitioned layers, ReduceScatter between layers
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from repro import compat
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.hypercube import Hypercube


def _smap(cube, f, in_specs, out_specs):
    return jax.jit(shard_map(f, mesh=cube.mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False))


# ----------------------------------------------------------------- DLRM
def make_dlrm(cube: Hypercube, *, batch_per_shard=64, emb_dim=32,
              n_tables=4, rows=512, algorithm="pidcomm"):
    """3D hypercube; communication chain of paper Fig. 11."""
    dims = cube.dim_names[-3:]
    x, y, z = dims
    c_xyz = cube.comm(dims)
    c_y = cube.comm((y,))
    c_xz = cube.comm((x, z))
    nx, ny, nz = (cube.size(d) for d in dims)
    G = nx * ny * nz
    Dl = max(emb_dim // nz, 1)
    F = n_tables * Dl
    b_l = max(batch_per_shard, G)            # divisible by G
    C1 = F * G // ny                          # after AA(xyz) + RS(y)
    C2 = C1 // (nx * nz)                      # after AA(xz) feature width

    def step(tables, idx, w0, w1):
        emb = jax.vmap(lambda t, i: t[i])(tables, idx % rows)  # (T, b_l, Dl)
        emb = jnp.moveaxis(emb, 0, 1).reshape(b_l, F)
        ex = c_xyz.all_to_all(emb, split_axis=0, concat_axis=1,
                              algorithm=algorithm)       # (b_l/G, F*G)
        red = c_y.reduce_scatter(ex, axis=1, op="add",
                                 algorithm=algorithm)    # (b_l/G, C1)
        rel = c_xz.all_to_all(red, split_axis=1, concat_axis=0,
                              algorithm=algorithm)       # (b_l/G*nx*nz, C2)
        h = jax.nn.relu(rel @ w0)
        out = h @ w1
        return c_xyz.all_reduce(out.sum(), algorithm=algorithm)

    tables = jnp.ones((n_tables, rows, Dl), jnp.float32)
    idx = (jnp.arange(b_l * n_tables).reshape(n_tables, b_l) % rows
           ).astype(jnp.int32)
    w0 = jnp.ones((C2, 64), jnp.float32) * 0.01
    w1 = jnp.ones((64, 1), jnp.float32) * 0.01
    fn = _smap(cube, step, (P(), P(), P(), P()), P())
    return lambda: jax.block_until_ready(fn(tables, idx, w0, w1))


# ------------------------------------------------------------------ GNN
def make_gnn(cube: Hypercube, *, n_nodes=2048, feat=256, variant="rs_ar",
             algorithm="pidcomm"):
    r, c = cube.dim_names[-2:]
    nr, nc = cube.size(r), cube.size(c)
    c_c = cube.comm((c,))

    adj = jnp.ones((n_nodes // nr, n_nodes // nc), jnp.float32) / n_nodes
    feats = jnp.ones((n_nodes // nc, feat), jnp.float32)

    if variant == "rs_ar":
        w = jnp.ones((feat // nc, feat), jnp.float32) * 0.01

        def run(adj, feats, w):
            agg = adj @ feats                            # partial over c
            agg = c_c.reduce_scatter(agg, axis=1, op="add",
                                     algorithm=algorithm)
            comb = agg @ w                               # partial over c
            out = c_c.all_reduce(comb, algorithm=algorithm)
            return jax.nn.relu(out).sum()
    else:
        w = jnp.ones((feat, feat // nc), jnp.float32) * 0.01

        def run(adj, feats, w):
            agg = c_c.all_reduce(adj @ feats, algorithm=algorithm)
            comb = agg @ w                               # 2D tiled result
            out = c_c.all_gather(comb, axis=1, algorithm=algorithm)
            return jax.nn.relu(out).sum()

    fn = _smap(cube, run, (P(), P(), P()), P())
    return lambda: jax.block_until_ready(fn(adj, feats, w))


# ------------------------------------------------------------- BFS / CC
def make_bfs(cube: Hypercube, *, n_nodes=4096, iters=8, algorithm="pidcomm"):
    dims = cube.dim_names
    comm = cube.comm(dims)
    n_l = n_nodes // cube.ndev
    adj = ((jnp.arange(n_l)[:, None] * 31 + jnp.arange(n_nodes)[None] * 17)
           % 97 < 3).astype(jnp.float32)

    def run(adj):
        visited = jnp.zeros((n_nodes,), jnp.float32).at[0].set(1.0)

        def body(i, visited):
            local = (adj @ visited > 0).astype(jnp.float32)
            me = compat.axis_index(dims)
            upd = jnp.zeros((n_nodes,), jnp.float32)
            upd = compat.dynamic_update_slice(upd, local, (me * n_l,))
            new = comm.all_reduce(upd, op="max", algorithm=algorithm)
            return jnp.maximum(visited, new)

        visited = compat.fori_loop(0, iters, body, visited)
        return visited.sum()

    fn = _smap(cube, run, (P(),), P())
    return lambda: jax.block_until_ready(fn(adj))


def make_cc(cube: Hypercube, *, n_nodes=4096, iters=8, algorithm="pidcomm"):
    dims = cube.dim_names
    comm = cube.comm(dims)
    n_l = n_nodes // cube.ndev
    adj = ((jnp.arange(n_l)[:, None] * 13 + jnp.arange(n_nodes)[None] * 7)
           % 89 < 3)

    def run(adj):
        labels = jnp.arange(n_nodes, dtype=jnp.float32)
        big = jnp.float32(n_nodes + 1)

        def body(i, labels):
            neigh = jnp.where(adj, labels[None, :], big).min(axis=1)
            me = compat.axis_index(dims)
            upd = jnp.full((n_nodes,), big)
            upd = compat.dynamic_update_slice(upd, neigh, (me * n_l,))
            new = comm.all_reduce(upd, op="min", algorithm=algorithm)
            return jnp.minimum(labels, new)

        labels = compat.fori_loop(0, iters, body, labels)
        return labels.sum()

    fn = _smap(cube, run, (P(),), P())
    return lambda: jax.block_until_ready(fn(adj))


# ------------------------------------------------------------------ MLP
def make_mlp(cube: Hypercube, *, features=2048, layers=5, batch=64,
             algorithm="pidcomm"):
    dims = cube.dim_names
    comm = cube.comm(dims)
    f_l = features // cube.ndev
    ws = tuple(jnp.ones((f_l, features), jnp.float32) * 0.001
               for _ in range(layers))

    def run(x, ws):
        h = x                                            # (batch, f_l)
        for w in ws:
            full = jax.nn.relu(h @ w)                    # partial (batch, F)
            h = comm.reduce_scatter(full, axis=1, op="add",
                                    algorithm=algorithm)
        return h.sum()

    x = jnp.ones((batch, f_l), jnp.float32)
    fn = _smap(cube, run, (P(), tuple(P() for _ in ws)), P())
    return lambda: jax.block_until_ready(fn(x, ws))


APPS = {
    "dlrm": (make_dlrm, 3),
    "gnn_rs_ar": (lambda cube, **kw: make_gnn(cube, variant="rs_ar", **kw), 2),
    "gnn_ar_ag": (lambda cube, **kw: make_gnn(cube, variant="ar_ag", **kw), 2),
    "bfs": (make_bfs, 1),
    "cc": (make_cc, 1),
    "mlp": (make_mlp, 1),
}
