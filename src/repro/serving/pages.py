"""Paged/block KV cache: fixed-size KV blocks behind a per-request page
table, with cross-cube page exchange expressed as rooted scatter/gather
collectives on the serve topology.

The contiguous decode cache (``repro.models.serving.cache_defs``) allocates
``S_cache`` slots per request up front; a paged cache carves the same slot
space into fixed-size **blocks** (``page_size`` slots) drawn from per-shard
physical page pools, so short requests hold only the pages they touched and
freed pages are immediately reusable by the next admission (slot reuse,
continuous batching).

Layout invariants that make paged decode *bit-identical* to the contiguous
reference:

  * logical block ``j`` of any request covers cache slots
    ``[j*page_size, (j+1)*page_size)`` and is **owned** by the kv shard whose
    contiguous slot range contains it (``owner(j) = j // blocks_per_shard``).
    Allocation never crosses that boundary, so each shard can materialize its
    exact contiguous ``(B, S_loc, ...)`` cache view from purely local pages;
  * the view gather zero-fills unallocated blocks, matching the zero-init of
    the contiguous cache; stale data in a *reallocated* page sits at key
    positions the flash-decode mask already excludes (causality / ``dk >= 0``
    under rolling), so it never reaches a logit;
  * each shard's pool carries one extra **scratch** page: masked writes (a
    slot whose block is unallocated -- e.g. an idle batch lane) land there
    instead of scatter-aliasing a live page.

``PagedServer.decode_shard`` is therefore gather-view -> the *unchanged*
``Server.decode_shard`` flash-decode cell -> scatter-back, and the bf16
differential test asserts bitwise equality against the contiguous path.

Page exchange across the cube boundary (preemption/swap in the engine, or
any host-mediated migration) is the rooted-collective pair of paper
SIV-B3: ``extract_slot_pages`` gathers a request's blocks PEs -> host
(``comm.gather``), ``inject_slot_pages`` partitions them back host -> PEs
along the block axis in owner order (``comm.scatter``), plus a broadcast
for the per-request recurrent-state rows (SSM/RWKV) that are not paged.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.serving import ServePlan, Server, cache_defs
from repro.models.topology import Topology

Array = jax.Array

# cache-tree keys that live in page pools; everything else (SSM states,
# conv tails, token-shift carries, encoder-decoder cross K/V) stays a
# per-slot row exactly as in the contiguous layout
PAGED_KEYS = ("k", "v", "k_s", "v_s")


@dataclasses.dataclass(frozen=True)
class PagePlan:
    """Static geometry of the page pools for one (ServePlan, topology)."""
    page_size: int           # cache slots per block/page
    pages_per_shard: int     # usable physical pages per kv shard
    n_shards: int            # size of the kv group (plan.kv_axes)
    S_loc: int               # contiguous slots per shard (= S_cache / n)
    blocks_per_shard: int    # logical blocks of one request per shard
    n_blocks: int            # logical blocks per request (= S_cache / page)

    @property
    def pool_pages(self) -> int:
        """Physical page-axis extent per shard (usable + 1 scratch)."""
        return self.pages_per_shard + 1

    @property
    def n_pages_global(self) -> int:
        return self.n_shards * self.pool_pages

    def owner(self, block: int) -> int:
        """The kv shard whose contiguous slot range covers ``block``."""
        return block // self.blocks_per_shard


def make_page_plan(plan: ServePlan, topo: Topology, *, page_size: int = 4,
                   pages_per_shard: int | None = None) -> PagePlan:
    """Derive the page geometry. ``page_size`` must divide the per-shard
    cache extent so no block straddles a shard boundary; the default pool
    capacity covers every slot of every request (no paging pressure) --
    shrink ``pages_per_shard`` to exercise admission control/preemption."""
    n = topo.size(plan.kv_axes)
    S_loc = plan.S_cache // n
    if S_loc % page_size:
        raise ValueError(
            f"page_size {page_size} does not divide the per-shard cache "
            f"extent {S_loc} (S_cache {plan.S_cache} over {n} shards); "
            "pick a divisor so no block straddles a shard boundary")
    blocks_per_shard = S_loc // page_size
    if pages_per_shard is None:
        pages_per_shard = blocks_per_shard * plan.global_batch
    return PagePlan(page_size=page_size, pages_per_shard=pages_per_shard,
                    n_shards=n, S_loc=S_loc,
                    blocks_per_shard=blocks_per_shard,
                    n_blocks=blocks_per_shard * n)


# ------------------------------------------------------------- pool layout
def paged_cache_defs(cfg: ModelConfig, topo: Topology, plan: ServePlan,
                     pplan: PagePlan):
    """Like :func:`repro.models.serving.cache_defs`, with the attention K/V
    entries (and int8 scales) re-laid as page pools: the per-request
    ``(B, S_cache)`` slot axes become a shared ``(n_pages_global, page_size)``
    pool sharded over the kv axes along the page axis."""
    defs = cache_defs(cfg, topo, plan)
    out = {}
    for pkey, d in defs.items():
        nd = {}
        for k, (shp, spec, dt) in d.items():
            if k in PAGED_KEYS:
                # (n_units, B, S_cache, *tail) -> (n_units, pages, page, *tail)
                tail = shp[3:]
                nd[k] = ((shp[0], pplan.n_pages_global, pplan.page_size)
                         + tail,
                         P(None, plan.kv_axes, None, *([None] * len(tail))),
                         dt)
            else:
                nd[k] = (shp, spec, dt)
        out[pkey] = nd
    return out


def _is_def(x):
    return isinstance(x, tuple) and isinstance(x[0], tuple)


def paged_cache_specs(cfg, topo, plan, pplan):
    return jax.tree.map(lambda d: d[1],
                        paged_cache_defs(cfg, topo, plan, pplan),
                        is_leaf=_is_def)


def init_paged_cache(cfg, topo, plan, pplan):
    """Zero pools (smoke-scale only; a reallocated page is *not* re-zeroed
    at runtime -- the flash-decode mask makes that unnecessary)."""
    return jax.tree.map(lambda d: jnp.zeros(d[0], d[2]),
                        paged_cache_defs(cfg, topo, plan, pplan),
                        is_leaf=_is_def)


# ------------------------------------------------- host-side page table
class PageTable:
    """Per-request page table + per-shard LIFO free lists (host side).

    ``table[slot, j]`` is the *local* page index of logical block ``j`` on
    its owner shard, or -1 while unallocated.  Blocks allocate lazily as a
    request's write position crosses a block boundary (``ensure``) and free
    as a batch on eviction (``free_slot``).
    """

    def __init__(self, pplan: PagePlan, max_slots: int):
        self.pplan = pplan
        self.max_slots = max_slots
        self.table = np.full((max_slots, pplan.n_blocks), -1, np.int32)
        # LIFO free lists: the page freed last is reused first, which keeps
        # the stale-data window (masked anyway) as short as possible
        self.free = [list(range(pplan.pages_per_shard - 1, -1, -1))
                     for _ in range(pplan.n_shards)]

    # -------------------------------------------------------- allocation
    def block_of(self, cache_pos: int) -> int:
        return int(cache_pos) // self.pplan.page_size

    def ensure(self, slot: int, cache_pos: int) -> bool:
        """Allocate the block covering ``cache_pos`` (a slot index within
        ``S_cache``; the caller applies any rolling modulus).  Returns False
        when the owner shard's free list is empty (admission control /
        preemption territory) without partial effects."""
        j = self.block_of(cache_pos)
        if self.table[slot, j] >= 0:
            return True
        sh = self.pplan.owner(j)
        if not self.free[sh]:
            return False
        self.table[slot, j] = self.free[sh].pop()
        return True

    def free_slot(self, slot: int) -> int:
        """Return every page of ``slot`` to its shard free list."""
        n = 0
        for j in range(self.pplan.n_blocks):
            pid = int(self.table[slot, j])
            if pid >= 0:
                self.free[self.pplan.owner(j)].append(pid)
                self.table[slot, j] = -1
                n += 1
        return n

    # ---------------------------------------------------------- capacity
    def free_per_shard(self) -> list[int]:
        return [len(f) for f in self.free]

    def blocks_needed(self, n_positions: int) -> list[int]:
        """Per-shard block count covering cache slots ``0..n_positions-1``
        (capped at the full cache extent)."""
        pp = self.pplan
        nb = min(-(-int(n_positions) // pp.page_size), pp.n_blocks)
        need = [0] * pp.n_shards
        for j in range(nb):
            need[pp.owner(j)] += 1
        return need

    def can_admit(self, n_positions: int) -> bool:
        """True when every shard can cover the request's full eventual
        footprint -- the no-deadlock admission policy."""
        return all(f >= n for f, n in zip(self.free_per_shard(),
                                          self.blocks_needed(n_positions)))

    def array(self) -> np.ndarray:
        """Snapshot for the per-step replicated broadcast."""
        return self.table.copy()


# ------------------------------------------ per-shard gather/scatter view
def local_block_ids(pplan: PagePlan, table: Array, shard: Array | int):
    """This shard's slice of the table: (safe local page ids, valid mask),
    both ``(B, blocks_per_shard)``.  Unallocated blocks map to the scratch
    page so gathers/scatters stay branch-free."""
    myt = lax.dynamic_slice_in_dim(
        table, shard * pplan.blocks_per_shard, pplan.blocks_per_shard,
        axis=1)
    valid = myt >= 0
    safe = jnp.where(valid, myt, pplan.pages_per_shard)
    return safe, valid


def gather_view(pool: Array, safe: Array, valid: Array,
                pplan: PagePlan) -> Array:
    """Local pool ``(n_units, pool_pages, page, *tail)`` -> the shard's
    contiguous cache view ``(n_units, B, S_loc, *tail)``.  Unallocated
    blocks read as zeros (identical to the contiguous zero-init)."""
    B, bps = safe.shape
    tail = pool.shape[3:]
    g = jnp.take(pool, safe.reshape(-1), axis=1)
    g = g.reshape((pool.shape[0], B, bps, pplan.page_size) + tail)
    vm = valid.reshape((1, B, bps, 1) + (1,) * len(tail))
    g = jnp.where(vm, g, jnp.zeros((), pool.dtype))
    return g.reshape((pool.shape[0], B, pplan.S_loc) + tail)


def scatter_view(pool: Array, view: Array, safe: Array,
                 pplan: PagePlan) -> Array:
    """Write an updated contiguous view back into the local pool.  Blocks of
    unallocated slots route to the scratch page (never read); allocated page
    ids are unique by construction, so the scatter never aliases."""
    B, bps = safe.shape
    tail = pool.shape[3:]
    blocks = view.reshape((pool.shape[0], B * bps, pplan.page_size) + tail)
    return pool.at[:, safe.reshape(-1)].set(blocks)


class PagedServer:
    """Paged decode cell: gather-view -> ``Server.decode_shard`` (unchanged
    flash-decode arithmetic) -> scatter-back.  Per-shard function; wrap in
    ``shard_map`` with ``paged_cache_specs`` for the cache and a replicated
    spec for the page table."""

    def __init__(self, server: Server, pplan: PagePlan):
        self.server = server
        self.pplan = pplan

    def decode_shard(self, params, pcache, table, tokens: Array, pos: Array):
        """One paged decode step. ``table``: (B, n_blocks) int32 replicated.
        Returns (logits, new paged cache)."""
        pplan = self.pplan
        plan = self.server.plan
        me = lax.axis_index(plan.kv_axes)
        safe, valid = local_block_ids(pplan, table, me)
        view = {}
        for pkey, d in pcache.items():
            view[pkey] = {
                k: gather_view(leaf, safe, valid, pplan)
                if k in PAGED_KEYS else leaf
                for k, leaf in d.items()}
        logits, new_view = self.server.decode_shard(params, view, tokens,
                                                    pos)
        out = {}
        for pkey, d in pcache.items():
            out[pkey] = {
                k: scatter_view(leaf, new_view[pkey][k], safe, pplan)
                if k in PAGED_KEYS else new_view[pkey][k]
                for k, leaf in d.items()}
        return logits, out


# --------------------------------------------- cross-cube page exchange
def _global_page_ids(pplan: PagePlan, table_row: np.ndarray):
    """A request's blocks as global pool page ids, unallocated -> the owner
    shard's scratch page.  Returns (ids (n_blocks,), valid (n_blocks,))."""
    ids = np.empty(pplan.n_blocks, np.int32)
    valid = np.zeros(pplan.n_blocks, bool)
    for j in range(pplan.n_blocks):
        sh = pplan.owner(j)
        pid = int(table_row[j])
        valid[j] = pid >= 0
        ids[j] = sh * pplan.pool_pages + (pid if pid >= 0
                                          else pplan.pages_per_shard)
    return ids, valid


def extract_slot_pages(pcache, table_row: np.ndarray, slot: int,
                       pplan: PagePlan, topo: Topology, plan: ServePlan
                       ) -> dict:
    """Swap-out half of the page exchange: gather one request's pages (and
    its per-slot recurrent-state rows) PEs -> host through the rooted
    ``gather`` collective on the kv group.  The caller frees the pages
    afterwards; the returned dict round-trips through
    :func:`inject_slot_pages`."""
    kvc = topo.comm(plan.kv_axes)
    gids, valid = _global_page_ids(pplan, table_row)
    gidx = jnp.asarray(gids)
    pages, rows = {}, {}
    for pkey, d in pcache.items():
        for k, leaf in d.items():
            if k in PAGED_KEYS:
                taken = jnp.take(leaf, gidx, axis=1)
                host = np.array(kvc.gather(taken))
                host[:, ~valid] = 0          # scratch content is garbage
                pages[(pkey, k)] = host
            else:
                rows[(pkey, k)] = np.array(kvc.gather(leaf[:, slot]))
    return {"pages": pages, "rows": rows, "valid": valid}


def inject_slot_pages(pcache, saved: dict, table_row: np.ndarray, slot: int,
                      pplan: PagePlan, topo: Topology, plan: ServePlan):
    """Swap-in half: partition the saved pages back host -> PEs with the
    rooted ``scatter`` along the block axis (blocks sit in owner order, so
    the equal per-shard split lands each page on the shard that owns it),
    broadcast the per-slot state rows, and write both into the pools at the
    freshly allocated ids in ``table_row``."""
    kvc = topo.comm(plan.kv_axes)
    gids, _ = _global_page_ids(pplan, table_row)
    gidx = jnp.asarray(gids)
    bidx = jnp.asarray(int(slot))
    new = {pkey: dict(d) for pkey, d in pcache.items()}
    for (pkey, k), host in saved["pages"].items():
        dev = kvc.scatter(host, axis=1)
        new[pkey][k] = new[pkey][k].at[:, gidx].set(
            dev.astype(new[pkey][k].dtype))
    for (pkey, k), host in saved["rows"].items():
        dev = kvc.broadcast(host)
        new[pkey][k] = new[pkey][k].at[:, bidx].set(
            dev.astype(new[pkey][k].dtype))
    return new


__all__ = [
    "PAGED_KEYS", "PagePlan", "PageTable", "PagedServer",
    "extract_slot_pages", "gather_view", "init_paged_cache",
    "inject_slot_pages", "local_block_ids", "make_page_plan",
    "paged_cache_defs", "paged_cache_specs", "scatter_view",
]
