"""Continuous-batching decode engine over program-scheduled collectives.

One engine step serves every in-flight request at once and costs exactly:

  * **one recorded CommProgram** of rooted collectives -- the host->PE
    broadcasts of the step's control state (page table, admit/evict masks,
    prompt buffer, sampling temperatures, rng key) plus the PE->host gather
    of the *previous* step's sampled tokens.  The program is re-recorded
    every step (constants change) but its structure never does, so the
    PR 5 structural-fingerprint lower cache serves every step after the
    first (``LOWER_STATS["cache_hits"]`` grows by one per step) -- per-token
    collectives are planned once and overlap-scheduled under any installed
    profile;
  * **one jitted shard_map step** wrapping the paged flash-decode cell
    (:class:`repro.serving.pages.PagedServer` around the unchanged
    ``Server.decode_shard``) plus device-side sampling, so no logits ever
    cross to the host.

Scheduling is continuous batching with slot reuse: requests admit from the
arrival queue into free batch lanes, prefill runs *through the decode cell*
(chunk-1 chunked prefill: each step teacher-forces the next prompt token
while building the paged KV cache -- "prefill-then-decode" as phases of one
request, not separate kernels), decode samples on-device (greedy or
temperature via a sharded-vocab collective argmax), and completed requests
evict the next step, returning their pages to the pools.

Host bookkeeping is deterministic without token values (completion is
length-based: ``plen + max_new``), which is what lets sampled tokens flow
back with a one-step lag through the next program's gather instead of a
blocking per-step device round-trip.

Admission policies:
  * ``"reserve"`` (default): admit only when every shard can cover the
    request's full eventual page footprint net of pages already promised
    to in-flight requests -- allocation can then never fail mid-decode;
  * ``"lazy"``: admit optimistically as soon as a lane is free and the
    request's first block fits; if a shard's pool later runs dry, the
    youngest other request is **preempted** -- its pages are
    swapped to the host via the rooted gather
    (:func:`repro.serving.pages.extract_slot_pages`), freed, and the
    request re-queued; re-admission scatters the saved pages back
    (:func:`~repro.serving.pages.inject_slot_pages`).  Swap traffic is the
    only host-mediated cache motion and happens outside the per-step
    program, only on preemption events.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.config import ModelConfig
from repro.models.params import param_specs
from repro.models.serving import ServePlan, Server
from repro.models.topology import Topology
from repro.serving import pages as pages_mod
from repro.serving.pages import (
    PagedServer, PageTable, extract_slot_pages, init_paged_cache,
    inject_slot_pages, make_page_plan, paged_cache_specs)
from repro.telemetry import drift as _drift
from repro.telemetry import spans as _spans
from repro.telemetry.metrics import MetricsRegistry

Array = jax.Array
_I32MAX = np.int32(np.iinfo(np.int32).max)


@dataclasses.dataclass
class Request:
    """One decode request.  ``arrival`` is in engine steps (the bench maps a
    Poisson arrival trace onto it); ``temperature == 0`` samples greedily."""
    rid: int
    prompt: list[int]
    max_new: int
    temperature: float = 0.0
    arrival: int = 0
    # filled by the engine
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    admitted_step: int = -1
    finished_step: int = -1
    preemptions: int = 0

    @property
    def plen(self) -> int:
        return len(self.prompt)

    @property
    def limit(self) -> int:
        """One past the last decoded position (= plen + max_new - 1)."""
        return self.plen + self.max_new - 1


class ServeEngine:
    """Continuous-batching decode server on the serve topology."""

    def __init__(self, cfg: ModelConfig, topo: Topology, plan: ServePlan,
                 params, *, page_size: int = 4,
                 pages_per_shard: int | None = None,
                 admission: str = "reserve", seed: int = 0):
        if plan.batch_axes:
            raise NotImplementedError(
                "ServeEngine runs single-pod serve plans (batch replicated); "
                f"got batch_axes={plan.batch_axes}")
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "encoder-decoder serving needs a cross-cache prefill path")
        if admission not in ("reserve", "lazy"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.cfg, self.topo, self.plan = cfg, topo, plan
        self.params = params
        self.admission = admission
        self.seed = seed
        self.pplan = make_page_plan(plan, topo, page_size=page_size,
                                    pages_per_shard=pages_per_shard)
        self.B = plan.global_batch
        self.P_max = plan.S_ctx
        self.rolling = plan.S_cache < plan.S_ctx

        self.table = PageTable(self.pplan, self.B)
        self.pcache = init_paged_cache(cfg, topo, plan, self.pplan)
        self.paged = PagedServer(Server(cfg, topo, plan), self.pplan)

        # host mirrors (deterministic: no token values needed)
        self.slot_req: list[Request | None] = [None] * self.B
        self.pos_h = np.zeros(self.B, np.int32)
        self.active_h = np.zeros(self.B, bool)
        self.plen_h = np.zeros(self.B, np.int32)
        self.limit_h = np.zeros(self.B, np.int32)
        self.temp_h = np.zeros(self.B, np.float32)
        self._admit_order = np.zeros(self.B, np.int64)  # admission stamp
        self._slot_commit = np.zeros((self.B, self.pplan.n_shards), np.int64)
        self._committed = np.zeros(self.pplan.n_shards, np.int64)

        # device-carried state
        self._toks = jnp.zeros(self.B, jnp.int32)
        self._pos = jnp.zeros(self.B, jnp.int32)
        self._active = jnp.zeros(self.B, bool)
        self._prompts = jnp.zeros((self.B, self.P_max), jnp.int32)
        self._sampled = jnp.zeros(self.B, jnp.int32)
        # lanes whose previous-step sample is a generated token:
        # (slot, request, generated-token index)
        self._meta: list[tuple[int, Request, int]] = []

        self.queue: list[Request] = []
        self.step_idx = 0
        self.programs_recorded = 0
        self.last_program = None   # most recent per-step CommProgram
        self.finished: list[Request] = []

        # Per-engine metrics registry (always on -- it replaces the old
        # step_wall/token_wall list bookkeeping and is the single source
        # run() and benchmarks/serving.py read latency/throughput from).
        self.metrics = MetricsRegistry()
        self._lower_hits = 0
        self._lower_lookups = 0

        self._step_fn = self._build_step()

    # ----------------------------------------------------------- jitted step
    def _build_step(self):
        topo, plan, cfg = self.topo, self.plan, self.cfg
        pplan, paged, P_max = self.pplan, self.paged, self.P_max
        vocab = cfg.vocab_size

        def step_shard(params, pcache, table, toks, pos, active, prompts,
                       admit, admit_tok, admit_pos, admit_prompts, plen,
                       evict, temps, key):
            tpc = topo.comm(topo.tp)
            # merge this step's schedule into the carried lane state
            active = (active & ~evict) | admit
            toks = jnp.where(admit, admit_tok, toks)
            pos = jnp.where(admit, admit_pos, pos)
            prompts = jnp.where(admit[:, None], admit_prompts, prompts)

            logits, pcache = paged.decode_shard(params, pcache, table,
                                                toks, pos)
            # ---- on-device sampling over the vocab-sharded logits
            V_loc = logits.shape[-1]
            me = compat.axis_index(topo.tp)
            gid = me * V_loc + jnp.arange(V_loc, dtype=jnp.int32)
            neg = jnp.finfo(jnp.float32).min
            logits = jnp.where(gid[None, :] < vocab, logits, neg)
            k = jax.random.fold_in(key, me)
            g = jax.random.gumbel(k, logits.shape, jnp.float32)
            warm = logits / jnp.maximum(temps, 1e-6)[:, None] + g
            eff = jnp.where(temps[:, None] > 0.0, warm, logits)
            # collective argmax: max over shards, then min global id
            # among the (bitwise-equal on the owner) maximizers
            m_loc = eff.max(axis=-1)
            m_all = tpc.all_reduce(m_loc, op="max")
            cand = jnp.where(eff == m_all[:, None], gid[None, :],
                             jnp.int32(_I32MAX)).min(axis=-1)
            sampled = tpc.all_reduce(cand, op="min")
            # ---- teacher-force prefill, advance the lanes
            nxt_p = jnp.take_along_axis(
                prompts, jnp.clip(pos + 1, 0, P_max - 1)[:, None],
                axis=1)[:, 0]
            nxt = jnp.where(pos + 1 < plen, nxt_p, sampled)
            toks = jnp.where(active, nxt, toks)
            pos = jnp.where(active, pos + 1, pos)
            return sampled, toks, pos, active, prompts, pcache

        pspec = param_specs(cfg, topo)
        cspec = paged_cache_specs(cfg, topo, plan, pplan)
        rep = P()
        fn = compat.shard_map(
            step_shard, mesh=topo.cube.mesh,
            in_specs=(pspec, cspec) + (rep,) * 13,
            out_specs=(rep, rep, rep, rep, rep, cspec),
            check_vma=False)
        return jax.jit(fn, donate_argnums=(1,))

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError(f"request {req.rid} has an empty prompt")
        if req.max_new < 1:
            raise ValueError(f"request {req.rid} asks for no tokens")
        if req.limit > self.plan.S_ctx:
            raise ValueError(
                f"request {req.rid} needs {req.limit} positions, over the "
                f"serve plan's S_ctx={self.plan.S_ctx}")
        need = self._need(req)
        if any(n > self.pplan.pages_per_shard for n in need):
            raise ValueError(
                f"request {req.rid} needs {max(need)} pages on one shard "
                f"but the pools hold {self.pplan.pages_per_shard} -- it "
                "could never run even alone")
        self.queue.append(req)
        self.queue.sort(key=lambda r: r.arrival)

    def _need(self, req_or_state) -> list[int]:
        limit = (req_or_state["req"].limit
                 if isinstance(req_or_state, dict) else req_or_state.limit)
        return self.table.blocks_needed(min(limit, self.plan.S_cache))

    def _can_admit(self, entry) -> bool:
        if isinstance(entry, dict):        # resumed: exact saved footprint
            need = np.zeros(self.pplan.n_shards, np.int64)
            for j in np.nonzero(entry["valid"])[0]:
                need[self.pplan.owner(int(j))] += 1
            free = np.asarray(self.table.free_per_shard(), np.int64)
            return bool((free >= need).all())
        free = np.asarray(self.table.free_per_shard(), np.int64)
        if self.admission == "reserve":
            need = np.asarray(self._need(entry), np.int64)
            return bool((free - self._committed >= need).all())
        # lazy: optimistic -- only the request's first block must fit now;
        # a shard running dry later preempts (feasibility of the full
        # footprint against the pool size was checked at submit)
        need = np.asarray(self.table.blocks_needed(1), np.int64)
        return bool((free >= need).all())

    def _admit_into(self, slot: int, entry, admit, admit_tok, admit_pos,
                    admit_prompts) -> None:
        saved = entry if isinstance(entry, dict) else None
        req: Request = saved["req"] if saved else entry
        start = int(saved["pos"]) if saved else 0
        self.metrics.counter("serve.admitted").inc()
        self.slot_req[slot] = req
        self.pos_h[slot] = start
        self.active_h[slot] = True
        self.plen_h[slot] = req.plen
        self.limit_h[slot] = req.limit
        self.temp_h[slot] = req.temperature
        self._admit_order[slot] = self._stamp = getattr(
            self, "_stamp", 0) + 1
        if req.admitted_step < 0:
            req.admitted_step = self.step_idx
        need = np.asarray(self._need(req), np.int64)
        self._slot_commit[slot] = need
        self._committed += need
        admit[slot] = True
        admit_pos[slot] = start
        if start < req.plen:
            admit_tok[slot] = req.prompt[start]
        else:                               # resumed mid-decode
            admit_tok[slot] = req.out_tokens[start - req.plen]
        admit_prompts[slot, :req.plen] = np.asarray(req.prompt, np.int32)
        if saved:
            # re-allocate exactly the saved blocks, then scatter pages back
            req.preemptions += 1
            for j in np.nonzero(saved["valid"])[0]:
                assert self._ensure(slot, int(j) * self.pplan.page_size)
            self.pcache = inject_slot_pages(
                self.pcache, saved, self.table.table[slot], slot,
                self.pplan, self.topo, self.plan)

    def _ensure(self, slot: int, cache_pos: int) -> bool:
        j = self.table.block_of(cache_pos)
        fresh = self.table.table[slot, j] < 0
        if not self.table.ensure(slot, cache_pos):
            return False
        if fresh:
            sh = self.pplan.owner(j)
            if self._slot_commit[slot, sh] > 0:
                self._slot_commit[slot, sh] -= 1
                self._committed[sh] -= 1
        return True

    def _release(self, slot: int) -> None:
        self.table.free_slot(slot)
        self._committed -= self._slot_commit[slot]
        self._slot_commit[slot] = 0
        self.slot_req[slot] = None
        self.active_h[slot] = False

    def _preempt_for(self, slot: int, shard: int) -> bool:
        """Swap out the youngest other active request holding pages on
        ``shard``; returns False when no victim exists."""
        cands = [b for b in range(self.B)
                 if b != slot and self.active_h[b] and any(
                     self.table.table[b, j] >= 0
                     for j in range(self.pplan.n_blocks)
                     if self.pplan.owner(j) == shard)]
        if not cands:
            return False
        victim = max(cands, key=lambda b: self._admit_order[b])
        self._drain()                       # bank pending sampled tokens
        req = self.slot_req[victim]
        saved = extract_slot_pages(self.pcache, self.table.table[victim],
                                   victim, self.pplan, self.topo, self.plan)
        saved["req"] = req
        saved["pos"] = int(self.pos_h[victim])
        self._release(victim)
        self._evict_next[victim] = True     # device lane off next program
        self.queue.insert(0, saved)
        self.metrics.counter("serve.preempted").inc()
        return True

    # ------------------------------------------------------------- stepping
    def _drain(self) -> None:
        """Apply pending generated-token bookkeeping from the device copy
        (used before swaps and at end of run; normally the next step's
        program gather does this without blocking)."""
        if not self._meta:
            return
        vals = np.asarray(jax.device_get(self._sampled))
        self._apply_meta(vals)

    def _apply_meta(self, sampled: np.ndarray) -> None:
        for slot, req, gi in self._meta:
            tok = int(sampled[slot])
            if gi == len(req.out_tokens):
                req.out_tokens.append(tok)
        self._meta = []

    def step(self) -> None:
        """One engine step: evict / admit / record-and-run the step program
        / run the jitted paged-decode + sampling cell."""
        with _spans.maybe_span("serve-step", cat="wall",
                               step=self.step_idx):
            self._step_inner()

    def _step_inner(self) -> None:
        t0 = time.perf_counter()
        B, pplan = self.B, self.pplan
        self._evict_next = np.zeros(B, bool)

        # -- evict lanes that finished last step (their final token arrives
        #    through this step's gather, recorded in _meta)
        for b in range(B):
            if self.active_h[b] and self.pos_h[b] >= self.limit_h[b]:
                req = self.slot_req[b]
                req.finished_step = self.step_idx
                self.finished.append(req)
                self._release(b)
                self._evict_next[b] = True
                self.metrics.counter("serve.evicted").inc()

        # -- admit from the arrival queue into free lanes
        admit = np.zeros(B, bool)
        admit_tok = np.zeros(B, np.int32)
        admit_pos = np.zeros(B, np.int32)
        admit_prompts = np.zeros((B, self.P_max), np.int32)
        while self.queue:
            head = self.queue[0]
            arr = (head["req"].arrival if isinstance(head, dict)
                   else head.arrival)
            if arr > self.step_idx:
                break
            free = [b for b in range(B) if not self.active_h[b]]
            if not free or not self._can_admit(head):
                break
            self.queue.pop(0)
            self._admit_into(free[0], head, admit, admit_tok, admit_pos,
                             admit_prompts)

        # -- allocate this step's write blocks (deterministic on host);
        #    under lazy admission a dry shard triggers preemption
        for b in range(B):
            if not self.active_h[b]:
                continue
            wp = int(self.pos_h[b]) % self.plan.S_cache
            while not self._ensure(b, wp):
                sh = pplan.owner(self.table.block_of(wp))
                if not self._preempt_for(b, sh):
                    raise RuntimeError(
                        f"page pools exhausted on shard {sh} and no "
                        "preemptible request holds pages there")

        free = np.asarray(self.table.free_per_shard(), np.int64)
        total_pages = self.pplan.n_shards * self.pplan.pages_per_shard
        self.metrics.gauge("serve.page_occupancy").set(
            1.0 - float(free.sum()) / total_pages if total_pages else 0.0)

        evict = self._evict_next
        key = np.array([np.uint32(self.seed), np.uint32(self.step_idx)],
                       np.uint32)

        # -- ONE recorded CommProgram per decode step: the rooted host->PE
        #    broadcasts of control state + the PE->host gather of the
        #    previous step's sampled tokens.  Structure is step-invariant,
        #    so lowering is a structural-fingerprint cache hit from step 1.
        kvc = self.topo.comm(self.plan.kv_axes)
        prog = self.topo.cube.program(name="serve-step")
        with prog:
            prev = prog.input(jax.ShapeDtypeStruct((B,), jnp.int32))
            outs = [kvc.broadcast(self.table.array()),
                    kvc.broadcast(admit), kvc.broadcast(admit_tok),
                    kvc.broadcast(admit_pos), kvc.broadcast(admit_prompts),
                    kvc.broadcast(self.plen_h.copy()),
                    kvc.broadcast(evict), kvc.broadcast(self.temp_h.copy()),
                    kvc.broadcast(key), kvc.gather(prev)]
            prog.output(*outs)
        from repro.core.program import LOWER_STATS
        hits0, low0 = LOWER_STATS["cache_hits"], LOWER_STATS["lowered"]
        te0 = time.perf_counter()
        with _spans.maybe_span("step-program", cat="wall",
                               step=self.step_idx,
                               program_id=prog.program_id):
            (table_d, admit_d, atok_d, apos_d, aprm_d, plen_d, evict_d,
             temp_d, key_d, prev_host) = prog.execute(self._sampled)
        exec_wall = time.perf_counter() - te0
        self._lower_hits += LOWER_STATS["cache_hits"] - hits0
        self._lower_lookups += (LOWER_STATS["cache_hits"] - hits0
                                + LOWER_STATS["lowered"] - low0)
        if self._lower_lookups:
            self.metrics.gauge("serve.lower_cache_hit_ratio").set(
                self._lower_hits / self._lower_lookups)
        mon = _drift.active_monitor()
        if mon is not None:
            mon.observe_plan(prog._lowered_default().plan, exec_wall)
        self.programs_recorded += 1
        self.last_program = prog
        self._apply_meta(np.asarray(prev_host))

        # -- the fused paged-decode + on-device-sampling step
        (self._sampled, self._toks, self._pos, self._active, self._prompts,
         self.pcache) = self._step_fn(
            self.params, self.pcache, table_d, self._toks, self._pos,
            self._active, self._prompts, admit_d, atok_d, apos_d, aprm_d,
            plen_d, evict_d, temp_d, key_d)
        jax.block_until_ready(self._sampled)

        # -- host mirrors advance deterministically; note which lanes just
        #    produced a *generated* (post-prefill) token
        gen_this_step = 0
        for b in range(B):
            if not self.active_h[b]:
                continue
            p = int(self.pos_h[b])
            if p + 1 >= self.plen_h[b]:
                req = self.slot_req[b]
                self._meta.append((b, req, p + 1 - int(self.plen_h[b])))
                gen_this_step += 1
            self.pos_h[b] = p + 1
        self.step_idx += 1
        dt = time.perf_counter() - t0
        self.metrics.counter("serve.steps").inc()
        self.metrics.histogram("serve.step_seconds").observe(dt)
        if gen_this_step:
            self.metrics.counter("serve.generated_tokens").inc(
                gen_this_step)
            tok_hist = self.metrics.histogram("serve.token_seconds")
            for _ in range(gen_this_step):
                tok_hist.observe(dt)

    # ------------------------------------------------------------------ run
    def run(self, requests: list[Request] | None = None, *,
            max_steps: int = 10_000) -> dict[str, Any]:
        """Drive the arrival trace to completion; returns throughput and
        per-token latency metrics plus the finished requests."""
        for r in requests or []:
            self.submit(r)
        t0 = time.perf_counter()
        while (self.queue or self.active_h.any()):
            if self.step_idx >= max_steps:
                raise RuntimeError(f"no convergence in {max_steps} steps")
            self.step()
        self._drain()
        wall = time.perf_counter() - t0
        # Single measurement path: throughput and per-token percentiles
        # come from the engine's metrics registry (the token_seconds
        # histogram retains raw samples, so quantile() reproduces the
        # historical sorted-array formula exactly).
        n_tok = int(self.metrics.value("serve.generated_tokens"))
        tps = n_tok / wall if wall > 0 else 0.0
        self.metrics.gauge("serve.tokens_per_s").set(tps)
        return {
            "steps": self.step_idx,
            "wall_s": wall,
            "generated_tokens": n_tok,
            "tokens_per_s": tps,
            "p50_token_s": self.metrics.quantile("serve.token_seconds",
                                                 0.50),
            "p99_token_s": self.metrics.quantile("serve.token_seconds",
                                                 0.99),
            "programs_recorded": self.programs_recorded,
            "preemptions": sum(r.preemptions for r in self.finished),
            "finished": list(self.finished),
        }

    def reset_metrics(self) -> None:
        """Zero the registry and run-scoped bookkeeping (warmup boundary
        for benchmarks); in-flight request state is untouched."""
        self.metrics.reset()
        self._lower_hits = 0
        self._lower_lookups = 0
        self.programs_recorded = 0
        self.finished.clear()


def poisson_trace(n_requests: int, *, rate: float, plen_range=(4, 16),
                  max_new_range=(4, 12), temperature: float = 0.0,
                  vocab: int = 256, seed: int = 0) -> list[Request]:
    """A Poisson arrival trace (``rate`` = mean arrivals per engine step)
    with mixed prompt/output lengths -- the bench and example workload."""
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.randint(plen_range[0], plen_range[1] + 1))
        reqs.append(Request(
            rid=i,
            prompt=rng.randint(0, vocab, plen).astype(int).tolist(),
            max_new=int(rng.randint(max_new_range[0],
                                    max_new_range[1] + 1)),
            temperature=temperature,
            arrival=int(arrivals[i])))
    return reqs


__all__ = ["Request", "ServeEngine", "poisson_trace"]
