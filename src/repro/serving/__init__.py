"""Production decode serving on the PE hypercube: a paged/block KV cache
whose cross-cube page motion is rooted scatter/gather collectives
(:mod:`repro.serving.pages`), and a continuous-batching engine whose
per-step host<->PE traffic is one recorded CommProgram served by the
structural-fingerprint lower cache (:mod:`repro.serving.engine`).
"""
from repro.serving.engine import Request, ServeEngine, poisson_trace
from repro.serving.pages import (
    PAGED_KEYS, PagePlan, PagedServer, PageTable, extract_slot_pages,
    gather_view, init_paged_cache, inject_slot_pages, local_block_ids,
    make_page_plan, paged_cache_defs, paged_cache_specs, scatter_view)

__all__ = [
    "PAGED_KEYS", "PagePlan", "PageTable", "PagedServer", "Request",
    "ServeEngine", "extract_slot_pages", "gather_view", "init_paged_cache",
    "inject_slot_pages", "local_block_ids", "make_page_plan",
    "paged_cache_defs", "paged_cache_specs", "poisson_trace", "scatter_view",
]
