"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh): the three terms
  compute    = HLO_FLOPs_per_chip / 197 TF/s
  memory     = HLO_bytes_per_chip / 819 GB/s
  collective = wire_bytes_per_chip / link bandwidth
with wire bytes derived from the parsed HLO collective schedule:
  all-gather (g-1)/g x result | reduce-scatter (g-1) x result
  all-reduce 2(g-1)/g x result | all-to-all (g-1)/g x result | permute 1x.

MODEL_FLOPS uses 6*N_active*tokens (train) or 2*N_active*tokens (inference);
the ratio MODEL/HLO catches remat and redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os

PEAK = 197e12
HBM = 819e9
ICI = 50e9
DCN = 3.125e9

SHAPE_TOKENS = {  # global tokens processed per step
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,
    "long_500k": 1,
}
TRAIN_MULT = {"train_4k": 6, "prefill_32k": 2, "decode_32k": 2,
              "long_500k": 2}


def wire_bytes(collectives: dict) -> tuple[float, float]:
    """(ici_bytes, dcn_bytes) per chip. Size-2 groups on the multipod mesh
    are attributed to DCN (the pod axis; see caveat for etp=2 archs)."""
    ici = dcn = 0.0
    for op, d in collectives.items():
        for gs, bucket in d.get("by_group", {}).items():
            g = int(gs) or 1
            b = bucket["bytes"]
            if op == "all-gather":
                w = b * (g - 1) / g
            elif op == "reduce-scatter":
                w = b * (g - 1)
            elif op == "all-reduce":
                w = 2 * b * (g - 1) / g
            elif op == "all-to-all":
                w = b * (g - 1) / g
            else:  # collective-permute
                w = b
            ici += w
    return ici, dcn


def analyse(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    # prefer the scan-extrapolated probe costs (XLA counts loop bodies once)
    cost = rec.get("cost_x") or rec["cost"]
    colls = rec.get("collectives_x") or rec.get("collectives", {})
    flops = cost.get("flops", 0.0)
    mem_b = cost.get("bytes accessed", 0.0)
    ici_b, dcn_b = wire_bytes(colls)
    # pod-axis traffic on the multipod mesh: size-2 groups
    pod_b = 0.0
    if rec["mesh"] == "2x16x16":
        for op, d in colls.items():
            for gs, bucket in d.get("by_group", {}).items():
                if int(gs) == 2:
                    pod_b += bucket["bytes"]
    t_c = flops / PEAK
    t_m = mem_b / HBM
    t_x = (ici_b - pod_b) / ICI + pod_b / DCN
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    tokens = SHAPE_TOKENS[rec["shape"]]
    model_flops = (TRAIN_MULT[rec["shape"]] * rec["params_active"] * tokens
                   / chips)
    step_time = max(t_c, t_m, t_x)
    mfu = model_flops / PEAK / step_time if step_time else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom, "model_flops": model_flops, "hlo_flops": flops,
        "useful_ratio": model_flops / flops if flops else 0.0,
        "roofline_frac": mfu,
        "probed": "cost_x" in rec,
        "temp_gib": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30,
    }


def load_all(root="results/dryrun"):
    out = []
    for f in sorted(glob.glob(os.path.join(root, "*.json"))):
        rec = json.load(open(f))
        a = analyse(rec)
        if a:
            out.append(a)
        elif rec.get("status") == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "dominant": "skipped"})
    return out


def markdown_table(rows, mesh="16x16"):
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck |"
        " MODEL/HLO flops | roofline frac | temp GiB |",
        "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["dominant"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"skipped (full attention @500k) | - | - | - |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2%} | {r['temp_gib']:.1f} |")
    return "\n".join(lines)


def run():
    from benchmarks._timing import emit
    for r in load_all():
        if r["dominant"] == "skipped":
            continue
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"bottleneck={r['dominant']};frac={r['roofline_frac']:.3f};"
             f"useful={r['useful_ratio']:.2f}")


if __name__ == "__main__":
    rows = load_all()
    print(markdown_table(rows, "16x16"))
    print()
    print(markdown_table(rows, "2x16x16"))
