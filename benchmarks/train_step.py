"""End-to-end train-step benchmark: comm-visible vs comm-hidden grad sync.

The primitive sweep prices collectives in isolation; this section prices a
whole training step (fwd -> bwd -> gradient sync -> clip -> AdamW) on the
multi-pod CPU substrate (2 pods x 2 data x 2 model), the accounting the PIM
methodology survey (arXiv:2205.14647) asks for.  Two variants of the same
step run on identical params/batch:

  barrier (comm-visible)
      ``TrainConfig(overlap_grad_sync=False)``: backward completes, then
      one coalesced grad-sync program executes -- every wire microsecond
      lands on the critical path.

  overlap (comm-hidden)
      ``TrainConfig(overlap_grad_sync=True)``: reverse-layer bucket
      programs fire *during* backward via custom_vjp hooks
      (:mod:`repro.runtime.overlap`), so the head bucket's sync runs under
      the remaining backward compute.

Both step functions are checked bit-identical (same updated params from
the same inputs) before timing.  Each variant contributes a row to the
``programs`` section of the bench trajectory: ``measured_us`` is the
median wall time per step (the regression-gate column -- on this
substrate's in-process device threads the two fused programs wall-time
within noise of each other, XLA CPU serializes collectives against
compute), ``serial_est_us`` sums the step's traced grad-sync op estimates
(all comm priced on the critical path), and ``plan_est_us`` is the
*exposed* sync budget under the DDP exposure model (see
:func:`_price_step`): the barrier program is fully exposed, the
overlapped path exposes only its final bucket, so the overlapped row's
``plan_est_us`` sits strictly below the barrier row's.  Under the tuned
CommProfile of a ``--profile`` run both estimate columns are
measured-sourced.  On vma-tracking jax the hook path is inert, so the two
variants collapse to the same step -- the rows still gate wall-time
regressions but the overlap-vs-barrier gap is only meaningful on the
pre-vma leg.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks._timing import bench, emit

ARCH = "qwen3-1.7b"
STEP_NAME = "train_step"      # row names: train_step_barrier/_overlap

# Upscale the smoke config until the pod-crossing gradient sync is a real
# fraction of the step (~25MB of replicated gradients): at pure smoke scale
# the sync is <1% of wall time and the overlap win drowns in step noise.
SCALE = dict(d_model=256, n_heads=8, head_dim=32, d_ff=1024, vocab_size=8192)


def _setup_train():
    from repro.configs import get
    from repro.launch.mesh import make_mesh
    from repro.models.topology import build_topology
    cfg = dataclasses.replace(get(ARCH).scaled_for_smoke(), tp=2, **SCALE)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    topo = build_topology(cfg, mesh)
    return cfg, topo


def _make_batch(cfg, B=8, S=32, seed=11):
    import jax.numpy as jnp
    rng = np.random.RandomState(seed)
    return {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }


def _fresh_state(cfg, topo, tc):
    import jax
    import jax.numpy as jnp
    from repro.models.params import init_params
    from repro.runtime.trainer import opt_structs
    params = init_params(cfg, topo, seed=3)
    # moment shapes (8-bit quantization scale columns) depend on the mesh
    # sharding, so build them from the dry-run structs, not init_state
    opt = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                       opt_structs(cfg, topo, tc))
    return params, opt


def _step_timer(step_fn, params, opt_state, batch):
    """Per-call closure that threads the (donated) carry through."""
    import jax
    state = [params, opt_state]
    def call():
        p, o, _ = step_fn(state[0], state[1], batch)
        jax.block_until_ready((p, o))
        state[0], state[1] = p, o
    return call


def _price_step(tr, cube):
    """(ops, exposed_plan_us, serial_est_us, est_source) for one traced
    step.  ``serial`` sums every grad-sync op's estimate (all comm priced
    on the critical path).  ``exposed`` prices what the sync adds to the
    step under the DDP exposure model: the barrier path's single program
    is entirely exposed (it cannot start before the last gradient exists),
    while the overlapped path exposes only its *final* bucket -- the one
    whose cotangents are backward's last outputs -- because every earlier
    bucket fires with backward compute still ahead to hide under.  Each
    program is priced by :func:`planner.plan_program`, so under an
    installed tuned CommProfile both columns are measured-sourced."""
    from repro.core import planner
    by_prog: dict[str, list] = {}
    for e in tr.events:
        if e.program_id and e.program_id.startswith("grad-sync"):
            by_prog.setdefault(e.program_id, []).append(e)
    serial_s = sum(e.seconds for evs in by_prog.values() for e in evs)
    plans = {}
    for pid, evs in by_prog.items():
        plans[pid] = planner.plan_program(cube, [
            planner.ProgramOpSpec(op_id=i, primitive=e.primitive,
                                  dims=e.dims, payload_bytes=e.payload_bytes)
            for i, e in enumerate(evs)])
    sources = {p.est_source for p in plans.values()}
    source = sources.pop() if len(sources) == 1 else ("mixed" if sources
                                                      else "analytic")
    # buckets are named grad-sync-b{k}; the highest k (the embedding
    # bucket) is the one backward cannot hide.  The barrier path has one
    # unsuffixed program, which is then also the "last" -- fully exposed.
    exposed_s = 0.0
    if plans:
        last = max(plans, key=lambda pid: int(pid.rsplit("-b", 1)[1])
                   if "-b" in pid else -1)
        exposed_s = plans[last].seconds
    return len(tr.events), exposed_s * 1e6, serial_s * 1e6, source


def _assert_bit_identical(p_a, p_b):
    import jax
    flat_a, tdef = jax.tree.flatten(jax.device_get(p_a))
    flat_b = tdef.flatten_up_to(jax.device_get(p_b))
    for a, b in zip(flat_a, flat_b):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            "overlapped grad sync diverged from the barrier path")


def train_step_bench():
    """Emits train_step_{barrier,overlap} program rows; asserts the two
    sync paths produce bit-identical updated params first."""
    from repro.core.comm import CommTrace
    from repro.runtime.trainer import TrainConfig, make_train_step

    cfg, topo = _setup_train()
    batch = _make_batch(cfg, B=8, S=64)
    variants = {
        "barrier": TrainConfig(overlap_grad_sync=False),
        "overlap": TrainConfig(overlap_grad_sync=True),
    }
    steps = {tag: make_train_step(cfg, topo, tc)
             for tag, tc in variants.items()}

    # bit-identity gate: one step of each variant from identical state --
    # this first call is also the one jax traces, so it is the call the
    # CommTrace must wrap to see the step's comm events
    stepped, traces = {}, {}
    for tag, tc in variants.items():
        params, opt_state = _fresh_state(cfg, topo, tc)
        with CommTrace() as tr:
            p1, _, _ = steps[tag](params, opt_state, batch)
        stepped[tag], traces[tag] = p1, tr
    _assert_bit_identical(stepped["barrier"], stepped["overlap"])

    rows = {}
    for tag, tc in variants.items():
        params, opt_state = _fresh_state(cfg, topo, tc)
        tr = traces[tag]
        call = _step_timer(steps[tag], params, opt_state, batch)
        us = bench(call, warmup=2, reps=7)
        n_ops, exposed_us, serial_us, source = _price_step(tr, topo.cube)
        rows[tag] = {"name": f"{STEP_NAME}_{tag}", "ops": n_ops,
                     "measured_us": round(us, 2),
                     "plan_est_us": round(exposed_us, 3),
                     "serial_est_us": round(serial_us, 3),
                     "est_source": source}
        emit(f"train_step/{ARCH}/{tag}", us,
             f"events={n_ops};sync_exposed_us={exposed_us:.1f}"
             f";sync_serial_us={serial_us:.1f};est_source={source}")
    hidden = (rows["barrier"]["plan_est_us"]
              - rows["overlap"]["plan_est_us"])
    emit(f"train_step/{ARCH}/comm_hidden_us", hidden,
         "barrier_exposed_minus_overlap_exposed")

    overhead = telemetry_overhead_bench(cfg, topo, steps["barrier"],
                                        variants["barrier"], batch,
                                        disabled_us=rows["barrier"]
                                        ["measured_us"])
    ckpt = ckpt_overlap_bench(cfg, topo, variants["barrier"])
    return [rows["barrier"], rows["overlap"], overhead, ckpt]


def ckpt_overlap_bench(cfg, topo, tc):
    """``ckpt_overlap`` row: what an async checkpoint save costs the
    training loop per dispatch.

    ``measured_us`` is the median wall time of an async
    ``CheckpointManager.save()`` call -- the rooted-gather programs
    (device->host, must run at dispatch because the train step donates the
    buffers) plus the executor handoff; serialization and disk writes are
    off the timed path.  ``plan_est_us``/``serial_est_us`` price the
    recorded gather programs through :func:`planner.plan_program`
    (overlap-priced vs summed per-op estimates).  The derived cell carries
    the synchronous save wall time: ``sync_save_us - measured_us`` is the
    write time the async design hides under training.
    """
    import shutil
    import tempfile
    import time as _time

    from repro.checkpoint.manager import CheckpointManager, TrainState
    from repro.core import planner
    from repro.core.comm import CommTrace
    from repro.models.params import param_specs
    from repro.runtime.trainer import opt_specs

    params, opt_state = _fresh_state(cfg, topo, tc)
    state = TrainState(params=params, opt=opt_state)
    specs = {"params": param_specs(cfg, topo),
             "opt": opt_specs(cfg, topo, tc)}
    root = tempfile.mkdtemp(prefix="bench-ckpt-")
    try:
        mgr = CheckpointManager(root, topo=topo, specs=specs, keep_last=1)
        with CommTrace() as tr:   # first save records + lowers the gathers
            mgr.save(0, state)
        mgr.wait()

        by_prog: dict[str, list] = {}
        for e in tr.events:
            if e.program_id and e.program_id.startswith("ckpt-gather"):
                by_prog.setdefault(e.program_id, []).append(e)
        serial_s = sum(e.seconds for evs in by_prog.values() for e in evs)
        plans = {pid: planner.plan_program(topo.cube, [
            planner.ProgramOpSpec(op_id=i, primitive=e.primitive,
                                  dims=e.dims, payload_bytes=e.payload_bytes)
            for i, e in enumerate(evs)]) for pid, evs in by_prog.items()}
        plan_s = sum(p.seconds for p in plans.values())
        sources = {p.est_source for p in plans.values()}
        source = sources.pop() if len(sources) == 1 else "mixed"
        n_ops = sum(len(evs) for evs in by_prog.values())

        def timed_saves(manager, reps):
            times, step = [], manager.latest_step() or 0
            for _ in range(reps):
                step += 1
                manager.wait()    # drain OUTSIDE the timed window
                t0 = _time.perf_counter()
                manager.save(step, state)
                times.append(_time.perf_counter() - t0)
            manager.wait()
            times.sort()
            return times[len(times) // 2] * 1e6

        timed_saves(mgr, 2)                      # warmup (cache-hit path)
        async_us = timed_saves(mgr, 5)
        sync_mgr = CheckpointManager(root, topo=topo, specs=specs,
                                     keep_last=1, async_save=False)
        sync_us = timed_saves(sync_mgr, 5)
        emit(f"train_step/{ARCH}/ckpt_overlap", async_us,
             f"sync_save_us={sync_us:.1f}"
             f";hidden_write_us={sync_us - async_us:.1f}"
             f";gather_ops={n_ops};est_source={source}")
        return {"name": "ckpt_overlap", "ops": n_ops,
                "measured_us": round(async_us, 2),
                "plan_est_us": round(plan_s * 1e6, 3),
                "serial_est_us": round(serial_s * 1e6, 3),
                "est_source": source}
    finally:
        shutil.rmtree(root, ignore_errors=True)


def telemetry_overhead_bench(cfg, topo, step_fn, tc, batch, *,
                             disabled_us: float):
    """``telemetry_overhead`` row: the barrier step re-timed with metrics
    enabled and a Tracer active, including the per-step bookkeeping
    ``Trainer.run`` does on the enabled path (span + counter + histogram).
    ``measured_us`` is the enabled step; ``plan_est_us``/``serial_est_us``
    carry the disabled baseline (the already-gated ``train_step_barrier``
    cell), so the gate tracks the enabled path and the ratio of the two
    columns is the relative overhead -- "disabled within noise of the
    pre-PR step" is enforced by the unchanged ``train_step_barrier`` row.

    The Tracer sees no CommEvents here (the step is already compiled;
    dispatch happens at trace time), so this prices exactly the
    steady-state cost a metered production loop pays per step.
    """
    from repro import telemetry

    params, opt_state = _fresh_state(cfg, topo, tc)
    inner = _step_timer(step_fn, params, opt_state, batch)

    def call():
        with telemetry.maybe_span("train-step", cat="wall"):
            inner()
        telemetry.inc("train.steps")
        telemetry.observe("train.step_seconds", 0.0)

    telemetry.enable_metrics()
    try:
        with telemetry.Tracer():
            us = bench(call, warmup=2, reps=7)
    finally:
        telemetry.disable_metrics()
        telemetry.REGISTRY.reset()
    emit(f"train_step/{ARCH}/telemetry_overhead", us,
         f"disabled_us={disabled_us:.1f}"
         f";overhead_ratio={us / disabled_us:.4f}")
    return {"name": "telemetry_overhead", "ops": 2,
            "measured_us": round(us, 2),
            "plan_est_us": round(disabled_us, 2),
            "serial_est_us": round(disabled_us, 2),
            "est_source": "measured"}


def run():
    from benchmarks import primitives
    primitives.PROGRAM_ROWS.extend(train_step_bench())
