"""Shared timing + device-bootstrap helpers for the benchmark harness.

The harness is its own process entry point and configures 8 CPU devices for
real multi-device collective timing (never the dry-run's fake 512).
"""
import os
import time


def ensure_devices(n: int = 8):
    if "XLA_FLAGS" not in os.environ or "device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n} "
            + os.environ.get("XLA_FLAGS", ""))
    import jax
    assert len(jax.devices()) >= n, (
        "benchmarks must be launched fresh (jax already initialized with "
        f"{len(jax.devices())} devices)")


def bench(fn, *, warmup: int = 2, reps: int = 5) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
