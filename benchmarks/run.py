"""Benchmark harness entry point: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Runs on 8 real CPU devices
(its own process; never inherits the dry-run's fake 512).

    PYTHONPATH=src python -m benchmarks.run [--only primitives|apps|roofline]
    PYTHONPATH=src python -m benchmarks.run --profile [--cache-dir DIR]

Every run of the primitives section seeds the bench trajectory at
``--bench-json`` (default ``BENCH_primitives.json`` at the repo root): one
row per measured primitive cell (primitive, flow, stage, nbytes,
measured_us, est_us, est_source) plus a ``programs`` section of measured
multi-op schedules (name, ops, measured_us, plan_est_us, serial_est_us,
est_source).

``--profile`` exercises the tuning subsystem end to end: run the primitive
sweep with analytic estimates, ``tune()`` on the live substrate (per-op
alpha-beta models AND program-level overlap factors), save the
``CommProfile`` into the cache dir, *reload it under the same topology
fingerprint*, install it, and re-run the sweep -- the emitted
``profile/meas_over_est`` lines compare the median measurement/estimate
ratio before and after calibration (the calibrated median must sit strictly
closer to 1.0), and the program section re-runs under the installed profile
so its joint plans are measured-sourced.

``--check-against SEED[=FRESH]`` is the CI regression gate: after the run,
every (primitive, flow, nbytes) row *and* every named ``programs`` entry
(the multi-op schedules plus the end-to-end ``train_step`` barrier/overlap
pair) of the fresh bench JSON is compared against SEED and the process
exits non-zero when any cell's best ``measured_us`` regresses beyond
``--tolerance`` (default 2x -- CPU-substrate wall times are noisy; the
gate catches order-of-magnitude breakage, not percent drift).  Seed cells
are lifted to the ``--floor-us`` absolute floor before the tolerance
applies, so a zero/denormal seed cell cannot fail the gate on noise.

The flag repeats to gate several bench files in one invocation -- each
occurrence names a committed seed and, after ``=``, the fresh JSON to hold
against it (defaulting to this run's ``--bench-json``), so the primitive
trajectory and the serving trajectory (``BENCH_serving.json``, produced by
``benchmarks/serving.py``) share one gate with per-file coverage warnings:

    python -m benchmarks.run --profile --bench-json BENCH_fresh.json \\
        --check-against BENCH_primitives.json \\
        --check-against BENCH_serving.json=BENCH_serving_fresh.json
"""
import argparse
import json
import statistics
import sys

from benchmarks._timing import ensure_devices

BENCH_JSON = "BENCH_primitives.json"

SEED_RECIPE = """\
bench-regression gate:
  compare a fresh run against the committed seed (CI does this per matrix
  leg; exits 1 on any >tolerance regression):
      python -m benchmarks.run --profile --bench-json BENCH_fresh.json \\
          --check-against BENCH_primitives.json

seed refresh (after an intentional perf or schema change):
      python -m benchmarks.run --profile --cache-dir .tuning-cache \\
          --bench-json BENCH_primitives.json
      git add BENCH_primitives.json   # commit the new trajectory seed

serving trajectory (seeded by benchmarks/serving.py, gated here):
      python -m benchmarks.serving --bench-json BENCH_serving_fresh.json
      python -m benchmarks.run --profile --bench-json BENCH_fresh.json \\
          --check-against BENCH_primitives.json \\
          --check-against BENCH_serving.json=BENCH_serving_fresh.json
"""


def _write_bench_json(path: str, rows, programs=(), extra: dict | None = None
                      ) -> None:
    doc = {"schema": ["primitive", "flow", "stage", "nbytes", "measured_us",
                      "est_us", "est_source"],
           "program_schema": ["name", "ops", "measured_us", "plan_est_us",
                              "serial_est_us", "est_source"],
           "rows": list(rows),
           "programs": list(programs)}
    if extra:
        doc.update(extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path} ({len(doc['rows'])} rows, "
          f"{len(doc['programs'])} programs)", file=sys.stderr)


def _median_ratio(rows) -> float:
    """Median measured/estimated ratio over rows with a usable estimate."""
    ratios = [r["measured_us"] / r["est_us"] for r in rows
              if r.get("est_us", 0) > 0]
    return statistics.median(ratios) if ratios else float("nan")


def _best_by_key(rows) -> dict:
    """Best (minimum) measured_us per (primitive, flow, nbytes) -- several
    algorithm requests can execute the same flow at the same size, and the
    min damps single-run noise on both sides of the comparison."""
    out: dict[tuple, float] = {}
    for r in rows:
        key = (r["primitive"], r["flow"], r["nbytes"])
        us = float(r["measured_us"])
        if key not in out or us < out[key]:
            out[key] = us
    return out


def _best_by_name(programs) -> dict:
    """Best (minimum) measured_us per program-row name."""
    out: dict[str, float] = {}
    for r in programs:
        us = float(r["measured_us"])
        if r["name"] not in out or us < out[r["name"]]:
            out[r["name"]] = us
    return out


def check_against(seed_path: str, fresh_path: str,
                  tolerance: float = 2.0, floor_us: float = 5.0
                  ) -> list[str]:
    """Compare a fresh bench JSON against the committed seed; returns the
    list of regression descriptions (empty = gate passes).  Gates both the
    primitive ``rows`` (keyed by primitive/flow/nbytes) and the ``programs``
    section (keyed by name).  Rows present in the seed but missing from the
    fresh run are reported as warnings (a coverage drop cannot "pass"
    silently) without failing the gate.

    ``floor_us`` is the absolute comparison floor: the seed value is lifted
    to at least this many microseconds before the tolerance multiplies it.
    Without it a zero (or denormally small) seed cell makes the gate
    hair-trigger -- any measurable fresh value exceeds ``tolerance * ~0``
    and fails on pure noise instead of a real regression."""
    with open(seed_path) as f:
        seed = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    failures = []
    label = seed_path

    def gate(section, seed_best, fresh_best):
        for key, seed_us in sorted(seed_best.items()):
            fresh_us = fresh_best.get(key)
            tag = key if isinstance(key, str) else "/".join(
                str(k) for k in key)
            if fresh_us is None:
                print(f"# check-against[{label}]: {section} {tag} missing "
                      "from fresh run (coverage dropped)", file=sys.stderr)
                continue
            if fresh_us > tolerance * max(seed_us, floor_us):
                failures.append(
                    f"{label}: {tag}: {fresh_us:.1f}us vs seed "
                    f"{seed_us:.1f}us (> {tolerance:g}x tolerance)")
        new = sorted(set(fresh_best) - set(seed_best))
        if new:
            print(f"# check-against[{label}]: {len(new)} new {section} "
                  "cells not in the seed (refresh the seed to start "
                  "tracking them)", file=sys.stderr)

    gate("row", _best_by_key(seed["rows"]), _best_by_key(fresh["rows"]))
    gate("program", _best_by_name(seed.get("programs", [])),
         _best_by_name(fresh.get("programs", [])))
    return failures


def profile_mode(cache_dir: str, out_json: str) -> None:
    """tune -> save -> reload (same fingerprint) -> re-run the sweep."""
    from benchmarks import primitives
    from repro.core import planner
    from repro.tuning import Tuner

    cube = primitives._setup((8,), ("d",))

    # 1. analytic baseline sweep
    primitives.ROWS.clear()
    primitives.fig14_fig16_primitives()
    analytic_rows = list(primitives.ROWS)
    med_analytic = _median_ratio(analytic_rows)

    # 2. tune on the live substrate (per-op models + overlap) and persist
    tuner = Tuner(cache_dir=cache_dir)
    profile = tuner.tune(cube, sizes=(64 * 1024, 256 * 1024, 512 * 1024,
                                      1024 * 1024))
    path = tuner.profile_path(cube)
    print(f"# tuned {profile.describe()} -> {path}", file=sys.stderr)

    # 3. reload under the same topology fingerprint (load() rejects drift)
    reloaded = tuner.load(cube)

    # 4. calibrated sweep + program-level section under the reloaded
    # profile: the joint plans (and their interleaving budgets) are priced
    # from the measured models and overlap factors
    primitives.ROWS.clear()
    primitives.PROGRAM_ROWS.clear()
    with planner.install_profile(reloaded):
        primitives.fig14_fig16_primitives()
        primitives.program_fusion()
        primitives.program_overlap()
        primitives.fused_kernels()
    # 5. end-to-end step accounting.  The train-step bench runs on the
    # multi-pod (2x2x2) cube, a different topology fingerprint than the
    # ring sweep above -- tune that cube too so the step's grad-sync
    # exposure estimates (incl. the DCN hop) price measured-sourced.
    from benchmarks import train_step
    pod_cube = train_step._setup_train()[1].cube
    pod_profile = tuner.tune(pod_cube, sizes=(64 * 1024, 256 * 1024,
                                              1024 * 1024))
    print(f"# tuned {pod_profile.describe()} (train-step cube)",
          file=sys.stderr)
    with planner.install_profile(pod_profile):
        train_step.run()
    measured_rows = list(primitives.ROWS)
    med_measured = _median_ratio(measured_rows)

    emit_rows = analytic_rows + measured_rows
    closer = abs(med_measured - 1.0) < abs(med_analytic - 1.0)
    _write_bench_json(out_json, emit_rows, primitives.PROGRAM_ROWS, extra={
        "median_meas_over_est": {"analytic": med_analytic,
                                 "measured": med_measured},
        "calibration_improved": closer,
        "profile_path": path})
    print(f"profile/meas_over_est/analytic,{med_analytic:.3f},")
    print(f"profile/meas_over_est/measured,{med_measured:.3f},"
          f"closer_to_1={closer}")
    if not closer:
        print("# WARNING: calibrated estimates did not improve on the "
              "analytic baseline", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog=SEED_RECIPE)
    ap.add_argument("--only", default=None,
                    choices=["primitives", "apps", "roofline"])
    ap.add_argument("--profile", action="store_true",
                    help="tune -> save -> reload -> calibrated re-run of "
                         "the primitive sweep (incl. program-level overlap)")
    ap.add_argument("--cache-dir", default=".tuning-cache",
                    help="CommProfile cache directory for --profile")
    ap.add_argument("--bench-json", default=BENCH_JSON,
                    help="bench-trajectory output path (never written "
                         "anywhere else)")
    ap.add_argument("--check-against", action="append", default=None,
                    metavar="SEED[=FRESH]",
                    help="after the run, gate a fresh bench JSON against "
                         "this committed seed; exit 1 on regression. "
                         "Repeatable; FRESH defaults to --bench-json, so "
                         "extra occurrences can gate other trajectories "
                         "(e.g. BENCH_serving.json=BENCH_serving_fresh.json)")
    ap.add_argument("--tolerance", type=float, default=2.0,
                    help="check-against noise tolerance as a ratio "
                         "(default 2.0 = fail when a row doubles)")
    ap.add_argument("--floor-us", type=float, default=5.0,
                    help="check-against absolute floor: seed cells are "
                         "lifted to at least this many microseconds before "
                         "the tolerance applies (a zero seed cell must not "
                         "fail the gate on noise)")
    args = ap.parse_args()

    ensure_devices(8)

    print("name,us_per_call,derived")
    wrote_bench = False
    if args.profile:
        profile_mode(args.cache_dir, args.bench_json)
        wrote_bench = True
    else:
        if args.only in (None, "primitives"):
            from benchmarks import primitives, train_step
            primitives.run()
            train_step.run()
            _write_bench_json(args.bench_json, primitives.ROWS,
                              primitives.PROGRAM_ROWS)
            wrote_bench = True
        if args.only in (None, "apps"):
            from benchmarks import apps
            apps.run()
        if args.only in (None, "roofline"):
            from benchmarks import roofline
            roofline.run()

    if args.check_against:
        failures = []
        for spec in args.check_against:
            seed, _, fresh = spec.partition("=")
            if not fresh:
                # gating this run's own output needs this run to have
                # produced it; an explicit SEED=FRESH pair gates a file
                # written by another harness (e.g. benchmarks/serving.py)
                if not wrote_bench:
                    print("# check-against requires a run that writes the "
                          "bench JSON (primitives or --profile)",
                          file=sys.stderr)
                    sys.exit(2)
                fresh = args.bench_json
            failures += check_against(seed, fresh, args.tolerance,
                                      args.floor_us)
        if failures:
            print("# BENCH REGRESSION:", file=sys.stderr)
            for f in failures:
                print(f"#   {f}", file=sys.stderr)
            print("# intentional change? refresh the seed (see --help)",
                  file=sys.stderr)
            sys.exit(1)
        print(f"# check-against {', '.join(args.check_against)}: "
              f"ok (tolerance {args.tolerance:g}x)", file=sys.stderr)


if __name__ == '__main__':
    main()
