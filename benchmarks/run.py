"""Benchmark harness entry point: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Runs on 8 real CPU devices
(its own process; never inherits the dry-run's fake 512).

    PYTHONPATH=src python -m benchmarks.run [--only primitives|apps|roofline]
    PYTHONPATH=src python -m benchmarks.run --profile [--cache-dir DIR]

Every run of the primitives section seeds the bench trajectory:
``BENCH_primitives.json`` at the repo root, one row per measured cell
(primitive, flow, stage, nbytes, measured_us, est_us, est_source).

``--profile`` exercises the tuning subsystem end to end: run the primitive
sweep with analytic estimates, ``tune()`` on the live substrate, save the
``CommProfile`` into the cache dir, *reload it under the same topology
fingerprint*, install it, and re-run the sweep -- the emitted
``profile/meas_over_est`` lines compare the median measurement/estimate
ratio before and after calibration (the calibrated median must sit strictly
closer to 1.0).
"""
import argparse
import json
import os
import statistics
import sys

from benchmarks._timing import ensure_devices

BENCH_JSON = "BENCH_primitives.json"


def _write_bench_json(path: str, rows, extra: dict | None = None) -> None:
    doc = {"schema": ["primitive", "flow", "stage", "nbytes", "measured_us",
                      "est_us", "est_source"],
           "rows": list(rows)}
    if extra:
        doc.update(extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path} ({len(doc['rows'])} rows)", file=sys.stderr)


def _median_ratio(rows) -> float:
    """Median measured/estimated ratio over rows with a usable estimate."""
    ratios = [r["measured_us"] / r["est_us"] for r in rows
              if r.get("est_us", 0) > 0]
    return statistics.median(ratios) if ratios else float("nan")


def profile_mode(cache_dir: str, out_json: str) -> None:
    """tune -> save -> reload (same fingerprint) -> re-run the sweep."""
    from benchmarks import primitives
    from repro.core import planner
    from repro.tuning import Tuner

    cube = primitives._setup((8,), ("d",))

    # 1. analytic baseline sweep
    primitives.ROWS.clear()
    primitives.fig14_fig16_primitives()
    analytic_rows = list(primitives.ROWS)
    med_analytic = _median_ratio(analytic_rows)

    # 2. tune on the live substrate and persist
    tuner = Tuner(cache_dir=cache_dir)
    profile = tuner.tune(cube, sizes=(64 * 1024, 256 * 1024, 512 * 1024,
                                      1024 * 1024))
    path = tuner.profile_path(cube)
    print(f"# tuned {profile.describe()} -> {path}", file=sys.stderr)

    # 3. reload under the same topology fingerprint (load() rejects drift)
    reloaded = tuner.load(cube)

    # 4. calibrated sweep under the reloaded profile
    primitives.ROWS.clear()
    with planner.install_profile(reloaded):
        primitives.fig14_fig16_primitives()
    measured_rows = list(primitives.ROWS)
    med_measured = _median_ratio(measured_rows)

    emit_rows = analytic_rows + measured_rows
    closer = abs(med_measured - 1.0) < abs(med_analytic - 1.0)
    _write_bench_json(out_json, emit_rows, extra={
        "median_meas_over_est": {"analytic": med_analytic,
                                 "measured": med_measured},
        "calibration_improved": closer,
        "profile_path": path})
    print(f"profile/meas_over_est/analytic,{med_analytic:.3f},")
    print(f"profile/meas_over_est/measured,{med_measured:.3f},"
          f"closer_to_1={closer}")
    if not closer:
        print("# WARNING: calibrated estimates did not improve on the "
              "analytic baseline", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["primitives", "apps", "roofline"])
    ap.add_argument("--profile", action="store_true",
                    help="tune -> save -> reload -> calibrated re-run of "
                         "the primitive sweep")
    ap.add_argument("--cache-dir", default=".tuning-cache",
                    help="CommProfile cache directory for --profile")
    ap.add_argument("--bench-json", default=BENCH_JSON,
                    help="bench-trajectory output path")
    args = ap.parse_args()

    ensure_devices(8)

    print("name,us_per_call,derived")
    if args.profile:
        profile_mode(args.cache_dir, args.bench_json)
        return
    if args.only in (None, "primitives"):
        from benchmarks import primitives
        primitives.run()
        _write_bench_json(args.bench_json, primitives.ROWS)
    if args.only in (None, "apps"):
        from benchmarks import apps
        apps.run()
    if args.only in (None, "roofline"):
        from benchmarks import roofline
        roofline.run()


if __name__ == '__main__':
    main()
