"""Benchmark harness entry point: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Runs on 8 real CPU devices
(its own process; never inherits the dry-run's fake 512).

    PYTHONPATH=src python -m benchmarks.run [--only primitives|apps|roofline]
"""
import argparse
import sys

from benchmarks._timing import ensure_devices


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["primitives", "apps", "roofline"])
    args = ap.parse_args()

    ensure_devices(8)

    print("name,us_per_call,derived")
    if args.only in (None, "primitives"):
        from benchmarks import primitives
        primitives.run()
    if args.only in (None, "apps"):
        from benchmarks import apps
        apps.run()
    if args.only in (None, "roofline"):
        from benchmarks import roofline
        roofline.run()


if __name__ == '__main__':
    main()
