"""Primitive-level benchmarks: paper Fig. 14 (throughput baseline vs
PID-Comm), Fig. 16 (ablation naive -> +PR -> +IM -> +CM), Fig. 18 (data
size), Fig. 19 (device count), Fig. 20 (hypercube shapes), Fig. 23(a)
(ring / tree / hypercube) and 23(b) (hierarchical multi-pod).

Throughput convention follows the paper (§VIII-B): payload = the larger side
of the exchanged data divided by wall time.
"""
from __future__ import annotations

import numpy as np

from benchmarks._timing import bench, emit

# Bench-trajectory rows (one per measured primitive cell); harvested by
# ``benchmarks/run.py`` into BENCH_primitives.json at the repo root.  Schema
# per row: primitive, flow, stage, nbytes, measured_us, est_us, est_source.
ROWS: list[dict] = []

# Program-level trajectory rows (one per measured multi-op schedule):
# name, ops, measured_us, plan_est_us (the overlap-aware joint budget),
# serial_est_us, est_source (the ProgramPlan's provenance).
PROGRAM_ROWS: list[dict] = []


def _record_row(primitive: str, ev, us: float) -> None:
    if ev is None:
        return
    ROWS.append({
        "primitive": primitive, "flow": ev.flow, "stage": ev.stage,
        "nbytes": ev.payload_bytes, "measured_us": round(us, 2),
        "est_us": round(ev.seconds * 1e6, 3), "est_source": ev.est_source})


def _record_program_row(name: str, lowered, us: float) -> None:
    plan = lowered.plan
    PROGRAM_ROWS.append({
        "name": name, "ops": len(lowered.ops),
        "measured_us": round(us, 2),
        "plan_est_us": round(plan.seconds * 1e6, 3),
        "serial_est_us": round(plan.serial_seconds * 1e6, 3),
        "est_source": plan.est_source})


def _setup(shape, names):
    from repro.core.hypercube import Hypercube
    from repro.launch.mesh import make_mesh
    mesh = make_mesh(shape, names)
    return Hypercube.build(mesh, dict(zip(names, shape)))


def _smap_call(cube, f, in_specs, out_specs, *args):
    import jax
    from repro.compat import shard_map
    fn = jax.jit(shard_map(f, mesh=cube.mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False))
    return lambda: jax.block_until_ready(fn(*args))


def fig14_fig16_primitives(size_kb: int = 512):
    """8 primitives x every applicable algorithm stage on an 8-device dim.

    Each cell runs through a bound :class:`Communicator` under a
    :class:`CommTrace`; the ``derived`` column carries the planner's Table II
    ``stage`` and estimated seconds next to the measurement, plus the
    measured/estimated ratio (the estimate uses TPU v5e constants, so on the
    CPU substrate the ratio calibrates the model, it does not validate it).
    """
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.comm import CommTrace, applicability
    APPLICABILITY = applicability()
    cube = _setup((8,), ("d",))
    comm = cube.comm("d")
    n = size_kb * 1024 // 4
    g = 8
    x = jnp.ones((g, n), jnp.float32)

    cases = {
        "all_reduce": lambda alg: _smap_call(
            cube, lambda v: comm.all_reduce(v, algorithm=alg),
            (P("d", None),), P(None, None), x),
        "reduce_scatter": lambda alg: _smap_call(
            cube, lambda v: comm.reduce_scatter(v, axis=1, algorithm=alg),
            (P("d", None),), P("d", None), x),
        "all_gather": lambda alg: _smap_call(
            cube, lambda v: comm.all_gather(v, axis=0, algorithm=alg),
            (P("d", None),), P(None, None), x),
        "all_to_all": lambda alg: _smap_call(
            cube, lambda v: comm.all_to_all(v, split_axis=1,
                                            concat_axis=1, algorithm=alg),
            (P("d", None),), P("d", None), x),
    }
    payload = g * n * 4
    for prim, make in cases.items():
        base_us = None
        for alg in APPLICABILITY[prim] + ("pidcomm", "auto"):
            with CommTrace() as tr:
                us = bench(make(alg))   # first call traces -> records event
            if alg == "naive":
                base_us = us
            gbps = payload / (us * 1e-6) / 1e9
            speedup = base_us / us if base_us else 1.0
            derived = f"GBps={gbps:.2f};speedup_vs_naive={speedup:.2f}"
            ev = next((e for e in tr.events if e.primitive == prim), None)
            if ev is not None and ev.seconds > 0:
                est_us = ev.seconds * 1e6
                derived += (f";flow={ev.flow};stage={ev.stage}"
                            f";est_us={est_us:.1f}"
                            f";meas_over_est={us / est_us:.1f}"
                            f";est_source={ev.est_source}")
            _record_row(prim, ev, us)
            emit(f"fig14_16/{prim}/{alg}", us, derived)

    # rooted primitives (host <-> PE path, jit-boundary timing)
    import jax
    host = np.ones((g, n), np.float32)
    dev = comm.scatter(host, axis=0)
    rooted = {
        "scatter": lambda: jax.block_until_ready(comm.scatter(host, axis=0)),
        "gather": lambda: comm.gather(dev),
        "broadcast": lambda: jax.block_until_ready(comm.broadcast(host)),
        "reduce": lambda: comm.reduce(dev),
    }
    for prim, call in rooted.items():
        with CommTrace() as tr:
            us = bench(call)
        ev = next((e for e in tr.events if e.primitive == prim), None)
        _record_row(prim, ev, us)
        emit(f"fig14/{prim}/pidcomm", us, "")


def fig18_size_sweep():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    cube = _setup((8,), ("d",))
    comm = cube.comm("d")
    for kb in (128, 512, 2048, 8192):
        n = kb * 1024 // 4
        x = jnp.ones((8, n), jnp.float32)
        for alg in ("naive", "pidcomm"):
            fn = _smap_call(
                cube, lambda v: comm.all_reduce(v, algorithm=alg),
                (P("d", None),), P(None, None), x)
            us = bench(fn)
            emit(f"fig18/all_reduce/{kb}KB/{alg}", us,
                 f"GBps={8*n*4/(us*1e-6)/1e9:.2f}")


def fig19_device_sweep():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    for nd in (2, 4, 8):
        cube = _setup((nd,), ("d",))
        comm = cube.comm("d")
        n = 512 * 1024 // 4
        x = jnp.ones((nd, n), jnp.float32)
        for alg in ("naive", "pidcomm"):
            fn = _smap_call(
                cube, lambda v: comm.all_reduce(v, algorithm=alg),
                (P("d", None),), P(None, None), x)
            us = bench(fn)
            emit(f"fig19/all_reduce/{nd}dev/{alg}", us,
                 f"GBps={nd*n*4/(us*1e-6)/1e9:.2f}")


def fig20_cube_shapes():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    n = 256 * 1024 // 4
    for shape in ((8,), (4, 2), (2, 2, 2)):
        names = ("x", "y", "z")[: len(shape)]
        cube = _setup(shape, names)
        comm = cube.comm(names, algorithm="pidcomm")
        x = jnp.ones((8, n), jnp.float32)
        fn = _smap_call(
            cube, lambda v: comm.all_to_all(v, split_axis=1,
                                            concat_axis=1),
            (P(names, None),), P(names, None), x)
        us = bench(fn)
        tag = "x".join(str(s) for s in shape)
        emit(f"fig20/all_to_all/{tag}", us,
             f"GBps={8*n*4/(us*1e-6)/1e9:.2f}")


def fig23_topologies():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.collectives import ring_all_reduce, tree_all_reduce
    cube = _setup((8,), ("d",))
    comm = cube.comm("d", algorithm="pidcomm")
    n = 512 * 1024 // 4
    x = jnp.ones((8, n), jnp.float32)
    fns = {
        "hypercube": lambda v: comm.all_reduce(v),
        "ring": lambda v: ring_all_reduce(v[0], cube, "d")[None],
        "tree": lambda v: tree_all_reduce(v, cube, "d"),
    }
    for name, f in fns.items():
        fn = _smap_call(cube, f, (P("d", None),), P(None, None), x)
        us = bench(fn)
        emit(f"fig23a/all_reduce/{name}", us,
             f"GBps={8*n*4/(us*1e-6)/1e9:.2f}")

    # 23(b): hierarchical multi-pod AR (pod axis = DCN domain)
    from repro.core.hypercube import Hypercube
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cube2 = Hypercube.build(mesh, {"pod": 2, "dp": 2, "tp": 2})
    comm2 = cube2.comm(("pod", "dp"))
    x2 = jnp.ones((8, n), jnp.float32)
    for alg, tag in (("naive", "flat-naive"), ("pr", "flat-gathered"),
                     ("pidcomm", "hierarchical")):
        fn = _smap_call(
            cube2, lambda v: comm2.all_reduce(v, algorithm=alg),
            (P(("pod", "dp"), None),), P(None, None), x2)
        us = bench(fn)
        emit(f"fig23b/pod_all_reduce/{tag}", us, "")


def program_fusion(size_kb: int = 512):
    """Deferred-program benchmark: an eager rs+ag pair vs the recorded
    program whose lowering fuses the pair into one all_reduce, and a
    16-leaf gradient sync vs its coalesced one-bucket program."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.comm import CommTrace
    from repro.core.hypercube import Hypercube
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cube = Hypercube.build(mesh, {"pod": 2, "dp": 2, "tp": 2})
    comm = cube.comm(("pod", "dp"))
    n = size_kb * 1024 // 4
    x = jnp.ones((8, n), jnp.float32)
    in_specs = (P(("pod", "dp", "tp"), None),)
    out_specs = P(("pod", "dp", "tp"), None)

    eager = _smap_call(
        cube, lambda v: comm.all_gather(comm.reduce_scatter(v, axis=1),
                                        axis=1),
        in_specs, out_specs, x)
    us_eager = bench(eager)
    emit("program/rs_ag/eager", us_eager, "events=2")

    prog = cube.program(name="bench-rsag")
    with prog:
        a = prog.input(jax.ShapeDtypeStruct((1, n), jnp.float32))
        prog.output(comm.all_gather(comm.reduce_scatter(a, axis=1), axis=1))
    low = prog.lower()
    with CommTrace() as tr:
        fused = _smap_call(cube, lambda v: low.execute(v),
                           in_specs, out_specs, x)
        us_fused = bench(fused)
    ev = tr.events[0]
    emit("program/rs_ag/fused", us_fused,
         f"events={len(tr.events)};flow={ev.flow}"
         f";fused_from={len(ev.fused_from)}"
         f";speedup_vs_eager={us_eager / us_fused:.2f}")
    _record_program_row("rs_ag_fused", low, us_fused)

    grads_comm = cube.comm(("pod", "dp", "tp"))

    def per_leaf(*vs):
        return tuple(grads_comm.all_reduce(v) for v in vs)

    us_leaf = bench(_smap_call(cube, per_leaf,
                               tuple(in_specs * 16), tuple([out_specs] * 16),
                               *([jnp.ones((8, 4096), jnp.float32)] * 16)))
    emit("program/grad_sync/per_leaf", us_leaf, "events=16")

    gprog = cube.program(name="bench-coalesce")
    with gprog:
        ins = [gprog.input(jax.ShapeDtypeStruct((1, 4096), jnp.float32))
               for _ in range(16)]
        gprog.output(*(grads_comm.all_reduce(v) for v in ins))
    glow = gprog.lower()
    us_coal = bench(_smap_call(cube, lambda *vs: glow.execute(*vs),
                               tuple(in_specs * 16), tuple([out_specs] * 16),
                               *([jnp.ones((8, 4096), jnp.float32)] * 16)))
    emit("program/grad_sync/coalesced", us_coal,
         f"events=1;speedup_vs_per_leaf={us_leaf / us_coal:.2f}")
    _record_program_row("grad_sync_coalesced", glow, us_coal)


def program_overlap(size_kb: int = 256):
    """Overlap-aware scheduling benchmark: a two-independent-op program
    (all_reduce + all_gather on the 8-device ring) measured end to end
    against its joint plan.  Under an installed overlap-tuned CommProfile
    the plan's ``seconds`` budget and interleaving order are measured-
    sourced; the emitted row carries plan vs serial vs wall time so the
    trajectory tracks how well the interleaving model predicts reality."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    cube = _setup((8,), ("d",))
    comm = cube.comm("d")
    n = size_kb * 1024 // 4

    prog = cube.program(name="bench-overlap")
    with prog:
        a = prog.input(jax.ShapeDtypeStruct((1, n), jnp.float32))
        b = prog.input(jax.ShapeDtypeStruct((1, n), jnp.float32))
        prog.output(comm.all_reduce(a), comm.all_gather(b, axis=1))
    low = prog.lower()
    spec = P("d", None)
    x = jnp.ones((8, n), jnp.float32)
    y = jnp.ones((8, n), jnp.float32)
    from repro.tuning.microbench import measure_program
    us = measure_program(cube, low, (x, y), (spec, spec),
                         (spec, spec)) * 1e6
    plan = low.plan
    emit("program/overlap/ar_ag", us,
         f"ops={len(low.ops)};plan_est_us={plan.seconds * 1e6:.1f}"
         f";serial_est_us={plan.serial_seconds * 1e6:.1f}"
         f";est_source={plan.est_source}")
    _record_program_row("overlap_ar_ag", low, us)


def fused_kernels():
    """Collective-fused kernels (repro.kernels.collective): ring attention
    vs gather-then-attend and the lazy-tile rs_epilogue vs matmul +
    reduce_scatter.  The fused schedules are multi-hop compute/comm
    interleavings rather than single primitive cells, so their rows land in
    the bench trajectory's ``programs`` section (names ``fused_ring_attn``
    and ``rs_epilogue``) where ``--check-against`` gates their wall time;
    plan_est/serial_est carry the planner's fused vs direct pricing."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import planner
    from repro.core.comm import CommTrace
    from repro.kernels.collective import (matmul_reduce_scatter,
                                          ring_attention)
    from repro.models.layers import chunked_attention

    cube = _setup((8,), ("d",))
    comm = cube.comm("d")
    g = 8

    # ring attention: kv blocks rotate over the ring while the flash
    # kv-loop consumes them; baseline assembles the full sequence first
    B, S_loc, H, hd = 1, 128, 8, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (g, B, S_loc, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (g, B, S_loc, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (g, B, S_loc, H, hd), jnp.float32)
    specs = (P("d", None, None, None, None),) * 3
    out_spec = P("d", None, None, None, None)

    def ring(qv, kv, vv):
        return ring_attention(comm, qv[0], kv[0], vv[0])[None]

    def gather_attend(qv, kv, vv):
        kf = comm.all_gather(kv[0], axis=1)
        vf = comm.all_gather(vv[0], axis=1)
        q_off = comm.axis_index() * S_loc
        return chunked_attention(qv[0], kf, vf, causal=True,
                                 q_offset=q_off)[None]

    with CommTrace() as tr:
        us_fused = bench(_smap_call(cube, ring, specs, out_spec, q, k, v))
    ev = tr.events[0]
    us_base = bench(_smap_call(cube, gather_attend, specs, out_spec,
                               q, k, v))
    kv_bytes = 2 * B * S_loc * H * hd * 4          # the rotating (k, v) pair
    fused_est = planner.estimate(cube, "all_gather", ("d",), kv_bytes,
                                 algorithm="ring_fused")
    serial_est = planner.estimate(cube, "all_gather", ("d",), kv_bytes,
                                  algorithm="direct")
    emit("fused/ring_attn/fused", us_fused,
         f"flow={ev.flow};est_source={ev.est_source}"
         f";speedup_vs_gather={us_base / us_fused:.2f}")
    emit("fused/ring_attn/gather_attend", us_base, "")
    PROGRAM_ROWS.append({
        "name": "fused_ring_attn", "ops": 1, "measured_us": round(us_fused, 2),
        "plan_est_us": round(fused_est.seconds * 1e6, 3),
        "serial_est_us": round(serial_est.seconds * 1e6, 3),
        "est_source": ev.est_source})

    # rs_epilogue: the out-projection's partial product produced one 1/g
    # tile at a time inside the ring vs materialize-then-reduce_scatter
    L, K, N = 2048, 256, 256
    h = jax.random.normal(ks[0], (g, L, K), jnp.float32)
    w = jax.random.normal(ks[1], (K, N), jnp.float32)
    mspecs = (P("d", None, None),)
    mout = P("d", None, None)

    def fused_mm(hv):
        return matmul_reduce_scatter(comm, hv[0], w, axis=0)[None]

    def unfused_mm(hv):
        return comm.reduce_scatter(hv[0] @ w, axis=0)[None]

    with CommTrace() as tr:
        us_fused = bench(_smap_call(cube, fused_mm, mspecs, mout, h))
    ev = tr.events[0]
    us_base = bench(_smap_call(cube, unfused_mm, mspecs, mout, h))
    rs_bytes = L * N * 4                        # the never-materialized h @ w
    fused_est = planner.estimate(cube, "reduce_scatter", ("d",), rs_bytes,
                                 algorithm="rs_epilogue")
    serial_est = planner.estimate(cube, "reduce_scatter", ("d",), rs_bytes,
                                  algorithm="direct")
    emit("fused/rs_epilogue/fused", us_fused,
         f"flow={ev.flow};est_source={ev.est_source}"
         f";speedup_vs_unfused={us_base / us_fused:.2f}")
    emit("fused/rs_epilogue/matmul_rs", us_base, "")
    PROGRAM_ROWS.append({
        "name": "rs_epilogue", "ops": 1, "measured_us": round(us_fused, 2),
        "plan_est_us": round(fused_est.seconds * 1e6, 3),
        "serial_est_us": round(serial_est.seconds * 1e6, 3),
        "est_source": ev.est_source})


def run():
    fig14_fig16_primitives()
    fig18_size_sweep()
    fig19_device_sweep()
    fig20_cube_shapes()
    fig23_topologies()
    program_fusion()
    program_overlap()
    fused_kernels()
