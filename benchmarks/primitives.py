"""Primitive-level benchmarks: paper Fig. 14 (throughput baseline vs
PID-Comm), Fig. 16 (ablation naive -> +PR -> +IM -> +CM), Fig. 18 (data
size), Fig. 19 (device count), Fig. 20 (hypercube shapes), Fig. 23(a)
(ring / tree / hypercube) and 23(b) (hierarchical multi-pod).

Throughput convention follows the paper (§VIII-B): payload = the larger side
of the exchanged data divided by wall time.
"""
from __future__ import annotations

import numpy as np

from benchmarks._timing import bench, emit


def _setup(shape, names):
    from repro.core.hypercube import Hypercube
    from repro.core.collectives import Collectives
    from repro.launch.mesh import make_mesh
    mesh = make_mesh(shape, names)
    cube = Hypercube.build(mesh, dict(zip(names, shape)))
    return cube, Collectives(cube)


def _smap_call(cube, f, in_specs, out_specs, *args):
    import jax
    from repro.compat import shard_map
    fn = jax.jit(shard_map(f, mesh=cube.mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False))
    return lambda: jax.block_until_ready(fn(*args))


def fig14_fig16_primitives(size_kb: int = 512):
    """8 primitives x every applicable algorithm stage on an 8-device dim.

    Each cell runs through a bound :class:`Communicator` under a
    :class:`CommTrace`; the ``derived`` column carries the planner's Table II
    ``stage`` and estimated seconds next to the measurement, plus the
    measured/estimated ratio (the estimate uses TPU v5e constants, so on the
    CPU substrate the ratio calibrates the model, it does not validate it).
    """
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.collectives import APPLICABILITY
    from repro.core.comm import CommTrace
    cube, col = _setup((8,), ("d",))
    comm = cube.comm("d")
    n = size_kb * 1024 // 4
    g = 8
    x = jnp.ones((g, n), jnp.float32)

    cases = {
        "all_reduce": lambda alg: _smap_call(
            cube, lambda v: comm.all_reduce(v, algorithm=alg),
            (P("d", None),), P(None, None), x),
        "reduce_scatter": lambda alg: _smap_call(
            cube, lambda v: comm.reduce_scatter(v, axis=1, algorithm=alg),
            (P("d", None),), P("d", None), x),
        "all_gather": lambda alg: _smap_call(
            cube, lambda v: comm.all_gather(v, axis=0, algorithm=alg),
            (P("d", None),), P(None, None), x),
        "all_to_all": lambda alg: _smap_call(
            cube, lambda v: comm.all_to_all(v, split_axis=1,
                                            concat_axis=1, algorithm=alg),
            (P("d", None),), P("d", None), x),
    }
    payload = g * n * 4
    for prim, make in cases.items():
        base_us = None
        for alg in APPLICABILITY[prim] + ("pidcomm", "auto"):
            with CommTrace() as tr:
                us = bench(make(alg))   # first call traces -> records event
            if alg == "naive":
                base_us = us
            gbps = payload / (us * 1e-6) / 1e9
            speedup = base_us / us if base_us else 1.0
            derived = f"GBps={gbps:.2f};speedup_vs_naive={speedup:.2f}"
            ev = next((e for e in tr.events if e.primitive == prim), None)
            if ev is not None and ev.seconds > 0:
                est_us = ev.seconds * 1e6
                derived += (f";flow={ev.flow};stage={ev.stage}"
                            f";est_us={est_us:.1f}"
                            f";meas_over_est={us / est_us:.1f}")
            emit(f"fig14_16/{prim}/{alg}", us, derived)

    # rooted primitives (host <-> PE path, jit-boundary timing)
    import jax
    host = np.ones((g, n), np.float32)
    dev = col.scatter(host, ("d",), axis=0)
    emit("fig14/scatter/pidcomm",
         bench(lambda: jax.block_until_ready(
             col.scatter(host, ("d",), axis=0))), "")
    emit("fig14/gather/pidcomm", bench(lambda: col.gather(dev)), "")
    emit("fig14/broadcast/pidcomm",
         bench(lambda: jax.block_until_ready(col.broadcast(host))), "")
    emit("fig14/reduce/pidcomm", bench(lambda: col.reduce(dev)), "")


def fig18_size_sweep():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    cube, col = _setup((8,), ("d",))
    for kb in (128, 512, 2048, 8192):
        n = kb * 1024 // 4
        x = jnp.ones((8, n), jnp.float32)
        for alg in ("naive", "pidcomm"):
            fn = _smap_call(
                cube, lambda v: col.all_reduce(v, "d", algorithm=alg),
                (P("d", None),), P(None, None), x)
            us = bench(fn)
            emit(f"fig18/all_reduce/{kb}KB/{alg}", us,
                 f"GBps={8*n*4/(us*1e-6)/1e9:.2f}")


def fig19_device_sweep():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    for nd in (2, 4, 8):
        cube, col = _setup((nd,), ("d",))
        n = 512 * 1024 // 4
        x = jnp.ones((nd, n), jnp.float32)
        for alg in ("naive", "pidcomm"):
            fn = _smap_call(
                cube, lambda v: col.all_reduce(v, "d", algorithm=alg),
                (P("d", None),), P(None, None), x)
            us = bench(fn)
            emit(f"fig19/all_reduce/{nd}dev/{alg}", us,
                 f"GBps={nd*n*4/(us*1e-6)/1e9:.2f}")


def fig20_cube_shapes():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    n = 256 * 1024 // 4
    for shape in ((8,), (4, 2), (2, 2, 2)):
        names = ("x", "y", "z")[: len(shape)]
        cube, col = _setup(shape, names)
        x = jnp.ones((8, n), jnp.float32)
        fn = _smap_call(
            cube, lambda v: col.all_to_all(v, names, split_axis=1,
                                           concat_axis=1),
            (P(names, None),), P(names, None), x)
        us = bench(fn)
        tag = "x".join(str(s) for s in shape)
        emit(f"fig20/all_to_all/{tag}", us,
             f"GBps={8*n*4/(us*1e-6)/1e9:.2f}")


def fig23_topologies():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.collectives import ring_all_reduce, tree_all_reduce
    cube, col = _setup((8,), ("d",))
    n = 512 * 1024 // 4
    x = jnp.ones((8, n), jnp.float32)
    fns = {
        "hypercube": lambda v: col.all_reduce(v, "d"),
        "ring": lambda v: ring_all_reduce(v[0], cube, "d")[None],
        "tree": lambda v: tree_all_reduce(v, cube, "d"),
    }
    for name, f in fns.items():
        fn = _smap_call(cube, f, (P("d", None),), P(None, None), x)
        us = bench(fn)
        emit(f"fig23a/all_reduce/{name}", us,
             f"GBps={8*n*4/(us*1e-6)/1e9:.2f}")

    # 23(b): hierarchical multi-pod AR (pod axis = DCN domain)
    from repro.core.hypercube import Hypercube
    from repro.core.collectives import Collectives
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cube2 = Hypercube.build(mesh, {"pod": 2, "dp": 2, "tp": 2})
    col2 = Collectives(cube2)
    x2 = jnp.ones((8, n), jnp.float32)
    for alg, tag in (("naive", "flat-naive"), ("pr", "flat-gathered"),
                     ("pidcomm", "hierarchical")):
        fn = _smap_call(
            cube2, lambda v: col2.all_reduce(v, ("pod", "dp"), algorithm=alg),
            (P(("pod", "dp"), None),), P(None, None), x2)
        us = bench(fn)
        emit(f"fig23b/pod_all_reduce/{tag}", us, "")


def run():
    fig14_fig16_primitives()
    fig18_size_sweep()
    fig19_device_sweep()
    fig20_cube_shapes()
    fig23_topologies()
