"""Serving throughput benchmark: the continuous-batching engine under a
Poisson request-arrival trace, swept over batch size.

Per batch size the engine serves a mixed-length trace (random prompt and
output lengths) and reports aggregate decode throughput plus the per-token
latency distribution; all host<->PE control traffic rides the engine's
one-recorded-CommProgram-per-step path, so the numbers include the
program-scheduled collective overhead the framework actually pays.

    PYTHONPATH=src python -m benchmarks.serving [--bench-json BENCH_serving.json]

Seeds the serving bench trajectory (default ``BENCH_serving.json``): a
``programs`` section with three lower-is-better cells per batch size --

    serving/b<B>/tok_us    inverse aggregate throughput (us per token)
    serving/b<B>/p50_us    median per-token latency
    serving/b<B>/p99_us    tail  per-token latency

-- each carrying the per-step program's jointly-planned cost estimate, plus
a ``serving`` extra with the raw metrics (tokens/s, steps, preemptions).
CI gates a fresh run against the committed seed through the multi-file
``benchmarks.run --check-against BENCH_serving.json=BENCH_serving_fresh.json``.
"""
import argparse
import dataclasses
import sys

from benchmarks._timing import emit, ensure_devices

BENCH_JSON = "BENCH_serving.json"


def bench_batch(cfg, B: int, *, n_requests: int, s_ctx: int, seed: int):
    """One engine instance at batch ``B``: warmup trace (compiles the step),
    then the measured Poisson trace."""
    from repro.launch.mesh import make_mesh
    from repro.models.params import init_params
    from repro.models.serving import make_serve_plan
    from repro.models.topology import build_serve_topology
    from repro.serving import ServeEngine, poisson_trace

    mesh = make_mesh((1, 8), ("data", "model"))
    topo = build_serve_topology(cfg, mesh)
    plan = make_serve_plan(cfg, topo, S_ctx=s_ctx, global_batch=B)
    params = init_params(cfg, topo, seed=0)
    eng = ServeEngine(cfg, topo, plan, params, page_size=4, seed=seed)

    warm = poisson_trace(2, rate=2.0, plen_range=(3, 6),
                         max_new_range=(2, 3), vocab=cfg.vocab_size,
                         seed=seed + 1)
    eng.run(warm)
    eng.reset_metrics()               # warmup boundary

    trace = poisson_trace(n_requests, rate=max(1.0, B / 2),
                          plen_range=(4, 12), max_new_range=(4, 10),
                          vocab=cfg.vocab_size, seed=seed)
    for r in trace:
        r.arrival += eng.step_idx     # trace is relative to "now"
    metrics = eng.run(trace)
    # single measurement path: the latency/throughput cells come from the
    # engine's own metrics registry (run() populates its dict from the
    # same registry, so these agree by construction)
    metrics["tokens_per_s"] = eng.metrics.value("serve.tokens_per_s")
    metrics["p50_token_s"] = eng.metrics.quantile("serve.token_seconds",
                                                  0.50)
    metrics["p99_token_s"] = eng.metrics.quantile("serve.token_seconds",
                                                  0.99)
    lowered = eng.last_program.lower()
    metrics["plan_est_us"] = lowered.plan.seconds * 1e6
    metrics["serial_est_us"] = lowered.plan.serial_seconds * 1e6
    metrics["est_source"] = lowered.plan.est_source
    metrics["program_ops"] = len(lowered.ops)
    return metrics


def run(batches=(2, 4, 8), *, n_requests: int | None = None,
        s_ctx: int = 32, seed: int = 0):
    """Returns (program_rows, serving_extra) for the bench JSON."""
    from repro.configs import get

    cfg = get("qwen3-1.7b").scaled_for_smoke()
    cfg = dataclasses.replace(cfg, tp=8)
    program_rows, extra = [], {}
    for B in batches:
        n = n_requests or 3 * B
        m = bench_batch(cfg, B, n_requests=n, s_ctx=s_ctx, seed=seed)
        tok_us = 1e6 / m["tokens_per_s"]
        cells = {"tok_us": tok_us,
                 "p50_us": m["p50_token_s"] * 1e6,
                 "p99_us": m["p99_token_s"] * 1e6}
        for cell, us in cells.items():
            program_rows.append({
                "name": f"serving/b{B}/{cell}", "ops": m["program_ops"],
                "measured_us": us, "plan_est_us": m["plan_est_us"],
                "serial_est_us": m["serial_est_us"],
                "est_source": m["est_source"]})
            emit(f"serving/b{B}/{cell}", us)
        extra[str(B)] = {
            "tokens_per_s": m["tokens_per_s"], "steps": m["steps"],
            "generated_tokens": m["generated_tokens"],
            "requests": n, "preemptions": m["preemptions"],
            "programs_recorded": m["programs_recorded"]}
        print(f"# b{B}: {m['tokens_per_s']:.1f} tok/s over {m['steps']} "
              f"steps ({m['generated_tokens']} tokens, "
              f"{m['programs_recorded']} step programs)", file=sys.stderr)
    return program_rows, extra


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench-json", default=BENCH_JSON)
    ap.add_argument("--batches", default="2,4,8",
                    help="comma-separated batch sizes to sweep")
    ap.add_argument("--requests", type=int, default=None,
                    help="requests per batch point (default 3x batch)")
    ap.add_argument("--s-ctx", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    ensure_devices(8)

    print("name,us_per_call,derived")
    batches = tuple(int(b) for b in args.batches.split(","))
    rows, extra = run(batches, n_requests=args.requests, s_ctx=args.s_ctx,
                      seed=args.seed)
    from benchmarks.run import _write_bench_json
    _write_bench_json(args.bench_json, [], rows, extra={"serving": extra})


if __name__ == "__main__":
    main()
