"""Analytic per-chip HBM working-set estimates (v5e: 16 GB).

XLA-CPU's ``memory_analysis().temp_size_in_bytes`` over-approximates the
device peak: unrolled per-layer transients are not buffer-shared the way the
TPU compiler schedules them (verified with a micro-benchmark: N checkpointed
layers report ~N x one layer's transients). This module derives the
first-principles working set the TPU scheduler actually needs, per cell, and
is reported next to the XLA upper bound in EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

import json
import glob
import os

HBM = 16 * 2**30


def train_fit(cfg, chips: int, pods: int, gb: int, seq: int) -> dict:
    P = cfg.param_count()
    mp = cfg.model_parallel
    per_pod = chips // pods
    dp = per_pod // mp
    B_l = gb // (dp * pods)
    L = cfg.n_layers
    D = cfg.d_model
    state = P * 4 / (per_pod) + P * 2.1 / per_pod          # master + 8bit m,v
    grads = P * 2 / per_pod
    # largest single layer's gathered bf16 weights per chip
    per_layer = P / L
    gathered = 3 * per_layer * 2 / mp                      # fwd + bwd + grad
    resid = L * B_l * (seq // mp) * D * 2                  # saved x_sp
    act = 8 * B_l * seq * D * 2                            # one layer live
    ce = 2 * B_l * 512 * (cfg.vocab_size // mp) * 4
    total = state + grads + gathered + resid + act + ce
    return {"state": state, "grads": grads, "gathered": gathered,
            "residuals": resid, "activations": act, "ce": ce,
            "total_gib": total / 2**30, "fits": total < HBM}


def decode_fit(cfg, chips: int, pods: int, gb: int, seq: int) -> dict:
    from repro.models.config import ATTN
    P = cfg.param_count()
    per_pod = chips // pods
    mp = per_pod if not cfg.serve_tp else min(per_pod, cfg.serve_tp)
    params = P * 4 / per_pod + P * 2 / mp / 8              # stored + gathered/8
    n_attn = sum(1 for m in cfg.mixers() if m == ATTN)
    wins = cfg.windows()
    s_cache = seq
    if (wins >= 0).all() and len(set(wins.tolist())) == 1:
        s_cache = min(seq, int(wins[0]))
    B = max(gb // pods, 1)
    cache = n_attn * B * s_cache * cfg.n_kv_heads * cfg.head_dim * 2 * 2 / mp
    total = params + cache * 1.5
    return {"params": params, "cache": cache, "total_gib": total / 2**30,
            "fits": total < HBM}


def table(root="results/dryrun"):
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro import configs
    rows = ["| arch | shape | mesh | XLA-CPU temp GiB (upper bound) | "
            "analytic working set GiB | fits 16 GB |",
            "|---|---|---|---|---|---|"]
    for f in sorted(glob.glob(os.path.join(root, "*.json"))):
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            continue
        cfg = configs.get(rec["arch"])
        chips = 512 if rec["mesh"] == "2x16x16" else 256
        pods = 2 if chips == 512 else 1
        if rec["shape"] == "train_4k":
            fit = train_fit(cfg, chips, pods, 256, 4096)
        elif rec["shape"] == "prefill_32k":
            fit = train_fit(cfg, chips, pods, 32, 32768)
            fit["total_gib"] *= 0.5                        # no grads/residual
        elif rec["shape"] == "decode_32k":
            fit = decode_fit(cfg, chips, pods, 128, 32768)
        else:
            fit = decode_fit(cfg, chips, pods, 1, 524288)
        xla = rec.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30
        rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                    f"{xla:.1f} | {fit['total_gib']:.1f} | "
                    f"{'yes' if fit['total_gib'] < 16 else 'NO'} |")
    return "\n".join(rows)


if __name__ == "__main__":
    print(table())
