"""Application benchmarks: paper Fig. 13/15 -- the five workloads with
conventional (naive) vs PID-Comm collectives end-to-end."""
from __future__ import annotations

from benchmarks._timing import bench, emit


def run():
    from repro.apps.paper_apps import APPS
    from repro.core.hypercube import Hypercube
    from repro.launch.mesh import make_mesh

    for name, (make, ndims) in APPS.items():
        shape = {1: (8,), 2: (4, 2), 3: (2, 2, 2)}[ndims]
        names = ("x", "y", "z")[: ndims]
        mesh = make_mesh(shape, names)
        cube = Hypercube.build(mesh, dict(zip(names, shape)))
        naive_us = None
        for alg in ("naive", "pidcomm"):
            fn = make(cube, algorithm=alg)
            us = bench(fn, warmup=1, reps=3)
            if alg == "naive":
                naive_us = us
            emit(f"fig15/{name}/{alg}", us,
                 f"speedup_vs_naive={naive_us/us:.2f}" if naive_us else "")
