"""Elastic checkpoint restore: train-cube save -> serve-cube restore.

A qwen3-family smoke model is initialized on the training topology
(data-parallel cube), checkpointed through a topology-bound
:class:`CheckpointManager` -- the device->host side is ONE recorded
rooted-gather CommProgram per section, and a second save hits the
structural-fingerprint lower cache -- then the **same checkpoint** is
restored onto the serving topology (maximal tensor parallelism, a
different cube) through a rooted-scatter program planned for that cube.
The restored params are bit-identical to directly initializing on the
serve topology, and every checkpoint collective carries ``program_id``
provenance into the CommTrace.  The same planned-scatter path also places
a torch-free Hugging Face safetensors import.

    PYTHONPATH=src python examples/elastic_restore.py
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import shutil
import tempfile

import jax
import numpy as np

from repro.checkpoint import CheckpointManager, TrainState, hf_import
from repro.configs import get
from repro.core.comm import CommTrace
from repro.core.program import LOWER_STATS
from repro.launch.mesh import make_mesh
from repro.models.params import init_params, param_specs
from repro.models.topology import build_serve_topology, build_topology

cfg = get("qwen3-1.7b").scaled_for_smoke()
mesh = make_mesh((4, 2), ("data", "model"))
train_topo = build_topology(cfg, mesh)
serve_topo = build_serve_topology(cfg, mesh)
print("train cube:", train_topo.cube.describe())
print("serve cube:", serve_topo.cube.describe())

# ---- save on the training topology --------------------------------------
params = init_params(cfg, train_topo, seed=0)
ckpt_dir = tempfile.mkdtemp(prefix="elastic-ckpt-")
mgr = CheckpointManager(ckpt_dir, topo=train_topo, async_save=False,
                        specs={"params": param_specs(cfg, train_topo),
                               "opt": None})
hits0 = LOWER_STATS["cache_hits"]
with CommTrace() as save_trace:
    mgr.save(1, TrainState(params=params))
    mgr.save(2, TrainState(params=params))
save_hits = LOWER_STATS["cache_hits"] - hits0
assert save_hits >= 1, "second save must reuse the lowered gather program"
n_leaves = len(jax.tree.leaves(params))
print(f"saved steps {mgr.all_steps()}: {n_leaves} leaves per step through "
      f"program(s) {save_trace.summary()['programs']}, "
      f"{save_hits} lower-cache hit(s) on the repeat save")

# ---- elastic restore onto the serving topology --------------------------
serve_specs = param_specs(cfg, serve_topo)
with CommTrace() as restore_trace:
    restored = mgr.restore_params(2, serve_topo=serve_topo,
                                  specs=serve_specs)
summary = restore_trace.summary()
assert "ckpt-restore-params" in summary["programs"]
print(f"restored params onto the serve cube via planned program(s) "
      f"{summary['programs']}: {summary['events']} scatter ops, "
      f"{summary['ici_bytes']:.0f} ICI bytes planned")

direct = init_params(cfg, serve_topo, seed=0)
for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_flatten_with_path(restored)[0],
        jax.tree_util.tree_flatten_with_path(direct)[0]):
    assert pa == pb
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("elastic restore is bit-identical to direct init on the serve "
      "topology")

# ---- the same scatter path places a Hugging Face import -----------------
host_params = jax.tree.map(np.asarray, restored)
sd = hf_import.export_state_dict(host_params, cfg)
st_path = os.path.join(ckpt_dir, "model.safetensors")
hf_import.write_safetensors(st_path, sd)
imported = hf_import.import_checkpoint(st_path, cfg, serve_topo,
                                       specs=serve_specs)
for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_flatten_with_path(imported)[0],
        jax.tree_util.tree_flatten_with_path(host_params)[0]):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print(f"HF safetensors roundtrip ({len(sd)} tensors) placed through the "
      "same rooted-scatter program path, bit-identical")

shutil.rmtree(ckpt_dir)
