"""Serve a small model with batched requests: flash-decode with a shared
KV cache, per-request positions (continuous batching), greedy sampling.

    PYTHONPATH=src python examples/serve_decode.py
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs import get
from repro.launch.mesh import make_mesh
from repro.models.params import init_params, param_specs
from repro.models.serving import (
    Server, cache_specs, init_cache, make_serve_plan)
from repro.models.topology import build_serve_topology

cfg = get("qwen3-1.7b").scaled_for_smoke()
# serve on all 8 devices: maximal model sharding, batch replicated
import dataclasses
cfg = dataclasses.replace(cfg, tp=8)

mesh = make_mesh((1, 8), ("data", "model"))
topo = build_serve_topology(cfg, mesh)
B, S_ctx = 4, 48
plan = make_serve_plan(cfg, topo, S_ctx=S_ctx, global_batch=B)
server = Server(cfg, topo, plan)
print(f"serving {cfg.name} on {topo.cube.describe()}; "
      f"cache {plan.S_cache} x {B} requests")

params = init_params(cfg, topo, seed=0)
cache = init_cache(cfg, topo, plan)
ba = plan.batch_axes or None
step = jax.jit(shard_map(
    server.decode_shard, mesh=topo.cube.mesh,
    in_specs=(param_specs(cfg, topo), cache_specs(cfg, topo, plan),
              P(ba), P(ba)),
    out_specs=(P(ba, topo.tp), cache_specs(cfg, topo, plan)),
    check_vma=False), donate_argnums=(1,))

rng = np.random.RandomState(0)
# requests arrive with different prompt lengths (continuous batching):
prompt_lens = np.array([8, 12, 5, 16])
prompts = [rng.randint(0, cfg.vocab_size, (int(n),)) for n in prompt_lens]
pos = np.zeros(B, np.int32)
toks = np.array([p[0] for p in prompts], np.int32)
outputs = [[] for _ in range(B)]

import time
t0 = time.monotonic()
steps = 0
while pos.max() < S_ctx - 1:
    logits, cache = step(params, cache, jnp.asarray(toks),
                         jnp.asarray(pos))
    nxt = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
    steps += 1
    for b in range(B):
        pos[b] += 1
        if pos[b] < prompt_lens[b]:
            toks[b] = prompts[b][pos[b]]          # still consuming prompt
        else:
            toks[b] = nxt[b]
            outputs[b].append(int(nxt[b]))
dt = time.monotonic() - t0
print(f"{steps} decode steps in {dt:.1f}s "
      f"({steps*B/dt:.1f} tok/s aggregate)")
for b, o in enumerate(outputs):
    print(f"request {b} (prompt {prompt_lens[b]:2d}): {o[:10]}")
