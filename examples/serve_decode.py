"""Continuous-batching decode serving on the PE hypercube: a Poisson
arrival trace of mixed-length requests served by ``repro.serving`` --
paged KV cache (per-shard page pools, per-request page table), admission /
eviction / slot reuse per decode step, teacher-forced prefill through the
flash-decode cell, on-device sampling, and ONE recorded CommProgram of
rooted collectives per step, lowered once and served from the
structural-fingerprint cache ever after.

    PYTHONPATH=src python examples/serve_decode.py
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import dataclasses

from repro.configs import get
from repro.core.program import LOWER_STATS
from repro.launch.mesh import make_mesh
from repro.models.params import init_params
from repro.models.serving import make_serve_plan
from repro.models.topology import build_serve_topology
from repro.serving import ServeEngine, poisson_trace

cfg = get("qwen3-1.7b").scaled_for_smoke()
# serve on all 8 devices: maximal model sharding, batch replicated
cfg = dataclasses.replace(cfg, tp=8)

mesh = make_mesh((1, 8), ("data", "model"))
topo = build_serve_topology(cfg, mesh)
B, S_ctx = 4, 48
plan = make_serve_plan(cfg, topo, S_ctx=S_ctx, global_batch=B)
params = init_params(cfg, topo, seed=0)
# S_cache 48 over 8 shards = 6 slots/shard -> 3-slot pages, 2 per shard
engine = ServeEngine(cfg, topo, plan, params, page_size=3, seed=0)
print(f"serving {cfg.name} on {topo.cube.describe()}; "
      f"{B} lanes x {plan.S_cache} slots in "
      f"{engine.pplan.pages_per_shard}-page pools "
      f"({engine.pplan.page_size} slots/page, "
      f"{engine.pplan.n_shards} shards)")

# mixed request lengths under Poisson arrivals -- more requests than lanes,
# so lanes are reused as requests complete (continuous batching)
trace = poisson_trace(10, rate=1.5, plen_range=(5, 16),
                      max_new_range=(4, 10), vocab=cfg.vocab_size, seed=7)
before = dict(LOWER_STATS)
metrics = engine.run(trace)
hits = LOWER_STATS["cache_hits"] - before["cache_hits"]
lowered = LOWER_STATS["lowered"] - before["lowered"]

print(f"{metrics['steps']} engine steps in {metrics['wall_s']:.1f}s: "
      f"{metrics['generated_tokens']} tokens at "
      f"{metrics['tokens_per_s']:.1f} tok/s "
      f"(p50 {metrics['p50_token_s'] * 1e3:.1f} ms/tok, "
      f"p99 {metrics['p99_token_s'] * 1e3:.1f} ms/tok)")
print(f"per-step programs: {metrics['programs_recorded']} recorded, "
      f"{lowered} lowered, {hits} fingerprint-cache hits")
assert lowered == 1 and hits >= metrics["steps"] - 1
assert len(metrics["finished"]) == len(trace)
for r in sorted(metrics["finished"], key=lambda r: r.rid):
    assert len(r.out_tokens) == r.max_new
    print(f"request {r.rid} (arrived {r.arrival:2d}, prompt {r.plen:2d}): "
          f"steps {r.admitted_step}-{r.finished_step} -> "
          f"{r.out_tokens[:8]}")
