"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps with the full framework stack (hypercube collectives, FSDP specs,
8-bit AdamW, deterministic data stream, checkpointing).

    PYTHONPATH=src python examples/train_100m.py [--steps 200] [--d-model 512]

On this CPU container the defaults complete in tens of minutes; pass
``--steps 40 --d-model 256`` for a quick run. On TPU the same script runs
the same model on the full mesh.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, TokenStream
from repro.launch.mesh import make_mesh
from repro.models.config import ModelConfig
from repro.models.params import init_params
from repro.models.topology import build_topology
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="pidcomm-100m", family="dense",
        n_layers=args.layers, d_model=args.d_model, n_heads=8, n_kv_heads=4,
        head_dim=args.d_model // 8, d_ff=4 * args.d_model,
        vocab_size=32768, rope_theta=1e4, tp=1,
    )
    print(f"model: {cfg.param_count()/1e6:.1f}M params")

    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    topo = build_topology(cfg, mesh, global_batch=args.batch)
    tc = TrainConfig(lr=6e-4, warmup=max(args.steps // 10, 5),
                     total_steps=args.steps)
    params = init_params(cfg, topo, seed=0)
    opt = adamw.init_state(params, tc.adamw)

    stream = TokenStream(cfg, DataConfig(
        seq_len=args.seq, global_batch=args.batch,
        vocab_size=cfg.vocab_size))
    ckpt = CheckpointManager(args.ckpt_dir, topo=topo) \
        if args.ckpt_dir else None
    trainer = Trainer(cfg, topo, tc, checkpointer=ckpt)

    def batches():
        for s in range(args.steps):
            yield {k: jnp.asarray(v)
                   for k, v in stream.global_batch_at(s).items()}

    params, opt, hist = trainer.run(
        params, opt, batches(),
        checkpoint_every=args.steps // 2 if ckpt else 0,
        log_every=max(args.steps // 25, 1))
    if ckpt:
        ckpt.wait()
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over "
          f"{args.steps} steps")


if __name__ == "__main__":
    main()
