"""The paper's flagship application: DLRM on a 3D virtual hypercube
(Fig. 11), end-to-end with conventional vs PID-Comm collectives.

    PYTHONPATH=src python examples/dlrm_pipeline.py
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import time

from repro.apps.paper_apps import make_dlrm
from repro.core.hypercube import Hypercube
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
cube = Hypercube.build(mesh, {"x": 2, "y": 2, "z": 2})
print("DLRM hypercube (tables x rows x cols):", cube.describe())
print("comm chain: lookup -> AlltoAll(xyz) -> ReduceScatter(y) -> "
      "AlltoAll(xz) -> MLP\n")

for alg in ("naive", "pidcomm"):
    run = make_dlrm(cube, batch_per_shard=64, emb_dim=32, algorithm=alg)
    run()                                    # compile + warm
    t0 = time.monotonic()
    for _ in range(5):
        run()
    dt = (time.monotonic() - t0) / 5
    print(f"{alg:8s}: {dt*1e3:7.2f} ms/step")
