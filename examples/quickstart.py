"""Quickstart: the PID-Comm communicator API in five minutes.

Builds a 2x2x2 virtual hypercube over 8 (fake CPU) devices, binds
communicators to dim selections (``cube.comm``), runs multi-instance
collectives over cube slices (paper Fig. 5), sweeps the Table II algorithm
stages, lets planner-driven ``algorithm="auto"`` dispatch pick the
§IX-A hierarchical flow on a pod-crossing all-reduce -- with every dispatch
observed by a :class:`CommTrace` -- and records a deferred ``cube.program()``
whose lowering fuses a reduce_scatter+all_gather chain into one all_reduce.
Section 9 walks the backward-overlapped gradient sync: reverse-layer bucket
programs fired inside backward via custom_vjp hooks, bit-identical to the
barrier path.  Section 10 runs the continuous-batching serve engine
(paged KV cache + one recorded CommProgram per decode step) through an
admit -> prefill -> decode -> evict request lifecycle.  Section 11 races
the collective-fused kernels (repro.kernels.collective): a measured
profile steers a recorded program's all_gather onto the ring_fused flow,
bit-identically.

    PYTHONPATH=src python examples/quickstart.py

Set ``QUICKSTART_SUMMARY=/path.json`` to dump the CommTrace summaries
(CI uploads them as the API-surface artifact).
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import CommTrace, Hypercube, plan
from repro.launch.mesh import make_mesh

# 1. define a virtual hypercube over the physical mesh (paper §IV-B):
#    dims are user-chosen; mapping follows the device hierarchy.
mesh = make_mesh((2, 4), ("data", "model"))
cube = Hypercube.build(mesh, {"x": 2, "y": 2, "z": 2})
print("cube:", cube.describe())

# 2. bind a communicator to a dim selection: the bitmap "010" selects the y
#    dimension -> four independent AllReduce instances run at once.  The
#    handle caches group size / instance count / ICI-DCN split once.
ar_y = cube.comm("010")
print("comm:", ar_y.describe())

x = jnp.arange(8.0 * 6).reshape(2, 2, 2, 6)
out = jax.jit(shard_map(
    lambda v: ar_y.all_reduce(v), mesh=cube.mesh,
    in_specs=P("x", "y", "z", None), out_specs=P("x", None, "z", None),
    check_vma=False))(x)
print("AllReduce along y (4 instances):", np.asarray(out).shape)

# 3. AlltoAll over the (x, z) plane -- 2 instances of group size 4
#    (the DLRM embedding exchange of paper Fig. 11).
aa_xz = cube.comm(("x", "z"))
out = jax.jit(shard_map(
    lambda v: aa_xz.all_to_all(v, split_axis=3, concat_axis=3),
    mesh=cube.mesh, in_specs=P("x", "y", "z", None),
    out_specs=P("x", "y", "z", None), check_vma=False))(
        jnp.ones((2, 2, 2, 8)))
print("AlltoAll over (x,z):", np.asarray(out).shape)

# 4. algorithm stages (paper Fig. 16 ablation): naive -> pr -> im -> cm;
#    "auto" asks the planner, "pidcomm" takes the strongest Table II stage.
aa_z = cube.comm("001")
for alg in ("naive", "pr", "im", "pidcomm", "auto"):
    out = jax.jit(shard_map(
        lambda v: aa_z.all_to_all(v, split_axis=3, concat_axis=3,
                                  algorithm=alg),
        mesh=cube.mesh, in_specs=P("x", "y", "z", None),
        out_specs=P("x", "y", "z", None), check_vma=False))(
            jnp.ones((2, 2, 2, 8)))
    print(f"  all_to_all[{alg:8s}] ok, shape {np.asarray(out).shape}")

# 5. plan-driven dispatch across pods: on a pod-crossing gradient AllReduce
#    the planner picks the hierarchical §IX-A split (ICI reduce-scatter ->
#    DCN all-reduce of the 1/|ICI| shard -> ICI all-gather), and that is
#    what algorithm="auto" executes.  CommTrace records each dispatch with
#    the chosen flow/stage and the estimated ICI/DCN bytes and seconds.
prod = Hypercube.build(make_mesh((2, 2, 2), ("pod", "data", "model")),
                       {"pod": 2, "dp": 2, "tp": 2})
grad_ar = prod.comm(("pod", "dp"))
est = plan(prod, "all_reduce", ("pod", "dp"), 64 * 2**20)
print(f"plan: {est.algorithm} via {est.schedule}; "
      f"ICI {est.ici_bytes/2**20:.0f} MiB, DCN {est.dcn_bytes/2**20:.0f} MiB,"
      f" est {est.seconds*1e3:.2f} ms")

with CommTrace() as trace:
    g = jnp.ones((2, 2, 2, 64), jnp.float32)
    out = jax.jit(shard_map(
        lambda v: grad_ar.all_reduce(v), mesh=prod.mesh,
        in_specs=P("pod", "dp", "tp", None),
        out_specs=P(None, None, "tp", None), check_vma=False))(g)
for ev in trace.events:
    print(f"traced: {ev.primitive}[{ev.bitmap}] -> {ev.flow} "
          f"(stage {ev.stage}, g={ev.group_size}x{ev.num_instances}inst, "
          f"ICI {ev.ici_bytes:.0f}B, DCN {ev.dcn_bytes:.0f}B, "
          f"est {ev.seconds*1e6:.2f}us)")
assert trace.events and trace.events[0].flow == "hierarchical"
print("auto dispatch executed the planner's hierarchical pick")

# 6. deferred programs (record -> optimize -> execute): composed patterns
#    are recorded as a CommProgram, and lower() optimizes the whole chain --
#    here the reduce_scatter + all_gather pair (the two halves of a gradient
#    sync written out by hand) fuses into ONE all_reduce, which on the
#    pod-crossing group executes the hierarchical split.  CommTrace.summary()
#    shows the provenance: one event, fused from two recorded ops.
with grad_ar.program(name="quickstart-fuse") as prog:
    a = prog.input(jax.ShapeDtypeStruct((1, 1, 1, 64), jnp.float32))
    shard = grad_ar.reduce_scatter(a, axis=3)
    full = grad_ar.all_gather(shard, axis=3)
    prog.output(full)
lowered = prog.lower()
print(lowered.describe())
assert len(lowered.ops) == 1 and lowered.ops[0].fused_from == (0, 1)

with CommTrace() as ptrace:
    out2 = jax.jit(shard_map(
        lambda v: lowered.execute(v), mesh=prod.mesh,
        in_specs=P("pod", "dp", "tp", None),
        out_specs=P("pod", "dp", "tp", None), check_vma=False))(g)
np.testing.assert_array_equal(np.asarray(out2)[0, 0], np.asarray(out)[0, 0])
summary = ptrace.summary()
print("program trace summary:", summary)
assert summary["fused_events"] == 1 and summary["events"] == 1
assert summary["programs"] == ["quickstart-fuse"]
print("record->optimize->execute: rs+ag fused into one hierarchical "
      "all_reduce, bit-identical to the eager result")

# 7. autotuning (measure -> fit -> plan): a Tuner microbenchmarks the
#    registered flows on the live substrate, fits per-(flow, stage, domain)
#    alpha-beta models, and persists them as a fingerprint-keyed
#    CommProfile.  Installing the profile makes algorithm="auto" dispatch
#    on *measured* data -- every CommEvent (and CommTrace.summary()) then
#    carries est_source="measured" instead of the analytic constants.
import tempfile  # noqa: E402

from repro.core import install_profile  # noqa: E402
from repro.tuning import Tuner  # noqa: E402

tuner = Tuner(cache_dir=tempfile.mkdtemp(prefix="repro-tuning-"))
prof = tuner.tune(cube, sizes=(16 * 1024, 64 * 1024),
                  primitives=("all_reduce", "all_to_all"),
                  reps=2, warmup=1)
print("tuned:", prof.describe())
prof = tuner.load(cube)        # reload: fingerprint-checked round-trip

with install_profile(prof), CommTrace() as ttrace:
    out = jax.jit(shard_map(
        lambda v: ar_y.all_reduce(v), mesh=cube.mesh,
        in_specs=P("x", "y", "z", None), out_specs=P("x", None, "z", None),
        check_vma=False))(x)
tuned_summary = ttrace.summary()
print("tuned trace summary:", tuned_summary)
assert ttrace.events[0].est_source == "measured"
assert tuned_summary["est_sources"] == {"measured": 1}
print("auto dispatch priced from the measured CommProfile "
      f"(flow {ttrace.events[0].flow}, "
      f"est {ttrace.events[0].seconds * 1e6:.1f}us measured)")

# 8. overlap-aware program scheduling (measure -> fit -> plan, program
#    level): the tune() above also ran the *overlap sweep* -- pairs of
#    collectives dispatched back-to-back vs alone -- fitting per-domain-pair
#    serialization factors into the profile.  With the profile installed,
#    plan_program prices a multi-op program's interleaving order and its
#    seconds-vs-serial budget from those measurements: the printed plan
#    carries est_source=measured, closing the loop the per-op models left
#    open.  Structurally identical recordings reuse one cached lowered
#    schedule (the trainer's per-step grad sync rides this cache).
from repro.core.program import LOWER_STATS  # noqa: E402

print("overlap factors:",
      {k: round(m.factor, 3) for k, m in prof.overlap.items()})

def record_pair():
    prog = cube.program(name="quickstart-overlap")
    with prog:
        a = prog.input(jax.ShapeDtypeStruct((1, 1, 1, 64), jnp.float32))
        b = prog.input(jax.ShapeDtypeStruct((1, 1, 1, 64), jnp.float32))
        prog.output(ar_y.all_reduce(a), aa_z.all_gather(b, axis=3))
    return prog

with install_profile(prof):
    lowered_pair = record_pair().lower()
    stats0 = dict(LOWER_STATS)
    record_pair().lower()                   # identical structure: cache hit
print(lowered_pair.describe())
plan = lowered_pair.plan
assert plan.est_source == "measured"
assert plan.seconds <= plan.serial_seconds + 1e-12
assert LOWER_STATS["cache_hits"] > stats0["cache_hits"]
print(f"overlap-aware plan: {plan.seconds*1e6:.1f}us vs serial "
      f"{plan.serial_seconds*1e6:.1f}us (est_source={plan.est_source}); "
      "re-recording reused the cached lowered program")

# 9. backward-overlapped gradient sync: the trainer's barrier path runs
#    backward to completion and then executes ONE coalesced grad-sync
#    program -- every wire microsecond exposed.  The overlapped path
#    (repro.runtime.overlap) partitions the replicated gradients into
#    reverse-layer buckets and fires each bucket's program *inside*
#    backward via an identity custom_vjp hook: the loss head's gradients
#    are backward's first outputs, so its bucket (grad-sync-b0) dispatches
#    while the rest of backward still computes, hiding its wire time.
#    Grads stay bit-identical to the barrier path.  On vma-tracking jax
#    autodiff inserts (and interleaves) the reductions itself, so the
#    hooks are inert there and the two paths coincide.
from repro import compat  # noqa: E402
from repro.runtime.overlap import with_backward_bucket_sync  # noqa: E402
from repro.runtime.trainer import sync_replicated_grads  # noqa: E402

tree = {"embed": jnp.ones((8, 4)),                 # sharded: no sync needed
        "units": {"w": jnp.ones((2, 16))},         # replicated trunk
        "lm_head": jnp.ones((4, 16))}              # replicated loss head
tspecs = {"embed": P(("pod", "dp", "tp"), None),
          "units": {"w": P()}, "lm_head": P()}

def toy_loss(p, b):
    # consume groups in forward order (embed -> trunk -> head), like a
    # real model: backward then produces the head gradients first
    h = jnp.sum(jnp.square(p["embed"])) + 0.0 * b
    h = h + jnp.sum(jnp.square(p["units"]["w"]))
    h = h + jnp.sum(jnp.square(p["lm_head"]))
    return h, {}

hooked_loss = with_backward_bucket_sync(toy_loss, tspecs, prod)

def overlapped_grads(p, b):
    (_, _), grads = jax.value_and_grad(hooked_loss, has_aux=True)(p, b)
    return grads                       # synced during backward, per bucket

def barrier_grads(p, b):
    (_, _), grads = jax.value_and_grad(toy_loss, has_aux=True)(p, b)
    return sync_replicated_grads(grads, tspecs, prod)

b9 = jnp.float32(1.0)
with CommTrace() as btrace:
    g_ov = jax.jit(shard_map(
        overlapped_grads, mesh=prod.mesh, in_specs=(tspecs, P()),
        out_specs=tspecs, check_vma=False))(tree, b9)
g_bar = jax.jit(shard_map(
    barrier_grads, mesh=prod.mesh, in_specs=(tspecs, P()),
    out_specs=tspecs, check_vma=False))(tree, b9)

bucket_order = [ev.program_id for ev in btrace.events
                if ev.program_id and ev.program_id.startswith("grad-sync-b")]
overlap_summary = btrace.summary()
print("backward-overlap trace summary:", overlap_summary)
print("bucket dispatch order during backward:", bucket_order)

flat_bar, tdef9 = jax.tree.flatten(jax.device_get(g_bar))
for want, got in zip(flat_bar, tdef9.flatten_up_to(jax.device_get(g_ov))):
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
if not compat.HAS_VMA:
    # head bucket first, trunk second; the fully-sharded embed leaf never
    # records a program at all
    assert bucket_order == ["grad-sync-b0", "grad-sync-b1"]
    assert overlap_summary["programs"] == ["grad-sync-b0", "grad-sync-b1"]
print("backward-overlapped sync: bucket programs fired in reverse-layer "
      "order during backward, bit-identical to the barrier sync")

# 10. production decode serving (repro.serving): a paged/block KV cache --
#     per-shard page pools, a per-request page table, cross-cube page
#     motion as rooted scatter/gather -- under a continuous-batching
#     engine.  One request's lifecycle: it ADMITS from the arrival queue
#     into a free batch lane, PREFILLS through the flash-decode cell
#     (chunk-1 chunked prefill: each step teacher-forces the next prompt
#     token into the paged cache), DECODES with on-device sampling until
#     its length budget is spent, and EVICTS, returning its pages to the
#     pools for the next admission.  Every step's host<->PE control
#     traffic is ONE recorded CommProgram (broadcasts + the lagged sampled
#     gather), so after the first step every lowering is a
#     structural-fingerprint cache hit.
from repro.configs import get  # noqa: E402
from repro.models.params import init_params  # noqa: E402
from repro.models.serving import make_serve_plan  # noqa: E402
from repro.models.topology import build_serve_topology  # noqa: E402
from repro.serving import Request, ServeEngine  # noqa: E402

cfg = get("qwen3-1.7b").scaled_for_smoke()
stopo = build_serve_topology(cfg, make_mesh((1, 1), ("data", "model")))
splan = make_serve_plan(cfg, stopo, S_ctx=24, global_batch=2)
engine = ServeEngine(cfg, stopo, splan, init_params(cfg, stopo, seed=0),
                     page_size=4)
reqs = [Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new=4),
        Request(rid=1, prompt=[2, 7, 1], max_new=6, arrival=2)]
sstats0 = dict(LOWER_STATS)
with CommTrace() as strace:
    serve_metrics = engine.run(reqs)
serve_summary = strace.summary()
print("serving trace summary:", serve_summary)
for r in serve_metrics["finished"]:
    print(f"  request {r.rid}: admitted step {r.admitted_step}, prefill "
          f"{r.plen} toks, decoded {r.out_tokens}, evicted after step "
          f"{r.finished_step}")
assert "serve-step" in serve_summary["programs"]
assert serve_metrics["programs_recorded"] == serve_metrics["steps"]
assert (LOWER_STATS["cache_hits"] - sstats0["cache_hits"]
        >= serve_metrics["steps"] - 1)
print(f"served {len(serve_metrics['finished'])} requests in "
      f"{serve_metrics['steps']} steps at "
      f"{serve_metrics['tokens_per_s']:.0f} tok/s; the per-step program "
      "lowered once and hit the fingerprint cache every step after")

# 11. collective-fused kernels (repro.kernels.collective): ring-rotation
#     flows that weave the collective *through* compute -- ring attention,
#     gather prologues, reduce-scatter epilogues -- registered in the same
#     algorithm registry as the Table II stages, so they trace, price, and
#     race under algorithm="auto".  A measured CommProfile that prices the
#     fused ring cheaper flips both the eager call site and a recorded
#     program's joint plan onto ring_fused; the movement itself is
#     bit-identical (it is the same blocks, interleaved with compute).
from repro.tuning import (CommProfile, LinkModel,  # noqa: E402
                          topology_fingerprint)

fast = LinkModel(alpha=0.0, beta=1e-12, n=8, r2=1.0)
slow = LinkModel(alpha=1.0, beta=1e-6, n=8, r2=1.0)
fused_prof = CommProfile(topology_fingerprint(cube), models={
    "ring_fused/cm/ici": fast, "rs_epilogue/cm/ici": fast,
    "naive/naive/ici": slow, "direct/im/ici": slow, "direct/cm/ici": slow})

ag_z = cube.comm("001")
with ag_z.program(name="quickstart-fused") as fprog:
    a = fprog.input(jax.ShapeDtypeStruct((1, 1, 1, 16), jnp.float32))
    fprog.output(ag_z.all_gather(a, axis=3))

with install_profile(fused_prof):
    flow_lowered = fprog.lower()
    fest = next(iter(flow_lowered.plan.estimates.values()))
    assert fest.algorithm == "ring_fused", fest
    assert fest.est_source == "measured"
    with CommTrace() as ftrace:
        fx = jnp.ones((2, 2, 2, 16), jnp.float32)
        fout = jax.jit(shard_map(
            lambda v: flow_lowered.execute(v), mesh=cube.mesh,
            in_specs=P("x", "y", "z", None),
            out_specs=P("x", "y", None, None), check_vma=False))(fx)
fused_summary = ftrace.summary()
print("fused-kernel trace summary:", fused_summary)
assert [ev.flow for ev in ftrace.events] == ["ring_fused"]
np.testing.assert_array_equal(          # same blocks, same bytes, same bits
    np.asarray(fout),
    np.asarray(jax.jit(shard_map(
        lambda v: ag_z.all_gather(v, axis=3, algorithm="pidcomm"),
        mesh=cube.mesh, in_specs=P("x", "y", "z", None),
        out_specs=P("x", "y", None, None), check_vma=False))(fx)))
print("measured profile steered the recorded program onto the fused ring "
      f"flow (est {fest.seconds * 1e6:.2f}us measured), bit-identical "
      "to the Table II gather")

# 12. unified telemetry (repro.telemetry): one Tracer captures a span
#     timeline across a train step and the serving engine.  While the
#     tracer is active it sits on the comm trace stack, so every live
#     CommEvent becomes a child span under whatever span is open --
#     carrying flow/stage/est_source/program_id/fused_from provenance --
#     and lower-cache hits annotate the timeline as instant marks.  The
#     metrics registry counts what the narrative above only printed, and
#     a drift monitor catches a synthetically mis-scaled profile: the
#     fused ring's real wall time sits far outside the band around the
#     profile's (absurdly fast) measured estimate, so exactly one
#     structured ProfileStalenessWarning names the stale
#     (flow, stage, domain) and carries the retune recipe.
import json  # noqa: E402
import time  # noqa: E402
import warnings  # noqa: E402

from repro import telemetry  # noqa: E402

engine.reset_metrics()                   # warmup boundary: fresh registry
steps_before12 = engine.step_idx         # run() reports cumulative steps
telemetry.enable_metrics()
with telemetry.Tracer() as tracer:
    with tracer.span("train-step", cat="wall"):
        # fresh jit -> retrace -> the step's grad-sync dispatches land as
        # child spans under the train-step envelope
        jax.block_until_ready(jax.jit(shard_map(
            barrier_grads, mesh=prod.mesh, in_specs=(tspecs, P()),
            out_specs=tspecs, check_vma=False))(tree, b9))
    req12 = Request(rid=9, prompt=[6, 2, 8, 3], max_new=3,
                    arrival=engine.step_idx)
    serve12 = engine.run([req12])        # serve-step spans + children
telemetry.disable_metrics()

chrome = json.loads(tracer.chrome_trace_json())   # Perfetto-loadable
evs = chrome["traceEvents"]
serve_spans = [e for e in evs if e.get("name") == "serve-step"]
prog_children = [e for e in evs if e.get("cat") == "comm"
                 and e["args"].get("program_id") == "serve-step"]
assert serve_spans, "each engine decode step opens a serve-step span"
assert prog_children, "the step program's ops land as comm child spans"
assert all("est_source" in e["args"] and "fused_from" in e["args"]
           for e in prog_children)
assert any(e.get("name") == "lower-cache-hit" for e in evs), \
    "warm-cache lowerings annotate the timeline"
snap = telemetry.REGISTRY.snapshot()
steps12 = serve12["steps"] - steps_before12
assert telemetry.REGISTRY.value("comm.dispatches") > 0
assert telemetry.REGISTRY.value("program.lower_cache_hits") >= steps12
assert engine.metrics.value("serve.steps") == steps12
assert serve12["p50_token_s"] == engine.metrics.quantile(
    "serve.token_seconds", 0.50)
print(f"telemetry: {len(serve_spans)} serve-step spans, "
      f"{len(prog_children)} per-op child spans with provenance, "
      f"{sum(e.get('name') == 'lower-cache-hit' for e in evs)} "
      "lower-cache-hit marks; engine registry is the measurement path")

mon = telemetry.DriftMonitor(min_samples=1)     # judge on first residual
t12 = time.perf_counter()
with install_profile(fused_prof):
    jax.block_until_ready(jax.jit(shard_map(
        lambda v: flow_lowered.execute(v), mesh=cube.mesh,
        in_specs=P("x", "y", "z", None),
        out_specs=P("x", "y", None, None), check_vma=False))(fx))
wall12 = time.perf_counter() - t12
with warnings.catch_warnings(record=True) as wlist:
    warnings.simplefilter("always")
    for ev in ftrace.events:     # measured-sourced, priced ~0 by fused_prof
        mon.observe_event(ev, measured_s=wall12)
stale = [w.message for w in wlist
         if isinstance(w.message, telemetry.ProfileStalenessWarning)]
assert len(stale) == 1, "exactly one structured warning per stale key"
sw = stale[0]
assert (sw.flow, sw.stage, sw.domain) == ("ring_fused", "cm", "ici")
assert "Tuner" in sw.recipe or "tune" in sw.recipe.lower()
print(f"drift monitor flagged ({sw.flow}, {sw.stage}, {sw.domain}): "
      f"median meas_over_est={sw.median:.3g} outside "
      f"[{sw.band[0]:g}, {sw.band[1]:g}] -- {sw.recipe}")

# 13. elastic checkpointing (repro.checkpoint): save from the 2x2x2 cube
#     -- one recorded rooted-gather program per section; the second save's
#     structural fingerprint matches the first, so it hits the lower cache
#     -- then restore the same checkpoint onto a 1-D ring of the same 8
#     devices through a rooted-scatter program planned for THAT cube.
#     Same global bits, different placement: the forward pass on the ring
#     is bit-identical.  Every checkpoint collective carries program_id
#     provenance into the trace.
import shutil  # noqa: E402
import tempfile  # noqa: E402

from repro.checkpoint import CheckpointManager, TrainState
from repro.core import program as program_mod  # noqa: E402

wspec = {"w": P("x", ("y", "z")), "b": P(("x", "y"), None)}
host_w = {"w": jnp.arange(64.0, dtype=jnp.float32).reshape(8, 8),
          "b": jnp.arange(32.0, dtype=jnp.float32).reshape(8, 4)}
placed_w = {k: jax.device_put(v, cube.sharding(wspec[k]))
            for k, v in host_w.items()}
ckpt_dir = tempfile.mkdtemp(prefix="quickstart-ckpt-")
saver = CheckpointManager(ckpt_dir, topo=cube, async_save=False,
                          specs={"params": wspec, "opt": None})
hits_before = program_mod.LOWER_STATS["cache_hits"]
saver.save(1, TrainState(params=placed_w))
saver.save(2, TrainState(params=placed_w))
ckpt_cache_hits = program_mod.LOWER_STATS["cache_hits"] - hits_before
assert ckpt_cache_hits >= 1, "second save must reuse the gather lowering"

ring = Hypercube.build(mesh, {"r": 8})          # elastic: different cube
rspec = {"w": P("r", None), "b": P("r", None)}
loader = CheckpointManager(ckpt_dir, topo=ring,
                           specs={"params": rspec, "opt": None})
with CommTrace() as ckpt_trace:
    restored = loader.restore_params(2)
ckpt_summary = ckpt_trace.summary()
assert "ckpt-restore-params" in ckpt_summary["programs"]
assert restored["w"].sharding.spec == P("r", None)

fwd13 = jax.jit(lambda t: t["w"] @ t["b"])
np.testing.assert_array_equal(np.asarray(fwd13(restored)),
                              np.asarray(fwd13(host_w)))
shutil.rmtree(ckpt_dir)
print("elastic restore: saved on {x,y,z}=2x2x2, restored onto {r}=8 via "
      f"a planned scatter program ({ckpt_cache_hits} save lower-cache "
      "hits); ring forward bit-identical to the host reference")

import os  # noqa: E402
if os.environ.get("QUICKSTART_SUMMARY"):
    out_dir = os.path.dirname(os.environ["QUICKSTART_SUMMARY"]) or "."
    with open(os.path.join(out_dir, "quickstart_chrome_trace.json"),
              "w") as f:
        f.write(tracer.chrome_trace_json())
    with open(os.path.join(out_dir, "quickstart_metrics.json"), "w") as f:
        json.dump({"global": snap, "engine": engine.metrics.snapshot(),
                   "drift": mon.summary()}, f, indent=1)
    with open(os.environ["QUICKSTART_SUMMARY"], "w") as f:
        json.dump({"eager": trace.summary(), "program": summary,
                   "tuned": tuned_summary,
                   "overlap_plan": {
                       "seconds": plan.seconds,
                       "serial_seconds": plan.serial_seconds,
                       "est_source": plan.est_source,
                       "order": list(plan.order)},
                   "backward_overlap": {
                       "bucket_order": bucket_order,
                       "summary": overlap_summary},
                   "fused_kernels": {
                       "summary": fused_summary,
                       "flow": ftrace.events[0].flow,
                       "est_source": ftrace.events[0].est_source},
                   "serving": {
                       "summary": serve_summary,
                       "steps": serve_metrics["steps"],
                       "tokens_per_s": serve_metrics["tokens_per_s"],
                       "programs_recorded":
                           serve_metrics["programs_recorded"]},
                   "checkpoint": {
                       "summary": ckpt_summary,
                       "save_lower_cache_hits": ckpt_cache_hits,
                       "restore_programs": ckpt_summary["programs"]},
                   "telemetry": {
                       "serve_step_spans": len(serve_spans),
                       "comm_child_spans": len(prog_children),
                       "lower_cache_hit_marks": sum(
                           e.get("name") == "lower-cache-hit" for e in evs),
                       "metrics": {k: snap[k] for k in sorted(snap)},
                       "stale": mon.summary()["stale"]}},
                  f, indent=1)
    print("wrote", os.environ["QUICKSTART_SUMMARY"],
          "quickstart_chrome_trace.json quickstart_metrics.json")
