"""Quickstart: the PID-Comm public API in five minutes.

Builds a 2x2x2 virtual hypercube over 8 (fake CPU) devices, runs
multi-instance collectives over cube slices (paper Fig. 5), compares the
conventional vs optimized algorithms, and consults the planner.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import Hypercube, Collectives, estimate
from repro.launch.mesh import make_mesh

# 1. define a virtual hypercube over the physical mesh (paper §IV-B):
#    dims are user-chosen; mapping follows the device hierarchy.
mesh = make_mesh((2, 4), ("data", "model"))
cube = Hypercube.build(mesh, {"x": 2, "y": 2, "z": 2})
col = Collectives(cube)
print("cube:", cube.describe())

# 2. multi-instance collective over a cube slice: the bitmap "010" selects
#    the y dimension -> four independent AllReduce instances run at once.
x = jnp.arange(8.0 * 6).reshape(2, 2, 2, 6)

ar_y = jax.jit(shard_map(
    lambda v: col.all_reduce(v, "010"), mesh=cube.mesh,
    in_specs=P("x", "y", "z", None), out_specs=P("x", None, "z", None),
    check_vma=False))
print("AllReduce along y (4 instances):", np.asarray(ar_y(x)).shape)

# 3. AlltoAll over the (x, z) plane -- 2 instances of group size 4
#    (the DLRM embedding exchange of paper Fig. 11).
aa = jax.jit(shard_map(
    lambda v: col.all_to_all(v, ("x", "z"), split_axis=3, concat_axis=3),
    mesh=cube.mesh, in_specs=P("x", "y", "z", None),
    out_specs=P("x", "y", "z", None), check_vma=False))
print("AlltoAll over (x,z):", np.asarray(aa(jnp.ones((2, 2, 2, 8)))).shape)

# 4. algorithm stages (paper Fig. 16 ablation): naive -> pr -> im -> cm
for alg in ("naive", "pr", "im", "pidcomm"):
    out = jax.jit(shard_map(
        lambda v: col.all_to_all(v, "001", split_axis=3, concat_axis=3,
                                 algorithm=alg),
        mesh=cube.mesh, in_specs=P("x", "y", "z", None),
        out_specs=P("x", "y", "z", None), check_vma=False))(
            jnp.ones((2, 2, 2, 8)))
    print(f"  all_to_all[{alg:8s}] ok, shape {np.asarray(out).shape}")

# 5. the planner estimates per-algorithm cost on the production target
#    (v5e constants) and picks the schedule -- here for a pod-crossing
#    gradient AllReduce:
prod = Hypercube.build(make_mesh((2, 2, 2), ("pod", "data", "model")),
                       {"pod": 2, "dp": 2, "tp": 2})
est = estimate(prod, "all_reduce", ("pod", "dp"), 64 * 2**20)
print(f"plan: {est.algorithm} via {est.schedule}; "
      f"ICI {est.ici_bytes/2**20:.0f} MiB, DCN {est.dcn_bytes/2**20:.0f} MiB,"
      f" est {est.seconds*1e3:.2f} ms")
