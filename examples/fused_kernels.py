"""Collective-fused kernels on the PE hypercube: ring attention and matmul
comm epilogues (``repro.kernels.collective``) dispatched as first-class
registry algorithms.

Three acts:
  1. explicit dispatch -- ``ring_attention`` rotates kv blocks around an
     8-PE ring while the flash kv-loop consumes them, checked against the
     gather-then-attend pipeline within the documented tolerance;
  2. the matmul fusions -- ``all_gather_matmul`` / ``matmul_reduce_scatter``
     are *bit-identical* to their unfused gather/scatter pipelines
     (integer-valued fp32 for the epilogue);
  3. ``algorithm="auto"`` -- a measured CommProfile that prices the fused
     ring flows cheaper flips an MLP call site from the direct collectives
     to ``ring_fused`` + ``rs_epilogue``, visible in the CommTrace.

    PYTHONPATH=src python examples/fused_kernels.py
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import planner
from repro.core.comm import CommTrace
from repro.core.hypercube import Hypercube
from repro.kernels.collective import (
    RING_ATTN_TOL, all_gather_matmul, matmul_reduce_scatter, ring_attention)
from repro.launch.mesh import make_mesh
from repro.models.layers import chunked_attention, rms_norm
from repro.tuning import CommProfile, LinkModel, topology_fingerprint

cube = Hypercube.build(make_mesh((8,), ("d",)), {"d": 8})
comm = cube.comm("d")
g = 8
print(f"hypercube {cube.describe()}")


def run(fn, in_specs, out_specs, *args):
    f = jax.jit(shard_map(fn, mesh=cube.mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False))
    return np.asarray(f(*args))


# ---- 1. ring attention: the full-sequence k/v never materializes --------
B, S_loc, H, hd = 1, 32, 4, 16
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (g, B, S_loc, H, hd), jnp.float32)
k = jax.random.normal(ks[1], (g, B, S_loc, H, hd), jnp.float32)
v = jax.random.normal(ks[2], (g, B, S_loc, H, hd), jnp.float32)
spec = P("d", None, None, None, None)

ring = run(lambda qv, kv, vv: ring_attention(comm, qv[0], kv[0], vv[0])[None],
           (spec,) * 3, spec, q, k, v)


def gather_attend(qv, kv, vv):
    kf = comm.all_gather(kv[0], axis=1)          # assemble the sequence
    vf = comm.all_gather(vv[0], axis=1)
    q_off = comm.axis_index() * S_loc
    return chunked_attention(qv[0], kf, vf, causal=True, q_offset=q_off)[None]


base = run(gather_attend, (spec,) * 3, spec, q, k, v)
err = np.abs(ring - base).max()
assert err <= RING_ATTN_TOL["float32"], err
print(f"ring attention vs gather-then-attend: max |err| {err:.2e} "
      f"(documented tol {RING_ATTN_TOL['float32']:g})")

# ---- 2. matmul comm fusions: bit-identical contracts --------------------
rng = np.random.RandomState(1)
x = rng.randn(g, 2, 4, 6).astype(np.float32)
gamma, wu = rng.randn(6).astype(np.float32), rng.randn(6, 5).astype(np.float32)
block_fn = lambda b: rms_norm(b, gamma, 1e-6) @ wu
mspec = P("d", None, None, None)
fused = run(lambda vv: all_gather_matmul(comm, vv[0], axis=1,
                                         block_fn=block_fn)[None],
            (mspec,), mspec, x)
plain = run(lambda vv: block_fn(comm.all_gather(vv[0], axis=1))[None],
            (mspec,), mspec, x)
assert (fused == plain).all()
print("ag_prologue (norm + up-proj in the gather ring): bit-identical")

h = rng.randint(-3, 4, (g, 16, 4)).astype(np.float32)
w = rng.randint(-3, 4, (4, 6)).astype(np.float32)
hspec = P("d", None, None)
fused = run(lambda vv: matmul_reduce_scatter(comm, vv[0], w, axis=0)[None],
            (hspec,), hspec, h)
plain = run(lambda vv: comm.reduce_scatter(vv[0] @ w, axis=0)[None],
            (hspec,), hspec, h)
assert (fused == plain).all()
print("rs_epilogue (lazy-tile out-proj, integer fp32): bit-identical")

# ---- 3. auto dispatch under a measured profile --------------------------
fast = LinkModel(alpha=0.0, beta=1e-12, n=8, r2=1.0)
slow = LinkModel(alpha=1.0, beta=1e-6, n=8, r2=1.0)
prof = CommProfile(topology_fingerprint(cube), models={
    "ring_fused/cm/ici": fast, "rs_epilogue/cm/ici": fast,
    "naive/naive/ici": slow, "direct/im/ici": slow, "direct/cm/ici": slow})


def mlp(vv):                                     # a tensor-parallel MLP
    hh = comm.all_gather(vv[0], axis=0)
    return comm.reduce_scatter(hh @ w, axis=0)[None]


xin = rng.randint(-3, 4, (g, 4, 4)).astype(np.float32)
with CommTrace() as tr0:
    out0 = run(mlp, (hspec,), hspec, xin)
with planner.install_profile(prof), CommTrace() as tr1:
    out1 = run(mlp, (hspec,), hspec, xin)
flows0 = [e.flow for e in tr0.events]
flows1 = [e.flow for e in tr1.events]
print(f"auto MLP flows: analytic {flows0} -> measured {flows1}")
assert flows1 == ["ring_fused", "rs_epilogue"], flows1
assert all(e.est_source == "measured" for e in tr1.events)
assert (out0 == out1).all()                      # the flip is bit-identical
print("measured profile flipped the call site to the fused ring flows; "
      "outputs bit-identical")
