"""Standalone multi-device numeric oracle for core collectives.

Run in a subprocess (so the fake device count never leaks into the main
pytest process):

    python tests/multidev_check.py

Prints ``ALL-OK`` on success; raises on any mismatch.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map

from repro.core.hypercube import Hypercube
from repro.core.collectives import ring_all_reduce, tree_all_reduce
from repro.core.comm import applicability
from repro.launch.mesh import make_mesh

APPLICABILITY = applicability()


def smap(cube, f, in_specs, out_specs):
    return jax.jit(shard_map(
        f, mesh=cube.mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False))


def check(name, got, want, atol=1e-5):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=atol,
                               err_msg=name)
    print(f"ok: {name}")


def run_single_dim(cube, dim, g):
    comm = cube.comm(dim, algorithm="pidcomm")
    rng = np.random.RandomState(0)
    n = 4 * g
    x = rng.randn(g, n).astype(np.float32)

    for alg in APPLICABILITY["all_reduce"] + ("pidcomm",):
        f = smap(cube, lambda v: comm.all_reduce(v, algorithm=alg),
                 P(dim, None), P(None, None))
        check(f"AR[{dim},{alg}]", f(x)[0], x.sum(0))

    for alg in APPLICABILITY["reduce_scatter"] + ("pidcomm",):
        f = smap(cube, lambda v: comm.reduce_scatter(v, axis=1, algorithm=alg),
                 P(dim, None), P(dim, None))
        check(f"RS[{dim},{alg}]", f(x), x.sum(0).reshape(g, -1))

    for alg in APPLICABILITY["all_gather"] + ("pidcomm",):
        f = smap(cube, lambda v: comm.all_gather(v, axis=0, algorithm=alg),
                 P(dim, None), P(None, None))
        check(f"AG[{dim},{alg}]", f(x), x)

    b = n // g
    want_aa = x.reshape(g, g, b).transpose(1, 0, 2).reshape(g, n)
    for alg in APPLICABILITY["all_to_all"] + ("pidcomm",):
        f = smap(cube, lambda v: comm.all_to_all(v, split_axis=1,
                                                 concat_axis=1, algorithm=alg),
                 P(dim, None), P(dim, None))
        check(f"AA[{dim},{alg}]", f(x), want_aa)

    # non-add reductions
    f = smap(cube, lambda v: comm.all_reduce(v, op="max"),
             P(dim, None), P(None, None))
    check(f"AR-max[{dim}]", f(x)[0], x.max(0))
    f = smap(cube, lambda v: comm.reduce_scatter(v, axis=1, op="min"),
             P(dim, None), P(dim, None))
    check(f"RS-min[{dim}]", f(x), x.min(0).reshape(g, -1))

    # single-op deferred programs execute the identical registry bodies
    import jax as _jax
    prog = cube.program(name="md-oneop")
    with prog:
        a = prog.input(_jax.ShapeDtypeStruct((1, n), jnp.float32))
        prog.output(comm.all_reduce(a))
    f = smap(cube, lambda v: prog.execute(v), P(dim, None), P(None, None))
    check(f"AR[{dim}] via one-op program", f(x)[0], x.sum(0))

    # topology comparators (payload is the per-shard row)
    f = smap(cube, lambda v: ring_all_reduce(v[0], cube, dim)[None],
             P(dim, None), P(None, None))
    check(f"ring-AR[{dim}]", f(x)[0], x.sum(0))
    f = smap(cube, lambda v: tree_all_reduce(v, cube, dim),
             P(dim, None), P(None, None))
    check(f"tree-AR[{dim}]", f(x)[0], x.sum(0))


def run_multi_instance(cube):
    # 2x2x2 cube; collective over the middle dim only -> 4 instances.
    rng = np.random.RandomState(1)
    x = rng.randn(2, 2, 2, 6).astype(np.float32)  # (a, b, c, n)

    f = smap(cube, lambda v: cube.comm("010", algorithm="pidcomm")
             .all_reduce(v),
             P("a", "b", "c", None), P("a", None, "c", None))
    check("AR[b bitmap 010] multi-instance", f(x)[:, 0], x.sum(1))

    # tuple-dim group over (a, c): 2 instances of size 4.
    f = smap(cube, lambda v: cube.comm(("a", "c"), algorithm="pidcomm")
             .all_reduce(v),
             P("a", "b", "c", None), P(None, "b", None, None))
    check("AR[(a,c)] tuple", f(x)[0, :, 0], x.sum(axis=(0, 2)))

    # all_to_all over tuple (b, c): group size 4 along stacked dims.
    g = 4
    y = rng.randn(2, g, g * 3).astype(np.float32)  # (a, bc, n)
    want = y.reshape(2, g, g, 3).transpose(0, 2, 1, 3).reshape(2, g, g * 3)
    f = smap(cube, lambda v: cube.comm(("b", "c"), algorithm="pidcomm")
             .all_to_all(v, split_axis=2, concat_axis=2),
             P("a", ("b", "c"), None), P("a", ("b", "c"), None))
    got = f(y.reshape(2, g, g * 3))
    check("AA[(b,c)] tuple", got, want)

    # hierarchical AR path: treat 'a' as DCN by building a pod-mesh cube.
    f = smap(cube, lambda v: cube.comm(("a", "b")).all_reduce(
        v, algorithm="im"),
             P("a", "b", "c", None), P(None, None, "c", None))
    check("AR[(a,b)] im", f(x)[0, 0], x.sum(axis=(0, 1)))


def run_rooted(cube):
    comm = cube.comm(("a", "b", "c"), algorithm="pidcomm")
    rng = np.random.RandomState(2)
    host = rng.randn(8, 5).astype(np.float32)
    dev = comm.scatter(host, axis=0)
    check("scatter/gather roundtrip", comm.gather(dev), host)
    rep = comm.broadcast(host)
    check("broadcast", np.asarray(rep), host)
    check("reduce", comm.reduce(dev, op="add"), host.sum(0))


def run_dcn_hierarchy():
    # pod-crossing hypercube: physical (pod=2, data=2, model=2)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cube = Hypercube.build(mesh, {"pod": 2, "dp": 2, "tp": 2})
    assert cube.dcn_dims == ("pod",), cube.dcn_dims
    comm = cube.comm(("pod", "dp"), algorithm="pidcomm")
    rng = np.random.RandomState(3)
    x = rng.randn(4, 8).astype(np.float32)  # sharded over (pod, dp)
    f = smap(cube, lambda v: comm.all_reduce(v),
             P(("pod", "dp"), None), P(None, None))
    check("hierarchical AR over DCN+ICI", f(x)[0], x.sum(0))

    hlo = jax.jit(shard_map(
        lambda v: comm.all_reduce(v), mesh=cube.mesh,
        in_specs=P(("pod", "dp"), None),
        out_specs=P(None, None), check_vma=False)).lower(
            jax.ShapeDtypeStruct((4, 8), jnp.float32)).as_text()
    assert "reduce_scatter" in hlo and "all_gather" in hlo, (
        "hierarchical AR should lower to RS + pod-AR + AG")
    print("ok: hierarchical AR lowers to RS/AR/AG schedule")


def run_compressed_ar():
    """int8 error-feedback DCN all-reduce (paper §V-C) vs exact."""
    from repro.core.compress import compressed_pod_all_reduce
    mesh = make_mesh((2, 4), ("pod", "data"))
    cube = Hypercube.build(mesh, {"pod": 2, "dp": 4})
    rng = np.random.RandomState(4)
    x = (rng.randn(8, 4096) * 0.01).astype(np.float32)

    def f(v):
        out, err = compressed_pod_all_reduce(v[0], cube, ("dp",), ("pod",))
        return out[None], err[None]

    fn = smap(cube, f, P(("pod", "dp"), None), (P(None, None), P(None, None)))
    got, err = fn(x)
    want = x.sum(0)
    rel = np.abs(np.asarray(got)[0] - want).max() / np.abs(want).max()
    assert rel < 0.02, rel                      # int8 per-pod shards ~1%
    # error feedback residual bounds the quantization error
    assert np.abs(np.asarray(err)).max() <= np.abs(want).max() / 100
    print(f"ok: compressed pod AR (rel err {rel:.4f}, feedback bounded)")


def main():
    mesh = make_mesh((2, 2, 2), ("a", "b", "c"))
    cube8 = Hypercube.build(mesh, {"a": 2, "b": 2, "c": 2})
    run_multi_instance(cube8)
    run_rooted(cube8)

    mesh1d = make_mesh((8,), ("d",))
    cube1d = Hypercube.build(mesh1d, {"d": 8})
    run_single_dim(cube1d, "d", 8)

    run_dcn_hierarchy()
    run_compressed_ar()
    print("ALL-OK")


if __name__ == "__main__":
    main()
