"""Continuous-batching engine: mixed-length arrival traces complete with the
per-step CommProgram served from the structural-fingerprint lower cache,
greedy outputs are batching-invariant, preemption round-trips through the
rooted-collective swap, and the restore-for-serving checkpoint path loads
train-cube params onto the serve topology."""
import dataclasses

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get
from repro.core.program import LOWER_STATS, clear_lower_cache
from repro.launch.mesh import make_mesh
from repro.models.params import init_params, param_specs
from repro.models.serving import make_serve_plan
from repro.models.topology import build_serve_topology, build_topology
from repro.serving import Request, ServeEngine, poisson_trace


def _setup(B, *, tp=1, S_ctx=32, **eng_kw):
    cfg = get("qwen3-1.7b").scaled_for_smoke()
    if tp > 1:
        cfg = dataclasses.replace(cfg, tp=tp)
    mesh = make_mesh((1, tp), ("data", "model"))
    topo = build_serve_topology(cfg, mesh)
    plan = make_serve_plan(cfg, topo, S_ctx=S_ctx, global_batch=B)
    params = init_params(cfg, topo, seed=1)
    return cfg, ServeEngine(cfg, topo, plan, params, **eng_kw)


def _trace(cfg, n, seed=3, temperature=0.0):
    return poisson_trace(n, rate=1.0, plen_range=(3, 8),
                         max_new_range=(3, 6), vocab=cfg.vocab_size,
                         seed=seed, temperature=temperature)


def test_mixed_trace_completes_with_cached_programs():
    """The tentpole invariant: a mixed-length Poisson trace is served to
    completion with ONE recorded CommProgram per step, and every lowering
    after the first is a structural-fingerprint cache hit."""
    cfg, eng = _setup(3)
    reqs = _trace(cfg, 6)
    clear_lower_cache()
    before = dict(LOWER_STATS)
    m = eng.run(reqs)
    hits = LOWER_STATS["cache_hits"] - before["cache_hits"]
    lowered = LOWER_STATS["lowered"] - before["lowered"]
    assert m["programs_recorded"] == m["steps"]
    assert lowered == 1, "per-step program must lower exactly once"
    assert hits >= m["steps"] - 1
    assert len(m["finished"]) == 6
    for r in m["finished"]:
        assert len(r.out_tokens) == r.max_new, r.rid
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_greedy_outputs_are_batching_invariant():
    """Each request decoded alone (B=1) must produce the same greedy tokens
    as the continuously-batched run -- slot assignment, paging and admission
    order cannot leak into the sampled stream."""
    cfg, eng = _setup(3)
    m = eng.run(_trace(cfg, 5))
    batched = {r.rid: list(r.out_tokens) for r in m["finished"]}
    _, solo = _setup(1)      # one engine, one compile; requests in sequence
    for proto in _trace(cfg, 5):
        alone = dataclasses.replace(proto, arrival=solo.step_idx)
        ms = solo.run([alone])
        assert list(ms["finished"][-1].out_tokens) == batched[proto.rid], \
            proto.rid


def test_preemption_swap_preserves_outputs():
    """Tight page pools under lazy admission force preemption; the swap
    round-trip (rooted gather out / scatter back) must not change any
    request's greedy continuation."""
    cfg, eng = _setup(3, tp=2)
    ref = {r.rid: list(r.out_tokens)
           for r in eng.run(_trace(cfg, 6))["finished"]}
    _, tight = _setup(3, tp=2, pages_per_shard=4, admission="lazy")
    m = tight.run(_trace(cfg, 6))
    assert m["preemptions"] > 0, "pools sized to force preemption"
    for r in m["finished"]:
        assert list(r.out_tokens) == ref[r.rid], r.rid


def test_temperature_sampling_and_slot_reuse():
    """Temperature sampling completes; more requests than lanes exercises
    slot reuse (every lane serves several requests)."""
    cfg, eng = _setup(2)
    m = eng.run(_trace(cfg, 6, temperature=0.8))
    assert len(m["finished"]) == 6
    for r in m["finished"]:
        assert len(r.out_tokens) == r.max_new
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_engine_input_validation():
    cfg, eng = _setup(2, S_ctx=16)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(rid=0, prompt=[], max_new=2))
    with pytest.raises(ValueError, match="S_ctx"):
        eng.submit(Request(rid=1, prompt=[1] * 10, max_new=10))


def test_make_serve_plan_rejects_unknown_cache_dtype():
    cfg = get("qwen3-1.7b").scaled_for_smoke()
    mesh = make_mesh((1, 1), ("data", "model"))
    topo = build_serve_topology(cfg, mesh)
    with pytest.raises(ValueError, match="bf16.*int8"):
        make_serve_plan(cfg, topo, S_ctx=8, global_batch=1,
                        cache_dtype="fp8")


def test_restore_for_serving(tmp_path):
    """Params saved on the train cube restore straight onto the serve
    topology (sectioned manifest, no opt-state skeleton, device_put with
    the serve-side specs) and the engine decodes with them."""
    cfg = dataclasses.replace(get("qwen3-1.7b").scaled_for_smoke(), tp=2)
    train_topo = build_topology(cfg, make_mesh((1, 2), ("data", "model")))
    params = init_params(cfg, train_topo, seed=4)
    opt = {"m": np.zeros(3, np.float32), "count": np.int32(0)}
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(7, params, opt)

    stopo = build_serve_topology(cfg, make_mesh((1, 2), ("data", "model")))
    sspecs = param_specs(cfg, stopo)
    restored = mgr.restore_params(7, params, topo=stopo, param_specs=sspecs)
    # values survive the re-shard bit-exactly
    import jax
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    plan = make_serve_plan(cfg, stopo, S_ctx=16, global_batch=2)
    eng = ServeEngine(cfg, stopo, plan, restored)
    m = eng.run([Request(rid=0, prompt=[5, 6, 7], max_new=3)])
    assert len(m["finished"][0].out_tokens) == 3
    # architecture mismatch is a clear error, not leaf-offset garbage
    with pytest.raises(ValueError, match="params leaves"):
        mgr.restore_params(7, {"w": np.zeros(2)})
