"""Paged KV cache correctness: the host page table against the pure-NumPy
oracle, the device gather/scatter view against the NumPy paged view, the
rooted-collective swap round-trip, and -- the headline guarantee -- paged
decode bit-identical (bf16) / close (int8) to the contiguous-cache
``Server.decode_shard`` across architectures, including a rolling-window
cache and a multi-shard (tp=2) kv group."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs import get
from repro.launch.mesh import make_mesh
from repro.models.params import init_params, param_specs
from repro.models.serving import (
    Server, cache_specs, init_cache, make_serve_plan)
from repro.models.topology import build_serve_topology
from repro.serving.pages import (
    PAGED_KEYS, PagedServer, PageTable, extract_slot_pages, gather_view,
    init_paged_cache, inject_slot_pages, local_block_ids, make_page_plan,
    paged_cache_specs, scatter_view)
from repro.testing.paging import PageTableOracle, paged_view


# --------------------------------------------------- table vs NumPy oracle
def test_page_table_matches_oracle():
    """Random ensure/free/admit interleavings: every observable (tables,
    free lists, return values, admission math) must match the independent
    NumPy implementation step for step."""
    rng = np.random.RandomState(0)
    page, pps, nsh, S_cache, slots = 4, 5, 2, 32, 3
    impl = _table(page, pps, nsh, S_cache, slots)
    orac = PageTableOracle(page, pps, nsh, S_cache, slots)
    for t in range(400):
        r = rng.rand()
        if r < 0.6:
            s = rng.randint(slots)
            p = rng.randint(S_cache)
            assert impl.ensure(s, p) == orac.ensure(s, p), (t, s, p)
        elif r < 0.8:
            s = rng.randint(slots)
            assert impl.free_slot(s) == orac.free_slot(s), (t, s)
        else:
            n = rng.randint(1, S_cache + 4)
            assert impl.blocks_needed(n) == orac.blocks_needed(n)
            assert impl.can_admit(n) == orac.can_admit(n)
        assert np.array_equal(impl.table, orac.table), t
        assert [list(f) for f in impl.free] == orac.free, t


def _table(page, pps, nsh, S_cache, slots):
    from repro.serving.pages import PagePlan
    S_loc = S_cache // nsh
    pplan = PagePlan(page_size=page, pages_per_shard=pps, n_shards=nsh,
                     S_loc=S_loc, blocks_per_shard=S_loc // page,
                     n_blocks=(S_loc // page) * nsh)
    return PageTable(pplan, slots)


# ------------------------------------------- gather/scatter view vs NumPy
def test_gather_view_matches_numpy_oracle():
    rng = np.random.RandomState(1)
    page, pps, nsh, S_cache, B = 4, 6, 2, 32, 3
    impl = _table(page, pps, nsh, S_cache, B)
    pplan = impl.pplan
    # allocate a random subset of blocks
    for s in range(B):
        for p in rng.choice(S_cache, size=rng.randint(2, S_cache),
                            replace=False):
            impl.ensure(s, int(p))
    table = jnp.asarray(impl.array())
    for shard in range(nsh):
        pool = rng.randn(2, pplan.pool_pages, page, 5).astype(np.float32)
        safe, valid = local_block_ids(pplan, table, shard)
        got = np.asarray(gather_view(jnp.asarray(pool), safe, valid, pplan))
        want = paged_view(pool, impl.array(), shard, page,
                          pplan.blocks_per_shard)
        assert np.array_equal(got, want), shard
        # scatter_view is gather_view's right inverse on allocated blocks
        back = np.asarray(scatter_view(jnp.asarray(pool), jnp.asarray(got),
                                       safe, pplan))
        re = np.asarray(gather_view(jnp.asarray(back), safe, valid, pplan))
        assert np.array_equal(re, want), shard


# ------------------------------------- paged decode vs contiguous decode
def _paged_step_fn(cfg, topo, plan, pplan, paged):
    ba = plan.batch_axes or None
    cspec = paged_cache_specs(cfg, topo, plan, pplan)
    return jax.jit(shard_map(
        paged.decode_shard, mesh=topo.cube.mesh,
        in_specs=(param_specs(cfg, topo), cspec, P(), P(ba), P(ba)),
        out_specs=(P(ba, topo.tp), cspec), check_vma=False))


def _contig_step_fn(cfg, topo, plan, server):
    ba = plan.batch_axes or None
    cspec = cache_specs(cfg, topo, plan)
    return jax.jit(shard_map(
        server.decode_shard, mesh=topo.cube.mesh,
        in_specs=(param_specs(cfg, topo), cspec, P(ba), P(ba)),
        out_specs=(P(ba, topo.tp), cspec), check_vma=False))


def _run_diff(arch, *, tp=1, cache_dtype="bf16", S=16, B=2):
    """Teacher-forced decode, paged vs contiguous, step by step.  Returns
    the worst absolute logits difference (0.0 = bit-identical)."""
    cfg = get(arch).scaled_for_smoke()
    if tp > 1:
        cfg = dataclasses.replace(cfg, tp=tp)
    mesh = make_mesh((1, tp), ("data", "model"))
    topo = build_serve_topology(cfg, mesh)
    plan = make_serve_plan(cfg, topo, S_ctx=S, global_batch=B,
                           cache_dtype=cache_dtype)
    pplan = make_page_plan(plan, topo, page_size=4)
    params = init_params(cfg, topo, seed=1)
    server = Server(cfg, topo, plan)
    paged = PagedServer(server, pplan)

    cache = init_cache(cfg, topo, plan)
    pcache = init_paged_cache(cfg, topo, plan, pplan)
    tbl = PageTable(pplan, B)
    step_c = _contig_step_fn(cfg, topo, plan, server)
    step_p = _paged_step_fn(cfg, topo, plan, pplan, paged)

    rng = np.random.RandomState(7)
    tokens = rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32)
    worst = 0.0
    for t in range(S):
        for b in range(B):
            assert tbl.ensure(b, t % plan.S_cache)
        pos = jnp.full((B,), t, jnp.int32)
        tok = jnp.asarray(tokens[:, t])
        ref, cache = step_c(params, cache, tok, pos)
        got, pcache = step_p(params, pcache, jnp.asarray(tbl.array()),
                             tok, pos)
        worst = max(worst, float(np.abs(np.asarray(got)
                                        - np.asarray(ref)).max()))
    return worst


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-1b",
                                  "mixtral-8x7b"])
def test_paged_decode_bit_identical_bf16(arch):
    """bf16 caches: the paged path reconstructs the exact contiguous view
    and runs the unchanged flash-decode cell, so logits must be bitwise
    equal -- incl. mixtral's rolling window-8 cache (block reuse on wrap)."""
    assert _run_diff(arch) == 0.0


def test_paged_decode_bit_identical_multishard():
    """tp=2 kv group: per-shard page pools, shard-local block ownership."""
    assert _run_diff("qwen3-1.7b", tp=2) == 0.0


def test_paged_decode_int8_close():
    """int8 KV cache: quantization happens on identical values in both
    layouts, so the paths still agree tightly."""
    assert _run_diff("qwen3-1.7b", cache_dtype="int8") < 1e-5


# ------------------------------------------------- swap-out / swap-in
def test_swap_roundtrip_restores_views():
    """extract (rooted gather) -> free -> re-allocate -> inject (rooted
    scatter + broadcast): every shard's reconstructed cache view for the
    swapped slot must come back bit-identical; other slots untouched."""
    cfg = dataclasses.replace(get("qwen3-1.7b").scaled_for_smoke(), tp=2)
    mesh = make_mesh((1, 2), ("data", "model"))
    topo = build_serve_topology(cfg, mesh)
    plan = make_serve_plan(cfg, topo, S_ctx=16, global_batch=2)
    pplan = make_page_plan(plan, topo, page_size=4)
    tbl = PageTable(pplan, 2)
    rng = np.random.RandomState(3)
    pcache = jax.tree.map(
        lambda z: jnp.asarray(rng.randn(*z.shape).astype(np.float32)
                              ).astype(z.dtype),
        init_paged_cache(cfg, topo, plan, pplan))
    for b in range(2):
        for t in range(0, 12):          # partial footprint: blocks 0..2
            tbl.ensure(b, t)

    def views(pc, slot):
        out = {}
        table = jnp.asarray(tbl.array())
        for shard in range(pplan.n_shards):
            safe, valid = local_block_ids(pplan, table, shard)
            lo = shard * pplan.pool_pages
            for pk, d in pc.items():
                for k, leaf in d.items():
                    if k in PAGED_KEYS:
                        # gather_view takes the shard-LOCAL pool slice
                        v = gather_view(leaf[:, lo:lo + pplan.pool_pages],
                                        safe, valid, pplan)
                        out[(shard, pk, k)] = np.asarray(v[:, slot])
                    else:
                        out[(shard, pk, k)] = np.asarray(leaf[:, slot])
        return out

    before0 = views(pcache, 0)
    row1 = tbl.table[1].copy()
    saved = extract_slot_pages(pcache, tbl.table[0], 0, pplan, topo, plan)
    tbl.free_slot(0)
    # scrub every page of the pools so restoration can't luck into stale data
    pcache = jax.tree.map(lambda z: jnp.zeros_like(z) - 1, pcache)
    for j in np.nonzero(saved["valid"])[0]:
        assert tbl.ensure(0, int(j) * pplan.page_size)
    pcache = inject_slot_pages(pcache, saved, tbl.table[0], 0, pplan,
                               topo, plan)
    after0 = views(pcache, 0)
    for key in before0:
        assert np.array_equal(after0[key], before0[key]), key
    # slot 1's mapping is untouched by slot 0's swap cycle
    assert np.array_equal(tbl.table[1], row1)
