"""Conformance suite: every (primitive x applicable-stage x dim-selection)
cell of paper Table II, executed on a virtual-PE hypercube and compared
against the independent NumPy oracles (repro.testing.oracles).

Contract per cell:
  * oracle agreement -- the shard_map execution reproduces the golden
    layout/values for every cube slice (multi-instance semantics, §IV-B3);
  * bit-identical stage equivalence (fp32) -- reduction payloads are
    integer-valued, so fp32 arithmetic is exact and every optimization
    stage (naive -> pr -> im -> cm) must match the oracle *bitwise*; since
    all stages equal the same oracle bitwise, they are bitwise equal to
    each other, which is the paper's "same result, fewer bytes" claim as an
    executed test rather than a comment.

Also covered: the bitmap selections "010"/"110"/"011" (multi-instance
groups), the _LADDER_MAX fall-through (im -> cm escalation), the
hierarchical §IX-A all-reduce split over a DCN-crossing group, and the
rooted host primitives' block placement.
"""
import numpy as np
import pytest

from repro.core import comm as C
from repro.core.comm import applicability, resolve_stage
from repro.testing import oracles, substrate

APPLICABILITY = applicability()

# (cube fixture name, bitmap) cells. ring8 is the flat 8-wide group; the
# 2x4 rectangle's "01" selects the 4-wide dim (2 instances); the 2x2x2
# bitmaps exercise multi-instance groups (4, 2, 2 instances) and multi-dim
# groups (the "110"/"011" tuple selections).
SELECTIONS = [
    ("cube_ring8", "1"),
    ("cube_2x4", "01"),
    ("cube_2x2x2", "010"),
    ("cube_2x2x2", "110"),
    ("cube_2x2x2", "011"),
]


def _sel(cube, bitmap):
    names = cube.dims_from_bitmap(bitmap)
    idx = tuple(cube.dim_names.index(d) for d in names)
    return names, idx


def _stages(primitive):
    return APPLICABILITY[primitive] + ("pidcomm",)


def _cells(primitive):
    return [(cn, bm, st) for cn, bm in SELECTIONS
            for st in _stages(primitive)]


# ---------------------------------------------------------------- PE <-> PE
@pytest.mark.parametrize("cube_name,bitmap,stage", _cells("all_reduce"))
def test_all_reduce_conformance(cube_name, bitmap, stage, request):
    cube = request.getfixturevalue(cube_name)
    names, idx = _sel(cube, bitmap)
    comm = cube.comm(names)
    nd = len(cube.dim_sizes)
    x = substrate.integer_payload(cube, (3, 5), seed=nd)
    got = substrate.run_per_shard(
        cube, lambda v: comm.all_reduce(v, algorithm=stage), x)
    want = oracles.all_reduce(x, nd, idx)
    np.testing.assert_array_equal(got, want)  # bit-identical, fp32 exact


@pytest.mark.parametrize("op", ["add", "min"])
@pytest.mark.parametrize("cube_name,bitmap,stage", _cells("reduce_scatter"))
def test_reduce_scatter_conformance(cube_name, bitmap, stage, op, request):
    cube = request.getfixturevalue(cube_name)
    names, idx = _sel(cube, bitmap)
    comm = cube.comm(names)
    nd = len(cube.dim_sizes)
    g = cube.group_size(names)
    x = substrate.integer_payload(cube, (2, 8 * g), seed=g)
    got = substrate.run_per_shard(
        cube,
        lambda v: comm.reduce_scatter(v, axis=nd + 1, op=op,
                                      algorithm=stage),
        x)
    want = oracles.reduce_scatter(x, nd, idx, axis=1, op=op)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("cube_name,bitmap,stage", _cells("all_gather"))
def test_all_gather_conformance(cube_name, bitmap, stage, request):
    cube = request.getfixturevalue(cube_name)
    names, idx = _sel(cube, bitmap)
    comm = cube.comm(names)
    nd = len(cube.dim_sizes)
    rng = np.random.RandomState(7)
    shape = tuple(cube.dim_sizes) + (3, 4)
    x = rng.randn(*shape).astype(np.float32)  # pure movement: any values
    got = substrate.run_per_shard(
        cube, lambda v: comm.all_gather(v, axis=nd, algorithm=stage),
        x)
    want = oracles.all_gather(x, nd, idx, axis=0)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("cube_name,bitmap,stage", _cells("all_to_all"))
def test_all_to_all_conformance(cube_name, bitmap, stage, request):
    cube = request.getfixturevalue(cube_name)
    names, idx = _sel(cube, bitmap)
    comm = cube.comm(names)
    nd = len(cube.dim_sizes)
    g = cube.group_size(names)
    rng = np.random.RandomState(g)
    shape = tuple(cube.dim_sizes) + (2, 4 * g)
    x = rng.randn(*shape).astype(np.float32)
    got = substrate.run_per_shard(
        cube,
        lambda v: comm.all_to_all(v, split_axis=nd + 1,
                                  concat_axis=nd + 1, algorithm=stage),
        x)
    want = oracles.all_to_all(x, nd, idx, split_axis=1, concat_axis=1)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("op", ["max", "min"])
@pytest.mark.parametrize("stage", _stages("all_reduce"))
def test_all_reduce_nonadd_ops(cube_ring8, op, stage):
    comm = cube_ring8.comm("d")
    x = substrate.integer_payload(cube_ring8, (6,), seed=11)
    got = substrate.run_per_shard(
        cube_ring8,
        lambda v: comm.all_reduce(v, op=op, algorithm=stage), x)
    np.testing.assert_array_equal(got, oracles.all_reduce(x, 1, (0,), op=op))


@pytest.mark.parametrize("dtype", [np.float32, np.int32, "bfloat16"])
def test_dtype_sweep(cube_ring8, dtype):
    """pidcomm all_reduce + all_to_all across payload dtypes."""
    import jax.numpy as jnp
    dt = jnp.bfloat16 if dtype == "bfloat16" else dtype
    comm = cube_ring8.comm("d", algorithm="pidcomm")
    x = substrate.integer_payload(cube_ring8, (16,), seed=3).astype(dt)
    got = substrate.run_per_shard(
        cube_ring8, lambda v: comm.all_reduce(v), x)
    np.testing.assert_array_equal(
        np.asarray(got, np.float64),
        oracles.all_reduce(np.asarray(x, np.float64), 1, (0,)))
    got = substrate.run_per_shard(
        cube_ring8,
        lambda v: comm.all_to_all(v, split_axis=1, concat_axis=1), x)
    np.testing.assert_array_equal(
        np.asarray(got, np.float64),
        oracles.all_to_all(np.asarray(x, np.float64), 1, (0,),
                           split_axis=0, concat_axis=0))


# ---------------------------------------------------- collective-fused flows
# The registered compute-fused ring flows (repro.kernels.collective) are
# conformance cells like the Table II stages: dispatched by name through the
# same Communicator entry points at every dim selection.  ring_fused /
# ag_prologue are pure movement here (no consumer / identity block_fn), so
# they must be bit-identical; rs_epilogue's ring sum is exact on the
# integer-valued payloads (the suite's stage-equivalence contract).
@pytest.mark.parametrize("alg", ["ring_fused", "ag_prologue"])
@pytest.mark.parametrize("cube_name,bitmap", SELECTIONS)
def test_fused_all_gather_conformance(cube_name, bitmap, alg, request):
    cube = request.getfixturevalue(cube_name)
    names, idx = _sel(cube, bitmap)
    comm = cube.comm(names)
    nd = len(cube.dim_sizes)
    rng = np.random.RandomState(17)
    shape = tuple(cube.dim_sizes) + (3, 4)
    x = rng.randn(*shape).astype(np.float32)
    got = substrate.run_per_shard(
        cube, lambda v: comm.all_gather(v, axis=nd, algorithm=alg), x)
    want = oracles.all_gather(x, nd, idx, axis=0)
    np.testing.assert_array_equal(got, want)  # bit-identical: pure movement


@pytest.mark.parametrize("op", ["add", "min"])
@pytest.mark.parametrize("alg", ["rs_epilogue"])
@pytest.mark.parametrize("cube_name,bitmap", SELECTIONS)
def test_fused_reduce_scatter_conformance(cube_name, bitmap, alg, op,
                                          request):
    cube = request.getfixturevalue(cube_name)
    names, idx = _sel(cube, bitmap)
    comm = cube.comm(names)
    nd = len(cube.dim_sizes)
    g = cube.group_size(names)
    x = substrate.integer_payload(cube, (2, 8 * g), seed=g)
    got = substrate.run_per_shard(
        cube,
        lambda v: comm.reduce_scatter(v, axis=nd + 1, op=op, algorithm=alg),
        x)
    want = oracles.reduce_scatter(x, nd, idx, axis=1, op=op)
    np.testing.assert_array_equal(got, want)


# -------------------------------------------------------- stage escalation
def test_ladder_max_fallthrough(cube_ring8, monkeypatch):
    """im all_to_all beyond _LADDER_MAX falls through to the fused cm
    collective and must still match the oracle."""
    monkeypatch.setattr(C, "_LADDER_MAX", 2)  # 8 > 2: forces the cm branch
    comm = cube_ring8.comm("d")
    rng = np.random.RandomState(0)
    x = rng.randn(8, 2, 16).astype(np.float32)
    got = substrate.run_per_shard(
        cube_ring8,
        lambda v: comm.all_to_all(v, split_axis=2, concat_axis=2,
                                  algorithm="im"), x)
    want = oracles.all_to_all(x, 1, (0,), split_axis=1, concat_axis=1)
    np.testing.assert_array_equal(got, want)


def test_stage_resolution_table_ii():
    """Requesting an inapplicable stage falls back to the strongest
    applicable one at or below the request; pidcomm takes the ladder top."""
    assert resolve_stage("reduce_scatter", "cm") == "im"
    assert resolve_stage("scatter", "pr") == "naive"
    assert resolve_stage("scatter", "cm") == "im"
    assert resolve_stage("broadcast", "cm") == "naive"
    for prim, stages in APPLICABILITY.items():
        assert resolve_stage(prim, "pidcomm") == stages[-1]
        for st in stages:  # applicable requests resolve to themselves
            assert resolve_stage(prim, st) == st
        with pytest.raises(ValueError):
            resolve_stage(prim, "warp")


# ------------------------------------------------------- hierarchical IX-A
def test_hierarchical_all_reduce_dcn(cube_pod):
    """Pod-crossing im all_reduce: oracle agreement plus the §IX-A schedule
    (ICI reduce-scatter + DCN all-reduce + ICI all-gather) in the HLO."""
    assert cube_pod.dcn_dims == ("pod",)
    comm = cube_pod.comm(("pod", "dp"))
    x = substrate.integer_payload(cube_pod, (5,), seed=9)
    fn = lambda v: comm.all_reduce(v, algorithm="im")
    got = substrate.run_per_shard(cube_pod, fn, x)
    want = oracles.all_reduce(x, 3, (0, 1))
    np.testing.assert_array_equal(got, want)
    hlo = substrate.lowered_text(cube_pod, fn, x)
    assert "reduce-scatter" in hlo or "reduce_scatter" in hlo
    assert "all-gather" in hlo or "all_gather" in hlo


@pytest.mark.parametrize("stage", _stages("all_reduce"))
def test_pod_crossing_stage_sweep(cube_pod, stage):
    """Every all_reduce stage agrees on the DCN-crossing "110" group."""
    names, idx = _sel(cube_pod, "110")
    comm = cube_pod.comm(names)
    x = substrate.integer_payload(cube_pod, (4,), seed=13)
    got = substrate.run_per_shard(
        cube_pod, lambda v: comm.all_reduce(v, algorithm=stage), x)
    np.testing.assert_array_equal(got, oracles.all_reduce(x, 3, idx))


# ------------------------------------------------------------- rooted four
@pytest.mark.parametrize("stage", _stages("scatter"))
@pytest.mark.parametrize("bitmap", ["111", "010"])
def test_scatter_conformance(cube_2x2x2, bitmap, stage):
    names, idx = _sel(cube_2x2x2, bitmap)
    comm = cube_2x2x2.comm(names)
    g = cube_2x2x2.group_size(names)
    rng = np.random.RandomState(5)
    host = rng.randn(4 * g, 3).astype(np.float32)
    dev = comm.scatter(host, axis=0, algorithm=stage)
    got = substrate.local_blocks(cube_2x2x2, dev)
    want = oracles.scatter(host, cube_2x2x2.dim_sizes, idx, axis=0)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("stage", _stages("gather"))
def test_gather_conformance(cube_2x2x2, stage):
    names, idx = _sel(cube_2x2x2, "111")
    comm = cube_2x2x2.comm(names, algorithm="pidcomm")
    rng = np.random.RandomState(6)
    host = rng.randn(16, 3).astype(np.float32)
    dev = comm.scatter(host, axis=0)
    back = comm.gather(dev, algorithm=stage)
    np.testing.assert_array_equal(np.asarray(back), host)
    # the oracle reassembly from per-PE blocks agrees too
    blocks = substrate.local_blocks(cube_2x2x2, dev)
    np.testing.assert_array_equal(
        oracles.gather(blocks, 3, idx, axis=0), host)


@pytest.mark.parametrize("op", ["add", "max", "min"])
@pytest.mark.parametrize("stage", _stages("reduce"))
def test_reduce_conformance(cube_2x2x2, op, stage):
    comm = cube_2x2x2.comm(("a", "b", "c"), algorithm="pidcomm")
    host = substrate.integer_payload(cube_2x2x2, (), seed=8).reshape(8, 1)
    host = np.concatenate([host] * 4, axis=1).astype(np.float32)
    dev = comm.scatter(host, axis=0)
    got = comm.reduce(dev, op=op, axis=0, algorithm=stage)
    np.testing.assert_array_equal(np.asarray(got),
                                  oracles.reduce(host, axis=0, op=op))


@pytest.mark.parametrize("stage", _stages("broadcast"))
def test_broadcast_conformance(cube_2x2x2, stage):
    comm = cube_2x2x2.comm(("a", "b", "c"))
    rng = np.random.RandomState(9)
    host = rng.randn(6, 2).astype(np.float32)
    dev = comm.broadcast(host, algorithm=stage)
    got = substrate.local_blocks(cube_2x2x2, dev)
    want = oracles.broadcast(host, cube_2x2x2.dim_sizes)
    np.testing.assert_array_equal(got, want)


# ----------------------------------------------------- coverage accounting
# Which conformance test carries each primitive's stage sweep. The meta-test
# below reads the *actual* parametrize marks off these functions, so
# deleting a test or shrinking its parametrization fails the accounting.
_CELL_TESTS = {
    "all_reduce": test_all_reduce_conformance,
    "reduce_scatter": test_reduce_scatter_conformance,
    "all_gather": test_all_gather_conformance,
    "all_to_all": test_all_to_all_conformance,
    "scatter": test_scatter_conformance,
    "gather": test_gather_conformance,
    "reduce": test_reduce_conformance,
    "broadcast": test_broadcast_conformance,
}


def _swept_params(test_fn, name):
    """Values a parametrize mark sweeps for argument ``name``."""
    vals = set()
    for mark in getattr(test_fn, "pytestmark", []):
        if mark.name != "parametrize":
            continue
        names = [n.strip() for n in mark.args[0].split(",")]
        if name not in names:
            continue
        i = names.index(name)
        for val in mark.args[1]:
            vals.add(val[i] if isinstance(val, tuple) else val)
    return vals


def _swept_stages(test_fn):
    """Stage values in a test function's parametrize marks."""
    return _swept_params(test_fn, "stage")


def test_every_table_ii_cell_is_swept():
    """Meta-test: every (primitive, applicable stage) cell of APPLICABILITY
    is attached to a collected conformance test's parametrization."""
    for prim, stages in APPLICABILITY.items():
        swept = _swept_stages(_CELL_TESTS[prim])
        assert set(stages) <= swept, (
            f"unswept stages for {prim}: {set(stages) - swept}")
        assert "pidcomm" in swept, f"pidcomm alias unswept for {prim}"


# Which conformance test carries each fused flow's sweep (same accounting
# contract as _CELL_TESTS: the meta-test reads the live parametrize marks,
# so deleting a fused sweep or dropping a selection fails here).
_FUSED_CELL_TESTS = {
    "all_gather": test_fused_all_gather_conformance,
    "reduce_scatter": test_fused_reduce_scatter_conformance,
}


def _swept_cells(test_fn):
    """(cube_name, bitmap) pairs in a test function's parametrize marks."""
    cells = set()
    for mark in getattr(test_fn, "pytestmark", []):
        if mark.name != "parametrize":
            continue
        names = [n.strip() for n in mark.args[0].split(",")]
        if names[:2] == ["cube_name", "bitmap"]:
            cells.update(tuple(v[:2]) for v in mark.args[1])
    return cells


def test_every_fused_entry_is_swept():
    """Meta-test: every registered fused flow (collective.FUSED_ENTRIES) is
    swept as a conformance cell at every dim selection."""
    from repro.kernels.collective import FUSED_ENTRIES
    for prim, alg, _bit_identical in FUSED_ENTRIES:
        fn = _FUSED_CELL_TESTS[prim]
        assert alg in _swept_params(fn, "alg"), (
            f"unswept fused flow {prim}/{alg}")
        missing = set(SELECTIONS) - _swept_cells(fn)
        assert not missing, f"fused {prim} sweep missing cells: {missing}"
