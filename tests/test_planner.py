"""Unit tests for the analytic cost model / algorithm planner.

Device-free: ``substrate.fake_cube`` builds the hypercube over a numpy
stand-in mesh, so no jax device state is touched -- the planner only reads
hypercube metadata.
"""
import pytest

from repro.core import planner
from repro.core.collectives import APPLICABILITY
from repro.testing.substrate import fake_cube


@pytest.fixture(scope="module")
def pod_cube():
    return fake_cube((2, 16, 16), ("pod", "data", "model"),
                     {"pod": 2, "dp": 16, "tp": 16})


PAYLOAD = 64 * 2 ** 20


def test_estimate_monotonicity_pod_crossing_all_reduce(pod_cube):
    """naive >= direct >= hierarchical in estimated seconds: the replicated
    intermediate is worst, the flat DCN collective pays full-payload DCN
    bytes, the §IX-A split pays only the 1/|ICI| shard over DCN."""
    naive = planner.estimate(pod_cube, "all_reduce", ("pod", "dp"), PAYLOAD,
                             algorithm="naive")
    direct = planner.estimate(pod_cube, "all_reduce", ("pod", "dp"), PAYLOAD,
                              algorithm="direct")
    hier = planner.estimate(pod_cube, "all_reduce", ("pod", "dp"), PAYLOAD)
    assert hier.algorithm == "hierarchical"
    assert direct.algorithm == "direct"
    assert naive.seconds >= direct.seconds >= hier.seconds
    # the hierarchical DCN hop carries 1/|ICI| of the payload
    assert hier.dcn_bytes < direct.dcn_bytes / 8
    assert hier.dcn_bytes < naive.dcn_bytes / 8


def test_dominant_domain_classification(pod_cube):
    """Pod-crossing direct flows are DCN-bound; intra-pod flows are
    ICI-bound; the hierarchical split moves an all-reduce from DCN-bound
    back to ICI-bound (the point of §IX-A)."""
    direct = planner.estimate(pod_cube, "all_reduce", ("pod", "dp"), PAYLOAD,
                              algorithm="direct")
    assert direct.dominant() == "dcn"
    intra = planner.estimate(pod_cube, "all_reduce", ("dp",), PAYLOAD)
    assert intra.dominant() == "ici"
    assert intra.dcn_bytes == 0.0
    hier = planner.estimate(pod_cube, "all_reduce", ("pod", "dp"), PAYLOAD)
    assert hier.dominant() == "ici"


@pytest.mark.parametrize("primitive", sorted(APPLICABILITY))
@pytest.mark.parametrize("dims", [("dp",), ("pod", "dp"), ("dp", "tp")])
def test_plan_returns_applicable_stage(pod_cube, primitive, dims):
    """plan() must map every choice onto a Table II stage that is actually
    applicable to the primitive, and never pick a slower candidate than the
    naive host flow."""
    est = planner.plan(pod_cube, primitive, dims, PAYLOAD)
    assert est.stage in APPLICABILITY[primitive]
    naive = planner.estimate(pod_cube, primitive, dims, PAYLOAD,
                             algorithm="naive")
    assert est.seconds <= naive.seconds
    assert est.ici_bytes >= 0 and est.dcn_bytes >= 0


def test_estimate_rejects_unknown_algorithm(pod_cube):
    with pytest.raises(ValueError, match="unknown planner algorithm"):
        planner.estimate(pod_cube, "all_reduce", ("dp",), PAYLOAD,
                         algorithm="warp")


def test_fused_estimate_stage_provenance(pod_cube):
    """Fused flows are not Table II rows: their estimates must report the
    registry entry's own stage label (the non-table_ii path), never the
    Table II stage the primitive would resolve to."""
    from repro.core.comm import get_algorithm, resolve_stage
    for alg, prim in sorted(planner._FUSED_PRIMITIVE.items()):
        est = planner.estimate(pod_cube, prim, ("tp",), PAYLOAD,
                               algorithm=alg)
        spec = get_algorithm(prim, alg)
        assert not spec.table_ii
        assert est.algorithm == alg
        assert est.stage == spec.stage == "cm"
        assert "fused-compute" in est.schedule[0]
        # byte model matches the direct flow: the ring moves the same blocks
        direct = planner.estimate(pod_cube, prim, ("tp",), PAYLOAD,
                                  algorithm="direct")
        assert est.ici_bytes == direct.ici_bytes
        assert est.dcn_bytes == direct.dcn_bytes
    # the witness that provenance is NOT routed through resolve_stage:
    # reduce_scatter's Table II ladder tops at "im", but rs_epilogue's
    # estimates must keep the registry's "cm" label
    assert resolve_stage("reduce_scatter", "pidcomm") == "im"
    rs = planner.estimate(pod_cube, "reduce_scatter", ("tp",), PAYLOAD,
                          algorithm="rs_epilogue")
    assert rs.stage == "cm" != resolve_stage("reduce_scatter", "pidcomm")


def test_fused_estimate_rejects_wrong_primitive(pod_cube):
    with pytest.raises(ValueError, match="flow, not"):
        planner.estimate(pod_cube, "all_reduce", ("dp",), PAYLOAD,
                         algorithm="ring_fused")
    with pytest.raises(ValueError, match="flow, not"):
        planner.estimate(pod_cube, "all_gather", ("dp",), PAYLOAD,
                         algorithm="rs_epilogue")


def test_plan_fused_candidates_require_measured_profile(pod_cube):
    """Analytically the fused candidates tie the direct flow byte-for-byte,
    and the tie-break keeps direct -- only a measured profile showing the
    fused ring actually faster may flip the pick (cf. test_tuning)."""
    est = planner.plan(pod_cube, "all_gather", ("tp",), PAYLOAD)
    assert est.algorithm not in planner._FUSED_PRIMITIVE
    est = planner.plan(pod_cube, "reduce_scatter", ("tp",), PAYLOAD)
    assert est.algorithm not in planner._FUSED_PRIMITIVE
