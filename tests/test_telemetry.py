"""Unified telemetry (repro.telemetry): exporter determinism, metric
registry semantics, drift monitoring, and the instrumentation threaded
through program lowering, the trainer and the serving engine.

* Chrome-trace and Prometheus/JSON-lines exports are byte-deterministic
  (monotonic fake clock injected) for a fixed recorded program and a fixed
  serve trace, and round-trip through their own parsers;
* every registered metric name appears in the docs table (meta-test);
* the disabled path writes nothing (default-off contract);
* the drift monitor warns exactly once per stale (flow, stage, domain)
  with the retune recipe, stays quiet in-band, and is fed by live engine
  steps; dryrun's byte-underrun check shares its band;
* the serving engine's registry is the single measurement path run()
  reports from; trainer telemetry fills step/phase histograms.
"""
import dataclasses
import json
import warnings
from pathlib import Path

import jax
import numpy as np
import pytest

from repro import telemetry
from repro.core.comm import CommEvent
from repro.telemetry import drift as drift_mod
from repro.telemetry.metrics import DECLARED
from repro.testing import substrate


class FakeClock:
    """Deterministic monotonic clock: +100us per reading."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        self.t += 1e-4
        return self.t


def _per_shard_aval(cube, payload_shape):
    shape = (1,) * len(cube.dim_sizes) + tuple(payload_shape)
    return jax.ShapeDtypeStruct(shape, jax.numpy.float32)


def _fixed_program(cube):
    """rs+ag pair: lowers to one fused all_reduce with provenance."""
    comm = cube.comm("1")
    with cube.program(name="fixed") as prog:
        a = prog.input(_per_shard_aval(cube, (2, 16)))
        b = comm.reduce_scatter(a, axis=2)
        c = comm.all_gather(b, axis=2)
        prog.output(c)
    return prog


# ------------------------------------------------------ span determinism
def test_chrome_trace_deterministic_for_fixed_program(cube_ring8):
    prog = _fixed_program(cube_ring8)
    prog._lowered_default()            # pre-lower: runs compare hit-free
    x = substrate.integer_payload(cube_ring8, (2, 16), seed=5)
    outs, tracers = [], []
    for _ in range(2):
        with telemetry.Tracer(clock=FakeClock()) as tr:
            with tr.span("step", cat="wall"):
                substrate.run_per_shard(cube_ring8,
                                        lambda v: prog.execute(v), x)
        outs.append(tr.chrome_trace_json())
        tracers.append(tr)
    assert outs[0] == outs[1], "fake-clock export must be byte-identical"

    data = json.loads(outs[0])
    assert "traceEvents" in data       # Perfetto/chrome trace_event format
    comm_evs = [e for e in data["traceEvents"] if e["cat"] == "comm"]
    assert comm_evs, "program execution must ingest CommEvents"
    for e in comm_evs:
        assert {"ph", "ts", "pid", "tid"} <= set(e)
        assert "est_source" in e["args"] and "fused_from" in e["args"]
    # rs+ag fused into one all_reduce: provenance names both recorded ops
    assert any(e["args"]["fused_from"] == [0, 1] for e in comm_evs)
    assert any(e["args"].get("program_id") == "fixed" for e in comm_evs)
    # plain-text timeline carries the same spans for CI logs
    text = tracers[0].timeline()
    assert "step [wall]" in text and "comm:" in text


def test_chrome_trace_roundtrip(cube_ring8):
    prog = _fixed_program(cube_ring8)
    prog._lowered_default()
    x = substrate.integer_payload(cube_ring8, (2, 16), seed=5)
    with telemetry.Tracer(clock=FakeClock()) as tr:
        substrate.run_per_shard(cube_ring8, lambda v: prog.execute(v), x)
    blob = tr.chrome_trace_json()
    assert json.dumps(json.loads(blob), sort_keys=True, indent=1) == blob


# --------------------------------------------------- metrics determinism
def _lower_fixed_program_twice():
    """A fresh cube + program: lower misses then hits, metrics scoped."""
    cube = substrate.build_cube("ring8")
    with telemetry.scoped_metrics() as reg:
        prog = _fixed_program(cube)
        prog.lower()
        _fixed_program(cube).lower()   # structural twin: cache hit
    return reg


def test_metrics_exports_deterministic_and_roundtrip():
    a = _lower_fixed_program_twice()
    b = _lower_fixed_program_twice()
    assert a.to_prometheus() == b.to_prometheus()
    assert a.to_jsonl() == b.to_jsonl()
    assert a.snapshot() == b.snapshot()
    # the scoped registry saw the lowering instrumentation
    assert a.value("program.lowered") == 1
    assert a.value("program.lower_cache_hits") == 1
    assert a.value("program.fused_ops") == 1
    assert a.value("planner.plan_program_calls") == 1
    # JSON-lines round-trip: parse and re-serialize byte-identically
    lines = a.to_jsonl().splitlines()
    rt = "\n".join(json.dumps(json.loads(ln), sort_keys=True)
                   for ln in lines) + "\n"
    assert rt == a.to_jsonl()
    # Prometheus text: every declared-name line is prefixed and typed
    prom = a.to_prometheus()
    assert "# TYPE repro_program_lowered counter" in prom
    assert "repro_program_lowered 1" in prom


def test_metrics_disabled_path_writes_nothing():
    assert not telemetry.metrics_enabled()
    telemetry.inc("train.steps")
    telemetry.observe("train.step_seconds", 0.5)
    telemetry.set_gauge("serve.tokens_per_s", 1.0)
    assert telemetry.REGISTRY.snapshot() == {}
    cube = substrate.build_cube("ring8")
    _fixed_program(cube).lower()       # instrumented sites stay silent
    assert telemetry.REGISTRY.snapshot() == {}


def test_declared_kind_is_enforced():
    reg = telemetry.MetricsRegistry()
    with pytest.raises(TypeError, match="declared as counter"):
        reg.gauge("train.steps")
    reg.counter("train.steps").inc()
    with pytest.raises(TypeError, match="is a counter"):
        reg.histogram("train.steps")


def test_histogram_quantile_matches_sorted_index_formula():
    reg = telemetry.MetricsRegistry()
    h = reg.histogram("serve.token_seconds")
    vals = [0.003, 0.001, 0.009, 0.002, 0.004]
    for v in vals:
        h.observe(v)
    lat = np.sort(np.asarray(vals))
    n = len(vals)
    for q in (0.5, 0.9, 0.99, 1.0):
        want = float(lat[min(n - 1, int(np.ceil(q * n)) - 1)])
        assert h.quantile(q) == want


# ------------------------------------------------------------- meta-test
def test_every_declared_metric_is_documented():
    doc = (Path(__file__).parent.parent / "docs" /
           "TELEMETRY.md").read_text()
    missing = [name for name in DECLARED if f"`{name}`" not in doc]
    assert not missing, f"docs/TELEMETRY.md missing metrics: {missing}"


# ----------------------------------------------------------------- drift
def _event(**kw):
    base = dict(primitive="all_reduce", bitmap="1", dims=("a",),
                algorithm="auto", flow="ring_fused", stage="cm",
                group_size=8, num_instances=1, payload_bytes=1024.0,
                ici_bytes=1024.0, dcn_bytes=0.0, seconds=1e-4,
                est_source="measured")
    base.update(kw)
    return CommEvent(**base)


def test_drift_monitor_warns_exactly_once_per_key():
    mon = telemetry.DriftMonitor(min_samples=2, require_measured=False)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        for _ in range(6):                       # meas 100x over estimate
            mon.observe("ring_fused", "cm", "ici", 1e-2, 1e-4)
    ws = [x for x in w
          if issubclass(x.category, telemetry.ProfileStalenessWarning)]
    assert len(ws) == 1, "one structured warning per stale key"
    msg = str(ws[0].message)
    assert "ring_fused" in msg and "cm" in msg and "ici" in msg
    assert "Tuner" in msg or "regenerate" in msg     # retune recipe
    warning = ws[0].message
    assert (warning.flow, warning.stage, warning.domain) == \
        ("ring_fused", "cm", "ici")
    assert mon.stale() == [("ring_fused", "cm", "ici")]
    assert mon.summary()["stale"] == ["ring_fused/cm/ici"]


def test_drift_monitor_quiet_in_band():
    mon = telemetry.DriftMonitor(min_samples=2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        for r in (0.8, 1.0, 1.2, 1.5, 0.6):
            mon.observe("ring_fused", "cm", "ici", r * 1e-4, 1e-4)
    assert not [x for x in w if issubclass(
        x.category, telemetry.ProfileStalenessWarning)]
    assert mon.stale() == []


def test_drift_monitor_skips_analytic_estimates_by_default():
    mon = telemetry.DriftMonitor(min_samples=1)
    mon.observe_event(_event(est_source="analytic"), measured_s=1.0)
    assert mon.residuals == {}
    mon.observe_event(_event(est_source="measured"), measured_s=1.2e-4)
    assert list(mon.residuals) == [("ring_fused", "cm", "ici")]


def test_dryrun_underrun_check_shares_drift_band():
    lo, hi = drift_mod.DEFAULT_BAND
    assert drift_mod.underrun(lo - 1e-9) and not drift_mod.underrun(lo)
    assert drift_mod.outside_band(hi + 1e-9)
    assert not drift_mod.outside_band(1.0)


# -------------------------------------------------------- serving engine
def _setup_engine(B, *, tp=1, **eng_kw):
    import dataclasses as _dc
    from repro.configs import get
    from repro.launch.mesh import make_mesh
    from repro.models.params import init_params
    from repro.models.serving import make_serve_plan
    from repro.models.topology import build_serve_topology
    from repro.serving import ServeEngine
    substrate.ensure_virtual_devices(8)
    cfg = get("qwen3-1.7b").scaled_for_smoke()
    if tp > 1:
        cfg = _dc.replace(cfg, tp=tp)
    mesh = make_mesh((1, tp), ("data", "model"))
    topo = build_serve_topology(cfg, mesh)
    plan = make_serve_plan(cfg, topo, S_ctx=32, global_batch=B)
    params = init_params(cfg, topo, seed=1)
    return cfg, ServeEngine(cfg, topo, plan, params, **eng_kw)


def _serve_trace(cfg, n, seed=3):
    from repro.serving import poisson_trace
    return poisson_trace(n, rate=1.0, plen_range=(3, 6),
                         max_new_range=(2, 4), vocab=cfg.vocab_size,
                         seed=seed)


def test_engine_registry_is_the_single_measurement_path():
    cfg, eng = _setup_engine(2)
    m = eng.run(_serve_trace(cfg, 3))
    reg = eng.metrics
    assert reg.value("serve.steps") == m["steps"]
    assert reg.value("serve.generated_tokens") == m["generated_tokens"]
    assert m["p50_token_s"] == reg.quantile("serve.token_seconds", 0.50)
    assert m["p99_token_s"] == reg.quantile("serve.token_seconds", 0.99)
    assert m["tokens_per_s"] == reg.value("serve.tokens_per_s")
    assert reg.value("serve.admitted") == 3
    assert reg.value("serve.evicted") == len(m["finished"]) == 3
    assert reg.value("serve.preempted") == m["preemptions"] == 0
    assert 0.0 <= reg.value("serve.page_occupancy") <= 1.0
    # per-step program: one miss then hits -> ratio approaches 1
    assert reg.value("serve.lower_cache_hit_ratio") == pytest.approx(
        (m["steps"] - 1) / m["steps"])
    assert "repro_serve_steps" in reg.to_prometheus()
    eng.reset_metrics()
    assert reg.snapshot() == {} and eng.programs_recorded == 0


def test_engine_serve_trace_chrome_deterministic():
    blobs = []
    for _ in range(2):
        cfg, eng = _setup_engine(2)      # fresh cube: fresh lower cache
        with telemetry.Tracer(clock=FakeClock()) as tr:
            eng.run(_serve_trace(cfg, 2))
        blobs.append(tr.chrome_trace_json())
    assert blobs[0] == blobs[1]
    evs = json.loads(blobs[0])["traceEvents"]
    steps = [e for e in evs if e["name"] == "serve-step"]
    assert steps, "each engine step must open a serve-step span"
    comm = [e for e in evs if e["cat"] == "comm"]
    assert comm and all("est_source" in e["args"] for e in comm)
    assert any(e["args"].get("program_id") == "serve-step" for e in comm)
    # lower-cache hits annotate the timeline from step 2 on
    hits = [e for e in evs if e["name"] == "lower-cache-hit"]
    assert hits and all(e["ph"] == "i" for e in hits)


def test_engine_feeds_installed_drift_monitor():
    # tp=2: group-size-1 plans estimate zero seconds and are (correctly)
    # skipped, so the drift path needs a real tensor-parallel step program
    cfg, eng = _setup_engine(2, tp=2)
    mon = telemetry.DriftMonitor(band=(1e-12, 1e12), min_samples=1,
                                 require_measured=False)
    with telemetry.install_monitor(mon):
        m = eng.run(_serve_trace(cfg, 2))
    assert mon.residuals, "live steps must feed wall/plan residuals"
    assert sum(len(dq) for dq in mon.residuals.values()) >= m["steps"]
    assert mon.stale() == []             # band is deliberately huge


# ---------------------------------------------------------------- trainer
def _setup_train(**tc_kw):
    from repro.configs import get
    from repro.launch.mesh import make_mesh
    from repro.models.topology import build_topology
    from repro.optim import adamw
    from repro.models.params import init_params
    from repro.runtime.trainer import TrainConfig
    cfg = get("qwen3-1.7b").scaled_for_smoke()
    mesh = make_mesh((1, 1), ("data", "model"))
    topo = build_topology(cfg, mesh)
    tc = TrainConfig(warmup=2, lr=1e-3, **tc_kw)
    params = init_params(cfg, topo, seed=0)
    opt = adamw.init_state(params, tc.adamw)
    return cfg, topo, tc, params, opt


def _batches(cfg, n):
    import jax.numpy as jnp
    from repro.data.pipeline import DataConfig, TokenStream
    dc = DataConfig(seq_len=32, global_batch=2, vocab_size=cfg.vocab_size)
    stream = TokenStream(cfg, dc)
    for s in range(n):
        yield {k: jnp.asarray(v)
               for k, v in stream.global_batch_at(s).items()}


def test_trainer_step_metrics_and_span():
    from repro.runtime.trainer import Trainer
    cfg, topo, tc, params, opt = _setup_train()
    tr = Trainer(cfg, topo, tc)
    telemetry.enable_metrics()
    try:
        with telemetry.Tracer(clock=FakeClock()) as tracer:
            _, _, hist = tr.run(params, opt, _batches(cfg, 2),
                                log_every=0, log=lambda *_: None)
    finally:
        telemetry.disable_metrics()
    assert telemetry.REGISTRY.value("train.steps") == 2
    assert telemetry.REGISTRY.get("train.step_seconds").count == 2
    evs = json.loads(tracer.chrome_trace_json())["traceEvents"]
    assert sum(e["name"] == "train-step" for e in evs) == 2
    assert np.isfinite(hist[-1]["loss"])


def test_trainer_telemetry_split_phases():
    from repro.runtime.trainer import Trainer
    cfg, topo, tc, params, opt = _setup_train(telemetry_split=True)
    tr = Trainer(cfg, topo, tc)
    telemetry.enable_metrics()
    try:
        _, _, hist = tr.run(params, opt, _batches(cfg, 2),
                            log_every=0, log=lambda *_: None)
    finally:
        telemetry.disable_metrics()
    reg = telemetry.REGISTRY
    for name in ("train.fwd_seconds", "train.fwd_bwd_seconds",
                 "train.sync_seconds", "train.opt_seconds"):
        assert reg.get(name).count == 2, name
    # phase metrics still produce a full history row
    assert np.isfinite(hist[-1]["loss"])
    assert np.isfinite(hist[-1]["grad_norm"])


def test_split_step_rejects_compressed_path():
    from repro.runtime.trainer import make_split_train_step
    cfg, topo, tc, *_ = _setup_train()
    tc = dataclasses.replace(tc, compress_pod_grads=True)
    with pytest.raises(ValueError, match="plain gradient-sync"):
        make_split_train_step(cfg, topo, tc)
