"""Decode-path integration test: teacher-forced flash-decode must reproduce
the training forward's logits position by position -- exercises KV caches,
rolling windows, SSM states, conv tails and token-shift carries for every
mixer family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

import repro.models.blocks as blocks_mod
import repro.models.lm as lm_mod
import repro.models.params as params_mod

# fp32 compute: the comparison should be exact-ish, not bf16-fuzzy
params_mod.COMPUTE_DTYPE = jnp.float32
blocks_mod.COMPUTE_DTYPE = jnp.float32
lm_mod.COMPUTE_DTYPE = jnp.float32

from repro.configs import get
from repro.launch.mesh import make_mesh
from repro.models.lm import Model
from repro.models.params import init_params, param_specs, vocab_padded
from repro.models.serving import (
    Server, cache_specs, init_cache, make_serve_plan)
from repro.models.topology import build_serve_topology, build_topology
from repro.runtime.trainer import input_batch_specs

ARCHS = ["qwen3-1.7b", "gemma3-1b", "mixtral-8x7b", "rwkv6-7b",
         "jamba-1.5-large-398b"]


def _forward_logits(cfg, topo, params, batch):
    model = Model(cfg, topo)
    fwd = jax.jit(shard_map(
        model.forward_logits, mesh=topo.cube.mesh,
        in_specs=(param_specs(cfg, topo), input_batch_specs(cfg, topo)),
        out_specs=P(topo.dp, None, topo.tp), check_vma=False))
    return np.asarray(fwd(params, batch))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = get(arch).scaled_for_smoke()
    if cfg.window > 0:
        cfg = dataclasses.replace(cfg, window=8)   # exercise rolling cache
    B, S = 2, 24
    rng = np.random.RandomState(5)
    tokens = rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens),
             "labels": jnp.asarray(tokens)}

    mesh = make_mesh((1, 1), ("data", "model"))
    topo = build_topology(cfg, mesh)
    params = init_params(cfg, topo, seed=1)
    ref = _forward_logits(cfg, topo, params, batch)

    stopo = build_serve_topology(cfg, mesh)
    plan = make_serve_plan(cfg, stopo, S_ctx=S, global_batch=B)
    server = Server(cfg, stopo, plan)
    cache = init_cache(cfg, stopo, plan)
    ba = plan.batch_axes or None
    step = jax.jit(shard_map(
        server.decode_shard, mesh=stopo.cube.mesh,
        in_specs=(param_specs(cfg, stopo), cache_specs(cfg, stopo, plan),
                  P(ba), P(ba)),
        out_specs=(P(ba, stopo.tp), cache_specs(cfg, stopo, plan)),
        check_vma=False))

    worst = 0.0
    for t in range(S):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = step(params, cache, jnp.asarray(tokens[:, t]), pos)
        d = np.abs(np.asarray(logits) - ref[:, t]).max()
        worst = max(worst, float(d))
    scale = np.abs(ref).max()
    # tolerance: chunked-scan vs step-by-step fp32 accumulation differs
    # (mamba's exp(dt*A) recurrences are the most sensitive)
    assert worst < 5e-3 * max(scale, 1.0), (arch, worst, scale)


def test_int8_kv_cache_decode_close():
    """8-bit cross-domain-modulated KV cache (paper §V-C applied to
    serving): decode logits track the bf16-cache reference closely."""
    cfg = get("qwen3-1.7b").scaled_for_smoke()
    B, S = 2, 16
    rng = np.random.RandomState(9)
    tokens = rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32)
    mesh = make_mesh((1, 1), ("data", "model"))
    topo = build_topology(cfg, mesh)
    params = init_params(cfg, topo, seed=1)
    ref = _forward_logits(cfg, topo, params,
                          {"tokens": jnp.asarray(tokens),
                           "labels": jnp.asarray(tokens)})
    stopo = build_serve_topology(cfg, mesh)
    plan = make_serve_plan(cfg, stopo, S_ctx=S, global_batch=B,
                           cache_dtype="int8")
    server = Server(cfg, stopo, plan)
    cache = init_cache(cfg, stopo, plan)
    ba = plan.batch_axes or None
    step = jax.jit(shard_map(
        server.decode_shard, mesh=stopo.cube.mesh,
        in_specs=(param_specs(cfg, stopo), cache_specs(cfg, stopo, plan),
                  P(ba), P(ba)),
        out_specs=(P(ba, stopo.tp), cache_specs(cfg, stopo, plan)),
        check_vma=False))
    worst = 0.0
    for t in range(S):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = step(params, cache, jnp.asarray(tokens[:, t]), pos)
        worst = max(worst, float(np.abs(np.asarray(logits) - ref[:, t]).max()))
    scale = max(float(np.abs(ref).max()), 1.0)
    assert worst < 0.05 * scale, (worst, scale)   # ~1% quantization noise
