"""Tuning-subsystem tests: profile persistence, fitted pricing, and the
measured-dispatch contract.

Persistence (device-free, fake cubes):
  * JSON round-trip determinism -- save -> load -> save is byte-identical;
  * schema-version bump rejection with a retune recipe;
  * topology-fingerprint mismatch rejection with a retune recipe;
  * partial-sweep merge (same fingerprint unions + refits, different
    fingerprint raises).

Measured dispatch (live 8-device substrate):
  * a synthetic profile that inverts the analytic ranking flips
    ``planner.plan()``'s pick AND a recorded ``CommProgram``'s plan for a
    conformance cell, execution stays bit-identical to the NumPy oracle,
    and every resulting CommEvent carries ``est_source="measured"``;
  * a real (tiny) ``Tuner.tune`` sweep prices subsequent plans as
    measured and survives a cache round-trip;
  * ``Tuner.select`` falls back to exhaustive measurement on
    low-confidence fits and persists what it measured.
"""
import json
import os

import numpy as np
import pytest

from repro.core import planner
from repro.core.comm import CommTrace
from repro.testing import oracles, substrate
from repro.testing.substrate import fake_cube
from repro.tuning import (
    CommProfile, LinkModel, MeasuredSample, ProfileMismatchError, Tuner,
    fit_models, topology_fingerprint)
from repro.tuning import profile as profile_mod


def _sample(**kw):
    base = dict(primitive="all_reduce", algorithm="direct", stage="im",
                bitmap="1", nbytes=1 << 20, ici_bytes=2.0 * (1 << 20) * 7 / 8,
                dcn_bytes=0.0, seconds=1e-3)
    base.update(kw)
    return MeasuredSample(**base)


@pytest.fixture()
def ring_fake():
    return fake_cube((8,), ("d",), {"d": 8})


@pytest.fixture()
def rect_fake():
    return fake_cube((2, 4), ("data", "model"), {"r": 2, "c": 4})


# ------------------------------------------------------------- persistence
def test_roundtrip_deterministic(tmp_path, ring_fake):
    samples = [_sample(nbytes=n, ici_bytes=n * 7 / 8, seconds=n * 1e-9 + 5e-5)
               for n in (1 << 16, 1 << 18, 1 << 20)]
    prof = CommProfile(topology_fingerprint(ring_fake), samples)
    p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
    prof.save(p1)
    CommProfile.load(p1).save(p2)
    assert p1.read_bytes() == p2.read_bytes()
    re = CommProfile.load(p1, cube=ring_fake)       # fingerprint-checked
    assert re.models == prof.models
    assert re.samples == prof.samples


def test_schema_version_bump_rejected(tmp_path, ring_fake):
    prof = CommProfile(topology_fingerprint(ring_fake), [_sample()])
    path = prof.save(tmp_path / "prof.json")
    data = json.loads(open(path).read())
    data["schema_version"] = profile_mod.SCHEMA_VERSION + 1
    with open(path, "w") as f:
        json.dump(data, f)
    with pytest.raises(ProfileMismatchError, match="schema"):
        CommProfile.load(path)
    with pytest.raises(ProfileMismatchError, match="tune"):
        CommProfile.load(path)      # the error carries a retune recipe


def test_fingerprint_mismatch_rejected(tmp_path, ring_fake, rect_fake):
    prof = CommProfile(topology_fingerprint(ring_fake), [_sample()])
    path = prof.save(tmp_path / "prof.json")
    with pytest.raises(ProfileMismatchError, match="fingerprint mismatch"):
        CommProfile.load(path, cube=rect_fake)
    with pytest.raises(ProfileMismatchError, match="tune"):
        CommProfile.load(path, cube=rect_fake)   # recipe present
    # the mismatch names what differs
    with pytest.raises(ProfileMismatchError, match="dims"):
        prof.check_fingerprint(rect_fake)


def test_merge_partial_sweeps(ring_fake, rect_fake):
    fp = topology_fingerprint(ring_fake)
    a = CommProfile(fp, [_sample(algorithm="naive", stage="naive")])
    b = CommProfile(fp, [_sample(algorithm="direct", stage="im"),
                         _sample(algorithm="naive", stage="naive")])  # dup
    merged = a.merge(b)
    assert len(merged.samples) == 2                # exact dup dropped
    assert "naive/naive/ici" in merged.models
    assert "direct/im/ici" in merged.models
    with pytest.raises(ProfileMismatchError, match="different topologies"):
        a.merge(CommProfile(topology_fingerprint(rect_fake), []))


def test_fit_recovers_alpha_beta():
    alpha, beta = 2e-4, 3e-9
    samples = [_sample(nbytes=n, ici_bytes=float(n),
                       seconds=alpha + beta * n)
               for n in (1 << 14, 1 << 16, 1 << 18, 1 << 20)]
    models = fit_models(samples)
    m = models["direct/im/ici"]
    assert m.alpha == pytest.approx(alpha, rel=1e-3)
    assert m.beta == pytest.approx(beta, rel=1e-3)
    assert m.r2 > 0.99 and m.n == 4
    prof = CommProfile({"any": "fp"}, samples)
    t = prof.seconds_for("direct", "im", 1 << 19, 0.0)
    assert t == pytest.approx(alpha + beta * (1 << 19), rel=1e-3)
    assert prof.is_confident("direct", "im")
    # uncovered flows price as None -> planner falls back to analytic
    assert prof.seconds_for("hierarchical", "im", 1.0, 0.0) is None
    assert prof.confidence("hierarchical", "im") == 0.0


def test_fit_dcn_domain_split():
    """A flow moving both ICI and DCN bytes gets both domain models, and
    dcn pricing needs the dcn model."""
    # ici and dcn columns must not be collinear or the joint fit is
    # underdetermined (lstsq would split the slope arbitrarily)
    rng = [(1 << 16, 1 << 13), (1 << 18, 1 << 13), (1 << 18, 1 << 16),
           (1 << 20, 1 << 14)]
    samples = [_sample(algorithm="hierarchical", stage="im",
                       ici_bytes=float(i), dcn_bytes=float(d),
                       seconds=1e-5 + 2e-9 * i + 4e-8 * d)
               for i, d in rng]
    models = fit_models(samples)
    assert set(models) == {"hierarchical/im/ici", "hierarchical/im/dcn"}
    prof = CommProfile({"fp": 1}, samples)
    t = prof.seconds_for("hierarchical", "im", 1e6, 1e5)
    assert t == pytest.approx(1e-5 + 2e-9 * 1e6 + 4e-8 * 1e5, rel=0.05)


# ----------------------------------------------- measured pricing / plan()
def _inverting_profile(cube):
    """Synthetic measured profile that makes the naive host flow the
    cheapest candidate -- the opposite of the analytic ranking."""
    return CommProfile(topology_fingerprint(cube), models={
        "naive/naive/ici": LinkModel(alpha=0.0, beta=1e-12, n=8, r2=1.0),
        "direct/im/ici": LinkModel(alpha=1.0, beta=1e-6, n=8, r2=1.0),
        "direct/cm/ici": LinkModel(alpha=1.0, beta=1e-6, n=8, r2=1.0),
    })


def test_synthetic_profile_inverts_plan(ring_fake):
    payload = 512 * 1024
    analytic = planner.plan(ring_fake, "all_to_all", ("d",), payload)
    assert analytic.algorithm == "direct"
    assert analytic.est_source == "analytic"
    prof = _inverting_profile(ring_fake)
    measured = planner.plan(ring_fake, "all_to_all", ("d",), payload,
                            profile=prof)
    assert measured.algorithm == "naive"            # the pick flipped
    assert measured.est_source == "measured"
    # the context form prices identically to the explicit kwarg
    with planner.install_profile(prof):
        assert planner.plan(ring_fake, "all_to_all", ("d",),
                            payload).algorithm == "naive"
    assert planner.active_profile() is None


def test_measured_auto_dispatch_bit_identical(cube_ring8):
    """Acceptance: with the inverting profile installed, algorithm="auto"
    executes a different flow (naive instead of the direct cm ladder) on a
    conformance cell, stays bit-identical to the oracle, and every emitted
    CommEvent is measured-priced."""
    comm = cube_ring8.comm("d")
    rng = np.random.RandomState(7)
    x = rng.randn(8, 2, 32).astype(np.float32)

    with CommTrace() as tr0:
        got0 = substrate.run_per_shard(
            cube_ring8,
            lambda v: comm.all_to_all(v, split_axis=2, concat_axis=2), x)
    assert tr0.events[0].flow == "cm"               # analytic auto pick
    assert tr0.events[0].est_source == "analytic"

    prof = _inverting_profile(cube_ring8)
    with planner.install_profile(prof), CommTrace() as tr:
        got = substrate.run_per_shard(
            cube_ring8,
            lambda v: comm.all_to_all(v, split_axis=2, concat_axis=2), x)
    assert [e.flow for e in tr.events] == ["naive"]  # the pick changed
    assert all(e.est_source == "measured" for e in tr.events)
    want = oracles.all_to_all(x, 1, (0,), split_axis=1, concat_axis=1)
    np.testing.assert_array_equal(got, want)         # bit-identical
    np.testing.assert_array_equal(got0, want)
    s = tr.summary()
    assert s["est_sources"] == {"measured": 1}
    assert s["by_flow"]["all_to_all/naive"]["est_source"] == "measured"


def _fused_favoring_profile(cube):
    """Synthetic measured profile that prices the compute-fused ring flows
    (repro.kernels.collective) below every unfused candidate."""
    fast = LinkModel(alpha=0.0, beta=1e-12, n=8, r2=1.0)
    slow = LinkModel(alpha=1.0, beta=1e-6, n=8, r2=1.0)
    return CommProfile(topology_fingerprint(cube), models={
        "ring_fused/cm/ici": fast,
        "rs_epilogue/cm/ici": fast,
        "naive/naive/ici": slow,
        "direct/im/ici": slow,
        "direct/cm/ici": slow,
    })


def test_measured_auto_flips_mlp_call_site_to_fused(cube_ring8):
    """Acceptance (collective-fused kernels): at a tensor-parallel MLP call
    site -- sequence all_gather, up/down matmuls, reduce_scatter of the
    partial sums -- a measured profile favoring the fused ring flows flips
    ``algorithm="auto"`` from the unfused direct collectives to
    ``ring_fused`` + ``rs_epilogue``, execution stays bit-identical on
    integer payloads (the documented epilogue/prologue contract), and every
    event is measured-priced."""
    comm = cube_ring8.comm("d")
    x = substrate.integer_payload(cube_ring8, (4, 6), seed=21)  # (8, 4, 6)
    w = np.random.RandomState(21).randint(-3, 4, (6, 6)).astype(np.float32)

    def mlp(v):                       # v: (1, 4, 6) shard of the sequence
        h = comm.all_gather(v, axis=1)            # (1, 32, 6) assembled
        return comm.reduce_scatter(h @ w, axis=1)  # partial sums folded

    with CommTrace() as tr0:
        got0 = substrate.run_per_shard(cube_ring8, mlp, x)
    assert [e.flow for e in tr0.events] == ["cm", "im"]  # unfused analytic
    assert all(e.est_source == "analytic" for e in tr0.events)

    prof = _fused_favoring_profile(cube_ring8)
    with planner.install_profile(prof), CommTrace() as tr:
        got = substrate.run_per_shard(cube_ring8, mlp, x)
    assert [e.flow for e in tr.events] == ["ring_fused", "rs_epilogue"]
    assert all(e.est_source == "measured" for e in tr.events)
    np.testing.assert_array_equal(got, got0)       # bit-identical flip
    want = oracles.reduce_scatter(
        oracles.all_gather(x, 1, (0,), axis=0) @ w, 1, (0,), axis=0)
    np.testing.assert_array_equal(got, want)
    s = tr.summary()
    assert s["est_sources"] == {"measured": 2}
    assert s["by_flow"]["all_gather/ring_fused"]["est_source"] == "measured"
    assert s["by_flow"]["reduce_scatter/rs_epilogue"]["est_source"] \
        == "measured"


def test_measured_program_plan_and_execute(cube_ring8):
    """The deferred path: plan_program under the inverting profile picks
    naive for the recorded op, execution emits measured events, result is
    bit-identical to the oracle."""
    import jax
    import jax.numpy as jnp
    comm = cube_ring8.comm("d")
    rng = np.random.RandomState(9)
    x = rng.randn(8, 2, 32).astype(np.float32)
    prof = _inverting_profile(cube_ring8)

    prog = cube_ring8.program(name="tuned-aa")
    with prog:
        v = prog.input(jax.ShapeDtypeStruct((1, 2, 32), jnp.float32))
        prog.output(comm.all_to_all(v, split_axis=2, concat_axis=2))

    analytic = prog.lower()
    a_est = next(iter(analytic.plan.estimates.values()))
    assert a_est.algorithm == "direct" and a_est.est_source == "analytic"

    with planner.install_profile(prof):
        lowered = prog.lower()
        m_est = next(iter(lowered.plan.estimates.values()))
        assert m_est.algorithm == "naive"           # joint plan flipped too
        assert m_est.est_source == "measured"
        with CommTrace() as tr:
            got = substrate.run_per_shard(
                cube_ring8, lambda v: lowered.execute(v), x)
    assert [e.flow for e in tr.events] == ["naive"]
    assert all(e.est_source == "measured" for e in tr.events)
    assert tr.events[0].program_id == "tuned-aa"
    want = oracles.all_to_all(x, 1, (0,), split_axis=1, concat_axis=1)
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------ live tuning
def test_tune_cache_and_measured_plan(tmp_path, cube_ring8):
    """A real (tiny) sweep: tune -> persist -> reload under the same
    fingerprint -> auto pricing is measured for covered flows."""
    tuner = Tuner(cache_dir=tmp_path)
    prof = tuner.tune(cube_ring8, sizes=(8192, 32768),
                      primitives=("all_reduce", "all_gather"),
                      reps=2, warmup=1)
    assert os.path.exists(tuner.profile_path(cube_ring8))
    assert any(k.startswith("naive/naive/") for k in prof.models)
    # all sampled seconds are real wall times
    assert all(s.seconds > 0 for s in prof.samples)

    fresh = Tuner(cache_dir=tmp_path)               # new process analogue
    reloaded = fresh.load(cube_ring8)
    est = planner.plan(cube_ring8, "all_reduce", ("d",), 16384,
                       profile=reloaded)
    assert est.est_source == "measured"

    # tuning again merges rather than discarding the first sweep
    n0 = len(reloaded.samples)
    prof2 = fresh.tune(cube_ring8, sizes=(16384,),
                       primitives=("all_reduce",), reps=2, warmup=1)
    assert len(prof2.samples) > 0 and len(prof2.samples) >= n0


def test_select_exhaustive_fallback(tmp_path, cube_ring8):
    """An under-sampled profile (n < MIN_SAMPLES) is low-confidence, so
    select() measures the candidates at the requested size and persists
    the new samples."""
    tuner = Tuner(cache_dir=tmp_path)
    # seed a deliberately under-sampled profile (one sample per flow)
    seed = CommProfile(topology_fingerprint(cube_ring8), [
        _sample(algorithm="naive", stage="naive", seconds=1e-3),
        _sample(algorithm="direct", stage="im", seconds=2e-3),
    ])
    seed.save(tuner.profile_path(cube_ring8))
    comm = cube_ring8.comm("d")
    alg = tuner.select("all_reduce", 16384, comm, reps=2, warmup=1)
    assert alg in ("naive", "pidcomm", "hierarchical")
    grown = CommProfile.load(tuner.profile_path(cube_ring8))
    assert len(grown.samples) > 2                   # measurements persisted


def test_select_trusts_confident_profile(tmp_path, cube_ring8):
    """With confident models covering every candidate, select() prices
    without measuring (no new samples appear)."""
    tuner = Tuner(cache_dir=tmp_path)
    prof = _inverting_profile(cube_ring8)
    prof.save(tuner.profile_path(cube_ring8))
    comm = cube_ring8.comm("d")
    assert tuner.select("all_to_all", 512 * 1024, comm) == "naive"
    after = CommProfile.load(tuner.profile_path(cube_ring8))
    assert len(after.samples) == 0                  # priced, not measured


def test_partial_coverage_excludes_analytic_candidates():
    """Measured CPU seconds and analytic v5e seconds are incomparable: on a
    pod-crossing all_reduce the `direct` candidate can never be measured
    (the dispatcher escalates it away), so with naive+hierarchical covered
    the race must pick among the measured candidates -- not hand the win to
    direct's incomparably-cheap analytic constants."""
    pod = fake_cube((2, 2, 2), ("pod", "data", "model"),
                    {"pod": 2, "dp": 2, "tp": 2})
    slow_model = LinkModel(alpha=1e-3, beta=1e-8, n=8, r2=1.0)
    prof = CommProfile(topology_fingerprint(pod), models={
        "naive/naive/ici": slow_model, "naive/naive/dcn": slow_model,
        "hierarchical/im/ici": slow_model,
        "hierarchical/im/dcn": LinkModel(alpha=0.0, beta=1e-8, n=8, r2=1.0),
    })
    est = planner.plan(pod, "all_reduce", ("pod", "dp"), 1 << 20,
                       profile=prof)
    assert est.est_source == "measured"
    assert est.algorithm in ("naive", "hierarchical")
