"""Per-kernel interpret=True validation: shape/dtype sweeps against the
pure-jnp oracles (ref.py), per the kernels/ contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.flash import flash_attention
from repro.kernels.reorder import ref as reorder_ref
from repro.kernels.reorder.reorder import tile_swizzle, block_transpose
from repro.kernels.rwkv6.rwkv6 import rwkv6_chunked as rwkv_pallas
from repro.models.layers import reference_attention, chunked_attention
from repro.models.ssm import rwkv6_chunked as rwkv_jnp


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 128, 4, 1, 128),    # MQA, wide head
])
@pytest.mark.parametrize("causal,window", [(True, -1), (True, 64),
                                           (False, -1)])
def test_flash_attention_sweep(dtype, B, S, H, KV, hd, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = reference_attention(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 128, 8, 2, 64),     # GQA 4:1
])
@pytest.mark.parametrize("causal,window,q_off,k_off", [
    (True, -1, 64, 0),      # q block placed later in the sequence
    (True, -1, 128, 64),    # both blocks offset (a ring-attention hop)
    (True, 96, 32, 0),      # sliding window across offset positions
])
def test_flash_attention_offset_sweep(dtype, B, S, H, KV, hd, causal,
                                      window, q_off, k_off):
    """q_offset/k_offset place the blocks at global positions: the kernel's
    masks must match the jnp oracle's (ref.py) at the same offsets."""
    from repro.kernels.attention import ref
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_offset=q_off, k_offset=k_off,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_off, k_offset=k_off)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [-1, 96])
def test_flash_offset_equals_full_sequence_slice(dtype, window):
    """The ring/context-parallel contract: running the kernel on a q slice
    at its global q_offset (full k visible) reproduces exactly those rows
    of the full-sequence result."""
    B, S, H, KV, hd, blk = 1, 256, 8, 2, 64, 64    # GQA 4:1
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    full = reference_attention(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    for q0 in (64, 192):
        got = flash_attention(q[:, q0:q0 + blk], k, v, causal=True,
                              window=window, q_offset=q0,
                              block_q=64, block_k=64, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(full[:, q0:q0 + blk], np.float32), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("G,b,D", [(4, 8, 128), (8, 16, 64), (16, 4, 256)])
def test_tile_swizzle_sweep(dtype, G, b, D):
    x = jax.random.normal(jax.random.PRNGKey(1), (G * b, D), dtype)
    perm = np.random.RandomState(G).permutation(G)
    got = tile_swizzle(x, perm, interpret=True)
    want = reorder_ref.tile_swizzle(x, perm)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("g1,g2", [(2, 4), (4, 2), (2, 2)])
def test_block_transpose(g1, g2):
    x = jax.random.normal(jax.random.PRNGKey(2), (g1 * g2 * 8, 32))
    got = block_transpose(x, g1, g2, interpret=True)
    want = reorder_ref.block_transpose(x, g1, g2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,K,chunk", [
    (1, 128, 2, 16, 32), (2, 64, 4, 32, 64), (1, 256, 1, 64, 64)])
def test_rwkv6_kernel_sweep(dtype, B, S, H, K, chunk):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = jax.random.normal(ks[0], (B, S, H, K), dtype)
    k = jax.random.normal(ks[1], (B, S, H, K), dtype)
    v = jax.random.normal(ks[2], (B, S, H, K), dtype)
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) * 0.5).astype(
        jnp.float32)
    u = (jax.random.normal(ks[4], (H, K)) * 0.1).astype(dtype)
    got = rwkv_pallas(r, k, v, logw, u, chunk=chunk, interpret=True)
    want, _ = rwkv_jnp(r, k, v, logw, u, chunk=chunk)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_chunked_attention_oracle_matches_naive():
    """The model's blockwise attention (used as kernel ref) vs naive."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (2, 192, 6, 32))
    k = jax.random.normal(ks[1], (2, 192, 3, 32))
    v = jax.random.normal(ks[2], (2, 192, 3, 32))
    got = chunked_attention(q, k, v, causal=True, window=48, chunk=64)
    want = reference_attention(q, k, v, causal=True, window=48)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
