"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get
from repro.launch.mesh import make_mesh
from repro.models.params import init_params
from repro.models.topology import build_topology
from repro.optim import adamw
from repro.runtime.trainer import TrainConfig, make_train_step


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.RandomState(seed)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.frontend == "patch":
        batch["patches"] = jnp.asarray(
            rng.randn(B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(rng.randn(B, S, cfg.frontend_dim),
                                      jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    cfg = get(arch).scaled_for_smoke()
    mesh = make_mesh((1, 1), ("data", "model"))
    topo = build_topology(cfg, mesh)
    params = init_params(cfg, topo, seed=0)
    tc = TrainConfig(warmup=1, lr=1e-3)
    opt = adamw.init_state(params, tc.adamw)
    step = make_train_step(cfg, topo, tc)
    batch = make_batch(cfg)

    params, opt, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, metrics)
    # correct initial CE scale: ~ln(vocab) for random targets
    assert 0.5 * np.log(cfg.vocab_size) < loss < 3 * np.log(cfg.vocab_size)
    # params updated and finite
    leaves = jax.tree.leaves(params)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves
               if l.dtype != jnp.int8)
    # forward logits shape
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.models.lm import Model
    from repro.models.params import param_specs, vocab_padded
    from repro.runtime.trainer import input_batch_specs
    model = Model(cfg, topo)
    fwd = jax.jit(shard_map(
        model.forward_logits, mesh=topo.cube.mesh,
        in_specs=(param_specs(cfg, topo), input_batch_specs(cfg, topo)),
        out_specs=P(topo.dp, None, topo.tp), check_vma=False))
    S_dec = batch["tokens"].shape[1]
    logits = fwd(params, batch)
    assert logits.shape == (2, S_dec, vocab_padded(cfg, topo))
    assert np.isfinite(np.asarray(logits)).all()


def test_loss_decreases_with_training():
    cfg = get("qwen3-1.7b").scaled_for_smoke()
    mesh = make_mesh((1, 1), ("data", "model"))
    topo = build_topology(cfg, mesh)
    params = init_params(cfg, topo, seed=0)
    tc = TrainConfig(warmup=2, lr=2e-3, total_steps=40)
    opt = adamw.init_state(params, tc.adamw)
    step = make_train_step(cfg, topo, tc)
    batch = make_batch(cfg, B=4, S=64)
    losses = []
    for _ in range(15):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses
