"""Elastic checkpointing: atomic layout, async save, reshard-on-restore.

Covers the redesigned topology-bound :class:`CheckpointManager` surface —
``save(step, TrainState)`` / ``restore(step)`` / ``restore_params(step,
serve_topo=...)`` — the deprecated positional shims, the manifest's
structural fingerprint validation, crash/GC hardening, async write-error
propagation, save/train overlap (asserted via spans), reshard-on-restore
bit-identity against both the pure-NumPy placement oracle and direct init
on the target topology, and the torch-free Hugging Face import path.
"""
import os
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import hf_import, layout, reshard
from repro.checkpoint.manager import CheckpointManager, TrainState
from repro.configs import get
from repro.core import program
from repro.core.comm import CommTrace
from repro.launch.mesh import make_mesh
from repro.models.params import init_params, param_specs
from repro.models.topology import build_serve_topology, build_topology
from repro.testing import oracles
from repro import telemetry


def _tiny_state(seed=0):
    rng = np.random.default_rng(seed)
    params = {"w": rng.standard_normal((4, 8)).astype(np.float32),
              "b": {"scale": rng.standard_normal(8).astype(np.float32)}}
    opt = {"m": jax.tree.map(np.zeros_like, params),
           "count": np.int32(3)}
    return TrainState(params=jax.tree.map(jnp.asarray, params),
                      opt=jax.tree.map(jnp.asarray, opt))


def _assert_tree_equal(a, b):
    la = jax.tree_util.tree_flatten_with_path(a)[0]
    lb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(la) == len(lb)
    for (pa, va), (pb, vb) in zip(la, lb):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))


# --------------------------------------------------------------- layout
def test_all_steps_ignores_foreign_entries(tmp_path):
    root = str(tmp_path)
    mgr = CheckpointManager(root, async_save=False)
    mgr.save(10, _tiny_state())
    mgr.save(20, _tiny_state())
    # foreign debris a hardened all_steps must skip
    os.makedirs(os.path.join(root, "step_00000030.tmp"))  # killed writer
    os.makedirs(os.path.join(root, "notastep"))
    open(os.path.join(root, "step_00000040"), "w").close()  # file, not dir
    open(os.path.join(root, "events.log"), "w").close()
    os.makedirs(os.path.join(root, "step_123"))  # wrong digit count
    assert mgr.all_steps() == [10, 20]
    assert mgr.latest_step() == 20


def test_killed_mid_write_is_invisible_and_retry_wins(tmp_path):
    root = str(tmp_path)
    mgr = CheckpointManager(root, async_save=False)
    # simulate a writer killed mid-step-5: partial .tmp with garbage files
    debris = os.path.join(root, "step_00000005.tmp")
    os.makedirs(debris)
    np.save(os.path.join(debris, "arr_0.npy"), np.zeros(3))
    open(os.path.join(debris, "garbage"), "w").close()

    assert mgr.all_steps() == []
    with pytest.raises(FileNotFoundError, match="no checkpoint for step 5"):
        mgr.restore(5)

    state = _tiny_state(seed=7)
    mgr.save(5, state)  # retry overwrites the debris
    assert mgr.all_steps() == [5]
    assert not os.path.exists(debris)
    restored = mgr.restore(5)
    _assert_tree_equal(restored.params, state.params)
    _assert_tree_equal(restored.opt, state.opt)


def test_keep_last_gc_and_in_flight_protection(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False, keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tiny_state(seed=s))
    assert mgr.all_steps() == [3, 4]

    # a step registered as in-flight is never collected, even when the GC
    # horizon would otherwise claim it
    mgr.keep_last = 1
    mgr._writing.add(3)
    mgr._gc()
    assert mgr.all_steps() == [3, 4]
    mgr._writing.discard(3)
    mgr._gc()
    assert mgr.all_steps() == [4]


# ----------------------------------------------------------- async save
def test_async_write_error_surfaces_at_wait(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    orig_save = np.save

    def failing_save(path, arr, *a, **k):
        raise OSError("disk full (simulated)")

    monkeypatch.setattr(np, "save", failing_save)
    mgr.save(1, _tiny_state())
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    # the failed step never became visible, and the manager recovers
    assert mgr.all_steps() == []
    monkeypatch.setattr(np, "save", orig_save)
    mgr.save(2, _tiny_state())
    mgr.wait()
    assert mgr.all_steps() == [2]


def test_async_write_error_surfaces_at_next_save(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    orig_save = np.save
    monkeypatch.setattr(
        np, "save",
        lambda *a, **k: (_ for _ in ()).throw(OSError("bad sector")))
    mgr.save(1, _tiny_state())
    monkeypatch.setattr(np, "save", orig_save)
    with pytest.raises(OSError, match="bad sector"):
        mgr.save(2, _tiny_state())
    mgr.save(3, _tiny_state())
    mgr.wait()
    assert mgr.all_steps() == [3]


def test_async_save_overlaps_and_spans_cross_threads(tmp_path, monkeypatch):
    """save() returns after the host gather; the writes land on the
    executor.  Proven via spans: the worker's ``checkpoint:params`` span
    lives on its own tracer lane and extends past the save() dispatch."""
    state = _tiny_state()
    orig_save = np.save

    def slow_save(path, arr, *a, **k):
        time.sleep(0.03)
        return orig_save(path, arr, *a, **k)

    monkeypatch.setattr(np, "save", slow_save)
    # the two sections write concurrently (max_workers=2): the wall floor
    # is the slowest section, not the sum
    slowest = 0.03 * max(len(jax.tree.leaves(state.params)),
                         len(jax.tree.leaves(state.opt)))
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    with telemetry.Tracer() as tr:
        t0 = time.monotonic()
        mgr.save(1, state)
        dispatch = time.monotonic() - t0
        mgr.wait()
        durable = time.monotonic() - t0
    # dispatch did not pay for the writes
    assert dispatch < slowest <= durable

    spans = {sp.name: sp for sp in tr.finished()}
    main_tid = spans["checkpoint:gather:params"].tid
    assert spans["checkpoint:params"].tid != main_tid  # worker lane
    assert spans["checkpoint:opt"].tid != main_tid
    assert any(sp.name == "checkpoint-durable" and sp.ph == "i"
               for sp in tr.finished())
    assert mgr.all_steps() == [1]


def test_trainer_step_does_not_block_on_write(tmp_path, monkeypatch):
    """End-to-end overlap: with slowed disk writes, the train step after a
    checkpoint dispatch finishes before the checkpoint becomes durable."""
    from repro.data.pipeline import DataConfig, TokenStream
    from repro.optim import adamw
    from repro.runtime.trainer import Trainer, TrainConfig

    cfg = get("qwen3-1.7b").scaled_for_smoke()
    mesh = make_mesh((1, 1), ("data", "model"))
    topo = build_topology(cfg, mesh)
    tc = TrainConfig(warmup=2, lr=1e-3)
    params = init_params(cfg, topo, seed=0)
    opt = adamw.init_state(params, tc.adamw)
    n_leaves = len(jax.tree.leaves({"opt": opt, "params": params}))

    orig_save = np.save
    delay = 0.02

    def slow_save(path, arr, *a, **k):
        time.sleep(delay)
        return orig_save(path, arr, *a, **k)

    monkeypatch.setattr(np, "save", slow_save)
    stream = TokenStream(cfg, DataConfig(seq_len=32, global_batch=2,
                                         vocab_size=cfg.vocab_size))
    batches = ({k: jnp.asarray(v)
                for k, v in stream.global_batch_at(s).items()}
               for s in range(3))
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    with telemetry.Tracer() as tr:
        trainer = Trainer(cfg, topo, tc, checkpointer=mgr)
        trainer.run(params, opt, batches, checkpoint_every=2,
                    log_every=0, log=lambda *_: None)
        mgr.wait()

    steps = [sp for sp in tr.finished() if sp.name == "train-step"]
    durable = [sp for sp in tr.finished() if sp.name == "checkpoint-durable"]
    assert len(steps) == 3 and durable
    # the write takes at least n_leaves * delay; the step that ran behind
    # it finished long before the durable instant
    after = steps[2]
    assert after.ts + after.dur < durable[0].ts
    assert after.dur / 1e6 < n_leaves * delay
    assert mgr.all_steps() == [2]


# ------------------------------------------------- API redesign + shims
def test_deprecated_shims_match_new_surface(tmp_path):
    state = _tiny_state(seed=3)
    new_root, old_root = str(tmp_path / "new"), str(tmp_path / "old")
    new_mgr = CheckpointManager(new_root, async_save=False)
    new_mgr.save(7, state)

    old_mgr = CheckpointManager(old_root, async_save=False)
    with pytest.warns(DeprecationWarning, match="save\\(step, params"):
        old_mgr.save(7, state.params, state.opt)

    # identical bytes on disk (manifest + every leaf file)
    for d in (new_root, old_root):
        assert layout.list_steps(d) == [7]
    m_new = layout.read_manifest(layout.step_dir(new_root, 7))
    m_old = layout.read_manifest(layout.step_dir(old_root, 7))
    assert m_new == m_old
    assert m_new["fingerprint"] == layout.fingerprint(m_new["leaves"])

    st = new_mgr.restore(7)
    with pytest.warns(DeprecationWarning, match="restore\\(step\\)"):
        params, opt = old_mgr.restore(7, state.params, state.opt)
    _assert_tree_equal(st.params, params)
    _assert_tree_equal(st.opt, opt)

    p_new = new_mgr.restore_params(7)
    with pytest.warns(DeprecationWarning, match="restore_params"):
        p_old = old_mgr.restore_params(7, state.params)
    _assert_tree_equal(p_new, p_old)
    _assert_tree_equal(p_new, state.params)


def test_fingerprint_validation_catches_architecture_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = _tiny_state()
    mgr.save(1, state)

    # wrong leaf count
    bad_count = TrainState(params={"w": np.zeros((4, 8), np.float32)},
                           opt=state.opt)
    with pytest.raises(ValueError, match="architecture mismatch"):
        with pytest.warns(DeprecationWarning):
            mgr.restore(1, bad_count.params, bad_count.opt)

    # right count, wrong shape: the per-leaf record diff fires
    bad_shape = jax.tree.map(np.asarray, state.params)
    bad_shape["w"] = np.zeros((5, 8), np.float32)
    with pytest.raises(ValueError, match="does not match the restore"):
        with pytest.warns(DeprecationWarning):
            mgr.restore_params(1, bad_shape)


def test_restore_without_specs_rebuilds_from_manifest(tmp_path):
    """A spec-free manager restores structure from the manifest's leaf
    records (the fix for the dead v1 ``treedef`` field)."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = _tiny_state(seed=11)
    mgr.save(3, state)
    st = CheckpointManager(str(tmp_path)).restore(3)
    _assert_tree_equal(st.params, state.params)
    _assert_tree_equal(st.opt, state.opt)
    p = CheckpointManager(str(tmp_path)).restore_params(3)
    _assert_tree_equal(p, state.params)


# ------------------------------------------------------ reshard-on-restore
def _logical_coords(cube):
    """device -> logical coords map via the cube's device grid."""
    grid = np.asarray(cube.mesh.devices).reshape(tuple(cube.dim_sizes))
    return {grid[c].id: c for c in np.ndindex(*grid.shape)}


def test_scatter_matches_numpy_oracle(cube_2x4):
    cube = cube_2x4
    x = np.arange(8 * 16, dtype=np.float32).reshape(8, 16)
    spec = (cube.dim_names[0], cube.dim_names[1])
    [placed] = reshard.scatter_to_cube(cube, [x], [spec])
    np.testing.assert_array_equal(np.asarray(placed), x)
    want = oracles.reshard(x, cube.dim_sizes, cube.dim_names, spec)
    coords = _logical_coords(cube)
    for sh in placed.addressable_shards:
        np.testing.assert_array_equal(
            np.asarray(sh.data), want[coords[sh.device.id]])


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-1b",
                                  "phi3-mini-3.8b"])
def test_elastic_restore_bit_identical_across_topologies(arch, tmp_path):
    """Save on the training topology, restore onto a different serve
    topology: one rooted-scatter CommProgram with program_id provenance,
    bit-identical to direct init on the target, shards matching the
    pure-NumPy placement oracle."""
    cfg = get(arch).scaled_for_smoke()
    mesh = make_mesh((4, 2), ("data", "model"))
    train_topo = build_topology(cfg, mesh)
    serve_topo = build_serve_topology(cfg, mesh)
    assert dict(zip(train_topo.cube.dim_names, train_topo.cube.dim_sizes)) \
        != dict(zip(serve_topo.cube.dim_names, serve_topo.cube.dim_sizes))

    params = init_params(cfg, train_topo, seed=0)
    mgr = CheckpointManager(
        str(tmp_path), async_save=False, topo=train_topo,
        specs={"params": param_specs(cfg, train_topo), "opt": None})
    mgr.save(1, TrainState(params=params))

    serve_specs = param_specs(cfg, serve_topo)
    with CommTrace() as tr:
        restored = mgr.restore_params(1, serve_topo=serve_topo,
                                      specs=serve_specs)
    assert any(e.program_id == "ckpt-restore-params" for e in tr.events)
    assert "ckpt-restore-params" in tr.summary()["programs"]

    direct = init_params(cfg, serve_topo, seed=0)
    _assert_tree_equal(restored, direct)

    # spot-check physical placement of one sharded leaf vs the oracle
    cube = serve_topo.cube
    coords = _logical_coords(cube)
    flat = jax.tree_util.tree_flatten_with_path(restored)[0]
    spec_flat = reshard.flatten_specs(serve_specs, [v for _, v in flat])
    checked = 0
    for (path, leaf), spec in zip(flat, spec_flat):
        if not any(s is not None for s in spec):
            continue
        want = oracles.reshard(np.asarray(leaf), cube.dim_sizes,
                               cube.dim_names, spec)
        for sh in leaf.addressable_shards:
            np.testing.assert_array_equal(
                np.asarray(sh.data), want[coords[sh.device.id]])
        checked += 1
        if checked >= 2:
            break
    assert checked


def test_save_gather_program_hits_lower_cache(tmp_path, cube_2x2x2):
    """The save-side gather program's structural fingerprint is
    step-invariant, so the second save reuses the lowered program."""
    cube = cube_2x2x2
    specs = {"a": P("a", ("b", "c")), "b": P(("a", "b"), None)}
    rng = np.random.default_rng(0)
    trees = [{"a": jnp.asarray(rng.standard_normal((8, 8),).astype("f4")),
              "b": jnp.asarray(rng.standard_normal((8, 4)).astype("f4"))}
             for _ in range(2)]
    placed = [jax.tree.unflatten(
        jax.tree.structure(t),
        reshard.scatter_to_cube(cube, jax.tree.leaves(t),
                                reshard.flatten_specs(specs,
                                                      jax.tree.leaves(t))))
        for t in trees]
    mgr = CheckpointManager(str(tmp_path), async_save=False, topo=cube,
                            specs={"params": specs, "opt": None})
    base = dict(program.LOWER_STATS)
    mgr.save(1, TrainState(params=placed[0]))
    mgr.save(2, TrainState(params=placed[1]))
    assert program.LOWER_STATS["cache_hits"] >= base.get("cache_hits", 0) + 1
    st1 = mgr.restore_params(1)
    _assert_tree_equal(st1, trees[0])


# --------------------------------------------------------------- HF import
def test_hf_roundtrip_qwen3(tmp_path):
    cfg = get("qwen3-1.7b").scaled_for_smoke()
    mesh = make_mesh((1, 1), ("data", "model"))
    topo = build_topology(cfg, mesh)
    params = jax.tree.map(np.asarray, init_params(cfg, topo, seed=0))

    sd = hf_import.export_state_dict(params, cfg)
    assert "lm_head.weight" in sd  # qwen3-1.7b does not tie embeddings
    st = str(tmp_path / "model.safetensors")
    pt = str(tmp_path / "pytorch_model.bin")
    hf_import.write_safetensors(st, sd)
    hf_import.write_pytorch_bin(pt, sd)
    for path in (st, pt):
        back = hf_import.import_state_dict(
            hf_import.read_state_dict(path), cfg, topo)
        _assert_tree_equal(params, back)


def test_hf_import_rejects_unmapped_keys(tmp_path):
    cfg = get("qwen3-1.7b").scaled_for_smoke()
    mesh = make_mesh((1, 1), ("data", "model"))
    topo = build_topology(cfg, mesh)
    params = jax.tree.map(np.asarray, init_params(cfg, topo, seed=0))
    sd = hf_import.export_state_dict(params, cfg)
    sd["model.layers.0.self_attn.rotary_emb.inv_freq"] = np.zeros(4)  # ok
    sd["model.layers.0.self_attn.q_proj.bias"] = np.zeros(4)  # not ok
    with pytest.raises(ValueError, match="no mapping"):
        hf_import.import_state_dict(sd, cfg, topo)
    tree = hf_import.import_state_dict(sd, cfg, topo, strict=False)
    _assert_tree_equal(params, tree)


def test_hf_import_unsupported_architectures():
    cfg = get("rwkv6-7b").scaled_for_smoke()
    with pytest.raises(NotImplementedError, match="no[\\s\\S]*mapping"):
        hf_import.import_state_dict({}, cfg)
