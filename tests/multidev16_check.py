"""16-virtual-device conformance sweep: 4-D hypercubes and deeper
`1100`-style bitmap selections, through the communicator API.

Run in a subprocess (so the 16-device count never leaks into the main
pytest process, which boots 8):

    python tests/multidev16_check.py

Prints ``ALL-OK`` on success; raises on any mismatch.  The sweep covers:
  * every Table II stage (+ pidcomm + auto) of the four PE<->PE primitives
    on the 2x2x2x2 cube, over contiguous ("1100"/"0011"), interleaved
    ("1010"/"0101"), middle ("0110") and full ("1111") bitmap selections --
    multi-instance groups of size 2/4/16 with up to 8 instances;
  * the 16-wide flat ring (single-dim, stresses the _LADDER_MAX ladder);
  * a pod-crossing 2x4x2 cube: planner-driven "auto" must execute the
    hierarchical §IX-A schedule at 16 devices (HLO assertion included).
"""
import os
import re

# Replace (not just prepend) any inherited device-count flag: under pytest
# the parent process exports =8, and XLA honors the last occurrence.
_flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=16 " + _flags).strip()

import numpy as np

from repro.core.collectives import APPLICABILITY
from repro.core.comm import CommTrace
from repro.testing import oracles, substrate


def check(name, got, want):
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                  err_msg=name)
    print(f"ok: {name}")


def sweep_cube(cube, bitmaps):
    nd = len(cube.dim_sizes)
    for bm in bitmaps:
        names = cube.dims_from_bitmap(bm)
        idx = tuple(cube.dim_names.index(d) for d in names)
        comm = cube.comm(bm)
        g = comm.group_size
        x = substrate.integer_payload(cube, (2, 4 * g), seed=g + nd)

        for alg in APPLICABILITY["all_reduce"] + ("pidcomm", "auto"):
            got = substrate.run_per_shard(
                cube, lambda v: comm.all_reduce(v, algorithm=alg), x)
            check(f"AR[{bm},{alg}] g={g}", got,
                  oracles.all_reduce(x, nd, idx))

        for alg in APPLICABILITY["reduce_scatter"] + ("pidcomm", "auto"):
            got = substrate.run_per_shard(
                cube,
                lambda v: comm.reduce_scatter(v, axis=nd + 1, algorithm=alg),
                x)
            check(f"RS[{bm},{alg}] g={g}", got,
                  oracles.reduce_scatter(x, nd, idx, axis=1))

        for alg in APPLICABILITY["all_gather"] + ("pidcomm", "auto"):
            got = substrate.run_per_shard(
                cube, lambda v: comm.all_gather(v, axis=nd, algorithm=alg),
                x)
            check(f"AG[{bm},{alg}] g={g}", got,
                  oracles.all_gather(x, nd, idx, axis=0))

        for alg in APPLICABILITY["all_to_all"] + ("pidcomm", "auto"):
            got = substrate.run_per_shard(
                cube,
                lambda v: comm.all_to_all(v, split_axis=nd + 1,
                                          concat_axis=nd + 1, algorithm=alg),
                x)
            check(f"AA[{bm},{alg}] g={g}", got,
                  oracles.all_to_all(x, nd, idx, split_axis=1,
                                     concat_axis=1))


def pod_16dev():
    cube = substrate.build_cube("pod2x4x2")
    assert cube.dcn_dims == ("pod",)
    comm = cube.comm(("pod", "dp"))
    x = substrate.integer_payload(cube, (40,), seed=7)
    with CommTrace() as tr:
        got = substrate.run_per_shard(cube, lambda v: comm.all_reduce(v), x)
    check("pod AR[110] auto (16 dev)", got, oracles.all_reduce(x, 3, (0, 1)))
    assert tr.events[0].flow == "hierarchical", tr.events
    hlo = substrate.lowered_text(cube, lambda v: comm.all_reduce(v), x)
    assert ("reduce-scatter" in hlo or "reduce_scatter" in hlo), \
        "hierarchical AR must lower to RS/AR/AG at 16 devices"
    assert "all-gather" in hlo or "all_gather" in hlo
    print("ok: hierarchical AR lowers to RS/AR/AG schedule at 16 devices")


def main():
    substrate.ensure_virtual_devices(16)
    cube4d = substrate.build_cube("4d16")
    sweep_cube(cube4d, ("1100", "0110", "0011", "1010", "0101", "1111"))
    ring16 = substrate.build_cube("ring16")
    sweep_cube(ring16, ("1",))
    pod_16dev()
    print("ALL-OK")


if __name__ == "__main__":
    main()
