"""Shared pytest configuration for the suite.

Must run before anything imports jax: the XLA host platform only honors
``--xla_force_host_platform_device_count`` at backend init, so the flag is
set at conftest import time (pytest imports conftest before test modules).
The in-process suite then sees 8 virtual devices; the subprocess oracles
(``multidev_check.py`` / ``parallel_check.py``) still set their own flags
and are unaffected.
"""
import os

# Before any jax import -- see module docstring.
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")).strip()

import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _reset_observability_state():
    """Isolate per-test observability state so test order can never change
    observed counts: zero ``program.LOWER_STATS``, empty every cube's
    cross-program lower cache (the session-scoped cube fixtures otherwise
    carry cached schedules -- and their hit counts -- between tests), and
    leave the process-wide telemetry registry disabled and empty."""
    yield
    from repro.core import program
    from repro.telemetry import metrics as telemetry_metrics
    program.clear_lower_cache()
    for k in program.LOWER_STATS:
        program.LOWER_STATS[k] = 0
    telemetry_metrics.disable()
    telemetry_metrics.REGISTRY.reset()


def _cube(name):
    from repro.testing import substrate
    substrate.ensure_virtual_devices(8)
    return substrate.build_cube(name)


@pytest.fixture(scope="session")
def cube_ring8():
    """1-D ring: 8 devices on one dim."""
    return _cube("ring8")


@pytest.fixture(scope="session")
def cube_2x4():
    """2-D rectangle: 2 x 4."""
    return _cube("2x4")


@pytest.fixture(scope="session")
def cube_2x2x2():
    """3-D cube a x b x c -- the multi-instance bitmap shapes."""
    return _cube("2x2x2")


@pytest.fixture(scope="session")
def cube_pod():
    """Pod-crossing 2x2x2 with ``pod`` as a DCN axis (paper §IX-A)."""
    return _cube("pod2x2x2")
