"""Tests for the communicator-centric API redesign (repro.core.comm):

* registry completeness against paper Table II (derived, not hand-kept);
* plan-driven dispatch -- ``algorithm="auto"`` executes ``planner.plan()``'s
  pick for every primitive, with the pod-crossing all-reduce lowering to the
  hierarchical §IX-A schedule (HLO assertion);
* CommTrace event accounting;
* the deprecated ``Collectives`` shim is bit-identical to a bound
  ``Communicator`` on conformance cells;
* the §V-C compressed registry algorithm end-to-end (value + custom_vjp
  boundary + trainer gradient-sync flag).
"""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import planner
from repro.core.collectives import APPLICABILITY, Collectives
from repro.core.comm import (
    CommTrace, Communicator, applicability, get_algorithm,
    register_algorithm, registered_algorithms, resolve_stage)
from repro.testing import oracles, substrate

# Paper Table II, spelled out -- the registry must reproduce it exactly.
TABLE_II = {
    "all_to_all": ("naive", "pr", "im", "cm"),
    "reduce_scatter": ("naive", "pr", "im"),
    "all_reduce": ("naive", "pr", "im"),
    "all_gather": ("naive", "pr", "im", "cm"),
    "scatter": ("naive", "im"),
    "gather": ("naive", "im"),
    "reduce": ("naive", "pr", "im"),
    "broadcast": ("naive",),
}


# ------------------------------------------------------------- the registry
def test_registry_reproduces_table_ii():
    assert applicability() == TABLE_II
    # the legacy constant is the derived table, not a divergent copy
    assert APPLICABILITY == TABLE_II


def test_first_class_algorithms_registered():
    extras = {"hierarchical", "compressed", "ring", "tree"}
    assert extras <= set(registered_algorithms("all_reduce"))
    # extras must not widen the Table II applicability ladder
    for name in extras:
        assert not get_algorithm("all_reduce", name).table_ii
    # every Table II cell resolves to a registered body
    for prim, stages in TABLE_II.items():
        for st in stages:
            assert get_algorithm(prim, st).stage == st


def test_register_rejects_duplicates_and_unknowns():
    with pytest.raises(ValueError, match="already registered"):
        register_algorithm("all_reduce", "im")(lambda comm, x, *, op: x)
    with pytest.raises(ValueError, match="unknown primitive"):
        register_algorithm("warp_gate", "im")(lambda comm, x: x)
    with pytest.raises(ValueError, match="needs an explicit stage"):
        register_algorithm("all_reduce", "fancy")(lambda comm, x, *, op: x)
    with pytest.raises(ValueError, match="no algorithm"):
        get_algorithm("all_reduce", "warp")


def test_communicator_binding(cube_2x2x2):
    c = cube_2x2x2.comm("110")
    assert c.dims == ("a", "b")
    assert c.bitmap == "110"
    assert c.group_size == 4 and c.num_instances == 2
    assert c.fast_dims == ("a", "b") and c.slow_dims == ()
    with pytest.raises(ValueError, match="unknown algorithm"):
        c.all_reduce(np.ones(4, np.float32), algorithm="warp")


def test_pod_communicator_caches_fast_slow_split(cube_pod):
    c = cube_pod.comm(("pod", "dp"))
    assert c.crosses_dcn
    assert c.fast_dims == ("dp",) and c.slow_dims == ("pod",)


# ------------------------------------------------------ plan-driven dispatch
def _expected_flow(cube, primitive, dims, payload_bytes, op="add"):
    """The registry flow 'auto' must execute, per the planner contract."""
    est = planner.plan(cube, primitive, dims, payload_bytes)
    if est.algorithm == "naive":
        return "naive"
    if est.algorithm == "hierarchical" and primitive == "all_reduce" \
            and op == "add":
        return "hierarchical"
    return resolve_stage(primitive, "pidcomm")


@pytest.mark.parametrize("primitive", ["all_reduce", "reduce_scatter",
                                       "all_gather", "all_to_all"])
def test_auto_dispatches_planner_choice(cube_pod, primitive):
    """Every PE<->PE primitive with algorithm="auto" executes the planner's
    pick on a pod-crossing group, and the result matches the oracle."""
    comm = cube_pod.comm(("pod", "dp"))
    g = comm.group_size
    x = substrate.integer_payload(cube_pod, (2, 4 * g), seed=g)
    fns = {
        "all_reduce": lambda v: comm.all_reduce(v),
        "reduce_scatter": lambda v: comm.reduce_scatter(v, axis=4),
        "all_gather": lambda v: comm.all_gather(v, axis=3),
        "all_to_all": lambda v: comm.all_to_all(v, split_axis=4,
                                                concat_axis=4),
    }
    wants = {
        "all_reduce": lambda: oracles.all_reduce(x, 3, (0, 1)),
        "reduce_scatter": lambda: oracles.reduce_scatter(x, 3, (0, 1),
                                                         axis=1),
        "all_gather": lambda: oracles.all_gather(x, 3, (0, 1), axis=0),
        "all_to_all": lambda: oracles.all_to_all(x, 3, (0, 1), split_axis=1,
                                                 concat_axis=1),
    }
    with CommTrace() as tr:
        got = substrate.run_per_shard(cube_pod, fns[primitive], x)
    np.testing.assert_array_equal(got, wants[primitive]())
    ev = [e for e in tr.events if e.primitive == primitive]
    assert len(ev) == 1
    payload = x[0, 0, 0].size * x.dtype.itemsize
    assert ev[0].flow == _expected_flow(cube_pod, primitive, ("pod", "dp"),
                                        payload)
    assert ev[0].algorithm == "auto"


def test_auto_rooted_primitives_dispatch_and_trace(cube_2x2x2):
    comm = cube_2x2x2.comm("111")
    host = np.arange(16 * 3, dtype=np.float32).reshape(16, 3)
    with CommTrace() as tr:
        dev = comm.scatter(host, axis=0)
        rep = comm.broadcast(host)
        back = comm.gather(dev)
        red = comm.reduce(dev, op="add", axis=0)
    np.testing.assert_array_equal(back, host)
    np.testing.assert_array_equal(np.asarray(red), host.sum(0))
    got = substrate.local_blocks(cube_2x2x2, dev)
    np.testing.assert_array_equal(
        got, oracles.scatter(host, cube_2x2x2.dim_sizes, (0, 1, 2), axis=0))
    assert [e.primitive for e in tr.events] == [
        "scatter", "broadcast", "gather", "reduce"]
    assert all(e.algorithm == "auto" for e in tr.events)


def test_auto_nonadditive_pod_all_reduce_event_matches_executed_flow(
        cube_pod):
    """op="max" cannot take the hierarchical split: auto must execute the
    direct flow AND the recorded event must carry the direct estimate (the
    op-blind planner pick's hierarchical numbers would understate DCN
    bytes by |ICI|x)."""
    comm = cube_pod.comm(("pod", "dp"))
    x = substrate.integer_payload(cube_pod, (64,), seed=6)
    with CommTrace() as tr:
        got = substrate.run_per_shard(
            cube_pod, lambda v: comm.all_reduce(v, op="max"), x)
    np.testing.assert_array_equal(got, oracles.all_reduce(x, 3, (0, 1),
                                                          op="max"))
    ev = tr.events[0]
    assert ev.flow == "im"
    direct = planner.estimate(cube_pod, "all_reduce", ("pod", "dp"), 64 * 4,
                              algorithm="direct")
    assert ev.dcn_bytes == direct.dcn_bytes
    assert ev.seconds == direct.seconds


def test_auto_pod_crossing_all_reduce_is_hierarchical_hlo(cube_pod):
    """Acceptance: the planner picks hierarchical for the pod-crossing
    all-reduce and 'auto' lowers the §IX-A reduce-scatter/all-reduce/
    all-gather schedule."""
    est = planner.plan(cube_pod, "all_reduce", ("pod", "dp"), 4 * 4096)
    assert est.algorithm == "hierarchical"
    comm = cube_pod.comm(("pod", "dp"))
    x = substrate.integer_payload(cube_pod, (4096,), seed=3)
    hlo = substrate.lowered_text(cube_pod, lambda v: comm.all_reduce(v), x)
    assert "reduce-scatter" in hlo or "reduce_scatter" in hlo
    assert "all-gather" in hlo or "all_gather" in hlo
    # intra-pod group: auto lowers the direct psum, not the split
    intra = cube_pod.comm(("dp",))
    with CommTrace() as tr:
        got = substrate.run_per_shard(cube_pod,
                                      lambda v: intra.all_reduce(v), x)
    np.testing.assert_array_equal(got, oracles.all_reduce(x, 3, (1,)))
    assert tr.events[0].flow == "im"


# ----------------------------------------------------------- trace accounting
def test_commtrace_event_accounting(cube_pod):
    comm = cube_pod.comm(("pod", "dp"))
    x = substrate.integer_payload(cube_pod, (64,), seed=5)
    payload = 64 * 4
    with CommTrace() as outer:
        with CommTrace() as inner:
            substrate.run_per_shard(cube_pod, lambda v: comm.all_reduce(v), x)
        substrate.run_per_shard(
            cube_pod, lambda v: comm.all_gather(v, axis=3), x)
    # nested traces both observe the dispatch inside their window
    assert len(inner.events) == 1 and len(outer.events) == 2
    ar, ag = outer.events
    assert (ar.primitive, ar.flow, ar.stage) == ("all_reduce",
                                                 "hierarchical", "im")
    assert ar.bitmap == "110" and ar.dims == ("pod", "dp")
    assert ar.group_size == 4 and ar.num_instances == 2
    assert ar.payload_bytes == payload
    assert ar.dcn_bytes > 0 and ar.ici_bytes > 0 and ar.seconds > 0
    # the hierarchical DCN hop carries the 1/|ICI| shard, cheaper than the
    # flat collective's
    flat = planner.estimate(cube_pod, "all_reduce", ("pod", "dp"), payload,
                            algorithm="direct")
    assert ar.dcn_bytes < flat.dcn_bytes
    assert ag.primitive == "all_gather" and ag.payload_bytes == payload
    s = outer.summary()
    assert s["events"] == 2
    assert s["by_flow"]["all_reduce/hierarchical"]["count"] == 1
    assert s["ici_bytes"] == pytest.approx(ar.ici_bytes + ag.ici_bytes)
    # no active trace -> no recording, dispatch unaffected
    substrate.run_per_shard(cube_pod, lambda v: comm.all_reduce(v), x)
    assert len(outer.events) == 2


def test_commtrace_records_gradient_sync(cube_pod):
    """The trainer's replicated-gradient sync dispatches through the
    communicator and is observable (pre-vma explicit path only)."""
    from repro import compat
    if compat.HAS_VMA:
        pytest.skip("vma jax: gradient reductions are autodiff-inserted")
    from repro.runtime.trainer import sync_replicated_grads
    x = substrate.integer_payload(cube_pod, (8,), seed=2)
    specs = {"g": P()}
    with CommTrace() as tr:
        got = substrate.run_per_shard(
            cube_pod,
            lambda v: sync_replicated_grads({"g": v}, specs, cube_pod)["g"],
            x)
    np.testing.assert_array_equal(got, oracles.all_reduce(x, 3, (0, 1, 2)))
    assert [e.flow for e in tr.events] == ["hierarchical"]


# ------------------------------------------------------- shim differential
SHIM_CELLS = [
    ("cube_ring8", "1", "all_reduce", "pidcomm"),
    ("cube_2x2x2", "011", "all_to_all", "im"),
    ("cube_2x4", "01", "reduce_scatter", "pr"),
]


@pytest.mark.parametrize("cube_name,bitmap,primitive,stage", SHIM_CELLS)
def test_shim_equals_communicator(cube_name, bitmap, primitive, stage,
                                  request):
    """Collectives (deprecated shim) and Communicator produce bit-identical
    results on conformance cells -- same registry bodies underneath."""
    cube = request.getfixturevalue(cube_name)
    names = cube.dims_from_bitmap(bitmap)
    idx = tuple(cube.dim_names.index(d) for d in names)
    with pytest.warns(DeprecationWarning, match="cube.comm"):
        col = Collectives(cube)
    comm = cube.comm(bitmap)
    nd = len(cube.dim_sizes)
    g = cube.group_size(names)
    x = substrate.integer_payload(cube, (2, 4 * g), seed=g)
    if primitive == "all_reduce":
        via_col = substrate.run_per_shard(
            cube, lambda v: col.all_reduce(v, names, algorithm=stage), x)
        via_comm = substrate.run_per_shard(
            cube, lambda v: comm.all_reduce(v, algorithm=stage), x)
        want = oracles.all_reduce(x, nd, idx)
    elif primitive == "all_to_all":
        via_col = substrate.run_per_shard(
            cube, lambda v: col.all_to_all(v, names, split_axis=nd + 1,
                                           concat_axis=nd + 1,
                                           algorithm=stage), x)
        via_comm = substrate.run_per_shard(
            cube, lambda v: comm.all_to_all(v, split_axis=nd + 1,
                                            concat_axis=nd + 1,
                                            algorithm=stage), x)
        want = oracles.all_to_all(x, nd, idx, split_axis=1, concat_axis=1)
    else:
        via_col = substrate.run_per_shard(
            cube, lambda v: col.reduce_scatter(v, names, axis=nd + 1,
                                               algorithm=stage), x)
        via_comm = substrate.run_per_shard(
            cube, lambda v: comm.reduce_scatter(v, axis=nd + 1,
                                                algorithm=stage), x)
        want = oracles.reduce_scatter(x, nd, idx, axis=1)
    np.testing.assert_array_equal(via_col, via_comm)  # bit-identical
    np.testing.assert_array_equal(via_comm, want)


# ------------------------------------------------------ compressed algorithm
def test_compressed_all_reduce_value_and_planner(cube_pod):
    comm = cube_pod.comm(("pod", "dp"))
    x = substrate.integer_payload(cube_pod, (512,), seed=11)
    with CommTrace() as tr:
        got = substrate.run_per_shard(
            cube_pod, lambda v: comm.all_reduce(v, algorithm="compressed"), x)
    want = oracles.all_reduce(x, 3, (0, 1))
    # int8 DCN hop: lossy but blockwise-absmax tight on small-int payloads
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=0.5)
    ev = tr.events[0]
    assert (ev.flow, ev.stage) == ("compressed", "cm")
    hier = planner.estimate(cube_pod, "all_reduce", ("pod", "dp"), 512 * 4,
                            algorithm="pidcomm")
    assert ev.dcn_bytes < hier.dcn_bytes  # 8-bit wire vs fp32 wire
    # opt-in planner candidate
    p = planner.plan(cube_pod, "all_reduce", ("pod", "dp"), 512 * 4,
                     allow_compressed=True)
    assert p.algorithm == "compressed" and p.stage == "cm"
    p0 = planner.plan(cube_pod, "all_reduce", ("pod", "dp"), 512 * 4)
    assert p0.algorithm == "hierarchical"


def test_compressed_all_reduce_custom_vjp_boundary(cube_pod):
    """Gradients flow through the compressed collective (straight-through
    quantizer): d/dx sum(compressed_AR(x)) stays finite and matches the
    uncompressed all-reduce cotangent within quantization tolerance."""
    import jax
    import jax.numpy as jnp
    comm = cube_pod.comm(("pod", "dp"))
    x = substrate.integer_payload(cube_pod, (512,), seed=13)

    def per_shard(v):
        def f(u):
            return jnp.sum(comm.all_reduce(u, algorithm="compressed"))
        return jax.grad(f)(v)

    got = substrate.run_per_shard(cube_pod, per_shard, x)
    # uncompressed convention: grad of sum(psum(x)) per shard is g * ones
    want = np.ones_like(x) * cube_pod.comm(("pod", "dp")).group_size
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=0.5)


def test_compressed_requires_dcn_and_add(cube_ring8):
    with pytest.raises(ValueError, match="DCN-crossing"):
        substrate.run_per_shard(
            cube_ring8,
            lambda v: cube_ring8.comm("d").all_reduce(
                v, algorithm="compressed"),
            np.ones((8, 4), np.float32))


def test_trainer_compress_pod_grads_flag(cube_pod):
    """sync_replicated_grads(compress_pod=True) routes DCN-crossing
    gradient sums through the int8 registry flow (observable in the trace);
    fully-sharded leaves are left untouched."""
    from repro import compat
    if compat.HAS_VMA:
        pytest.skip("vma jax: explicit sync path inactive")
    from repro.runtime.trainer import sync_replicated_grads
    x = substrate.integer_payload(cube_pod, (300,), seed=4)
    specs = {"repl": P(), "sharded": P(("pod", "dp", "tp"))}

    def per_shard(v):
        out = sync_replicated_grads(
            {"repl": v, "sharded": v}, specs, cube_pod, compress_pod=True)
        # the sharded leaf has no replication axes: must come back untouched
        return out["repl"] + 0 * out["sharded"]

    with CommTrace() as tr:
        got = substrate.run_per_shard(cube_pod, per_shard, x)
    assert [e.flow for e in tr.events] == ["compressed"]
    np.testing.assert_allclose(
        got, oracles.all_reduce(x, 3, (0, 1, 2)), rtol=2e-2, atol=0.5)
