"""Overlap-aware program scheduler + cross-program reuse (PR 5 tentpole).

Overlap profile (schema v2):
  * OverlapSample factor math and the median fit;
  * JSON round-trip determinism with the overlap section populated;
  * v1 -> v2 migration (pre-overlap profiles load with an empty overlap
    section) and future-schema rejection with a retune recipe;
  * the fingerprint-mismatch error names both jax versions (the CI matrix
    leg that measured vs the one loading).

Overlap-aware planning (``planner.plan_program``):
  * a fully-measured profile (op models + overlap factors < 1) prices
    ``seconds`` strictly under ``serial_seconds`` with
    ``est_source="measured"`` on the plan itself;
  * overlap factors alone (no op models) mark the plan ``"mixed"``;
  * no profile keeps the analytic model bit-for-bit (order and budget);
  * a synthetic *inverting* overlap profile flips the chosen interleaving
    of a two-op program, with bit-identical execution either way;
  * ``execute_async`` dispatches in exactly the plan's interleaving order.

Cross-program reuse (``repro.core.program`` lower cache):
  * structurally identical programs lower once (observable via
    ``LOWER_STATS``) and execute bit-identically through the cached
    schedule;
  * structure, lowering knobs and installed profile all key the cache;
  * the trainer's repeated grad-sync recordings strictly reduce lowering
    work while ``parallel_check``-style gradient sums stay exact.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import planner
from repro.core import program as program_mod
from repro.core.comm import CommTrace
from repro.testing import oracles, substrate
from repro.testing.substrate import fake_cube
from repro.tuning import (
    CommProfile, LinkModel, OverlapModel, OverlapSample,
    ProfileMismatchError, Tuner, fit_overlap, overlap_key,
    topology_fingerprint)
from repro.tuning import microbench
from repro.tuning import profile as profile_mod


def _per_shard_aval(cube, payload_shape, dtype=jnp.float32):
    shape = (1,) * len(cube.dim_sizes) + tuple(payload_shape)
    return jax.ShapeDtypeStruct(shape, dtype)


def _ov(dom_a, dom_b, sa, sb, pair):
    return OverlapSample(dom_a=dom_a, dom_b=dom_b,
                         primitive_a="all_reduce", primitive_b="all_reduce",
                         bitmap_a="1", bitmap_b="1", nbytes=1 << 20,
                         seconds_a=sa, seconds_b=sb, seconds_pair=pair)


@pytest.fixture(autouse=True)
def _fresh_lower_cache():
    program_mod.clear_lower_cache()
    yield
    program_mod.clear_lower_cache()


# ------------------------------------------------------- overlap profile
def test_overlap_sample_factor_math():
    # pair == max + 0.5*min -> half the smaller op serializes
    assert _ov("ici", "dcn", 1e-3, 2e-3, 2.5e-3).factor() == \
        pytest.approx(0.5)
    # perfect overlap and fully-serial clip to the [0, 1] ends
    assert _ov("ici", "ici", 1e-3, 1e-3, 0.5e-3).factor() == 0.0
    assert _ov("ici", "ici", 1e-3, 1e-3, 5e-3).factor() == 1.0
    models = fit_overlap([_ov("ici", "dcn", 1e-3, 2e-3, 2.5e-3),
                          _ov("ici", "dcn", 1e-3, 2e-3, 2.7e-3),
                          _ov("dcn", "ici", 1e-3, 1e-3, 2e-3)])
    assert set(models) == {"ici->dcn", "dcn->ici"}
    assert models["ici->dcn"].factor == pytest.approx(0.6)  # median of .5/.7
    assert models["ici->dcn"].n == 2
    assert models["dcn->ici"].factor == 1.0
    assert overlap_key("ici", "dcn") == "ici->dcn"


def test_overlap_roundtrip_deterministic(tmp_path):
    ring = fake_cube((8,), ("d",), {"d": 8})
    prof = CommProfile(topology_fingerprint(ring),
                       overlap_samples=[_ov("ici", "ici", 1e-3, 1e-3, 1.5e-3)])
    assert prof.has_overlap
    assert prof.overlap_factor("ici", "ici") == pytest.approx(0.5)
    assert prof.overlap_factor("ici", "dcn") is None
    p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
    prof.save(p1)
    CommProfile.load(p1).save(p2)
    assert p1.read_bytes() == p2.read_bytes()
    re = CommProfile.load(p1, cube=ring)
    assert re.overlap == prof.overlap
    assert re.token() == prof.token()


def test_v1_profile_migrates_with_empty_overlap(tmp_path):
    """Schema bump with migration: a pre-overlap (v1) profile file loads as
    a valid v2 profile whose overlap section is empty -- per-op fits carry
    over, plan_program keeps the analytic interleaving until a retune."""
    ring = fake_cube((8,), ("d",), {"d": 8})
    prof = CommProfile(topology_fingerprint(ring),
                       overlap_samples=[_ov("ici", "ici", 1e-3, 1e-3, 2e-3)])
    path = prof.save(tmp_path / "prof.json")
    data = json.loads(open(path).read())
    del data["overlap"]
    del data["overlap_samples"]
    data["schema_version"] = 1
    with open(path, "w") as f:
        json.dump(data, f)
    old = CommProfile.load(path, cube=ring)
    assert old.overlap == {} and not old.has_overlap
    assert old.fingerprint == prof.fingerprint
    # ... while a future schema is still rejected with the retune recipe
    data["schema_version"] = profile_mod.SCHEMA_VERSION + 1
    with open(path, "w") as f:
        json.dump(data, f)
    with pytest.raises(ProfileMismatchError, match="tune"):
        CommProfile.load(path)


def test_fingerprint_mismatch_names_jax_version():
    """The CI matrix satellite: a profile measured on one jax leg loaded on
    another must say so in the error, not just dump two dicts."""
    ring = fake_cube((8,), ("d",), {"d": 8})
    fp = dict(topology_fingerprint(ring), jax="9.9.9")
    prof = CommProfile(fp)
    with pytest.raises(ProfileMismatchError, match=r"jax 9\.9\.9"):
        prof.check_fingerprint(ring)
    import jax as jax_mod
    with pytest.raises(ProfileMismatchError,
                       match=jax_mod.__version__.replace(".", r"\.")):
        prof.check_fingerprint(ring)


def test_profile_merge_unions_overlap():
    ring = fake_cube((8,), ("d",), {"d": 8})
    fp = topology_fingerprint(ring)
    a = CommProfile(fp, overlap_samples=[_ov("ici", "ici", 1e-3, 1e-3, 2e-3)])
    b = CommProfile(fp, overlap_samples=[
        _ov("ici", "ici", 1e-3, 1e-3, 2e-3),        # exact dup: dropped
        _ov("ici", "dcn", 1e-3, 1e-3, 1e-3)])
    merged = a.merge(b)
    assert len(merged.overlap_samples) == 2
    assert set(merged.overlap) == {"ici->ici", "ici->dcn"}
    assert a.token() != merged.token()


# --------------------------------------------- overlap-aware plan_program
def _pod_link_models():
    lm = LinkModel(alpha=1e-4, beta=1e-9, n=8, r2=1.0)
    return {f"{alg}/{stage}/{dom}": lm
            for alg, stage in (("naive", "naive"), ("direct", "im"),
                               ("direct", "cm"), ("hierarchical", "im"))
            for dom in ("ici", "dcn")}


def _two_op_specs():
    mb = float(1 << 20)
    return [planner.ProgramOpSpec(0, "all_reduce", ("pod", "dp"), mb),
            planner.ProgramOpSpec(1, "all_gather", ("tp",), mb)]


def test_plan_program_measured_overlap_budget():
    """Acceptance: with a profile covering op models AND overlap factors,
    the joint plan's seconds are measured-sourced and strictly under the
    serial bound (factor < 1 leaves real overlap on the table)."""
    pod = fake_cube((2, 2, 2), ("pod", "data", "model"),
                    {"pod": 2, "dp": 2, "tp": 2})
    fp = topology_fingerprint(pod)
    full = CommProfile(fp, models=_pod_link_models(), overlap={
        overlap_key(a, b): OverlapModel(factor=0.25, n=4)
        for a in ("ici", "dcn") for b in ("ici", "dcn")})
    plan = planner.plan_program(pod, _two_op_specs(), profile=full)
    assert plan.est_source == "measured"
    assert all(e.est_source == "measured" for e in plan.estimates.values())
    assert plan.seconds < plan.serial_seconds
    # overlap factors without op models still beat the serial bound but
    # carry "mixed" provenance (ops priced analytic, interleaving measured)
    ov_only = CommProfile(fp, overlap={
        overlap_key(a, b): OverlapModel(factor=0.25, n=4)
        for a in ("ici", "dcn") for b in ("ici", "dcn")})
    plan2 = planner.plan_program(pod, _two_op_specs(), profile=ov_only)
    assert plan2.est_source == "mixed"
    # no profile: analytic provenance, analytic budget formula
    plan3 = planner.plan_program(pod, _two_op_specs())
    assert plan3.est_source == "analytic"


def test_plan_program_without_overlap_is_unchanged():
    """A profile with op models but no overlap section must not perturb the
    analytic interleaving model: same order, same seconds formula, and the
    measured-ops-under-analytic-interleaving gap is visible as "mixed"."""
    pod = fake_cube((2, 2, 2), ("pod", "data", "model"),
                    {"pod": 2, "dp": 2, "tp": 2})
    prof = CommProfile(topology_fingerprint(pod), models=_pod_link_models())
    assert not prof.has_overlap
    p_prof = planner.plan_program(pod, _two_op_specs(), profile=prof)
    p_none = planner.plan_program(pod, _two_op_specs())
    assert p_prof.order == p_none.order
    assert p_prof.levels == p_none.levels
    assert p_prof.est_source == "mixed"


def test_wave_order_never_hides_an_op_twice():
    """Adjacent-pair pricing caps each op's hidden time at its own length:
    a short op flanked by two long same-link neighbours must not be
    subtracted once per neighbour (the two long ops still serialize)."""
    from repro.core.planner import CommEstimate, _wave_order_seconds
    est = {
        0: CommEstimate("all_reduce", "direct", (), 0.0, 1e6, 100e-6),
        1: CommEstimate("all_gather", "direct", (), 1e6, 0.0, 10e-6),
        2: CommEstimate("all_reduce", "direct", (), 0.0, 1e6, 100e-6),
    }
    secs, measured, total_pairs = _wave_order_seconds(
        (0, 1, 2), est, lambda a, b: 0.0)       # perfect overlap everywhere
    assert measured == 2 and total_pairs == 2
    # the 10us op hides once, not twice: 210 - 10 = 200, never 190
    assert secs == pytest.approx(200e-6)


def test_partial_overlap_coverage_is_mixed_not_measured():
    """Plan-level provenance: measured op models + an overlap section that
    does not cover the chosen order's domain pairs must report "mixed" --
    the interleaving budget fell back to the analytic assumption."""
    pod = fake_cube((2, 2, 2), ("pod", "data", "model"),
                    {"pod": 2, "dp": 2, "tp": 2})
    prof = CommProfile(topology_fingerprint(pod), models=_pod_link_models(),
                       overlap={overlap_key("ici", "ici"):
                                OverlapModel(factor=0.25, n=4)})
    # the two-op wave is one dcn + one ici op: its adjacent pair is
    # cross-domain either way, which this profile never measured
    plan = planner.plan_program(pod, _two_op_specs(), profile=prof)
    assert all(e.est_source == "measured" for e in plan.estimates.values())
    assert plan.est_source == "mixed"


def _inverting_overlap_profile(cube):
    """Overlap factors that contradict the analytic assumption: leading
    with the DCN op serializes completely, leading with the ICI op overlaps
    perfectly -- so the cheapest interleaving reverses."""
    return CommProfile(topology_fingerprint(cube), overlap={
        overlap_key("dcn", "ici"): OverlapModel(factor=1.0, n=4),
        overlap_key("ici", "dcn"): OverlapModel(factor=0.0, n=4),
    })


def test_inverting_overlap_flips_interleaving(cube_pod):
    """Tentpole satellite: the same recorded two-op program lowers to the
    DCN-led order analytically and to the ICI-led order under the
    inverting overlap profile, with bit-identical outputs through both
    schedules."""
    ar = cube_pod.comm(("pod",))           # DCN-dominant all_reduce
    ag = cube_pod.comm(("tp",))            # ICI-dominant all_gather

    def record():
        prog = cube_pod.program(name="flip")
        with prog:
            a = prog.input(_per_shard_aval(cube_pod, (2, 8)))
            b = prog.input(_per_shard_aval(cube_pod, (2, 8)))
            prog.output(ar.all_reduce(a), ag.all_gather(b, axis=4))
        return prog

    analytic = record().lower()
    doms = [analytic.plan.estimates[o.op_id].dominant()
            for o in analytic.ops]
    assert doms == ["dcn", "ici"]          # analytic interleave leads DCN
    assert analytic.plan.est_source == "analytic"

    prof = _inverting_overlap_profile(cube_pod)
    with planner.install_profile(prof):
        flipped = record().lower()
    doms = [flipped.plan.estimates[o.op_id].dominant()
            for o in flipped.ops]
    assert doms == ["ici", "dcn"]          # the measured factors flipped it
    assert flipped.plan.est_source == "mixed"
    assert flipped.plan.order != analytic.plan.order

    xa = substrate.integer_payload(cube_pod, (2, 8), seed=11)
    xb = substrate.integer_payload(cube_pod, (2, 8), seed=12)
    from repro.compat import shard_map
    sp = substrate.global_spec(cube_pod, 2)
    out_sp = (sp, sp)

    def run(low):
        fn = jax.jit(shard_map(lambda u, v: low.execute(u, v),
                               mesh=cube_pod.mesh, in_specs=(sp, sp),
                               out_specs=out_sp, check_vma=False))
        return [np.asarray(r) for r in fn(xa, xb)]

    got_a = run(analytic)
    got_f = run(flipped)
    for ga, gf in zip(got_a, got_f):
        np.testing.assert_array_equal(ga, gf)        # bit-identical
    np.testing.assert_array_equal(got_a[0],
                                  oracles.all_reduce(xa, 3, (0,)))
    np.testing.assert_array_equal(got_a[1],
                                  oracles.all_gather(xb, 3, (2,), axis=1))


def test_execute_async_matches_plan_order(cube_pod):
    """The dispatch order of ``execute_async`` (forced via ``outputs()``)
    is exactly ``plan_program``'s interleaving order -- for the analytic
    order and for the overlap-flipped one."""
    ar = cube_pod.comm(("pod",))
    ag = cube_pod.comm(("tp",))

    def record():
        prog = cube_pod.program(name="async-order")
        with prog:
            a = prog.input(_per_shard_aval(cube_pod, (2, 8)))
            b = prog.input(_per_shard_aval(cube_pod, (2, 8)))
            prog.output(ar.all_reduce(a), ag.all_gather(b, axis=4))
        return prog

    xa = substrate.integer_payload(cube_pod, (2, 8), seed=21)
    xb = substrate.integer_payload(cube_pod, (2, 8), seed=22)
    from repro.compat import shard_map
    sp = substrate.global_spec(cube_pod, 2)

    def dispatched(low):
        """primitives in actual dispatch order, per plan-ordered ops."""
        with CommTrace() as tr:
            fn = jax.jit(shard_map(
                lambda u, v: low.execute_async(u, v).outputs(),
                mesh=cube_pod.mesh, in_specs=(sp, sp), out_specs=(sp, sp),
                check_vma=False))
            fn(xa, xb)
        return [e.primitive for e in tr.events]

    analytic = record().lower()
    want = [next(o.primitive for o in analytic.ops if o.op_id == oid)
            for oid in analytic.plan.order]
    assert dispatched(analytic) == want == ["all_reduce", "all_gather"]

    with planner.install_profile(_inverting_overlap_profile(cube_pod)):
        flipped = record().lower()
    want = [next(o.primitive for o in flipped.ops if o.op_id == oid)
            for oid in flipped.plan.order]
    assert dispatched(flipped) == want == ["all_gather", "all_reduce"]


# --------------------------------------------------- cross-program reuse
def _twin_program(cube, n=16):
    comm = cube.comm("1")
    prog = cube.program(name="twin")
    with prog:
        a = prog.input(_per_shard_aval(cube, (2, n)))
        b = prog.input(_per_shard_aval(cube, (2, n)))
        prog.output(comm.all_reduce(a), comm.all_gather(b, axis=2))
    return prog

def test_lower_cache_reuses_identical_structure(cube_ring8):
    s0 = dict(program_mod.LOWER_STATS)
    l1 = _twin_program(cube_ring8).lower()
    l2 = _twin_program(cube_ring8).lower()
    d = {k: program_mod.LOWER_STATS[k] - s0[k]
         for k in program_mod.LOWER_STATS}
    assert d == {"lowered": 1, "cache_hits": 1}
    # the cached schedule is rebound, not shared: each lowered program
    # executes with its own constants/inputs
    assert l2.ops is l1.ops and l2.plan is l1.plan
    assert l2.program is not l1.program

    xa = substrate.integer_payload(cube_ring8, (2, 16), seed=31)
    xb = substrate.integer_payload(cube_ring8, (2, 16), seed=32)
    from repro.compat import shard_map
    sp = substrate.global_spec(cube_ring8, 2)

    def run(low):
        fn = jax.jit(shard_map(lambda u, v: low.execute(u, v),
                               mesh=cube_ring8.mesh, in_specs=(sp, sp),
                               out_specs=(sp, sp), check_vma=False))
        return [np.asarray(r) for r in fn(xa, xb)]

    for g, w in zip(run(l1), run(l2)):
        np.testing.assert_array_equal(g, w)          # bit-identical
    np.testing.assert_array_equal(run(l2)[0],
                                  oracles.all_reduce(xa, 1, (0,)))


def test_lower_cache_keys_structure_knobs_and_profile(cube_ring8):
    s0 = dict(program_mod.LOWER_STATS)
    _twin_program(cube_ring8).lower()
    _twin_program(cube_ring8, n=32).lower()          # different avals
    _twin_program(cube_ring8).lower(fuse=False)      # different knobs
    with planner.install_profile(CommProfile(
            topology_fingerprint(cube_ring8),
            overlap={overlap_key("ici", "ici"): OverlapModel(0.5, 4)})):
        _twin_program(cube_ring8).lower()            # different profile
    _twin_program(cube_ring8).lower(reuse=False)     # opt-out
    d = {k: program_mod.LOWER_STATS[k] - s0[k]
         for k in program_mod.LOWER_STATS}
    assert d == {"lowered": 5, "cache_hits": 0}
    # same structure+knobs+profile again: hit
    _twin_program(cube_ring8).lower()
    assert program_mod.LOWER_STATS["cache_hits"] - s0["cache_hits"] == 1


def test_trainer_grad_sync_reuses_lowered_program(cube_pod):
    """The ROADMAP's named rewrite: repeated grad-sync recordings (one per
    trace) strictly reduce lowering work via the cross-program cache while
    the synced gradients stay exact."""
    from repro import compat
    if compat.HAS_VMA:
        pytest.skip("vma jax: gradient reductions are autodiff-inserted")
    from repro.compat import shard_map
    from repro.runtime.trainer import sync_replicated_grads

    specs = {"a": P(), "b": P(), "sharded": P(("pod", "dp", "tp"))}
    xa = substrate.integer_payload(cube_pod, (6,), seed=41)
    xb = substrate.integer_payload(cube_pod, (2, 5), seed=42)
    xs = substrate.integer_payload(cube_pod, (4,), seed=43)
    sp = [substrate.global_spec(cube_pod, x.ndim - 3) for x in (xa, xb, xs)]

    def run_once():
        def step(a, b, s):
            out = sync_replicated_grads({"a": a, "b": b, "sharded": s},
                                        specs, cube_pod)
            return out["a"], out["b"], out["sharded"]
        fn = jax.jit(shard_map(step, mesh=cube_pod.mesh,
                               in_specs=tuple(sp), out_specs=tuple(sp),
                               check_vma=False))
        return [np.asarray(r) for r in fn(xa, xb, xs)]

    s0 = dict(program_mod.LOWER_STATS)
    first = run_once()
    second = run_once()                  # fresh trace -> fresh recording
    d = {k: program_mod.LOWER_STATS[k] - s0[k]
         for k in program_mod.LOWER_STATS}
    assert d["lowered"] == 1             # one schedule built...
    assert d["cache_hits"] >= 1          # ...every re-trace reuses it
    for g, w in zip(first, second):
        np.testing.assert_array_equal(g, w)
    np.testing.assert_array_equal(
        first[0], oracles.all_reduce(xa, 3, (0, 1, 2)))
    np.testing.assert_array_equal(
        first[1], oracles.all_reduce(xb, 3, (0, 1, 2)))
    np.testing.assert_array_equal(first[2], xs)      # sharded: untouched


# ------------------------------------------------- bench-regression gate
def test_bench_check_against(tmp_path):
    """The CI gate (benchmarks.run --check-against): best measured_us per
    (primitive, flow, nbytes) compared at a noise tolerance; regressions
    fail, improvements and within-tolerance drift pass, dropped coverage
    warns without failing."""
    run_mod = pytest.importorskip("benchmarks.run")

    def write(name, rows):
        path = tmp_path / name
        with open(path, "w") as f:
            json.dump({"rows": rows, "programs": []}, f)
        return str(path)

    def row(prim, flow, nbytes, us):
        return {"primitive": prim, "flow": flow, "nbytes": nbytes,
                "measured_us": us, "stage": "im", "est_us": 1.0,
                "est_source": "analytic"}

    seed = write("seed.json", [row("all_reduce", "im", 1024, 100.0),
                               row("all_reduce", "im", 1024, 90.0),  # dup key
                               row("all_gather", "im", 2048, 50.0)])
    ok = write("ok.json", [row("all_reduce", "im", 1024, 170.0),
                           row("all_gather", "im", 2048, 10.0)])
    assert run_mod.check_against(seed, ok, 2.0) == []
    bad = write("bad.json", [row("all_reduce", "im", 1024, 500.0),
                             row("all_gather", "im", 2048, 50.0)])
    failures = run_mod.check_against(seed, bad, 2.0)
    assert len(failures) == 1 and "all_reduce/im/1024" in failures[0]
    # the tolerance is against the *best* seed row for the key (90, not 100)
    edge = write("edge.json", [row("all_reduce", "im", 1024, 185.0),
                               row("all_gather", "im", 2048, 50.0)])
    assert len(run_mod.check_against(seed, edge, 2.0)) == 1
    # dropped coverage warns (stderr) but does not fail the gate
    sparse = write("sparse.json", [row("all_reduce", "im", 1024, 100.0)])
    assert run_mod.check_against(seed, sparse, 2.0) == []


# ----------------------------------------------------------- live tuning
def test_live_overlap_sweep_and_measured_program_plan(tmp_path, cube_ring8):
    """End to end on the live substrate: tune (with the overlap sweep) ->
    reload -> a multi-op program's joint plan prices its budget from the
    measured models, and the overlap section actually drove the wave
    pricing (seconds <= serial with measured provenance)."""
    samples = microbench.overlap_sweep(cube_ring8, sizes=(16 * 1024,),
                                       reps=2, warmup=1)
    assert [s.dom_a for s in samples] == ["ici"]     # single-domain cube
    assert all(0.0 <= s.factor() <= 1.0 for s in samples)

    tuner = Tuner(cache_dir=tmp_path)
    prof = tuner.tune(cube_ring8, sizes=(8192,),
                      primitives=("all_reduce", "all_gather"),
                      reps=2, warmup=1, overlap_sizes=(8192,))
    assert prof.has_overlap
    reloaded = tuner.load(cube_ring8)                # fingerprint-checked
    assert reloaded.overlap == prof.overlap

    with planner.install_profile(reloaded):
        low = _twin_program(cube_ring8).lower()
    assert low.plan.est_source == "measured"
    assert low.plan.seconds <= low.plan.serial_seconds + 1e-12
    assert "est_source=measured" in low.describe()

    # per-op-only tunes remain possible (partial sweep, no overlap)
    t2 = Tuner(cache_dir=tmp_path / "no-ov")
    p2 = t2.tune(cube_ring8, sizes=(8192,), primitives=("all_reduce",),
                 reps=2, warmup=1, overlap=False)
    assert not p2.has_overlap
