"""End-to-end behaviour tests for the framework (training loop driver,
checkpoint/restart resume, hypothesis property tests on model invariants)."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # optional dev dep: fixed examples instead
    HAVE_HYPOTHESIS = False

from repro.configs import get
from repro.data.pipeline import DataConfig, TokenStream
from repro.launch.mesh import make_mesh
from repro.models.config import ModelConfig
from repro.models.params import init_params
from repro.models.topology import build_topology, build_serve_topology
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainConfig, make_train_step


def _setup(arch="qwen3-1.7b", **tc_kw):
    cfg = get(arch).scaled_for_smoke()
    mesh = make_mesh((1, 1), ("data", "model"))
    topo = build_topology(cfg, mesh)
    tc = TrainConfig(warmup=2, lr=1e-3, **tc_kw)
    params = init_params(cfg, topo, seed=0)
    opt = adamw.init_state(params, tc.adamw)
    return cfg, topo, tc, params, opt


def test_trainer_loop_with_checkpoint_restart():
    from repro.checkpoint.manager import CheckpointManager
    cfg, topo, tc, params, opt = _setup()
    dc = DataConfig(seq_len=32, global_batch=2, vocab_size=cfg.vocab_size)
    stream = TokenStream(cfg, dc)

    def batches(lo, hi):
        for s in range(lo, hi):
            yield {k: jnp.asarray(v)
                   for k, v in stream.global_batch_at(s).items()}

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        tr = Trainer(cfg, topo, tc, checkpointer=mgr)
        p1, o1, h1 = tr.run(params, opt, batches(0, 6), checkpoint_every=3,
                            log_every=0, log=lambda *_: None)
        # simulate failure + restart from latest checkpoint
        step = mgr.latest_step()
        assert step == 6
        p2, o2 = mgr.restore(step, params, opt)
        tr2 = Trainer(cfg, topo, tc, checkpointer=mgr)
        p3, o3, h3 = tr2.run(p2, o2, batches(6, 8), start_step=6,
                             log_every=0, log=lambda *_: None)
        assert np.isfinite(h3[-1]["loss"])


def test_straggler_deadline_counter():
    cfg, topo, tc, params, opt = _setup()
    tc = dataclasses.replace(tc, step_deadline_s=1e-9)  # everything is slow
    dc = DataConfig(seq_len=32, global_batch=2, vocab_size=cfg.vocab_size)
    stream = TokenStream(cfg, dc)
    tr = Trainer(cfg, topo, tc)
    batches = ({k: jnp.asarray(v)
                for k, v in stream.global_batch_at(s).items()}
               for s in range(3))
    tr.run(params, opt, batches, log_every=0, log=lambda *_: None)
    assert tr.slow_steps == 3


def _property_decorator():
    """Randomized under hypothesis; fixed (seed, S) examples without it."""
    if HAVE_HYPOTHESIS:
        def deco(f):
            return settings(max_examples=8, deadline=None)(
                given(st.integers(0, 2**31 - 1),
                      st.sampled_from([16, 32, 48]))(f))
        return deco
    return pytest.mark.parametrize("seed,S", [(0, 16), (1234, 32), (77, 48)])


@_property_decorator()
def test_property_loss_invariant_to_masked_rows(seed, S):
    """Masked (-1) labels never contribute: appending a fully-masked row
    leaves the loss unchanged (vocab-parallel CE invariant)."""
    cfg, topo, tc, params, opt = _setup()
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.models.lm import Model
    from repro.models.params import param_specs
    from repro.runtime.trainer import input_batch_specs
    model = Model(cfg, topo)
    fn = jax.jit(shard_map(
        lambda p, b: model.loss_shard(p, b)[0], mesh=topo.cube.mesh,
        in_specs=(param_specs(cfg, topo), input_batch_specs(cfg, topo)),
        out_specs=P(), check_vma=False))
    rng = np.random.RandomState(seed % 10000)
    toks = rng.randint(0, cfg.vocab_size, (2, S)).astype(np.int32)
    labels = rng.randint(0, cfg.vocab_size, (2, S)).astype(np.int32)
    l1 = float(fn(params, {"tokens": jnp.asarray(toks),
                           "labels": jnp.asarray(labels)}))
    toks2 = np.concatenate([toks, toks[:1]], 0)
    labels2 = np.concatenate([labels, np.full((1, S), -1, np.int32)], 0)
    l2 = float(fn(params, {"tokens": jnp.asarray(toks2),
                           "labels": jnp.asarray(labels2)}))
    assert abs(l1 - l2) < 5e-3, (l1, l2)


def test_serve_topology_geometry():
    """Serve-time maximal model sharding divides every arch's dimensions."""
    from repro.configs import ARCH_IDS
    per_pod = 256
    for arch in ARCH_IDS:
        cfg = get(arch)
        if cfg.n_experts:
            ep = min(cfg.n_experts_padded, per_pod)
            assert per_pod % ep == 0
            assert cfg.d_ff_expert % (per_pod // ep) == 0, arch
        else:
            tp = min(per_pod, cfg.serve_tp or per_pod)
            assert cfg.d_ff % tp == 0, arch
            if cfg.n_heads:
                assert (cfg.n_heads * cfg.head_dim) % tp == 0, arch
