"""Collective-fused kernel tests (repro.kernels.collective).

Contracts, per the package docstring:
  * ``ring_attention`` -- matches the gather-then-attend oracle within the
    *documented* tolerance (``RING_ATTN_TOL``): online-softmax merging of
    the per-hop partials reorders the exp/sum, so bit-identity is
    impossible by construction and the budget is asserted explicitly;
  * ``all_gather_matmul`` (ag_prologue) -- bit-identical to
    compute-after-gather: row-wise maps commute with concatenation;
  * ``matmul_reduce_scatter`` (rs_epilogue) -- bit-identical to
    matmul-then-reduce_scatter on integer-valued fp32 (exact sums);
  * the model call sites (``attn_block`` / ``dense_ffn`` via
    ``ModelConfig.fused_comm``) -- a full forward agrees with the unfused
    pipeline within the propagated ring-attention tolerance.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.kernels.collective import (
    RING_ATTN_TOL, all_gather_matmul, matmul_reduce_scatter, ring_attention)
from repro.models.layers import reference_attention, rms_norm
from repro.testing import substrate


def _run_ring8(cube, fn, *arrays, out_ndim):
    """shard_map ``fn`` over the flat 8-ring: each input is global-layout
    ``(8, *payload)``; ``fn`` sees the payloads (leading shard dim
    stripped) and its output is returned in global layout ``(8, *out)``."""
    from repro.compat import shard_map
    specs = tuple(substrate.global_spec(cube, a.ndim - 1) for a in arrays)
    wrapped = jax.jit(shard_map(
        lambda *vs: fn(*(v[0] for v in vs))[None],
        mesh=cube.mesh, in_specs=specs,
        out_specs=substrate.global_spec(cube, out_ndim),
        check_vma=False))
    return np.asarray(wrapped(*arrays))


# ------------------------------------------------------------ ring attention
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2)])       # MHA + GQA 2:1
@pytest.mark.parametrize("causal,window", [(True, -1), (True, 16),
                                           (False, -1)])
def test_ring_attention_documented_tolerance(cube_ring8, dtype, H, KV,
                                             causal, window):
    """Shard-rotated kv attention vs the full-sequence oracle, asserting
    the documented RING_ATTN_TOL budget for the dtype."""
    import jax.numpy as jnp
    g, B, S_loc, hd = 8, 2, 16, 16
    dt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (g, B, S_loc, H, hd), dt)
    k = jax.random.normal(ks[1], (g, B, S_loc, KV, hd), dt)
    v = jax.random.normal(ks[2], (g, B, S_loc, KV, hd), dt)
    comm = cube_ring8.comm("d")

    got = _run_ring8(
        cube_ring8,
        lambda qi, ki, vi: ring_attention(comm, qi, ki, vi, causal=causal,
                                          window=window),
        np.asarray(q.astype(jnp.float32)).astype(dtype),
        np.asarray(k.astype(jnp.float32)).astype(dtype),
        np.asarray(v.astype(jnp.float32)).astype(dtype),
        out_ndim=4)
    # oracle: concatenate the shard chunks into the global sequence
    to_full = lambda a: jnp.moveaxis(jnp.asarray(np.asarray(
        a.astype(jnp.float32))), 0, 1).reshape(B, g * S_loc, -1, hd)
    want = reference_attention(to_full(q).astype(dt), to_full(k).astype(dt),
                               to_full(v).astype(dt), causal=causal,
                               window=window)
    got_full = np.moveaxis(got, 0, 1).reshape(B, g * S_loc, H, hd)
    np.testing.assert_allclose(got_full.astype(np.float32),
                               np.asarray(want, np.float32),
                               atol=RING_ATTN_TOL[dtype])


# ----------------------------------------------------- matmul comm fusions
def test_all_gather_matmul_bit_identical(cube_ring8):
    """ag_prologue with a row-wise block_fn (norm + up-projection) is
    bitwise equal to gathering first and computing after."""
    comm = cube_ring8.comm("d")
    rng = np.random.RandomState(3)
    x = rng.randn(8, 2, 4, 6).astype(np.float32)
    gamma = rng.randn(6).astype(np.float32)
    wu = rng.randn(6, 5).astype(np.float32)
    block_fn = lambda b: rms_norm(b, gamma, 1e-6) @ wu

    fused = _run_ring8(
        cube_ring8,
        lambda v: all_gather_matmul(comm, v, axis=1, block_fn=block_fn),
        x, out_ndim=3)
    unfused = _run_ring8(
        cube_ring8,
        lambda v: block_fn(comm.all_gather(v, axis=1)),
        x, out_ndim=3)
    np.testing.assert_array_equal(fused, unfused)


@pytest.mark.parametrize("op", ["add", "min"])
def test_matmul_reduce_scatter_bit_identical(cube_ring8, op):
    """rs_epilogue on integer-valued fp32: the lazy-tile ring epilogue is
    bitwise equal to materializing h @ w and reduce-scattering it."""
    comm = cube_ring8.comm("d")
    h = substrate.integer_payload(cube_ring8, (16, 4), seed=5)  # (8, 16, 4)
    w = np.random.RandomState(5).randint(-3, 4, (4, 6)).astype(np.float32)

    fused = _run_ring8(
        cube_ring8,
        lambda v: matmul_reduce_scatter(comm, v, w, axis=0, op=op),
        h, out_ndim=2)
    unfused = _run_ring8(
        cube_ring8,
        lambda v: comm.reduce_scatter(v @ w, axis=0, op=op),
        h, out_ndim=2)
    np.testing.assert_array_equal(fused, unfused)


def test_matmul_reduce_scatter_rejects_indivisible(cube_ring8):
    comm = cube_ring8.comm("d")
    with pytest.raises(ValueError, match="not divisible"):
        _run_ring8(cube_ring8,
                   lambda v: matmul_reduce_scatter(comm, v, np.eye(
                       4, dtype=np.float32), axis=0),
                   np.zeros((8, 12, 4), np.float32), out_ndim=2)


# ------------------------------------------------------- model call sites
def test_fused_comm_model_forward_matches_unfused():
    """ModelConfig.fused_comm reroutes attn_block/dense_ffn through the
    fused kernels (ring attention over cp, gather-prologue / scatter-
    epilogue over tp); a full forward agrees with the unfused pipeline
    within the propagated ring-attention tolerance."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.configs import get
    from repro.launch.mesh import make_mesh
    from repro.models.lm import Model
    from repro.models.params import init_params, param_specs
    from repro.models.topology import build_topology
    from repro.runtime.trainer import input_batch_specs
    from tests.test_models import make_batch

    substrate.ensure_virtual_devices(8)
    cfg = dataclasses.replace(get("qwen3_1_7b").scaled_for_smoke(), tp=2)
    mesh = make_mesh((2, 4), ("data", "model"))
    # global_batch 2 < data capacity 4: the surplus becomes cp=2, so the
    # fused path exercises ring attention, not just the matmul fusions
    topo = build_topology(cfg, mesh, global_batch=2)
    assert topo.cp and topo.tp
    params = init_params(cfg, topo, seed=0)
    batch = make_batch(cfg, B=2, S=32)

    def logits_for(c):
        model = Model(c, topo)
        fwd = jax.jit(shard_map(
            model.forward_logits, mesh=topo.cube.mesh,
            in_specs=(param_specs(c, topo), input_batch_specs(c, topo)),
            out_specs=P(topo.dp, None, topo.tp), check_vma=False))
        return np.asarray(fwd(params, batch), np.float32)

    base = logits_for(cfg)
    fused = logits_for(dataclasses.replace(cfg, fused_comm=True))
    assert base.shape == fused.shape
    assert np.isfinite(fused).all()
    # the model runs bf16 activations, so the budget is the bf16 ring
    # tolerance (one bf16 ulp of re-rounding per merged partial), amplified
    # by the layer stack / logit projection
    np.testing.assert_allclose(fused, base, atol=RING_ATTN_TOL["bfloat16"])
