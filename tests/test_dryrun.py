"""Dry-run analysis utilities: the planner/runtime drift cross-check
(ROADMAP open item) -- the recorded ``comm_trace`` of a cell must agree
with the HLO-parsed ``collectives`` section of the same compiled module."""
import numpy as np
import pytest

from repro.launch.dryrun import comm_drift, parse_collectives


def _summary(by_flow, ici=1e6, dcn=0.0):
    return {"events": len(by_flow), "ici_bytes": ici, "dcn_bytes": dcn,
            "by_flow": {k: {"count": 1} for k in by_flow}}


def test_comm_drift_clean_cell():
    """Planned hierarchical all-reduce + FSDP all-gather, and the compiled
    module contains reduce-scatter/all-gather/all-reduce: no drift."""
    summary = _summary(["all_reduce/hierarchical", "all_gather/im"],
                       ici=1e6, dcn=1e5)
    collectives = {"reduce-scatter": {"count": 2, "result_bytes": 500_000},
                   "all-gather": {"count": 4, "result_bytes": 900_000},
                   "all-reduce": {"count": 1, "result_bytes": 200_000}}
    rep = comm_drift(summary, collectives)
    assert not rep["drift"]
    assert rep["missing_ops"] == []
    assert rep["hlo_over_trace_bytes"] == pytest.approx(1.6 / 1.1, rel=1e-6)


def test_comm_drift_flags_missing_schedule():
    """The planner recorded the hierarchical split but the compiled module
    only has a flat all-reduce: the reduce-scatter/all-gather hops are
    missing -> drift."""
    summary = _summary(["all_reduce/hierarchical"], ici=1e6, dcn=1e5)
    collectives = {"all-reduce": {"count": 1, "result_bytes": 1_100_000}}
    rep = comm_drift(summary, collectives)
    assert rep["drift"]
    assert rep["missing_ops"] == ["all-gather", "reduce-scatter"]


def test_comm_drift_flags_empty_hlo_and_underrun():
    """Traced communication with zero compiled collectives (or well under
    the planned volume) is drift; rooted-only traces are exempt."""
    rep = comm_drift(_summary(["all_reduce/im"]), {})
    assert rep["drift"] and rep["hlo_over_trace_bytes"] == 0.0
    # compiled wire bytes far below plan -> over-estimation drift
    rep = comm_drift(_summary(["all_reduce/im"], ici=1e6),
                     {"all-reduce": {"count": 1, "result_bytes": 1000}})
    assert rep["drift"] and rep["hlo_over_trace_bytes"] < 0.5
    # rooted primitives leave no collective ops: nothing to check
    rep = comm_drift(_summary(["scatter/im", "gather/im"]), {})
    assert not rep["drift"] and rep["checked_flows"] == []


def test_comm_drift_on_live_lowering(cube_pod):
    """End-to-end: trace + compile a pod-crossing all-reduce on the 8-device
    substrate and run the cross-check on the real HLO."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core.comm import CommTrace

    comm = cube_pod.comm(("pod", "dp"))
    spec = P(*cube_pod.dim_names, None)
    with CommTrace() as trace:
        compiled = jax.jit(shard_map(
            lambda v: comm.all_reduce(v), mesh=cube_pod.mesh,
            in_specs=spec, out_specs=spec, check_vma=False)).lower(
                jax.ShapeDtypeStruct((2, 2, 2, 4096), jnp.float32)).compile()
    summary = trace.summary()
    assert "all_reduce/hierarchical" in summary["by_flow"]
    collectives = parse_collectives(compiled.as_text())
    rep = comm_drift(summary, collectives)
    assert rep["missing_ops"] == []
    assert not rep["drift"]
