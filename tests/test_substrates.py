"""Substrate tests: data pipeline determinism/disjointness, checkpoint
roundtrip + elastic restore, 8-bit optimizer fidelity, int8 compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core.compress import quantize_int8, dequantize_int8
from repro.data.pipeline import DataConfig, TokenStream
from repro.optim import adamw


def test_data_determinism_and_disjointness():
    cfg = get("qwen3-1.7b").scaled_for_smoke()
    dc = DataConfig(seq_len=64, global_batch=8, vocab_size=cfg.vocab_size,
                    seed=3)
    s = TokenStream(cfg, dc)
    a = s.global_batch_at(7)
    b = s.global_batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = s.global_batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shard slicing reconstructs the global batch exactly (disjoint cover)
    parts = [s.shard_batch_at(7, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), a["tokens"])
    # elastic re-sharding: different shard count, same global stream
    parts2 = [s.shard_batch_at(7, i, 2)["tokens"] for i in range(2)]
    np.testing.assert_array_equal(np.concatenate(parts2, 0), a["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < cfg.vocab_size
    assert (a["labels"] == -1).any()      # document-break masking exists


def test_checkpoint_roundtrip_and_gc():
    from repro.checkpoint.manager import CheckpointManager
    params = {"w": jnp.arange(12.0).reshape(3, 4),
              "b": {"x": jnp.ones((5,))}}
    opt = {"m": jnp.zeros((3, 4)), "step": jnp.int32(7)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=True, keep_last=2)
        for step in (10, 20, 30):
            mgr.save(step, params, opt)
        mgr.wait()
        assert mgr.all_steps() == [20, 30]          # gc kept last 2
        p2, o2 = mgr.restore(30, params, opt)
        np.testing.assert_array_equal(np.asarray(p2["w"]),
                                      np.asarray(params["w"]))
        assert int(o2["step"]) == 7


def test_adamw_8bit_tracks_fp32():
    key = jax.random.PRNGKey(0)
    p0 = {"w": jax.random.normal(key, (64, 64)) * 0.1}
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (64, 64)) * 0.01}
    cfg8 = adamw.AdamWConfig(use_8bit=True)
    cfg32 = adamw.AdamWConfig(use_8bit=False)
    p8, s8 = dict(p0), adamw.init_state(p0, cfg8)
    p32, s32 = dict(p0), adamw.init_state(p0, cfg32)
    for i in range(20):
        p8, s8 = adamw.update(p8, s8, g, lr=1e-3, cfg=cfg8)
        p32, s32 = adamw.update(p32, s32, g, lr=1e-3, cfg=cfg32)
    d = np.abs(np.asarray(p8["w"]) - np.asarray(p32["w"])).max()
    step_sz = np.abs(np.asarray(p32["w"]) - np.asarray(p0["w"])).max()
    assert d < 0.15 * step_sz, (d, step_sz)   # tracks within 15% of motion


def test_int8_compression_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(2), (1000,)) * 3.0
    q, s = quantize_int8(x, block=128)
    y = dequantize_int8(q, s, x.shape, x.size)
    err = np.abs(np.asarray(x - y))
    scale = np.abs(np.asarray(x)).max()
    assert err.max() < scale / 100       # <1% of absmax per block


def test_cosine_schedule_shape():
    lr = adamw.cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(100)) < 1e-5
