"""Parallel-consistency oracle: the sharded (FSDP+TP+EP, manual-SPMD) loss
and gradients must match a single-device run of the same tiny config.

Run in a subprocess: python tests/parallel_check.py
Prints ALL-OK on success.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

import repro.models.params as params_mod
import repro.models.blocks as blocks_mod
import repro.models.lm as lm_mod

# Run the oracle in fp32 so any mismatch is structural, not rounding.
params_mod.COMPUTE_DTYPE = jnp.float32
blocks_mod.COMPUTE_DTYPE = jnp.float32
lm_mod.COMPUTE_DTYPE = jnp.float32

# The MoE load-balance aux loss is computed per dispatch group (standard
# practice at scale); it legitimately differs from the single-device global
# value, so the strict consistency check runs on the CE loss alone.
lm_mod.AUX_COEF = 0.0

from repro import compat
from repro.configs import get
from repro.launch.mesh import make_mesh
from repro.models.lm import Model
from repro.models.params import init_params, param_specs
from repro.models.topology import build_topology
from repro.runtime.overlap import with_backward_bucket_sync
from repro.runtime.trainer import input_batch_specs, sync_replicated_grads

TOL = dict(rtol=5e-2, atol=5e-3)


def grads_fn(cfg, topo):
    model = Model(cfg, topo)
    specs = param_specs(cfg, topo)

    def f(params, batch):
        # vma-aware autodiff inserts every needed gradient reduction; on
        # pre-vma jax sync_replicated_grads adds the same psums explicitly
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_shard, has_aux=True)(params, batch)
        grads = sync_replicated_grads(grads, specs, topo.cube)
        return loss, grads

    bspecs = input_batch_specs(cfg, topo)
    return jax.jit(shard_map(
        f, mesh=topo.cube.mesh, in_specs=(specs, bspecs),
        out_specs=(P(), specs), check_vma=True))


def overlapped_grads_fn(cfg, topo):
    """Backward-overlapped sync: reverse-layer bucket programs fire inside
    backward via custom_vjp hooks (repro.runtime.overlap).  Must produce
    grads bit-identical to the barrier path above."""
    model = Model(cfg, topo)
    specs = param_specs(cfg, topo)
    hooked = with_backward_bucket_sync(model.loss_shard, specs, topo.cube)

    def f(params, batch):
        (_, _), grads = jax.value_and_grad(hooked, has_aux=True)(
            params, batch)
        return grads

    bspecs = input_batch_specs(cfg, topo)
    return jax.jit(shard_map(
        f, mesh=topo.cube.mesh, in_specs=(specs, bspecs),
        out_specs=specs, check_vma=False))


def make_batch(cfg, rng, B=4, S=32):
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.frontend == "patch":
        batch["patches"] = jnp.asarray(
            rng.randn(B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.randn(B, S, cfg.frontend_dim), jnp.float32)
    return batch


CASES = {
    # arch -> parallelism override for the 8-device mesh (2 pods x 2 x 2)
    "qwen3-1.7b": dict(tp=2),
    "gemma3-1b": dict(tp=2),
    "mixtral-8x7b": dict(ep=2, etp=2, tp=4, capacity_factor=8.0),
    "qwen2-moe-a2.7b": dict(ep=2, etp=1, tp=2, capacity_factor=8.0),
    "rwkv6-7b": dict(tp=2),
    "jamba-1.5-large-398b": dict(ep=2, etp=1, tp=2, capacity_factor=8.0),
    "whisper-base": dict(tp=2),
    "llava-next-34b": dict(tp=2),
    "internlm2-20b": dict(tp=2),
    "phi3-mini-3.8b": dict(tp=2),
}


def run_case(arch, overrides):
    cfg = dataclasses.replace(get(arch).scaled_for_smoke(), **overrides)
    rng = np.random.RandomState(7)
    batch = make_batch(cfg, rng)

    # reference: single device (every hypercube dim = 1)
    ref_cfg = dataclasses.replace(cfg, tp=1, ep=1, etp=1)
    mesh1 = make_mesh((1, 1), ("data", "model"))
    topo1 = build_topology(ref_cfg, mesh1)
    params = init_params(ref_cfg, topo1, seed=3)
    loss1, g1 = grads_fn(ref_cfg, topo1)(params, batch)

    # sharded: multi-pod mesh (pod=2, data=2, model=2); model axes per case
    mesh8 = make_mesh((2, 2, 2), ("pod", "data", "model"))
    topo8 = build_topology(cfg, mesh8)
    fn8 = grads_fn(cfg, topo8)
    loss8, g8 = fn8(params, batch)
    np.testing.assert_allclose(np.asarray(loss8), np.asarray(loss1), **TOL)

    flat1, tdef = jax.tree.flatten(jax.device_get(g1))
    flat8 = list(map(np.asarray, tdef.flatten_up_to(jax.device_get(g8))))
    worst = 0.0
    for a, b in zip(flat1, flat8):
        denom = np.maximum(np.abs(a).max(), 1e-3)
        worst = max(worst, float(np.abs(a - b).max() / denom))
    assert worst < 5e-3, f"{arch}: worst rel grad diff {worst}"

    # backward-overlapped sync must be *bit-identical* to the barrier sync
    # (on vma jax the hook path is inert -- autodiff already interleaves
    # the reductions -- so there is nothing distinct to compare)
    note = ""
    if not compat.HAS_VMA:
        g_ov = overlapped_grads_fn(cfg, topo8)(params, batch)
        flat_ov = list(map(np.asarray,
                           tdef.flatten_up_to(jax.device_get(g_ov))))
        for b, o in zip(flat8, flat_ov):
            np.testing.assert_array_equal(b, o, err_msg=(
                f"{arch}: overlapped grad sync diverged from barrier sync"))
        note = " overlap-sync=bit-identical"
    print(f"ok: {arch} loss={float(loss1):.4f} "
          f"worst-rel-grad-diff={worst:.4f}{note}")


def main():
    for arch, ov in CASES.items():
        run_case(arch, ov)
    print("ALL-OK")


if __name__ == "__main__":
    main()
