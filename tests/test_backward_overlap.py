"""Backward-overlapped gradient sync + satellites of the same PR:

* reverse-layer bucketing and in-backward hook dispatch order
  (:mod:`repro.runtime.overlap`), bit-identical to the barrier sync;
* double-buffered ``execute_async`` bucket staging;
* futures resolved through rewrite provenance
  (:meth:`ProgramExecution.future_for` through the rs+ag peephole and
  through coalescing);
* inter-wave overlap pricing in :func:`planner.plan_program`;
* the ``Trainer.run`` step-timing fix (block before reading the clock);
* the bench-gate absolute floor (zero-seed rows must not fire on noise).
"""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import shard_map
from repro.core.comm import CommTrace
from repro.runtime.overlap import (
    bucket_leaf_indices, sync_replicated_grads_overlapped,
    with_backward_bucket_sync)
from repro.runtime.trainer import replication_dims, sync_replicated_grads
from repro.testing import substrate

pre_vma = pytest.mark.skipif(
    compat.HAS_VMA, reason="vma jax: autodiff inserts the grad reductions; "
    "the explicit overlapped sync path is inert")


# ------------------------------------------------------------- bucketing
def test_bucket_leaf_indices_reverse_layer_order():
    """Bucket 0 is the loss head (first grads out of backward), the last
    bucket is the embeddings (last grads out); unknown groups ride with
    the trunk."""
    params = {
        "embed": jnp.zeros((4, 2)),
        "final_norm": jnp.zeros((2,)),
        "lm_head": jnp.zeros((2, 4)),
        "units": {"b": jnp.zeros((3,)), "w": jnp.zeros((3, 3))},
    }
    flat, _ = jax.tree.flatten(params)
    # flatten order: embed=0, final_norm=1, lm_head=2, units.b=3, units.w=4
    assert bucket_leaf_indices(params) == [[1, 2], [3, 4], [0]]

    # unknown top-level keys land in the trunk bucket
    assert bucket_leaf_indices({"mystery": jnp.zeros(2),
                                "lm_head": jnp.zeros(2)}) == [[0], [1]]


def _toy_setup(cube):
    """Toy param tree on the pod cube: embed fully sharded (no sync),
    units sharded over tp only, head/norm fully replicated."""
    params = {
        "embed": jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4),
        "final_norm": jnp.arange(4, dtype=jnp.float32),
        "lm_head": jnp.arange(4 * 2, dtype=jnp.float32).reshape(4, 2),
        "units": {"b": jnp.arange(2, dtype=jnp.float32),
                  "w": jnp.arange(2 * 4, dtype=jnp.float32).reshape(2, 4)},
    }
    d = cube.dim_names                      # ("pod", "dp", "tp")
    specs = {
        "embed": P(d, None),
        "final_norm": P(),
        "lm_head": P(None, None),
        "units": {"b": P(d[-1]), "w": P(d[-1], None)},
    }
    return params, specs


def _loss(params, batch):
    # consume param groups in forward-production order (embed -> trunk ->
    # head), like a real model: backward then reaches the head grads first
    h = jnp.sum(jnp.square(params["embed"])) + 0.0 * batch.sum()
    h = h + jnp.sum(jnp.square(params["units"]["w"]))
    h = h + jnp.sum(jnp.square(params["units"]["b"]))
    h = h + jnp.sum(jnp.square(params["final_norm"]))
    h = h + jnp.sum(jnp.square(params["lm_head"]))
    return h, {}


@pre_vma
def test_hooked_backward_sync_bit_identical_and_ordered(cube_pod):
    """The custom_vjp hook path produces grads bit-identical to the
    barrier sync, and its bucket programs are dispatched in reverse-layer
    order during backward (head bucket first)."""
    cube = cube_pod
    params, specs = _toy_setup(cube)
    batch = jnp.ones((4,), jnp.float32)
    hooked = with_backward_bucket_sync(_loss, specs, cube)

    def f_barrier(p, b):
        (_, _), g = jax.value_and_grad(_loss, has_aux=True)(p, b)
        return sync_replicated_grads(g, specs, cube)

    def f_hooked(p, b):
        (_, _), g = jax.value_and_grad(hooked, has_aux=True)(p, b)
        return g

    in_specs = (specs, P())
    with CommTrace() as tr:
        gh = jax.jit(shard_map(f_hooked, mesh=cube.mesh, in_specs=in_specs,
                               out_specs=specs, check_vma=False)
                     )(params, batch)
    gb = jax.jit(shard_map(f_barrier, mesh=cube.mesh, in_specs=in_specs,
                           out_specs=specs, check_vma=False))(params, batch)

    fa, tdef = jax.tree.flatten(jax.device_get(gb))
    fb = tdef.flatten_up_to(jax.device_get(gh))
    for a, b in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # dispatch order: all of bucket 0's events (head) strictly before
    # bucket 1's (trunk); the fully-sharded embed bucket records nothing
    pids = [e.program_id for e in tr.events
            if e.program_id and e.program_id.startswith("grad-sync-b")]
    assert pids, "hook path recorded no bucket programs"
    assert set(pids) == {"grad-sync-b0", "grad-sync-b1"}
    assert pids == sorted(pids), f"bucket dispatch out of order: {pids}"


@pre_vma
def test_post_backward_bucketed_dispatch_order_and_identity(cube_pod):
    """sync_replicated_grads_overlapped (the no-hook fallback) dispatches
    its per-bucket execute_async programs in reverse-layer bucket order
    and matches the barrier sync bit-for-bit."""
    cube = cube_pod
    params, specs = _toy_setup(cube)

    def f_overlapped(p):
        return sync_replicated_grads_overlapped(p, specs, cube)

    def f_barrier(p):
        return sync_replicated_grads(p, specs, cube)

    with CommTrace() as tr:
        go = jax.jit(shard_map(f_overlapped, mesh=cube.mesh,
                               in_specs=(specs,), out_specs=specs,
                               check_vma=False))(params)
    gb = jax.jit(shard_map(f_barrier, mesh=cube.mesh, in_specs=(specs,),
                           out_specs=specs, check_vma=False))(params)

    fa, tdef = jax.tree.flatten(jax.device_get(gb))
    fb = tdef.flatten_up_to(jax.device_get(go))
    for a, b in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    pids = [e.program_id for e in tr.events
            if e.program_id and e.program_id.startswith("grad-sync-b")]
    assert pids == sorted(pids), f"bucket dispatch out of order: {pids}"
    assert len(set(pids)) >= 2


# ------------------------------------------- futures through rewrites
def _per_shard_aval(cube, payload_shape, dtype=jnp.float32):
    shape = (1,) * len(cube.dim_sizes) + tuple(payload_shape)
    return jax.ShapeDtypeStruct(shape, dtype)


def test_future_for_resolves_through_rs_ag_fusion(cube_ring8):
    """A caller holding the recorded reduce_scatter (or all_gather) of a
    fused rs+ag pair still gets a resolvable future: it maps through
    fused_from provenance to the fused all_reduce's result."""
    from repro.testing import oracles
    comm = cube_ring8.comm("1")
    prog = cube_ring8.program()
    with prog:
        a = prog.input(_per_shard_aval(cube_ring8, (2, 16)))
        rs = comm.reduce_scatter(a, axis=2)
        ag = comm.all_gather(rs, axis=2)
        prog.output(ag)
    low = prog.lower()                       # rs+ag -> one all_reduce
    assert len(low.ops) == 1 and low.ops[0].primitive == "all_reduce"
    x = substrate.integer_payload(cube_ring8, (2, 16), seed=8)

    def per_shard(v):
        ex = low.execute_async(v)
        f_rs = ex.future_for(rs)             # recorded op eaten by fusion
        f_ag = ex.future_for(ag)
        f_id = ex.future_for(1)              # same, by recorded op id
        assert f_rs.op is low.ops[0] and f_ag.op is low.ops[0]
        out = f_rs.result()
        assert f_ag.done() and f_id.done()
        return out

    got = substrate.run_per_shard(cube_ring8, per_shard, x)
    np.testing.assert_array_equal(got, oracles.all_reduce(x, 1, (0,)))


def test_future_for_coalesced_member_returns_own_value(cube_ring8):
    """future_for on one leaf of a coalesced bucket returns exactly that
    leaf's synced value (out_vids subsetting), not the whole bucket."""
    comm = cube_ring8.comm("1")
    prog = cube_ring8.program()
    with prog:
        a = prog.input(_per_shard_aval(cube_ring8, (2, 4)))
        b = prog.input(_per_shard_aval(cube_ring8, (2, 4)))
        ra = comm.all_reduce(a)
        rb = comm.all_reduce(b)
        prog.output(ra, rb)
    low = prog.lower()
    assert len(low.ops) == 1 and low.ops[0].coalesced
    x = substrate.integer_payload(cube_ring8, (2, 4), seed=1)
    y = substrate.integer_payload(cube_ring8, (2, 4), seed=2)

    def per_shard(va, vb):
        ex = low.execute_async(va, vb)
        out_b = ex.future_for(rb).result()   # just rb's leaf
        assert out_b.shape == vb.shape
        return out_b

    from repro.compat import shard_map as smap
    sp = substrate.global_spec(cube_ring8, 2)
    got = jax.jit(smap(per_shard, mesh=cube_ring8.mesh, in_specs=(sp, sp),
                       out_specs=sp, check_vma=False))(x, y)
    from repro.testing import oracles
    np.testing.assert_array_equal(np.asarray(got),
                                  oracles.all_reduce(y, 1, (0,)))


def test_future_for_rejects_foreign_and_unknown_handles(cube_ring8):
    comm = cube_ring8.comm("1")
    prog = cube_ring8.program()
    with prog:
        a = prog.input(_per_shard_aval(cube_ring8, (2, 4)))
        ra = comm.all_reduce(a)
        prog.output(ra)
    other = cube_ring8.program()
    with other:
        oa = other.input(_per_shard_aval(cube_ring8, (2, 4)))
        ob = comm.all_reduce(oa)
        other.output(ob)
    low = prog.lower()
    x = substrate.integer_payload(cube_ring8, (2, 4), seed=3)

    def per_shard(v):
        ex = low.execute_async(v)
        with pytest.raises(ValueError, match="belongs to"):
            ex.future_for(ob)
        with pytest.raises(KeyError, match="no recorded op"):
            ex.future_for(7)
        return ex.outputs()

    substrate.run_per_shard(cube_ring8, per_shard, x)


def test_stage_prebuilds_coalesced_payload(cube_ring8):
    """stage() concatenates a coalesced bucket's payload ahead of the wire
    op; force() consumes the staged payload and the result is unchanged."""
    comm = cube_ring8.comm("1")
    prog = cube_ring8.program()
    with prog:
        a = prog.input(_per_shard_aval(cube_ring8, (2, 4)))
        b = prog.input(_per_shard_aval(cube_ring8, (2, 4)))
        prog.output(comm.all_reduce(a), comm.all_reduce(b))
    low = prog.lower()
    assert low.ops[0].coalesced
    x = substrate.integer_payload(cube_ring8, (2, 4), seed=4)
    y = substrate.integer_payload(cube_ring8, (2, 4), seed=5)

    def per_shard(va, vb):
        ex = low.execute_async(va, vb).stage()
        assert set(ex._staged) == {low.ops[0].op_id}
        outs = ex.outputs()
        assert not ex._staged                # consumed, not re-concatenated
        return outs[0]

    from repro.compat import shard_map as smap
    sp = substrate.global_spec(cube_ring8, 2)
    got = jax.jit(smap(per_shard, mesh=cube_ring8.mesh, in_specs=(sp, sp),
                       out_specs=sp, check_vma=False))(x, y)
    from repro.testing import oracles
    np.testing.assert_array_equal(np.asarray(got),
                                  oracles.all_reduce(x, 1, (0,)))


# ------------------------------------------------- inter-wave planning
def _pod_fake():
    return substrate.fake_cube((2, 2, 2), ("pod", "data", "model"),
                               {"pod": 2, "dp": 2, "tp": 2})


def _profile(cube, factor):
    from repro.tuning import (
        CommProfile, LinkModel, OverlapModel, overlap_key,
        topology_fingerprint)
    lm = LinkModel(alpha=1e-4, beta=1e-9, n=8, r2=1.0)
    models = {f"{alg}/{stage}/{dom}": lm
              for alg, stage in (("naive", "naive"), ("direct", "im"),
                                 ("direct", "cm"), ("hierarchical", "im"))
              for dom in ("ici", "dcn")}
    overlap = {overlap_key(a, b): OverlapModel(factor=factor, n=4)
               for a in ("ici", "dcn") for b in ("ici", "dcn")}
    return CommProfile(topology_fingerprint(cube), models=models,
                       overlap=overlap)


def _two_wave_ops(head_deps=(0,)):
    from repro.core import planner
    mb = float(1 << 20)
    return [
        planner.ProgramOpSpec(0, "all_reduce", ("pod", "dp"), mb),
        planner.ProgramOpSpec(1, "all_gather", ("tp",), mb),
        planner.ProgramOpSpec(2, "all_gather", ("tp",), mb,
                              deps=head_deps),
    ]


def test_inter_wave_boundary_discount_under_measured_factors():
    """With measured serialization factors, the wave-boundary pair earns
    an overlap credit when the next wave's head does not depend on the
    previous wave's tail -- the budget drops strictly below the
    no-discount (factor=1.0) budget, provenance stays measured."""
    from repro.core import planner
    cube = _pod_fake()
    ops = _two_wave_ops(head_deps=(0,))      # head dep != chosen tail
    p_discount = planner.plan_program(cube, ops,
                                      profile=_profile(cube, 0.25))
    p_serial = planner.plan_program(cube, ops,
                                    profile=_profile(cube, 1.0))
    assert p_discount.est_source == "measured"
    assert p_serial.est_source == "measured"
    assert p_discount.seconds < p_serial.seconds
    assert p_discount.serial_seconds == p_serial.serial_seconds
    # discounting never reorders waves or drops ops
    assert p_discount.levels == p_serial.levels


def test_inter_wave_no_credit_when_head_depends_on_tail():
    """A wave-2 op that depends on every wave-1 op cannot overlap the
    boundary: the program's budget is exactly wave-1's (intra-discounted)
    budget plus the standalone wave-2 budget."""
    from repro.core import planner
    cube = _pod_fake()
    prof = _profile(cube, 0.25)
    free = planner.plan_program(cube, _two_wave_ops(head_deps=(0,)),
                                profile=prof)
    chained = planner.plan_program(cube, _two_wave_ops(head_deps=(0, 1)),
                                   profile=prof)
    assert chained.seconds > free.seconds    # the boundary credit is lost
    wave0 = planner.plan_program(cube, _two_wave_ops()[:2], profile=prof)
    solo = planner.plan_program(
        cube, [planner.ProgramOpSpec(2, "all_gather", ("tp",),
                                     float(1 << 20))], profile=prof)
    assert chained.seconds == wave0.seconds + solo.seconds
    assert chained.est_source == "measured"


def test_inter_wave_unmeasured_boundary_is_mixed():
    """An overlappable wave boundary whose ordered domain pair the profile
    never measured counts as an unmeasured pair: the plan demotes to
    "mixed" even though every op and intra-wave pair is measured -- and
    covering the boundary pair restores full provenance."""
    from repro.core import planner
    from repro.tuning import OverlapModel, overlap_key
    cube = _pod_fake()
    mb = float(1 << 20)
    prof = _profile(cube, 0.25)
    prof.overlap.clear()
    prof.overlap[overlap_key("ici", "ici")] = OverlapModel(0.25, 4)
    prof.overlap[overlap_key("dcn", "dcn")] = OverlapModel(0.25, 4)
    ops = [  # wave0: two ici ops; wave1: two dcn ops, ici->dcn boundary
        planner.ProgramOpSpec(0, "all_gather", ("tp",), mb),
        planner.ProgramOpSpec(1, "all_gather", ("tp",), mb),
        planner.ProgramOpSpec(2, "all_reduce", ("pod", "dp"), mb,
                              deps=(0,)),
        planner.ProgramOpSpec(3, "all_reduce", ("pod", "dp"), mb,
                              deps=(0,)),
    ]
    p = planner.plan_program(cube, ops, profile=prof)
    assert p.est_source == "mixed"
    prof.overlap[overlap_key("ici", "dcn")] = OverlapModel(0.25, 4)
    p_full = planner.plan_program(cube, ops, profile=prof)
    assert p_full.est_source == "measured"
    assert p_full.seconds < p.seconds        # the boundary now discounts


def test_multi_wave_analytic_budget_unchanged():
    """Without a profile the multi-wave budget is exactly the sum of the
    standalone per-wave analytic budgets -- the inter-wave machinery must
    be invisible on the analytic path."""
    from repro.core import planner
    cube = _pod_fake()
    ops = _two_wave_ops(head_deps=(0,))
    p = planner.plan_program(cube, ops)
    assert p.est_source == "analytic"
    wave0 = planner.plan_program(cube, ops[:2])
    wave1 = planner.plan_program(
        cube, [planner.ProgramOpSpec(2, "all_gather", ("tp",),
                                     float(1 << 20))])
    assert p.seconds == wave0.seconds + wave1.seconds


# ------------------------------------------------ trainer step timing
def test_step_deadline_sees_async_dispatched_compute():
    """Regression for the step-timing bug: Trainer.run must block on the
    step's real outputs (params/opt_state) before reading the clock.  A
    step whose metrics are ready immediately but whose param update is an
    async-dispatched expensive computation must still trip the deadline."""
    from repro.runtime.trainer import Trainer, TrainConfig

    n = 800
    x = jnp.ones((n, n), jnp.float32)

    @jax.jit
    def expensive(v):
        for _ in range(20):
            v = jnp.tanh(v @ v) / n
        return v

    jax.block_until_ready(expensive(x))      # compile + warm cache
    t0 = time.monotonic()
    jax.block_until_ready(expensive(x))
    step_cost = time.monotonic() - t0
    # above async-dispatch latency, well below the blocked step cost
    deadline = max(step_cost / 4, 2e-3)

    def slow_step(params, opt_state, batch):
        # metrics are plain floats (ready instantly); the param update is
        # dispatched asynchronously -- without the block-before-clock fix
        # dt would only see the dispatch, not the compute
        return expensive(params), opt_state, {"loss": 0.1,
                                              "grad_norm": 1.0}

    tr = object.__new__(Trainer)
    tr.tc = TrainConfig(step_deadline_s=deadline)
    tr.step_fn = slow_step
    tr.checkpointer = None
    tr.slow_steps = 0
    _, _, hist = tr.run(x, {}, [None], log_every=0, log=lambda *_: None)
    assert tr.slow_steps == 1
    assert hist[0]["straggler"] == 1.0


# ------------------------------------------------------ bench-gate floor
def _bench_doc(rows=(), programs=()):
    return {"schema": [], "program_schema": [],
            "rows": list(rows), "programs": list(programs)}


def _row(us, primitive="all_reduce", flow="direct", nbytes=1024):
    return {"primitive": primitive, "flow": flow, "stage": "im",
            "nbytes": nbytes, "measured_us": us, "est_us": 1.0,
            "est_source": "analytic"}


def test_check_against_zero_seed_row_uses_absolute_floor(tmp_path):
    """A seed row with measured_us == 0 must not make the gate
    hair-trigger: fresh values inside tolerance * floor pass, genuinely
    regressed values still fail."""
    from benchmarks.run import check_against
    seed = tmp_path / "seed.json"
    fresh_ok = tmp_path / "ok.json"
    fresh_bad = tmp_path / "bad.json"
    seed.write_text(json.dumps(_bench_doc(rows=[_row(0.0)])))
    fresh_ok.write_text(json.dumps(_bench_doc(rows=[_row(9.0)])))
    fresh_bad.write_text(json.dumps(_bench_doc(rows=[_row(80.0)])))
    assert check_against(str(seed), str(fresh_ok),
                         tolerance=2.0, floor_us=5.0) == []
    failures = check_against(str(seed), str(fresh_bad),
                             tolerance=2.0, floor_us=5.0)
    assert len(failures) == 1 and "80.0us" in failures[0]


def test_check_against_gates_programs_section(tmp_path):
    """The programs section (train_step rows included) is gated by name
    with the same tolerance and floor."""
    from benchmarks.run import check_against

    def prow(us):
        return {"name": "train_step_overlap", "ops": 3, "measured_us": us,
                "plan_est_us": 1.0, "serial_est_us": 2.0,
                "est_source": "measured"}

    seed = tmp_path / "seed.json"
    fresh_ok = tmp_path / "ok.json"
    fresh_bad = tmp_path / "bad.json"
    seed.write_text(json.dumps(_bench_doc(programs=[prow(100.0)])))
    fresh_ok.write_text(json.dumps(_bench_doc(programs=[prow(150.0)])))
    fresh_bad.write_text(json.dumps(_bench_doc(programs=[prow(250.0)])))
    assert check_against(str(seed), str(fresh_ok), tolerance=2.0) == []
    failures = check_against(str(seed), str(fresh_bad), tolerance=2.0)
    assert len(failures) == 1 and "train_step_overlap" in failures[0]
    # seeds without a programs key (older trajectory docs) still gate rows
    old = tmp_path / "old.json"
    old.write_text(json.dumps({"rows": [_row(10.0)]}))
    fresh = tmp_path / "fresh.json"
    fresh.write_text(json.dumps(_bench_doc(rows=[_row(11.0)])))
    assert check_against(str(old), str(fresh), tolerance=2.0) == []
