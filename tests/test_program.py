"""Deferred CommProgram semantics (repro.core.program):

* recording defers dispatch (no events, symbolic values, op accounting);
* one-op programs are bit-identical to eager dispatch (the conformance
  contract holds through both paths);
* peephole fusion: a recorded rs+ag pair executes as one all_reduce --
  bit-identical, provenance-tagged (``fused_from``), verified in the HLO,
  and strictly cheaper in event count and estimated DCN bytes/seconds;
* the all_reduce -> rs+ag split rewrite (forced mode);
* same-group coalescing: the trainer's gradient sync dispatches one
  bucketed all-reduce, bit-identical to per-leaf psums;
* joint planning (planner.plan_program): dependency-safe interleaved order
  and the shared ICI/DCN budget;
* execute_async per-op futures with dependency-ordered dispatch;
* the error-feedback buffer for the compressed pod hop (trainer satellite):
  quantization-error decay vs the no-feedback flow over steps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import planner
from repro.core.comm import CommTrace
from repro.core.program import CommProgram, ProgramValue
from repro.testing import oracles, substrate


def _per_shard_aval(cube, payload_shape, dtype=jnp.float32):
    shape = (1,) * len(cube.dim_sizes) + tuple(payload_shape)
    return jax.ShapeDtypeStruct(shape, dtype)


# ------------------------------------------------------------- recording
def test_recording_defers_dispatch(cube_ring8):
    comm = cube_ring8.comm("1")
    with CommTrace() as tr:
        with comm.program(name="rec") as prog:
            a = prog.input(_per_shard_aval(cube_ring8, (2, 16)))
            b = comm.reduce_scatter(a, axis=2)
            c = comm.all_gather(b, axis=2)
            prog.output(c)
    assert tr.events == []                       # nothing dispatched
    assert isinstance(b, ProgramValue) and isinstance(c, ProgramValue)
    assert b.shape == (1, 2, 2) and c.shape == (1, 2, 16)
    assert len(prog._ops) == 2
    assert "reduce_scatter" in prog.describe()


def test_program_validation(cube_ring8, cube_2x2x2):
    comm = cube_ring8.comm("1")
    prog = cube_ring8.program()
    with prog:
        a = prog.input(_per_shard_aval(cube_ring8, (8,)))
        with pytest.raises(ValueError, match="different cube"):
            cube_2x2x2.comm("010").all_reduce(a)
        with pytest.raises(RuntimeError, match="still recording"):
            prog.lower()
        comm.all_reduce(a)
    with pytest.raises(ValueError, match="takes 1 inputs"):
        prog.execute()
    with pytest.raises(RuntimeError, match="already recorded"):
        with prog:
            pass


# ------------------------------------------------- one-op program parity
ONE_OP_CELLS = [
    ("cube_ring8", "1", "all_to_all", "naive"),
    ("cube_ring8", "1", "all_to_all", "pidcomm"),
    ("cube_2x4", "01", "reduce_scatter", "pr"),
    ("cube_2x4", "01", "all_gather", "pidcomm"),
    ("cube_2x2x2", "011", "all_reduce", "naive"),
    ("cube_2x2x2", "110", "all_reduce", "pidcomm"),
    ("cube_pod", "110", "all_reduce", "auto"),
]


@pytest.mark.parametrize("cube_name,bitmap,primitive,alg", ONE_OP_CELLS)
def test_one_op_program_bit_identical_to_eager(cube_name, bitmap, primitive,
                                               alg, request):
    """Eager single-op calls remain supported as one-op programs: the
    program path executes the identical registry body, bit-identically."""
    cube = request.getfixturevalue(cube_name)
    names = cube.dims_from_bitmap(bitmap)
    idx = tuple(cube.dim_names.index(d) for d in names)
    comm = cube.comm(names)
    nd = len(cube.dim_sizes)
    g = cube.group_size(names)
    x = substrate.integer_payload(cube, (2, 4 * g), seed=g)
    kwargs = {
        "all_to_all": dict(split_axis=nd + 1, concat_axis=nd + 1),
        "reduce_scatter": dict(axis=nd + 1),
        "all_gather": dict(axis=nd),
        "all_reduce": {},
    }[primitive]
    oracle = {
        "all_to_all": lambda: oracles.all_to_all(x, nd, idx, split_axis=1,
                                                 concat_axis=1),
        "reduce_scatter": lambda: oracles.reduce_scatter(x, nd, idx, axis=1),
        "all_gather": lambda: oracles.all_gather(x, nd, idx, axis=0),
        "all_reduce": lambda: oracles.all_reduce(x, nd, idx),
    }[primitive]()

    eager = substrate.run_per_shard(
        cube, lambda v: getattr(comm, primitive)(v, algorithm=alg,
                                                 **kwargs), x)
    with cube.program() as prog:
        a = prog.input(_per_shard_aval(cube, (2, 4 * g)))
        prog.output(getattr(comm, primitive)(a, algorithm=alg, **kwargs))
    via_prog = substrate.run_per_shard(cube, lambda v: prog.execute(v), x)
    np.testing.assert_array_equal(via_prog, eager)   # bit-identical
    np.testing.assert_array_equal(via_prog, oracle)


# ----------------------------------------------------------- rs+ag fusion
def _record_rs_ag(cube, comm, payload):
    prog = cube.program(name="rsag")
    with prog:
        a = prog.input(_per_shard_aval(cube, payload))
        axis = len(cube.dim_sizes) + 1
        b = comm.reduce_scatter(a, axis=axis)
        c = comm.all_gather(b, axis=axis)
        prog.output(c)
    return prog


def test_fused_rs_ag_equals_eager_all_reduce(cube_pod):
    """Acceptance: a recorded rs+ag pair executes as one all_reduce, with
    fused_from provenance on the CommTrace event, bit-identical to the
    eager all_reduce on the 8-device substrate."""
    comm = cube_pod.comm(("pod", "dp"))
    g = comm.group_size
    x = substrate.integer_payload(cube_pod, (2, 4 * g), seed=7)
    prog = _record_rs_ag(cube_pod, comm, (2, 4 * g))
    low = prog.lower()
    assert len(low.ops) == 1
    fused = low.ops[0]
    assert fused.primitive == "all_reduce"
    assert fused.fused_from == (0, 1) and not fused.coalesced

    with CommTrace() as tr:
        got = substrate.run_per_shard(cube_pod, lambda v: low.execute(v), x)
    eager = substrate.run_per_shard(cube_pod, lambda v: comm.all_reduce(v), x)
    np.testing.assert_array_equal(got, eager)        # bit-identical
    np.testing.assert_array_equal(got, oracles.all_reduce(x, 3, (0, 1)))
    [ev] = tr.events
    assert ev.primitive == "all_reduce" and ev.flow == "hierarchical"
    assert ev.program_id == prog.program_id
    assert ev.fused_from == (0, 1)


def test_fusion_strictly_reduces_events_and_bytes(cube_pod):
    """CommTrace accounting: fusion cuts the event count 2 -> 1 and the
    estimated DCN bytes and seconds strictly drop (the fused pod-crossing
    all_reduce takes the hierarchical split; the eager pair pays full-rate
    DCN on both hops)."""
    comm = cube_pod.comm(("pod", "dp"))
    g = comm.group_size
    x = substrate.integer_payload(cube_pod, (2, 4 * g), seed=3)
    axis = 4
    with CommTrace() as eager_tr:
        substrate.run_per_shard(
            cube_pod,
            lambda v: comm.all_gather(comm.reduce_scatter(v, axis=axis),
                                      axis=axis), x)
    prog = _record_rs_ag(cube_pod, comm, (2, 4 * g))
    low = prog.lower()
    with CommTrace() as fused_tr:
        substrate.run_per_shard(cube_pod, lambda v: low.execute(v), x)
    assert len(eager_tr.events) == 2 and len(fused_tr.events) == 1
    e_ici, e_dcn = eager_tr.total_bytes()
    f_ici, f_dcn = fused_tr.total_bytes()
    assert f_dcn < e_dcn
    assert sum(e.seconds for e in fused_tr.events) < \
        sum(e.seconds for e in eager_tr.events)
    s = fused_tr.summary()
    assert s["fused_events"] == 1 and s["fused_from_ops"] == 2
    assert s["programs"] == [prog.program_id]


def test_fused_program_hlo_is_one_all_reduce(cube_ring8):
    """Acceptance HLO check: the fused program lowers to the all-reduce op
    alone -- no reduce-scatter / all-gather survives -- while the eager pair
    lowers to both."""
    comm = cube_ring8.comm("1")
    x = substrate.integer_payload(cube_ring8, (2, 16), seed=5)
    eager_hlo = substrate.lowered_text(
        cube_ring8,
        lambda v: comm.all_gather(comm.reduce_scatter(v, axis=2), axis=2), x)
    assert "reduce_scatter" in eager_hlo or "reduce-scatter" in eager_hlo
    assert "all_gather" in eager_hlo or "all-gather" in eager_hlo

    low = _record_rs_ag(cube_ring8, comm, (2, 16)).lower()
    hlo = substrate.lowered_text(cube_ring8, lambda v: low.execute(v), x)
    assert "all_reduce" in hlo or "all-reduce" in hlo
    assert "reduce_scatter" not in hlo and "reduce-scatter" not in hlo
    assert "all_gather" not in hlo and "all-gather" not in hlo


def test_no_fusion_when_shard_is_consumed(cube_ring8):
    """The rs result escaping as a program output blocks the rewrite."""
    comm = cube_ring8.comm("1")
    prog = cube_ring8.program()
    with prog:
        a = prog.input(_per_shard_aval(cube_ring8, (2, 16)))
        b = comm.reduce_scatter(a, axis=2)
        c = comm.all_gather(b, axis=2)
        prog.output(b, c)                      # the shard itself is needed
    low = prog.lower()
    assert [o.primitive for o in low.ops] == ["reduce_scatter", "all_gather"]
    x = substrate.integer_payload(cube_ring8, (2, 16), seed=2)
    from repro.compat import shard_map
    spec = substrate.global_spec(cube_ring8, 2)
    shard, full = jax.jit(shard_map(
        lambda v: low.execute(v), mesh=cube_ring8.mesh, in_specs=spec,
        out_specs=(spec, spec), check_vma=False))(x)
    np.testing.assert_array_equal(
        np.asarray(shard), oracles.reduce_scatter(x, 1, (0,), axis=1))
    np.testing.assert_array_equal(np.asarray(full),
                                  oracles.all_reduce(x, 1, (0,)))


def test_split_all_reduce_rewrite(cube_ring8):
    """The reverse peephole: under forced mode an all_reduce becomes the
    rs+ag pair (provenance on both halves), bit-identical."""
    comm = cube_ring8.comm("1")
    prog = cube_ring8.program()
    with prog:
        a = prog.input(_per_shard_aval(cube_ring8, (16, 3)))
        prog.output(comm.all_reduce(a))
    low = prog.lower(split_all_reduce=True)
    prims = [o.primitive for o in low.ops]
    assert prims == ["reduce_scatter", "all_gather"]
    assert all(o.fused_from == (0,) for o in low.ops)
    x = substrate.integer_payload(cube_ring8, (16, 3), seed=9)
    got = substrate.run_per_shard(cube_ring8, lambda v: low.execute(v), x)
    np.testing.assert_array_equal(got, oracles.all_reduce(x, 1, (0,)))
    # default "cost" mode keeps the fused collective (the split ties, never
    # strictly wins, on the flat byte model)
    assert [o.primitive for o in prog.lower().ops] == ["all_reduce"]


# ------------------------------------------------------------- coalescing
def test_coalesced_gradient_sync_equals_per_leaf_psums(cube_pod):
    """Acceptance: sync_replicated_grads dispatches one coalesced bucketed
    program, bit-identical to eager per-leaf psums."""
    from repro import compat
    if compat.HAS_VMA:
        pytest.skip("vma jax: gradient reductions are autodiff-inserted")
    from repro.runtime.trainer import sync_replicated_grads
    specs = {"a": P(), "b": P(), "c": P(), "sharded": P(("pod", "dp", "tp"))}
    xa = substrate.integer_payload(cube_pod, (6,), seed=1)
    xb = substrate.integer_payload(cube_pod, (2, 5), seed=2)
    xc = substrate.integer_payload(cube_pod, (3,), seed=3)
    xs = substrate.integer_payload(cube_pod, (4,), seed=4)

    def via_sync(a, b, c, s):
        out = sync_replicated_grads(
            {"a": a, "b": b, "c": c, "sharded": s}, specs, cube_pod)
        return out["a"], out["b"], out["c"], out["sharded"]

    def via_eager(a, b, c, s):
        comm = cube_pod.comm(("pod", "dp", "tp"))
        return (comm.all_reduce(a), comm.all_reduce(b), comm.all_reduce(c), s)

    from repro.compat import shard_map
    sp = [substrate.global_spec(cube_pod, x.ndim - 3)
          for x in (xa, xb, xc, xs)]

    def run(f, trace):
        fn = jax.jit(shard_map(f, mesh=cube_pod.mesh, in_specs=tuple(sp),
                               out_specs=tuple(sp), check_vma=False))
        with trace:
            return [np.asarray(r) for r in fn(xa, xb, xc, xs)]

    coal_tr, eager_tr = CommTrace(), CommTrace()
    got = run(via_sync, coal_tr)
    want = run(via_eager, eager_tr)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)          # bit-identical
    np.testing.assert_array_equal(got[0], oracles.all_reduce(xa, 3,
                                                             (0, 1, 2)))
    # three per-leaf dispatches collapse into one bucketed dispatch
    assert len(eager_tr.events) == 3 and len(coal_tr.events) == 1
    [ev] = coal_tr.events
    assert len(ev.fused_from) == 3
    assert ev.payload_bytes == sum(e.payload_bytes for e in eager_tr.events)
    assert ev.flow == "hierarchical"                 # planner's pod pick


def test_multiple_coalesce_buckets_all_survive(cube_pod):
    """Regression: several distinct-group buckets in one program must each
    emit their own coalesced op (the trainer records a mixed-dims program,
    one group per replication pattern)."""
    prog = cube_pod.program()
    groups = [("pod",), ("pod", "tp"), ("pod", "dp", "tp")]
    vals = []
    with prog:
        for gi, dims in enumerate(groups):
            comm = cube_pod.comm(dims)
            for k in range(2):
                v = prog.input(_per_shard_aval(cube_pod, (4 + gi + k,)))
                vals.append(comm.all_reduce(v))
        prog.output(*vals)
    low = prog.lower()
    assert len(low.ops) == 3 and all(o.coalesced for o in low.ops)
    assert sorted(o.comm.dims for o in low.ops) == sorted(groups)
    xs = [substrate.integer_payload(cube_pod, (4 + gi + k,),
                                    seed=10 * gi + k)
          for gi in range(3) for k in range(2)]
    from repro.compat import shard_map
    sp = tuple(substrate.global_spec(cube_pod, 1) for _ in xs)
    got = jax.jit(shard_map(lambda *vs: low.execute(*vs),
                            mesh=cube_pod.mesh, in_specs=sp, out_specs=sp,
                            check_vma=False))(*xs)
    for (gi, k), x, r in zip([(g, k) for g in range(3) for k in range(2)],
                             xs, got):
        idx = tuple(cube_pod.dim_names.index(d) for d in groups[gi])
        np.testing.assert_array_equal(np.asarray(r),
                                      oracles.all_reduce(x, 3, idx))


def test_coalescing_respects_size_and_group(cube_pod):
    """A leaf above the coalescing threshold and a leaf on a different
    group each keep their own dispatch."""
    big = 1 << 19                                    # 2 MiB of f32 > 1 MiB
    prog = cube_pod.program()
    c_all = cube_pod.comm(("pod", "dp"))
    c_tp = cube_pod.comm(("tp",))
    with prog:
        i1 = prog.input(_per_shard_aval(cube_pod, (8,)))
        i2 = prog.input(_per_shard_aval(cube_pod, (12,)))
        i3 = prog.input(_per_shard_aval(cube_pod, (big,)))
        i4 = prog.input(_per_shard_aval(cube_pod, (8,)))
        prog.output(c_all.all_reduce(i1), c_all.all_reduce(i2),
                    c_all.all_reduce(i3), c_tp.all_reduce(i4))
    low = prog.lower()
    coalesced = [o for o in low.ops if o.coalesced]
    assert len(coalesced) == 1 and len(coalesced[0].fused_from) == 2
    assert len(low.ops) == 3                         # bucket + big + tp


def test_provenance_chains_to_recorded_ops(cube_pod):
    """fused_from always names *recorded* op ids: when coalescing absorbs
    an op that fusion created, the provenance chains through to the
    original rs/ag pair, not the synthetic intermediate id."""
    comm = cube_pod.comm(("pod", "dp"))
    prog = cube_pod.program()
    with prog:
        a = prog.input(_per_shard_aval(cube_pod, (2, 8)))
        fused = comm.all_gather(comm.reduce_scatter(a, axis=4), axis=4)
        b = prog.input(_per_shard_aval(cube_pod, (2, 8)))
        plain = comm.all_reduce(b)
        prog.output(fused, plain)
    low = prog.lower()
    [op] = low.ops
    assert op.coalesced
    assert sorted(op.fused_from) == [0, 1, 2]        # rs, ag, plain ar
    assert all(i < len(prog._ops) for i in op.fused_from)


# ---------------------------------------------------------- joint planning
def test_plan_program_escalation_parity(cube_pod):
    """A stage-requested additive all_reduce on a both-domain group is
    priced as the hierarchical flow the dispatcher actually executes (not
    the flat direct collective), while max-reductions and intra-pod groups
    keep the direct byte model."""
    mb = float(1 << 20)
    plan = planner.plan_program(cube_pod, [
        planner.ProgramOpSpec(0, "all_reduce", ("pod", "dp"), mb,
                              algorithm="im"),
        planner.ProgramOpSpec(1, "all_reduce", ("pod", "dp"), mb,
                              algorithm="im", op="max"),
        planner.ProgramOpSpec(2, "all_reduce", ("dp",), mb,
                              algorithm="im"),
        planner.ProgramOpSpec(3, "all_reduce", ("pod", "dp"), mb,
                              algorithm="ring"),
    ])
    direct = planner.estimate(cube_pod, "all_reduce", ("pod", "dp"), mb,
                              algorithm="direct")
    assert plan.estimates[0].algorithm == "hierarchical"
    assert plan.estimates[0].dcn_bytes < direct.dcn_bytes
    assert plan.estimates[1].algorithm == "direct"   # max cannot split
    assert plan.estimates[2].algorithm == "direct"   # intra-pod
    assert plan.estimates[3].algorithm == "direct"   # ring never escalates


def test_plan_program_order_and_budget(cube_pod):
    """plan_program levels ops by dependency, interleaves independent
    DCN/ICI-dominant ops, and prices each wave at the larger of the two
    domain budgets (never more than the serial sum)."""
    mb = float(1 << 20)
    ops = [
        planner.ProgramOpSpec(0, "all_reduce", ("pod", "dp"), mb),
        planner.ProgramOpSpec(1, "all_gather", ("tp",), mb),
        planner.ProgramOpSpec(2, "all_reduce", ("pod", "dp"), mb,
                              algorithm="compressed"),
        planner.ProgramOpSpec(3, "reduce_scatter", ("tp",), mb, deps=(1,)),
    ]
    plan = planner.plan_program(cube_pod, ops)
    assert set(plan.order) == {0, 1, 2, 3}
    assert plan.order.index(1) < plan.order.index(3)     # dependency-safe
    assert plan.levels[0] and plan.levels[1] == (3,)
    # wave 0 interleaves: a DCN-dominant op leads, an ICI one follows
    doms = [plan.estimates[i].dominant() for i in plan.levels[0][:2]]
    assert doms == ["dcn", "ici"]
    assert plan.seconds <= plan.serial_seconds + 1e-12
    assert plan.ici_bytes > 0 and plan.dcn_bytes > 0
    assert plan.estimates[2].algorithm == "compressed"
    with pytest.raises(ValueError, match="cyclic"):
        planner.plan_program(cube_pod, [
            planner.ProgramOpSpec(0, "all_reduce", ("tp",), mb, deps=(1,)),
            planner.ProgramOpSpec(1, "all_reduce", ("tp",), mb, deps=(0,)),
        ])


# ------------------------------------------------------------------ async
def test_execute_async_futures(cube_ring8):
    """Per-op futures dispatch in dependency order and memoize."""
    comm = cube_ring8.comm("1")
    prog = cube_ring8.program()
    with prog:
        a = prog.input(_per_shard_aval(cube_ring8, (2, 16)))
        b = comm.reduce_scatter(a, axis=2)
        c = comm.all_gather(b, axis=2)
        prog.output(c)
    low = prog.lower(fuse=False)                     # keep both ops live
    assert len(low.ops) == 2
    x = substrate.integer_payload(cube_ring8, (2, 16), seed=8)

    def per_shard(v):
        ex = low.execute_async(v)
        assert not any(f.done() for f in ex.futures)
        out = ex.futures[1].result()                 # forces the rs dep too
        assert all(f.done() for f in ex.futures)
        return out

    got = substrate.run_per_shard(cube_ring8, per_shard, x)
    np.testing.assert_array_equal(got, oracles.all_reduce(x, 1, (0,)))


# -------------------------------------------- error feedback (satellite)
def test_error_feedback_reduces_accumulated_error(cube_pod):
    """ROADMAP open item: persisting the compressed hop's quantization error
    across steps (error feedback) keeps the accumulated gradient-sum error
    bounded, while the no-feedback flow drifts linearly."""
    from repro import compat
    if compat.HAS_VMA:
        pytest.skip("vma jax: explicit sync path inactive")
    from repro.compat import shard_map
    from repro.runtime.trainer import sync_replicated_grads

    n = 2048
    rng = np.random.RandomState(0)
    x = (rng.randn(8, n) * 0.01).astype(np.float32)   # one row per device
    exact = x.sum(0)
    specs = {"g": P()}                               # logically replicated
    gspec = P(("pod", "dp", "tp"), None)
    efspec = P("pod", None, None)

    def step_ef(g, ef):
        out, new_ef = sync_replicated_grads(
            {"g": g}, specs, cube_pod, compress_pod=True, ef={"0": ef})
        return out["g"], new_ef["0"]

    def step_plain(g):
        return sync_replicated_grads({"g": g}, specs, cube_pod,
                                     compress_pod=True)["g"]

    fn_ef = jax.jit(shard_map(step_ef, mesh=cube_pod.mesh,
                              in_specs=(gspec, efspec),
                              out_specs=(gspec, efspec), check_vma=False))
    fn_plain = jax.jit(shard_map(step_plain, mesh=cube_pod.mesh,
                                 in_specs=(gspec,), out_specs=gspec,
                                 check_vma=False))

    steps = 8
    ef = jnp.zeros((2, 1, n), jnp.float32)
    acc_ef = np.zeros(n, np.float64)
    acc_plain = np.zeros(n, np.float64)
    with CommTrace() as tr:
        for _ in range(steps):
            out, ef = fn_ef(x, ef)
            acc_ef += np.asarray(out)[0].astype(np.float64)
            acc_plain += np.asarray(fn_plain(x))[0].astype(np.float64)
    want = steps * exact.astype(np.float64)
    err_ef = np.abs(acc_ef - want).max()
    err_plain = np.abs(acc_plain - want).max()
    assert err_plain > 0                             # compression is lossy
    assert err_ef < 0.5 * err_plain                  # feedback decays it
    # both paths dispatch the compressed flow (observable provenance)
    assert {e.flow for e in tr.events} == {"compressed"}


def test_error_feedback_optstate_plumbing(cube_pod):
    """init/spec helpers agree: buffers exist exactly for DCN-replicated
    leaves, shaped (n_pods, *leaf) and pod-sharded."""
    from repro import compat
    from repro.runtime.trainer import (
        TrainConfig, init_error_feedback, use_error_feedback)
    params = {"norm": np.zeros((6,), np.float32),
              "w": np.zeros((8, 3), np.float32)}
    specs = {"norm": P(), "w": P(("pod", "dp", "tp"))}
    ef = init_error_feedback(params, specs, cube_pod)
    assert set(ef) == {"0"}                          # "norm" flattens first
    assert ef["0"].shape == (2, 6)
    tc = TrainConfig(compress_pod_grads=True)
    assert tc.error_feedback                         # default on
    if not compat.HAS_VMA:
        assert use_error_feedback(tc, cube_pod)
    ring = substrate.fake_cube((8,), ("d",), {"d": 8})
    assert not use_error_feedback(tc, ring)          # no DCN: nothing to do
    assert not use_error_feedback(TrainConfig(), cube_pod)


# -------------------------------------------------- all_to_all chain merge
def test_merge_a2a_chain_bit_identical(cube_2x2x2):
    """§VII DLRM peephole: consecutive all_to_all ops over disjoint dims
    lower to ONE chained IR op (jointly planned over the union of dims)
    whose execution is bit-identical to the unfused program -- the merged
    form must chain, because a single joint multi-dim all_to_all orders
    blocks differently."""
    ca = cube_2x2x2.comm("100")
    cc = cube_2x2x2.comm("001")
    nd = len(cube_2x2x2.dim_sizes)
    rng = np.random.RandomState(3)
    x = rng.randn(2, 2, 2, 16).astype(np.float32)

    prog = cube_2x2x2.program(name="aa-chain")
    with prog:
        v = prog.input(_per_shard_aval(cube_2x2x2, (16,)))
        w = ca.all_to_all(v, split_axis=nd, concat_axis=nd)
        prog.output(cc.all_to_all(w, split_axis=nd, concat_axis=nd))

    merged = prog.lower()
    plain = prog.lower(merge_a2a=False)
    assert len(plain.ops) == 2
    assert len(merged.ops) == 1
    mop = merged.ops[0]
    assert mop.fused_from == (0, 1) and len(mop.chain) == 2
    assert mop.comm.dims == ("a", "c")               # planned over the union
    est = merged.plan.estimates[mop.op_id]
    assert est.primitive == "all_to_all"

    with CommTrace() as tr:
        got = substrate.run_per_shard(cube_2x2x2,
                                      lambda v: merged.execute(v), x)
    want = substrate.run_per_shard(cube_2x2x2,
                                   lambda v: plain.execute(v), x)
    np.testing.assert_array_equal(got, want)         # bit-identical
    # ... and both equal the composed oracles
    o = oracles.all_to_all(x, 3, (0,), split_axis=0, concat_axis=0)
    o = oracles.all_to_all(o, 3, (2,), split_axis=0, concat_axis=0)
    np.testing.assert_array_equal(got, o)
    # execution chains both stages under the merged op's provenance
    assert [e.primitive for e in tr.events] == ["all_to_all", "all_to_all"]
    assert all(e.fused_from == (0, 1) for e in tr.events)
    assert all(e.program_id == "aa-chain" for e in tr.events)


def test_merge_a2a_requires_disjoint_dims(cube_2x2x2):
    """Overlapping dim selections must NOT merge (the rewrite is only
    defined for disjoint groups), and an intermediate that is a program
    output is kept."""
    cab = cube_2x2x2.comm("110")
    cbc = cube_2x2x2.comm("011")
    nd = len(cube_2x2x2.dim_sizes)
    prog = cube_2x2x2.program(name="aa-overlap")
    with prog:
        v = prog.input(_per_shard_aval(cube_2x2x2, (16,)))
        w = cab.all_to_all(v, split_axis=nd, concat_axis=nd)
        prog.output(cbc.all_to_all(w, split_axis=nd, concat_axis=nd))
    assert len(prog.lower().ops) == 2                # shared dim "b": no merge

    ca = cube_2x2x2.comm("100")
    cc = cube_2x2x2.comm("001")
    prog2 = cube_2x2x2.program(name="aa-mid-out")
    with prog2:
        v = prog2.input(_per_shard_aval(cube_2x2x2, (16,)))
        w = ca.all_to_all(v, split_axis=nd, concat_axis=nd)
        out = cc.all_to_all(w, split_axis=nd, concat_axis=nd)
        prog2.output(w, out)                         # intermediate escapes
    assert len(prog2.lower().ops) == 2
