"""Multi-device numeric oracles, run in subprocesses so the fake device
count never leaks into this pytest process (which stays single-device),
plus the deprecation contract of the legacy ``Collectives`` shim."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_collectives_shim_warns_on_construction():
    """The per-call shim is deprecated: constructing it must emit a
    DeprecationWarning pointing at the communicator API (the differential
    cells themselves now run through ``cube.comm`` -- see test_conformance
    and the shim-equivalence test in test_comm)."""
    from repro.core.collectives import Collectives
    from repro.testing import substrate
    cube = substrate.fake_cube((8,), ("d",), {"d": 8})
    with pytest.warns(DeprecationWarning, match="cube.comm"):
        Collectives(cube)
    # the topology handle constructs the shim lazily: first .col access
    # warns, plain topology construction stays silent
    import warnings
    from repro.models.topology import Topology
    topo = Topology(cube=cube, dp=("d",), fsdp=("d",), tp=(), cp=(),
                    ep=(), etp=())
    with pytest.warns(DeprecationWarning, match="cube.comm"):
        topo.col
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        topo.col  # cached: no second warning


def _run(script, timeout=1800):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", script)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, p.stdout[-3000:] + p.stderr[-3000:]
    assert "ALL-OK" in p.stdout, p.stdout[-3000:]
    return p.stdout


def test_collective_oracles_8dev():
    """Every primitive x every Table-II algorithm stage vs numpy, plus
    multi-instance, tuple-dim groups, hierarchical DCN and rooted ops."""
    out = _run("multidev_check.py")
    assert "hierarchical AR lowers to RS/AR/AG schedule" in out


@pytest.mark.slow
def test_collective_oracles_16dev():
    """16-virtual-device sweep: 4-D hypercube with deep `1100`-style bitmap
    selections, the 16-wide ring, and the pod-crossing hierarchical HLO
    check, all through the communicator API (ROADMAP open item)."""
    out = _run("multidev16_check.py")
    assert "hierarchical AR lowers to RS/AR/AG schedule at 16 devices" in out


@pytest.mark.slow
def test_parallel_consistency_all_archs():
    """Sharded (pod x data x model; FSDP+TP+EP) loss and grads match the
    single-device oracle exactly (fp32) for all 10 architectures."""
    _run("parallel_check.py", timeout=3600)
