"""Property tests for the virtual hypercube (paper §IV).

``hypothesis`` is an optional dev dependency: with it installed the mapping
test is a randomized property test; without it the same check runs on a
fixed set of example decompositions so collection never hard-fails.
"""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core.hypercube import Hypercube
from repro.core import planner
from repro.testing.substrate import fake_cube as build


def _check_mapping(dims):
    cube = build((16, 16), ("data", "model"), dims)
    assert int(np.prod(cube.dim_sizes)) == 256
    # device order preserved (hierarchy-order mapping)
    assert list(cube.mesh.devices.reshape(-1)) == list(range(256))
    # bitmap round trip
    bitmap = "".join("1" if i % 2 == 0 else "0"
                     for i in range(len(cube.dim_names)))
    if "1" in bitmap:
        sel = cube.dims_from_bitmap(bitmap)
        assert cube.group_size(sel) * cube.num_instances(sel) == 256


if HAVE_HYPOTHESIS:
    @st.composite
    def cube_dims(draw):
        # total 256 devices (one pod), power-of-two dims
        n = draw(st.integers(1, 5))
        cuts = sorted(draw(st.lists(st.integers(0, 8), min_size=n - 1,
                                    max_size=n - 1)))
        bounds = [0] + cuts + [8]
        parts = [bounds[i + 1] - bounds[i] for i in range(n)]
        return {f"d{i}": 2 ** k for i, k in enumerate(parts)}

    @given(cube_dims())
    @settings(max_examples=50, deadline=None)
    def test_mapping_properties(dims):
        _check_mapping(dims)
else:
    @pytest.mark.parametrize("dims", [
        {"d0": 256},
        {"d0": 2, "d1": 128},
        {"d0": 16, "d1": 16},
        {"d0": 4, "d1": 8, "d2": 8},
        {"d0": 2, "d1": 2, "d2": 2, "d3": 32},
    ])
    def test_mapping_properties(dims):
        _check_mapping(dims)


def test_pod_boundary_rule():
    # splitting the pod boundary must be rejected
    with pytest.raises(ValueError, match="pod boundary"):
        build((2, 16, 16), ("pod", "data", "model"),
              {"a": 4, "b": 128})  # 128 not a suffix product incl. pod split
    # aligned decomposition passes and tags pod as DCN
    cube = build((2, 16, 16), ("pod", "data", "model"),
                 {"pod": 2, "dp": 16, "tp": 16})
    assert cube.dcn_dims == ("pod",)
    fast, slow = cube.split_fast_slow(("pod", "dp"))
    assert fast == ("dp",) and slow == ("pod",)


def test_power_of_two_rule():
    with pytest.raises(ValueError, match="power of two"):
        build((12, 16), ("data", "model"), {"a": 16, "b": 12})
    # non-power-of-two allowed only outermost (paper: channel count)
    cube = build((12, 16), ("data", "model"), {"a": 12, "b": 16})
    assert cube.ndev == 192


def test_planner_hierarchical_beats_flat():
    cube = build((2, 16, 16), ("pod", "data", "model"),
                 {"pod": 2, "dp": 16, "tp": 16})
    payload = 64 * 2**20
    hier = planner.estimate(cube, "all_reduce", ("pod", "dp"), payload)
    naive = planner.estimate(cube, "all_reduce", ("pod", "dp"), payload,
                             algorithm="naive")
    assert hier.algorithm == "hierarchical"
    assert hier.seconds < naive.seconds
    assert hier.dcn_bytes < naive.dcn_bytes / 4


def test_planner_matmul_roofline():
    t_small = planner.matmul_time(128, 128, 128)
    t_big = planner.matmul_time(8192, 8192, 8192)
    assert t_big > t_small
    # big matmul is compute-bound
    assert t_big == pytest.approx(2 * 8192**3 / planner.PEAK_BF16_FLOPS)
