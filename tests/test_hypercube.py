"""Property tests for the virtual hypercube (paper §IV)."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hypercube import Hypercube
from repro.core import planner


class FakeMesh:
    """Device-free stand-in: Hypercube.build only touches .devices shape and
    .axis_names for validation; reshape of a numpy arange works the same."""

    def __init__(self, shape, names):
        self.devices = np.arange(int(np.prod(shape))).reshape(shape)
        self.axis_names = names


def build(phys_shape, phys_names, dims):
    import repro.core.hypercube as hc

    class _H(Hypercube):
        pass
    mesh = FakeMesh(phys_shape, phys_names)
    # monkeypatch Mesh construction: we only need mapping metadata here
    orig = hc.Mesh
    hc.Mesh = lambda devs, names: type(
        "M", (), {"devices": devs, "axis_names": tuple(names)})()
    try:
        return Hypercube.build(mesh, dims)
    finally:
        hc.Mesh = orig


@st.composite
def cube_dims(draw):
    # total 256 devices (one pod), power-of-two dims
    n = draw(st.integers(1, 5))
    cuts = sorted(draw(st.lists(st.integers(0, 8), min_size=n - 1,
                                max_size=n - 1)))
    bounds = [0] + cuts + [8]
    parts = [bounds[i + 1] - bounds[i] for i in range(n)]
    return {f"d{i}": 2 ** k for i, k in enumerate(parts)}


@given(cube_dims())
@settings(max_examples=50, deadline=None)
def test_mapping_properties(dims):
    cube = build((16, 16), ("data", "model"), dims)
    assert int(np.prod(cube.dim_sizes)) == 256
    # device order preserved (hierarchy-order mapping)
    assert list(cube.mesh.devices.reshape(-1)) == list(range(256))
    # bitmap round trip
    bitmap = "".join("1" if i % 2 == 0 else "0"
                     for i in range(len(cube.dim_names)))
    if "1" in bitmap:
        sel = cube.dims_from_bitmap(bitmap)
        assert cube.group_size(sel) * cube.num_instances(sel) == 256


def test_pod_boundary_rule():
    # splitting the pod boundary must be rejected
    with pytest.raises(ValueError, match="pod boundary"):
        build((2, 16, 16), ("pod", "data", "model"),
              {"a": 4, "b": 128})  # 128 not a suffix product incl. pod split
    # aligned decomposition passes and tags pod as DCN
    cube = build((2, 16, 16), ("pod", "data", "model"),
                 {"pod": 2, "dp": 16, "tp": 16})
    assert cube.dcn_dims == ("pod",)
    fast, slow = cube.split_fast_slow(("pod", "dp"))
    assert fast == ("dp",) and slow == ("pod",)


def test_power_of_two_rule():
    with pytest.raises(ValueError, match="power of two"):
        build((12, 16), ("data", "model"), {"a": 16, "b": 12})
    # non-power-of-two allowed only outermost (paper: channel count)
    cube = build((12, 16), ("data", "model"), {"a": 12, "b": 16})
    assert cube.ndev == 192


def test_planner_hierarchical_beats_flat():
    cube = build((2, 16, 16), ("pod", "data", "model"),
                 {"pod": 2, "dp": 16, "tp": 16})
    payload = 64 * 2**20
    hier = planner.estimate(cube, "all_reduce", ("pod", "dp"), payload)
    naive = planner.estimate(cube, "all_reduce", ("pod", "dp"), payload,
                             algorithm="naive")
    assert hier.algorithm == "hierarchical"
    assert hier.seconds < naive.seconds
    assert hier.dcn_bytes < naive.dcn_bytes / 4


def test_planner_matmul_roofline():
    t_small = planner.matmul_time(128, 128, 128)
    t_big = planner.matmul_time(8192, 8192, 8192)
    assert t_big > t_small
    # big matmul is compute-bound
    assert t_big == pytest.approx(2 * 8192**3 / planner.PEAK_BF16_FLOPS)
